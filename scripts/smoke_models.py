"""Quick dev smoke: every arch smoke-config does loss + decode on CPU."""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import Model


def make_batch(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.default_rng(0)
    tok = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}
    if cfg.is_encoder_decoder:
        batch["enc_inputs"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.bfloat16)
    if cfg.num_prefix_tokens:
        batch["prefix"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


def main():
    ids = sys.argv[1:] or ARCH_IDS
    for arch in ids:
        cfg = get_config(arch).smoke()
        model = Model(cfg)
        t0 = time.time()
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg)
        loss, metrics = jax.jit(model.loss_fn)(params, batch)
        assert jnp.isfinite(loss), (arch, loss)
        cache = model.init_cache(2, 64)
        logits, cache = jax.jit(model.decode_step)(
            params, cache, jnp.ones((2, 1), jnp.int32))
        assert logits.shape == (2, cfg.padded_vocab)
        assert jnp.all(jnp.isfinite(logits))
        print(f"{arch:24s} loss={float(loss):8.4f} "
              f"params={model.param_count()/1e6:7.2f}M  "
              f"analytic={cfg.param_count()/1e6:7.2f}M  "
              f"({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
