import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration tool: relower a cell, print the 3 roofline terms and the
top collective contributors (the dry-run 'profile').

    PYTHONPATH=src python scripts/hillclimb.py deepseek-v3-671b train_4k
"""
import sys
import time

import jax

from repro.configs import SHAPES, get_config
from repro.distributed.hlo_analysis import (collective_bytes, hlo_stats,
                                            top_collectives)
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.launch.specs import build_cell


def analyze(arch: str, shape: str, save_hlo: str = ""):
    t0 = time.time()
    mesh = make_production_mesh()
    with mesh:
        cell = build_cell(arch, shape, mesh)
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums,
                           ).lower(*cell.args).compile()
        hlo = compiled.as_text()
    if save_hlo:
        open(save_hlo, "w").write(hlo)
    st = hlo_stats(hlo)
    coll = collective_bytes(hlo)
    t_comp = st.flops / PEAK_FLOPS_BF16
    t_mem = st.dot_bytes / HBM_BW
    t_coll = coll.total_bytes / ICI_BW
    print(f"== {arch} {shape}  (compile {time.time()-t0:.0f}s)")
    print(f"   compute {t_comp:.3f}s | memory {t_mem:.3f}s | "
          f"collective {t_coll:.3f}s   flops/dev={st.flops:.3e}")
    print(f"   collective bytes by kind: "
          + ", ".join(f"{k}={v:.2e}" for k, v in coll.bytes_by_kind.items()))
    print("   top collectives (kind, weighted bytes/dev, type, count):")
    for kind, b, ty, cnt in top_collectives(hlo):
        print(f"     {kind:20s} {b:.3e}  {ty[:64]:64s} x{cnt}")
    return t_comp, t_mem, t_coll


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "deepseek-v3-671b"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    save = sys.argv[3] if len(sys.argv) > 3 else ""
    analyze(arch, shape, save)
