"""Step-atomic sharded checkpointing with elastic restore.

Layout (one directory per step, atomically renamed into place):

    <root>/step_000120/
        manifest.json      # tree structure, shapes, dtypes, step, wall time
        leaf_00000.npy ...# one file per pytree leaf (bf16 stored as u16)

Guarantees exercised by tests:
  * atomicity: a crash mid-save never corrupts the latest checkpoint
    (tmp dir + os.replace);
  * restart: restore() returns a state tree identical to what was saved;
  * elasticity: restore(sharding=...) re-lays the arrays out on a
    *different* mesh than the one that saved them (full-array files are
    mesh-agnostic; per-shard streaming is the documented scale-up path);
  * retention: keep_last_k garbage-collects old steps, never the newest.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

# Version compat: jax >= 0.6 exposes jax.tree.flatten_with_path; 0.4.x only
# has the tree_util spelling.
_flatten_with_path = getattr(jax.tree, "flatten_with_path", None) or \
    jax.tree_util.tree_flatten_with_path


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, root: str, keep_last_k: int = 3):
        self.root = root
        self.keep = keep_last_k
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any) -> str:
        leaves, treedef = jax.tree.flatten(state)
        paths = [_path_str(p) for p, _ in _flatten_with_path(state)[0]]
        tmp = os.path.join(self.root, f".tmp_step_{step:06d}_{os.getpid()}")
        final = os.path.join(self.root, f"step_{step:06d}")
        os.makedirs(tmp, exist_ok=True)
        manifest: Dict[str, Any] = {
            "step": step, "time": time.time(), "leaves": []}
        for i, (leaf, path) in enumerate(zip(leaves, paths)):
            arr = np.asarray(jax.device_get(leaf))
            dtype = str(arr.dtype)
            if dtype == "bfloat16":
                arr = arr.view(np.uint16)
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
            manifest["leaves"].append(
                {"path": path, "file": fname, "dtype": dtype,
                 "shape": list(arr.shape)})
        manifest["treedef"] = jax.tree_util.tree_structure(state).__repr__()
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        self._gc()
        return final

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, like: Any, step: Optional[int] = None,
                sharding: Any = None) -> Any:
        """Restore into the structure of ``like``.

        ``sharding``: optional pytree (matching ``like``) of NamedShardings —
        pass shardings built on the *current* mesh for elastic restore.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = os.path.join(self.root, f"step_{step:06d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree.flatten(like)
        if len(manifest["leaves"]) != len(leaves_like):
            raise ValueError("checkpoint/like structure mismatch: "
                             f"{len(manifest['leaves'])} vs {len(leaves_like)}")
        shard_leaves = (jax.tree.leaves(sharding) if sharding is not None
                        else [None] * len(leaves_like))
        out = []
        for rec, leaf_like, sh in zip(manifest["leaves"], leaves_like,
                                      shard_leaves):
            arr = np.load(os.path.join(d, rec["file"]), allow_pickle=False)
            if rec["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16.dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, out)

    # --------------------------------------------------------------- gc
    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:06d}"),
                          ignore_errors=True)
