"""Static HLO analysis: collective bytes (and a while-loop-aware walker).

``compiled.cost_analysis()`` gives FLOPs/bytes but no collective traffic, and
XLA's cost analysis does not multiply while-loop bodies by their trip count.
This module parses the (post-SPMD-partitioning) HLO text:

  * splits it into named computations,
  * finds while loops and recovers their trip count from the loop-condition
    computation (scan loops compare the induction variable against a
    constant),
  * sums per-device link traffic of every collective, weighting ops inside
    while bodies by the trip count.

Per-device traffic model (ring algorithms, group size n):
  all-gather:          out_bytes * (n-1)/n
  reduce-scatter:      out_bytes * (n-1)
  all-reduce:          out_bytes * 2(n-1)/n
  all-to-all:          out_bytes * (n-1)/n
  collective-permute:  out_bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^=]*\}|\[[\dx,]+\]<=\[\d+\])")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, first.count(",") + 1)
    # iota form: [groups,size]<=[total] (possibly [a,b,c]... -> last dim)
    dims = re.findall(r"\d+", g.split("<=")[0])
    return int(dims[-1]) if dims else default


_FACTORS = {
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    total_bytes: float

    def merged(self) -> Dict[str, float]:
        out = dict(self.bytes_by_kind)
        out["total"] = self.total_bytes
        return out


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            # computation headers are top-level lines "…%name (params) -> type {"
            if (line and not line[0].isspace() and "->" in line
                    and line.rstrip().endswith("{")):
                tokens = line.replace("ENTRY", "").strip().split()
                if tokens:
                    cur = tokens[0].lstrip("%")
                    comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Largest integer constant in the loop condition (scan: iter < L)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def collective_bytes(hlo: str, default_group: int = 16) -> CollectiveStats:
    comps = _split_computations(hlo)
    memo: Dict[str, Tuple[Dict[str, float], Dict[str, int]]] = {}

    def walk(name: str) -> Tuple[Dict[str, float], Dict[str, int]]:
        if name in memo:
            return memo[name]
        memo[name] = ({}, {})  # cycle guard
        by: Dict[str, float] = {}
        cnt: Dict[str, int] = {}
        for line in comps.get(name, ()):
            cm = _COLLECTIVE_RE.search(line)
            if cm:
                ty = cm.group(1) or cm.group(2)
                kind = cm.group(3)
                if "-done(" in line:
                    continue  # counted at -start
                n = _group_size(line, default_group)
                b = shape_bytes(ty) * _FACTORS[kind](n)
                by[kind] = by.get(kind, 0.0) + b
                cnt[kind] = cnt.get(kind, 0) + 1
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips = _trip_count(comps.get(cond, []))
                sub_by, sub_cnt = walk(body)
                for k, v in sub_by.items():
                    by[k] = by.get(k, 0.0) + trips * v
                for k, v in sub_cnt.items():
                    cnt[k] = cnt.get(k, 0) + trips * v
            for call in re.finditer(r"(?:call|fusion)\(.*?to_apply=%?([\w\.\-]+)", line):
                sub_by, sub_cnt = walk(call.group(1))
                for k, v in sub_by.items():
                    by[k] = by.get(k, 0.0) + v
                for k, v in sub_cnt.items():
                    cnt[k] = cnt.get(k, 0) + v
        memo[name] = (by, cnt)
        return memo[name]

    # entry computation: the one defined with ENTRY; fall back to scanning all
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # sum everything not referenced as a body (conservative fallback)
        by: Dict[str, float] = {}
        cnt: Dict[str, int] = {}
        for name in comps:
            sub_by, sub_cnt = walk(name)
            for k, v in sub_by.items():
                by[k] = by.get(k, 0.0) + v
            for k, v in sub_cnt.items():
                cnt[k] = cnt.get(k, 0) + v
    else:
        by, cnt = walk(entry)
    return CollectiveStats(by, cnt, sum(by.values()))


def top_collectives(hlo: str, k: int = 12, default_group: int = 16):
    """Largest collective contributors: (kind, weighted bytes, result type,
    count) — while-loop trip counts applied.  The §Perf iteration loop's
    'profile'."""
    comps = _split_computations(hlo)
    # compute trip multiplier for each computation reachable from entry
    mult: Dict[str, int] = {}

    def walk(name: str, m: int):
        if mult.get(name, 0) >= m:
            return
        mult[name] = max(mult.get(name, 0), m)
        for line in comps.get(name, ()):
            wm = _WHILE_RE.search(line)
            if wm:
                trips = _trip_count(comps.get(wm.group(1), []))
                walk(wm.group(2), m * trips)
            cm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", line)
            if cm and cm.group(1) in comps:
                walk(cm.group(1), m)

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            entry = m.group(1) if m else None
            break
    if entry:
        walk(entry, 1)
    agg: Dict[tuple, list] = {}
    for name, m in mult.items():
        for line in comps.get(name, ()):
            cm = _COLLECTIVE_RE.search(line)
            if not cm or "-done(" in line:
                continue
            ty = cm.group(1) or cm.group(2)
            kind = cm.group(3)
            n = _group_size(line, default_group)
            b = shape_bytes(ty) * _FACTORS[kind](n) * m
            key = (kind, ty)
            if key not in agg:
                agg[key] = [0.0, 0]
            agg[key][0] += b
            agg[key][1] += m
    rows = sorted(((kind, v[0], ty, v[1]) for (kind, ty), v in agg.items()),
                  key=lambda r: -r[1])
    return rows[:k]


def while_trip_counts(hlo: str) -> Dict[str, int]:
    """body-computation -> trip count, for FLOP rescaling."""
    comps = _split_computations(hlo)
    out = {}
    for lines in comps.values():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                out[wm.group(2)] = _trip_count(comps.get(wm.group(1), []))
    return out


# ------------------------------------------------- host callbacks/transfers
#
# The contract layer (repro.analysis.contracts) asserts that hot paths never
# smuggle a host round-trip into a device loop: a python callback custom-call
# or an infeed/outfeed/send/recv inside a while body serializes every trip on
# the host.  This walker finds such ops and reports whether each sits inside
# a while body (with the recovered trip count, so the serialization cost is
# trip-weighted like the collective model above).

_HOST_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(infeed|outfeed|send-done|recv-done|send|recv|copy-start)\(")
_CUSTOM_CALL_RE = re.compile(r"custom-call\(")
_CALL_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')
# custom-call targets that round-trip through the host python runtime
_HOST_TARGET_RE = re.compile(r"callback|host", re.IGNORECASE)


def _reach_multipliers(hlo: str, comps: Dict[str, List[str]]) -> Dict[str, int]:
    """computation -> trip multiplier reachable from ENTRY (1 outside
    loops, product of trip counts inside nested while bodies)."""
    mult: Dict[str, int] = {}

    def walk(name: str, m: int):
        if mult.get(name, 0) >= m:
            return
        mult[name] = max(mult.get(name, 0), m)
        for line in comps.get(name, ()):
            wm = _WHILE_RE.search(line)
            if wm:
                trips = _trip_count(comps.get(wm.group(1), []))
                walk(wm.group(1), m)
                walk(wm.group(2), m * trips)
            cm = re.search(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)",
                           line)
            if cm and cm.group(1) in comps:
                walk(cm.group(1), m)

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            entry = m.group(1) if m else None
            break
    if entry and entry in comps:
        walk(entry, 1)
    else:
        for name in comps:
            mult.setdefault(name, 1)
    return mult


def host_transfer_ops(hlo: str) -> List[dict]:
    """Host round-trip ops: infeed/outfeed/send/recv and python-callback
    custom-calls, each tagged with its computation, whether that
    computation runs inside a while loop, and the trip multiplier.

    Benign custom-calls (Sharding, SPMD reshape markers, TopK, ...) are
    NOT reported — only targets matching ``callback``/``host``.
    """
    comps = _split_computations(hlo)
    bodies = set(while_trip_counts(hlo))
    mult = _reach_multipliers(hlo, comps)
    out: List[dict] = []
    for name, lines in comps.items():
        in_while = name in bodies or mult.get(name, 1) > 1
        for line in lines:
            hm = _HOST_OP_RE.search(line)
            op = None
            target = ""
            if hm:
                op = hm.group(1)
            elif _CUSTOM_CALL_RE.search(line):
                tm = _CALL_TARGET_RE.search(line)
                if tm and _HOST_TARGET_RE.search(tm.group(1)):
                    op = "custom-call"
                    target = tm.group(1)
            if op is None:
                continue
            out.append({"op": op, "target": target, "computation": name,
                        "in_while": bool(in_while),
                        "trips": int(mult.get(name, 1))})
    return out


def while_body_stats(hlo: str, default_group: int = 16
                     ) -> Dict[str, Tuple[int, CollectiveStats]]:
    """Per-while-body collective traffic for ONE trip (un-multiplied),
    plus the recovered trip count: body -> (trips, stats).

    This is the per-pivot/per-step view: ``collective_bytes`` answers
    "how much total", this answers "how much per iteration" so budgets
    can be declared per pivot regardless of the loop bound.
    """
    comps = _split_computations(hlo)
    trips = while_trip_counts(hlo)
    out: Dict[str, Tuple[int, CollectiveStats]] = {}
    for body, t in trips.items():
        by: Dict[str, float] = {}
        cnt: Dict[str, int] = {}

        def walk(name: str, seen=None):
            seen = set() if seen is None else seen
            if name in seen:
                return
            seen.add(name)
            for line in comps.get(name, ()):
                cm = _COLLECTIVE_RE.search(line)
                if cm and "-done(" not in line:
                    ty = cm.group(1) or cm.group(2)
                    kind = cm.group(3)
                    n = _group_size(line, default_group)
                    b = shape_bytes(ty) * _FACTORS[kind](n)
                    by[kind] = by.get(kind, 0.0) + b
                    cnt[kind] = cnt.get(kind, 0) + 1
                sub = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", line)
                if sub and sub.group(1) in comps:
                    walk(sub.group(1), seen)

        walk(body)
        out[body] = (t, CollectiveStats(by, cnt, sum(by.values())))
    return out


# ---------------------------------------------------------------- FLOPs
#
# XLA's HloCostAnalysis (exposed via compiled.cost_analysis()) does NOT
# multiply while-loop bodies by their trip count, so any scanned-layer model
# is undercounted by ~num_layers.  We therefore count dot FLOPs and dot
# operand/result HBM bytes ourselves, with while multipliers.  Dots dominate
# transformer FLOPs; elementwise ops are ignored for FLOPs but approximated
# for bytes via instruction result sizes.

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+(\S+?)\(")
_DOT_PAREN_RE = re.compile(r"(?:dot|convolution)\(([^)]*)\)")
_NAME_REF_RE = re.compile(r"%([\w\.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")


def _shape_dims(type_str: str) -> Tuple[str, Tuple[int, ...]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", ()
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return m.group(1), dims


@dataclasses.dataclass
class HloStats:
    flops: float            # dot/conv FLOPs, while-weighted, per device
    dot_bytes: float        # dot operand+result bytes, while-weighted
    instr_bytes: float      # all instruction result bytes, while-weighted


def hlo_stats(hlo: str) -> HloStats:
    comps = _split_computations(hlo)
    memo: Dict[str, Tuple[float, float, float]] = {}

    def walk(name: str) -> Tuple[float, float, float]:
        if name in memo:
            return memo[name]
        memo[name] = (0.0, 0.0, 0.0)
        flops = dot_b = instr_b = 0.0
        symtab: Dict[str, str] = {}
        lines = comps.get(name, ())
        for line in lines:
            im = _INSTR_RE.match(line)
            if im:
                symtab[im.group(1)] = im.group(2)
        for line in lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            res_type = im.group(2)
            op = im.group(3)
            instr_b += shape_bytes(res_type)
            if op in ("dot", "convolution"):
                dm = _DOT_PAREN_RE.search(line)
                lm = _LHS_C_RE.search(line)
                if dm:
                    names = _NAME_REF_RE.findall(dm.group(1))
                    lhs_type = symtab.get(names[0], "") if names else ""
                    rhs_type = symtab.get(names[1], "") if len(names) > 1 else ""
                    _, lhs_dims = _shape_dims(lhs_type)
                    k = 1
                    if lm is not None:
                        cdims = [int(x) for x in lm.group(1).split(",") if x]
                        for c in cdims:
                            if c < len(lhs_dims):
                                k *= lhs_dims[c]
                    _, res_dims = _shape_dims(res_type)
                    out_n = 1
                    for d in res_dims:
                        out_n *= d
                    flops += 2.0 * out_n * k
                    dot_b += (shape_bytes(lhs_type) + shape_bytes(rhs_type)
                              + shape_bytes(res_type))
            wm = _WHILE_RE.search(line)
            if wm:
                trips = _trip_count(comps.get(wm.group(1), []))
                f, db, ib = walk(wm.group(2))
                flops += trips * f
                dot_b += trips * db
                instr_b += trips * ib
            else:
                cm = _CALLS_RE.search(line) or _TO_APPLY_RE.search(line)
                if cm and cm.group(1) in comps:
                    f, db, ib = walk(cm.group(1))
                    flops += f
                    dot_b += db
                    instr_b += ib
        memo[name] = (flops, dot_b, instr_b)
        return memo[name]

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry and entry in comps:
        f, db, ib = walk(entry)
    else:
        f = db = ib = 0.0
        for name in comps:
            ff, dd, ii = walk(name)
            f, db, ib = f + ff, db + dd, ib + ii
    return HloStats(f, db, ib)
