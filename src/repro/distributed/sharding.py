"""Logical-axis -> mesh-axis sharding rules with divisibility fallbacks.

Strategy (MaxText-style, adapted):
  * TP: the first logical axis in TP_PRIORITY whose dim is divisible by the
    ``model`` mesh axis gets sharded over it (one TP dim per param).
  * FSDP/ZeRO: the largest remaining dim divisible by the full data-parallel
    degree (pod*data) is sharded over those axes — parameters AND optimizer
    moments, giving ZeRO-3-style memory scaling.  Tiny params (< 2^16
    elements) stay replicated to avoid collective chatter.
  * 'layers' (scan) dims are never sharded.

Everything degrades gracefully: a dim that does not divide simply stays
unsharded (recorded by ``explain()`` for the roofline notes), so qwen2's 12
heads or mixtral's 8 experts never produce invalid shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axes eligible for tensor parallelism, in priority order.
TP_PRIORITY = (
    "vocab", "experts", "mlp", "heads", "ssm_inner", "kv_heads",
    "qlora", "kvlora", "ssm_state",
)
FSDP_MIN_SIZE = 1 << 16


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    dp_axes: Tuple[str, ...]        # ("data",) or ("pod", "data")
    tp_axis: str = "model"
    # decode-time: replicate per-token activations over dp so GSPMD keeps
    # weights resident (sharded) and all-reduces the (tiny) activations,
    # instead of all-gathering weights every layer (§Perf iteration 2)
    replicate_decode_activations: bool = False
    # sequence-parallel attention for archs whose head count does not
    # divide the model axis (smollm 9H, qwen2 12H, ...): shard S over
    # 'model' inside the attention block instead of replicating the whole
    # attention computation on every model shard (§Perf smollm iteration)
    seq_parallel_attn: bool = False

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])

    # ------------------------------------------------------------ params
    def param_pspec(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
        entries: list = [None] * len(shape)
        # 1) tensor parallelism
        placed_tp = False
        for name in TP_PRIORITY:
            if placed_tp:
                break
            for i, a in enumerate(axes):
                if a == name and shape[i] % self.tp_size == 0 and shape[i] >= self.tp_size:
                    entries[i] = self.tp_axis
                    placed_tp = True
                    break
        # 2) FSDP over the largest remaining dim
        if int(np.prod(shape)) >= FSDP_MIN_SIZE:
            order = sorted(range(len(shape)), key=lambda i: -shape[i])
            for i in order:
                if entries[i] is not None or axes[i] == "layers":
                    continue
                if shape[i] % self.dp_size == 0 and shape[i] >= self.dp_size:
                    entries[i] = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
                    break
        return P(*entries)

    def param_sharding(self, abstract_params, axes_tree) -> Any:
        return jax.tree.map(
            lambda p, ax: NamedSharding(self.mesh, self.param_pspec(p.shape, ax)),
            abstract_params, axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    # -------------------------------------------------------- activations
    def batch_pspec(self, batch_size: int, extra_dims: int = 1) -> P:
        """(B, ...) activation/input sharding: B over dp when divisible."""
        b = self._dp_entry(batch_size)
        return P(b, *([None] * extra_dims))

    def _dp_entry(self, dim: int):
        if dim % self.dp_size == 0 and dim >= self.dp_size:
            return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        # try data-only (multi-pod, batch divisible by data but not pod*data)
        if "data" in self.dp_axes and dim % self.mesh.shape["data"] == 0 and dim >= self.mesh.shape["data"]:
            return "data"
        return None

    def cache_pspec(self, shape: Sequence[int], kind: str) -> P:
        """Decode-cache shardings.

        kv:    (L, B, S, KV, hd)  -> B over dp, S over model
        mla:   (L, B, S, r)       -> B over dp, S over model
        state: (L, B, nh, N, hp)  -> B over dp, nh over model if divisible
        conv:  (L, B, ck, Ch)     -> B over dp, Ch over model if divisible
        """
        L, B = shape[0], shape[1]
        b = self._dp_entry(B)
        if kind in ("kv", "mla"):
            S = shape[2]
            s_entry = None
            if S % self.tp_size == 0:
                s_entry = self.tp_axis
                if b is None:
                    # B undivisible (e.g. long_500k B=1): spread S over dp too
                    dp = self.dp_axes if len(self.dp_axes) > 1 else (self.dp_axes[0],)
                    if S % (self.tp_size * self.dp_size) == 0:
                        s_entry = tuple(dp) + (self.tp_axis,)
            rest = [None] * (len(shape) - 3)
            return P(None, b, s_entry, *rest)
        if kind == "state":
            nh = shape[2]
            h_entry = self.tp_axis if nh % self.tp_size == 0 and nh >= self.tp_size else None
            return P(None, b, h_entry, *([None] * (len(shape) - 3)))
        if kind == "conv":
            Ch = shape[-1]
            c_entry = self.tp_axis if Ch % self.tp_size == 0 else None
            return P(*([None, b] + [None] * (len(shape) - 3) + [c_entry]))
        raise ValueError(kind)

    def named(self, pspec: P) -> NamedSharding:
        return NamedSharding(self.mesh, pspec)

    # ------------------------------------------------------------- report
    def explain(self, abstract_params, axes_tree) -> Dict[str, str]:
        """path -> 'shape axes -> pspec' map for DESIGN/roofline notes."""
        out = {}
        flat_p = jax.tree.flatten_with_path(abstract_params)[0]
        flat_a = jax.tree.leaves(
            axes_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        for (path, p), ax in zip(flat_p, flat_a):
            key = "/".join(str(getattr(k, "key", k)) for k in path)
            out[key] = f"{p.shape} {ax} -> {self.param_pspec(p.shape, ax)}"
        return out


def make_rules(mesh: Mesh) -> ShardingRules:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return ShardingRules(mesh=mesh, dp_axes=dp)
