"""Ambient sharding-rules context for activation constraints.

Model code calls ``constrain(x, ("dp", None, "tp"))`` at key points; when a
``ShardingRules`` context is active (dry-run / real launch) this becomes a
``with_sharding_constraint`` that pins the batch/expert/sequence dims to the
mesh — which is what keeps GSPMD from replicating activations inside scanned
while-loops.  With no active context (unit tests, single-device smoke) it is
a no-op.

Entry vocabulary per dim:
  None      leave unsharded / let GSPMD propagate
  "dp"      data-parallel axes (pod, data) if the dim divides
  "tp"      model axis if the dim divides
  "dp+tp"   both (e.g. very long sequence dims)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules

_STATE = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def _entry(rules: ShardingRules, dim: int, tag):
    if tag is None:
        return None
    if tag == "dp":
        return rules._dp_entry(dim)
    if tag == "tp":
        return rules.tp_axis if dim % rules.tp_size == 0 and dim >= rules.tp_size else None
    if tag == "dp+tp":
        total = rules.dp_size * rules.tp_size
        if dim % total == 0 and dim >= total:
            return tuple(rules.dp_axes) + (rules.tp_axis,)
        return _entry(rules, dim, "tp")
    raise ValueError(tag)


def constrain(x: jax.Array, spec: Sequence) -> jax.Array:
    rules = current_rules()
    if rules is None:
        return x
    assert len(spec) == x.ndim, (spec, x.shape)
    entries = [_entry(rules, d, t) for d, t in zip(x.shape, spec)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*entries)))


def constrain_decode_act(x: jax.Array) -> jax.Array:
    """Per-token decode activations: batch over dp normally; under the
    replicate_decode_activations perf mode the *embedding* dim is sharded
    over dp instead — aligning activations with the weights' FSDP
    (contraction) dim so projections become tiny activation partial-sums
    instead of per-layer 36MB weight all-gathers (§Perf iteration 3)."""
    rules = current_rules()
    if rules is None:
        return x
    if rules.replicate_decode_activations:
        return constrain(x, (None,) * (x.ndim - 1) + ("dp",))
    return constrain(x, ("dp",) + (None,) * (x.ndim - 1))


def constrain_cache(x: jax.Array, kind: str) -> jax.Array:
    """Decode-cache constraint matching ShardingRules.cache_pspec (layer dim
    stripped): kv/mla -> B over dp, S over model (+dp when B undivisible);
    state/conv -> B over dp, heads/channels over model."""
    rules = current_rules()
    if rules is None:
        return x
    pspec = rules.cache_pspec((1,) + x.shape, kind)
    inner = P(*tuple(pspec)[1:])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, inner))
