"""mixtral-8x22b — [moe] 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,                  # every FFN is MoE
    moe_d_ff=16384,
    num_experts=8,
    num_experts_per_tok=2,
    vocab_size=32768,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    opt_dtype="bfloat16",    # 141B params: bf16 moments to fit one pod
    source="arXiv:2401.04088; hf",
)
