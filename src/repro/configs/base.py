"""Architecture configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``.  The model
stack in ``repro.models`` is driven entirely by this dataclass — there is no
per-arch model code, only per-arch configs (plus family-level layer code).

Shapes (the per-arch input-shape set from the brief) are global:
    train_4k      seq_len=4096    global_batch=256   (train_step)
    prefill_32k   seq_len=32768   global_batch=32    (prefill_step)
    decode_32k    seq_len=32768   global_batch=128   (serve_step, 1 new token)
    long_500k     seq_len=524288  global_batch=1     (serve_step, 1 new token)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

VOCAB_PAD_MULTIPLE = 128  # vocab padded so TP over 16-way model axis divides


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free layers
    num_kv_heads: int
    d_ff: int                        # dense FFN width (0 if every layer is MoE/SSM)
    vocab_size: int

    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- attention flavour ---
    attention: str = "gqa"           # gqa | mla | none
    sliding_window: int = 0          # 0 = full attention
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    prefix_lm: bool = False          # PaliGemma-style full attention on prefix
    # --- MLA (DeepSeek) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert FFN width
    first_k_dense: int = 0           # leading dense layers (DeepSeek-V3 uses 3)
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_period: int = 0             # hybrid: 1 attention layer every `period`
                                     # layers (rest SSM); 0 = not hybrid
    moe_period: int = 0              # hybrid: MoE FFN every `period` layers
    # --- encoder/decoder & multimodal ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper-base: 30 s of audio frames
    num_prefix_tokens: int = 0       # VLM: # of precomputed patch embeddings
    frontend: str = "none"           # none | audio_stub | vision_stub
    # --- extra heads ---
    mtp_depth: int = 0               # DeepSeek-V3 multi-token prediction depth
    # --- numerics / training ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"                # silu (SwiGLU) | gelu (plain MLP)
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    opt_dtype: str = "float32"       # bf16 moments for the 398B/671B MoEs
    remat: str = "full"              # none | full | dots  (activation ckpt)
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_hybrid(self) -> bool:
        return self.attn_period > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if a 500k-token decode is feasible (bounded per-token state)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Params active per token (MoE: shared + top-k experts only)."""
        return _param_count(self, active_only=True)

    # ------------------------------------------------------------------
    def smoke(self) -> "ArchConfig":
        """A reduced same-family config for CPU smoke tests."""
        changes = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if self.attn_period == 0 else 2 * self.attn_period),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            remat="none",
        )
        if self.uses_moe:
            changes.update(num_experts=4, num_experts_per_tok=min(2, self.num_experts_per_tok),
                           moe_d_ff=128, first_k_dense=min(self.first_k_dense, 1),
                           num_shared_experts=min(self.num_shared_experts, 1))
        if self.attention == "mla":
            changes.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
                           qk_rope_head_dim=16, v_head_dim=32)
        if self.ssm_state:
            changes.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.is_encoder_decoder:
            changes.update(num_encoder_layers=2, encoder_seq_len=64)
        if self.num_prefix_tokens:
            changes.update(num_prefix_tokens=16)
        if self.mtp_depth:
            changes.update(mtp_depth=1)
        if self.attn_period:
            changes.update(attn_period=min(self.attn_period, 2),
                           moe_period=min(self.moe_period, 2) if self.moe_period else 0)
        return dataclasses.replace(self, **changes)


def _param_count(cfg: ArchConfig, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    n = 0
    # embeddings (+ untied head)
    n += cfg.padded_vocab * d
    if not cfg.tie_embeddings:
        n += cfg.padded_vocab * d

    def attn_params() -> int:
        if cfg.attention == "mla":
            p = d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * (
                cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
            p += d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            p += cfg.kv_lora_rank * cfg.num_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim)
            p += cfg.num_heads * cfg.v_head_dim * d
            return p
        q = d * cfg.num_heads * hd
        kv = 2 * d * cfg.num_kv_heads * hd
        o = cfg.num_heads * hd * d
        b = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd if cfg.qkv_bias else 0
        return q + kv + o + b

    def dense_ffn(width: int) -> int:
        if cfg.act == "silu":
            return 3 * d * width
        return 2 * d * width

    def moe_ffn() -> int:
        per = 3 * d * cfg.moe_d_ff  # experts use SwiGLU
        router = d * cfg.num_experts
        if active_only:
            k = cfg.num_experts_per_tok + cfg.num_shared_experts
            return router + k * per
        return router + (cfg.num_experts + cfg.num_shared_experts) * per

    def ssm_params() -> int:
        di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        p = d * (2 * di + 2 * ns + nh)     # in_proj: z, x, B, C, dt
        p += cfg.ssm_conv * (di + 2 * ns)  # depthwise conv over x, B, C
        p += nh * 2                        # A_log, D
        p += di * d                        # out_proj
        p += di                            # gated norm
        return p

    L = cfg.num_layers
    if cfg.family == "ssm":
        n += L * ssm_params() + L * 2 * d  # + norms
        return n
    if cfg.is_hybrid:
        for i in range(L):
            is_attn = (i % cfg.attn_period) == (cfg.attn_period // 2)
            n += attn_params() if is_attn else ssm_params()
            is_moe = cfg.moe_period and (i % cfg.moe_period == cfg.moe_period - 1)
            n += moe_ffn() if is_moe else dense_ffn(cfg.d_ff)
            n += 2 * d
        return n
    # plain transformer families (dense / moe / audio / vlm)
    dense_layers = cfg.first_k_dense if cfg.uses_moe else L
    moe_layers = L - dense_layers if cfg.uses_moe else 0
    per_dense = attn_params() + dense_ffn(cfg.d_ff if cfg.d_ff else cfg.moe_d_ff) + 2 * d
    per_moe = attn_params() + moe_ffn() + 2 * d
    n += dense_layers * per_dense + moe_layers * per_moe
    if cfg.is_encoder_decoder:
        # encoder layers + decoder cross-attention
        enc = cfg.num_encoder_layers * (attn_params() + dense_ffn(cfg.d_ff) + 2 * d)
        xattn = L * (attn_params() + d)
        n += enc + xattn
    if cfg.mtp_depth:
        # MTP head: concat-proj + norm + one dense block (see Model._mtp_loss)
        n += cfg.mtp_depth * (2 * d * d + d + per_dense)
    return n


# ----------------------------------------------------------------------
# Shapes assigned to the LM pool (identical for all 10 archs).
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and if not, why (recorded in docs)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k-token decode is O(L^2)/unbounded KV (skip per brief)"
    return True, ""
