"""paligemma-3b — [vlm] 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP frontend (STUB) + gemma decoder.
[arXiv:2407.07726; hf]

Per the brief, the vision frontend is a stub: ``input_specs()`` supplies 256
precomputed patch embeddings which are prepended to the token sequence with
PaliGemma's prefix-LM attention mask (full attention over the prefix).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    num_prefix_tokens=256,
    prefix_lm=True,
    frontend="vision_stub",
    act="gelu",
    tie_embeddings=True,
    rope_theta=10000.0,
    source="arXiv:2407.07726; hf",
)
