"""mamba2-1.3b — [ssm] 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]

vocab 50280 is padded to 50304 (multiple of 128) for clean TP sharding; the
padding ids are masked out of the loss (see DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    attention="none",
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,         # d_inner=4096 -> 64 heads
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
    norm="rmsnorm",
    source="arXiv:2405.21060; unverified",
)
