"""jamba-1.5-large-398b — [hybrid] 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7
interleave, MoE every other layer.  [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    moe_d_ff=24576,
    num_experts=16,
    num_experts_per_tok=2,
    vocab_size=65536,
    attn_period=8,           # 1 attention layer per 8 (rest Mamba)
    moe_period=2,            # MoE FFN every 2nd layer
    ssm_state=128,
    ssm_head_dim=128,        # d_inner=16384 -> 128 mamba heads
    ssm_expand=2,
    rope_theta=0.0,          # Jamba uses no positional encoding
    norm="rmsnorm",
    opt_dtype="bfloat16",    # 398B: bf16 moments
    source="arXiv:2403.19887; hf",
)
