"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_applicable

_ARCH_MODULES = {
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "glm4-9b": "repro.configs.glm4_9b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "smollm-135m": "repro.configs.smollm_135m",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "whisper-base": "repro.configs.whisper_base",
    "paligemma-3b": "repro.configs.paligemma_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id.endswith("-smoke"):
        return get_config(arch_id[: -len("-smoke")]).smoke()
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
    "get_config", "all_configs", "shape_applicable",
]
