"""whisper-base — [audio] 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
encoder-decoder, conv frontend (STUB).  [arXiv:2212.04356; unverified]

Per the brief, the modality frontend is a stub: ``input_specs()`` supplies
precomputed frame embeddings (batch, 1500, d_model) as the encoder input.
Decoder uses learned absolute positions (approximated here with sinusoidal)
and full self/cross attention.  vocab padded 51865 -> 51968.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,            # decoder layers
    num_encoder_layers=6,
    encoder_seq_len=1500,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    is_encoder_decoder=True,
    frontend="audio_stub",
    norm="layernorm",
    act="gelu",
    rope_theta=0.0,          # no RoPE: sinusoidal absolute positions
    source="arXiv:2212.04356; unverified",
)
