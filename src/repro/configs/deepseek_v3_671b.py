"""deepseek-v3-671b — [moe] 61L d_model=7168 128H (GQA kv=128) d_ff=2048
vocab=129280, MoE 256 experts top-8 — MLA, 1 shared + 256 routed, MTP.
[arXiv:2412.19437; hf]

Notes: d_ff=2048 is the per-expert (routed) FFN width; the first 3 layers
are dense with the published 18432 width.  Attention is MLA with the
published low-rank dims; MTP implemented as a depth-1 extra prediction head.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: kv heads == q heads post-expansion
    d_ff=18432,              # first_k_dense layers
    moe_d_ff=2048,
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    first_k_dense=3,
    vocab_size=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    rope_theta=10000.0,
    opt_dtype="bfloat16",    # 671B: bf16 moments (DeepSeek-V3 trains low-prec)
    source="arXiv:2412.19437; hf",
)
