import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count on first init).  512 host devices back both the 16x16 single-pod mesh
and the 2x16x16 multi-pod mesh.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
Outputs one JSON per cell with memory analysis, cost analysis, collective
bytes (while-aware), and corrected dot-FLOPs for the roofline.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.distributed.hlo_analysis import collective_bytes, hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        try:
            v = getattr(mem, attr)
            out[attr] = int(v() if callable(v) else v)
        except (AttributeError, TypeError, ValueError, RuntimeError):
            pass  # field absent on this jaxlib's MemoryAnalysis
    if not out:
        out["repr"] = str(mem)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             save_hlo: str = "") -> dict:
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, SHAPES[shape_name])
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="SKIP", reason=why)
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        cell = build_cell(arch, shape_name, mesh)
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          out_shardings=cell.out_shardings,
                          donate_argnums=cell.donate_argnums,
                          ).lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        st = hlo_stats(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    rec.update(
        status="OK",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        n_devices=int(mesh.size),
        memory=_mem_dict(mem),
        cost={k: float(v) for k, v in cost.items()
              if k in ("flops", "bytes accessed", "transcendentals")},
        collectives={k: float(v) for k, v in coll.merged().items()},
        collective_counts=dict(coll.count_by_kind),
        dot_flops=st.flops,
        dot_bytes=st.dot_bytes,
        instr_bytes=st.instr_bytes,
    )
    return rec


def run_pq_cell(*, multi_pod: bool, n: int = 1 << 24) -> dict:
    """Dry-run the paper's own technique: one distributed dual-simplex
    pivot — the pricing + exact-BFRT selection step (consuming MAINTAINED
    reduced costs, no c - y @ A recompute) and the post-pivot O(n/p)
    d-update step — on the full mesh.

    Delegates to the contract checker so the dry-run and CI prove the
    SAME invariants (zero update collectives, pq byte budget, dense-pass
    discipline, f32 cleanliness) instead of re-deriving them here."""
    from repro.analysis import contracts
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": "pq_step", "shape": f"m8_n{n}", "mesh": mesh_name}
    mesh = make_production_mesh(multi_pod=multi_pod)
    pq = contracts.check_pq_step(mesh, 8, n)
    upd = contracts.check_update_step(mesh, 8, n)
    viols = pq.violations + upd.violations
    rec.update(
        status="OK" if not viols else "CONTRACT_FAIL",
        compile_s=round(pq.wall_s + upd.wall_s, 1),
        n_devices=int(mesh.size),
        collectives=pq.record["collective_bytes"],
        collective_counts=pq.record["collective_counts"],
        budget_bytes=pq.record["budget_bytes"],
        budget_used_frac=pq.record["budget_used_frac"],
        dense_passes=pq.record["dense_passes"],
        update_collectives=upd.record["collective_bytes"],
        update_collective_counts=upd.record["collective_counts"],
        violations=[v.format() for v in viols],
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--pq", action="store_true",
                    help="dry-run the distributed package-query step")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.pq:
        os.makedirs(args.out, exist_ok=True)
        rc = 0
        for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
            mesh_name = "2x16x16" if mp else "16x16"
            try:
                rec = run_pq_cell(multi_pod=mp)
            except (ValueError, TypeError, KeyError, RuntimeError,
                    NotImplementedError, OSError) as e:
                # XlaRuntimeError subclasses RuntimeError; anything else
                # (assertion, keyboard interrupt) should still crash loudly
                rec = {"arch": "pq_step", "mesh": mesh_name, "status": "FAIL",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            if rec["status"] != "OK":
                rc = 1
            with open(os.path.join(args.out,
                                   f"pq_step__{mesh_name}.json"), "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[dryrun] pq_step {mesh_name}: {rec['status']} "
                  + rec.get("error", "")[:200], flush=True)
            for v in rec.get("violations", ()):
                print(f"  {v}", flush=True)
            if rec["status"] in ("OK", "CONTRACT_FAIL"):
                print(f"  coll_bytes/dev={rec['collectives'].get('total', 0):.3e}"
                      f" budget_used={rec['budget_used_frac']:.2f}")
        return rc

    cells = []
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        for a in archs:
            for s in shapes:
                cells.append((a, s, mp))

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for a, s, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        path = os.path.join(args.out, f"{a}__{s}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {a} {s} {mesh_name}: exists, skipping")
            continue
        print(f"[dryrun] {a} {s} {mesh_name} ...", flush=True)
        try:
            rec = run_cell(a, s, multi_pod=mp, save_hlo=args.save_hlo)
        except (ValueError, TypeError, KeyError, RuntimeError,
                NotImplementedError, OSError) as e:
            rec = {"arch": a, "shape": s, "mesh": mesh_name,
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            failures += 1
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        msg = rec["status"]
        if rec["status"] == "OK":
            per_dev = rec["memory"].get("argument_size_in_bytes", 0)
            msg += (f" compile={rec['compile_s']}s"
                    f" arg_bytes/dev={per_dev/2**30:.2f}GiB"
                    f" dot_flops/dev={rec['dot_flops']:.3e}"
                    f" coll_bytes/dev={rec['collectives'].get('total', 0):.3e}")
        elif rec["status"] == "FAIL":
            msg += " " + rec["error"][:200]
        print(f"[dryrun] {a} {s} {mesh_name}: {msg}", flush=True)
    print(f"[dryrun] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
