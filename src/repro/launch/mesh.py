"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips of
TPU v5e; multi-pod adds a leading 'pod' axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_abstract_mesh(shape, axes):
    """Version-compat AbstractMesh: build shardings without real devices.

    Newer jax spells it ``AbstractMesh(axis_sizes, axis_names)``; 0.4.x
    takes a single tuple of ``(name, size)`` pairs (same pattern as the
    shard_map shim in ``repro.core.distributed``).
    """
    import inspect

    from jax.sharding import AbstractMesh
    params = inspect.signature(AbstractMesh.__init__).parameters
    if "axis_names" in params:
        return AbstractMesh(tuple(shape), tuple(axes))
    return AbstractMesh(tuple(zip(axes, shape)))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (per the brief)
HBM_BYTES = 16 * 2**30         # 16 GiB per chip
