"""Training driver: data pipeline + train_step + checkpointing + fault
tolerance, for any ``--arch`` (full or -smoke reduced configs).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m-smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

The same driver is what a pod deployment runs per host (the mesh/sharding
come from launch.mesh + distributed.sharding; on this container it runs on
the single local device).  Failure injection (--fail-at) exercises the
restore path end-to-end: the run crashes mid-training and, relaunched with
the same flags, resumes from the latest atomic checkpoint and reproduces
the same batch sequence.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.data.selection import (CorpusSpec, selection_query, synth_corpus,
                                  select_training_docs)
from repro.models import Model
from repro.runtime import Coordinator
from repro.training.optimizer import OptHyper
from repro.training.step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a crash after this step (tests restart)")
    ap.add_argument("--select-data", action="store_true",
                    help="run package-query data selection first")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = Model(cfg)
    print(f"[train] arch={cfg.name} params={model.param_count()/1e6:.2f}M")

    if args.select_data:
        corpus = synth_corpus(CorpusSpec(num_docs=20_000))
        q = selection_query(corpus, token_budget=2e6,
                            domain_caps={"web": 1.2e6}, dup_budget=50.0)
        sel = select_training_docs(corpus, q, d_f=20, alpha=2000)
        print(f"[train] data selection: feasible={sel.feasible} "
              f"docs={len(sel.idx)} quality={sel.obj:.1f}")

    data = SyntheticTokens(DataConfig(cfg.vocab_size, args.seq, args.batch))
    hyper = OptHyper(lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                     total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, hyper,
                                      microbatches=args.microbatches,
                                      compress=args.compress_grads),
                      donate_argnums=(0,))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    coord = Coordinator(num_workers=1, ckpt_cadence_steps=args.ckpt_every)

    state = init_train_state(model, jax.random.PRNGKey(0),
                             compress=args.compress_grads)
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start = int(np.asarray(state["opt"]["step"]))
        print(f"[train] resumed from checkpoint at step {start}")

    losses = []
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in
                 data.global_batch(step).items()}
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        coord.heartbeat(0, time.time())
        coord.report_step(0, time.time(), dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:4d} loss={loss:.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt:.2f}s",
                  flush=True)
        if ckpt and coord.should_checkpoint(step + 1):
            path = ckpt.save(step + 1, state)
        if args.fail_at == step:
            print(f"[train] injected failure at step {step}", flush=True)
            raise SystemExit(42)
    if ckpt:
        ckpt.save(args.steps, state)
    print(f"[train] done. loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
