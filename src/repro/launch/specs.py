"""Per-cell (arch × shape × mesh) abstract inputs, step fns and shardings.

``input_specs`` produces weak-type-correct ``jax.ShapeDtypeStruct`` stand-ins
for every model input — no device allocation — exactly what
``jax.jit(...).lower()`` needs for the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ArchConfig, ShapeConfig, get_config, SHAPES
from repro.distributed.context import use_rules
from repro.distributed.sharding import ShardingRules, make_rules

import os
# §Perf iterations 2-5: contraction-aligned decode activations (default on;
# set =0 to reproduce the paper-faithful baseline numbers)
_REPL_DECODE = os.environ.get("REPRO_DECODE_REPLICATED_ACT", "1") == "1"
# §Perf smollm iteration: sequence-parallel attention when heads don't
# divide the model axis
_SEQ_PAR = os.environ.get("REPRO_SEQ_PARALLEL_ATTN", "0") == "1"

from repro.models import Model
from repro.training.step import abstract_train_state, make_train_step


def _with_rules(fn, rules):
    """Activate the sharding-rules context while tracing fn."""
    import functools

    @functools.wraps(fn)
    def wrapped(*args):
        with use_rules(rules):
            return fn(*args)
    return wrapped


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, *, with_labels: bool):
    """Abstract batch + pspecs for train/prefill inputs."""
    B, S = shape.global_batch, shape.seq_len
    s_tokens = S - cfg.num_prefix_tokens if cfg.num_prefix_tokens else S
    batch = {"tokens": _sds((B, s_tokens), jnp.int32)}
    specs = {"tokens": P("__dp__", None)}
    if with_labels:
        batch["labels"] = _sds((B, s_tokens), jnp.int32)
        specs["labels"] = P("__dp__", None)
    if cfg.is_encoder_decoder:
        batch["enc_inputs"] = _sds((B, cfg.encoder_seq_len, cfg.d_model),
                                   jnp.bfloat16)
        specs["enc_inputs"] = P("__dp__", None, None)
    if cfg.num_prefix_tokens:
        batch["prefix"] = _sds((B, cfg.num_prefix_tokens, cfg.d_model),
                               jnp.bfloat16)
        specs["prefix"] = P("__dp__", None, None)
    return batch, specs


def _resolve_dp(pspec: P, rules: ShardingRules, batch_size: int) -> P:
    """Replace the '__dp__' placeholder with the actual dp entry."""
    entry = rules._dp_entry(batch_size)
    return P(*[entry if e == "__dp__" else e for e in pspec])


def _cache_kind(key: str) -> Optional[str]:
    if key in ("k", "v", "xk", "xv"):
        return "kv"
    if key in ("c", "r"):
        return "mla"
    if key.startswith("state"):
        return "state"
    if key.startswith("conv"):
        return "conv"
    return None  # index


def cache_shardings(cache_abstract, rules: ShardingRules):
    out = {}
    for key, v in cache_abstract.items():
        kind = _cache_kind(key)
        if kind is None:
            out[key] = rules.named(P())
        else:
            out[key] = rules.named(rules.cache_pspec(v.shape, kind))
    return out


def build_cell(arch: str, shape_name: str, mesh: Mesh) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = make_rules(mesh)
    if SHAPES[shape_name].kind == "decode" and _REPL_DECODE:
        rules = dataclasses.replace(rules, replicate_decode_activations=True)
    if _SEQ_PAR:
        rules = dataclasses.replace(rules, seq_parallel_attn=True)
    model = Model(cfg)
    axes = model.axes()

    if shape.kind == "train":
        state = abstract_train_state(model)
        p_shard = rules.param_sharding(model.abstract_params(), axes)
        state_shard = {
            "params": p_shard,
            "opt": {"mu": p_shard, "nu": p_shard,
                    "step": rules.named(P())},
        }
        batch, bspecs = batch_specs(cfg, shape, with_labels=True)
        bshard = {k: rules.named(_resolve_dp(v, rules, shape.global_batch))
                  for k, v in bspecs.items()}
        step = _with_rules(make_train_step(model), rules)
        metrics_shard = None  # replicated scalars
        return Cell(arch, shape_name, step, (state, batch),
                    (state_shard, bshard), (state_shard, metrics_shard),
                    donate_argnums=(0,))

    params = model.abstract_params()
    p_shard = rules.param_sharding(params, axes)

    if shape.kind == "prefill":
        batch, bspecs = batch_specs(cfg, shape, with_labels=False)
        bshard = {k: rules.named(_resolve_dp(v, rules, shape.global_batch))
                  for k, v in bspecs.items()}
        fn = _with_rules(lambda p, b: model.prefill_logits(p, b), rules)
        V = cfg.padded_vocab
        out_spec = P(rules._dp_entry(shape.global_batch), None,
                     "model" if V % rules.tp_size == 0 else None)
        return Cell(arch, shape_name, fn, (params, batch),
                    (p_shard, bshard), rules.named(out_spec))

    # decode: one new token against a cache of shape.seq_len
    B, S = shape.global_batch, shape.seq_len
    cache = model.init_cache(B, S, abstract=True)
    c_shard = cache_shardings(cache, rules)
    tokens = _sds((B, 1), jnp.int32)
    t_shard = rules.named(P(rules._dp_entry(B), None))
    fn = _with_rules(lambda p, c, t: model.decode_step(p, c, t), rules)
    V = cfg.padded_vocab
    logits_shard = rules.named(
        P(rules._dp_entry(B), "model" if V % rules.tp_size == 0 else None))
    return Cell(arch, shape_name, fn, (params, cache, tokens),
                (p_shard, c_shard, t_shard), (logits_shard, c_shard),
                donate_argnums=(1,))
