"""Serving driver: package-query admission control + batched generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b-smoke \
        --requests 24 --ticks 6
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving import PackageScheduler, Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b-smoke")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--ticks", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--hbm-frac", type=float, default=0.05)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] arch={cfg.name} params={model.param_count()/1e6:.2f}M")

    rng = np.random.default_rng(0)
    sched = PackageScheduler(
        cfg,
        hbm_budget_bytes=args.hbm_frac * 16 * 2**30,
        flop_budget=5e13,
        max_batch=args.max_batch)
    for rid in range(args.requests):
        sched.submit(Request(
            rid=rid,
            prompt_tokens=int(rng.integers(4, 24)),
            max_new_tokens=int(rng.integers(4, 16)),
            priority=float(rng.uniform(0.1, 1.0))))

    engine = ServingEngine(cfg, params, cache_len=64)
    t0 = time.time()
    done = engine.serve(sched, ticks=args.ticks)
    dt = time.time() - t0
    print(f"[serve] completed {len(done)}/{args.requests} requests in "
          f"{dt:.1f}s over {args.ticks} ticks "
          f"(admitted={sched.admitted_total}, queued={len(sched.queue)})")
    for g in done[:3]:
        print(f"  rid={g.rid} tokens={g.tokens[:8]}...")
    return done


if __name__ == "__main__":
    main()
