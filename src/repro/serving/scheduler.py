"""Package-query admission control for serving — the paper's technique in
the serving tier.

Every scheduling tick, the waiting-request pool is a relation (one row per
request: priority, prefill FLOPs, KV-cache bytes, decode length estimate)
and batch formation IS a package query:

    SELECT PACKAGE(*) FROM queue REPEAT 0
    SUCH THAT COUNT(P.*) <= max_batch
          AND SUM(P.kv_bytes)      <= hbm_budget
          AND SUM(P.prefill_flops) <= flop_budget
    MAXIMIZE  SUM(P.priority)

solved with Dual Reducer (sub-second at 10^5+ queued requests, matching the
paper's interactivity requirement).  This replaces greedy FCFS admission
with a globally optimal knapsack per tick.

The per-request feature table is maintained incrementally: columns are
appended once at ``submit`` (kv_bytes / prefill_flops are computed exactly
once per request) and mask-compacted when requests are admitted, so a tick
over a large pool never rebuilds python-side lists.  Each tick solves
under a ``guard.SolveBudget`` deadline and contains any solver exception,
so the serving loop inherits the never-raise / never-hang contract; the
last ``guard.SolveReport`` is kept on ``last_report`` for observability.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional

import numpy as np

from repro.core.dual_reducer import dual_reducer
from repro.core.guard import ERROR, NumericalMonitor, SolveBudget, SolveReport
from repro.core.paql import Constraint, PackageQuery


@dataclasses.dataclass
class Request:
    rid: int
    prompt_tokens: int
    max_new_tokens: int
    priority: float

    def kv_bytes(self, cfg) -> float:
        per_tok = 2 * 2 * cfg.num_kv_heads * cfg.resolved_head_dim \
            * cfg.num_layers
        return float(per_tok * (self.prompt_tokens + self.max_new_tokens))

    def prefill_flops(self, cfg) -> float:
        n_active = cfg.active_param_count()
        return float(2 * n_active * self.prompt_tokens)


_COLUMNS = ("priority", "kv_bytes", "prefill_flops")


class _ColumnStore:
    """Growable column arrays for the waiting pool.

    Rows are appended on ``submit`` (amortized O(1): capacity doubles)
    and removed by boolean-mask compaction on admission, so the solver
    sees zero-copy array views instead of per-tick list comprehensions.
    """

    def __init__(self, capacity: int = 64):
        self._cap = max(int(capacity), 1)
        self._len = 0
        self._cols = {k: np.zeros(self._cap) for k in _COLUMNS}

    def __len__(self) -> int:
        return self._len

    def append(self, priority: float, kv: float, flops: float) -> None:
        if self._len == self._cap:
            self._cap *= 2
            for k, old in self._cols.items():
                buf = np.zeros(self._cap)
                buf[:self._len] = old[:self._len]
                self._cols[k] = buf
        row = {"priority": priority, "kv_bytes": kv, "prefill_flops": flops}
        for k in _COLUMNS:
            self._cols[k][self._len] = row[k]
        self._len += 1

    def view(self) -> Dict[str, np.ndarray]:
        return {k: v[:self._len] for k, v in self._cols.items()}

    def snapshot(self, n: int) -> Dict[str, np.ndarray]:
        """Copied column prefix of length ``n`` — safe to read after the
        caller drops the lock (concurrent appends touch other rows, but
        a capacity-doubling re-allocation would invalidate a view)."""
        return {k: v[:n].copy() for k, v in self._cols.items()}

    def compact(self, keep: np.ndarray) -> None:
        """Drop rows where ``keep`` is False (in place, order-preserving)."""
        kept = int(np.count_nonzero(keep))
        for v in self._cols.values():
            v[:kept] = v[:self._len][keep]
        self._len = kept


class PackageScheduler:

    # Pool state is guarded by the data lock ``_lock`` (held briefly:
    # appends, snapshots, compaction).  Ticks serialize on ``_tick_lock``
    # — one admission solve at a time, rng confined to the ticking
    # thread — while submits stay concurrent.  Lock order: _tick_lock
    # may take _lock; never the reverse.
    __guarded_by__ = {"queue": "_lock", "_store": "_lock",
                      "_admitted_total": "_lock", "last_report": "_lock",
                      "rng": "_tick_lock"}

    def __init__(self, cfg, *, hbm_budget_bytes: float,
                 flop_budget: float, max_batch: int = 64, seed: int = 0,
                 time_limit_s: float = 5.0, wave_width: int = 8):
        self.cfg = cfg
        self.hbm_budget = hbm_budget_bytes
        self.flop_budget = flop_budget
        self.max_batch = max_batch
        self.time_limit_s = time_limit_s
        self.wave_width = wave_width
        self.queue: List[Request] = []
        self.rng = np.random.default_rng(seed)
        self._store = _ColumnStore()
        self._admitted_total = 0
        self.last_report: Optional[SolveReport] = None
        self._lock = threading.Lock()
        self._tick_lock = threading.Lock()

    def submit(self, req: Request):
        with self._lock:
            self.queue.append(req)
            self._store.append(req.priority, req.kv_bytes(self.cfg),
                               req.prefill_flops(self.cfg))

    def tick(self) -> List[Request]:
        """Admit the optimal batch; admitted requests leave the queue.

        Never raises and never hangs: the solve runs under a
        ``SolveBudget`` wall-clock deadline and any unexpected exception
        is contained into an ERROR report (empty admission).

        Thread-safety: the tick solves over a snapshot of the first
        ``n`` pool rows taken under the data lock, runs the solver with
        the data lock RELEASED (submits proceed concurrently), then
        removes the admitted prefix rows under the lock again — rows
        appended mid-solve are simply not candidates until the next
        tick.  ``_tick_lock`` serializes whole ticks.
        """
        with self._tick_lock:
            with self._lock:
                n = len(self.queue)
                if n == 0:
                    return []
                cols = self._store.snapshot(n)
            query = PackageQuery(
                "priority", maximize=True,
                constraints=(
                    Constraint(None, 0, self.max_batch),
                    Constraint("kv_bytes", hi=self.hbm_budget),
                    Constraint("prefill_flops", hi=self.flop_budget),
                ))
            budget = SolveBudget(deadline_s=self.time_limit_s).start()
            report = SolveReport(budget=budget, monitor=NumericalMonitor())
            # The admission solve holds only _tick_lock (the
            # whole-operation serializer), never the data lock — the
            # REPRO011 no-dispatch-under-a-data-lock discipline.
            try:
                # repro: allow[REPRO011] tick-exclusivity lock by
                # design: _tick_lock serializes whole admission solves
                # (rng confinement); the data lock _lock is NOT held
                res = dual_reducer(query, cols, np.arange(n),
                                   q=min(500, n), rng=self.rng,
                                   budget=budget, report=report,
                                   ilp_kwargs=dict(
                                       max_nodes=200,
                                       wave_width=self.wave_width))
            # repro: allow[REPRO004] containment rung by design: the tick
            # contract is "never raises" — failures become an ERROR report
            except Exception as exc:   # pragma: no cover - containment
                report.status = ERROR
                report.note(f"scheduler tick contained: "
                            f"{type(exc).__name__}: {exc}")
                with self._lock:
                    self.last_report = report
                return []
            with self._lock:
                self.last_report = report.finalize(res.feasible)
                if not res.feasible:
                    return []   # nothing admissible this tick
                take = set(int(i) for i in res.idx)
                # the pool may have grown mid-solve: rows >= n are kept
                keep = np.ones(len(self.queue), bool)
                keep[list(take)] = False
                admitted = [r for i, r in enumerate(self.queue)
                            if i in take]
                self.queue = [r for i, r in enumerate(self.queue)
                              if i not in take]
                self._store.compact(keep)
                self._admitted_total += len(admitted)
            return admitted

    @property
    def admitted_total(self) -> int:
        with self._lock:
            return self._admitted_total
