"""Package-query admission control for serving — the paper's technique in
the serving tier.

Every scheduling tick, the waiting-request pool is a relation (one row per
request: priority, prefill FLOPs, KV-cache bytes, decode length estimate)
and batch formation IS a package query:

    SELECT PACKAGE(*) FROM queue REPEAT 0
    SUCH THAT COUNT(P.*) <= max_batch
          AND SUM(P.kv_bytes)      <= hbm_budget
          AND SUM(P.prefill_flops) <= flop_budget
    MAXIMIZE  SUM(P.priority)

solved with Dual Reducer (sub-second at 10^5+ queued requests, matching the
paper's interactivity requirement).  This replaces greedy FCFS admission
with a globally optimal knapsack per tick.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import numpy as np

from repro.core.dual_reducer import dual_reducer
from repro.core.paql import Constraint, PackageQuery


@dataclasses.dataclass
class Request:
    rid: int
    prompt_tokens: int
    max_new_tokens: int
    priority: float

    def kv_bytes(self, cfg) -> float:
        per_tok = 2 * 2 * cfg.num_kv_heads * cfg.resolved_head_dim \
            * cfg.num_layers
        return float(per_tok * (self.prompt_tokens + self.max_new_tokens))

    def prefill_flops(self, cfg) -> float:
        n_active = cfg.active_param_count()
        return float(2 * n_active * self.prompt_tokens)


class PackageScheduler:
    def __init__(self, cfg, *, hbm_budget_bytes: float,
                 flop_budget: float, max_batch: int = 64, seed: int = 0):
        self.cfg = cfg
        self.hbm_budget = hbm_budget_bytes
        self.flop_budget = flop_budget
        self.max_batch = max_batch
        self.queue: List[Request] = []
        self.rng = np.random.default_rng(seed)
        self._admitted_total = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def _table(self) -> Dict[str, np.ndarray]:
        return {
            "priority": np.array([r.priority for r in self.queue]),
            "kv_bytes": np.array([r.kv_bytes(self.cfg) for r in self.queue]),
            "prefill_flops": np.array(
                [r.prefill_flops(self.cfg) for r in self.queue]),
        }

    def tick(self) -> List[Request]:
        """Admit the optimal batch; admitted requests leave the queue."""
        if not self.queue:
            return []
        table = self._table()
        query = PackageQuery(
            "priority", maximize=True,
            constraints=(
                Constraint(None, 0, self.max_batch),
                Constraint("kv_bytes", hi=self.hbm_budget),
                Constraint("prefill_flops", hi=self.flop_budget),
            ))
        res = dual_reducer(query, table, np.arange(len(self.queue)),
                           q=min(500, len(self.queue)), rng=self.rng,
                           ilp_kwargs=dict(max_nodes=200, time_limit_s=5))
        if not res.feasible:
            return []   # nothing admissible this tick
        take = set(int(i) for i in res.idx)
        admitted = [r for i, r in enumerate(self.queue) if i in take]
        self.queue = [r for i, r in enumerate(self.queue) if i not in take]
        self._admitted_total += len(admitted)
        return admitted

    @property
    def admitted_total(self) -> int:
        return self._admitted_total
