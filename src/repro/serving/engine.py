"""Serving engine: batched prefill + decode around Model.decode_step.

Production path: the decode_32k/long_500k dry-run cells lower exactly this
``decode_step`` on the pod meshes; this class is the host-side loop that
feeds it (batch assembly from the PackageScheduler, cache management,
greedy/temperature sampling).  On this container it runs the reduced
configs end-to-end (examples/serve_lm.py).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import Model
from repro.serving.scheduler import PackageScheduler


@dataclasses.dataclass
class Generation:
    rid: int
    tokens: List[int]


class ServingEngine:

    # Sampling state: concurrent generate_batch calls split the engine
    # key under the lock, so each draw consumes a distinct subkey.
    __guarded_by__ = {"rng": "_lock"}

    def __init__(self, cfg: ArchConfig, params, *, cache_len: int = 512,
                 seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.cache_len = cache_len
        self.rng = jax.random.PRNGKey(seed)
        self._lock = threading.Lock()
        self._decode = jax.jit(self.model.decode_step)

    def _next_key(self):
        """Split off one sampling subkey (atomic rng advance)."""
        with self._lock:
            self.rng, k = jax.random.split(self.rng)
        return k

    def generate_batch(self, prompts: np.ndarray, max_new: int,
                       temperature: float = 0.0) -> np.ndarray:
        """prompts: (B, P) int32 -> (B, max_new) int32 greedy/temp samples."""
        B, P = prompts.shape
        cache = self.model.init_cache(B, self.cache_len)
        # prefill by stepping the decoder over the prompt (CPU-scale path;
        # the pod-scale path lowers prefill_logits instead)
        logits = None
        for t in range(P):
            logits, cache = self._decode(self.params, cache,
                                         prompts[:, t:t + 1])
        out = np.zeros((B, max_new), np.int32)
        tok = None
        for i in range(max_new):
            if temperature > 0:
                k = self._next_key()
                tok = jax.random.categorical(k, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            tok = jnp.clip(tok, 0, self.cfg.vocab_size - 1).astype(jnp.int32)
            out[:, i] = np.asarray(tok)
            logits, cache = self._decode(self.params, cache, tok[:, None])
        return out

    def serve(self, scheduler: PackageScheduler, *, ticks: int,
              pad_token: int = 0) -> List[Generation]:
        """Run admission ticks; each admitted batch is generated jointly."""
        done: List[Generation] = []
        for _ in range(ticks):
            batch = scheduler.tick()
            if not batch:
                continue
            P = max(r.prompt_tokens for r in batch)
            new = max(r.max_new_tokens for r in batch)
            prompts = np.full((len(batch), P), pad_token, np.int32)
            for i, r in enumerate(batch):
                rng = np.random.default_rng(r.rid)
                prompts[i, -r.prompt_tokens:] = rng.integers(
                    1, self.cfg.vocab_size, r.prompt_tokens)
            gen = self.generate_batch(prompts, new)
            for i, r in enumerate(batch):
                done.append(Generation(r.rid,
                                       gen[i, :r.max_new_tokens].tolist()))
        return done
