from repro.serving.scheduler import PackageScheduler, Request
from repro.serving.engine import ServingEngine

__all__ = ["PackageScheduler", "Request", "ServingEngine"]
