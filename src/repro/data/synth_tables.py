"""Synthetic stand-ins for the paper's datasets (SDSS APOGEE-2, TPC-H
LINEITEM sf300), generated to match the column statistics published in
Tables 1-2 so the hardness-derived bounds transfer.

No network access in-container: column marginals are matched (mean/std and
qualitative shape — heavy-tailed tmass_prox/discount/tax, uniform quantity),
which is what the hardness machinery and all benchmarks consume.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def sdss_table(n: int, rng: np.random.Generator) -> Dict[str, np.ndarray]:
    # tmass_prox: nonnegative, heavy-tailed, mu=14.45 sigma=14.96, many zeros
    raw = rng.gamma(shape=0.55, scale=30.0, size=n)
    raw[rng.random(n) < 0.12] = 0.0
    t = raw * (14.96 / raw.std())
    t = t - t.mean() + 14.45
    t = np.clip(t, 0.0, None)
    return {
        "tmass_prox": t,
        "j": rng.normal(14.82, 1.562, n),
        "h": rng.normal(14.05, 1.657, n),
        "k": rng.normal(13.73, 1.727, n),
    }


def tpch_table(n: int, rng: np.random.Generator) -> Dict[str, np.ndarray]:
    quantity = rng.integers(1, 51, n).astype(np.float64)   # mu 25.5 sd 14.43
    price = rng.lognormal(mean=0.0, sigma=0.55, size=n)
    price = price * (23290 / price.std())
    price = np.clip(price - price.mean() + 38240, 900.0, None)
    def skewed(mu, sigma):
        v = rng.exponential(scale=1.0, size=n)
        v = v * (sigma / v.std())
        return np.clip(v - v.mean() + mu, 0.0, None)
    return {
        "quantity": quantity,
        "price": price,
        "discount": skewed(1912, 1833),
        "tax": skewed(1530, 1485),
    }


def make_table(kind: str, n: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    if kind == "sdss":
        return sdss_table(n, rng)
    if kind == "tpch":
        return tpch_table(n, rng)
    raise ValueError(kind)


def subsample(table: Dict[str, np.ndarray], size: int,
              rng: np.random.Generator) -> Dict[str, np.ndarray]:
    n = len(next(iter(table.values())))
    idx = rng.choice(n, size=min(size, n), replace=False)
    return {k: v[idx] for k, v in table.items()}
