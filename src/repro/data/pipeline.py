"""Deterministic sharded synthetic-token pipeline.

Every (shard, step) pair maps to a unique seed, so a restarted/re-sharded
job replays the exact same global batch order — the property the
fault-tolerance path relies on (resume from checkpoint step k reproduces
batch k+1 regardless of the new mesh width).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np



@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    """Markov-ish synthetic LM data (not uniform noise: next-token has
    structure so the loss actually decreases during the example runs)."""

    def __init__(self, cfg: DataConfig, selected_docs: Optional[np.ndarray] = None):
        self.cfg = cfg
        self.selected = selected_docs
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self._proj = base.integers(0, v, size=4096).astype(np.int64)

    def _gen_doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        v = self.cfg.vocab_size
        toks = np.empty(length, np.int64)
        toks[0] = rng.integers(1, v)
        for i in range(1, length):
            if rng.random() < 0.7:   # structured transition
                toks[i] = self._proj[toks[i - 1] % 4096] % v
            else:
                toks[i] = rng.integers(1, v)
        return toks

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.cfg.global_batch, self.cfg.seq_len
        out = np.empty((B, S + 1), np.int64)
        for b in range(B):
            rng = np.random.default_rng(
                (self.cfg.seed, step, b, 0xD1CE))
            out[b] = self._gen_doc(rng, S + 1)
        return {"tokens": out[:, :-1].astype(np.int32),
                "labels": out[:, 1:].astype(np.int32)}

    def shard_batch(self, step: int, shard: int, num_shards: int
                    ) -> Dict[str, np.ndarray]:
        g = self.global_batch(step)
        per = self.cfg.global_batch // num_shards
        sl = slice(shard * per, (shard + 1) * per)
        return {k: v[sl] for k, v in g.items()}
