"""Package-query-driven training-data selection — the paper's technique as
a first-class feature of the training framework.

The training corpus is a relation: one row per document with columns
(quality score, token count, per-domain indicators, dedup-cluster cost).
Curating a training mix IS a package query:

    SELECT PACKAGE(*) FROM corpus REPEAT 0
    SUCH THAT  SUM(tokens)        BETWEEN budget*(1-slack) AND budget
           AND SUM(domain_web)    <= web_cap_tokens   (per-domain mix caps)
           AND SUM(dup_penalty)   <= dup_budget
    MAXIMIZE   SUM(quality)

At fleet scale the corpus has 10^8-10^9 documents — exactly the regime
Progressive Shading exists for; on this container the same engine runs at
10^5-10^6 documents (tests + examples).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.engine import PackageQueryEngine
from repro.core.paql import Constraint, PackageQuery
from repro.core.dual_reducer import PackageResult


@dataclasses.dataclass
class CorpusSpec:
    num_docs: int
    domains: Sequence[str] = ("web", "code", "papers", "books")
    seed: int = 0


def synth_corpus(spec: CorpusSpec) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(spec.seed)
    n = spec.num_docs
    table: Dict[str, np.ndarray] = {
        "quality": np.clip(rng.normal(0.55, 0.2, n), 0, 1),
        "tokens": rng.lognormal(7.2, 1.0, n).clip(64, 65536).round(),
        "dup_penalty": rng.exponential(0.1, n),
    }
    dom = rng.integers(0, len(spec.domains), n)
    for i, d in enumerate(spec.domains):
        table[f"dom_{d}"] = (dom == i).astype(np.float64)
        # token-weighted domain usage
        table[f"tok_{d}"] = table[f"dom_{d}"] * table["tokens"]
    # quality correlates with papers/books a bit
    table["quality"] += 0.08 * (table["dom_papers"] + table["dom_books"])
    return table


def selection_query(table: Dict[str, np.ndarray], *, token_budget: float,
                    domain_caps: Optional[Dict[str, float]] = None,
                    dup_budget: Optional[float] = None,
                    slack: float = 0.05) -> PackageQuery:
    cons = [Constraint("tokens", lo=token_budget * (1 - slack),
                       hi=token_budget)]
    for d, cap in (domain_caps or {}).items():
        cons.append(Constraint(f"tok_{d}", hi=cap))
    if dup_budget is not None:
        cons.append(Constraint("dup_penalty", hi=dup_budget))
    return PackageQuery("quality", maximize=True, constraints=tuple(cons))


def select_training_docs(table: Dict[str, np.ndarray],
                         query: PackageQuery, *, d_f: int = 50,
                         alpha: int = 5000, seed: int = 0
                         ) -> PackageResult:
    attrs = [query.objective_attr] + [
        c.attr for c in query.constraints if c.attr]
    eng = PackageQueryEngine(table, attrs, d_f=d_f, alpha=alpha, seed=seed)
    eng.partition()
    return eng.solve(query, ilp_kwargs=dict(max_nodes=200, time_limit_s=30))
