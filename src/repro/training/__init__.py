from repro.training.optimizer import adamw_init, adamw_update, OptHyper
from repro.training.step import make_train_step, abstract_train_state

__all__ = ["adamw_init", "adamw_update", "OptHyper", "make_train_step",
           "abstract_train_state"]
