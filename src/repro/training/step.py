"""Train-step builder: value_and_grad + AdamW (+ optional microbatch
accumulation and gradient compression), all pjit-shardable.

State is a plain dict pytree:
    {"params": ..., "opt": {"mu","nu","step"}}
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.training.optimizer import OptHyper, adamw_init, adamw_update


def abstract_train_state(model: Model) -> Dict[str, Any]:
    cfg = model.cfg
    params = model.abstract_params()
    dt = jnp.dtype(cfg.opt_dtype)
    like = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {"params": params,
            "opt": {"mu": jax.tree.map(like, params),
                    "nu": jax.tree.map(like, params),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def init_train_state(model: Model, rng: jax.Array,
                     compress: bool = False) -> Dict[str, Any]:
    params = model.init(rng)
    opt = adamw_init(params, model.cfg.opt_dtype)
    if compress:
        from repro.training.compression import ef_init
        opt["ef"] = ef_init(params)
    return {"params": params, "opt": opt}


def make_train_step(model: Model, hyper: Optional[OptHyper] = None,
                    microbatches: int = 1,
                    compress: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    ``compress=True`` applies int8 gradient compression with error feedback
    (``repro.training.compression``); the residual tree lives in
    state["opt"]["ef"] (add it via ``init_train_state(..., compress=True)``).
    """
    hyper = hyper or OptHyper()

    def loss_fn(params, batch):
        return model.loss_fn(params, batch)

    def grads_of(params, batch):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, mbatch):
            acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch)
            acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree.map(lambda g: (g / microbatches).astype(jnp.float32),
                             grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = grads_of(state["params"], batch)
        opt_in = dict(state["opt"])
        if compress:
            from repro.training.compression import compress_with_ef
            grads, new_ef = compress_with_ef(grads, opt_in.pop("ef"))
        params, opt, gnorm = adamw_update(grads, opt_in, state["params"],
                                          hyper)
        if compress:
            opt = dict(opt)
            opt["ef"] = new_ef
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm,
                       step=opt["step"].astype(jnp.float32))
        return {"params": params, "opt": opt}, metrics

    return train_step
