"""AdamW with global-norm clipping, hand-rolled on pytrees.

Moments are stored in ``cfg.opt_dtype`` (f32 default; bf16 for the 398B/671B
MoEs so the optimizer fits the pod — noted in DESIGN.md).  All update math
runs in f32.  Because parameters are FSDP-sharded by the rules engine and
moments share the parameter sharding, this is ZeRO-3-style sharding with no
additional code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptHyper:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(h: OptHyper, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(h.warmup_steps, 1)
    decay_t = (step - h.warmup_steps) / jnp.maximum(
        h.total_steps - h.warmup_steps, 1)
    decay_t = jnp.clip(decay_t, 0.0, 1.0)
    cos = h.min_lr_frac + (1 - h.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * decay_t))
    return h.lr * jnp.where(step < h.warmup_steps, warm, cos)


def adamw_init(params, opt_dtype: str) -> Dict[str, Any]:
    dt = jnp.dtype(opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, opt_state, params, h: OptHyper):
    step = opt_state["step"] + 1
    lr = schedule(h, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, h.clip_norm / jnp.maximum(gnorm, 1e-9))
    t = step.astype(jnp.float32)
    bc1 = 1 - h.b1 ** t
    bc2 = 1 - h.b2 ** t

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32) * scale
        mu32 = h.b1 * mu.astype(jnp.float32) + (1 - h.b1) * g32
        nu32 = h.b2 * nu.astype(jnp.float32) + (1 - h.b2) * jnp.square(g32)
        upd32 = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + h.eps)
        upd32 = upd32 + h.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * upd32
        return newp.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, gnorm
