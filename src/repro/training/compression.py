"""Gradient compression with error feedback.

int8 per-tensor-scaled quantisation applied to gradients before the
optimizer.  Because parameters (and hence gradients) are FSDP-sharded, the
DP reduction operates on the dequantised values — i.e. this implements the
compressed-allreduce *numerics* (what reaches the optimizer is exactly what
a compressed ring allreduce would produce), while the wire-format saving is
a runtime concern (XLA collectives do not expose int8 allreduce; noted in
DESIGN.md as the 1-bit/8-bit trade-off knob for cross-pod DP traffic).

Error feedback: the quantisation residual is carried in the optimizer state
and added back the next step, which keeps SGD/Adam convergence unbiased
(Seide et al.; Karimireddy et al.).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_ef(grads, residual):
    """Returns (dequantised grads, new residual)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = _quantize(g32)
        deq = _dequantize(q, s)
        return deq.astype(g.dtype), g32 - deq
    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))


def compression_ratio() -> float:
    """Wire bytes ratio vs f32 allreduce (int8 payload + f32 scale)."""
    return 4.0
