"""CLI: ``python -m repro.analysis`` — run both analysis layers, apply the
baseline ratchet, emit ``results/analysis.json``, exit non-zero on any new
violation.

  python -m repro.analysis                           # host grid + lint
  python -m repro.analysis --grid pod                # CI gate (512 devs)
  python -m repro.analysis --baseline analysis/baseline.json
  python -m repro.analysis --update-baseline ...     # re-pin (shrink only)
"""
import argparse
import os
import sys
import time


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checks (jaxpr/HLO) + project lint")
    ap.add_argument("--grid", choices=("host", "pod", "none"),
                    default="host",
                    help="mesh grid for the IR contract layer: 'host' = "
                         "forced host devices (fast, default), 'pod' = "
                         "production 16x16 / 2x16x16 meshes, 'none' = "
                         "lint only")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (ratchet: new violations fail, "
                         "pinned ones must only shrink)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline to the current violation "
                         "set (refuses to grow an existing pin)")
    ap.add_argument("--out", default="results/analysis.json",
                    help="machine-readable report path")
    ap.add_argument("--root", default=".",
                    help="repo root (lint paths are relative to it)")
    ap.add_argument("--lint-dir", action="append", default=None,
                    help="lint target (repeatable; default: src/repro, "
                         "benchmarks, examples, scripts)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(sys.argv[1:] if argv is None else argv)

    # device topology must be pinned BEFORE jax initializes (same
    # constraint launch/dryrun.py documents): the pod grid needs 512
    # forced host devices, the host grid the tier-1 default of 4.
    if args.grid == "pod" and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=512").strip()
    elif args.grid == "host":
        from repro import hostdev
        hostdev.ensure_host_devices()

    from repro.analysis import concurrency, lint, report

    wall = {}
    t0 = time.time()
    lint_dirs = args.lint_dir or [
        d for d in lint.DEFAULT_LINT_DIRS
        if os.path.isdir(os.path.join(args.root, d))]
    lint_violations, files_linted = lint.lint_paths(lint_dirs,
                                                    root=args.root)
    wall["lint"] = round(time.time() - t0, 3)
    print(f"[analysis] lint: {files_linted} files, "
          f"{len(lint_violations)} violations ({wall['lint']}s)")

    # concurrency contracts ride the lint bucket (same suppression /
    # ratchet machinery); they run in every grid mode incl. 'none'.
    t0 = time.time()
    conc_violations, _ = concurrency.check_paths(lint_dirs,
                                                 root=args.root)
    lint_violations = list(lint_violations) + list(conc_violations)
    wall["concurrency"] = round(time.time() - t0, 3)
    print(f"[analysis] concurrency: {files_linted} files, "
          f"{len(conc_violations)} violations "
          f"({wall['concurrency']}s)")

    contract_violations = []
    records = []
    if args.grid != "none":            # 'none' = lint only, no jax import
        from repro.analysis import contracts
        contract_violations, records, wall_c = contracts.run_contracts(
            args.grid)
        wall["contracts"] = round(wall_c, 3)
        print(f"[analysis] contracts ({args.grid} grid): "
              f"{len(records)} hot paths, "
              f"{len(contract_violations)} violations "
              f"({wall['contracts']}s)")

    violations = list(lint_violations) + list(contract_violations)

    new, shrunk, stale = violations, [], []
    if args.baseline and os.path.exists(args.baseline) \
            and not args.update_baseline:
        pinned = report.load_baseline(args.baseline)
        new, shrunk, stale = report.compare_baseline(violations, pinned)
        pinned_n = len(violations) - len(new)
        print(f"[analysis] baseline {args.baseline}: {len(new)} new, "
              f"{pinned_n} pinned, {len(shrunk)} shrunk, "
              f"{len(stale)} stale")
        for k in shrunk:
            print(f"[analysis]   shrunk: {k} (re-pin with "
                  "--update-baseline)")
        for k in stale:
            print(f"[analysis]   stale pin: {k} (re-pin with "
                  "--update-baseline)")

    if args.update_baseline:
        if not args.baseline:
            print("[analysis] --update-baseline requires --baseline",
                  file=sys.stderr)
            return 2
        cur = report.count_by_key(violations)
        if os.path.exists(args.baseline):
            pinned = report.load_baseline(args.baseline)
            grew = sorted(k for k, v in cur.items()
                          if v > pinned.get(k, 0))
            if grew:
                print("[analysis] refusing to GROW the baseline; fix or "
                      "suppress these first:", file=sys.stderr)
                for k in grew:
                    print(f"  {k}: {pinned.get(k, 0)} -> {cur[k]}",
                          file=sys.stderr)
                return 2
        report.save_baseline(args.baseline, cur)
        print(f"[analysis] baseline written: {args.baseline} "
              f"({len(cur)} keys)")
        new = []

    for v in new:
        print(f"  {v.format()}")
    exit_code = 1 if new else 0
    report.write_report(args.out, grid=args.grid,
                        lint_violations=lint_violations,
                        contract_violations=contract_violations,
                        contract_records=records,
                        files_linted=files_linted,
                        baseline_path=args.baseline,
                        new=new, shrunk=shrunk, stale=stale,
                        wall_s=wall, exit_code=exit_code)
    print(f"[analysis] report: {args.out}  ->  "
          f"{'FAIL' if exit_code else 'OK'}")
    return exit_code


if __name__ == "__main__":
    raise SystemExit(main())
