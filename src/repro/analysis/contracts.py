"""IR contract checks: lower the registered hot paths and statically prove
the engine's invariants on the jaxpr/HLO.

The contracts (ids ``IRC00x``; the lint layer owns ``REPROxxx``):

``IRC001`` zero collectives — ``distributed.update_step`` (the post-pivot
    O(n/p) maintenance axpy) must lower with NO collective ops at all.
    This is PR 2's design point; before this gate it was only demonstrated
    by a one-off dry-run.
``IRC002`` dense-pass discipline — the reduced costs are MAINTAINED, so
    ``pq_step`` performs exactly ONE top-level dense O(m·n/p) sweep of A
    (the pricing matvec; the dense flip-absorption fallback may add one
    more inside a ``cond`` branch) and ``update_step`` performs none.
    ``refresh_step`` is the only full-recompute site (``d = c - Aᵀy`` +
    the basic-value rebuild: one or two dense passes, recorded).
``IRC003`` no host round-trips in device loops — no python-callback
    custom-calls, infeed/outfeed or send/recv inside a ``while`` body
    (jaxpr level: no callback primitives anywhere in the hot path).
``IRC004`` collective budget — per-pivot collective bytes of ``pq_step``
    within the declared O(num_buckets + p·K + m) budget
    (:func:`pq_collective_budget`), via ``hlo_analysis.collective_bytes``.
``IRC005`` dtype preservation — lowering a hot path with f32 inputs must
    not introduce any f64 intermediate (under the repo's x64-enabled
    process a stray Python-int ``arange``/division silently promotes).

Every check reports through :class:`repro.analysis.report.Violation` with
``path`` = ``<hot path>@<mesh>`` so the baseline ratchet addresses hot
paths exactly like lint addresses files.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import core as jcore
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.report import Violation
from repro.distributed import hlo_analysis

# the analysis layer deliberately lowers every hot path at f64 (the x64
# production dtype) AND at f32 to prove dtype preservation; this is the
# probe dtype, not engine math:
_F64 = jnp.float64  # repro: allow[REPRO002] analysis-layer probe dtype

CONTRACTS: Dict[str, str] = {
    "IRC001": "zero collectives in the post-pivot update step",
    "IRC002": "dense-pass discipline (maintained reduced costs: one "
              "pricing sweep, refresh is the only recompute site)",
    "IRC003": "no host callbacks/transfers inside device while loops",
    "IRC004": "per-pivot collective bytes within the declared budget",
    "IRC005": "dtype preservation (no silent f64 introduction)",
}

# headroom over the analytic byte model: XLA pads bools, fuses scalar
# collectives and may tuple-combine gathers — 4x absorbs layout variance
# while still catching an accidental O(n) collective (which is orders of
# magnitude over budget, not a constant factor).
BUDGET_HEADROOM = 4.0

_COLLECTIVE_PRIMS = ("psum", "pmin", "pmax", "pargmin", "pargmax",
                     "all_gather", "all_to_all", "ppermute",
                     "reduce_scatter", "pbroadcast")
_CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                   "outside_call", "host_callback")


def pq_collective_budget(p: int, m: int, num_buckets: int = 128,
                         gather_k: int = 128, dtype_bytes: int = 8) -> float:
    """Declared per-pivot collective-byte budget for ``pq_step``.

    Mirrors the step's design-point traffic, O(num_buckets + p·K + m):
    the BFRT histogram all-reduce, the (p, K) exact-walk candidate
    all-gathers (3 float + 2 bool + 1 int64 per candidate, plus the
    per-shard trunc/kth scalars), the fvec/Acol psums and a fixed scalar
    overhead — times :data:`BUDGET_HEADROOM`.  Anything O(n) blows this
    budget by construction.
    """
    hist = 2 * num_buckets * dtype_bytes               # all-reduce
    gathered = p * gather_k * (3 * dtype_bytes + 2 + 8)
    shard_scalars = p * (1 + dtype_bytes)              # trunc + kth
    vecs = 2 * 2 * m * dtype_bytes                     # fvec + Acol psums
    misc = 64 * dtype_bytes                            # rmin/rmax/n_flips/...
    return BUDGET_HEADROOM * (hist + gathered + shard_scalars + vecs + misc)


# ------------------------------------------------------------ jaxpr walking


def _sub_jaxprs(v):
    if isinstance(v, jcore.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jcore.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _sub_jaxprs(x)


def walk_eqns(jaxpr, visit: Callable, ctx: Tuple[str, ...] = ()) -> None:
    """Visit every eqn of ``jaxpr`` and its nested sub-jaxprs (while
    bodies, cond branches, scan/pjit/shard_map/pallas inner jaxprs).
    ``ctx`` is the tuple of enclosing structured-control primitives."""
    for eqn in jaxpr.eqns:
        visit(eqn, ctx)
        name = eqn.primitive.name
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                walk_eqns(sub, visit, ctx + (name,))


def _jaxpr_of(fn, *args) -> jcore.Jaxpr:
    return jax.make_jaxpr(fn)(*args).jaxpr


def collective_prims(jaxpr) -> List[Tuple[str, Tuple[str, ...]]]:
    found: List[Tuple[str, Tuple[str, ...]]] = []

    def visit(eqn, ctx):
        # versioned primitive names (psum -> psum2) keep matching
        if eqn.primitive.name.rstrip("0123456789") in _COLLECTIVE_PRIMS:
            found.append((eqn.primitive.name, ctx))

    walk_eqns(jaxpr, visit)
    return found


def callback_prims(jaxpr) -> List[Tuple[str, Tuple[str, ...]]]:
    found: List[Tuple[str, Tuple[str, ...]]] = []

    def visit(eqn, ctx):
        name = eqn.primitive.name
        if any(c in name for c in _CALLBACK_PRIMS):
            found.append((name, ctx))

    walk_eqns(jaxpr, visit)
    return found


def dense_dot_counts(jaxpr, threshold_elems: int) -> Tuple[int, int]:
    """(top_level, in_cond_branch) counts of dot_general eqns with an
    operand of at least ``threshold_elems`` elements — the "dense pass
    over A" detector behind IRC002."""
    top = cond = 0

    def visit(eqn, ctx):
        nonlocal top, cond
        if eqn.primitive.name != "dot_general":
            return
        size = 0
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape:
                size = max(size, int(np.prod(shape)))
        if size >= threshold_elems:
            if "cond" in ctx:
                cond += 1
            else:
                top += 1

    walk_eqns(jaxpr, visit)
    return top, cond


def f64_introductions(jaxpr) -> List[str]:
    """Primitives whose outputs are float64 — meaningful only when the
    hot path was traced with float32 inputs (IRC005)."""
    found: List[str] = []

    def visit(eqn, ctx):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            # weak-typed f64 scalars (bare Python literals in a where/
            # select) never force promotion — only strong f64 counts
            if dt is not None and dt == _F64 and \
                    not getattr(aval, "weak_type", False):
                found.append(eqn.primitive.name)
                return

    walk_eqns(jaxpr, visit)
    return found


# ----------------------------------------------------------- hot-path audit


@dataclasses.dataclass
class HotPathResult:
    name: str          # e.g. "distributed.pq_step@2x2"
    wall_s: float
    record: dict       # collective bytes/counts, budgets, dense counts ...
    violations: List[Violation]

    @property
    def ok(self) -> bool:
        return not self.violations


def _mesh_label(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def _mesh_p(mesh) -> int:
    axes = [a for a in ("pod", "data", "model") if a in mesh.shape]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _hlo_host_violations(name: str, hlo: str) -> List[Violation]:
    out = []
    for h in hlo_analysis.host_transfer_ops(hlo):
        if h["in_while"]:
            out.append(Violation(
                "IRC003", name, 0,
                f"host op {h['op']}({h['target']}) inside while body "
                f"{h['computation']} (x{h['trips']} trips)"))
    return out


def _callback_violations(name: str, jaxpr) -> List[Violation]:
    out = []
    for prim, ctx in callback_prims(jaxpr):
        if "while" in ctx:
            out.append(Violation("IRC003", name, 0,
                                 f"callback primitive {prim} inside "
                                 f"while body (ctx={'/'.join(ctx)})"))
    return out


def check_pq_step(mesh, m: int = 8, n: int = 1 << 14,
                  num_buckets: int = 128, gather_k: int = 128
                  ) -> HotPathResult:
    """pq_step: one dense pricing sweep (IRC002), collective bytes within
    the declared per-pivot budget (IRC004), no host loops (IRC003), no
    f64 on f32 inputs (IRC005)."""
    from repro.core.distributed import make_pq_step, pq_input_specs
    t0 = time.time()
    label = _mesh_label(mesh)
    name = f"distributed.pq_step@{label}"
    p = _mesh_p(mesh)
    viol: List[Violation] = []
    step, col_spec, vec_spec = make_pq_step(mesh, m, n,
                                            num_buckets=num_buckets,
                                            gather_k=gather_k)
    rep = P()
    in_sh = (NamedSharding(mesh, col_spec),) + tuple(
        NamedSharding(mesh, vec_spec) for _ in range(4)) + tuple(
        NamedSharding(mesh, rep) for _ in range(3))
    with mesh:
        compiled = jax.jit(step, in_shardings=in_sh).lower(
            *pq_input_specs(m, n)).compile()
        hlo = compiled.as_text()
        jaxpr = _jaxpr_of(step, *pq_input_specs(m, n))
        jaxpr32 = _jaxpr_of(step, *pq_input_specs(m, n,
                                                  dtype=jnp.float32))
    coll = hlo_analysis.collective_bytes(hlo, default_group=p)
    budget = pq_collective_budget(p, m, num_buckets, gather_k)
    if coll.total_bytes > budget:
        viol.append(Violation(
            "IRC004", name, 0,
            f"per-pivot collective bytes {coll.total_bytes:.3e} exceed "
            f"declared budget {budget:.3e} "
            f"(p={p}, NB={num_buckets}, K={gather_k})"))
    viol += _hlo_host_violations(name, hlo)
    viol += _callback_violations(name, jaxpr)
    top, in_cond = dense_dot_counts(jaxpr, m * (n // p))
    if top != 1:
        viol.append(Violation(
            "IRC002", name, 0,
            f"{top} top-level dense passes over A (expected exactly 1: "
            "the pricing sweep — reduced costs are maintained, no "
            "c - y@A recompute belongs here)"))
    if in_cond > 1:
        viol.append(Violation(
            "IRC002", name, 0,
            f"{in_cond} dense passes inside cond branches (expected <= 1:"
            " the flip-absorption dense fallback)"))
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 inputs produce f64 intermediates via {sorted(set(f64s))}"
            ))
    rec = {"hot_path": name, "p": p, "m": m, "n": n,
           "collective_bytes": {k: float(v) for k, v in
                               coll.merged().items()},
           "collective_counts": dict(coll.count_by_kind),
           "budget_bytes": float(budget),
           "budget_used_frac": float(coll.total_bytes / budget),
           "dense_passes": {"top": top, "cond": in_cond}}
    return HotPathResult(name, time.time() - t0, rec, viol)


def check_update_step(mesh, m: int = 8, n: int = 1 << 14) -> HotPathResult:
    """update_step: ZERO collectives (IRC001) at both jaxpr and
    post-SPMD HLO level, zero dense passes (IRC002), f32-clean."""
    from repro.core.distributed import make_update_step
    t0 = time.time()
    label = _mesh_label(mesh)
    name = f"distributed.update_step@{label}"
    p = _mesh_p(mesh)
    viol: List[Violation] = []
    upd = make_update_step(mesh)
    axes = [a for a in ("pod", "data", "model") if a in mesh.shape]
    vec_spec = P(tuple(axes))
    rep = P()

    def abs_args(ft):
        f = lambda shape, dt=ft: jax.ShapeDtypeStruct(shape, dt)
        return (f((n,)), jax.ShapeDtypeStruct((n,), jnp.int32),
                f((n,)), jax.ShapeDtypeStruct((n,), jnp.bool_),
                f(()), jax.ShapeDtypeStruct((), jnp.int64),
                jax.ShapeDtypeStruct((), jnp.int64),
                jax.ShapeDtypeStruct((), jnp.bool_))

    in_sh = tuple(NamedSharding(mesh, vec_spec) for _ in range(4)) + \
        tuple(NamedSharding(mesh, rep) for _ in range(4))
    with mesh:
        compiled = jax.jit(upd, in_shardings=in_sh).lower(
            *abs_args(_F64)).compile()
        hlo = compiled.as_text()
        jaxpr = _jaxpr_of(upd, *abs_args(_F64))
        jaxpr32 = _jaxpr_of(upd, *abs_args(jnp.float32))
    coll = hlo_analysis.collective_bytes(hlo, default_group=p)
    n_coll = sum(coll.count_by_kind.values())
    if n_coll or coll.total_bytes:
        viol.append(Violation(
            "IRC001", name, 0,
            f"post-pivot update step lowered with {n_coll} collectives "
            f"({coll.total_bytes:.3e} bytes: "
            f"{sorted(coll.count_by_kind)}) — it must be purely "
            "shard-local"))
    jp_coll = collective_prims(jaxpr)
    if jp_coll:
        viol.append(Violation(
            "IRC001", name, 0,
            f"collective primitives in the update jaxpr: "
            f"{sorted({c for c, _ in jp_coll})}"))
    top, in_cond = dense_dot_counts(jaxpr, m * (n // p))
    if top or in_cond:
        viol.append(Violation(
            "IRC002", name, 0,
            f"{top + in_cond} dense passes in the O(n/p) update step "
            "(expected 0)"))
    viol += _hlo_host_violations(name, hlo)
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 inputs produce f64 intermediates via {sorted(set(f64s))}"
            ))
    rec = {"hot_path": name, "p": p, "n": n,
           "collective_bytes": {k: float(v) for k, v in
                               coll.merged().items()},
           "collective_counts": dict(coll.count_by_kind),
           "budget_bytes": 0.0,
           "dense_passes": {"top": top, "cond": in_cond}}
    return HotPathResult(name, time.time() - t0, rec, viol)


def check_refresh_step(mesh, m: int = 8, n: int = 1 << 14) -> HotPathResult:
    """refresh_step: the sanctioned full-recompute site — at least one
    dense pass is REQUIRED here (d = c - Aᵀy; the A@xN rebuild may add a
    second), its collective traffic is O(m), and it stays f32-clean."""
    from repro.core.distributed import make_refresh_step
    t0 = time.time()
    label = _mesh_label(mesh)
    name = f"distributed.refresh_step@{label}"
    p = _mesh_p(mesh)
    viol: List[Violation] = []
    ref = make_refresh_step(mesh)
    axes = [a for a in ("pod", "data", "model") if a in mesh.shape]
    col_spec = P(None, tuple(axes))
    vec_spec = P(tuple(axes))
    rep = P()

    def abs_args(ft):
        f = lambda shape, dt=ft: jax.ShapeDtypeStruct(shape, dt)
        return (f((m, n)), f((n,)), f((n,)), f((n,)), f((n,)), f((m,)))

    in_sh = (NamedSharding(mesh, col_spec),) + tuple(
        NamedSharding(mesh, vec_spec) for _ in range(4)) + (
        NamedSharding(mesh, rep),)
    with mesh:
        compiled = jax.jit(ref, in_shardings=in_sh).lower(
            *abs_args(_F64)).compile()
        hlo = compiled.as_text()
        jaxpr = _jaxpr_of(ref, *abs_args(_F64))
        jaxpr32 = _jaxpr_of(ref, *abs_args(jnp.float32))
    coll = hlo_analysis.collective_bytes(hlo, default_group=p)
    top, in_cond = dense_dot_counts(jaxpr, m * (n // p))
    if top < 1:
        viol.append(Violation(
            "IRC002", name, 0,
            "refresh_step lowered with no dense pass — it IS the "
            "sanctioned d = c - A^T y recompute site"))
    if top > 2:
        viol.append(Violation(
            "IRC002", name, 0,
            f"{top} dense passes in refresh_step (expected <= 2: the d "
            "recompute and the A@xN basic-value rebuild)"))
    viol += _hlo_host_violations(name, hlo)
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 inputs produce f64 intermediates via {sorted(set(f64s))}"
            ))
    rec = {"hot_path": name, "p": p, "n": n,
           "collective_bytes": {k: float(v) for k, v in
                               coll.merged().items()},
           "collective_counts": dict(coll.count_by_kind),
           "dense_passes": {"top": top, "cond": in_cond}}
    return HotPathResult(name, time.time() - t0, rec, viol)


def check_lp_twin(m: int = 4, N: int = 64, max_iters: int = 32
                  ) -> HotPathResult:
    """The jitted single-host LP twin (``lp._solve_lp_jax``): its pivot
    while-loop must contain no host callbacks (IRC003) and lowering with
    f32 operands must not promote to f64 (IRC005).  Trip-count recovery
    from the compiled HLO is recorded (the while bound must reflect the
    static ``max_iters``)."""
    from repro.core.lp import _solve_lp_jax
    t0 = time.time()
    name = f"lp.twin_step@m{m}_N{N}"
    viol: List[Violation] = []

    def abs_args(ft):
        f = lambda shape, dt=ft: jax.ShapeDtypeStruct(shape, dt)
        return (f((N,)), f((m, N)), f((N,)), f((N,)),
                jax.ShapeDtypeStruct((m,), jnp.int64),
                jax.ShapeDtypeStruct((N,), jnp.bool_))

    fn = lambda *a: _solve_lp_jax(*a, max_iters)
    compiled = jax.jit(fn).lower(*abs_args(_F64)).compile()
    hlo = compiled.as_text()
    jaxpr = _jaxpr_of(fn, *abs_args(_F64))
    jaxpr32 = _jaxpr_of(fn, *abs_args(jnp.float32))
    viol += _hlo_host_violations(name, hlo)
    viol += _callback_violations(name, jaxpr)
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 inputs produce f64 intermediates via {sorted(set(f64s))}"
            ))
    trips = hlo_analysis.while_trip_counts(hlo)
    rec = {"hot_path": name, "m": m, "N": N,
           "while_trip_counts": {k: int(v) for k, v in trips.items()},
           "max_trip": int(max(trips.values())) if trips else 0}
    return HotPathResult(name, time.time() - t0, rec, viol)


def check_lp_batch(m: int = 4, n: int = 16, K: int = 4,
                   max_iters: int = 16) -> HotPathResult:
    """The batched bound-variant LP engine (``lp_batch._batched_core``):
    a single-device batch, not an SPMD program, so it must lower with
    ZERO collectives (IRC001), no host callbacks/transfers inside the
    vmapped pivot while-loop (IRC003), and f32 operands must not
    silently promote to f64 (IRC005).  Shapes are one (m, n, K) shape
    class; the while trip bound must reflect the static per-lane cap."""
    from repro.core.lp_batch import _batched_core
    t0 = time.time()
    N = n + m
    name = f"lp_batch.core@m{m}_n{n}_K{K}"
    viol: List[Violation] = []
    core = _batched_core(m, n, K, max_iters, 64)

    def abs_args(ft):
        f = lambda shape, dt=ft: jax.ShapeDtypeStruct(shape, dt)
        # single packed operand: l | u | tol | basis0 | at_upper0 |
        # valid | pivot_cap — see _batched_core
        return (f((N,)), f((m, N)), f((K, 3 * N + m + 3)))

    compiled = core.lower(*abs_args(_F64)).compile()
    hlo = compiled.as_text()
    jaxpr = _jaxpr_of(core, *abs_args(_F64))
    jaxpr32 = _jaxpr_of(core, *abs_args(jnp.float32))
    jp_coll = collective_prims(jaxpr)
    if jp_coll:
        viol.append(Violation(
            "IRC001", name, 0,
            f"collective primitives in the batched LP core: "
            f"{sorted({c for c, _ in jp_coll})} — the wave solver is a "
            "single-device vmap, not an SPMD program"))
    viol += _hlo_host_violations(name, hlo)
    viol += _callback_violations(name, jaxpr)
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 inputs produce f64 intermediates via {sorted(set(f64s))}"
            ))
    trips = hlo_analysis.while_trip_counts(hlo)
    rec = {"hot_path": name, "m": m, "n": n, "K": K,
           "while_trip_counts": {k: int(v) for k, v in trips.items()},
           "max_trip": int(max(trips.values())) if trips else 0}
    return HotPathResult(name, time.time() - t0, rec, viol)


def check_kernel_pricing(m: int = 4, n: int = 4096) -> HotPathResult:
    """The Pallas pricing kernel, jaxpr level only: interpret-mode Pallas
    may legitimately lower to host callbacks in HLO, so the contract here
    is dtype preservation plus no callback primitives OUTSIDE the
    pallas_call itself."""
    from repro.kernels.pricing import pricing
    t0 = time.time()
    name = f"kernels.pricing@m{m}_n{n}"
    viol: List[Violation] = []

    def args(ft):
        f = lambda shape, dt=ft: jax.ShapeDtypeStruct(shape, dt)
        return (f((m, n)), f((m,)), f((n,)),
                jax.ShapeDtypeStruct((n,), jnp.int32),
                f((n,)), f((n,)), f(()))

    fn = lambda *a: pricing(*a)
    jaxpr32 = _jaxpr_of(fn, *args(jnp.float32))
    jaxpr = _jaxpr_of(fn, *args(_F64))
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 inputs produce f64 intermediates via {sorted(set(f64s))}"
            ))
    for prim, ctx in callback_prims(jaxpr):
        if not any("pallas" in c for c in ctx):
            viol.append(Violation(
                "IRC003", name, 0,
                f"callback primitive {prim} outside the pallas_call "
                f"(ctx={'/'.join(ctx)})"))
    rec = {"hot_path": name, "m": m, "n": n}
    return HotPathResult(name, time.time() - t0, rec, viol)


def check_kernel_segstats(n: int = 4096, k: int = 4) -> HotPathResult:
    """The Pallas segment-stats kernel: f32 accumulation is BY DESIGN
    (preferred_element_type=f32) — the contract is that f32 inputs never
    promote to f64, and no callbacks escape the pallas_call."""
    from repro.kernels.segstats import segstats_partials
    t0 = time.time()
    name = f"kernels.segstats@n{n}_k{k}"
    viol: List[Violation] = []
    fn = lambda v, i: segstats_partials(v, i)
    a32 = (jax.ShapeDtypeStruct((n, k), jnp.float32),
           jax.ShapeDtypeStruct((n,), jnp.int32))
    jaxpr32 = _jaxpr_of(fn, *a32)
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 inputs produce f64 intermediates via {sorted(set(f64s))}"
            ))
    for prim, ctx in callback_prims(jaxpr32):
        if not any("pallas" in c for c in ctx):
            viol.append(Violation(
                "IRC003", name, 0,
                f"callback primitive {prim} outside the pallas_call "
                f"(ctx={'/'.join(ctx)})"))
    rec = {"hot_path": name, "n": n, "k": k}
    return HotPathResult(name, time.time() - t0, rec, viol)


def check_split_descent(batch: int = 1024, nodes: int = 31,
                        bounds_per: int = 3) -> HotPathResult:
    """Batched split-tree descent (``partitioner._descend_batch_jax``):
    the nested while loops (tree levels x bisection) must not host-sync
    per level (IRC003) and must not promote f32 tuple values (IRC005)."""
    from repro.core.partitioner import _descend_batch_jax
    t0 = time.time()
    name = f"partitioner.descend_batch@b{batch}_N{nodes}"
    viol: List[Violation] = []
    B = nodes * bounds_per

    def args(ft):
        return (jax.ShapeDtypeStruct((nodes,), jnp.int32),
                jax.ShapeDtypeStruct((nodes + 1,), jnp.int64),
                jax.ShapeDtypeStruct((B,), ft),
                jax.ShapeDtypeStruct((B + nodes,), jnp.int64),
                jax.ShapeDtypeStruct((), jnp.int64),
                jax.ShapeDtypeStruct((batch, 4), ft))

    fn = lambda *a: _descend_batch_jax(*a)
    compiled = jax.jit(fn).lower(*args(_F64)).compile()
    hlo = compiled.as_text()
    jaxpr = _jaxpr_of(fn, *args(_F64))
    jaxpr32 = _jaxpr_of(fn, *args(jnp.float32))
    viol += _hlo_host_violations(name, hlo)
    viol += _callback_violations(name, jaxpr)
    f64s = f64_introductions(jaxpr32)
    if f64s:
        viol.append(Violation(
            "IRC005", name, 0,
            f"f32 tuples promote to f64 via {sorted(set(f64s))}"))
    rec = {"hot_path": name, "batch": batch, "nodes": nodes}
    return HotPathResult(name, time.time() - t0, rec, viol)


# -------------------------------------------------------------- mesh grids


def _host_meshes():
    """Meshes buildable on the 4 forced host devices (tier-1 tests)."""
    metas = []
    if len(jax.devices()) >= 2:
        metas.append(jax.make_mesh((1, 2), ("data", "model")))
    if len(jax.devices()) >= 4:
        metas.append(jax.make_mesh((2, 2), ("data", "model")))
    return metas


def _pod_meshes():
    """The production pod grid (needs 512 forced host devices — the CLI
    sets XLA_FLAGS before importing jax, like launch/dryrun.py)."""
    from repro.launch.mesh import make_production_mesh
    return [make_production_mesh(multi_pod=False),
            make_production_mesh(multi_pod=True)]


GRID_SHAPES = {
    # grid -> (m, n) for the distributed steps; n divisible by every p
    "host": (8, 1 << 14),
    "pod": (8, 1 << 20),
}


def run_contracts(grid: str = "host"
                  ) -> Tuple[List[Violation], List[dict], float]:
    """Run every hot-path check over the requested mesh grid.

    ``grid='none'`` skips the mesh-dependent checks (lint-only CI lanes);
    ``'host'`` uses the forced-host-device meshes the tier-1 tests use;
    ``'pod'`` lowers for the production 16x16 / 2x16x16 meshes.
    Returns (violations, per-hot-path records, total wall seconds).
    """
    t0 = time.time()
    results: List[HotPathResult] = []
    if grid != "none":
        m, n = GRID_SHAPES[grid]
        meshes = _host_meshes() if grid == "host" else _pod_meshes()
        for mesh in meshes:
            results.append(check_pq_step(mesh, m, n))
            results.append(check_update_step(mesh, m, n))
            results.append(check_refresh_step(mesh, m, n))
    results.append(check_lp_twin())
    results.append(check_lp_batch())
    results.append(check_kernel_pricing())
    results.append(check_kernel_segstats())
    results.append(check_split_descent())
    violations = [v for r in results for v in r.violations]
    records = [dict(r.record, wall_s=round(r.wall_s, 3)) for r in results]
    return violations, records, time.time() - t0
