"""Project lint — the footgun classes this repo has already paid for,
encoded as named AST rules.

Every rule below traces to a bug a past PR burned time on; the lint
exists so the *class* can never silently come back:

``REPRO001`` ``lax.cond``/``lax.switch`` branches closing over mutable
    enclosing-function state.  PR 1's jit-vs-eager divergence: branch
    jaxprs are cached by function identity, so a closure reused across
    cond calls with *different* captured tracers replays the first
    call's state.  Safe sites (identical captured state at every call
    within one trace) carry an explicit suppression.
``REPRO002`` unguarded ``jnp.float64`` references or float literals
    beyond f32 range (the ``1e300`` sentinel class) outside
    ``hostdev.py``.  PR 2's ``big_sentinel`` fix: under default no-x64
    such literals warn and truncate to ``inf``, silently poisoning
    masked reductions.
``REPRO003`` host materialisation of traced values (``.item()``,
    ``float()``/``int()``/``bool()``, ``np.asarray``) inside functions
    that are jitted or passed to ``lax`` control flow — a silent
    device-to-host sync (or a tracer error) in a hot loop.
``REPRO004`` bare ``except Exception`` / ``except:``.  The guard
    contract (PR 6) owns exception containment; any other broad handler
    can swallow the faults the resilience suite injects.  Sanctioned
    sites (``core/guard.py`` ladder, ``core/engine.py`` solve guard,
    ``runtime/faults.py``, harness loops) carry suppressions tying them
    to the guard ladder.
``REPRO005`` whole-column materialisation of a ``Relation``
    (``np.asarray(table[...])``-style, or a full ``[:]`` slice of a
    column) — defeats PR 4's out-of-core discipline; ``LazyColumn``
    raises at runtime, the lint catches it before it ships.
``REPRO006`` un-budgeted solver loops: a ``for``/``while`` whose header
    mentions ``max_iters``/``max_pivots``/``max_nodes`` but whose body
    never consults a ``SolveBudget`` — exactly the silent ``ITER_LIMIT``
    truncation PR 6 removed.
``REPRO007`` cache writes under swallowed exceptions: a
    ``*cache*.store/put/populate/insert`` call inside a ``try`` whose
    broad handler can eat the failure, or inside an ``except`` body.
    The cross-query cache contract (PR 8, ``core/qcache.py``) admits
    only *clean* solves; a write whose failure path is swallowed — or
    that IS a failure path — can poison every later hit.  Cache writes
    belong at guard-contract sites, after validation.

Suppression: append ``# repro: allow[REPROxxx] <justification>`` on the
flagged line or the line directly above it.  The justification is
mandatory — a bare allow is itself a violation of the same rule.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.report import Violation

RULES: Dict[str, str] = {
    "REPRO001": "lax.cond/lax.switch branch closes over enclosing "
                "mutable state (branch jaxprs are cached by function "
                "identity)",
    "REPRO002": "unguarded float64 reference / beyond-f32-range literal "
                "(truncates to inf under no-x64)",
    "REPRO003": "host materialisation of a traced value inside a "
                "jitted/control-flow function",
    "REPRO004": "bare `except Exception` outside the guard contract",
    "REPRO005": "whole-column materialisation of a streamed Relation",
    "REPRO006": "solver loop bounded by max_iters/pivots/nodes without "
                "charging a SolveBudget",
    "REPRO007": "cache write inside a broad exception handler / try — "
                "a swallowed failure can populate poisoned artifacts",
}

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*allow\[(REPRO\d{3})\]\s*(.*)")

# REPRO002: anything beyond float32 range is the 1e300 sentinel class.
_F32_MAX = 3.5e38  # repro: allow[REPRO002] the rule's own threshold
_F64_ALLOWED_FILES = ("hostdev.py",)

# REPRO003: the jit-entry decorators / tracing higher-order callees.
_JIT_DECOS = ("jax.jit", "jit", "pjit", "jax.pjit")
_TRACING_CALLEES = ("lax.while_loop", "lax.fori_loop", "lax.scan",
                    "lax.cond", "lax.switch", "lax.map", "shard_map",
                    "pallas_call", "jax.vmap", "vmap", "jax.grad",
                    "checkify")
_HOST_CASTS = ("float", "int", "bool")
_HOST_NP_CALLS = ("np.asarray", "np.array", "numpy.asarray",
                  "numpy.array")

# REPRO005: names that conventionally hold a (possibly streamed) Relation.
_RELATION_NAMES = ("table", "rel", "relation")
_NP_GATHER_CALLS = ("np.asarray", "np.array", "np.stack",
                    "np.column_stack", "np.vstack", "np.concatenate",
                    "numpy.asarray", "numpy.array", "numpy.stack")

_BUDGET_TOKENS = ("max_iters", "max_pivots", "max_nodes")

# REPRO007: mutating methods on a receiver whose name mentions a cache.
_CACHE_WRITE_METHODS = ("store", "put", "populate", "insert")


def _qualname(node: ast.AST) -> str:
    """Dotted name of an expression ('jax.lax.cond'), '' if not a name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_relationish(node: ast.AST) -> bool:
    """Name/attribute that conventionally binds a Relation."""
    q = _qualname(node)
    if not q:
        return False
    last = q.split(".")[-1]
    return last in _RELATION_NAMES


class _Scope:
    """One function scope: bindings + locally defined functions."""

    def __init__(self, node: Optional[ast.AST]):
        self.node = node
        self.bound: Set[str] = set()
        self.funcs: Dict[str, ast.AST] = {}


def _function_params(fn: ast.AST) -> Set[str]:
    args = fn.args
    names = {a.arg for a in args.posonlyargs + args.args + args.kwonlyargs}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    return names


def _local_bindings(fn: ast.AST) -> Set[str]:
    """Names bound inside ``fn`` (params, assignments, nested defs) —
    NOT descending into nested functions' own bodies for assignments."""
    bound = set(_function_params(fn))
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    stack = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(node.name)
            continue                      # do not descend: own scope
        if isinstance(node, ast.Lambda):
            continue
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for al in node.names:
                bound.add((al.asname or al.name).split(".")[0])
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
        stack.extend(ast.iter_child_nodes(node))
    return bound


def _free_names(fn: ast.AST) -> Set[str]:
    """Names loaded in ``fn``'s body that it does not bind itself
    (descends into nested functions, subtracting their params too)."""
    import builtins
    bound = _local_bindings(fn)
    loads: Set[str] = set()
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    stack: List[Tuple[ast.AST, frozenset]] = [(b, frozenset()) for b in body]
    while stack:
        node, extra = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            inner = extra | frozenset(_function_params(node)) \
                | frozenset(_local_bindings(node))
            body2 = node.body if isinstance(node.body, list) \
                else [node.body]
            stack.extend((b, inner) for b in body2)
            continue
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id not in bound and node.id not in extra \
                    and not hasattr(builtins, node.id):
                loads.add(node.id)
        stack.extend((c, extra) for c in ast.iter_child_nodes(node))
    return loads


class Linter:
    """Single-file linter; :func:`lint_source` is the entry point."""

    def __init__(self, src: str, path: str):
        self.src = src
        self.path = path
        self.lines = src.splitlines()
        self.tree = ast.parse(src, filename=path)
        self.module_names = self._module_level_names()
        self.violations: List[Violation] = []
        # function node -> enclosing function nodes (outermost first)
        self._enclosing: Dict[ast.AST, List[ast.AST]] = {}
        self._traced: Set[ast.AST] = set()
        self._fn_by_scope: Dict[ast.AST, Dict[str, ast.AST]] = {}

    # ------------------------------------------------------------- infra

    def _module_level_names(self) -> Set[str]:
        names: Set[str] = set()
        for node in self.tree.body:
            if isinstance(node, (ast.Import, ast.ImportFrom)):
                for al in node.names:
                    names.add((al.asname or al.name).split(".")[0])
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                names.add(node.name)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            names.add(n.id)
            elif isinstance(node, ast.AnnAssign) and \
                    isinstance(node.target, ast.Name):
                names.add(node.target.id)
        return names

    def _suppressed(self, rule: str, line: int) -> bool:
        """Trailing comment on the flagged line, or anywhere in the
        contiguous comment block immediately above it."""
        def _match(ln: int) -> bool:
            m = _SUPPRESS_RE.search(self.lines[ln - 1])
            return bool(m and m.group(1) == rule and m.group(2).strip())

        if 1 <= line <= len(self.lines) and _match(line):
            return True
        ln = line - 1
        while 1 <= ln <= len(self.lines) and \
                self.lines[ln - 1].lstrip().startswith("#"):
            if _match(ln):
                return True
            ln -= 1
        return False

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        if self._suppressed(rule, line):
            return
        self.violations.append(Violation(rule, self.path, line, message))

    # ------------------------------------------------------------ passes

    def run(self) -> List[Violation]:
        self._index_functions()
        self._mark_traced()
        self._walk_rules()
        return self.violations

    def _index_functions(self) -> None:
        """Record every function/lambda with its chain of enclosing
        function nodes, and a per-scope name -> FunctionDef map."""
        def visit(node: ast.AST, chain: List[ast.AST]) -> None:
            scope_fns = self._fn_by_scope.setdefault(
                chain[-1] if chain else self.tree, {})
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    self._enclosing[child] = list(chain)
                    scope_fns[child.name] = child
                    visit(child, chain + [child])
                elif isinstance(child, ast.Lambda):
                    self._enclosing[child] = list(chain)
                    visit(child, chain + [child])
                else:
                    visit(child, chain)
        visit(self.tree, [])

    def _decorated_jit(self, fn: ast.AST) -> bool:
        for deco in getattr(fn, "decorator_list", ()):  # lambdas: none
            target = deco
            if isinstance(deco, ast.Call):
                q = _qualname(deco.func)
                if q.endswith("partial"):
                    for a in deco.args:
                        if _qualname(a).endswith("jit"):
                            return True
                target = deco.func
            if _qualname(target).endswith(_JIT_DECOS):
                return True
        return False

    def _mark_traced(self) -> None:
        """A function is 'traced' if jit-decorated, passed to a tracing
        higher-order callee, or nested inside a traced function."""
        for fn in self._enclosing:
            if self._decorated_jit(fn):
                self._traced.add(fn)
        for call in ast.walk(self.tree):
            if not isinstance(call, ast.Call):
                continue
            q = _qualname(call.func)
            if not q.endswith(_TRACING_CALLEES):
                continue
            cargs = list(call.args) + [kw.value for kw in call.keywords]
            for a in cargs:
                for ref in self._resolve_fn_args(a, call):
                    self._traced.add(ref)
        # propagate: nested inside traced -> traced
        for fn, chain in self._enclosing.items():
            if any(c in self._traced for c in chain):
                self._traced.add(fn)

    def _resolve_fn_args(self, arg: ast.AST,
                         at: ast.AST) -> Iterable[ast.AST]:
        """Function nodes an argument expression refers to (lambdas,
        names of locally defined functions; descends list literals)."""
        if isinstance(arg, ast.Lambda):
            yield arg
        elif isinstance(arg, (ast.List, ast.Tuple)):
            for el in arg.elts:
                yield from self._resolve_fn_args(el, at)
        elif isinstance(arg, ast.Name):
            fn = self._lookup_function(arg.id, at)
            if fn is not None:
                yield fn

    def _lookup_function(self, name: str,
                         at: ast.AST) -> Optional[ast.AST]:
        """Resolve ``name`` to a FunctionDef visible at ``at`` (nearest
        enclosing scope outwards, module last)."""
        chain = None
        for fn, ch in self._enclosing.items():
            if fn is at:
                chain = ch
                break
        if chain is None:
            node, chain = at, []
            while not isinstance(node, (ast.FunctionDef,
                                        ast.AsyncFunctionDef, ast.Lambda,
                                        ast.Module)):
                node = getattr(node, "_parent", self.tree)
            # fall back to searching all scopes containing this lineno
            chain = [fn for fn in self._enclosing
                     if self._contains(fn, at)]
        for scope in list(reversed(chain)) + [self.tree]:
            fn = self._fn_by_scope.get(scope, {}).get(name)
            if fn is not None:
                return fn
        return None

    def _contains(self, fn: ast.AST, node: ast.AST) -> bool:
        lo = getattr(fn, "lineno", -1)
        hi = getattr(fn, "end_lineno", -1)
        ln = getattr(node, "lineno", -2)
        return lo <= ln <= hi

    # ------------------------------------------------------- rule checks

    def _walk_rules(self) -> None:
        basename = os.path.basename(self.path)
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                self._check_cond_closures(node)       # REPRO001
                self._check_relation_gather(node)     # REPRO005
            if isinstance(node, (ast.Attribute, ast.Name)) and \
                    basename not in _F64_ALLOWED_FILES:
                q = _qualname(node)
                if q in ("jnp.float64", "jax.numpy.float64",
                         "np.float128", "numpy.float128"):
                    self._emit("REPRO002", node,
                               f"{q} reference — derive the dtype from "
                               "an operand (cf. distributed.big_sentinel)"
                               )
            if isinstance(node, ast.Constant) and \
                    isinstance(node.value, float) and \
                    abs(node.value) >= _F32_MAX and \
                    basename not in _F64_ALLOWED_FILES:
                self._emit("REPRO002", node,
                           f"literal {node.value!r} exceeds f32 range — "
                           "truncates to inf under no-x64")
            if isinstance(node, ast.ExceptHandler):
                self._check_bare_except(node)         # REPRO004
            if isinstance(node, (ast.For, ast.While)):
                self._check_unbudgeted_loop(node)     # REPRO006
            if isinstance(node, ast.Subscript):
                self._check_full_slice(node)          # REPRO005 (b)
            if isinstance(node, ast.Try):
                self._check_cache_write_swallow(node)  # REPRO007
        self._check_traced_materialisation()          # REPRO003

    # REPRO001 ---------------------------------------------------------
    def _check_cond_closures(self, call: ast.Call) -> None:
        q = _qualname(call.func)
        if not (q.endswith("lax.cond") or q.endswith("lax.switch")
                or q in ("cond", "switch")):
            return
        if q in ("cond", "switch") and q not in self.module_names:
            return
        branches: List[ast.AST] = []
        for a in call.args[1:]:
            if isinstance(a, (ast.Lambda,)):
                branches.append(a)
            elif isinstance(a, (ast.List, ast.Tuple)):
                branches.extend(e for e in a.elts
                                if isinstance(e, ast.Lambda)
                                or isinstance(e, ast.Name))
            elif isinstance(a, ast.Name):
                branches.append(a)
        for br in branches:
            fn = br if isinstance(br, ast.Lambda) else \
                self._lookup_function(br.id, call)
            if fn is None:
                continue
            chain = self._enclosing.get(fn)
            if not chain:           # module-level function: no closure
                continue
            enclosing_bound: Set[str] = set()
            for outer in chain:
                enclosing_bound |= _local_bindings(outer)
            captured = sorted((_free_names(fn) - self.module_names)
                              & enclosing_bound)
            if captured:
                name = getattr(fn, "name", "<lambda>")
                self._emit("REPRO001", call,
                           f"branch {name!r} closes over enclosing state "
                           f"{captured} — pass it as a cond operand")

    # REPRO003 ---------------------------------------------------------
    def _check_traced_materialisation(self) -> None:
        for fn in self._traced:
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef,
                                         ast.Lambda)):
                        continue    # checked via their own traced entry
                    if not isinstance(node, ast.Call):
                        continue
                    q = _qualname(node.func)
                    if q.endswith(".item") and not node.args:
                        self._emit("REPRO003", node,
                                   ".item() inside a traced function "
                                   "forces a device sync / tracer error")
                    elif q in _HOST_CASTS and node.args and \
                            not isinstance(node.args[0], ast.Constant):
                        self._emit("REPRO003", node,
                                   f"{q}() on a traced value — use "
                                   "jnp ops or hoist out of the jit")
                    elif q in _HOST_NP_CALLS:
                        self._emit("REPRO003", node,
                                   f"{q}() materialises a traced value "
                                   "on host — use jnp.asarray")

    # REPRO004 ---------------------------------------------------------
    def _check_bare_except(self, node: ast.ExceptHandler) -> None:
        def broad(t: ast.AST) -> bool:
            return _qualname(t).split(".")[-1] in ("Exception",
                                                   "BaseException")
        ty = node.type
        if ty is None or broad(ty) or (
                isinstance(ty, ast.Tuple) and any(broad(e)
                                                  for e in ty.elts)):
            self._emit("REPRO004", node,
                       "bare except — narrow it, or tie it to the guard "
                       "ladder with an explicit suppression")

    # REPRO005 ---------------------------------------------------------
    def _check_relation_gather(self, call: ast.Call) -> None:
        q = _qualname(call.func)
        if q not in _NP_GATHER_CALLS:
            return
        for a in call.args:
            for sub in ast.walk(a):
                if isinstance(sub, ast.Subscript) and \
                        _is_relationish(sub.value) and \
                        not isinstance(sub.slice, (ast.Slice, ast.Tuple)):
                    self._emit(
                        "REPRO005", call,
                        f"{q}({_qualname(sub.value)}[...]) materialises "
                        "a whole column — gather candidate rows via "
                        "gather_rows()/chunks()")
                    return

    def _check_full_slice(self, node: ast.Subscript) -> None:
        # table['col'][:] — full-column slice of a relation column
        if not (isinstance(node.slice, ast.Slice)
                and node.slice.lower is None and node.slice.upper is None
                and node.slice.step is None):
            return
        base = node.value
        if isinstance(base, ast.Subscript) and _is_relationish(base.value):
            self._emit("REPRO005", node,
                       "full [:] slice of a Relation column — use "
                       "gather_rows()/chunks()")

    # REPRO007 ---------------------------------------------------------
    def _check_cache_write_swallow(self, node: ast.Try) -> None:
        def broad(h: ast.ExceptHandler) -> bool:
            ty = h.type
            if ty is None:
                return True

            def b(t: ast.AST) -> bool:
                return _qualname(t).split(".")[-1] in ("Exception",
                                                       "BaseException")
            return b(ty) or (isinstance(ty, ast.Tuple)
                             and any(b(e) for e in ty.elts))

        def cache_writes(stmts):
            for stmt in stmts:
                for sub in ast.walk(stmt):
                    if isinstance(sub, ast.Call) and \
                            isinstance(sub.func, ast.Attribute) and \
                            sub.func.attr in _CACHE_WRITE_METHODS and \
                            "cache" in _qualname(sub.func.value).lower():
                        yield sub

        if any(broad(h) for h in node.handlers):
            for call in cache_writes(node.body):
                self._emit(
                    "REPRO007", call,
                    f"{_qualname(call.func.value)}.{call.func.attr}() in "
                    "a try whose broad handler can swallow its failure — "
                    "populate caches only at guard-contract sites")
        for h in node.handlers:
            for call in cache_writes(h.body):
                self._emit(
                    "REPRO007", call,
                    f"{_qualname(call.func.value)}.{call.func.attr}() "
                    "inside an except body — a failure path must never "
                    "populate the cache")

    # REPRO006 ---------------------------------------------------------
    def _check_unbudgeted_loop(self, node: ast.AST) -> None:
        header = node.iter if isinstance(node, ast.For) else node.test
        tokens = {n.id for n in ast.walk(header)
                  if isinstance(n, ast.Name)}
        tokens |= {n.attr for n in ast.walk(header)
                   if isinstance(n, ast.Attribute)}
        if not tokens & set(_BUDGET_TOKENS):
            return
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and "budget" in sub.id.lower():
                return
            if isinstance(sub, ast.Attribute) and \
                    "budget" in sub.attr.lower():
                return
        self._emit("REPRO006", node,
                   "loop bounded by max_iters/pivots/nodes never "
                   "consults a SolveBudget — silent truncation "
                   "(the pre-PR-6 ITER_LIMIT class)")


# ------------------------------------------------------------- entry points


def lint_source(src: str, path: str = "<memory>") -> List[Violation]:
    """Lint one source string (the unit-test entry point)."""
    try:
        return Linter(src, path).run()
    except SyntaxError as e:
        return [Violation("REPRO000", path, e.lineno or 0,
                          f"syntax error: {e.msg}")]


def lint_file(path: str, root: str = ".") -> List[Violation]:
    with open(path) as f:
        src = f.read()
    return lint_source(src, os.path.relpath(path, root))


DEFAULT_LINT_DIRS = ("src/repro", "benchmarks", "examples", "scripts")


def lint_paths(paths: Sequence[str], root: str = "."
               ) -> Tuple[List[Violation], int]:
    """Lint every ``*.py`` under ``paths`` (files or directories).
    Returns (violations, files_linted)."""
    files: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            files.append(full)
        else:
            for dirpath, _, names in os.walk(full):
                files.extend(os.path.join(dirpath, n)
                             for n in sorted(names) if n.endswith(".py"))
    out: List[Violation] = []
    for f in sorted(files):
        out.extend(lint_file(f, root))
    return out, len(files)
