"""Violation model, baseline ratchet, and the machine-readable report.

Shared by both analysis layers (``analysis.contracts`` — IR contract
checks, ``analysis.lint`` — the project AST lint) and the
``python -m repro.analysis`` CLI:

* :class:`Violation` — one finding, addressed by ``rule:path`` (line
  numbers drift, so the baseline pins *counts per (rule, path)*, not
  positions).
* :func:`compare_baseline` — the ratchet.  New violations (any
  ``rule:path`` count above its pinned value, or an unpinned key) fail;
  pinned violations are tolerated; a shrunk count is reported so the
  baseline can be re-pinned smaller (``--update-baseline``), never
  larger.
* :func:`write_report` — ``results/analysis.json``: every violation,
  the per-hot-path contract records (collective bytes vs declared
  budgets, wall time) and the baseline delta, so budget regressions show
  up in the bench trajectory like perf regressions do.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

BASELINE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding from either layer.

    ``rule``  — lint rule id (``REPRO001``..) or contract id (``IRC00x``);
    ``path``  — repo-relative file path (lint) or hot-path name like
    ``distributed.update_step@2x2`` (contracts);
    ``line``  — 1-based source line (0 for contract findings).
    """
    rule: str
    path: str
    line: int
    message: str

    @property
    def key(self) -> str:
        """Baseline key: stable under line drift and message rewording."""
        return f"{self.rule}:{self.path}"

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule}: {self.message}"


def count_by_key(violations: Sequence[Violation]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for v in violations:
        out[v.key] = out.get(v.key, 0) + 1
    return out


# -------------------------------------------------------------- baseline


def load_baseline(path: str) -> Dict[str, int]:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or "pinned" not in data:
        raise ValueError(f"{path}: not a baseline file "
                         "(expected {'version': 1, 'pinned': {...}})")
    return {str(k): int(v) for k, v in data["pinned"].items()}


def save_baseline(path: str, pinned: Dict[str, int]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"version": BASELINE_VERSION,
                   "pinned": dict(sorted(pinned.items()))}, f, indent=1,
                  sort_keys=False)
        f.write("\n")


def compare_baseline(violations: Sequence[Violation],
                     pinned: Dict[str, int]
                     ) -> Tuple[List[Violation], List[str], List[str]]:
    """Ratchet comparison.

    Returns ``(new, shrunk, stale)``: ``new`` is every violation beyond
    its pinned count (these fail the gate); ``shrunk`` lists keys whose
    count dropped below the pin; ``stale`` lists pinned keys with no
    remaining violations at all.  Shrunk/stale keys never fail — they are
    the ratchet's progress signal (re-pin with ``--update-baseline``).
    """
    seen: Dict[str, int] = {}
    new: List[Violation] = []
    for v in violations:
        seen[v.key] = seen.get(v.key, 0) + 1
        if seen[v.key] > pinned.get(v.key, 0):
            new.append(v)
    cur = count_by_key(violations)
    shrunk = sorted(k for k, n in pinned.items() if 0 < cur.get(k, 0) < n)
    stale = sorted(k for k, n in pinned.items() if cur.get(k, 0) == 0)
    return new, shrunk, stale


# ---------------------------------------------------------------- report


def write_report(out_path: str, *,
                 grid: str,
                 lint_violations: Sequence[Violation],
                 contract_violations: Sequence[Violation],
                 contract_records: Sequence[dict],
                 files_linted: int,
                 baseline_path: Optional[str] = None,
                 new: Optional[Sequence[Violation]] = None,
                 shrunk: Optional[Sequence[str]] = None,
                 stale: Optional[Sequence[str]] = None,
                 wall_s: Optional[Dict[str, float]] = None,
                 exit_code: int = 0) -> dict:
    rep = {
        "grid": grid,
        "exit_code": int(exit_code),
        "lint": {
            "files": int(files_linted),
            "violations": [dataclasses.asdict(v) for v in lint_violations],
            "by_rule": _by_rule(lint_violations),
        },
        "contracts": {
            "violations": [dataclasses.asdict(v)
                           for v in contract_violations],
            "hot_paths": list(contract_records),
        },
        "wall_s": dict(wall_s or {}),
    }
    if baseline_path is not None:
        rep["baseline"] = {
            "path": baseline_path,
            "new": [v.format() for v in (new or [])],
            "shrunk": list(shrunk or []),
            "stale": list(stale or []),
        }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rep, f, indent=1)
        f.write("\n")
    return rep


def _by_rule(violations: Sequence[Violation]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for v in violations:
        out[v.rule] = out.get(v.rule, 0) + 1
    return dict(sorted(out.items()))
