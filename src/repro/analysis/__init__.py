"""repro.analysis — static proof of the engine's invariants.

Two layers, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.contracts` lowers the registered hot paths
  (distributed pq/update/refresh steps, the jitted LP twin, the Pallas
  kernels, batched split-tree descent) and asserts machine-checkable
  contracts on the jaxpr/HLO — zero collectives in ``update_step``,
  dense-pass discipline, no host round-trips in device loops, per-pivot
  collective bytes within declared budgets, dtype preservation.
* :mod:`repro.analysis.lint` is an AST pass encoding the repo's paid-for
  footgun classes as named REPRO rules with per-rule suppressions.

The CLI gates CI with a baseline ratchet (``analysis/baseline.json``):
new violations fail, pinned ones must only shrink.  See docs/ANALYSIS.md.
"""
from repro.analysis.report import (Violation, compare_baseline,
                                   count_by_key, load_baseline,
                                   save_baseline, write_report)
from repro.analysis.lint import (RULES, lint_file, lint_paths, lint_source,
                                 DEFAULT_LINT_DIRS)

__all__ = [
    "Violation", "compare_baseline", "count_by_key", "load_baseline",
    "save_baseline", "write_report", "RULES", "lint_file", "lint_paths",
    "lint_source", "DEFAULT_LINT_DIRS",
]
