"""repro.analysis — static proof of the engine's invariants.

Two layers, one CLI (``python -m repro.analysis``):

* :mod:`repro.analysis.contracts` lowers the registered hot paths
  (distributed pq/update/refresh steps, the jitted LP twin, the Pallas
  kernels, batched split-tree descent) and asserts machine-checkable
  contracts on the jaxpr/HLO — zero collectives in ``update_step``,
  dense-pass discipline, no host round-trips in device loops, per-pivot
  collective bytes within declared budgets, dtype preservation.
* :mod:`repro.analysis.lint` is an AST pass encoding the repo's paid-for
  footgun classes as named REPRO rules with per-rule suppressions.
* :mod:`repro.analysis.concurrency` extends the lint with the
  shared-state contracts of the serving path (REPRO008-012):
  ``__guarded_by__`` declarations, check-then-act cache races,
  unlocked process-globals, dispatch-under-lock, torn stats.

The CLI gates CI with a baseline ratchet (``analysis/baseline.json``):
new violations fail, pinned ones must only shrink.  See docs/ANALYSIS.md.
"""
from repro.analysis.report import (Violation, compare_baseline,
                                   count_by_key, load_baseline,
                                   save_baseline, write_report)
from repro.analysis.lint import (RULES, lint_file, lint_paths, lint_source,
                                 DEFAULT_LINT_DIRS)
from repro.analysis.concurrency import (ALL_RULES, CONCURRENCY_RULES,
                                        check_file, check_paths,
                                        check_source)

__all__ = [
    "Violation", "compare_baseline", "count_by_key", "load_baseline",
    "save_baseline", "write_report", "RULES", "lint_file", "lint_paths",
    "lint_source", "DEFAULT_LINT_DIRS", "ALL_RULES", "CONCURRENCY_RULES",
    "check_file", "check_paths", "check_source",
]
