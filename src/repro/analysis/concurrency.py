"""Concurrency contract checker — static rules for the shared-state
serving path.

The serving tier (PR 10) runs one resident engine under concurrent
sessions; every shared mutable structure now declares its guarding lock
and this pass holds the code to those declarations:

``REPRO008`` unlocked mutation of a registered shared attribute.  A
    class declares ``__guarded_by__ = {"attr": "_lock"}``; any write or
    mutating method call on ``self.attr`` outside a ``with self._lock``
    (or a ``@guarded_by("_lock")`` body) races readers.
``REPRO009`` check-then-act on a cache dict: the same function reads a
    cache-ish receiver (``.get`` / ``in`` / subscript load) in one lock
    scope and inserts into it in a *different* scope — the classic
    lost-update / duplicate-populate window between probe and insert.
``REPRO010`` process-global mutable state (module ``SHARED_MUTABLE``
    registry, or module-level dict/list/set literals in lock-aware
    files) mutated with no lock held.
``REPRO011`` solver dispatch under a lock: calling a
    ``solve_lp_batch``-class entry point while holding any lock
    serializes every concurrent solve behind a cache mutex (and a
    blocked owner parks all waiters).  Build/solve OUTSIDE the lock;
    publish under it.
``REPRO012`` torn stats: two or more fields of the same ``*stats*``
    object mutated with no lock held — a concurrent snapshot reads a
    half-updated pair (hits bumped, misses not).

Registries the checker consumes (all declarative, zero runtime cost):

* class attribute ``__guarded_by__ = {"attr": "lock_attr", ...}``
* module tuple ``SHARED_MUTABLE = ("_ACTIVE", ...)``
* decorator ``@guarded_by("_lock")`` / ``@racecheck.guarded_by("_lock")``
  — asserts the named lock is held for the whole body (callers carry
  the REPRO008 obligation).

Scope: REPRO008/009/011 run everywhere; REPRO010/012 only where they
can be meaningful — the strict serving-path files (``core/qcache.py``,
``core/distributed.py``, ``core/lp_batch.py``, ``runtime/faults.py``,
``runtime/racecheck.py``, ``serving/*``) plus any file that is
*lock-aware* (constructs a ``threading`` lock or registers
``SHARED_MUTABLE``).  Single-threaded scripts stay out of scope.

Suppression and ratchet are shared with the project lint: append
``# repro: allow[REPROxxx] <justification>`` on the flagged line or the
comment block above it; counts pin into ``analysis/baseline.json``.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis import lint
from repro.analysis.report import Violation

CONCURRENCY_RULES: Dict[str, str] = {
    "REPRO008": "unlocked mutation of a shared attribute registered in "
                "__guarded_by__",
    "REPRO009": "check-then-act race: cache read and insert in separate "
                "lock scopes",
    "REPRO010": "process-global mutable state mutated without a lock "
                "held",
    "REPRO011": "solver dispatch while holding a lock (no solves under "
                "a cache mutex)",
    "REPRO012": "non-atomic multi-field stats update (torn snapshot "
                "window)",
}

#: rules REPRO001..012 — the full project rule set for docs/tests.
ALL_RULES: Dict[str, str] = {**lint.RULES, **CONCURRENCY_RULES}

# A With context expression whose trailing name component looks like a
# synchronisation primitive.  Meshes / files / tempdirs don't match.
_LOCKISH_RE = re.compile(r"lock|mutex|mtx|cond|sem|meter", re.IGNORECASE)

# threading-primitive constructors: their presence makes a file
# "lock-aware" (REPRO010/012 in scope); binding one at module level
# must NOT itself register as shared mutable state.
_LOCK_CTORS = ("Lock", "RLock", "Condition", "Semaphore",
               "BoundedSemaphore", "Event", "Barrier",
               "InstrumentedLock", "InstrumentedRLock")

# Mutating methods on containers/objects (REPRO008 receiver writes).
_MUTATORS = frozenset({
    "append", "add", "extend", "insert", "update", "setdefault", "pop",
    "popitem", "remove", "discard", "clear", "move_to_end", "sort",
    "reverse", "appendleft", "popleft",
})
# The subset that *inserts* (REPRO009's act half).
_INSERTERS = frozenset({"append", "add", "extend", "insert", "update",
                        "setdefault", "appendleft"})

# Receivers that conventionally hold a cache (REPRO009 eligibility).
_CACHEISH_RE = re.compile(r"cache|entr|inflight|building|prep|memo",
                          re.IGNORECASE)

# Dispatch entry points that must never run under a held lock.
_DISPATCH_CALLEES = frozenset({
    "solve_lp_batch", "solve_lp", "solve_lp_np", "solve_lp_dist",
    "solve_ilp", "dual_reducer", "progressive_shading", "sketch_refine",
})

# Module-level constructors whose result is shared mutable state.
_MUTABLE_CTORS = ("dict", "list", "set", "OrderedDict", "defaultdict",
                  "deque", "Counter")

# Files where REPRO010/012 always apply (the audited serving path).
_STRICT_SUFFIXES = ("core/qcache.py", "core/distributed.py",
                    "core/lp_batch.py", "runtime/faults.py",
                    "runtime/racecheck.py")


def _is_strict(path: str) -> bool:
    p = path.replace(os.sep, "/")
    return p.endswith(_STRICT_SUFFIXES) or "/serving/" in p


def _last(qual: str) -> str:
    return qual.split(".")[-1] if qual else ""


class ConcurrencyLinter(lint.Linter):
    """Single-file concurrency pass; reuses the lint suppression /
    emission machinery but walks its own rule set."""

    # ------------------------------------------------------------- run

    def run(self) -> List[Violation]:
        self._collect_registry()
        strict = _is_strict(self.path)
        self._globals_in_scope = strict or self._lock_aware \
            or bool(self._shared_mutable)
        for node in self.tree.body:
            if isinstance(node, ast.ClassDef):
                guarded = self._guarded_by.get(node.name, {})
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        self._check_function(item, guarded=guarded,
                                             is_method=True)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_function(node, guarded={}, is_method=False)
        return self.violations

    # ------------------------------------------------------ registries

    def _collect_registry(self) -> None:
        self._guarded_by: Dict[str, Dict[str, str]] = {}
        self._shared_mutable: Set[str] = set()
        self._mutable_globals: Set[str] = set()
        self._lock_aware = False

        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                callee = _last(lint._qualname(node.func))
                if callee in _LOCK_CTORS:
                    self._lock_aware = True

        for node in self.tree.body:
            if isinstance(node, ast.ClassDef):
                g = self._class_guarded(node)
                if g:
                    self._guarded_by[node.name] = g
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                if len(targets) != 1 or \
                        not isinstance(targets[0], ast.Name):
                    continue
                name, value = targets[0].id, node.value
                if name == "SHARED_MUTABLE" and \
                        isinstance(value, (ast.Tuple, ast.List)):
                    for elt in value.elts:
                        if isinstance(elt, ast.Constant) and \
                                isinstance(elt.value, str):
                            self._shared_mutable.add(elt.value)
                elif self._is_mutable_literal(value):
                    self._mutable_globals.add(name)

    @staticmethod
    def _class_guarded(cls: ast.ClassDef) -> Dict[str, str]:
        out: Dict[str, str] = {}
        for item in cls.body:
            if isinstance(item, ast.Assign) and len(item.targets) == 1 \
                    and isinstance(item.targets[0], ast.Name) \
                    and item.targets[0].id == "__guarded_by__" \
                    and isinstance(item.value, ast.Dict):
                for k, v in zip(item.value.keys, item.value.values):
                    if isinstance(k, ast.Constant) and \
                            isinstance(v, ast.Constant):
                        out[str(k.value)] = str(v.value)
        return out

    @staticmethod
    def _is_mutable_literal(value: Optional[ast.AST]) -> bool:
        if isinstance(value, (ast.Dict, ast.List, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
            return True
        if isinstance(value, ast.Call):
            callee = _last(lint._qualname(value.func))
            return callee in _MUTABLE_CTORS
        return False

    # --------------------------------------------------- function walk

    def _check_function(self, fn: ast.AST, *, guarded: Dict[str, str],
                        is_method: bool) -> None:
        init_like = getattr(fn, "name", "") in (
            "__init__", "__new__", "__post_init__")
        declared_globals: Set[str] = set()
        for n in ast.walk(fn):
            if isinstance(n, ast.Global):
                declared_globals.update(n.names)
        local = lint._local_bindings(fn) - declared_globals

        deco_locks = self._decorator_locks(fn)
        # events: (node, held_locks, innermost_lock_scope_id)
        reads: Dict[str, List[Tuple[int, ast.AST]]] = {}
        inserts: Dict[str, List[Tuple[int, ast.AST]]] = {}
        stats_muts: Dict[str, List[Tuple[str, bool, ast.AST]]] = {}
        saw_lock_with = [bool(deco_locks)]
        self_name = self._self_name(fn) if is_method else None

        def root_of(node: ast.AST) -> str:
            q = lint._qualname(node)
            return q.split(".")[0] if q else ""

        def note_self_mutation(target: ast.AST, held: frozenset,
                               node: ast.AST) -> None:
            """REPRO008: `target` is an attribute chain rooted at self."""
            q = lint._qualname(target)
            parts = q.split(".")
            if len(parts) < 2:
                return
            attr = parts[1]
            if init_like or not guarded or attr not in guarded:
                return
            lock = guarded[attr]
            if lock not in held:
                self._emit(
                    "REPRO008", node,
                    f"`{q}` is guarded by `self.{lock}` "
                    f"(__guarded_by__) but mutated without it held")

        def note_stats_mutation(target: ast.AST, held: frozenset,
                                node: ast.AST) -> None:
            """REPRO012 candidate: field write `recv.field = ...`."""
            q = lint._qualname(target)
            parts = q.split(".")
            if len(parts) >= 2:
                recv, field = ".".join(parts[:-1]), parts[-1]
                root = parts[0]
                rooted = (root == self_name) or \
                    (root in self._mutable_globals or
                     root in self._shared_mutable)
                if rooted and "stats" in recv.lower():
                    stats_muts.setdefault(recv, []).append(
                        (field, bool(held), node))

        def note_global_mutation(name: str, held: frozenset,
                                 node: ast.AST) -> None:
            if name in local:
                return
            registered = self._shared_mutable | self._mutable_globals
            if name not in registered:
                return
            if not self._globals_in_scope:
                return
            if not held:
                self._emit(
                    "REPRO010", node,
                    f"module-global `{name}` is shared mutable state; "
                    f"mutation needs a lock (or a thread-local copy)")

        def note_container(kind: str, recv_node: ast.AST,
                           scope: int, node: ast.AST) -> None:
            q = lint._qualname(recv_node)
            if not q:
                return
            root = q.split(".")[0]
            attr = q.split(".")[1] if root == self_name and \
                "." in q else _last(q)
            eligible = bool(_CACHEISH_RE.search(_last(q))) or \
                (root == self_name and attr in guarded)
            if not eligible:
                return
            book = reads if kind == "read" else inserts
            book.setdefault(q, []).append((scope, node))

        def handle_target(t: ast.AST, held: frozenset, scope: int,
                          node: ast.AST) -> None:
            if isinstance(t, (ast.Tuple, ast.List)):
                for elt in t.elts:
                    handle_target(elt, held, scope, node)
            elif isinstance(t, ast.Starred):
                handle_target(t.value, held, scope, node)
            elif isinstance(t, ast.Attribute):
                if root_of(t) == self_name and self_name:
                    note_self_mutation(t, held, node)
                    note_stats_mutation(t, held, node)
                elif root_of(t) in self._shared_mutable | \
                        self._mutable_globals:
                    note_global_mutation(root_of(t), held, node)
                    note_stats_mutation(t, held, node)
            elif isinstance(t, ast.Subscript):
                base = t.value
                broot = root_of(base)
                if broot == self_name and self_name:
                    note_self_mutation(base, held, node)
                    note_container("insert", base, scope, node)
                elif isinstance(base, ast.Name):
                    note_global_mutation(base.id, held, node)
                    note_container("insert", base, scope, node)
            elif isinstance(t, ast.Name):
                if t.id in declared_globals:
                    note_global_mutation(t.id, held, node)

        def walk(node: ast.AST, held: frozenset, scope: int) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                # a nested def may run later, outside the current lock
                body = node.body if isinstance(node.body, list) \
                    else [node.body]
                for b in body:
                    walk(b, frozenset(), 0)
                return
            if isinstance(node, (ast.With, ast.AsyncWith)):
                new_locks = set()
                for item in node.items:
                    name = _last(lint._qualname(item.context_expr))
                    if name and _LOCKISH_RE.search(name):
                        new_locks.add(name)
                if new_locks:
                    saw_lock_with[0] = True
                    held = held | frozenset(new_locks)
                    scope = id(node)
                for b in node.body:
                    walk(b, held, scope)
                return

            if isinstance(node, ast.Assign):
                for t in node.targets:
                    handle_target(t, held, scope, node)
            elif isinstance(node, ast.AugAssign):
                handle_target(node.target, held, scope, node)
            elif isinstance(node, ast.AnnAssign) and node.value:
                handle_target(node.target, held, scope, node)
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    handle_target(t, held, scope, node)
            elif isinstance(node, ast.Call):
                callee = _last(lint._qualname(node.func))
                if callee in _DISPATCH_CALLEES and held:
                    self._emit(
                        "REPRO011", node,
                        f"`{callee}` dispatched while holding "
                        f"lock(s) {sorted(held)} — solve outside the "
                        f"lock, publish under it")
                if isinstance(node.func, ast.Attribute):
                    recv = node.func.value
                    if node.func.attr in _MUTATORS:
                        rroot = root_of(recv)
                        if rroot == self_name and self_name:
                            note_self_mutation(recv, held, node)
                        elif isinstance(recv, ast.Name):
                            note_global_mutation(recv.id, held, node)
                        if node.func.attr in _INSERTERS:
                            note_container("insert", recv, scope, node)
                    if node.func.attr == "get":
                        note_container("read", recv, scope, node)
            elif isinstance(node, ast.Compare):
                for op, comparator in zip(node.ops, node.comparators):
                    if isinstance(op, (ast.In, ast.NotIn)):
                        note_container("read", comparator, scope, node)
            elif isinstance(node, ast.Subscript) and \
                    isinstance(node.ctx, ast.Load):
                note_container("read", node.value, scope, node)

            for child in ast.iter_child_nodes(node):
                walk(child, held, scope)

        base_held = frozenset(deco_locks)
        base_scope = -1 if deco_locks else 0
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for b in body:
            walk(b, base_held, base_scope)

        # REPRO009: per-receiver, a read and an insert in different
        # innermost lock scopes (at least one inside a lock) means the
        # decision can go stale before the act.  Emitted once per
        # receiver at the function def, so one suppression covers the
        # whole claim/publish protocol.
        if saw_lock_with[0] and not init_like:
            for recv in sorted(set(reads) & set(inserts)):
                r_scopes = {s for s, _ in reads[recv]}
                i_scopes = {s for s, _ in inserts[recv]}
                split = any(r != i and (r != 0 or i != 0)
                            for r in r_scopes for i in i_scopes)
                if split:
                    self._emit(
                        "REPRO009", fn,
                        f"`{recv}` is probed and inserted under "
                        f"different lock scopes in "
                        f"`{getattr(fn, 'name', '<lambda>')}` — the "
                        f"check can go stale before the act")

        # REPRO012: >= 2 distinct fields of one stats receiver written
        # without a lock.
        if self._globals_in_scope and not init_like:
            for recv, muts in sorted(stats_muts.items()):
                unlocked = [(f, nd) for f, locked, nd in muts
                            if not locked]
                fields = {f for f, _ in unlocked}
                if len(fields) >= 2:
                    first = min(unlocked, key=lambda p: p[1].lineno)[1]
                    self._emit(
                        "REPRO012", first,
                        f"fields {sorted(fields)} of `{recv}` mutated "
                        f"without a lock — a concurrent snapshot sees "
                        f"a torn update")

    # ----------------------------------------------------------- helpers

    @staticmethod
    def _self_name(fn: ast.AST) -> Optional[str]:
        args = fn.args
        pos = args.posonlyargs + args.args
        return pos[0].arg if pos else None

    @staticmethod
    def _decorator_locks(fn: ast.AST) -> Set[str]:
        locks: Set[str] = set()
        for deco in getattr(fn, "decorator_list", ()):
            if isinstance(deco, ast.Call) and \
                    _last(lint._qualname(deco.func)) == "guarded_by" \
                    and deco.args and \
                    isinstance(deco.args[0], ast.Constant):
                locks.add(str(deco.args[0].value))
        return locks


# ------------------------------------------------------------- entry points


def check_source(src: str, path: str = "<memory>") -> List[Violation]:
    """Concurrency-check one source string (unit-test entry point)."""
    try:
        return ConcurrencyLinter(src, path).run()
    except SyntaxError as e:
        return [Violation("REPRO000", path, e.lineno or 0,
                          f"syntax error: {e.msg}")]


def check_file(path: str, root: str = ".") -> List[Violation]:
    with open(path) as f:
        src = f.read()
    return check_source(src, os.path.relpath(path, root))


def check_paths(paths: Sequence[str], root: str = "."
                ) -> Tuple[List[Violation], int]:
    """Concurrency-check every ``*.py`` under ``paths``.
    Returns (violations, files_checked)."""
    files: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full):
            files.append(full)
        else:
            for dirpath, _, names in os.walk(full):
                files.extend(os.path.join(dirpath, n)
                             for n in sorted(names) if n.endswith(".py"))
    out: List[Violation] = []
    for f in sorted(files):
        out.extend(check_file(f, root))
    return out, len(files)
