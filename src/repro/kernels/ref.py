"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def pricing_ref(A, rho, d, state, lo, hi, s, tol: float = 1e-9):
    """Oracle for kernels.pricing.pricing (d = maintained reduced costs)."""
    alpha = rho @ A
    sa = s * alpha
    nonbasic = state < 2
    at_up = state == 1
    elig = nonbasic & (((~at_up) & (sa > tol)) | (at_up & (sa < -tol)))
    safe = jnp.where(jnp.abs(sa) > tol, sa, 1.0)
    ratio = jnp.where(elig, jnp.maximum(d / safe, 0.0), jnp.inf)
    cost = jnp.where(elig, jnp.abs(alpha) * (hi - lo), 0.0)
    return alpha, ratio, cost


def bfrt_histogram_ref(ratio, cost, edges):
    """Oracle for kernels.bfrt.bfrt_histogram."""
    finite = jnp.isfinite(ratio)
    bucket = jnp.searchsorted(edges, ratio, side="left")
    bucket = jnp.clip(bucket, 0, len(edges) - 1)
    nb = edges.shape[0]
    sums = jnp.zeros(nb, jnp.float32).at[bucket].add(
        jnp.where(finite, cost, 0.0).astype(jnp.float32))
    counts = jnp.zeros(nb, jnp.float32).at[bucket].add(
        finite.astype(jnp.float32))
    return sums, counts


def bfrt_sequential_ref(ratio, cost, budget):
    """Sequential BFRT walk (the numpy twin in core.lp uses the same rule):
    sort by ratio; flip while cumulative cost stays below budget; crossing
    element enters."""
    import numpy as np
    ratio = np.asarray(ratio)
    cost = np.asarray(cost)
    finite = np.isfinite(ratio)
    order = np.argsort(ratio, kind="stable")
    order = order[finite[order]]
    csum = np.cumsum(cost[order])
    cross = int(np.searchsorted(csum, budget - 1e-12))
    if cross >= len(order):
        return -1, np.zeros_like(finite), False
    q = int(order[cross])
    flips = np.zeros_like(finite)
    flips[order[:cross]] = True
    return q, flips, True


def segment_stats_ref(vals, ids, num_groups):
    """Oracle for kernels.segstats.segment_stats."""
    ids = jnp.asarray(ids)
    vals = jnp.asarray(vals, jnp.float32)
    counts = jnp.zeros(num_groups, jnp.float32).at[ids].add(1.0)
    sums = jnp.zeros((num_groups, vals.shape[1]), jnp.float32).at[ids].add(vals)
    sqs = jnp.zeros((num_groups, vals.shape[1]), jnp.float32).at[ids].add(
        vals * vals)
    return counts, sums, sqs


def attention_ref(q, k, v, *, causal=True, window=0):
    """Oracle for kernels.attention.flash_attention. q/k/v: (BH, S, d)."""
    BH, S, d = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = qp >= kp
    if window > 0:
        mask = mask & ((qp - kp) < window)
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
