"""Pallas TPU kernel: segment statistics for representative-tuple building.

Building each hierarchy layer needs per-group (count, sum, sum-of-squares)
over up to 10^9 tuples — the hot loop of DLV partitioning (the paper does
this inside PostgreSQL).  After the DLV sort, group ids are contiguous and
sorted, so a block of BLOCK tuples touches at most BLOCK distinct groups:
each grid step builds a (BLOCK x BLOCK) one-hot of (id - block_base) and
reduces with MXU matmuls, emitting per-block partial stats that ops.py
scatter-adds into the (G, k) result — one pass over HBM, no host sort, no
scatter inside the kernel (TPU has no efficient scatter; this one-hot
matmul formulation is the TPU-native replacement for a CUDA atomic-add
histogram).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _segstats_kernel(vals_ref, ids_ref, base_ref,
                     cnt_ref, sum_ref, sq_ref):
    vals = vals_ref[...]                 # (B, k)
    ids = ids_ref[...]                   # (1, B) int32
    base = base_ref[...]                 # (1, 1) int32: first id in block
    B = vals.shape[0]
    rel = ids[0] - base[0, 0]            # (B,) in [0, B)
    cols = jax.lax.broadcasted_iota(jnp.int32, (B, B), 1)
    onehot = (rel[:, None] == cols).astype(vals.dtype)      # (B, B)
    valid = (rel >= 0) & (rel < B)
    onehot = onehot * valid[:, None].astype(vals.dtype)
    cnt_ref[...] = jnp.sum(onehot, axis=0, keepdims=True)   # (1, B)
    sum_ref[...] = jnp.dot(onehot.T, vals,
                           preferred_element_type=jnp.float32)  # (B, k)
    sq_ref[...] = jnp.dot(onehot.T, vals * vals,
                          preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def segstats_partials(vals, ids, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = True):
    """Per-block partial (count, sum, sumsq) keyed by block-local group ids.

    vals: (n, k); ids: (n,) int32 sorted ascending.
    Returns (bases (nb,), counts (nb, B), sums (nb, B, k), sqs (nb, B, k)).
    """
    n, k = vals.shape
    block = min(block, n)
    pad = (-n) % block
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        # pad ids far beyond any real group so rel-id masking rejects them
        ids = jnp.pad(ids, (0, pad), constant_values=1 << 30)
    npad = vals.shape[0]
    nb = npad // block
    bases = ids.reshape(nb, block)[:, 0:1]

    cnt, sm, sq = pl.pallas_call(
        _segstats_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.float32),
            jax.ShapeDtypeStruct((nb * block, k), jnp.float32),
            jax.ShapeDtypeStruct((nb * block, k), jnp.float32),
        ],
        interpret=interpret,
    )(vals, ids.reshape(nb, block).reshape(nb, block), bases)
    return (bases[:, 0], cnt, sm.reshape(nb, block, k),
            sq.reshape(nb, block, k))


def segment_stats_np(vals, ids, num_groups: int):
    """The kernel's host twin: per-group (count, sum, sumsq) via bincount.

    Exact float64 accumulation — the partitioner's default on hosts
    without a TPU, where interpreting the Pallas kernel would serialize
    the hot loop.  Same contract as :func:`segment_stats`.
    """
    import numpy as np

    vals = np.asarray(vals, np.float64)
    ids = np.asarray(ids)
    n, k = vals.shape
    if n and np.all(ids[1:] >= ids[:-1]):
        # sorted ids (the post-DLV layout): contiguous reduceat beats the
        # bincount scatter
        bpos = np.concatenate([[0], np.flatnonzero(np.diff(ids)) + 1])
        present = ids[bpos]
        cnt = np.zeros(num_groups)
        cnt[present] = np.diff(np.concatenate([bpos, [n]]))
        sums = np.zeros((num_groups, k))
        sqs = np.zeros((num_groups, k))
        for j in range(k):
            w = np.ascontiguousarray(vals[:, j])
            sums[present, j] = np.add.reduceat(w, bpos)
            sqs[present, j] = np.add.reduceat(w * w, bpos)
        return cnt, sums, sqs
    cnt = np.bincount(ids, minlength=num_groups).astype(np.float64)
    sums = np.empty((num_groups, k))
    sqs = np.empty((num_groups, k))
    for j in range(k):
        sums[:, j] = np.bincount(ids, weights=vals[:, j],
                                 minlength=num_groups)
        sqs[:, j] = np.bincount(ids, weights=vals[:, j] ** 2,
                                minlength=num_groups)
    return cnt, sums, sqs


def segment_stats(vals, ids, num_groups: int, *, block: int = DEFAULT_BLOCK,
                  interpret: bool = True):
    """Full segment stats: (counts (G,), sums (G, k), sumsqs (G, k))."""
    vals = jnp.asarray(vals)
    ids = jnp.asarray(ids, jnp.int32)
    bases, cnt, sm, sq = segstats_partials(vals, ids, block=block,
                                           interpret=interpret)
    nb, B = cnt.shape
    # scatter-add per-block partials (tiny: nb*B rows)
    tgt = bases[:, None] + jnp.arange(B)[None, :]            # (nb, B)
    tgt = jnp.clip(tgt, 0, num_groups)                       # extra row = junk
    flat = tgt.reshape(-1)
    counts = jnp.zeros(num_groups + 1, jnp.float32).at[flat].add(
        cnt.reshape(-1))
    sums = jnp.zeros((num_groups + 1, vals.shape[1]), jnp.float32).at[
        flat].add(sm.reshape(-1, vals.shape[1]))
    sqs = jnp.zeros((num_groups + 1, vals.shape[1]), jnp.float32).at[
        flat].add(sq.reshape(-1, vals.shape[1]))
    return counts[:num_groups], sums[:num_groups], sqs[:num_groups]
