"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) so the kernel bodies
execute in Python for correctness; on a real TPU backend pass
``interpret=False`` (the wrappers pick this automatically from the default
device platform).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.attention import flash_attention
from repro.kernels.bfrt import bfrt_histogram, bfrt_select
from repro.kernels.pricing import pricing
from repro.kernels.segstats import (segment_stats, segment_stats_np,
                                    segstats_partials)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def auto_interpret() -> bool:
    return not on_tpu()


def pricing_op(A, rho, d, state, lo, hi, s, **kw):
    kw.setdefault("interpret", auto_interpret())
    return pricing(A, rho, d, state, lo, hi, s, **kw)


def bfrt_select_op(ratio, cost, budget, **kw):
    kw.setdefault("interpret", auto_interpret())
    return bfrt_select(ratio, cost, budget, **kw)


def segment_stats_op(vals, ids, num_groups, **kw):
    kw.setdefault("interpret", auto_interpret())
    return segment_stats(vals, ids, num_groups, **kw)


def segment_stats_auto(vals, ids, num_groups):
    """Kernel on TPU, exact bincount twin on hosts (the partitioner path).

    CAVEAT: the TPU kernel accumulates in float32 (MXU one-hot matmuls) —
    callers must center ``vals`` (DLV passes globally-centered values) and
    the resulting sum/sumsq only steer split selection, never final reps
    (``partitioner.group_stats`` recomputes those exactly).  Groups far
    from the global mean relative to their spread lose variance precision;
    see ROADMAP "TPU-resident build" for the per-block centering follow-on.
    """
    import numpy as np

    if on_tpu():
        import jax.numpy as jnp
        cnt, sm, sq = segment_stats(jnp.asarray(vals, jnp.float32),
                                    jnp.asarray(ids, jnp.int32),
                                    num_groups, interpret=False)
        return (np.asarray(cnt, np.float64), np.asarray(sm, np.float64),
                np.asarray(sq, np.float64))
    return segment_stats_np(vals, ids, num_groups)


def flash_attention_op(q, k, v, *, num_kv_heads=None, **kw):
    """q: (B, S, H, d); k/v: (B, S, KV, d).  GQA expansion then kernel."""
    kw.setdefault("interpret", auto_interpret())
    B, S, H, d = q.shape
    KV = k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    o = flash_attention(qf, kf, vf, **kw)
    return o.reshape(B, H, S, d).transpose(0, 2, 1, 3)


__all__ = ["pricing_op", "bfrt_select_op", "segment_stats_op",
           "segment_stats_auto", "segment_stats_np", "flash_attention_op",
           "bfrt_histogram", "segstats_partials", "on_tpu",
           "auto_interpret"]
