"""Pallas TPU kernel: fused dual-simplex pricing (paper App. C.3, procedure 1).

Per iteration the revised dual simplex needs, for every column j of A
(m x n, m tiny):
    alpha_j = rho . A[:, j]            (pivot row)
    ratio_j = d_j / (s * alpha_j)  masked by BFRT eligibility
    cost_j  = |alpha_j| * width_j      (bound-flip budget use)

The reduced costs d are MAINTAINED by the revised simplex (one O(n) axpy
``d -= theta * alpha`` per pivot — see ``repro.core.lp``), so unlike the
textbook loop there is no second matvec ``c - y @ A`` here: this kernel
performs the single O(mn) sweep of A per simplex iteration — one rank-1
MXU matvec + VPU elementwise, one HBM read of A total.  This is ~45% of
dual-simplex time in the paper (OpenMP over n).

Block layout: A tile (m, B) in VMEM; rho broadcast as a (1, m) operand;
d/state/lo/hi as (1, B) tiles; out tiles (1, B).  n is padded to a
multiple of BLOCK.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048


def pricing_math(alpha, d, state, width, s, tol: float):
    """BFRT eligibility / ratio / flip-cost from a priced pivot row.

    Shared by the Pallas kernel below and the shard_map distributed step
    (``repro.core.distributed``) so every backend applies the exact same
    pivot rules.  ``d`` is the MAINTAINED reduced-cost vector (no
    ``c - y @ A`` recompute anywhere downstream of this function);
    ``state`` is 0 = nonbasic-at-lower, 1 = nonbasic-at-upper, 2 = basic.
    Returns (ratio, cost): ratio is +inf for ineligible columns, cost is 0.
    """
    sa = s * alpha
    nonbasic = state < 2
    at_up = state == 1
    elig = nonbasic & (((~at_up) & (sa > tol)) | (at_up & (sa < -tol)))
    safe = jnp.where(jnp.abs(sa) > tol, sa, 1.0)
    ratio = jnp.where(elig, jnp.maximum(d / safe, 0.0), jnp.inf)
    cost = jnp.where(elig, jnp.abs(alpha) * width, 0.0)
    return ratio, cost


def _pricing_kernel(A_ref, rho_ref, d_ref, state_ref,
                    lo_ref, hi_ref, s_ref,
                    alpha_ref, ratio_ref, cost_ref, *, tol: float):
    A = A_ref[...]                       # (m, B)
    rho = rho_ref[...]                   # (1, m)
    d = d_ref[...]                       # (1, B) maintained reduced costs
    state = state_ref[...]               # (1, B) 0=at_lo, 1=at_up, 2=basic
    lo = lo_ref[...]
    hi = hi_ref[...]
    s = s_ref[0, 0]                      # +-1, scalar

    acc_t = A.dtype  # f32 accumulation on MXU for <=f32; f64 stays f64
    alpha = jnp.dot(rho, A, preferred_element_type=acc_t)         # (1, B)
    ratio, cost = pricing_math(alpha, d, state, hi - lo, s, tol)

    alpha_ref[...] = alpha
    ratio_ref[...] = ratio
    cost_ref[...] = cost


@functools.partial(jax.jit, static_argnames=("block", "interpret", "tol"))
def pricing(A, rho, d, state, lo, hi, s, *, block: int = DEFAULT_BLOCK,
            interpret: bool = True, tol: float = 1e-9):
    """Fused pricing over columns.  A: (m, n) f32/f64 -> (alpha, ratio, cost).

    d: (n,) maintained reduced costs.  state: int32 (n,) with
    0 = nonbasic-at-lower, 1 = nonbasic-at-upper, 2 = basic.
    s: scalar sign of the primal infeasibility delta.
    """
    m, n = A.shape
    dt = A.dtype
    block = min(block, n)
    pad = (-n) % block
    if pad:
        A = jnp.pad(A, ((0, 0), (0, pad)))
        d = jnp.pad(d, (0, pad))
        state = jnp.pad(state, (0, pad), constant_values=2)  # basic = ignore
        lo = jnp.pad(lo, (0, pad))
        hi = jnp.pad(hi, (0, pad))
    npad = A.shape[1]
    grid = (npad // block,)

    kernel = functools.partial(_pricing_kernel, tol=tol)
    alpha, ratio, cost = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block), lambda i: (0, i)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_shape=[jax.ShapeDtypeStruct((1, npad), dt)] * 3,
        interpret=interpret,
    )(A, rho.reshape(1, m), d.reshape(1, npad),
      state.reshape(1, npad).astype(dt), lo.reshape(1, npad),
      hi.reshape(1, npad), jnp.asarray(s, dt).reshape(1, 1))
    return alpha[0, :n], ratio[0, :n], cost[0, :n]
