"""Pallas TPU kernel: BFRT bucketed histogram (paper App. C.3, procedure 2).

The Bound-Flipping Ratio Test walks breakpoints in increasing ratio order
until the flip budget |delta| is exhausted.  The paper parallelises this
with Map-Sort + per-core heaps; neither global sorts nor heaps map to the
TPU's vector units, so we use the TPU idiom instead (same trick as TPU
top-k): a two-pass *bucketed select*:

  pass 1 (this kernel): histogram the breakpoint ratios into NB buckets,
     accumulating per-bucket flip-cost sums and counts — one-hot comparisons
     against the bucket edges, reduced with an MXU matmul, accumulated into
     a VMEM scratch across the sequential grid;
  pass 2 (ops.py): a scalar cumsum over NB buckets locates the crossing
     bucket; only that bucket's elements (tiny) are resolved exactly.

Output matches the sequential BFRT exactly (tests sweep shapes/dtypes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 2048
NUM_BUCKETS = 128


def _bfrt_hist_kernel(ratio_ref, cost_ref, edges_ref,
                      sums_ref, counts_ref):
    i = pl.program_id(0)
    ratio = ratio_ref[...]               # (1, B)
    cost = cost_ref[...]                 # (1, B)
    edges = edges_ref[...]               # (1, NB) upper edges

    # bucket_j = first b with ratio <= edges[b]; one-hot via adjacent diff
    le = (ratio[0, :, None] <= edges[0, None, :]).astype(cost.dtype)  # (B, NB)
    onehot = le - jnp.concatenate(
        [jnp.zeros((le.shape[0], 1), le.dtype), le[:, :-1]], axis=1)
    finite = jnp.isfinite(ratio[0])[:, None].astype(cost.dtype)
    onehot = onehot * finite
    sums = jnp.dot(cost, onehot, preferred_element_type=jnp.float32)   # (1, NB)
    counts = jnp.dot(jnp.ones_like(cost), onehot * finite,
                     preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    sums_ref[...] += sums.astype(sums_ref.dtype)
    counts_ref[...] += counts.astype(counts_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "num_buckets", "interpret"))
def bfrt_histogram(ratio, cost, edges, *, block: int = DEFAULT_BLOCK,
                   num_buckets: int = NUM_BUCKETS, interpret: bool = True):
    """Pass 1: (per-bucket flip-cost sums, counts).

    ratio/cost: (n,); edges: (num_buckets,) ascending upper edges with
    edges[-1] = +inf so every finite ratio lands in a bucket.
    """
    n = ratio.shape[0]
    dt = cost.dtype
    block = min(block, n)
    pad = (-n) % block
    if pad:
        ratio = jnp.pad(ratio, (0, pad), constant_values=jnp.inf)
        cost = jnp.pad(cost, (0, pad))
    npad = ratio.shape[0]
    grid = (npad // block,)
    sums, counts = pl.pallas_call(
        _bfrt_hist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, num_buckets), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, num_buckets), lambda i: (0, 0)),
            pl.BlockSpec((1, num_buckets), lambda i: (0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((1, num_buckets), jnp.float32)] * 2,
        interpret=interpret,
    )(ratio.reshape(1, npad), cost.reshape(1, npad),
      edges.reshape(1, num_buckets))
    return sums[0], counts[0]


def bfrt_select(ratio, cost, budget, *, num_buckets: int = NUM_BUCKETS,
                interpret: bool = True):
    """Full two-pass BFRT: returns (entering index, flip mask).

    Equivalent to: sort eligible by ratio; flip until cumulative cost
    reaches budget; the crossing element enters the basis.
    Assumes ineligible entries have ratio=inf / cost=0 (pricing kernel).
    """
    finite = jnp.isfinite(ratio)
    any_elig = jnp.any(finite)
    rmax = jnp.max(jnp.where(finite, ratio, 0.0))
    rmin = jnp.min(jnp.where(finite, ratio, rmax))
    # NB-2 interior edges + final +inf edge; epsilon-widened
    span = jnp.maximum(rmax - rmin, 1e-12)
    interior = rmin + span * (jnp.arange(1, num_buckets) / (num_buckets - 1))
    edges = jnp.concatenate([interior, jnp.array([jnp.inf], ratio.dtype)])
    sums, _ = bfrt_histogram(ratio, cost, edges, num_buckets=num_buckets,
                             interpret=interpret)
    csum = jnp.cumsum(sums)
    # crossing bucket: first whose cumulative cost reaches the budget
    crossed = csum >= budget - 1e-12
    bidx = jnp.argmax(crossed)
    has_cross = jnp.any(crossed)
    lo_edge = jnp.where(bidx == 0, -jnp.inf, edges[jnp.maximum(bidx - 1, 0)])
    hi_edge = edges[bidx]
    base = jnp.where(bidx == 0, 0.0, csum[jnp.maximum(bidx - 1, 0)])

    # pass 2: exact walk inside the crossing bucket (tiny, jnp sort)
    in_bucket = (ratio > lo_edge) & (ratio <= hi_edge) & finite
    r_in = jnp.where(in_bucket, ratio, jnp.inf)
    order = jnp.argsort(r_in)
    cost_sorted = cost[order] * jnp.isfinite(r_in[order])
    csum_in = base + jnp.cumsum(cost_sorted)
    cross_pos = jnp.argmax((csum_in >= budget - 1e-12)
                           & jnp.isfinite(r_in[order]))
    q = order[cross_pos]
    # flips: every eligible entry with ratio strictly below the entering one
    # plus earlier same-bucket entries (by sorted position)
    rank = jnp.empty_like(order).at[order].set(jnp.arange(ratio.shape[0]))
    flips = finite & ((ratio < ratio[q]) | (in_bucket & (rank < rank[q])))
    flips = flips & (jnp.arange(ratio.shape[0]) != q)
    return q, flips, has_cross & any_elig
