"""Pallas TPU kernel: flash attention (causal / sliding-window / full).

The serving and training stacks' pure-XLA path uses the chunked
online-softmax scan in ``repro.models.attention``; this kernel is the
TPU-native replacement for the prefill/train hot spot: q/k/v tiles staged
through VMEM, online softmax state (m, l, acc) in VMEM scratch, causal and
sliding-window masking done on block indices so fully-masked tiles are
skipped at trace time via the grid structure.

Layout: q (BH, S, d), k/v (BH, S, d) with batch*heads folded (GQA expansion
in ops.py).  Grid (BH, nq, nk) with the kv axis innermost ("arbitrary"
semantics): scratch carries softmax state across the kv loop.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Version compat (same pattern as the shard_map shim in core.distributed):
# jax >= 0.7 spells it pltpu.CompilerParams; 0.4.x calls it TPUCompilerParams.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, nk: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                          # (bq, d)
    k = k_ref[0]                          # (bk, d)
    v = v_ref[0]
    s = jnp.dot(q.astype(jnp.float32) * scale, k.astype(jnp.float32).T,
                preferred_element_type=jnp.float32)       # (bq, bk)
    q_pos = q_i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_k), 0)
    k_pos = kv_i * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask = q_pos >= k_pos
    if window > 0:
        mask = mask & ((q_pos - k_pos) < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kv_i == nk - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q/k/v: (BH, S, d).  Returns (BH, S, d)."""
    BH, S, d = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(d)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
