"""Pre-jax host-device bootstrap (stdlib only — safe to import anywhere).

jax locks the device count at first initialization, so multi-device CPU
runs (the distributed-pricing tests and benchmarks) must append
``--xla_force_host_platform_device_count`` to XLA_FLAGS BEFORE anything
imports jax.  Shared by tests/conftest.py and benchmarks/run.py so the
two always agree on the virtual mesh size.
"""
from __future__ import annotations

import os

DEFAULT_HOST_DEVICES = 4


def ensure_host_devices(count: int = DEFAULT_HOST_DEVICES) -> None:
    """Idempotent: no-op when XLA_FLAGS already pins a device count
    (e.g. on a real TPU host or an explicit override)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={count}"
        ).strip()
