"""Parameter specs with logical sharding axes.

Every weight in the model stack is declared as a ``ParamInfo(shape, axes,
init)`` in a nested-dict *spec*.  From a spec we derive, with no duplicated
structural code:

  * abstract parameters (``jax.ShapeDtypeStruct``) for dry-run lowering,
  * concrete initialized parameters for smoke tests / real training,
  * the logical-axes tree consumed by ``repro.distributed.sharding``.

Logical axis vocabulary (mapped to mesh axes by the sharding rules engine):
  vocab, embed, heads, kv_heads, head, mlp, experts, qlora, kvlora, layers,
  ssm_inner, ssm_state, ssm_heads, conv, scalar
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"       # normal | zeros | ones | scaled | a_log
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stacked(spec: Dict[str, Any], num: int) -> Dict[str, Any]:
    """Prepend a scan ('layers') dimension to every ParamInfo in a spec."""
    out = {}
    for k, v in spec.items():
        if isinstance(v, ParamInfo):
            out[k] = ParamInfo((num,) + v.shape, ("layers",) + v.axes, v.init, v.scale)
        else:
            out[k] = stacked(v, num)
    return out


def _is_info(x) -> bool:
    return isinstance(x, ParamInfo)


def abstract_params(spec: Dict[str, Any], dtype) -> Dict[str, Any]:
    return jax.tree.map(
        lambda i: jax.ShapeDtypeStruct(i.shape, dtype), spec, is_leaf=_is_info)


def axes_tree(spec: Dict[str, Any]) -> Dict[str, Any]:
    return jax.tree.map(lambda i: i.axes, spec, is_leaf=_is_info)


def init_params(spec: Dict[str, Any], rng: jax.Array, dtype) -> Dict[str, Any]:
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_info)
    keys = jax.random.split(rng, len(leaves))
    vals = []
    for info, key in zip(leaves, keys):
        if info.init == "zeros":
            v = jnp.zeros(info.shape, dtype)
        elif info.init == "ones":
            v = jnp.ones(info.shape, dtype)
        elif info.init == "a_log":
            # Mamba A initialised in [1, 16), stored as log
            u = jax.random.uniform(key, info.shape, jnp.float32, 1.0, 16.0)
            v = jnp.log(u).astype(dtype)
        else:
            scale = info.scale
            if info.init == "scaled":  # fan-in scaled (output projections)
                fan_in = int(np.prod(info.shape[:-1])) or 1
                scale = 1.0 / math.sqrt(fan_in)
            v = (jax.random.normal(key, info.shape, jnp.float32) * scale).astype(dtype)
        vals.append(v)
    return jax.tree.unflatten(treedef, vals)


def param_count(spec: Dict[str, Any]) -> int:
    return sum(int(np.prod(i.shape))
               for i in jax.tree.leaves(spec, is_leaf=_is_info))
