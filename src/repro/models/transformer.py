"""Layer stacks for every assigned family.

All stacks scan over layers (params stacked on a leading 'layers' axis) so
the lowered HLO stays compact for 61–72-layer models and XLA's
latency-hiding scheduler can overlap per-layer collectives with compute.
Activation checkpointing (remat) wraps the scanned body per ``cfg.remat``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_mlp, apply_norm, mlp_spec, norm_spec)
from repro.models.param import stacked


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ------------------------------------------------------------------ blocks


def attn_block_spec(cfg: ArchConfig, use_moe: bool, d_ff: int) -> Dict:
    a = attn.mla_spec(cfg) if cfg.attention == "mla" else attn.gqa_spec(cfg)
    ffn = moe_lib.moe_spec(cfg) if use_moe else mlp_spec(cfg, d_ff)
    return {"ln1": norm_spec(cfg), "attn": a, "ln2": norm_spec(cfg), "ffn": ffn}


def apply_attn_block(p, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
                     use_moe: bool, prefix_len=None) -> Tuple[jax.Array, jax.Array]:
    from repro.distributed.context import current_rules
    x = constrain(x, ("dp", None, None))
    h = apply_norm(p["ln1"], x, cfg.norm_eps)
    rules = current_rules()
    sp = (rules is not None and rules.seq_parallel_attn and cfg.num_heads
          and cfg.num_heads % rules.tp_size != 0)
    if sp:  # sequence-parallel attention (§Perf): S over the idle model axis
        h = constrain(h, ("dp", "tp", None))
    if cfg.attention == "mla":
        h = attn.mla_forward(p["attn"], cfg, h, positions)
    else:
        h = attn.gqa_forward(p["attn"], cfg, h, positions,
                             causal=True, prefix_len=prefix_len)
    if sp:
        h = constrain(h, ("dp", None, None))
    x = x + h
    h = apply_norm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        h, aux = moe_lib.apply_moe(p["ffn"], cfg, h)
    else:
        h, aux = apply_mlp(p["ffn"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + h, aux


def ssm_block_spec(cfg: ArchConfig) -> Dict:
    return {"ln": norm_spec(cfg), "ssm": ssm_lib.ssm_spec(cfg)}


def apply_ssm_block(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = constrain(x, ("dp", None, None))
    h = apply_norm(p["ln"], x, cfg.norm_eps)
    return x + ssm_lib.ssd_forward(p["ssm"], cfg, h)


# --------------------------------------------------------- decoder stacks


def decoder_spec(cfg: ArchConfig) -> Dict:
    """Spec for the main decoder stack, by family."""
    if cfg.family == "ssm":
        return {"layers": stacked(ssm_block_spec(cfg), cfg.num_layers)}
    if cfg.is_hybrid:
        return {"layers": stacked(_jamba_block_spec(cfg),
                                  cfg.num_layers // cfg.attn_period)}
    spec: Dict[str, Any] = {}
    n_dense = cfg.first_k_dense if cfg.uses_moe else 0
    n_main = cfg.num_layers - n_dense
    if n_dense:
        spec["dense_layers"] = stacked(
            attn_block_spec(cfg, use_moe=False, d_ff=cfg.d_ff), n_dense)
    spec["layers"] = stacked(
        attn_block_spec(cfg, use_moe=cfg.uses_moe,
                        d_ff=cfg.d_ff or cfg.moe_d_ff), n_main)
    return spec


def apply_decoder(p, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
                  prefix_len=None) -> Tuple[jax.Array, jax.Array]:
    """Returns (hidden, aux_loss_sum)."""
    if cfg.family == "ssm":
        def body(carry, lp):
            return apply_ssm_block(lp, cfg, carry), None
        x, _ = jax.lax.scan(_remat(body, cfg.remat), x, p["layers"])
        return x, jnp.zeros((), jnp.float32)

    if cfg.is_hybrid:
        def body(carry, lp):
            h, aux = carry
            h, a = _apply_jamba_block(lp, cfg, h, positions)
            return (h, aux + a), None
        (x, aux), _ = jax.lax.scan(_remat(body, cfg.remat),
                                   (x, jnp.zeros((), jnp.float32)),
                                   p["layers"])
        return x, aux

    aux0 = jnp.zeros((), jnp.float32)
    if "dense_layers" in p:
        def dbody(carry, lp):
            h, aux = carry
            h, a = apply_attn_block(lp, cfg, h, positions, use_moe=False,
                                    prefix_len=prefix_len)
            return (h, aux + a), None
        (x, aux0), _ = jax.lax.scan(_remat(dbody, cfg.remat), (x, aux0),
                                    p["dense_layers"])

    def body(carry, lp):
        h, aux = carry
        h, a = apply_attn_block(lp, cfg, h, positions, use_moe=cfg.uses_moe,
                                prefix_len=prefix_len)
        return (h, aux + a), None
    (x, aux), _ = jax.lax.scan(_remat(body, cfg.remat), (x, aux0), p["layers"])
    return x, aux


# ------------------------------------------------------------- Jamba block


def _jamba_block_spec(cfg: ArchConfig) -> Dict:
    """One period of cfg.attn_period sublayers: attention at period//2,
    SSM elsewhere; MoE FFN on odd sublayers (moe_period=2)."""
    spec = {}
    for i in range(cfg.attn_period):
        is_attn = i == cfg.attn_period // 2
        is_moe = bool(cfg.moe_period) and (i % cfg.moe_period == cfg.moe_period - 1)
        if is_attn:
            sub = {"ln1": norm_spec(cfg), "attn": attn.gqa_spec(cfg)}
        else:
            sub = {"ln1": norm_spec(cfg), "ssm": ssm_lib.ssm_spec(cfg)}
        sub["ln2"] = norm_spec(cfg)
        sub["ffn"] = (moe_lib.moe_spec(cfg) if is_moe
                      else mlp_spec(cfg, cfg.d_ff))
        spec[f"sub{i}"] = sub
    return spec


def _apply_jamba_block(p, cfg: ArchConfig, x: jax.Array,
                       positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    for i in range(cfg.attn_period):
        sub = p[f"sub{i}"]
        x = constrain(x, ("dp", None, None))
        h = apply_norm(sub["ln1"], x, cfg.norm_eps)
        if "attn" in sub:
            h = attn.gqa_forward(sub["attn"], cfg, h, positions, causal=True)
        else:
            h = ssm_lib.ssd_forward(sub["ssm"], cfg, h)
        x = x + h
        h = apply_norm(sub["ln2"], x, cfg.norm_eps)
        if "router" in sub["ffn"]:
            h, a = moe_lib.apply_moe(sub["ffn"], cfg, h)
            aux = aux + a
        else:
            h = apply_mlp(sub["ffn"], h, cfg.act)
        x = x + h
    return x, aux


# --------------------------------------------------------------- encoder


def encoder_spec(cfg: ArchConfig) -> Dict:
    return {"layers": stacked(attn_block_spec(cfg, use_moe=False, d_ff=cfg.d_ff),
                              cfg.num_encoder_layers),
            "ln_post": norm_spec(cfg)}


def apply_encoder(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings."""
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, lp):
        carry = constrain(carry, ("dp", None, None))
        h = apply_norm(lp["ln1"], carry, cfg.norm_eps)
        h = attn.gqa_forward(lp["attn"], cfg, h, positions, causal=False)
        carry = carry + h
        h = apply_norm(lp["ln2"], carry, cfg.norm_eps)
        return carry + apply_mlp(lp["ffn"], h, cfg.act), None

    x, _ = jax.lax.scan(_remat(body, cfg.remat), x, p["layers"])
    return apply_norm(p["ln_post"], x, cfg.norm_eps)


# ----------------------------------------------------- enc-dec decoder


def xdecoder_spec(cfg: ArchConfig) -> Dict:
    sub = attn_block_spec(cfg, use_moe=False, d_ff=cfg.d_ff)
    sub["ln_x"] = norm_spec(cfg)
    sub["xattn"] = attn.gqa_spec(cfg)
    return {"layers": stacked(sub, cfg.num_layers)}


def apply_xdecoder(p, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
                   enc_out: jax.Array) -> jax.Array:
    enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(carry, lp):
        carry = constrain(carry, ("dp", None, None))
        h = apply_norm(lp["ln1"], carry, cfg.norm_eps)
        h = attn.gqa_forward(lp["attn"], cfg, h, positions, causal=True)
        carry = carry + h
        h = apply_norm(lp["ln_x"], carry, cfg.norm_eps)
        k, v = attn.gqa_project_kv(lp["xattn"], enc_out, enc_pos, cfg.rope_theta)
        h = attn.gqa_forward(lp["xattn"], cfg, h, positions, causal=False,
                             kv_override=(k, v), kv_positions=enc_pos)
        carry = carry + h
        h = apply_norm(lp["ln2"], carry, cfg.norm_eps)
        return carry + apply_mlp(lp["ffn"], h, cfg.act), None

    x, _ = jax.lax.scan(_remat(body, cfg.remat), x, p["layers"])
    return x
