"""Mixture-of-Experts with group-local capacity dispatch (GShard/MaxText
"dropping" style).

Tokens are split into groups aligned with the data-parallel sharding; routing,
capacity bookkeeping, dispatch and combine are *local to a group*, so GSPMD
keeps the expensive gathers shard-local and the only cross-shard traffic is
the expert-sharded einsum (+ the combine reduction over the model axis).
Baseline uses pjit propagation; a shard_map all-to-all variant is the
documented §Perf optimisation for the MoE-heavy cells.

Capacity factor > 1 with renormalised top-k gates; dropped tokens fall back
to the shared expert(s) (or to zero for pure-routed layers), matching
standard dropping-MoE semantics.  ``ref_moe`` is the exact (no-drop) oracle
used by tests with a capacity factor high enough to guarantee no drops.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain
from repro.models.param import ParamInfo
from repro.models.layers import mlp_spec, apply_mlp


def moe_spec(cfg: ArchConfig) -> Dict:
    d, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    spec = {
        "router": ParamInfo((d, E), ("embed", "experts")),
        "wi": ParamInfo((E, d, F), ("experts", "embed", "mlp")),
        "wg": ParamInfo((E, d, F), ("experts", "embed", "mlp")),
        "wo": ParamInfo((E, F, d), ("experts", "mlp", "embed"), init="scaled"),
    }
    if cfg.num_shared_experts:
        spec["shared"] = mlp_spec(cfg, cfg.moe_d_ff * cfg.num_shared_experts)
    return spec


def _route(p, cfg: ArchConfig, xf: jax.Array):
    """xf: (G, T, D) -> gates (G, T, K), idx (G, T, K), aux loss."""
    logits = jnp.einsum("gtd,de->gte", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    E = cfg.num_experts
    me = jnp.mean(probs, axis=(0, 1))                       # mean router prob
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))                              # top-1 load
    aux = (E * jnp.sum(me * ce)).astype(jnp.float32)
    return gate, idx, aux


def apply_moe(p, cfg: ArchConfig, x: jax.Array,
              group_size: int = 4096) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  Returns routed + shared expert output."""
    from repro.distributed.context import current_rules
    B, S, D = x.shape
    E, K, F = cfg.num_experts, cfg.num_experts_per_tok, cfg.moe_d_ff
    T = B * S
    # Group sizing (§Perf iteration log): when tokens are plentiful
    # (train/prefill) groups align with the data-parallel axes so
    # dispatch/combine stay shard-local; when tokens are scarce (decode)
    # one replicated group is cheaper — small sharded groups would make
    # GSPMD gather the (much larger) expert weights per data shard
    # instead of the small dispatch tensors (measured 15x regression).
    rules = current_rules()
    dp = rules.dp_size if rules is not None else 1
    if T // dp >= 1024:
        g = min(group_size, T // dp)
    else:
        g = min(group_size, T)
    g = max(1, g)
    while T % g:
        g -= 1
    G = T // g
    # NOTE (§Perf deepseek iteration B3, refuted): constraining groups over
    # BOTH mesh axes ("dp+tp") to push GSPMD toward all-to-all dispatch
    # triggers "involuntary full rematerialization" (reshard 256-way <->
    # 16x16-way) and doubles FLOPs — 247 s collective vs 62 s.  True
    # all-to-all EP needs the shard_map formulation, not pjit constraints.
    xf = constrain(x.reshape(G, g, D), ("dp", None, None))

    gate, idx, aux = _route(p, cfg, xf)
    C = max(1, int(math.ceil(g * K / E * cfg.capacity_factor)))
    C = min(C, g)

    # --- position of every (token, k) copy within its expert, k-major so
    # first choices win capacity (GShard priority) ---
    idx_km = jnp.swapaxes(idx, 1, 2).reshape(G, K * g)        # (G, K*g)
    gate_km = jnp.swapaxes(gate, 1, 2).reshape(G, K * g)
    oh = jax.nn.one_hot(idx_km, E, dtype=jnp.int32)           # (G, K*g, E)
    pos = jnp.cumsum(oh, axis=1) - oh
    pos_of = jnp.sum(pos * oh, axis=-1)                       # (G, K*g)
    keep = pos_of < C

    # --- dispatch indices (G, E, C): source token slot, g = padding sentinel
    tok_of = jnp.tile(jnp.arange(g, dtype=jnp.int32)[None, :], (G, K))
    disp = jnp.full((G, E, C), g, jnp.int32)
    safe_pos = jnp.where(keep, pos_of, C)  # overflow slots dropped via mode
    disp = disp.at[
        jnp.arange(G)[:, None], idx_km, safe_pos
    ].set(jnp.where(keep, tok_of, g), mode="drop")

    xpad = jnp.concatenate([xf, jnp.zeros((G, 1, D), xf.dtype)], axis=1)
    x_disp = jnp.take_along_axis(
        xpad[:, :, None, :], disp.reshape(G, E * C)[:, :, None, None], axis=1
    ).reshape(G, E, C, D)
    from repro.distributed.context import current_rules as _cr
    _rules = _cr()
    if _rules is not None and _rules.replicate_decode_activations:
        # decode perf mode: align dispatch with the experts' FSDP
        # (contraction) dim -> partial-sum instead of dispatch all-gather
        x_disp = constrain(x_disp, (None, "tp", None, "dp"))
    else:
        x_disp = constrain(x_disp, ("dp", "tp", None, None))

    # --- expert FFN (SwiGLU), expert dim shardable over the model axis ---
    h = jnp.einsum("gecd,edf->gecf", x_disp, p["wi"])
    gt = jnp.einsum("gecd,edf->gecf", x_disp, p["wg"])
    y = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * gt, p["wo"])
    if _rules is not None and _rules.replicate_decode_activations:
        y = constrain(y, (None, "tp", None, "dp"))
    else:
        y = constrain(y, ("dp", "tp", None, None))

    # --- combine: scatter-add gate-weighted expert rows back to tokens ---
    # (§Perf deepseek iterations 1-2).  The naive gather-then-weighted-sum
    # materialises a (G, K, g, D) copies tensor that GSPMD all-reduces over
    # the model axis (K x more bytes than necessary, in f32).  Instead we
    # weight each (e, c) row by its gate and scatter-add into (G, g, D) in
    # the activation dtype: the expert (k) sum happens shard-locally and
    # the cross-shard reduction moves only bf16 token activations
    # (measured: 4.3e12 -> ~2e11 bytes on deepseek train_4k).
    gate_slot = jnp.zeros((G, E, C + 1), jnp.float32)
    gate_slot = gate_slot.at[
        jnp.arange(G)[:, None], idx_km, safe_pos
    ].add(jnp.where(keep, gate_km, 0.0), mode="drop")
    yw = y.astype(x.dtype) * gate_slot[..., :C, None].astype(x.dtype)

    def _combine_one(d_idx, y_rows):
        o = jnp.zeros((g + 1, D), x.dtype)
        return o.at[d_idx.reshape(-1)].add(
            y_rows.reshape(-1, D), mode="drop")[:g]

    # batched scatter keeps G a (data-)sharded batch dim for GSPMD
    out = jax.vmap(_combine_one)(disp, yw)
    if _rules is not None and _rules.replicate_decode_activations:
        out = constrain(out, (None, None, "dp"))
    else:
        out = constrain(out, ("dp", None, None))

    if "shared" in p:
        out = out + apply_mlp(p["shared"], xf, "silu")
    out = out.reshape(B, S, D)
    return out, aux


def ref_moe(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Dense no-drop oracle: every expert applied to every token, masked."""
    B, S, D = x.shape
    xf = x.reshape(1, B * S, D)
    gate, idx, _ = _route(p, cfg, xf)
    gate, idx = gate[0], idx[0]                               # (T, K)
    outs = []
    for e in range(cfg.num_experts):
        h = jnp.einsum("td,df->tf", xf[0], p["wi"][e])
        g = jnp.einsum("td,df->tf", xf[0], p["wg"][e])
        outs.append(jnp.einsum("tf,fd->td", jax.nn.silu(h) * g, p["wo"][e]))
    ye = jnp.stack(outs, axis=0)                              # (E, T, D)
    w = jnp.zeros((cfg.num_experts, B * S), jnp.float32)
    for k in range(cfg.num_experts_per_tok):
        w = w.at[idx[:, k], jnp.arange(B * S)].add(gate[:, k])
    out = jnp.einsum("etd,et->td", ye.astype(jnp.float32), w)
    if "shared" in p:
        out = out + apply_mlp(p["shared"], xf[0], "silu").astype(jnp.float32)
    return out.reshape(B, S, D).astype(x.dtype)
