"""Attention: GQA (full / sliding-window / prefix-LM) and MLA (DeepSeek).

Training/prefill attention is *chunked online-softmax* (flash-style) over KV
blocks via ``lax.scan`` so that 32k-token prefill never materialises an
(S, S) score matrix — this is the pure-XLA analogue of the Pallas kernel in
``repro.kernels.attention`` (used where TPU lowering is available; the scan
form is what the multi-pod dry-run lowers).

Decode attends a single query over the cache; MLA decode uses the *absorbed*
formulation (scores in latent space) so per-token FLOPs stay O(S·c) instead
of re-expanding the latent cache to per-head K/V.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain
from repro.models.param import ParamInfo
from repro.models.layers import apply_norm, rope

NEG_INF = -2.0e38

# ===================================================================== GQA


def gqa_spec(cfg: ArchConfig) -> Dict[str, ParamInfo]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    spec = {
        "wq": ParamInfo((d, h, hd), ("embed", "heads", "head")),
        "wk": ParamInfo((d, kv, hd), ("embed", "kv_heads", "head")),
        "wv": ParamInfo((d, kv, hd), ("embed", "kv_heads", "head")),
        "wo": ParamInfo((h, hd, d), ("heads", "head", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamInfo((h, hd), ("heads", "head"), init="zeros")
        spec["bk"] = ParamInfo((kv, hd), ("kv_heads", "head"), init="zeros")
        spec["bv"] = ParamInfo((kv, hd), ("kv_heads", "head"), init="zeros")
    return spec


def _mask(q_pos, k_pos, *, causal: bool, window: int, prefix_len) -> jax.Array:
    """(..., Sq, Sk) boolean mask. True = attend."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m = qp >= kp
    if window > 0:
        m = jnp.logical_and(m, (qp - kp) < window)
    if prefix_len is not None:
        pl = prefix_len if jnp.ndim(prefix_len) == 0 else prefix_len[..., None, None]
        m = jnp.logical_or(m, kp < pl)  # full attention inside the prefix
    return m


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array, *,
                      causal: bool, window: int = 0,
                      prefix_len=None, chunk: int = 1024,
                      scale: Optional[float] = None) -> jax.Array:
    """Online-softmax attention, scanning over KV chunks.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd).  Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, hdv = v.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    groups = H // KV
    qg = q.reshape(B, Sq, KV, groups, hd).astype(jnp.float32) * scale

    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, pad),), constant_values=jnp.iinfo(jnp.int32).max)
    n_chunks = k.shape[1] // chunk
    kc = k.reshape(B, n_chunks, chunk, KV, hd).swapaxes(0, 1)
    vc = v.reshape(B, n_chunks, chunk, KV, hdv).swapaxes(0, 1)
    pc = k_pos.reshape(n_chunks, chunk)

    def step(carry, inp):
        m_run, l_run, o_run = carry
        k_i, v_i, p_i = inp
        s = jnp.einsum("bqkgh,bckh->bqkgc", qg, k_i.astype(jnp.float32))
        msk = _mask(q_pos, p_i, causal=causal, window=window,
                    prefix_len=prefix_len)          # (Sq, chunk)
        s = jnp.where(msk[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        o_new = o_run * corr[..., None] + jnp.einsum(
            "bqkgc,bckh->bqkgh", p, v_i.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = constrain(jnp.full((B, Sq, KV, groups), NEG_INF, jnp.float32),
                   ("dp", None, None, None))
    l0 = constrain(jnp.zeros((B, Sq, KV, groups), jnp.float32),
                   ("dp", None, None, None))
    o0 = constrain(jnp.zeros((B, Sq, KV, groups, hdv), jnp.float32),
                   ("dp", None, None, None, None))
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (kc, vc, pc))
    o = o / jnp.maximum(l[..., None], 1e-37)
    return o.reshape(B, Sq, H, hdv).astype(q.dtype)


def gqa_forward(p, cfg: ArchConfig, x: jax.Array, positions: jax.Array, *,
                causal: bool = True, prefix_len=None,
                kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if kv_override is None:
        k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
        v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = rope(k, positions, cfg.rope_theta)
        k_pos = positions
    else:
        k, v = kv_override
        k_pos = kv_positions
    q = rope(q, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, positions, k_pos, causal=causal,
                          window=cfg.sliding_window if causal else 0,
                          prefix_len=prefix_len)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"])


def gqa_project_kv(p, x: jax.Array, positions: jax.Array,
                   theta: float) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return rope(k, positions, theta), v


def gqa_decode(p, cfg: ArchConfig, x: jax.Array, k_cache: jax.Array,
               v_cache: jax.Array, index: jax.Array,
               window: int = 0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: (B, 1, D); caches: (B, S_cache, KV, hd).

    ``index`` is the absolute position of the new token; with a rolling
    (sliding-window) cache S_cache = window and slot = index % window.
    """
    B, _, _ = x.shape
    S_cache = k_cache.shape[1]
    pos = jnp.full((B, 1), index, jnp.int32)
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = rope(q, pos, cfg.rope_theta)
    k_new = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v_new = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if "bk" in p:
        k_new, v_new = k_new + p["bk"], v_new + p["bv"]
    k_new = rope(k_new, pos, cfg.rope_theta)
    slot = index % S_cache if window else jnp.minimum(index, S_cache - 1)
    zero = jnp.zeros((), jnp.int32)
    slot32 = jnp.asarray(slot, jnp.int32)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype),
                                           (zero, slot32, zero, zero))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype),
                                           (zero, slot32, zero, zero))
    # positions held in each cache slot
    slots = jnp.arange(S_cache, dtype=jnp.int32)
    if window:
        # slot s holds the most recent position p with p % window == s, p <= index
        cache_pos = index - (index - slots) % S_cache
        valid = ((index - cache_pos) < window) & (cache_pos >= 0)
    else:
        cache_pos = slots
        valid = slots <= index

    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    groups = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, groups, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgh,bckh->bkgc", qg, k_cache.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckh->bkgh", w, v_cache.astype(jnp.float32))
    o = o.reshape(B, 1, H, hd).astype(x.dtype)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"]), k_cache, v_cache


# ===================================================================== MLA


def mla_spec(cfg: ArchConfig) -> Dict[str, ParamInfo]:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rp, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": ParamInfo((d, qr), ("embed", "qlora")),
        "q_norm": {"scale": ParamInfo((qr,), ("qlora",), init="ones")},
        "wq_b": ParamInfo((qr, h, nope + rp), ("qlora", "heads", "head")),
        "wkv_a": ParamInfo((d, kvr), ("embed", "kvlora")),
        "wk_rope": ParamInfo((d, rp), ("embed", "head")),
        "kv_norm": {"scale": ParamInfo((kvr,), ("kvlora",), init="ones")},
        "wk_b": ParamInfo((kvr, h, nope), ("kvlora", "heads", "head")),
        "wv_b": ParamInfo((kvr, h, vh), ("kvlora", "heads", "head")),
        "wo": ParamInfo((h, vh, d), ("heads", "head", "embed"), init="scaled"),
    }


def _mla_qkr(p, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    """Shared q / latent / rope-key computation. x: (B, S, D)."""
    nope, rp = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q_lat = apply_norm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["wq_a"]),
                       cfg.norm_eps)
    q = jnp.einsum("bsr,rnh->bsnh", q_lat, p["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    c_kv = apply_norm(p["kv_norm"], jnp.einsum("bsd,dr->bsr", x, p["wkv_a"]),
                      cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wk_rope"])[:, :, None, :]
    k_rope = rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(p, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array) -> jax.Array:
    """Training/prefill MLA: expand latent to per-head K/V, chunked attention."""
    nope, rp, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkr(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rnh->bsnh", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rnh->bsnh", c_kv, p["wv_b"])
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_nope.shape[:-1] + (rp,))], axis=-1)
    scale = 1.0 / math.sqrt(nope + rp)
    o = chunked_attention(q_full, k_full, v, positions, positions,
                          causal=True, scale=scale)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"])


def mla_decode(p, cfg: ArchConfig, x: jax.Array, c_cache: jax.Array,
               r_cache: jax.Array, index: jax.Array):
    """Absorbed-form MLA decode.

    c_cache: (B, S, kv_lora) latent cache; r_cache: (B, S, rope_dim).
    Scores are computed in latent space: q_eff = q_nope @ wk_b  (per head),
    out_latent re-projected through wv_b — never materialises per-head K/V.
    """
    nope, rp, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    B = x.shape[0]
    S_cache = c_cache.shape[1]
    pos = jnp.full((B, 1), index, jnp.int32)
    q_nope, q_rope, c_new, r_new = _mla_qkr(p, cfg, x, pos)
    zero = jnp.zeros((), jnp.int32)
    idx32 = jnp.asarray(index, jnp.int32)
    c_cache = jax.lax.dynamic_update_slice(c_cache, c_new.astype(c_cache.dtype),
                                           (zero, idx32, zero))
    r_cache = jax.lax.dynamic_update_slice(r_cache, r_new.astype(r_cache.dtype),
                                           (zero, idx32, zero))
    q_eff = jnp.einsum("bsnh,rnh->bsnr", q_nope, p["wk_b"])  # (B,1,H,kv_lora)
    scale = 1.0 / math.sqrt(nope + rp)
    s = (jnp.einsum("bsnr,bcr->bnc", q_eff, c_cache.astype(q_eff.dtype))
         + jnp.einsum("bsnr,bcr->bnc", q_rope, r_cache.astype(q_rope.dtype)))
    s = s.astype(jnp.float32) * scale
    valid = jnp.arange(S_cache) <= index
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bnc,bcr->bnr", w.astype(c_cache.dtype), c_cache)
    o = jnp.einsum("bnr,rnh->bnh", ctx, p["wv_b"])[:, None]  # (B,1,H,vh)
    return jnp.einsum("bsnh,nhd->bsd", o.astype(x.dtype), p["wo"]), c_cache, r_cache
