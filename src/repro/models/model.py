"""Top-level model API, driven entirely by ArchConfig.

  model = Model(cfg)
  spec   = model.spec()                      # ParamInfo tree (+ logical axes)
  params = model.init(rng)                   # concrete init (smoke/small)
  loss, metrics = model.loss_fn(params, batch)
  logits = model.prefill_logits(params, batch)          # parallel prefill
  cache  = model.init_cache(batch_size, cache_len)      # decode state
  logits, cache = model.decode_step(params, cache, tokens, index)

Batches are dicts: tokens/labels (B, S) int32 (labels -1 = ignore), plus
``enc_inputs`` (audio stub frame embeddings) or ``prefix`` (VLM patch
embeddings) where the family requires them.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import (constrain, constrain_cache,
                                        constrain_decode_act)
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as tfm
from repro.models.layers import (apply_norm, embed_tokens, embedding_spec,
                                 logits_from, norm_spec, sinusoidal_positions)
from repro.models.param import (ParamInfo, abstract_params, axes_tree,
                                init_params, param_count)


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ params
    def spec(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {"embed": embedding_spec(cfg),
                             "ln_f": norm_spec(cfg)}
        s["decoder"] = tfm.decoder_spec(cfg)
        if cfg.is_encoder_decoder:
            s["encoder"] = tfm.encoder_spec(cfg)
            s["decoder"] = tfm.xdecoder_spec(cfg)
        if cfg.mtp_depth:
            s["mtp"] = {
                "proj": ParamInfo((2 * cfg.d_model, cfg.d_model),
                                  ("embed", "embed")),
                "ln": norm_spec(cfg),
                "block": tfm.attn_block_spec(cfg, use_moe=False,
                                             d_ff=cfg.d_ff or cfg.moe_d_ff),
            }
        return s

    def axes(self):
        return axes_tree(self.spec())

    def abstract_params(self):
        return abstract_params(self.spec(), _dtype(self.cfg))

    def init(self, rng: jax.Array):
        return init_params(self.spec(), rng, _dtype(self.cfg))

    def param_count(self) -> int:
        return param_count(self.spec())

    # ----------------------------------------------------------- forward
    def _embed_sequence(self, params, batch) -> Tuple[jax.Array, jax.Array, Any]:
        """Returns (x, positions, prefix_len)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], _dtype(cfg))
        prefix_len = None
        if cfg.num_prefix_tokens:
            x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
            prefix_len = cfg.num_prefix_tokens
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        if cfg.rope_theta <= 0 and not cfg.is_ssm and not cfg.is_hybrid:
            x = x + sinusoidal_positions(S, cfg.d_model, x.dtype)[None]
        x = constrain(x, ("dp", None, None))
        return x, positions, prefix_len

    def hidden_states(self, params, batch) -> Tuple[jax.Array, jax.Array]:
        """Final-norm hidden states + aux (router) loss."""
        cfg = self.cfg
        x, positions, prefix_len = self._embed_sequence(params, batch)
        if cfg.is_encoder_decoder:
            enc = tfm.apply_encoder(params["encoder"], cfg,
                                    batch["enc_inputs"].astype(x.dtype))
            x = tfm.apply_xdecoder(params["decoder"], cfg, x, positions, enc)
            aux = jnp.zeros((), jnp.float32)
        else:
            x, aux = tfm.apply_decoder(params["decoder"], cfg, x, positions,
                                       prefix_len=prefix_len)
        return apply_norm(params["ln_f"], x, cfg.norm_eps), aux

    def prefill_logits(self, params, batch) -> jax.Array:
        h, _ = self.hidden_states(params, batch)
        return logits_from(params["embed"], h).astype(jnp.float32)

    # -------------------------------------------------------------- loss
    def loss_fn(self, params, batch, ce_chunk: int = 1024):
        cfg = self.cfg
        h, aux = self.hidden_states(params, batch)
        if cfg.num_prefix_tokens:            # loss only on text positions
            h = h[:, cfg.num_prefix_tokens:]
        labels = batch["labels"]
        loss, denom = _chunked_ce(params["embed"], h, labels, ce_chunk)
        metrics = {"ce": loss / jnp.maximum(denom, 1.0),
                   "aux": aux, "tokens": denom}
        total = loss / jnp.maximum(denom, 1.0) + 0.01 * aux
        if cfg.mtp_depth:
            mtp_loss = self._mtp_loss(params, h, batch, ce_chunk)
            total = total + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        return total, metrics

    def _mtp_loss(self, params, h, batch, ce_chunk):
        """DeepSeek-V3 multi-token prediction: predict t+2 from
        [h_t ; emb(token_{t+1})] through one extra block."""
        cfg = self.cfg
        p = params["mtp"]
        emb_next = embed_tokens(params["embed"], batch["tokens"][:, 1:],
                                h.dtype)
        z = jnp.concatenate([apply_norm(p["ln"], h[:, :-1], cfg.norm_eps),
                             emb_next], axis=-1)
        z = jnp.einsum("bsd,de->bse", z, p["proj"])
        positions = jnp.arange(z.shape[1], dtype=jnp.int32)
        z, _ = tfm.apply_attn_block(p["block"], cfg, z, positions,
                                    use_moe=False)
        mtp_labels = jnp.pad(batch["labels"][:, 2:], ((0, 0), (0, 1)),
                             constant_values=-1)
        loss, denom = _chunked_ce(params["embed"], z, mtp_labels, ce_chunk)
        return loss / jnp.maximum(denom, 1.0)

    # ------------------------------------------------------------ decode
    def init_cache(self, batch_size: int, cache_len: int,
                   enc_len: Optional[int] = None, abstract: bool = False):
        cfg = self.cfg
        dt = _dtype(cfg)
        mk = (lambda shape, d: jax.ShapeDtypeStruct(shape, d)) if abstract \
            else (lambda shape, d: jnp.zeros(shape, d))
        kv_len = min(cache_len, cfg.sliding_window) if cfg.sliding_window \
            else cache_len
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache: Dict[str, Any] = {"index": mk((), jnp.int32)}

        if cfg.family == "ssm":
            L = cfg.num_layers
            cache["state"] = mk((L, batch_size, cfg.ssm_heads, cfg.ssm_state,
                                 cfg.ssm_head_dim), jnp.float32)
            cache["conv"] = mk((L, batch_size, cfg.ssm_conv - 1,
                                cfg.d_inner + 2 * cfg.ssm_state), dt)
            return cache
        if cfg.is_hybrid:
            nb = cfg.num_layers // cfg.attn_period
            cache["k"] = mk((nb, batch_size, kv_len, KV, hd), dt)
            cache["v"] = mk((nb, batch_size, kv_len, KV, hd), dt)
            for i in range(cfg.attn_period):
                if i == cfg.attn_period // 2:
                    continue
                cache[f"state{i}"] = mk((nb, batch_size, cfg.ssm_heads,
                                         cfg.ssm_state, cfg.ssm_head_dim),
                                        jnp.float32)
                cache[f"conv{i}"] = mk((nb, batch_size, cfg.ssm_conv - 1,
                                        cfg.d_inner + 2 * cfg.ssm_state), dt)
            return cache
        if cfg.attention == "mla":
            L = cfg.num_layers
            cache["c"] = mk((L, batch_size, kv_len, cfg.kv_lora_rank), dt)
            cache["r"] = mk((L, batch_size, kv_len, cfg.qk_rope_head_dim), dt)
            return cache
        # GQA families (dense / moe / audio / vlm)
        L = cfg.num_layers
        cache["k"] = mk((L, batch_size, kv_len, KV, hd), dt)
        cache["v"] = mk((L, batch_size, kv_len, KV, hd), dt)
        if cfg.is_encoder_decoder:
            el = enc_len or cfg.encoder_seq_len
            cache["xk"] = mk((L, batch_size, el, KV, hd), dt)
            cache["xv"] = mk((L, batch_size, el, KV, hd), dt)
        return cache

    def decode_step(self, params, cache, tokens, index=None):
        """tokens: (B, 1) int32.  Returns (logits (B, V) f32, new cache)."""
        cfg = self.cfg
        index = cache["index"] if index is None else index
        x = embed_tokens(params["embed"], tokens, _dtype(cfg))
        if cfg.rope_theta <= 0 and not cfg.is_ssm and not cfg.is_hybrid:
            pe = sinusoidal_positions(1 << 16, cfg.d_model, x.dtype)
            x = x + jax.lax.dynamic_slice_in_dim(pe, index, 1, axis=0)[None]

        new_cache = dict(cache)
        if cfg.family == "ssm":
            x, new_cache = self._decode_ssm(params, cache, x, index)
        elif cfg.is_hybrid:
            x, new_cache = self._decode_hybrid(params, cache, x, index)
        elif cfg.attention == "mla":
            x, new_cache = self._decode_mla(params, cache, x, index)
        else:
            x, new_cache = self._decode_gqa(params, cache, x, index)
        new_cache["index"] = index + 1
        h = apply_norm(params["ln_f"], x, cfg.norm_eps)
        logits = logits_from(params["embed"], h)[:, 0].astype(jnp.float32)
        return logits, new_cache

    # -- per-family decode bodies (scan over stacked layers + caches) ----
    def _decode_gqa(self, params, cache, x, index):
        cfg = self.cfg
        dec = params["decoder"]
        window = cfg.sliding_window

        def body(carry, inp):
            h = constrain_decode_act(carry)
            if cfg.is_encoder_decoder:
                lp, k, v, xk, xv = inp
            else:
                lp, k, v = inp
            k = constrain_cache(k, "kv")
            v = constrain_cache(v, "kv")
            a = apply_norm(lp["ln1"], h, cfg.norm_eps)
            a, k, v = attn.gqa_decode(lp["attn"], cfg, a, k, v, index,
                                      window=window)
            h = h + a
            if cfg.is_encoder_decoder:
                a = apply_norm(lp["ln_x"], h, cfg.norm_eps)
                a = _cross_decode(lp["xattn"], cfg, a, xk, xv)
                h = h + a
            f = apply_norm(lp["ln2"], h, cfg.norm_eps)
            if "router" in lp["ffn"]:
                f, _ = moe_lib.apply_moe(lp["ffn"], cfg, f)
            else:
                f = tfm.apply_mlp(lp["ffn"], f, cfg.act)
            out = (constrain_cache(k, "kv"), constrain_cache(v, "kv"))
            return h + f, out

        new_cache = dict(cache)
        if "dense_layers" in dec:  # DeepSeek-style leading dense (GQA unused)
            raise NotImplementedError
        xs = (dec["layers"], cache["k"], cache["v"])
        if cfg.is_encoder_decoder:
            xs = xs + (cache["xk"], cache["xv"])
        x, (ks, vs) = jax.lax.scan(body, x, xs)
        new_cache["k"], new_cache["v"] = ks, vs
        return x, new_cache

    def _decode_mla(self, params, cache, x, index):
        cfg = self.cfg
        dec = params["decoder"]

        def make_body(use_moe):
            def body(carry, inp):
                h = constrain_decode_act(carry)
                lp, c, r = inp
                c = constrain_cache(c, "mla")
                r = constrain_cache(r, "mla")
                a = apply_norm(lp["ln1"], h, cfg.norm_eps)
                a, c, r = attn.mla_decode(lp["attn"], cfg, a, c, r, index)
                c = constrain_cache(c, "mla")
                r = constrain_cache(r, "mla")
                h = h + a
                f = apply_norm(lp["ln2"], h, cfg.norm_eps)
                if use_moe:
                    f, _ = moe_lib.apply_moe(lp["ffn"], cfg, f)
                else:
                    f = tfm.apply_mlp(lp["ffn"], f, cfg.act)
                return h + f, (c, r)
            return body

        new_cache = dict(cache)
        nd = cfg.first_k_dense
        c_all, r_all = cache["c"], cache["r"]
        if nd:
            x, (cd, rd) = jax.lax.scan(make_body(False), x,
                                       (dec["dense_layers"],
                                        c_all[:nd], r_all[:nd]))
        x, (cm, rm) = jax.lax.scan(make_body(cfg.uses_moe), x,
                                   (dec["layers"], c_all[nd:], r_all[nd:]))
        if nd:
            new_cache["c"] = jnp.concatenate([cd, cm], axis=0)
            new_cache["r"] = jnp.concatenate([rd, rm], axis=0)
        else:
            new_cache["c"], new_cache["r"] = cm, rm
        return x, new_cache

    def _decode_ssm(self, params, cache, x, index):
        cfg = self.cfg

        def body(carry, inp):
            h = constrain_decode_act(carry)
            lp, state, conv = inp
            state = constrain_cache(state, "state")
            conv = constrain_cache(conv, "conv")
            a = apply_norm(lp["ln"], h, cfg.norm_eps)
            a, new = ssm_lib.ssm_decode(lp["ssm"], cfg, a,
                                        {"state": state, "conv": conv})
            return h + a, (constrain_cache(new["state"], "state"),
                           constrain_cache(new["conv"], "conv"))

        x, (states, convs) = jax.lax.scan(
            body, x, (params["decoder"]["layers"], cache["state"],
                      cache["conv"]))
        new_cache = dict(cache)
        new_cache["state"], new_cache["conv"] = states, convs
        return x, new_cache

    def _decode_hybrid(self, params, cache, x, index):
        cfg = self.cfg
        period = cfg.attn_period
        ssm_subs = [i for i in range(period) if i != period // 2]

        def body(carry, inp):
            h = constrain_decode_act(carry)
            lp, k, v, sstates, sconvs = inp
            k = constrain_cache(k, "kv")
            v = constrain_cache(v, "kv")
            sstates = {kk: constrain_cache(s, "state")
                       for kk, s in sstates.items()}
            sconvs = {kk: constrain_cache(s, "conv")
                      for kk, s in sconvs.items()}
            new_states, new_convs = {}, {}
            for i in range(period):
                sub = lp[f"sub{i}"]
                a = apply_norm(sub["ln1"], h, cfg.norm_eps)
                if "attn" in sub:
                    a, k, v = attn.gqa_decode(sub["attn"], cfg, a, k, v,
                                              index, window=cfg.sliding_window)
                else:
                    a, new = ssm_lib.ssm_decode(
                        sub["ssm"], cfg, a,
                        {"state": sstates[f"state{i}"],
                         "conv": sconvs[f"conv{i}"]})
                    new_states[f"state{i}"] = new["state"]
                    new_convs[f"conv{i}"] = new["conv"]
                h = h + a
                f = apply_norm(sub["ln2"], h, cfg.norm_eps)
                if "router" in sub["ffn"]:
                    f, _ = moe_lib.apply_moe(sub["ffn"], cfg, f)
                else:
                    f = tfm.apply_mlp(sub["ffn"], f, cfg.act)
                h = h + f
            return h, (k, v, new_states, new_convs)

        sstates = {f"state{i}": cache[f"state{i}"] for i in ssm_subs}
        sconvs = {f"conv{i}": cache[f"conv{i}"] for i in ssm_subs}
        x, (ks, vs, ns, ncv) = jax.lax.scan(
            body, x, (params["decoder"]["layers"], cache["k"], cache["v"],
                      sstates, sconvs))
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = ks, vs
        for i in ssm_subs:
            new_cache[f"state{i}"] = ns[f"state{i}"]
            new_cache[f"conv{i}"] = ncv[f"conv{i}"]
        return x, new_cache

    # -------------------------------------------- cache-filling prefill
    def prefill_with_cache(self, params, batch, cache_len: int):
        """Sequential prefill (scan of decode steps) — used by the CPU
        serving example; production prefill is the parallel forward."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        cache = self.init_cache(B, cache_len)
        if self.cfg.is_encoder_decoder:
            enc = tfm.apply_encoder(params["encoder"], self.cfg,
                                    batch["enc_inputs"].astype(_dtype(self.cfg)))
            pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
            ks, vs = [], []
            L = self.cfg.num_layers
            for l in range(L):
                lp = jax.tree.map(lambda a, l=l: a[l],
                                  params["decoder"]["layers"])
                k, v = attn.gqa_project_kv(lp["xattn"], enc, pos,
                                           self.cfg.rope_theta)
                ks.append(k)
                vs.append(v)
            cache["xk"] = jnp.stack(ks).astype(_dtype(self.cfg))
            cache["xv"] = jnp.stack(vs).astype(_dtype(self.cfg))

        def step(carry, t):
            cache, last = carry
            logits, cache = self.decode_step(params, cache, t[:, None])
            return (cache, logits), None

        (cache, logits), _ = jax.lax.scan(step, (cache, jnp.zeros(
            (B, self.cfg.padded_vocab), jnp.float32)), tokens.T)
        return logits, cache


def _cross_decode(p, cfg, x, xk, xv):
    """Single-token cross-attention over precomputed encoder K/V."""
    import math as _m
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    groups = H // KV
    qg = q.reshape(B, KV, groups, hd).astype(jnp.float32) / _m.sqrt(hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qg, xk.astype(jnp.float32))
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckh->bkgh", w, xv.astype(jnp.float32))
    o = o.reshape(B, 1, H, hd).astype(x.dtype)
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"])


def _chunked_ce(emb_params, h: jax.Array, labels: jax.Array,
                chunk: int) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy with the (B,S,V) logits materialised only chunk-wise.

    The chunk body is rematerialised so the full logits tensor never exists
    in the backward pass either.
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = h.shape[1] // chunk
    hc = h.reshape(B, n, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def one(hi, li):
        hi = constrain(hi, ("dp", None, None))
        logits = constrain(logits_from(emb_params, hi).astype(jnp.float32),
                           ("dp", None, "tp"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(li, 0)[..., None], axis=-1)[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        return jnp.sum((logz - tgt) * mask), jnp.sum(mask)

    def body(carry, inp):
        tot, cnt = carry
        l, c = one(*inp)
        return (tot + l, cnt + c), None

    (loss, denom), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    return loss, denom
