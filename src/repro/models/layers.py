"""Shared neural layers: norms, MLPs, embeddings, rotary/sinusoidal positions.

All ``*_spec`` functions return nested dicts of ParamInfo; all ``apply``
functions are pure jnp on the matching params tree.  Compute dtype follows
the input; normalisation and softmax accumulate in f32.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.param import ParamInfo

# ----------------------------------------------------------------- norms


def norm_spec(cfg: ArchConfig, d: Optional[int] = None) -> Dict[str, ParamInfo]:
    d = d or cfg.d_model
    spec = {"scale": ParamInfo((d,), ("embed",), init="ones")}
    if cfg.norm == "layernorm":
        spec["bias"] = ParamInfo((d,), ("embed",), init="zeros")
    return spec


def apply_norm(p, x: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


# ----------------------------------------------------------------- MLP


def mlp_spec(cfg: ArchConfig, d_ff: int) -> Dict[str, ParamInfo]:
    d = cfg.d_model
    if cfg.act == "silu":  # SwiGLU
        return {
            "wi": ParamInfo((d, d_ff), ("embed", "mlp")),
            "wg": ParamInfo((d, d_ff), ("embed", "mlp")),
            "wo": ParamInfo((d_ff, d), ("mlp", "embed"), init="scaled"),
        }
    return {
        "wi": ParamInfo((d, d_ff), ("embed", "mlp")),
        "wo": ParamInfo((d_ff, d), ("mlp", "embed"), init="scaled"),
    }


def apply_mlp(p, x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if act == "silu":
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ----------------------------------------------------------------- embeddings


def embedding_spec(cfg: ArchConfig) -> Dict[str, ParamInfo]:
    spec = {"embedding": ParamInfo((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        spec["head"] = ParamInfo((cfg.d_model, cfg.padded_vocab),
                                 ("embed", "vocab"))
    return spec


def embed_tokens(p, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["embedding"].astype(dtype), tokens, axis=0)


def logits_from(p, x: jax.Array) -> jax.Array:
    if "head" in p:
        return jnp.einsum("...d,dv->...v", x, p["head"])
    return jnp.einsum("...d,vd->...v", x, p["embedding"])


# ----------------------------------------------------------------- positions


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, head_dim), positions: (..., S)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    half = hd // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[:, :d].astype(dtype)
