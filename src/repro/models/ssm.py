"""Mamba2 (SSD — state-space duality) layer: chunked training scan + O(1)
decode step, per arXiv:2405.21060.

Shapes: d_inner = expand * d_model, heads nh = d_inner / head_dim (hp),
state size N.  B/C are shared across heads (MQA-like); dt and A are per
head; depthwise causal conv (width ssm_conv) over [x, B, C].
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain
from repro.models.param import ParamInfo

NEG_INF = -2.0e38


def ssm_spec(cfg: ArchConfig) -> Dict:
    d, di, N, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ck = cfg.ssm_conv
    return {
        "wz": ParamInfo((d, di), ("embed", "ssm_inner")),
        "wx": ParamInfo((d, di), ("embed", "ssm_inner")),
        "wB": ParamInfo((d, N), ("embed", "ssm_state")),
        "wC": ParamInfo((d, N), ("embed", "ssm_state")),
        "wdt": ParamInfo((d, nh), ("embed", "ssm_heads")),
        "dt_bias": ParamInfo((nh,), ("ssm_heads",), init="zeros"),
        "conv": ParamInfo((ck, di + 2 * N), ("conv", "ssm_inner")),
        "A_log": ParamInfo((nh,), ("ssm_heads",), init="a_log"),
        "D": ParamInfo((nh,), ("ssm_heads",), init="ones"),
        "norm": ParamInfo((di,), ("ssm_inner",), init="ones"),
        "wout": ParamInfo((di, d), ("ssm_inner", "embed"), init="scaled"),
    }


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float) -> jax.Array:
    g = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32))


def _proj_conv(p, cfg: ArchConfig, x: jax.Array):
    """Shared projections. x: (B, S, D) -> z, xBC(pre-conv), dt."""
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])
    Bv = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cv = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"]) + p["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    xBC = jnp.concatenate([xs, Bv, Cv], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. xBC: (B, S, Ch), w: (ck, Ch)."""
    ck = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (ck - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(ck):
        out = out + pad[:, i:i + xBC.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(xBC.dtype)


def ssd_forward(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Chunked SSD scan over the full sequence. x: (B, S, D)."""
    B, S, D = x.shape
    di, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q

    z, xBC, dt = _proj_conv(p, cfg, x)
    xBC = _causal_conv(xBC, p["conv"])
    xs, Bv, Cv = jnp.split(xBC, [di, di + N], axis=-1)
    xh = xs.reshape(B, nC, Q, nh, hp).astype(jnp.float32)
    Bc = Bv.reshape(B, nC, Q, N).astype(jnp.float32)
    Cc = Cv.reshape(B, nC, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nC, Q, nh)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (nh,)
    dA = dtc * A                                              # (B,nC,Q,nh)
    cum = jnp.cumsum(dA, axis=2)                              # within chunk

    # ---- intra-chunk (quadratic within chunk) ----
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)            # (B,nC,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # q - k (B,nC,Q,Q,nh)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: the future-position branch overflows (decay >> 0) and
    # would poison gradients through where()'s untaken branch
    L = jnp.exp(jnp.where(tri[None, None, :, :, None], decay, -1e30))
    W = scores[..., None] * L * dtc[:, :, None, :, :]         # (B,nC,Q,Q,nh)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", W, xh)

    # ---- chunk states & inter-chunk recurrence ----
    last = cum[:, :, -1:, :]                                  # (B,nC,1,nh)
    w_in = jnp.exp(last - cum) * dtc                          # (B,nC,Q,nh)
    S_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Bc, w_in, xh)
    chunk_decay = jnp.exp(last[:, :, 0, :])                   # (B,nC,nh)

    def scan_fn(h, inp):
        s_c, dcy = inp
        h_new = h * dcy[..., None, None] + s_c
        return h_new, h                                       # emit state *before* chunk

    h0 = constrain(jnp.zeros((B, nh, N, hp), jnp.float32),
                   ("dp", "tp", None, None))
    _, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.swapaxes(S_chunk, 0, 1), jnp.swapaxes(chunk_decay, 0, 1)))
    h_prev = jnp.swapaxes(h_prev, 0, 1)                       # (B,nC,nh,N,hp)

    w_out = jnp.exp(cum)                                      # (B,nC,Q,nh)
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cc, w_out, h_prev)

    y = (y_intra + y_inter + p["D"].astype(jnp.float32)[:, None] * xh)
    y = y.reshape(B, S, di)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["wout"])


# ------------------------------------------------------------- decode


def ssm_init_cache(cfg: ArchConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    di, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, nh, N, hp), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
    }


def ssm_decode(p, cfg: ArchConfig, x: jax.Array,
               cache: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    """One-token step. x: (B, 1, D)."""
    B = x.shape[0]
    di, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xBC, dt = _proj_conv(p, cfg, x)                        # (B,1,*)
    hist = jnp.concatenate([cache["conv"], xBC], axis=1)      # (B,ck,Ch)
    w = p["conv"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32), w)
    xBC1 = jax.nn.silu(conv_out)[:, None, :]
    new_conv = hist[:, 1:, :]

    xs, Bv, Cv = jnp.split(xBC1, [di, di + N], axis=-1)
    xhead = xs.reshape(B, nh, hp).astype(jnp.float32)
    Bv, Cv = Bv[:, 0].astype(jnp.float32), Cv[:, 0].astype(jnp.float32)
    dt1 = dt[:, 0]                                            # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt1 * A)                                  # (B,nh)
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bv, dt1, xhead)
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cv, state)
    y = y + p["D"].astype(jnp.float32)[:, None] * xhead
    y = y.reshape(B, 1, di)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["wout"])
    return out, {"state": state, "conv": new_conv}


def ssd_reference(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Sequential-recurrence oracle (token by token) for tests."""
    B, S, D = x.shape
    cache = ssm_init_cache(cfg, B, x.dtype)
    outs = []
    for t in range(S):
        o, cache = ssm_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
