"""PackageQueryEngine: the public API tying the pipeline together.

    engine = PackageQueryEngine(table, attrs, d_f=100, alpha=100_000)
    engine.partition()                       # offline: build the hierarchy
    result = engine.solve(query)             # Progressive Shading
    base   = engine.solve_direct(query)      # black-box ILP (Gurobi stand-in)
    sr     = engine.solve_sketchrefine(query)

``table`` may be a dict of resident numpy columns or any
:class:`~repro.core.relation.Relation` (e.g. ``MemmapRelation`` over an
on-disk matrix).  Streamed relations run the whole pipeline out-of-core:
layer 0 is partitioned through the bucketing backend (Appendix D.2,
``memory_rows`` bounding the resident set), the shading cascade passes
candidate-id subsets down, and Dual Reducer / validation gather only the
<= alpha candidate rows — an end-to-end solve holds O(alpha +
memory_rows) rows resident.  ``solve_direct``/``lp_bound`` assemble their
full-relation form chunk-wise behind a size guard (they are the
full-materialisation baselines by definition).
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.core import guard
from repro.core import ilp as ilp_mod
from repro.core.dual_reducer import PackageResult
from repro.core.hierarchy import Hierarchy
from repro.core.lp import OPTIMAL, solve_lp_np
from repro.core.paql import PackageQuery
from repro.core.relation import Relation, as_relation, io_retry_count
from repro.core.shading import progressive_shading
from repro.core.sketchrefine import sketch_refine


class PackageQueryEngine:
    def __init__(self, table, attrs: Sequence[str],
                 *, d_f: int = 100, alpha: int = 100_000,
                 seed: int = 0, partitioner_backend: str = "dlv",
                 layer0_backend: Optional[str] = None,
                 chunk_rows: Optional[int] = None,
                 memory_rows: Optional[int] = None, mesh=None,
                 cache=None):
        self.table: Relation = as_relation(table, columns=list(attrs))
        self.attrs = list(attrs)
        self.d_f = d_f
        self.alpha = alpha
        self.partitioner_backend = partitioner_backend
        self.layer0_backend = layer0_backend
        self.chunk_rows = chunk_rows
        self.memory_rows = memory_rows
        self.mesh = mesh
        self.rng = np.random.default_rng(seed)
        self.hierarchy: Optional[Hierarchy] = None
        self.partition_time_s: float = 0.0
        # cross-query artifact cache: True -> a private QCache; or pass a
        # QCache instance shared across engines (the serving-layer shape)
        if cache is True:
            from repro.core.qcache import QCache
            cache = QCache()
        # identity test, not truthiness: an empty QCache has len() == 0
        self.cache = None if cache in (None, False) else cache

    @property
    def n(self) -> int:
        return self.table.num_rows

    def session(self, seed: int = 0) -> "PackageQueryEngine":
        """A per-session engine sharing this engine's table, hierarchy
        and cross-query cache, with a PRIVATE rng.

        The serving-layer shape: one resident engine (partitioned once)
        serves many concurrent sessions — ``engine.rng`` is the only
        unshareable state (a numpy Generator is not thread-safe and its
        draw order must stay per-session deterministic), so each session
        gets its own seeded Generator while the heavy shared structures
        (Relation, Hierarchy, QCache — each thread-safe or read-only
        after partition) stay common.
        """
        import copy
        s = copy.copy(self)
        s.rng = np.random.default_rng(seed)
        return s

    def partition(self) -> "PackageQueryEngine":
        t0 = time.time()
        self.hierarchy = Hierarchy(self.table, self.attrs, d_f=self.d_f,
                                   alpha=self.alpha, rng=self.rng,
                                   backend=self.partitioner_backend,
                                   layer0_backend=self.layer0_backend,
                                   chunk_rows=self.chunk_rows,
                                   memory_rows=self.memory_rows,
                                   mesh=self.mesh)
        self.partition_time_s = time.time() - t0
        return self

    # ------------------------------------------------------------ solvers
    def solve(self, query: PackageQuery, *, dr_q: int = 500,
              ilp_kwargs: Optional[dict] = None,
              budget: Optional[guard.SolveBudget] = None,
              guarded: bool = True,
              **ps_kwargs) -> PackageResult:
        """Progressive Shading (the paper's algorithm).  Extra kwargs are
        the ablation knobs of progressive_shading (layer_solver, sampler,
        dr_aux).

        Guarded by default: every call returns a PackageResult carrying a
        ``guard.SolveReport`` (``res.report``) with a defined status —
        ok / degraded / infeasible / budget_exhausted / error — and never
        raises; ``budget=`` (a ``guard.SolveBudget``) bounds the whole
        cascade end to end.  ``guarded=False`` disables the degradation
        ladder and re-raises exceptions (the unguarded baseline for the
        robustness bench).

        With a ``cache`` (engine knob), solves consult the cross-query
        artifact cache before descending and populate it after clean
        solves; hit/miss/prune counters land on ``res.report``."""
        if self.hierarchy is None:
            self.partition()
        if self.cache is not None:
            self.cache.register(self.hierarchy)
        t0 = time.time()
        report = guard.SolveReport(budget=budget or guard.SolveBudget(),
                                   monitor=guard.NumericalMonitor())
        report.budget.start()
        io0 = io_retry_count()
        try:
            res = progressive_shading(self.hierarchy, query, self.table,
                                      alpha=self.alpha, dr_q=dr_q,
                                      rng=self.rng, ilp_kwargs=ilp_kwargs,
                                      budget=report.budget, report=report,
                                      ladder=guarded, qcache=self.cache,
                                      **ps_kwargs)
        # repro: allow[REPRO004] guard contract: guarded solve must never
        # raise -- contain, report, and return an empty (infeasible) result
        except Exception as e:
            if not guarded:
                raise
            # guard contract: never raise — contain, report, return empty
            report.status = guard.ERROR
            report.note(f"error: {type(e).__name__}: {e}")
            res = PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                                0.0, 0.0, status="error")
        report.fault_retries = io_retry_count() - io0
        res.report = report.finalize(res.feasible)
        res.status += f" t={time.time() - t0:.3f}s"
        return res

    def solve_direct(self, query: PackageQuery,
                     ilp_kwargs: Optional[dict] = None) -> PackageResult:
        """Black-box ILP over the full relation (the Gurobi role).  The
        standard form streams chunk-wise off a Relation; a size guard
        raises for relations too large to hold densely."""
        c, A, bl, bu, ub = query.matrices(self.table, None)
        res = ilp_mod.solve_ilp(c, A, bl, bu, ub, **(ilp_kwargs or {}))
        if not res.feasible:
            return PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                                 0.0, 0.0, status="ilp_infeasible")
        nz = res.x > 0.5
        obj = -res.obj if query.maximize else res.obj
        lp_obj = -res.lp_obj if query.maximize else res.lp_obj
        return PackageResult(True, np.flatnonzero(nz), res.x[nz], obj,
                             lp_obj, status="ok")

    def solve_sketchrefine(self, query: PackageQuery,
                           tau_frac: float = 0.001,
                           ilp_kwargs: Optional[dict] = None) -> PackageResult:
        return sketch_refine(query, self.table, self.attrs,
                             tau_frac=tau_frac, ilp_kwargs=ilp_kwargs,
                             memory_rows=self.memory_rows,
                             chunk_rows=self.chunk_rows)

    def lp_bound(self, query: PackageQuery) -> float:
        """LP relaxation over the full relation (integrality-gap metric).
        Streams its matrix assembly like solve_direct (same size guard)."""
        c, A, bl, bu, ub = query.matrices(self.table, None)
        res = solve_lp_np(c, A, bl, bu, ub, max_iters=20000)
        if res.status != OPTIMAL:
            return np.nan
        return -res.obj if query.maximize else res.obj
