"""Branch & bound ILP solver over the bounded-variable LP relaxation.

This stands in for the paper's "black-box ILP solver" (Gurobi).  Package
queries produce ILPs with a handful of constraints, so LP re-solves are
cheap; best-first search with a most-fractional branching rule and a
round-and-check incumbent heuristic handles the Dual Reducer sub-ILPs
(q ≈ 500 variables) comfortably.

Every node LP differs from its parent's only in one variable's bounds, so
node re-solves (and the diving / feasibility-pump LPs) are warm-started
from the parent basis — the textbook dual-simplex case (core.lp); the
root accepts an external ``warm_start`` (Dual Reducer passes lp1's basis
re-mapped onto the sub-ILP columns).

Minimisation form throughout (PackageQuery.matrices already negates
MAXIMIZE objectives).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Optional

import numpy as np

from repro.core.lp import solve_lp_np, BUDGET, OPTIMAL, INFEASIBLE
from repro.core.lp_batch import solve_lp_batch

ILP_OPTIMAL, ILP_FEASIBLE, ILP_INFEASIBLE, ILP_LIMIT = 0, 1, 2, 3


@dataclasses.dataclass
class ILPResult:
    status: int
    x: np.ndarray
    obj: float               # minimisation objective
    nodes: int
    lp_obj: float            # root relaxation bound
    lp_iters: int = 0        # total simplex iterations across node re-solves

    @property
    def feasible(self) -> bool:
        return self.status in (ILP_OPTIMAL, ILP_FEASIBLE)


def _round_feasible(x, c, A, bl, bu, lb, ub, tol):
    xi = np.clip(np.round(x), lb, ub)
    act = A @ xi
    if np.all(act >= bl - tol) and np.all(act <= bu + tol):
        return xi, float(c @ xi)
    return None, np.inf


def _dive(c, A, bl, bu, lb, ub, tol, max_lp_iters, max_steps=400,
          warm_start=None, budget=None, probe_batch: bool = False):
    """LP-guided fractional diving.

    Package-query LPs have at most m fractional (basic) variables, so
    repeatedly pinning the most-fractional variable to a nearby integer and
    re-solving converges quickly to an integer-feasible point when one is
    near the LP face — the workhorse incumbent finder for tight BETWEEN
    windows where naive rounding fails.

    ``probe_batch=True`` solves both branching probes (pure bound
    variants of the current dive LP) as one ``solve_lp_batch`` dispatch
    and keeps the first OPTIMAL one in today's preference order.
    """
    lbd, ubd = lb.copy(), ub.copy()
    warm = warm_start
    for _ in range(max_steps):
        res = solve_lp_np(c, A, bl, bu, ubd, lb=lbd, max_iters=max_lp_iters,
                          warm_start=warm, budget=budget)
        if res.status != OPTIMAL:
            return None, np.inf
        warm = res
        x = res.x
        frac = np.abs(x - np.round(x))
        j = int(np.argmax(frac))
        if frac[j] < tol:
            xi, obj = _round_feasible(x, c, A, bl, bu, lbd, ubd, tol)
            if xi is not None:
                return xi, obj
            return None, np.inf
        r = np.round(x[j])
        # try nearest integer first, fall back to the other side
        variants = []
        for v in (r, np.floor(x[j]) if r > x[j] else np.ceil(x[j])):
            v = float(np.clip(v, lbd[j], ubd[j]))
            lb2, ub2 = lbd.copy(), ubd.copy()
            lb2[j] = ub2[j] = v
            variants.append((lb2, ub2))
        if probe_batch:
            probes = solve_lp_batch(
                c, A, bl, bu, [vv[1] for vv in variants],
                [vv[0] for vv in variants], max_iters=max_lp_iters,
                warm_starts=[warm] * len(variants))
        else:
            probes = None
        for i, (lb2, ub2) in enumerate(variants):
            probe = probes[i] if probes is not None else solve_lp_np(
                c, A, bl, bu, ub2, lb=lb2, max_iters=max_lp_iters,
                warm_start=warm)
            if probe.status == OPTIMAL:
                lbd, ubd = lb2, ub2
                warm = probe
                break
        else:
            return None, np.inf
    return None, np.inf


def _violation(act, bl, bu):
    return np.sum(np.maximum(bl - act, 0) + np.maximum(act - bu, 0))


def _swap_step(x, c, A, bl, bu, lb, ub, *, improve: bool):
    """One best swap (dec a / inc b, incl. pure inc/dec).

    improve=False: minimise total constraint violation (repair mode).
    improve=True : minimise objective among moves that keep feasibility.
    Returns (new_x, improved?).  Vectorised over all O(|pkg| * n) moves.
    """
    act = A @ x
    dec = np.flatnonzero(x > lb + 0.5)          # can decrement
    inc = np.flatnonzero(x < ub - 0.5)          # can increment
    if len(dec) == 0 and len(inc) == 0:
        return x, False
    # pad with a "no-op" pseudo-variable (zero column)
    Ad = np.concatenate([A[:, dec], np.zeros((A.shape[0], 1))], axis=1)
    Ai = np.concatenate([A[:, inc], np.zeros((A.shape[0], 1))], axis=1)
    cd = np.concatenate([c[dec], [0.0]])
    ci = np.concatenate([c[inc], [0.0]])
    # new activity for every (a, b): act - A[:,a] + A[:,b]
    na = act[:, None, None] - Ad[:, :, None] + Ai[:, None, :]
    viol = (np.maximum(bl[:, None, None] - na, 0)
            + np.maximum(na - bu[:, None, None], 0)).sum(axis=0)
    dobj = -cd[:, None] + ci[None, :]
    if improve:
        feas = viol <= 1e-9
        dobj = np.where(feas, dobj, np.inf)
        a, b = np.unravel_index(np.argmin(dobj), dobj.shape)
        if not np.isfinite(dobj[a, b]) or dobj[a, b] >= -1e-12:
            return x, False
    else:
        cur = _violation(act, bl, bu)
        score = viol + 1e-12 * dobj             # tie-break toward objective
        a, b = np.unravel_index(np.argmin(score), score.shape)
        if viol[a, b] >= cur - 1e-12:
            return x, False
    x = x.copy()
    if a < len(dec):
        x[dec[a]] -= 1
    if b < len(inc):
        x[inc[b]] += 1
    return x, True


def _swap_search(x0, c, A, bl, bu, lb, ub, tol, *, max_moves=200):
    """Min-conflicts repair followed by 1-swap objective improvement."""
    x = np.clip(np.round(x0), lb, ub)
    for _ in range(max_moves):
        if _violation(A @ x, bl, bu) <= tol:
            break
        x, moved = _swap_step(x, c, A, bl, bu, lb, ub, improve=False)
        if not moved:
            return None, np.inf
    if _violation(A @ x, bl, bu) > tol:
        return None, np.inf
    for _ in range(max_moves):
        x, moved = _swap_step(x, c, A, bl, bu, lb, ub, improve=True)
        if not moved:
            break
    return x, float(c @ x)


def _feasibility_pump(c, A, bl, bu, lb, ub, tol, max_lp_iters,
                      max_rounds=120, seed=0, warm_start=None,
                      budget=None):
    """Objective feasibility pump (Fischetti-Glover-Lodi) for the tight
    BETWEEN-window packages where rounding/diving stall.

    Alternates LP projection and rounding, minimising an L1 distance to the
    current integer point blended with the (normalised) true objective;
    random flips break cycles.
    """
    rng = np.random.default_rng(seed)
    n = len(c)
    cn = c / (np.linalg.norm(c) + 1e-12)
    res = solve_lp_np(c, A, bl, bu, ub, lb=lb, max_iters=max_lp_iters,
                      warm_start=warm_start, budget=budget)
    if res.status != OPTIMAL:
        return None, np.inf
    x_tilde = np.clip(np.round(res.x), lb, ub)
    w = 0.5
    last = None
    for it in range(max_rounds):
        act = A @ x_tilde
        if np.all(act >= bl - tol) and np.all(act <= bu + tol):
            return x_tilde, float(c @ x_tilde)
        # distance objective: push x toward x_tilde
        c_dist = np.where(x_tilde <= lb + 0.5, 1.0,
                          np.where(x_tilde >= ub - 0.5, -1.0, 0.0))
        # NOTE: the objective changes between pump rounds, so only the
        # previous pump LP's basis (not its at_upper pattern, which the
        # engine re-derives from the new reduced costs) carries over.
        res = solve_lp_np(c_dist + w * cn, A, bl, bu, ub, lb=lb,
                          max_iters=max_lp_iters, warm_start=res,
                          budget=budget)
        if res.status != OPTIMAL:
            return None, np.inf
        new_tilde = np.clip(np.round(res.x), lb, ub)
        if last is not None and np.array_equal(new_tilde, last):
            # cycle: flip the T components with largest rounding error
            err = np.abs(res.x - new_tilde)
            T = int(rng.integers(2, 8))
            idx = np.argsort(-err)[:T]
            for j in idx:
                if res.x[j] > new_tilde[j]:
                    new_tilde[j] = min(new_tilde[j] + 1, ub[j])
                else:
                    new_tilde[j] = max(new_tilde[j] - 1, lb[j])
        last = x_tilde
        x_tilde = new_tilde
        w *= 0.7
    return None, np.inf


def solve_ilp(c, A, bl, bu, ub, *, lb: Optional[np.ndarray] = None,
              max_nodes: int = 5000, tol: float = 1e-6,
              time_limit_s: float = 60.0, max_lp_iters: int = 8000,
              warm_start=None, warm_nodes: bool = True,
              budget=None, monitor=None, wave_width: int = 1,
              batch_backend: Optional[str] = None) -> ILPResult:
    """warm_nodes=False disables node-LP warm starting (benchmark knob).

    ``budget=`` (a ``guard.SolveBudget``) clamps the node/time limits to
    what remains, charges every explored node against the shared node
    budget, and threads the pivot budget through the root/node/heuristic
    LPs — a budget-exhausted search returns ILP_LIMIT (with the incumbent
    if one exists) instead of running past the deadline.

    ``wave_width=W`` explores the frontier in waves: the W best-bound
    nodes are popped together and their child LPs — pure bound-variants
    of one shared ``(c, A)``, each warm-started from its parent — are
    solved as ONE ``solve_lp_batch`` dispatch.  ``W=1`` keeps today's
    one-node-at-a-time loop bit-identical (the batch engine degrades to
    the same sequential ``solve_lp_np`` calls); larger W trades a few
    extra node expansions (children of wave-mates can't prune each
    other before solving) for one dispatch per wave.  ``batch_backend``
    overrides the engine choice (default: ``"np"`` for W=1, ``"auto"``
    otherwise).
    """
    c = np.asarray(c, np.float64)
    A = np.atleast_2d(np.asarray(A, np.float64))
    m, n = A.shape
    bl = np.asarray(bl, np.float64)
    bu = np.asarray(bu, np.float64)
    ub0 = np.asarray(ub, np.float64)
    lb0 = np.zeros(n) if lb is None else np.asarray(lb, np.float64)

    if budget is not None:
        budget.start()
        kw = budget.clamp_ilp_kwargs(dict(time_limit_s=time_limit_s,
                                          max_nodes=max_nodes))
        time_limit_s = kw["time_limit_s"]
        max_nodes = kw["max_nodes"]

    root = solve_lp_np(c, A, bl, bu, ub0, lb=lb0, max_iters=max_lp_iters,
                       warm_start=warm_start, budget=budget,
                       monitor=monitor)
    lp_iters = root.iters
    if root.status == INFEASIBLE:
        return ILPResult(ILP_INFEASIBLE, np.zeros(n), np.inf, 1, np.inf,
                         lp_iters)
    root_obj = root.obj
    if root.status == BUDGET:
        # truncated root relaxation: salvage an incumbent by rounding the
        # (possibly primal-infeasible) iterate, skip the search
        best_x, best_obj = _round_feasible(root.x, c, A, bl, bu, lb0, ub0,
                                           tol)
        if best_x is None:
            best_x, best_obj = _swap_search(root.x, c, A, bl, bu, lb0,
                                            ub0, tol)
        if best_x is None:
            return ILPResult(ILP_LIMIT, np.zeros(n), np.inf, 0, root_obj,
                             lp_iters)
        return ILPResult(ILP_FEASIBLE, best_x, best_obj, 0, root_obj,
                         lp_iters)

    best_x, best_obj = _round_feasible(root.x, c, A, bl, bu, lb0, ub0, tol)
    if best_x is None:
        # swap-based repair + improvement from the rounded LP point
        best_x, best_obj = _swap_search(root.x, c, A, bl, bu, lb0, ub0, tol)
    if best_x is None:
        # randomized-rounding restarts escape repair local minima
        rng = np.random.default_rng(7)
        for _ in range(8):
            frac = root.x - np.floor(root.x)
            xr = np.floor(root.x) + (rng.random(n) < frac)
            jitter = rng.random(n) < (3.0 / max(n, 1))
            xr = np.clip(xr + jitter * rng.integers(-1, 2, n), lb0, ub0)
            bx, bo = _swap_search(xr, c, A, bl, bu, lb0, ub0, tol)
            if bx is not None:
                best_x, best_obj = bx, bo
                break
    if best_x is None:
        best_x, best_obj = _dive(c, A, bl, bu, lb0, ub0, tol, max_lp_iters,
                                 max_steps=4 * m + 8, warm_start=root,
                                 budget=budget,
                                 probe_batch=wave_width > 1)
    if best_x is None:
        best_x, best_obj = _feasibility_pump(c, A, bl, bu, lb0, ub0, tol,
                                             max_lp_iters, warm_start=root,
                                             budget=budget)
    if best_x is not None:
        bx, bo = _swap_search(best_x, c, A, bl, bu, lb0, ub0, tol)
        if bx is not None and bo < best_obj:
            best_x, best_obj = bx, bo

    heap = []
    counter = itertools.count()
    heapq.heappush(heap, (root.obj, next(counter), lb0, ub0, root.x,
                          root.warm))
    nodes = 0
    t0 = time.time()
    status = ILP_OPTIMAL
    wave_width = max(1, int(wave_width))
    if batch_backend is None:
        batch_backend = "np" if wave_width == 1 else "auto"
    while heap:
        # ---- gather one frontier wave: up to W best-bound expansions ----
        wave_specs = []       # (lb2, ub2, parent warm-start)
        expanded = 0
        limit = False
        while heap and expanded < wave_width:
            if nodes >= max_nodes or (time.time() - t0) > time_limit_s or \
                    (budget is not None and budget.exhausted()):
                limit = True
                break
            bound, _, lbn, ubn, xlp, node_warm = heapq.heappop(heap)
            if bound >= best_obj - 1e-9:
                continue
            nodes += 1
            if budget is not None:
                budget.charge_nodes(1)
            frac = np.abs(xlp - np.round(xlp))
            j = int(np.argmax(frac))
            if frac[j] < tol:
                # integral LP solution: new incumbent
                xi = np.round(xlp)
                obj = float(c @ xi)
                if obj < best_obj:
                    best_obj, best_x = obj, xi
                continue
            expanded += 1
            fl = np.floor(xlp[j])
            for lo_j, hi_j in ((lbn[j], fl), (fl + 1, ubn[j])):
                if lo_j > hi_j:
                    continue
                lb2, ub2 = lbn.copy(), ubn.copy()
                lb2[j], ub2[j] = lo_j, hi_j
                # child differs from parent in one variable's bounds
                # only: warm-start the dual simplex from the parent basis
                wave_specs.append(
                    (lb2, ub2, node_warm if warm_nodes else None))
        if limit and not wave_specs:
            status = ILP_LIMIT
            break
        if wave_specs:
            # the whole wave's children are bound-variants of one shared
            # (c, A): one batched dispatch (sequential np loop at W=1)
            ress = solve_lp_batch(
                c, A, bl, bu, [s[1] for s in wave_specs],
                [s[0] for s in wave_specs], max_iters=max_lp_iters,
                warm_starts=[s[2] for s in wave_specs], budget=budget,
                monitor=monitor, backend=batch_backend)
            # vectorized _round_feasible over the wave: one (K, n)
            # round/clip and one matmul per wave instead of per child —
            # acceptance stays sequential (best_obj updates prune later
            # children exactly as the per-child loop did)
            live = [i for i, r in enumerate(ress)
                    if r.status not in (INFEASIBLE, BUDGET)]
            if live:
                XI = np.clip(
                    np.round(np.stack([ress[i].x for i in live])),
                    np.stack([wave_specs[i][0] for i in live]),
                    np.stack([wave_specs[i][1] for i in live]))
                ACT = XI @ A.T
                r_feas = (np.all(ACT >= bl - tol, axis=1)
                          & np.all(ACT <= bu + tol, axis=1))
                r_obj = XI @ c
            ri = {k: j for j, k in enumerate(live)}
            for i, ((lb2, ub2, _), res) in enumerate(zip(wave_specs,
                                                         ress)):
                lp_iters += res.iters
                if res.status == INFEASIBLE:
                    continue
                if res.status == BUDGET:
                    # child bound is unusable and the budget is gone: the
                    # search is incomplete, never claim optimality
                    status = ILP_LIMIT
                    continue
                if res.obj >= best_obj - 1e-9:
                    continue
                j = ri[i]
                if r_feas[j] and r_obj[j] < best_obj:
                    best_obj, best_x = float(r_obj[j]), XI[j]
                heapq.heappush(heap, (res.obj, next(counter), lb2, ub2,
                                      res.x, res.warm))
        if limit:
            status = ILP_LIMIT
            break

    if best_x is None:
        st = ILP_INFEASIBLE if status == ILP_OPTIMAL else ILP_LIMIT
        return ILPResult(st, np.zeros(n), np.inf, nodes, root_obj, lp_iters)
    st = status if status == ILP_LIMIT else ILP_OPTIMAL
    if st == ILP_LIMIT:
        st = ILP_FEASIBLE
    return ILPResult(st, best_x, best_obj, nodes, root_obj, lp_iters)


def brute_force_ilp(c, A, bl, bu, ub) -> ILPResult:
    """Exhaustive oracle for tiny instances (tests only)."""
    c = np.asarray(c, np.float64)
    A = np.atleast_2d(np.asarray(A, np.float64))
    n = A.shape[1]
    ub = np.asarray(ub).astype(int)
    best, best_obj = None, np.inf
    total = int(np.prod(ub + 1))
    assert total <= 2_000_000, "too large for brute force"
    for combo in itertools.product(*[range(u + 1) for u in ub]):
        x = np.asarray(combo, np.float64)
        act = A @ x
        if np.all(act >= np.asarray(bl) - 1e-9) and np.all(
                act <= np.asarray(bu) + 1e-9):
            obj = float(c @ x)
            if obj < best_obj:
                best_obj, best = obj, x
    if best is None:
        return ILPResult(ILP_INFEASIBLE, np.zeros(n), np.inf, total, np.inf)
    return ILPResult(ILP_OPTIMAL, best, best_obj, total, -np.inf)
