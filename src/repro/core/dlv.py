"""Dynamic Low Variance partitioning — paper §3 (Algorithms 5, 6, 7).

1-D DLV is a running-variance reset scan over sorted attribute values
(Algorithm 5) — implemented as a jitted ``lax.scan`` (tiny carry, O(n)).
DLV (Algorithm 6) is divisive hierarchical clustering keyed by *total
variance* (|P| * max_j var_j), splitting the top partition on its
highest-variance attribute with a bounding variance beta = c_j sigma^2/d_f^2
(GetScaleFactors, Algorithm 7, calibrates c_j by binary search on a sample).

Partitions are kept as contiguous slices of a permutation array (the paper's
cache-friendly layout); each split records (attr, boundary values, children)
into a flat split tree enabling sub-linear GetGroup lookups (the PostgreSQL
GiST role in the paper — Appendix D.2).
"""
from __future__ import annotations

import dataclasses
import heapq
from functools import partial
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- 1-D DLV


@partial(jax.jit, static_argnames=())
def _dlv_scan(vals: jax.Array, beta: jax.Array) -> jax.Array:
    """cuts[i] = True iff a delimiter is placed immediately before vals[i].

    vals must be sorted ascending.  Matches Algorithm 5: the running set V
    is reset whenever var(V u {x}) > beta.
    """
    def step(carry, x):
        k, s1, s2 = carry
        k1 = k + 1.0
        s1n, s2n = s1 + x, s2 + x * x
        var = s2n / k1 - (s1n / k1) ** 2
        cut = var > beta
        return ((jnp.where(cut, 1.0, k1), jnp.where(cut, x, s1n),
                 jnp.where(cut, x * x, s2n)), cut)
    _, cuts = jax.lax.scan(step, (0.0, 0.0, 0.0), vals)
    return cuts


def dlv_1d(values: np.ndarray, beta: float) -> np.ndarray:
    """Delimiter positions for sorted ``values``; returns cut flags (n,)."""
    v = np.asarray(values, np.float64)
    shift = v.mean() if len(v) else 0.0   # numerical stabilisation
    cuts = np.array(_dlv_scan(jnp.asarray(v - shift), jnp.float64(beta)))
    if len(cuts):
        cuts[0] = False
    return cuts


def dlv_1d_partition(values: np.ndarray, beta: float):
    """(group_id per element, boundary values d_1..d_{p-1}) for sorted input."""
    cuts = dlv_1d(values, beta)
    gid = np.cumsum(cuts)
    bounds = values[np.flatnonzero(cuts)]
    return gid, bounds


def ratio_score(values: np.ndarray, gid: np.ndarray) -> float:
    """Definition 2: sum of per-partition variances / total variance.

    Single vectorised pass: per-group count/sum/sum-of-squares via
    ``np.bincount`` (O(n + G) instead of the old O(G * n) per-group scan;
    called per attribute in the partitioning benchmarks)."""
    values = np.asarray(values, np.float64)
    tot = float(np.var(values))
    if tot <= 0:
        return 0.0
    gid = np.asarray(gid)
    if gid.dtype.kind not in "iu" or (len(gid) and
                                      (gid.min() < 0
                                       or gid.max() >= len(gid))):
        # sparse/non-integer ids: compact them so bincount stays O(n)
        _, gid = np.unique(gid, return_inverse=True)
    shift = values.mean()              # numerical stabilisation
    v = values - shift
    cnt = np.bincount(gid)
    s1 = np.bincount(gid, weights=v)
    s2 = np.bincount(gid, weights=v * v)
    nz = cnt > 0
    var_g = s2[nz] / cnt[nz] - (s1[nz] / cnt[nz]) ** 2
    return float(np.maximum(var_g, 0.0).sum()) / tot


# ------------------------------------------------------ GetScaleFactors


def get_scale_factors(X: np.ndarray, d_f: int, *, sample: int = 10_000,
                      eps: float = 1e-9, max_steps: int = 60,
                      rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """Algorithm 7: per-attribute constants c_j with beta = c_j sigma^2/d_f^2."""
    rng = rng or np.random.default_rng(0)
    n, k = X.shape
    take = min(sample, n)
    idx = rng.choice(n, size=take, replace=False) if take < n else np.arange(n)
    P = X[idx]
    out = np.empty(k)
    for j in range(k):
        vals = np.sort(P[:, j])
        var_j = float(np.var(vals))
        if var_j <= 0:
            out[j] = 13.5  # paper's default c
            continue
        lo, hi = 0.0, 0.25 * (vals[-1] - vals[0]) ** 2
        beta = hi
        target = max(2, min(d_f, take))
        for _ in range(max_steps):
            if hi - lo <= eps * max(hi, 1.0):
                break
            beta = 0.5 * (lo + hi)
            p = int(dlv_1d(vals, beta).sum()) + 1
            if p == target:
                break
            if p < target:
                hi = beta
            else:
                lo = beta
        out[j] = beta * d_f * d_f / var_j
    return out


# ------------------------------------------------------------- split tree


_PID_TAG = 1 << 40   # children >= _PID_TAG are unresolved leaf pids


@dataclasses.dataclass
class SplitNode:
    attr: int
    bounds: np.ndarray              # d_1..d_{p-1} ascending
    children: List[int]             # node ids (>=0) or ~group_id (<0)


@dataclasses.dataclass
class DLVResult:
    gid: np.ndarray                 # (n,) group id per tuple
    order: np.ndarray               # permutation; groups are contiguous
    offsets: np.ndarray             # (G+1,) slice bounds into order
    reps: np.ndarray                # (G, k) group means
    boxes_lo: np.ndarray            # (G, k) member min per attr
    boxes_hi: np.ndarray            # (G, k)
    nodes: List[SplitNode]
    root: int

    @property
    def num_groups(self) -> int:
        return len(self.offsets) - 1

    def members(self, g: int) -> np.ndarray:
        return self.order[self.offsets[g]:self.offsets[g + 1]]

    def get_group(self, t: np.ndarray) -> int:
        """Sub-linear membership: descend the split tree (GiST analogue)."""
        node_id = self.root
        while node_id >= 0:
            node = self.nodes[node_id]
            i = int(np.searchsorted(node.bounds, t[node.attr], side="right"))
            node_id = node.children[i]
        return ~node_id


def dlv(X: np.ndarray, d_f: int, *, c: Optional[np.ndarray] = None,
        min_groups: Optional[int] = None,
        rng: Optional[np.random.Generator] = None) -> DLVResult:
    """Algorithm 6 over tuples X (n, k); produces ~n/d_f groups."""
    X = np.asarray(X, np.float64)
    n, k = X.shape
    target = min_groups if min_groups is not None else max(1, n // d_f)
    if c is None:
        c = get_scale_factors(X, d_f, rng=rng)

    order = np.arange(n)
    # partition registry: pid -> (start, end, node_ref)
    spans: Dict[int, Tuple[int, int]] = {0: (0, n)}
    var_cache: Dict[int, np.ndarray] = {0: np.var(X, axis=0)}
    next_pid = 1
    heap: List[Tuple[float, int]] = []

    def push(pid):
        s, e = spans[pid]
        v = var_cache[pid]
        tv = (e - s) * float(v.max())
        if e - s >= 2 and tv > 0:
            heapq.heappush(heap, (-tv, pid))

    push(0)
    nodes: List[SplitNode] = []
    # parent linkage for tree construction
    child_slot: Dict[int, Tuple[int, int]] = {}   # pid -> (node_id, slot)
    root = -1
    pid_of_root = 0

    while len(spans) < target and heap:
        _, pid = heapq.heappop(heap)
        if pid not in spans:
            continue
        s, e = spans[pid]
        v = var_cache[pid]
        j = int(np.argmax(v))
        sigma2 = float(v[j])
        if sigma2 <= 0:
            continue
        beta = c[j] * sigma2 / (d_f * d_f)
        idx = order[s:e]
        vals = X[idx, j]
        perm = np.argsort(vals, kind="stable")
        idx = idx[perm]
        vals = vals[perm]
        cuts = dlv_1d(vals, beta)
        p = int(cuts.sum()) + 1
        tries = 0
        while p == 1 and tries < 30:
            beta *= 0.25
            cuts = dlv_1d(vals, beta)
            p = int(cuts.sum()) + 1
            tries += 1
        if p == 1:
            continue  # unsplittable (all-equal values)
        order[s:e] = idx
        bpos = np.flatnonzero(cuts)
        bounds = vals[bpos]
        starts = np.concatenate([[0], bpos, [e - s]])
        node_id = len(nodes)
        # children temporarily tagged as _PID_TAG + pid; resolved below
        node = SplitNode(attr=j, bounds=bounds, children=[])
        nodes.append(node)
        if pid in child_slot:
            pn, slot = child_slot[pid]
            nodes[pn].children[slot] = node_id
        elif pid == pid_of_root:
            root = node_id
        del spans[pid]
        del var_cache[pid]
        for i in range(len(starts) - 1):
            cs, ce = s + int(starts[i]), s + int(starts[i + 1])
            cp = next_pid
            next_pid += 1
            spans[cp] = (cs, ce)
            var_cache[cp] = np.var(X[order[cs:ce]], axis=0) if ce - cs > 1 \
                else np.zeros(k)
            node.children.append(_PID_TAG + cp)
            child_slot[cp] = (node_id, i)
            push(cp)

    # compact group ids in slice order; resolve tagged leaf pids to ~gid
    pids = sorted(spans, key=lambda p: spans[p][0])
    offsets = np.empty(len(pids) + 1, np.int64)
    gid = np.empty(n, np.int64)
    reps = np.empty((len(pids), k))
    lo = np.empty((len(pids), k))
    hi = np.empty((len(pids), k))
    pid_to_gid = {}
    for g, pid in enumerate(pids):
        s, e = spans[pid]
        offsets[g] = s
        gid[order[s:e]] = g
        member_x = X[order[s:e]]
        reps[g] = member_x.mean(axis=0)
        lo[g] = member_x.min(axis=0)
        hi[g] = member_x.max(axis=0)
        pid_to_gid[pid] = g
    offsets[-1] = n
    for node in nodes:
        node.children = [
            ~pid_to_gid[ch - _PID_TAG] if ch >= _PID_TAG else ch
            for ch in node.children]
    if root == -1:
        # no split happened: single group
        return DLVResult(np.zeros(n, np.int64), order,
                         np.array([0, n]), X.mean(0, keepdims=True),
                         X.min(0, keepdims=True), X.max(0, keepdims=True),
                         [], -1)
    return DLVResult(gid, order, offsets, reps, lo, hi, nodes, root)
