"""Dynamic Low Variance partitioning — paper §3 (Algorithms 5, 6, 7).

1-D DLV is a running-variance reset scan over sorted attribute values
(Algorithm 5) — a jitted *segmented* ``lax.scan`` (tiny carry, O(n)) that
processes many partitions' concatenated spans in one launch, with
Kahan-compensated accumulators so the cut decisions stay identical to a
float64 host reference even when jax runs without x64 (the dtype is derived
from the input, never hard-coded).

DLV (Algorithm 6) is divisive hierarchical clustering keyed by *total
variance* (|P| * max_j var_j) with bounding variance beta = c_j sigma^2/d_f^2
(GetScaleFactors, Algorithm 7).  Two builds share the machinery:

* ``method="rounds"`` (default) — batched frontier rounds: every round
  selects ALL splittable partitions above the total-variance bar, runs ONE
  segmented sort (lexsort) + ONE segmented 1-D scan over their concatenated
  spans, and derives every child's per-attribute count/sum/sum-of-squares
  from a single ``segment_stats`` pass (Pallas kernel on TPU, ``bincount``
  twin on hosts) — no per-split ``argsort``/``np.var`` re-scans, no
  shape-polymorphic recompiles.
* ``method="heap"`` — the original one-pop-per-iteration reference build
  (kept as the quality/benchmark baseline).

Both produce :class:`repro.core.partitioner.Partition`: contiguous slices
of a permutation array (the paper's cache-friendly layout) plus the flat
array split tree for sub-linear GetGroup (the PostgreSQL GiST role,
Appendix D.2).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.partitioner import (Partition, SplitTree, finalize,
                                    register_backend)

# ------------------------------------------------------------- 1-D DLV


@jax.jit
def _dlv_scan_cols(V: jax.Array, beta: jax.Array) -> jax.Array:
    """Column-parallel Algorithm-5 scan: cuts[i, j] = True iff a delimiter
    is placed immediately before V[i, j] in segment (column) j.

    ``V`` is (rows, cols) with every column an independent segment, sorted
    ascending and centered on its own mean; ``beta`` is the per-column
    split bar.  One sequential pass over rows drives ALL columns at once
    (vectorized carry), which is what makes the batched-frontier build
    fast on CPU/TPU: a round with s segments of length L costs L steps,
    not s*L.  The running count/sum/sum-of-squares carry uses Kahan
    compensation and the computation dtype is derived from ``V`` — under
    no-x64 the f32 path keeps cut parity with the float64 host reference
    for mean-centered segment values.
    """
    zero = jnp.zeros((V.shape[1],), V.dtype)

    def step(carry, x):
        k, s1, c1, s2, c2 = carry
        k1 = k + 1.0
        x2 = x * x
        # compensated adds: s1 += x, s2 += x^2
        y1 = x - c1
        t1 = s1 + y1
        c1n = (t1 - s1) - y1
        y2 = x2 - c2
        t2 = s2 + y2
        c2n = (t2 - s2) - y2
        mean = t1 / k1
        var = t2 / k1 - mean * mean
        cut = (var > beta) & (k > 0)     # a segment's first row never cuts
        carry = (jnp.where(cut, 1.0, k1),
                 jnp.where(cut, x, t1), jnp.where(cut, zero, c1n),
                 jnp.where(cut, x2, t2), jnp.where(cut, zero, c2n))
        return carry, cut

    _, cuts = jax.lax.scan(step, (zero,) * 5, V, unroll=8)
    return cuts


def _dlv_scan_np(vals: np.ndarray, beta) -> np.ndarray:
    """float64 host reference of the scan over ONE segment (test oracle)."""
    v = np.asarray(vals, np.float64)
    n = len(v)
    beta = np.broadcast_to(np.asarray(beta, np.float64), (n,))
    cuts = np.zeros(n, bool)
    k = s1 = s2 = 0.0
    for i in range(n):
        x = v[i]
        k1, s1n, s2n = k + 1.0, s1 + x, s2 + x * x
        if s2n / k1 - (s1n / k1) ** 2 > beta[i] and k > 0:
            cuts[i] = True
            k, s1, s2 = 1.0, x, x * x
        else:
            k, s1, s2 = k1, s1n, s2n
    return cuts


def _scan_dtype():
    """The device scan dtype, derived from jax's current default float."""
    return jnp.result_type(float)


def _scan_cols_np(V: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Host twin of ``_dlv_scan_cols``: identical compensated arithmetic in
    float64, one numpy-vectorized row step per iteration.  Used for WIDE
    classes (many segments): no compile cost and the per-row python
    overhead amortizes across columns."""
    C, m = V.shape
    z = np.zeros(m)
    k, s1, c1, s2, c2 = z, z.copy(), z.copy(), z.copy(), z.copy()
    cuts = np.zeros((C, m), bool)
    for i in range(C):
        x = V[i]
        k1 = k + 1.0
        x2 = x * x
        y1 = x - c1
        t1 = s1 + y1
        c1n = (t1 - s1) - y1
        y2 = x2 - c2
        t2 = s2 + y2
        c2n = (t2 - s2) - y2
        var = t2 / k1 - (t1 / k1) ** 2
        cut = (var > B) & (k > 0)
        cuts[i] = cut
        k = np.where(cut, 1.0, k1)
        s1 = np.where(cut, x, t1)
        c1 = np.where(cut, 0.0, c1n)
        s2 = np.where(cut, x2, t2)
        c2 = np.where(cut, 0.0, c2n)
    return cuts


def _jump_scan_np(v: np.ndarray, beta: float) -> np.ndarray:
    """Exact Algorithm-5 scan of ONE long sorted (centered) segment via
    vectorized cut-to-cut jumps.

    The running stats reset at every delimiter, so from each cut the next
    one is found with a window-doubling lookahead: prefix count/sum/sumsq
    over the window give var(V u {x}) for every candidate position in one
    shot.  Cost is O(cuts) numpy calls + ~O(n) total vectorized work —
    the host path for long segments, where a sequential per-element scan
    is dispatch-bound.
    """
    n = len(v)
    cuts = np.zeros(n, bool)
    s = 0
    jump = 256                            # adapts to the observed cut pitch
    while s < n:
        W = max(64, 4 * jump)
        found = -1
        while True:
            e = min(s + W, n)
            w = v[s:e]
            kk = np.arange(1.0, e - s + 1.0)
            S1 = np.cumsum(w)
            S2 = np.cumsum(w * w)
            var = S2 / kk - (S1 / kk) ** 2
            hit = var > beta
            hit[0] = False                # a run's first element never cuts
            nz = np.flatnonzero(hit)
            if len(nz):
                found = s + int(nz[0])
                break
            if e >= n:
                break
            W *= 4
        if found < 0:
            break
        cuts[found] = True
        jump = max(found - s, 1)
        s = found
    return cuts


def _pad_rows(n: int, lo: int = 1024) -> int:
    """Pow2 length classes: bounded scan-shape set (and jit cache)."""
    return max(lo, 1 << int(n - 1).bit_length()) if n > 1 else lo


def _device_scanner(rows: int):
    """A ``_batch_cols`` scanner running the jitted Kahan column scan with
    rows padded to the pow2 class size and columns to pow2 (bounded jit
    shape set for the TPU path)."""
    def scan(Vr: np.ndarray, B: np.ndarray) -> np.ndarray:
        dt = _scan_dtype()
        cols = Vr.shape[1]
        m = 1 << int(cols - 1).bit_length() if cols > 1 else 1
        V = np.zeros((rows, m))
        V[:Vr.shape[0], :cols] = Vr
        Bp = np.full(m, np.inf)
        Bp[:cols] = B
        out = np.asarray(_dlv_scan_cols(jnp.asarray(V, dt),
                                        jnp.asarray(Bp, dt)))
        return out[:, :cols]
    return scan


_COL_BUDGET = 1 << 23        # max padded elements per scan launch
_BATCH_MIN_COLS = 16         # below this, per-segment jump scan wins
_MAX_COLS = 1024             # numpy row-step width sweet spot


def _batch_cols(cuts, vals_shifted, starts, Ls, beta_seg, sub,
                scanner=None) -> None:
    """Scan segments ``sub`` as columns of one (Lmax, cols) matrix; padding
    rows repeat each segment's last value (harmless — outputs beyond a
    segment's length are discarded).  ``scanner(V, B) -> (rows, cols)``
    defaults to the numpy row-step twin; the TPU path passes a jitted
    scanner that handles its own shape padding."""
    ridx = np.arange(int(Ls[sub].max()))[:, None]
    gather = starts[sub][None, :] + np.minimum(ridx, Ls[sub][None, :] - 1)
    out = (scanner or _scan_cols_np)(vals_shifted[gather], beta_seg[sub])
    valid = ridx < Ls[sub][None, :]
    cuts[(starts[sub][None, :] + ridx)[valid]] = \
        out[:ridx.shape[0]][valid]


def _snap_cuts_to_run_starts(vals: np.ndarray, cuts: np.ndarray,
                             seg_starts: np.ndarray) -> np.ndarray:
    """Move each cut to the first element of its equal-value run (dropping
    cuts whose run begins a segment).

    The scan may place a delimiter mid-run of equal values (adding a
    duplicate CAN raise the running variance), but a split boundary inside
    a run makes the split tree inconsistent with the stored gids: descent
    routes a value equal to the bound entirely to the right child while
    tied members sit left.  Snapping the cut to the run start keeps every
    tied tuple on the right of its boundary — GetGroup == gid even on
    duplicate-heavy data.  At most one cut per run exists (after a cut the
    remaining duplicates have zero variance), so snaps never collide.
    """
    n = len(vals)
    if not n or not cuts.any():
        return cuts
    change = np.empty(n, bool)
    change[0] = True
    change[1:] = vals[1:] != vals[:-1]
    change[seg_starts] = True
    run_start = np.maximum.accumulate(np.where(change, np.arange(n), -1))
    pos = np.flatnonzero(cuts)
    tgt = run_start[pos]
    if np.array_equal(tgt, pos):
        return cuts
    out = np.zeros(n, bool)
    is_seg_start = np.zeros(n, bool)
    is_seg_start[seg_starts] = True
    out[tgt[~is_seg_start[tgt]]] = True
    return out


def _seg_cuts(vals_shifted: np.ndarray, Ls: np.ndarray,
              beta_seg: np.ndarray, *, pitch: int = 256) -> np.ndarray:
    """Delimiters for many independent sorted segments, concatenated in
    ``vals_shifted`` with lengths ``Ls`` (each centered on its own mean).

    Host path: segments grouped by sorted length (<= 2x padding, no jit so
    shapes are free); a group runs as ONE column-parallel row-step scan
    when wide enough, otherwise each segment uses the exact vectorized
    jump scan — a 10^7-row round-1 segment costs ~one vectorized pass, not
    10^7 sequential steps.  ``pitch`` is the expected inter-cut distance
    (~d_f): the cost model — row scan ~ rows, jump scan ~ cols*rows/pitch
    — picks the cheaper form per group.  TPU path: pow2 length classes
    (bounded jit shapes) through the jitted Kahan column scan.  All paths
    end with cuts snapped to equal-value run starts (split-tree/gid
    consistency on ties).
    """
    n = len(vals_shifted)
    Ls = np.asarray(Ls, np.int64)
    cuts = np.zeros(n, bool)
    if n == 0 or not len(Ls):
        return cuts
    starts = np.concatenate([[0], np.cumsum(Ls)[:-1]])
    beta_seg = np.asarray(beta_seg, np.float64)
    from repro.kernels.ops import on_tpu
    if on_tpu():
        classes = np.fromiter((_pad_rows(int(l)) for l in Ls), np.int64,
                              len(Ls))
        for C in np.unique(classes):
            segs = np.flatnonzero(classes == C)
            max_cols = max(1, _COL_BUDGET // int(C))
            for a in range(0, len(segs), max_cols):
                sub = segs[a:a + max_cols]
                _batch_cols(cuts, vals_shifted, starts, Ls, beta_seg, sub,
                            scanner=_device_scanner(int(C)))
        return _snap_cuts_to_run_starts(vals_shifted, cuts, starts)

    ord_len = np.argsort(Ls, kind="stable")
    i = 0
    while i < len(ord_len):
        L0 = int(Ls[ord_len[i]])
        j = i + 1
        while (j < len(ord_len) and j - i < _MAX_COLS
               and Ls[ord_len[j]] <= max(2 * L0, L0 + 64)):
            j += 1
        group = ord_len[i:j]
        i = j
        cols = len(group)
        # jump cost ~ cols*rows/pitch window ops; row scan ~ rows steps
        if cols < _BATCH_MIN_COLS or cols < max(1, pitch) // 2:
            for s in group:
                a = starts[s]
                cuts[a:a + Ls[s]] = _jump_scan_np(
                    vals_shifted[a:a + Ls[s]], float(beta_seg[s]))
        else:
            _batch_cols(cuts, vals_shifted, starts, Ls, beta_seg, group)
    return _snap_cuts_to_run_starts(vals_shifted, cuts, starts)


def dlv_1d(values: np.ndarray, beta: float) -> np.ndarray:
    """Delimiter positions for sorted ``values``; returns cut flags (n,)."""
    v = np.asarray(values, np.float64)
    n = len(v)
    if n == 0:
        return np.zeros(0, bool)
    shift = v.mean()         # center: keeps the low-precision path accurate
    return _seg_cuts(v - shift, np.array([n]), np.array([float(beta)]))


# The SEED scan, kept verbatim as the benchmark baseline: jitted without
# padding, so every distinct span length triggers a fresh XLA compile —
# the cost profile the batched-frontier build eliminates.  (Only the
# float64-literal footgun is fixed: dtype derives from the input.)
@jax.jit
def _dlv_scan_seed(vals: jax.Array, beta: jax.Array) -> jax.Array:
    def step(carry, x):
        k, s1, s2 = carry
        k1 = k + 1.0
        s1n, s2n = s1 + x, s2 + x * x
        var = s2n / k1 - (s1n / k1) ** 2
        cut = var > beta
        return ((jnp.where(cut, 1.0, k1), jnp.where(cut, x, s1n),
                 jnp.where(cut, x * x, s2n)), cut)
    zero = jnp.zeros((), vals.dtype)
    _, cuts = jax.lax.scan(step, (zero, zero, zero), vals)
    return cuts


def dlv_1d_seed(values: np.ndarray, beta: float) -> np.ndarray:
    """The seed build's per-span scan (shape-polymorphic jit)."""
    v = np.asarray(values, np.float64)
    if not len(v):
        return np.zeros(0, bool)
    shift = v.mean()
    dt = _scan_dtype()
    cuts = np.array(_dlv_scan_seed(jnp.asarray(v - shift, dt),
                                   jnp.asarray(beta, dt)))
    cuts[0] = False
    return cuts


def dlv_1d_partition(values: np.ndarray, beta: float):
    """(group_id per element, boundary values d_1..d_{p-1}) for sorted input."""
    cuts = dlv_1d(values, beta)
    gid = np.cumsum(cuts)
    bounds = values[np.flatnonzero(cuts)]
    return gid, bounds


def ratio_score(values: np.ndarray, gid: np.ndarray, *,
                weighted: bool = False) -> float:
    """Definition 2: sum of per-partition variances / total variance.

    Single vectorised pass: per-group count/sum/sum-of-squares via
    ``np.bincount`` (O(n + G)).  Sparse / negative / non-integer ids are
    compacted with ONE ``np.unique`` call (the compacted ids feed bincount
    directly, no second pass).

    ``weighted=True`` weights each group's variance by its share of tuples
    (the within-group variance fraction, in [0, 1]) — the bounded quality
    metric the partitioning benchmarks track across attributes, where the
    paper's unweighted sum is only meaningful per split attribute."""
    values = np.asarray(values, np.float64)
    tot = float(np.var(values))
    if tot <= 0:
        return 0.0
    gid = np.asarray(gid)
    if gid.dtype.kind not in "iu" or (
            len(gid) and (gid.min() < 0 or gid.max() >= len(gid))):
        gid = np.unique(gid, return_inverse=True)[1]
    shift = values.mean()              # numerical stabilisation
    v = values - shift
    cnt = np.bincount(gid)
    s1 = np.bincount(gid, weights=v)
    s2 = np.bincount(gid, weights=v * v)
    nz = cnt > 0
    var_g = np.maximum(s2[nz] / cnt[nz] - (s1[nz] / cnt[nz]) ** 2, 0.0)
    if weighted:
        return float((var_g * cnt[nz]).sum() / len(values)) / tot
    return float(var_g.sum()) / tot


# ------------------------------------------------------ GetScaleFactors


def get_scale_factors(X: np.ndarray, d_f: int, *, sample: int = 10_000,
                      eps: float = 1e-9, max_steps: int = 60,
                      rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """Algorithm 7: per-attribute constants c_j with beta = c_j sigma^2/d_f^2.

    All attributes' binary searches advance in lock-step: each iteration
    runs ONE column-parallel scan over the (sample, k) sorted matrix with
    per-attribute betas, instead of k independent scan sequences.
    """
    rng = rng or np.random.default_rng(0)
    n, k = X.shape
    take = min(sample, n)
    idx = rng.choice(n, size=take, replace=False) if take < n else np.arange(n)
    V = np.sort(X[idx], axis=0)                  # per-column sorted sample
    Vc = V - V.mean(axis=0)
    var = V.var(axis=0)
    out = np.full(k, 13.5)                       # paper's default c
    searching = var > 0
    lo = np.zeros(k)
    hi = 0.25 * (V[-1] - V[0]) ** 2
    beta = hi.copy()
    target = max(2, min(d_f, take))
    vflat = Vc.T.reshape(-1)                     # k contiguous sorted segments
    Lk = np.full(k, take, np.int64)
    for _ in range(max_steps):
        run = searching & (hi - lo > eps * np.maximum(hi, 1.0))
        if not run.any():
            break
        beta = np.where(run, 0.5 * (lo + hi), beta)
        B = np.where(run, beta, np.inf)          # frozen columns never cut
        p = _seg_cuts(vflat, Lk, B).reshape(k, take).sum(axis=1) + 1
        searching &= ~(run & (p == target))      # converged exactly
        hi = np.where(run & (p < target), beta, hi)
        lo = np.where(run & (p > target), beta, lo)
    pos = var > 0
    out[pos] = beta[pos] * d_f * d_f / var[pos]
    return out


# ----------------------------------------------------- legacy split nodes


class SplitNode:
    """Pointer-tree node used only while the heap build runs; converted to
    the flat :class:`SplitTree` arrays at finalization."""

    __slots__ = ("attr", "bounds", "children")

    def __init__(self, attr: int, bounds: np.ndarray, children: List[int]):
        self.attr = attr
        self.bounds = bounds
        self.children = children


def _tree_from_nodes(nodes: List[SplitNode], root: int) -> SplitTree:
    if root < 0 or not nodes:
        return SplitTree.single_leaf()
    attr = np.fromiter((nd.attr for nd in nodes), np.int32, len(nodes))
    nb = np.fromiter((len(nd.bounds) for nd in nodes), np.int64, len(nodes))
    bound_off = np.concatenate([[0], np.cumsum(nb)])
    bounds = np.concatenate([nd.bounds for nd in nodes]) \
        if bound_off[-1] else np.zeros(0, np.float64)
    children = np.concatenate([np.asarray(nd.children, np.int64)
                               for nd in nodes])
    return SplitTree(attr, bound_off, np.asarray(bounds, np.float64),
                     children, root)


# -------------------------------------------------------- heap-based build


_PID_TAG = 1 << 40   # children >= _PID_TAG are unresolved leaf pids


def dlv_heap(X: np.ndarray, d_f: int, *, c: Optional[np.ndarray] = None,
             min_groups: Optional[int] = None,
             rng: Optional[np.random.Generator] = None,
             scan: str = "fast", mesh=None,
             chunk_rows: Optional[int] = None,
             time_budget_s: Optional[float] = None) -> Partition:
    """Algorithm 6, one heap pop (= one split) per iteration.

    The reference build the batched ``dlv_rounds`` is validated against;
    O(G) python iterations, each with its own span argsort + scan launch.
    ``scan="seed"`` restores the seed's shape-polymorphic jitted scan (one
    XLA compile per distinct span length — the benchmark baseline);
    ``time_budget_s`` raises TimeoutError mid-build when exceeded, so
    benchmarks can lower-bound the seed build without running it to the
    bitter end.
    """
    import time as _time
    t0 = _time.time()
    scan_1d = dlv_1d_seed if scan == "seed" else dlv_1d
    X = np.asarray(X, np.float64)
    n, k = X.shape
    target = min_groups if min_groups is not None else max(1, n // d_f)
    if c is None:
        c = get_scale_factors(X, d_f, rng=rng)

    order = np.arange(n)
    spans: Dict[int, Tuple[int, int]] = {0: (0, n)}
    var_cache: Dict[int, np.ndarray] = {0: np.var(X, axis=0)}
    next_pid = 1
    heap: List[Tuple[float, int]] = []

    def push(pid):
        s, e = spans[pid]
        tv = (e - s) * float(var_cache[pid].max())
        if e - s >= 2 and tv > 0:
            heapq.heappush(heap, (-tv, pid))

    push(0)
    nodes: List[SplitNode] = []
    child_slot: Dict[int, Tuple[int, int]] = {}   # pid -> (node_id, slot)
    root = -1

    while len(spans) < target and heap:
        if time_budget_s is not None and _time.time() - t0 > time_budget_s:
            raise TimeoutError(f"dlv_heap(scan={scan!r}) exceeded "
                               f"{time_budget_s}s at {len(spans)} groups")
        _, pid = heapq.heappop(heap)
        if pid not in spans:
            continue
        s, e = spans[pid]
        v = var_cache[pid]
        j = int(np.argmax(v))
        sigma2 = float(v[j])
        if sigma2 <= 0:
            continue
        beta = c[j] * sigma2 / (d_f * d_f)
        idx = order[s:e]
        vals = X[idx, j]
        perm = np.argsort(vals, kind="stable")
        idx = idx[perm]
        vals = vals[perm]
        cuts = scan_1d(vals, beta)
        p = int(cuts.sum()) + 1
        tries = 0
        while p == 1 and tries < 30:
            beta *= 0.25
            cuts = scan_1d(vals, beta)
            p = int(cuts.sum()) + 1
            tries += 1
        if p == 1:
            continue  # unsplittable (all-equal values)
        order[s:e] = idx
        bpos = np.flatnonzero(cuts)
        starts = np.concatenate([[0], bpos, [e - s]])
        node_id = len(nodes)
        node = SplitNode(j, vals[bpos], [])
        nodes.append(node)
        if pid in child_slot:
            pn, slot = child_slot[pid]
            nodes[pn].children[slot] = node_id
        elif root == -1:
            root = node_id
        del spans[pid]
        del var_cache[pid]
        for i in range(len(starts) - 1):
            cs, ce = s + int(starts[i]), s + int(starts[i + 1])
            cp = next_pid
            next_pid += 1
            spans[cp] = (cs, ce)
            var_cache[cp] = np.var(X[order[cs:ce]], axis=0) if ce - cs > 1 \
                else np.zeros(k)
            node.children.append(_PID_TAG + cp)
            child_slot[cp] = (node_id, i)
            push(cp)

    # compact group ids in slice order; resolve tagged leaf pids to ~gid
    pids = sorted(spans, key=lambda p: spans[p][0])
    offsets = np.fromiter((spans[p][0] for p in pids), np.int64, len(pids))
    offsets = np.concatenate([offsets, [n]])
    pid_to_gid = {p: g for g, p in enumerate(pids)}
    for node in nodes:
        node.children = [
            ~pid_to_gid[ch - _PID_TAG] if ch >= _PID_TAG else ch
            for ch in node.children]
    return finalize(X, order, offsets, _tree_from_nodes(nodes, root),
                    mesh=mesh, chunk_rows=chunk_rows)


# ----------------------------------------------- batched frontier rounds


def _segment_stats_auto(vals: np.ndarray, ids: np.ndarray, num_groups: int):
    """Child count/sum/sumsq in one pass: Pallas segstats kernel on TPU,
    ``np.bincount`` twin elsewhere (the kernel interprets on CPU, which
    would serialize the hot loop)."""
    from repro.kernels.ops import segment_stats_auto
    return segment_stats_auto(vals, ids, num_groups)


def dlv_rounds(X: np.ndarray, d_f: int, *, c: Optional[np.ndarray] = None,
               min_groups: Optional[int] = None,
               rng: Optional[np.random.Generator] = None,
               mesh=None, chunk_rows: Optional[int] = None,
               log: Optional[list] = None) -> Partition:
    """Algorithm 6 as batched frontier rounds (the tentpole build).

    Every round: (1) rank the frontier by total variance and select the
    splittable partitions above the bar (at most ``remaining/avg_children``
    of them, so the group count lands near the target exactly like the heap
    build's stop rule); (2) concatenate the selected spans and sort them
    with ONE ``np.lexsort`` keyed by (segment, value); (3) place all
    delimiters with ONE segmented scan launch; (4) obtain every child's
    per-attribute stats from ONE ``segment_stats`` pass.  ``log`` (optional
    list) receives one dict per round: groups so far, selected count, and
    new children — the build-time trajectory the partitioning benchmark
    records.
    """
    import time as _time
    t0 = _time.time()
    X = np.asarray(X, np.float64)
    n, k = X.shape
    target = min_groups if min_groups is not None else max(1, n // d_f)
    if c is None:
        c = get_scale_factors(X, d_f, rng=rng)
    gshift = X.mean(axis=0)

    order = np.arange(n)
    # frontier state (one row per live partition)
    S = np.zeros(1, np.int64)
    E = np.full(1, n, np.int64)
    Xc0 = X - gshift
    SU = Xc0.sum(axis=0, keepdims=True)            # (P, k) centered sums
    SQ = (Xc0 * Xc0).sum(axis=0, keepdims=True)    # (P, k) centered sumsqs
    frozen = np.zeros(1, bool)
    del Xc0
    pid = np.zeros(1, np.int64)                    # tree linkage handles
    next_pid = 1

    nodes: List[SplitNode] = []
    child_slot: Dict[int, Tuple[int, int]] = {}
    root = -1
    avg_children = float(max(2, min(d_f, n)))      # round-1 estimate

    while len(S) < target:
        cnt = (E - S).astype(np.float64)
        var = np.maximum(SQ / cnt[:, None] - (SU / cnt[:, None]) ** 2, 0.0)
        vmax = var.max(axis=1)
        jbest = var.argmax(axis=1)
        tv = cnt * vmax
        cand = np.flatnonzero((cnt >= 2) & (tv > 0) & ~frozen)
        if not len(cand):
            break
        remaining = target - len(S)
        take = max(1, int(np.ceil(remaining / max(avg_children - 1.0, 1.0))))
        if len(cand) > take:
            # the total-variance bar: the take-th largest tv among candidates
            sel = cand[np.argpartition(-tv[cand], take - 1)[:take]]
        else:
            sel = cand
        nseg = len(sel)
        Ls = (E - S)[sel]
        total = int(Ls.sum())
        seg_off = np.concatenate([[0], np.cumsum(Ls)])
        segid = np.repeat(np.arange(nseg), Ls)
        base = np.repeat(S[sel] - seg_off[:-1], Ls)
        pos = base + np.arange(total)              # order slots, per segment
        idxc = order[pos]
        jel = np.repeat(jbest[sel], Ls)
        vals = X[idxc, jel]
        # segmented sort: per-span stable argsort into one permutation
        # (beats a 2-key lexsort ~10x — span slices are contiguous)
        perm = np.empty(total, np.int64)
        for si in range(nseg):
            a, b = seg_off[si], seg_off[si + 1]
            perm[a:b] = a + np.argsort(vals[a:b], kind="stable")
        idxs = idxc[perm]
        vals_s = vals[perm]

        # per-segment center (raw partition mean on the split attribute)
        mean_sel = SU[sel, jbest[sel]] / Ls + gshift[jbest[sel]]
        beta_sel = c[jbest[sel]] * vmax[sel] / (d_f * d_f)
        reset = np.zeros(total, bool)
        reset[seg_off[:-1]] = True
        vs = vals_s - np.repeat(mean_sel, Ls)
        cuts = _seg_cuts(vs, Ls, beta_sel, pitch=d_f)

        # segments that produced no delimiter retry with beta/4 (the heap
        # build's rule); all-equal segments can never split -> frozen
        ncuts = np.bincount(segid[cuts], minlength=nseg)
        alleq = vals_s[seg_off[1:] - 1] == vals_s[seg_off[:-1]]
        fail = np.flatnonzero((ncuts == 0) & ~alleq)
        tries = 0
        while len(fail) and tries < 30:
            beta_sel[fail] *= 0.25
            fmask = np.zeros(nseg, bool)
            fmask[fail] = True
            elm = fmask[segid]
            cuts[elm] = _seg_cuts(vs[elm], Ls[fail], beta_sel[fail],
                                  pitch=d_f)
            ncuts = np.bincount(segid[cuts], minlength=nseg)
            fail = np.flatnonzero((ncuts == 0) & ~alleq)
            tries += 1

        order[pos] = idxs                          # spans are now sorted
        split = np.flatnonzero(ncuts > 0)
        if not len(split):
            frozen[sel] = True
            continue
        frozen[sel[ncuts == 0]] = True
        # accept splits in total-variance order only until the target is
        # reached (the heap build's stop rule, applied batch-wise): the
        # rejected tail stays on the frontier un-split, so the final group
        # count matches the one-pop-at-a-time build's instead of
        # overshooting by a whole round
        split = split[np.argsort(-tv[sel[split]], kind="stable")]
        gain = np.cumsum(ncuts[split])             # children-1 per split
        need = target - len(S)
        split = split[:int(np.searchsorted(gain, need, side="left")) + 1]
        split.sort()

        # contiguous child ids across the concatenated array
        boundary = cuts | reset
        cid = np.cumsum(boundary) - 1
        n_children = int(cid[-1]) + 1
        ccnt = np.bincount(cid, minlength=n_children).astype(np.float64)
        child_start = pos[boundary]                # order slot of each child

        # tree nodes for the split partitions (python loop is O(#splits)
        # with list appends only — no numeric work)
        keep = np.ones(len(S), bool)
        new_rows = []                              # frontier child row ranges
        cstart_of_seg = np.searchsorted(np.flatnonzero(boundary),
                                        seg_off[:-1])
        for si in split:
            i = sel[si]
            keep[i] = False
            c0, c1 = cstart_of_seg[si], (cstart_of_seg[si + 1]
                                         if si + 1 < nseg else n_children)
            bvals = vals_s[seg_off[si]:seg_off[si + 1]][
                cuts[seg_off[si]:seg_off[si + 1]]]
            node_id = len(nodes)
            node = SplitNode(int(jbest[i]), bvals, [])
            nodes.append(node)
            p = int(pid[i])
            if p in child_slot:
                pn, slot = child_slot[p]
                nodes[pn].children[slot] = node_id
                del child_slot[p]
            elif root == -1:
                root = node_id
            for ci in range(c0, c1):
                cp = next_pid
                next_pid += 1
                node.children.append(_PID_TAG + cp)
                child_slot[cp] = (node_id, ci - c0)
            new_rows.append((c0, c1, next_pid - (c1 - c0)))

        # frontier update: drop split rows, append their children
        ch_sel = np.concatenate([np.arange(c0, c1) for c0, c1, _ in new_rows])
        ch_pid = np.concatenate([np.arange(p0, p0 + (c1 - c0))
                                 for c0, c1, p0 in new_rows])
        ch_cnt = ccnt[ch_sel].astype(np.int64)
        # children sums/sumsqs feed the NEXT round's selection; the final
        # round (``done`` — the loop breaks below on the same flag, so the
        # zero placeholders are provably never ranked) skips the pass and
        # lets finalize recompute exact reps
        done = int(keep.sum()) + len(ch_sel) >= target
        if done:
            csum = np.zeros((n_children, k))
            csq = np.zeros((n_children, k))
        else:
            _, csum, csq = _segment_stats_auto(X[idxs] - gshift, cid,
                                               n_children)
        ch_S = child_start[ch_sel]
        S = np.concatenate([S[keep], ch_S])
        E = np.concatenate([E[keep], ch_S + ch_cnt])
        SU = np.concatenate([SU[keep], csum[ch_sel]])
        SQ = np.concatenate([SQ[keep], csq[ch_sel]])
        frozen = np.concatenate([frozen[keep], ch_cnt <= 1])
        pid = np.concatenate([pid[keep], ch_pid])
        avg_children = len(ch_sel) / max(len(split), 1)
        if log is not None:
            log.append({"round": len(log), "groups": int(len(S)),
                        "selected": int(nseg), "split": int(len(split)),
                        "children": int(len(ch_sel)),
                        "t": _time.time() - t0})
        if done:
            break

    # finalize: groups in slice order, unresolved leaf pids -> ~gid
    gorder = np.argsort(S, kind="stable")
    offsets = np.concatenate([S[gorder], [n]])
    pid_to_gid = {int(pid[r]): g for g, r in enumerate(gorder)}
    for node in nodes:
        node.children = [
            ~pid_to_gid[ch - _PID_TAG] if ch >= _PID_TAG else ch
            for ch in node.children]
    return finalize(X, order, offsets, _tree_from_nodes(nodes, root),
                    mesh=mesh, chunk_rows=chunk_rows)


# ------------------------------------------------------------- entry point


@register_backend("dlv")
def dlv(X: np.ndarray, d_f: int = 100, *, c: Optional[np.ndarray] = None,
        min_groups: Optional[int] = None,
        rng: Optional[np.random.Generator] = None,
        method: str = "rounds", **kwargs) -> Partition:
    """Algorithm 6 over tuples X (n, k); produces ~n/d_f groups."""
    if method == "rounds":
        return dlv_rounds(X, d_f, c=c, min_groups=min_groups, rng=rng,
                          **kwargs)
    if method == "heap":
        # forward everything: unknown options raise instead of silently
        # configuring nothing
        return dlv_heap(X, d_f, c=c, min_groups=min_groups, rng=rng,
                        **kwargs)
    raise ValueError(f"unknown dlv method {method!r}")


# Back-compat: old callers imported DLVResult; a Partition is the same shape.
DLVResult = Partition
