"""Out-of-core DLV via the bucketing scheme — paper Appendix D.2.

For relations that do not fit in memory (the paper's 10^9-tuple regime):

  1. one streaming pass estimates per-attribute mean/variance and the range
     of the highest-variance attribute (Welford over chunks — the pass the
     ``kernels/segstats.py`` Pallas kernel accelerates on TPU);
  2. the range is split into equal-width buckets, recursively until every
     bucket holds at most ``r`` tuples (r = in-memory budget);
  3. Algorithm 6 (in-memory DLV, batched-frontier rounds) runs per bucket;
     group ids are offset into a global id space.

Buckets are disjoint half-open intervals on one attribute, so the merged
result is one unified :class:`repro.core.partitioner.Partition`: a root
split node holding the bucket edges whose children are the per-bucket split
trees — GetGroup (scalar or batch) descends root -> bucket subtree exactly
like any other backend's tree, and global group ids stay contiguous.

The relation is consumed through the ``ChunkSource`` protocol (anything
yielding (n_i, k) arrays); ``MemmapSource`` adapts an on-disk .npy memmap —
the container-scale stand-in for the paper's PostgreSQL heap scans.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.core.partitioner import (Partition, SplitTree, register_backend)


class ChunkSource:
    """Minimal streaming-relation protocol."""

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        raise NotImplementedError

    @property
    def num_rows(self) -> int:
        raise NotImplementedError

    @property
    def num_cols(self) -> int:
        raise NotImplementedError

    def gather(self, mask_fn, chunk_rows: int) -> np.ndarray:
        """Materialise the rows where mask_fn(chunk) is True (bucket load)."""
        parts = [c[mask_fn(c)] for c in self.chunks(chunk_rows)]
        return np.concatenate(parts, axis=0) if parts else \
            np.zeros((0, self.num_cols))


class ArraySource(ChunkSource):
    def __init__(self, X: np.ndarray):
        self.X = X

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        for i in range(0, len(self.X), chunk_rows):
            yield np.asarray(self.X[i:i + chunk_rows], np.float64)

    @property
    def num_rows(self) -> int:
        return self.X.shape[0]

    @property
    def num_cols(self) -> int:
        return self.X.shape[1]


class MemmapSource(ArraySource):
    """On-disk relation (np.memmap) — rows stream through a fixed budget."""

    def __init__(self, path: str, shape, dtype=np.float64):
        self.X = np.lib.format.open_memmap(path, mode="r")
        assert self.X.shape == tuple(shape), (self.X.shape, shape)


@dataclasses.dataclass
class StreamStats:
    count: int
    mean: np.ndarray
    var: np.ndarray
    lo: np.ndarray
    hi: np.ndarray


def streaming_stats(src: ChunkSource, chunk_rows: int) -> StreamStats:
    """One pass: per-attribute mean/var (Chan's parallel Welford) + range."""
    count = 0
    mean = np.zeros(src.num_cols)
    m2 = np.zeros(src.num_cols)
    lo = np.full(src.num_cols, np.inf)
    hi = np.full(src.num_cols, -np.inf)
    for c in src.chunks(chunk_rows):
        nb = len(c)
        if nb == 0:
            continue
        mb = c.mean(axis=0)
        m2b = ((c - mb) ** 2).sum(axis=0)
        delta = mb - mean
        tot = count + nb
        mean = mean + delta * (nb / tot)
        m2 = m2 + m2b + delta ** 2 * (count * nb / tot)
        count = tot
        lo = np.minimum(lo, c.min(axis=0))
        hi = np.maximum(hi, c.max(axis=0))
    var = m2 / max(count, 1)
    return StreamStats(count, mean, var, lo, hi)


def _bucket_edges(src: ChunkSource, attr: int, lo: float, hi: float,
                  r: int, chunk_rows: int, max_depth: int = 8) -> np.ndarray:
    """Equal-width edges refined until every bucket holds <= r rows."""
    edges = [lo, np.nextafter(hi, np.inf)]
    for _ in range(max_depth):
        e = np.asarray(edges)
        counts = np.zeros(len(e) - 1, np.int64)
        for c in src.chunks(chunk_rows):
            idx = np.clip(np.searchsorted(e, c[:, attr], side="right") - 1,
                          0, len(counts) - 1)
            counts += np.bincount(idx, minlength=len(counts))
        if counts.max() <= r:
            return e
        new_edges = [e[0]]
        for i, n in enumerate(counts):
            if n > r:
                splits = int(np.ceil(n / r))
                new_edges.extend(np.linspace(e[i], e[i + 1],
                                             splits + 1)[1:].tolist())
            else:
                new_edges.append(e[i + 1])
        edges = new_edges
    return np.asarray(edges)


def _merge_bucket_trees(attr: int, edges: np.ndarray,
                        parts: List[Optional[Partition]],
                        group_offset: np.ndarray,
                        num_groups: int) -> SplitTree:
    """One unified flat tree: a root node on the bucket attribute whose
    children are the per-bucket subtrees (node ids and leaf gids offset
    into the global spaces)."""
    nb = len(parts)
    attrs = [np.asarray([attr], np.int32)]
    bound_off_len = [np.asarray([len(edges) - 2], np.int64)]
    bounds = [np.asarray(edges[1:-1], np.float64)]
    root_children = np.empty(nb, np.int64)
    sub_attrs, sub_lens, sub_bounds, sub_children = [], [], [], []
    node_base = 1
    for b, part in enumerate(parts):
        goff = int(group_offset[b])
        if part is None:
            # empty bucket: probes fall through to the next group base
            root_children[b] = ~min(goff, num_groups - 1)
            continue
        t = part.tree
        if t.num_nodes == 0:
            root_children[b] = ~goff
            continue
        root_children[b] = node_base + t.root
        sub_attrs.append(t.attr)
        sub_lens.append(np.diff(t.bound_off))
        sub_bounds.append(t.bounds)
        ch = t.children.copy()
        leaf = ch < 0
        ch[leaf] = ~(~ch[leaf] + goff)
        ch[~leaf] += node_base
        sub_children.append(ch)
        node_base += t.num_nodes
    attrs = np.concatenate(attrs + sub_attrs).astype(np.int32)
    lens = np.concatenate(bound_off_len + sub_lens)
    bound_off = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    all_bounds = np.concatenate(bounds + sub_bounds)
    children = np.concatenate([root_children] + sub_children) \
        if sub_children else root_children
    return SplitTree(attrs, bound_off, all_bounds,
                     children.astype(np.int64), 0)


def dlv_bucketed(src: ChunkSource, d_f: int, *, memory_rows: int,
                 chunk_rows: Optional[int] = None,
                 rng: Optional[np.random.Generator] = None,
                 method: str = "rounds") -> Partition:
    """Appendix D.2: bucket on the max-variance attribute, DLV per bucket."""
    from repro.core.dlv import dlv

    rng = rng or np.random.default_rng(0)
    chunk_rows = chunk_rows or max(memory_rows // 4, 1024)
    stats = streaming_stats(src, chunk_rows)
    attr = int(np.argmax(stats.var))
    edges = _bucket_edges(src, attr, stats.lo[attr], stats.hi[attr],
                          memory_rows, chunk_rows)
    nb = len(edges) - 1
    n = src.num_rows
    k = src.num_cols

    # row positions per bucket (second pass, streamed)
    row_base = 0
    bucket_rows: List[List[np.ndarray]] = [[] for _ in range(nb)]
    for c in src.chunks(chunk_rows):
        idx = np.clip(np.searchsorted(edges, c[:, attr], side="right") - 1,
                      0, nb - 1)
        for b in range(nb):
            sel = np.flatnonzero(idx == b)
            if len(sel):
                bucket_rows[b].append(sel + row_base)
        row_base += len(c)

    parts: List[Optional[Partition]] = []
    group_offset = np.zeros(nb, np.int64)
    gid = np.full(n, -1, np.int64)
    order_all, reps_all, lo_all, hi_all = [], [], [], []
    next_gid = 0
    for b in range(nb):
        rows = (np.concatenate(bucket_rows[b]) if bucket_rows[b]
                else np.zeros(0, np.int64))
        group_offset[b] = next_gid
        if len(rows) == 0:
            parts.append(None)
            continue
        lo_e, hi_e = edges[b], edges[b + 1]
        Xb = src.gather(lambda ch: (ch[:, attr] >= lo_e)
                        & (ch[:, attr] < hi_e), chunk_rows)
        # equal-width refinement can fail to isolate point masses /
        # duplicate-heavy clusters within max_depth; the budget is then
        # soft — degrade to a larger in-memory bucket instead of dying
        if len(Xb) > max(memory_rows, 1):
            import warnings
            warnings.warn(f"bucket {b} holds {len(Xb)} rows "
                          f"(> memory_rows={memory_rows}); edge refinement "
                          "could not isolate a concentration — running "
                          "in-memory DLV on the oversized bucket")
        res = dlv(Xb, d_f, rng=rng, method=method)
        parts.append(res)
        gid[rows] = next_gid + res.gid
        order_all.append(rows[res.order])
        reps_all.append(res.reps)
        lo_all.append(res.boxes_lo)
        hi_all.append(res.boxes_hi)
        next_gid += res.num_groups

    # global contiguous layout: buckets in edge order, groups within bucket
    order = np.concatenate(order_all) if order_all else np.zeros(0, np.int64)
    off = [0]
    for part in parts:
        if part is not None:
            off.extend((np.asarray(part.offsets[1:]) + off[-1]).tolist())
    offsets = np.asarray(off, np.int64)
    reps = np.concatenate(reps_all) if reps_all else np.zeros((0, k))
    boxes_lo = np.concatenate(lo_all) if lo_all else np.zeros((0, k))
    boxes_hi = np.concatenate(hi_all) if hi_all else np.zeros((0, k))
    tree = _merge_bucket_trees(attr, edges, parts, group_offset,
                               max(next_gid, 1))
    return Partition(gid, order, offsets, reps, boxes_lo, boxes_hi, tree)


@register_backend("bucketing")
def _bucketing_backend(X, *, d_f: int = 100, memory_rows: int = None,
                       chunk_rows: Optional[int] = None,
                       rng: Optional[np.random.Generator] = None,
                       method: str = "rounds", mesh=None) -> Partition:
    """Partitioner backend: accepts an array (wrapped in ArraySource) or
    any ChunkSource.  ``chunk_rows`` sets the streaming chunk size; mesh-
    sharded per-bucket stats are a ROADMAP item — raise rather than
    silently ignore."""
    if mesh is not None:
        raise TypeError("bucketing backend does not shard per-bucket stats "
                        "over a mesh yet (see ROADMAP 'Out-of-core layer "
                        "0'); use backend='dlv' for the mesh path")
    src = X if isinstance(X, ChunkSource) else ArraySource(np.asarray(X))
    if memory_rows is None:
        memory_rows = max(src.num_rows // 8, 4096)
    return dlv_bucketed(src, d_f, memory_rows=memory_rows,
                        chunk_rows=chunk_rows, rng=rng, method=method)


# Back-compat: the merged result is a plain Partition now.
BucketedDLV = Partition
