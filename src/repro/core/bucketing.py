"""Out-of-core DLV via the bucketing scheme — paper Appendix D.2.

For relations that do not fit in memory (the paper's 10^9-tuple regime):

  1. one streaming pass estimates per-attribute mean/variance and the range
     of the highest-variance attribute (Welford over chunks; with a
     ``mesh`` each chunk's moments are computed sharded over the mesh's
     leading axis with psum reduction — the same pattern
     ``partitioner.group_stats`` uses for group stats);
  2. the range is split into equal-width buckets, recursively until every
     bucket holds at most ``r`` tuples (r = in-memory budget) — each
     refinement is one counting pass, the refinement depth is bounded, and
     degenerate ranges (constant attribute, point masses) collapse to the
     oversized-bucket warning path instead of emitting phantom buckets;
  3. ONE further streaming pass spills every row into its bucket's scratch
     slice — a bucket-major (n, k) scratch plus an (n,) global-row-id
     array, memmap-backed above ``spill_rows`` — so the total build I/O is
     O(1) full passes *independent of the bucket count* (the seed did one
     full rescan per bucket = O(n_buckets * n) reads);
  4. Algorithm 6 (in-memory DLV, batched-frontier rounds) runs per bucket
     on its contiguous scratch slice; group ids are offset into a global
     id space.

Buckets are disjoint half-open intervals on one attribute, so the merged
result is one unified :class:`repro.core.partitioner.Partition`: a root
split node holding the bucket edges whose children are the per-bucket split
trees — GetGroup (scalar or batch) descends root -> bucket subtree exactly
like any other backend's tree, and global group ids stay contiguous.

The relation is consumed through the ``ChunkSource`` protocol (anything
yielding (n_i, k) arrays); ``MemmapSource`` adapts an on-disk .npy memmap
(or, via :meth:`MemmapSource.from_raw`, a headerless binary file) — the
container-scale stand-in for the paper's PostgreSQL heap scans.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
import warnings
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.core.partitioner import (Partition, SplitTree, register_backend)


class ChunkSource:
    """Minimal streaming-relation protocol."""

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        raise NotImplementedError

    @property
    def num_rows(self) -> int:
        raise NotImplementedError

    @property
    def num_cols(self) -> int:
        raise NotImplementedError

    def gather(self, mask_fn, chunk_rows: int) -> np.ndarray:
        """Materialise the rows where mask_fn(chunk) is True (one pass)."""
        parts = [c[mask_fn(c)] for c in self.chunks(chunk_rows)]
        return np.concatenate(parts, axis=0) if parts else \
            np.zeros((0, self.num_cols))


class ArraySource(ChunkSource):
    def __init__(self, X: np.ndarray):
        self.X = X

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        for i in range(0, len(self.X), chunk_rows):
            yield np.asarray(self.X[i:i + chunk_rows], np.float64)

    @property
    def num_rows(self) -> int:
        return self.X.shape[0]

    @property
    def num_cols(self) -> int:
        return self.X.shape[1]


class MemmapSource(ArraySource):
    """On-disk relation (np.memmap) — rows stream through a fixed budget.

    Chunk reads touch disk, so they run through the transient-read retry
    of ``core.relation`` (capped exponential backoff) and poll the
    ``CHUNK_READ`` fault-injection site."""

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        from repro.core.relation import _retry_io  # late: avoids a cycle
        from repro.runtime import faults
        for i in range(0, len(self.X), chunk_rows):

            def _read(i=i):
                faults.maybe_raise(faults.CHUNK_READ)
                return np.asarray(self.X[i:i + chunk_rows], np.float64)

            yield _retry_io(_read, f"memmap chunk [{i}:{i + chunk_rows})")

    def __init__(self, path: str, shape=None, dtype=None):
        self.X = np.lib.format.open_memmap(path, mode="r")
        if shape is not None and self.X.shape != tuple(shape):
            raise ValueError(f"{path}: stored shape {self.X.shape} != "
                             f"expected {tuple(shape)}")
        if dtype is not None and self.X.dtype != np.dtype(dtype):
            raise ValueError(f"{path}: stored dtype {self.X.dtype} != "
                             f"expected {np.dtype(dtype)}")

    @classmethod
    def from_raw(cls, path: str, shape, dtype=np.float64,
                 offset: int = 0) -> "MemmapSource":
        """Headerless row-major binary file (no .npy header)."""
        src = cls.__new__(cls)
        src.X = np.memmap(path, dtype=np.dtype(dtype), mode="r",
                          offset=offset, shape=tuple(shape))
        return src


@dataclasses.dataclass
class StreamStats:
    count: int
    mean: np.ndarray
    var: np.ndarray
    lo: np.ndarray
    hi: np.ndarray


# ----------------------------------------------------- mesh-sharded passes


def _mesh_pad(mesh, chunk: np.ndarray) -> np.ndarray:
    """Pad a chunk with NaN rows to a multiple of the mesh's leading axis
    (NaN rows are masked out inside the sharded reductions)."""
    nd = int(mesh.shape[mesh.axis_names[0]])
    rows = ((len(chunk) + nd - 1) // nd) * nd
    if rows == len(chunk):
        return chunk
    return np.pad(chunk, ((0, rows - len(chunk)), (0, 0)),
                  constant_values=np.nan)


def _mesh_moments_jit(mesh, k: int):
    """Sharded per-chunk (count, shifted sum, shifted sumsq, min, max):
    rows split over the mesh's leading axis, per-device partials
    psum-reduced — the streaming-stats twin of
    ``partitioner._chunk_stats_jit``.  ``shift`` (a per-column anchor, the
    relation's first row) centers the accumulators so the raw-moment
    variance ``q - n*mb^2`` never cancels catastrophically on
    large-mean/small-spread data (the PR 3 ``gshift`` trick)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import shard_map

    axis = mesh.axis_names[0]

    def local(v, shift):
        bad = jnp.isnan(v)
        cnt = jnp.sum(~bad[:, 0])
        vz = jnp.where(bad, 0.0, v - shift[None, :])
        s = vz.sum(axis=0)
        q = (vz * vz).sum(axis=0)
        mn = jnp.where(bad, jnp.inf, v).min(axis=0)
        mx = jnp.where(bad, -jnp.inf, v).max(axis=0)
        return (jax.lax.psum(cnt, axis), jax.lax.psum(s, axis),
                jax.lax.psum(q, axis), jax.lax.pmin(mn, axis),
                jax.lax.pmax(mx, axis))

    fn = jax.jit(shard_map(local, mesh=mesh,
                           in_specs=(P(axis, None), P(None)),
                           out_specs=(P(), P(None), P(None), P(None),
                                      P(None))))
    vsh = NamedSharding(mesh, P(axis, None))

    def run(chunk: np.ndarray, shift: np.ndarray):
        import jax as _jax
        cp = _mesh_pad(mesh, chunk)
        cnt, s, q, mn, mx = fn(_jax.device_put(jnp.asarray(cp), vsh),
                               jnp.asarray(shift))
        return (int(cnt), np.asarray(s), np.asarray(q), np.asarray(mn),
                np.asarray(mx))

    return run


def _mesh_bincount_jit(mesh, nbins: int):
    """Sharded per-chunk bucket histogram for one attribute column against
    fixed edges (NaN pad rows fall in the dead padding bin)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import shard_map

    axis = mesh.axis_names[0]

    def local(v, edges):
        bad = jnp.isnan(v)
        ids = jnp.clip(jnp.searchsorted(edges, jnp.where(bad, edges[0], v),
                                        side="right") - 1, 0, nbins - 1)
        cnt = jnp.zeros(nbins, jnp.int64).at[ids].add(
            jnp.where(bad, 0, 1).astype(jnp.int64))
        return jax.lax.psum(cnt, axis)

    fn = jax.jit(shard_map(local, mesh=mesh, in_specs=(P(axis), P(None)),
                           out_specs=P(None)))
    vsh = NamedSharding(mesh, P(axis))

    def run(col: np.ndarray, edges: np.ndarray):
        import jax as _jax
        nd = int(mesh.shape[mesh.axis_names[0]])
        rows = ((len(col) + nd - 1) // nd) * nd
        cp = np.pad(col, (0, rows - len(col)), constant_values=np.nan)
        return np.asarray(fn(_jax.device_put(jnp.asarray(cp), vsh),
                             jnp.asarray(edges)))

    return run


def streaming_stats(src: ChunkSource, chunk_rows: int,
                    mesh=None) -> StreamStats:
    """One pass: per-attribute mean/var (Chan's parallel Welford) + range.

    With ``mesh``, each chunk's (count, sum, sumsq, min, max) runs sharded
    over the mesh's leading axis (shard_map + psum); the cross-chunk Chan
    merge stays host-side on (k,) accumulators.
    """
    count = 0
    mean = np.zeros(src.num_cols)
    m2 = np.zeros(src.num_cols)
    lo = np.full(src.num_cols, np.inf)
    hi = np.full(src.num_cols, -np.inf)
    moments = _mesh_moments_jit(mesh, src.num_cols) if mesh is not None \
        else None
    shift = None
    for c in src.chunks(chunk_rows):
        nb = len(c)
        if nb == 0:
            continue
        if moments is not None:
            if shift is None:
                shift = np.asarray(c[0], np.float64)  # per-column anchor
            nb, s, q, cl, ch = moments(c, shift)
            mbs = s / nb                       # mean of (v - shift)
            m2b = np.maximum(q - nb * mbs * mbs, 0.0)
            mb = shift + mbs
        else:
            mb = c.mean(axis=0)
            m2b = ((c - mb) ** 2).sum(axis=0)
            cl = c.min(axis=0)
            ch = c.max(axis=0)
        delta = mb - mean
        tot = count + nb
        mean = mean + delta * (nb / tot)
        m2 = m2 + m2b + delta ** 2 * (count * nb / tot)
        count = tot
        lo = np.minimum(lo, cl)
        hi = np.maximum(hi, ch)
    var = np.maximum(m2, 0.0) / max(count, 1)
    return StreamStats(count, mean, var, lo, hi)


# -------------------------------------------------------------- bucket edges


def _count_buckets(src: ChunkSource, attr: int, e: np.ndarray,
                   chunk_rows: int, mesh=None) -> np.ndarray:
    counts = np.zeros(len(e) - 1, np.int64)
    counter = _mesh_bincount_jit(mesh, len(counts)) if mesh is not None \
        else None
    for c in src.chunks(chunk_rows):
        if not len(c):
            continue
        if counter is not None:
            counts += counter(np.asarray(c[:, attr], np.float64), e)
        else:
            idx = np.clip(np.searchsorted(e, c[:, attr], side="right") - 1,
                          0, len(counts) - 1)
            counts += np.bincount(idx, minlength=len(counts))
    return counts


def _bucket_edges(src: ChunkSource, attr: int, lo: float, hi: float,
                  r: int, chunk_rows: int, max_depth: int = 8,
                  mesh=None) -> Tuple[np.ndarray, np.ndarray]:
    """Equal-width edges refined until every bucket holds <= r rows.

    Returns ``(edges, counts)`` with counts exact for the returned edges.
    Degenerate ranges are guarded: a constant attribute (lo == hi) yields
    one bucket, and refinement of a point mass (``np.linspace`` emitting
    duplicate / zero-width edges) is deduped — when an overfull bucket can
    no longer be narrowed the loop stops and the caller's oversized-bucket
    warning path degrades gracefully instead of producing empty phantom
    buckets.
    """
    if not (np.isfinite(lo) and np.isfinite(hi)) or hi <= lo:
        # constant (or empty/degenerate) attribute: a single bucket
        edges = np.asarray([lo, np.nextafter(max(lo, hi), np.inf)])
        counts = np.asarray([src.num_rows], np.int64)
        return edges, counts
    edges = np.asarray([lo, np.nextafter(hi, np.inf)])
    counts = None
    for _ in range(max_depth):
        counts = _count_buckets(src, attr, edges, chunk_rows, mesh=mesh)
        if counts.max() <= r:
            return edges, counts
        new_edges = [edges[0]]
        for i, n in enumerate(counts):
            if n > r:
                splits = int(np.ceil(n / r))
                new_edges.extend(np.linspace(edges[i], edges[i + 1],
                                             splits + 1)[1:].tolist())
            else:
                new_edges.append(edges[i + 1])
        refined = np.unique(np.asarray(new_edges))   # dedupe zero-width
        if len(refined) == len(edges):
            break        # point mass: no new edge survived — stop refining
        edges = refined
        counts = None
    if counts is None:
        counts = _count_buckets(src, attr, edges, chunk_rows, mesh=mesh)
    return edges, counts


# -------------------------------------------------------------- spill pass


class BucketSpill:
    """Bucket-major scratch for the single spill pass.

    Values land in one (n, k) scratch matrix laid out bucket-by-bucket
    (bucket b owns ``[off[b], off[b+1])``) with the matching (n,) global
    row ids; both become ``.npy`` memmaps in a private temp dir when the
    relation exceeds ``budget_rows`` — per-bucket loads then read one
    contiguous slice each, so the whole build does O(1) streaming passes.
    """

    def __init__(self, counts: np.ndarray, k: int, budget_rows: int,
                 spill_dir: Optional[str] = None):
        self.off = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        n = int(self.off[-1])
        self._cursor = self.off[:-1].copy()
        self._tmp = None
        if n > budget_rows:
            self._tmp = tempfile.mkdtemp(prefix="pq_spill_",
                                         dir=spill_dir)
            self.vals = np.lib.format.open_memmap(
                os.path.join(self._tmp, "vals.npy"), mode="w+",
                dtype=np.float64, shape=(n, k))
            self.rows = np.lib.format.open_memmap(
                os.path.join(self._tmp, "rows.npy"), mode="w+",
                dtype=np.int64, shape=(n,))
        else:
            self.vals = np.empty((n, k), np.float64)
            self.rows = np.empty(n, np.int64)

    @property
    def spilled(self) -> bool:
        return self._tmp is not None

    def add(self, chunk: np.ndarray, bidx: np.ndarray,
            row_base: int) -> None:
        """Append this chunk's rows to their buckets (contiguous writes)."""
        order = np.argsort(bidx, kind="stable")
        ccnt = np.bincount(bidx, minlength=len(self._cursor))
        present = np.flatnonzero(ccnt)
        starts = np.concatenate([[0], np.cumsum(ccnt[present])])
        for t, b in enumerate(present):
            sel = order[starts[t]:starts[t + 1]]
            c0 = self._cursor[b]
            c1 = c0 + len(sel)
            self.vals[c0:c1] = chunk[sel]
            self.rows[c0:c1] = row_base + sel
            self._cursor[b] = c1

    def bucket(self, b: int) -> Tuple[np.ndarray, np.ndarray]:
        """Bucket b's (values, global row ids) — one resident copy."""
        s, e = self.off[b], self.off[b + 1]
        return np.array(self.vals[s:e]), np.array(self.rows[s:e])

    def close(self) -> None:
        del self.vals, self.rows
        if self._tmp is not None:
            shutil.rmtree(self._tmp, ignore_errors=True)
            self._tmp = None


# ------------------------------------------------------------- merged tree


def _merge_bucket_trees(attr: int, edges: np.ndarray,
                        parts: List[Optional[Partition]],
                        group_offset: np.ndarray,
                        num_groups: int) -> SplitTree:
    """One unified flat tree: a root node on the bucket attribute whose
    children are the per-bucket subtrees (node ids and leaf gids offset
    into the global spaces)."""
    nb = len(parts)
    attrs = [np.asarray([attr], np.int32)]
    bound_off_len = [np.asarray([len(edges) - 2], np.int64)]
    bounds = [np.asarray(edges[1:-1], np.float64)]
    root_children = np.empty(nb, np.int64)
    sub_attrs, sub_lens, sub_bounds, sub_children = [], [], [], []
    node_base = 1
    for b, part in enumerate(parts):
        goff = int(group_offset[b])
        if part is None:
            # empty bucket: probes fall through to the next group base
            root_children[b] = ~min(goff, num_groups - 1)
            continue
        t = part.tree
        if t.num_nodes == 0:
            root_children[b] = ~goff
            continue
        root_children[b] = node_base + t.root
        sub_attrs.append(t.attr)
        sub_lens.append(np.diff(t.bound_off))
        sub_bounds.append(t.bounds)
        ch = t.children.copy()
        leaf = ch < 0
        ch[leaf] = ~(~ch[leaf] + goff)
        ch[~leaf] += node_base
        sub_children.append(ch)
        node_base += t.num_nodes
    attrs = np.concatenate(attrs + sub_attrs).astype(np.int32)
    lens = np.concatenate(bound_off_len + sub_lens)
    bound_off = np.concatenate([[0], np.cumsum(lens)]).astype(np.int64)
    all_bounds = np.concatenate(bounds + sub_bounds)
    children = np.concatenate([root_children] + sub_children) \
        if sub_children else root_children
    return SplitTree(attrs, bound_off, all_bounds,
                     children.astype(np.int64), 0)


# ------------------------------------------------------------- main build


_SPILL_MEM_ROWS = 1 << 22    # in-RAM scratch ceiling when spill_rows unset


def dlv_bucketed(src: ChunkSource, d_f: int, *, memory_rows: int,
                 chunk_rows: Optional[int] = None,
                 rng: Optional[np.random.Generator] = None,
                 method: str = "rounds", mesh=None,
                 spill_rows: Optional[int] = None,
                 spill_dir: Optional[str] = None) -> Partition:
    """Appendix D.2: bucket on the max-variance attribute, DLV per bucket.

    The relation is read in O(1) full streaming passes regardless of the
    bucket count: one stats pass, <= max_depth counting passes for the
    edges, and ONE spill pass that lands every row in its bucket's scratch
    slice (see :class:`BucketSpill`); per-bucket DLV then consumes each
    contiguous slice.  ``spill_rows`` bounds the in-RAM scratch (above it
    the scratch is memmap-backed; default ``max(memory_rows, 4M)`` rows);
    ``mesh`` runs the per-chunk stats/histogram passes sharded (psum).
    """
    from repro.core.dlv import dlv

    rng = rng or np.random.default_rng(0)
    chunk_rows = chunk_rows or max(memory_rows // 4, 1024)
    stats = streaming_stats(src, chunk_rows, mesh=mesh)
    attr = int(np.argmax(stats.var))
    edges, counts = _bucket_edges(src, attr, stats.lo[attr], stats.hi[attr],
                                  memory_rows, chunk_rows, mesh=mesh)
    nb = len(edges) - 1
    n = src.num_rows
    k = src.num_cols
    if spill_rows is None:
        spill_rows = max(memory_rows, _SPILL_MEM_ROWS)

    # ---- the ONE spill pass: every row to its bucket's scratch slice
    spill = BucketSpill(counts, k, spill_rows, spill_dir)
    row_base = 0
    for c in src.chunks(chunk_rows):
        if not len(c):
            continue
        bidx = np.clip(np.searchsorted(edges, c[:, attr], side="right") - 1,
                       0, nb - 1)
        spill.add(np.asarray(c, np.float64), bidx, row_base)
        row_base += len(c)
    if row_base != int(spill.off[-1]):
        spill.close()
        raise RuntimeError(f"spill pass saw {row_base} rows but bucket "
                           f"counts sum to {int(spill.off[-1])} — source "
                           "changed between passes?")

    try:
        parts: List[Optional[Partition]] = []
        group_offset = np.zeros(nb, np.int64)
        gid = np.full(n, -1, np.int64)
        order_all, reps_all, lo_all, hi_all = [], [], [], []
        next_gid = 0
        for b in range(nb):
            group_offset[b] = next_gid
            if counts[b] == 0:
                parts.append(None)
                continue
            Xb, rows = spill.bucket(b)
            from repro.core import relation as relation_mod
            relation_mod.note_resident(len(Xb))
            # equal-width refinement can fail to isolate point masses /
            # duplicate-heavy clusters within max_depth; the budget is then
            # soft — degrade to a larger in-memory bucket instead of dying
            if len(Xb) > max(memory_rows, 1):
                warnings.warn(f"bucket {b} holds {len(Xb)} rows "
                              f"(> memory_rows={memory_rows}); edge "
                              "refinement could not isolate a "
                              "concentration — running in-memory DLV on "
                              "the oversized bucket")
            res = dlv(Xb, d_f, rng=rng, method=method)
            parts.append(res)
            gid[rows] = next_gid + res.gid
            order_all.append(rows[res.order])
            reps_all.append(res.reps)
            lo_all.append(res.boxes_lo)
            hi_all.append(res.boxes_hi)
            next_gid += res.num_groups
    finally:
        spill.close()

    # global contiguous layout: buckets in edge order, groups within bucket
    order = np.concatenate(order_all) if order_all else np.zeros(0, np.int64)
    off = [0]
    for part in parts:
        if part is not None:
            off.extend((np.asarray(part.offsets[1:]) + off[-1]).tolist())
    offsets = np.asarray(off, np.int64)
    reps = np.concatenate(reps_all) if reps_all else np.zeros((0, k))
    boxes_lo = np.concatenate(lo_all) if lo_all else np.zeros((0, k))
    boxes_hi = np.concatenate(hi_all) if hi_all else np.zeros((0, k))
    tree = _merge_bucket_trees(attr, edges, parts, group_offset,
                               max(next_gid, 1))
    return Partition(gid, order, offsets, reps, boxes_lo, boxes_hi, tree)


@register_backend("bucketing")
def _bucketing_backend(X, *, d_f: int = 100, memory_rows: int = None,
                       chunk_rows: Optional[int] = None,
                       rng: Optional[np.random.Generator] = None,
                       method: str = "rounds", mesh=None,
                       spill_rows: Optional[int] = None,
                       spill_dir: Optional[str] = None) -> Partition:
    """Partitioner backend: accepts an array (wrapped in ArraySource) or
    any ChunkSource.  ``chunk_rows`` sets the streaming chunk size;
    ``mesh`` shards the per-chunk stats / histogram passes (psum)."""
    src = X if isinstance(X, ChunkSource) else ArraySource(np.asarray(X))
    if memory_rows is None:
        memory_rows = max(src.num_rows // 8, 4096)
    return dlv_bucketed(src, d_f, memory_rows=memory_rows,
                        chunk_rows=chunk_rows, rng=rng, method=method,
                        mesh=mesh, spill_rows=spill_rows,
                        spill_dir=spill_dir)


# Back-compat: the merged result is a plain Partition now.
BucketedDLV = Partition
