"""Out-of-core DLV via the bucketing scheme — paper Appendix D.2.

For relations that do not fit in memory (the paper's 10^9-tuple regime):

  1. one streaming pass estimates per-attribute mean/variance and the range
     of the highest-variance attribute (Welford over chunks — this is the
     pass the ``kernels/segstats.py`` Pallas kernel accelerates on TPU);
  2. the range is split into equal-width buckets, recursively until every
     bucket holds at most ``r`` tuples (r = in-memory budget);
  3. Algorithm 6 (in-memory DLV) runs per bucket; group ids are offset into
     a global id space.

Buckets are disjoint half-open intervals on one attribute, so the global
partition remains a valid DLV-style partition and GetGroup stays sub-linear:
bucket lookup by ``searchsorted`` on the bucket edges, then the bucket's
split tree.

The relation is consumed through the ``ChunkSource`` protocol (anything
yielding (n_i, k) arrays); ``MemmapSource`` adapts an on-disk .npy memmap —
the container-scale stand-in for the paper's PostgreSQL heap scans.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.dlv import DLVResult, dlv


class ChunkSource:
    """Minimal streaming-relation protocol."""

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        raise NotImplementedError

    @property
    def num_rows(self) -> int:
        raise NotImplementedError

    @property
    def num_cols(self) -> int:
        raise NotImplementedError

    def gather(self, mask_fn, chunk_rows: int) -> np.ndarray:
        """Materialise the rows where mask_fn(chunk) is True (bucket load)."""
        parts = [c[mask_fn(c)] for c in self.chunks(chunk_rows)]
        return np.concatenate(parts, axis=0) if parts else \
            np.zeros((0, self.num_cols))


class ArraySource(ChunkSource):
    def __init__(self, X: np.ndarray):
        self.X = X

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        for i in range(0, len(self.X), chunk_rows):
            yield np.asarray(self.X[i:i + chunk_rows], np.float64)

    @property
    def num_rows(self) -> int:
        return self.X.shape[0]

    @property
    def num_cols(self) -> int:
        return self.X.shape[1]


class MemmapSource(ArraySource):
    """On-disk relation (np.memmap) — rows stream through a fixed budget."""

    def __init__(self, path: str, shape, dtype=np.float64):
        self.X = np.lib.format.open_memmap(path, mode="r")
        assert self.X.shape == tuple(shape), (self.X.shape, shape)


@dataclasses.dataclass
class StreamStats:
    count: int
    mean: np.ndarray
    var: np.ndarray
    lo: np.ndarray
    hi: np.ndarray


def streaming_stats(src: ChunkSource, chunk_rows: int) -> StreamStats:
    """One pass: per-attribute mean/var (Chan's parallel Welford) + range."""
    count = 0
    mean = np.zeros(src.num_cols)
    m2 = np.zeros(src.num_cols)
    lo = np.full(src.num_cols, np.inf)
    hi = np.full(src.num_cols, -np.inf)
    for c in src.chunks(chunk_rows):
        nb = len(c)
        if nb == 0:
            continue
        mb = c.mean(axis=0)
        m2b = ((c - mb) ** 2).sum(axis=0)
        delta = mb - mean
        tot = count + nb
        mean = mean + delta * (nb / tot)
        m2 = m2 + m2b + delta ** 2 * (count * nb / tot)
        count = tot
        lo = np.minimum(lo, c.min(axis=0))
        hi = np.maximum(hi, c.max(axis=0))
    var = m2 / max(count, 1)
    return StreamStats(count, mean, var, lo, hi)


def _bucket_edges(src: ChunkSource, attr: int, lo: float, hi: float,
                  r: int, chunk_rows: int, max_depth: int = 8) -> np.ndarray:
    """Equal-width edges refined until every bucket holds <= r rows."""
    edges = [lo, np.nextafter(hi, np.inf)]
    for _ in range(max_depth):
        e = np.asarray(edges)
        counts = np.zeros(len(e) - 1, np.int64)
        for c in src.chunks(chunk_rows):
            idx = np.clip(np.searchsorted(e, c[:, attr], side="right") - 1,
                          0, len(counts) - 1)
            counts += np.bincount(idx, minlength=len(counts))
        if counts.max() <= r:
            return e
        new_edges = [e[0]]
        for i, n in enumerate(counts):
            if n > r:
                splits = int(np.ceil(n / r))
                new_edges.extend(np.linspace(e[i], e[i + 1],
                                             splits + 1)[1:].tolist())
            else:
                new_edges.append(e[i + 1])
        edges = new_edges
    return np.asarray(edges)


@dataclasses.dataclass
class BucketedDLV:
    attr: int
    edges: np.ndarray                    # bucket boundaries (ascending)
    parts: List[Optional[DLVResult]]     # per-bucket in-memory DLV
    group_offset: np.ndarray             # global id base per bucket
    num_groups: int
    gid: np.ndarray                      # (n,) global group per input row
    reps: np.ndarray                     # (G, k)
    counts: np.ndarray                   # (G,)

    def get_group(self, t: np.ndarray) -> int:
        b = int(np.clip(np.searchsorted(self.edges, t[self.attr],
                                        side="right") - 1,
                        0, len(self.parts) - 1))
        part = self.parts[b]
        if part is None:
            return int(self.group_offset[b])
        return int(self.group_offset[b]) + part.get_group(t)


def dlv_bucketed(src: ChunkSource, d_f: int, *, memory_rows: int,
                 chunk_rows: Optional[int] = None,
                 rng: Optional[np.random.Generator] = None) -> BucketedDLV:
    """Appendix D.2: bucket on the max-variance attribute, DLV per bucket."""
    rng = rng or np.random.default_rng(0)
    chunk_rows = chunk_rows or max(memory_rows // 4, 1024)
    stats = streaming_stats(src, chunk_rows)
    attr = int(np.argmax(stats.var))
    edges = _bucket_edges(src, attr, stats.lo[attr], stats.hi[attr],
                          memory_rows, chunk_rows)
    nb = len(edges) - 1

    parts: List[Optional[DLVResult]] = []
    offsets = np.zeros(nb, np.int64)
    gid = np.full(src.num_rows, -1, np.int64)
    reps_all, counts_all = [], []
    next_gid = 0
    # row positions per bucket (second pass, streamed)
    row_base = 0
    bucket_rows: List[List[np.ndarray]] = [[] for _ in range(nb)]
    for c in src.chunks(chunk_rows):
        idx = np.clip(np.searchsorted(edges, c[:, attr], side="right") - 1,
                      0, nb - 1)
        for b in range(nb):
            sel = np.flatnonzero(idx == b)
            if len(sel):
                bucket_rows[b].append(sel + row_base)
        row_base += len(c)

    for b in range(nb):
        rows = (np.concatenate(bucket_rows[b]) if bucket_rows[b]
                else np.zeros(0, np.int64))
        offsets[b] = next_gid
        if len(rows) == 0:
            parts.append(None)
            continue
        lo_e, hi_e = edges[b], edges[b + 1]
        Xb = src.gather(lambda ch: (ch[:, attr] >= lo_e)
                        & (ch[:, attr] < hi_e), chunk_rows)
        assert len(Xb) <= max(memory_rows, 1), (len(Xb), memory_rows)
        res = dlv(Xb, d_f, rng=rng)
        parts.append(res)
        gid[rows] = next_gid + res.gid
        reps_all.append(res.reps)
        counts_all.append(np.diff(res.offsets))
        next_gid += res.num_groups

    reps = np.concatenate(reps_all) if reps_all else np.zeros((0, src.num_cols))
    counts = np.concatenate(counts_all) if counts_all else np.zeros(0)
    return BucketedDLV(attr, edges, parts, offsets, next_gid, gid, reps,
                       counts)
