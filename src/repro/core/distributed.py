"""Distributed pricing backend for the revised dual simplex — the paper's
80-core Parallel Dual Simplex (Mini-Exp 3) mapped onto a TPU pod with
shard_map, promoted from a dry-run lowering proof to the engine's actual
multi-device execution path (``solve_lp_dist`` / ``solve_lp(mesh=...)``).

Tuple columns (the A matrix) and the per-column simplex state — the
MAINTAINED reduced costs ``d``, the nonbasic position codes and the bounds
— live sharded over the data axes and stay device-resident across pivots;
the m x m basis state (basis inverse, duals, basic primal values) is tiny
and replicated on the host.  Three shard_map programs per pivot:

``pq_step``   — pricing + BFRT selection.  Per device:
  1. pricing: alpha = rho @ A_shard                  (the LONE O(m n/p)
     sweep of A; ``d`` arrives maintained, there is NO ``c - y @ A``
     recompute — the redundancy PR 1 removed from the single-host twins)
  2. BFRT pass 1: local breakpoint histogram          (local O(n/p))
  3. psum of histograms + crossing-bucket selection   (collective, O(NB))
  4. pass 2: EXACT in-crossing-bucket walk — each shard contributes its
     K smallest in-bucket breakpoints (top_k), one all_gather of the
     (p, K) candidate block, and the replicated exact merge locates the
     entering variable precisely as the sequential BFRT would.  When a
     shard holds more than K in-bucket breakpoints below the crossing
     point (detected, never assumed), the step falls back to the valid
     conservative pivot at the bucket minimum for that iteration only.

``update_step`` — the post-pivot O(n/p) axpy ``d -= theta * alpha`` plus
  bound-flip / basis-exchange bookkeeping on the state codes.  Purely
  local: zero collective traffic.

``refresh_step`` — periodic refactorization support (every
  ``REFACTOR_EVERY`` pivots): recomputes ``d = c - A^T y`` from fresh
  duals and returns ``A @ xN`` for the basic-value rebuild.  This is the
  ONLY place the full reduced-cost recompute exists, mirroring the
  single-host engines' ``refreshed()``.

Per-iteration collective traffic is O(num_buckets + p*K + m): the design
point of the TPU adaptation.  ``launch/dryrun.py --pq`` lowers the step
for the 2x16x16 pod mesh to prove it; ``benchmarks/warm_start.py``
benchmarks multi-pivot solves through this path against ``solve_lp_np``.
"""
from __future__ import annotations

import inspect
import threading
from collections import OrderedDict
from typing import Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

try:                                  # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                   # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compat wrapper: new jax spells the replication check
    ``check_vma``; the 0.4.x experimental API calls it ``check_rep``."""
    params = inspect.signature(_shard_map).parameters
    kw = {"check_vma": check_vma} if "check_vma" in params else \
        {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels.pricing import pricing_math
from repro.runtime import racecheck

NUM_BUCKETS = 128
GATHER_K = 128        # per-shard in-bucket candidates for the exact walk
_TOL = 1e-9
WIDTH_CAP = 1e30      # stand-in for infinite bound widths (flip cost = huge)


def big_sentinel(dtype):
    """Largest-finite sentinel for masked min/max reductions.

    Derived from the dtype so it is exact under any x64 setting —
    ``jnp.float64(1e300)`` warns and truncates to inf when jax runs with
    default 32-bit floats, which silently breaks the masked reductions.
    """
    return jnp.asarray(jnp.finfo(jnp.dtype(dtype)).max, dtype)


def _mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.shape)


def _my_rank(mesh, axes):
    rank = jax.lax.axis_index(axes[0]).astype(jnp.int64)
    for ax in axes[1:]:
        rank = rank * mesh.shape[ax] + jax.lax.axis_index(ax)
    return rank


def make_pq_step(mesh: Mesh, m: int, n: int,
                 num_buckets: int = NUM_BUCKETS, gather_k: int = GATHER_K):
    """Builds the distributed pricing + BFRT-selection step.

    ``step(A, d, l, u, state, rho, s, budget)`` with A ``(m, n)`` sharded
    on columns over the mesh's data axes; ``d``/``l``/``u`` ``(n,)`` and
    ``state`` int32 ``(n,)`` (0 = at-lower, 1 = at-upper, 2 = basic)
    sharded alike; ``rho`` (the pivot row of Binv), ``s`` (sign of the
    primal infeasibility) and ``budget`` (|delta|) replicated.

    Returns ``(alpha, flip_mask, r_best, q, d_q, at_up_q, Acol, fvec,
    n_flips, has_cross, exact)``:

      alpha     (n,)  sharded — kept on-device for the post-pivot axpy
      flip_mask (n,)  sharded bool — bound flips below the entering ratio
                      (capped at the K smallest per shard, a valid BFRT
                      early stop, so absorption needs only K gathered
                      columns instead of a second dense sweep of A)
      r_best    ()    entering BFRT ratio
      q         ()    global entering column index (int64)
      d_q       ()    maintained reduced cost of the entering column
      at_up_q   ()    whether q currently sits at its upper bound
      Acol      (m,)  the entering column of A (for w = Binv @ Acol)
      fvec      (m,)  A @ dx over flipped columns (flip absorption)
      n_flips   ()    number of bound flips this pivot
      has_cross ()    False => dual unbounded (no eligible crossing)
      exact     ()    True  => the in-bucket walk was exact (not the
                      conservative bucket-minimum fallback)

    Consumes the MAINTAINED reduced costs: no ``c - y @ A`` matvec occurs
    anywhere in this step; ``alpha = rho @ A_shard`` is the lone O(mn/p)
    pass over A.
    """
    axes = _mesh_axes(mesh)
    col_spec = P(None, axes)
    vec_spec = P(axes)
    rep = P()

    def step(A_loc, d_loc, l_loc, u_loc, state_loc, rho, s, budget):
        n_loc = A_loc.shape[1]
        alpha = rho @ A_loc               # pricing: the lone O(mn/p) sweep
        width = u_loc - l_loc
        width = jnp.where(jnp.isfinite(width), width, WIDTH_CAP)
        ratio, cost = pricing_math(alpha, d_loc, state_loc, width, s, _TOL)
        finite = jnp.isfinite(ratio)
        big = big_sentinel(ratio.dtype)

        # ---- BFRT pass 1: bucket the breakpoint ratios (psum: O(NB)) ----
        rmax = jax.lax.pmax(jnp.max(jnp.where(finite, ratio, -big)), axes)
        rmin = jax.lax.pmin(jnp.min(jnp.where(finite, ratio, big)), axes)
        span = jnp.maximum(rmax - rmin, 1e-12)
        # keep the edge grid in the pricing dtype: under x64 the bare
        # int-arange / int division promotes to f64 and silently drags
        # every downstream comparison with it on f32 problems
        grid = jnp.arange(1, num_buckets + 1,
                          dtype=ratio.dtype) / num_buckets
        edges = rmin + span * grid
        bucket = jnp.clip(jnp.searchsorted(edges, ratio), 0, num_buckets - 1)
        hist_l = jnp.zeros(num_buckets, cost.dtype).at[bucket].add(
            jnp.where(finite, cost, 0.0))
        hist = jax.lax.psum(hist_l, axes)
        csum = jnp.cumsum(hist)
        crossed = csum >= budget - 1e-12
        bidx = jnp.argmax(crossed)
        has_cross = jnp.any(crossed)
        lo_edge = jnp.where(bidx == 0, -jnp.inf,
                            edges[jnp.maximum(bidx - 1, 0)])
        hi_edge = edges[bidx]
        base = jnp.where(bidx == 0, 0.0, csum[jnp.maximum(bidx - 1, 0)])

        # ---- pass 2: exact walk inside the crossing bucket.  Each shard
        # contributes its K smallest in-bucket breakpoints; the gathered
        # (p, K) block is tiny and replicated, so the merge reproduces the
        # sequential BFRT exactly whenever no shard truncates below the
        # crossing point (checked; conservative fallback otherwise). ----
        k = min(gather_k, n_loc)
        in_b = finite & (ratio > lo_edge) & (ratio <= hi_edge)
        r_in = jnp.where(in_b, ratio, big)
        neg_top, idx = jax.lax.top_k(-r_in, k)
        r_k = -neg_top                               # k smallest in-bucket
        valid_k = r_k < big
        cost_k = jnp.where(valid_k, cost[idx], 0.0)
        d_k = d_loc[idx]
        up_k = state_loc[idx] == 1
        rank = _my_rank(mesh, axes)
        g_k = rank * n_loc + idx.astype(jnp.int64)
        cnt_in = jnp.sum(in_b)
        trunc = cnt_in > k                           # shard truncated?
        kth = r_k[k - 1]                             # largest gathered

        gat = lambda x: jax.lax.all_gather(x, axes).reshape(-1)
        r_g, cost_g, d_g, up_g, valid_g = map(
            gat, (r_k, cost_k, d_k, up_k, valid_k))
        g_g = gat(g_k)
        trunc_g = jax.lax.all_gather(trunc, axes).reshape(-1)    # (p,)
        kth_g = jax.lax.all_gather(kth, axes).reshape(-1)        # (p,)

        order = jnp.argsort(jnp.where(valid_g, r_g, big))
        r_s = r_g[order]
        valid_s = valid_g[order]
        csum_in = base + jnp.cumsum(jnp.where(valid_s, cost_g[order], 0.0))
        crossed_in = (csum_in >= budget - 1e-12) & valid_s
        pos = jnp.argmax(crossed_in)
        found = jnp.any(crossed_in)
        # exact iff the walk crossed within the gathered prefix and no
        # truncated shard could hide a breakpoint below the entering ratio
        r_exact = r_s[pos]
        ok = found & jnp.all(~trunc_g | (r_exact <= kth_g))
        sel = jnp.where(ok, pos, 0)                  # fallback: bucket min
        q = g_g[order][sel]
        r_best = r_s[sel]
        d_q = d_g[order][sel]
        at_up_q = up_g[order][sel]

        # ---- flips: everything strictly below the entering ratio PLUS
        # the gathered tie breakpoints the exact walk consumed before the
        # crossing position (degenerate pivots carry most of their
        # progress in equal-ratio flips, so skipping ties would stall the
        # solve exactly like the textbook non-BFRT dual simplex). ----
        flip_strict = finite & (ratio < r_best)
        # merged positions of THIS shard's gathered candidates
        merged_rank = jnp.empty_like(order).at[order].set(
            jnp.arange(order.shape[0]))
        mine = jax.lax.dynamic_slice(
            merged_rank, (rank.astype(jnp.int32) * k,), (k,))
        tie_sel = valid_k & (mine < sel) & (r_k >= r_best)
        flip_mask = flip_strict.at[idx].max(tie_sel)
        n_flips = jax.lax.psum(jnp.sum(flip_mask), axes)

        # ---- flip absorption fvec = A @ dx (psum: O(m)).  The strict
        # flips are the globally smallest ratios, so when a shard has at
        # most K of them the columns are fetched sparsely (O(mK) gather,
        # pricing stays the lone dense O(mn/p) sweep); a shard only falls
        # back to the dense masked matvec on the rare pivot whose local
        # flip count exceeds K — a per-shard runtime branch, not a
        # different global program. ----
        at_up = state_loc == 1
        neg_f, fidx = jax.lax.top_k(-jnp.where(finite, ratio, big), k)
        fsel = (-neg_f < r_best) & (-neg_f < big)
        over = jnp.sum(flip_strict) > k

        def fvec_sparse(_):
            up_f = at_up[fidx]
            dxf = jnp.where(fsel, jnp.where(up_f, -width[fidx],
                                            width[fidx]), 0.0)
            s1 = A_loc[:, fidx] @ dxf                  # (m, K) gather
            up_t = at_up[idx]
            dxt = jnp.where(tie_sel, jnp.where(up_t, -width[idx],
                                               width[idx]), 0.0)
            return s1 + A_loc[:, idx] @ dxt
        def fvec_dense(_):
            dx = jnp.where(flip_mask, jnp.where(at_up, -width, width), 0.0)
            return A_loc @ dx
        # repro: allow[REPRO001] one call site per trace: the captured
        # shard state is identical for both branches of this single cond
        fvec = jax.lax.cond(over, fvec_dense, fvec_sparse, None)
        fvec = jax.lax.psum(fvec, axes)
        # entering column, contributed by its owner shard
        j_loc = jnp.clip(q - rank * n_loc, 0, n_loc - 1)
        owner = (q >= rank * n_loc) & (q < (rank + 1) * n_loc)
        Acol = jax.lax.psum(
            jnp.where(owner, A_loc[:, j_loc], jnp.zeros(A_loc.shape[0],
                                                        A_loc.dtype)), axes)
        return (alpha, flip_mask, r_best, q, d_q, at_up_q, Acol, fvec,
                n_flips, has_cross, ok)

    fn = shard_map(
        step, mesh=mesh,
        in_specs=(col_spec, vec_spec, vec_spec, vec_spec, vec_spec,
                  rep, rep, rep),
        out_specs=(vec_spec, vec_spec, rep, rep, rep, rep, rep, rep,
                   rep, rep, rep),
        check_vma=False)
    return jax.jit(fn), col_spec, vec_spec


def make_update_step(mesh: Mesh):
    """Builds the post-pivot maintenance step: the O(n/p) axpy
    ``d -= theta * alpha`` plus bound-flip / basis-exchange bookkeeping on
    the state codes.  Purely shard-local — no collective traffic at all.

    ``update(d, state, alpha, flip_mask, theta, q, leave, leave_up)``
    returns the new sharded ``(d, state)``.
    """
    axes = _mesh_axes(mesh)
    vec_spec = P(axes)
    rep = P()

    def update(d_loc, state_loc, alpha_loc, flip_loc, theta, q, leave,
               leave_up):
        n_loc = d_loc.shape[0]
        rank = _my_rank(mesh, axes)
        g = rank * n_loc + jnp.arange(n_loc, dtype=jnp.int64)
        d = d_loc - theta * alpha_loc            # the O(n/p) axpy
        d = jnp.where(g == q, 0.0, d)
        d = jnp.where(g == leave, -theta, d)
        st = jnp.where(flip_loc, 1 - state_loc, state_loc)   # bound flips
        st = jnp.where(g == q, 2, st)                        # q enters
        st = jnp.where(g == leave,                           # leave exits
                       jnp.where(leave_up, 1, 0), st)
        return d, st.astype(state_loc.dtype)

    fn = shard_map(
        update, mesh=mesh,
        in_specs=(vec_spec, vec_spec, vec_spec, vec_spec, rep, rep, rep,
                  rep),
        out_specs=(vec_spec, vec_spec),
        check_vma=False)
    return jax.jit(fn)


def make_refresh_step(mesh: Mesh):
    """Builds the refactorization support step: from fresh duals ``y``,
    recompute the sharded reduced costs ``d = c - A^T y`` (the ONLY place
    this full recompute exists — between refactorizations ``d`` is
    maintained by ``update_step``) and return ``A @ xN`` so the host can
    rebuild ``xB = -Binv @ (A @ xN)``.
    """
    axes = _mesh_axes(mesh)
    col_spec = P(None, axes)
    vec_spec = P(axes)
    rep = P()

    def refresh(A_loc, cf_loc, state_loc, l_loc, u_loc, y):
        d = cf_loc - y @ A_loc
        d = jnp.where(state_loc == 2, 0.0, d)
        xN = jnp.where(state_loc == 1, u_loc,
                       jnp.where(state_loc == 0, l_loc, 0.0))
        xN = jnp.where(jnp.isfinite(xN), xN, 0.0)
        axn = jax.lax.psum(A_loc @ xN, axes)
        return d, axn

    fn = shard_map(
        refresh, mesh=mesh,
        in_specs=(col_spec, vec_spec, vec_spec, vec_spec, vec_spec, rep),
        out_specs=(vec_spec, rep),
        check_vma=False)
    return jax.jit(fn)


# ------------------------------------------------------ distributed solver


STEP_CACHE_MAXSIZE = 64   # distinct (mesh, shape) step triples kept


class BoundedStepCache:
    """LRU cache for the jitted (pq, update, refresh) step triples.

    Replaces a bare ``functools.lru_cache``: same bound, but with
    explicit hit/miss/eviction counters (compiled-executable churn is a
    real cost — an eviction storm means shapes are cycling faster than
    the cache can hold and should be visible, not silent).

    Thread-safe: entries and counters are guarded by ``_lock``, and each
    resolved ``get_or_create`` is exactly one hit or one miss, so
    ``hits + misses == lookups`` always holds.  A cold key is built by
    exactly one thread — the first caller claims the key with an
    in-flight event and runs ``factory()`` *outside* the lock (jit
    tracing is seconds-slow; holding the lock there would serialize every
    other shape-class behind it — the REPRO011 discipline), while later
    callers wait on the event and re-probe.
    """

    __guarded_by__ = {"_entries": "_lock", "hits": "_lock",
                      "misses": "_lock", "evictions": "_lock",
                      "lookups": "_lock", "_building": "_lock"}

    def __init__(self, maxsize: int = STEP_CACHE_MAXSIZE):
        self.maxsize = int(maxsize)
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.lookups = 0
        self._lock = threading.Lock()
        self._building: Dict[tuple, threading.Event] = {}

    # The probe and the insert live in different lock scopes by design:
    # the in-flight event in ``_building`` is the claim token that makes
    # the check-then-act atomic (waiters re-probe after the owner
    # publishes), so the REPRO009 shape here is the sanctioned pattern.
    # repro: allow[REPRO009] claim-token get-or-create: _building event
    # serializes builders; waiters re-probe after the owner's insert
    def get_or_create(self, key: tuple, factory):
        while True:
            racecheck.checkpoint("step_cache.probe")
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self._entries.move_to_end(key)
                    self.hits += 1
                    self.lookups += 1
                    return entry
                ev = self._building.get(key)
                if ev is None:
                    # We own the build for this key.
                    ev = self._building[key] = threading.Event()
                    self.misses += 1
                    self.lookups += 1
                    break
            # Another thread is building this key: wait, then re-probe.
            # Unresolved probes are not charged, so each resolved call is
            # exactly one lookup and one of hit/miss.
            racecheck.wait_event(ev, "step_cache.wait")
        try:
            entry = factory()
        # repro: allow[REPRO004] claim-release path: the failure is
        # RE-RAISED after waking waiters (nothing is swallowed) — not
        # releasing the claim would park every waiter forever
        except BaseException:
            with self._lock:
                self._building.pop(key, None)
            ev.set()
            raise
        racecheck.checkpoint("step_cache.publish")
        with self._lock:
            self._entries[key] = entry
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.evictions += 1
            self._building.pop(key, None)
        ev.set()
        return entry

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        """Atomic snapshot — never torn: hits+misses == lookups."""
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "lookups": self.lookups,
                    "size": len(self._entries), "maxsize": self.maxsize}

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


_STEP_CACHE = BoundedStepCache()


def step_cache_stats() -> dict:
    """Counters of the module step-triple cache (observability API)."""
    return _STEP_CACHE.stats()


def _cached_steps(mesh: Mesh, m: int, npad: int, num_buckets: int,
                  gather_k: int):
    """One jitted (pq, update, refresh) triple per (mesh, shape) so
    repeated solves — cascades, benchmarks, B&B re-solves — reuse the
    compiled executables instead of re-tracing every call."""
    def _build():
        pq, _, _ = make_pq_step(mesh, m, npad, num_buckets=num_buckets,
                                gather_k=gather_k)
        return pq, make_update_step(mesh), make_refresh_step(mesh)
    return _STEP_CACHE.get_or_create((mesh, m, npad, num_buckets, gather_k),
                                     _build)


def _put(v, sharding, dtype=None):
    """Host value -> device array at its final (replicated) sharding in
    ONE explicit device_put.  Feeding a bare Python scalar to jnp.asarray
    is an IMPLICIT host-to-device transfer, and handing a single-device
    array to the sharded step jits is an implicit device-to-device
    reshard — the strict_numerics guard (jax.transfer_guard) rejects
    both; explicit device_put is the sanctioned path."""
    return jax.device_put(np.asarray(v, dtype), sharding)


def solve_lp_dist(c, A_t, bl, bu, ub, *, mesh: Mesh, lb=None,
                  max_iters: int = 5000, tol: float = 1e-7,
                  warm_start=None, refactor_every: int = None,
                  num_buckets: int = NUM_BUCKETS,
                  gather_k: int = GATHER_K,
                  budget=None, monitor=None):
    """Revised dual simplex with DISTRIBUTED pricing (the ``mesh=`` path
    of ``repro.core.lp.solve_lp``).

    Same conventions and pivot rules as ``solve_lp_np`` — including the
    warm-start and budget/monitor contracts — but the per-column state
    (A, maintained reduced costs d, bounds, nonbasic position codes)
    lives sharded across ``mesh``'s data axes and stays device-resident
    across pivots, while the m x m basis state (Binv, y, xB, basis) is
    replicated on the host.  Per pivot: one ``pq_step`` (pricing + exact
    BFRT, O(mn/p) compute, O(num_buckets + p*K + m) collective traffic)
    and one ``update_step`` (the O(n/p) d-axpy + bookkeeping, no
    collectives).

    Resilience: a shard failure (any exception out of the mesh loop,
    including the ``dist.shard`` fault-injection site) or a degenerate
    stall past ``stall_bland`` (Bland mode is host-side only) falls back
    to ``solve_lp_np`` on a single host, warm-started from the basis
    snapshot at the point of failure, with the same budget — noted as
    ``single_host_fallback`` in ``LPResult.notes``.
    """
    from repro.core.guard import THETA_EPS, NumericalMonitor
    from repro.core.lp import (BUDGET, INFEASIBLE, ITER_LIMIT, OPTIMAL,
                               LPResult, REFACTOR_EVERY, _prep,
                               solve_lp_np)
    from repro.runtime import faults
    if refactor_every is None:
        refactor_every = REFACTOR_EVERY
    arrs, scale, m, n, start = _prep(c, A_t, bl, bu, ub, lb, warm_start,
                                     tol)
    N = n + m
    if arrs is None:
        res = LPResult(INFEASIBLE, np.zeros(n), 0.0, 0,
                       np.arange(n, N), np.zeros(N, bool), np.zeros(m))
        res.pivot_stats = {"exact": 0, "conservative": 0}
        return res
    cf, A, l, u = arrs
    basis0, at_upper0, winit, wnote = start
    notes = [] if wnote is None else [wnote]
    mon = monitor if monitor is not None else NumericalMonitor()
    if budget is not None:
        budget.start()
    axes = _mesh_axes(mesh)
    p = int(np.prod([mesh.shape[a] for a in axes]))
    Npad = -(-N // p) * p

    def pad(v, fill=0.0):
        return np.concatenate([v, np.full(Npad - N, fill, v.dtype)])

    basis = np.asarray(basis0, np.int64).copy()
    state0 = np.full(Npad, 2, np.int32)   # padding columns: never priced
    state0[:N] = np.where(at_upper0, 1, 0)
    state0[basis] = 2

    col_sh = NamedSharding(mesh, P(None, axes))
    vec_sh = NamedSharding(mesh, P(axes))
    rep_sh = NamedSharding(mesh, P())
    A_pad = np.concatenate([A, np.zeros((m, Npad - N))], axis=1)
    A_dev = jax.device_put(A_pad, col_sh)
    cf_dev = jax.device_put(pad(cf), vec_sh)
    l_dev = jax.device_put(pad(l), vec_sh)
    u_dev = jax.device_put(pad(u), vec_sh)
    state_dev = jax.device_put(state0, vec_sh)

    pq_step, update_step, refresh_step = _cached_steps(
        mesh, m, Npad, num_buckets, gather_k)

    if winit is not None:
        # reuse the factors computed during warm-basis validation (twin
        # parity with solve_lp_np): no refactorization, no d recompute
        _, _, _, Binv, y, d0 = winit
        Binv = Binv.copy()
        y = y.copy()
        d_dev = jax.device_put(pad(d0), vec_sh)
        xN = np.where(state0[:N] == 1, u, np.where(state0[:N] == 0, l, 0.0))
        xB = -Binv @ (A @ xN)
        since = 0
    else:
        d_dev = jax.device_put(pad(cf), vec_sh)    # overwritten by refresh
        Binv = np.eye(m)
        xB = np.zeros(m)
        y = np.zeros(m)
        since = refactor_every      # force a factorization on entry

    def refresh():
        nonlocal Binv, xB, y, d_dev, since
        Binv = np.linalg.inv(A[:, basis])
        y = Binv.T @ cf[basis]
        d_dev, axn = refresh_step(A_dev, cf_dev, state_dev, l_dev, u_dev,
                                  _put(y, rep_sh))
        xB = -Binv @ np.asarray(axn)
        since = 0

    status = ITER_LIMIT
    iters = 0
    stall = 0
    n_exact = n_cons = 0
    fallback_reason = None
    try:
        with mesh:
            for iters in range(1, max_iters + 1):
                if budget is not None and (
                        budget.out_of_time()
                        or iters > budget.remaining_pivots()):
                    status = BUDGET
                    notes.append(f"budget: truncated at pivot {iters - 1}")
                    break
                if since >= refactor_every:
                    refresh()
                lB, uB = l[basis], u[basis]
                viol_lo = lB - xB
                viol_hi = xB - uB
                viol = np.maximum(viol_lo, viol_hi)
                r = int(np.argmax(viol))
                if viol[r] <= tol and since > 0:
                    refresh()
                    viol_lo = lB - xB
                    viol_hi = xB - uB
                    viol = np.maximum(viol_lo, viol_hi)
                    r = int(np.argmax(viol))
                if viol[r] <= tol:
                    status = OPTIMAL
                    break
                above = bool(viol_hi[r] >= viol_lo[r])
                delta = xB[r] - (uB[r] if above else lB[r])
                s = 1.0 if delta > 0 else -1.0

                faults.maybe_raise(faults.SHARD, RuntimeError)
                rho = _put(Binv[r], rep_sh)
                (alpha_dev, flip_dev, r_best, q, d_q, at_up_q, Acol, fvec,
                 n_flips, has_cross, exact) = pq_step(
                    A_dev, d_dev, l_dev, u_dev, state_dev, rho,
                    _put(s, rep_sh), _put(abs(delta), rep_sh))
                # ONE explicit device->host pull for everything the host
                # loop consumes this pivot (alpha/flip stay sharded).
                # Implicit scalar syncs (bool(x), int(x)) are banned here:
                # each is a separate blocking transfer, and the
                # strict_numerics test fixture (jax.transfer_guard)
                # rejects them outright.
                (q, d_q, at_up_q, Acol, fvec, has_cross, exact) = \
                    jax.device_get((q, d_q, at_up_q, Acol, fvec,
                                    has_cross, exact))
                if not bool(has_cross):
                    if since > 0:   # could be drift: retry on fresh factors
                        refresh()
                        continue
                    status = INFEASIBLE
                    break
                q = int(q)
                w = Binv @ np.asarray(Acol)
                if abs(w[r]) < 1e-11:
                    if since > 0:
                        refresh()
                        continue
                    break           # cannot happen on fresh factors
                n_exact += int(bool(exact))
                n_cons += int(not bool(exact))
                leave = int(basis[r])
                # flip absorption: xB -= Binv @ (A[:, flips] @ dx)
                xB = xB - Binv @ np.asarray(fvec)
                target = uB[r] if above else lB[r]
                t = (xB[r] - target) / w[r]
                xq = u[q] if bool(at_up_q) else l[q]
                xB = xB - t * w
                xB[r] = xq + t
                theta = float(d_q) / w[r]
                y = y + theta * Binv[r]
                Binv_r = Binv[r] / w[r]
                Binv = Binv - np.outer(w, Binv_r)
                Binv[r] = Binv_r
                basis[r] = q
                d_dev, state_dev = update_step(
                    d_dev, state_dev, alpha_dev, flip_dev,
                    _put(theta, rep_sh), _put(q, rep_sh, np.int64),
                    _put(leave, rep_sh, np.int64), _put(above, rep_sh))
                since += 1
                # anti-cycling: degenerate streaks force a refactorize;
                # past stall_bland, fall back to the host twin (which has
                # the Bland's-rule mode; selection here is in-kernel)
                if abs(theta) <= THETA_EPS:
                    stall += 1
                    if stall == mon.stall_refactor:
                        mon.stall_refactors += 1
                        mon.stall_events += 1
                        since = refactor_every
                    if stall >= mon.stall_bland:
                        mon.stall_events += 1
                        fallback_reason = (f"{stall} degenerate pivots "
                                           "(Bland mode is host-side)")
                        break
                else:
                    stall = 0
    # repro: allow[REPRO004] guard contract: any shard/collective failure
    # (incl. the dist.shard fault site) falls back to the single-host twin
    except Exception as e:          # dead shard / collective failure
        fallback_reason = f"{type(e).__name__}: {e}"

    if budget is not None:
        budget.charge_pivots(iters)

    if fallback_reason is not None:
        # single-host fallback, warm-started from the failure-point basis
        state_np = np.asarray(state_dev)[:N]
        notes.append(f"single_host_fallback: {fallback_reason}")
        res = solve_lp_np(c, A_t, bl, bu, ub, lb=lb, max_iters=max_iters,
                          tol=tol, warm_start=(basis.copy(),
                                               state_np == 1),
                          budget=budget, monitor=monitor)
        res.notes = tuple(notes) + res.notes
        res.pivot_stats = {"exact": n_exact, "conservative": n_cons,
                           "fallback": 1}
        return res

    # final answer always from a fresh factorization (twin parity)
    state_np = np.asarray(state_dev)[:N]
    at_upper = state_np == 1
    in_basis = np.zeros(N, bool)
    in_basis[basis] = True
    at_upper[in_basis] = False
    Binv = np.linalg.inv(A[:, basis])
    xN = np.where(in_basis, 0.0, np.where(at_upper, u, l))
    xN[basis] = 0.0
    xB = -Binv @ (A @ xN)
    x = xN.copy()
    x[basis] = xB
    y = Binv.T @ cf[basis]
    obj_min = float(cf @ np.where(np.isfinite(x), x, 0.0))
    res = LPResult(status, x[:n], obj_min, iters, basis.copy(),
                   at_upper.copy(), y * scale, notes=tuple(notes))
    res.pivot_stats = {"exact": n_exact, "conservative": n_cons}
    return res


def pq_input_specs(m: int, n: int,
                   dtype=jnp.float64):  # repro: allow[REPRO002] x64 production dtype; the f32 contract grid passes dtype=f32
    """Abstract inputs for the pq_step dry-run cell:
    (A, d, l, u, state, rho, s, budget)."""
    f = lambda shape: jax.ShapeDtypeStruct(shape, dtype)
    return (f((m, n)), f((n,)), f((n,)), f((n,)),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            f((m,)), f(()), f(()))
