"""Distributed Parallel Dual Simplex — the paper's 80-core OpenMP scaling
(Mini-Exp 3) mapped onto a TPU pod with shard_map.

Tuple columns (the A matrix) are sharded over the data axes; the m x m
simplex state (basis inverse, duals) is tiny and replicated.  One
``pq_step`` performs, per device:

  1. primal infeasibility scan over basic variables  (replicated, m ops)
  2. pricing: alpha = rho @ A_shard, reduced costs    (local O(m n/p))
  3. BFRT pass 1: local breakpoint histogram          (local O(n/p))
  4. psum of histograms + crossing-bucket selection   (collective, O(NB))
  5. pass 2 within the crossing bucket + argmin-style
     global entering-variable selection               (pmax reduction)

This module provides the shard_map step used by the multi-pod dry-run
(``dryrun.py --pq``): lowering it for the 2x16x16 mesh proves the paper's
algorithm distributes across pods with only O(num_buckets) collective
traffic per iteration — the design point of the TPU adaptation.
"""
from __future__ import annotations

import functools
from typing import Tuple

import inspect

import jax
import jax.numpy as jnp
try:                                  # jax >= 0.6 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                   # 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compat wrapper: new jax spells the replication check
    ``check_vma``; the 0.4.x experimental API calls it ``check_rep``."""
    params = inspect.signature(_shard_map).parameters
    kw = {"check_vma": check_vma} if "check_vma" in params else \
        {"check_rep": check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

NUM_BUCKETS = 128
_TOL = 1e-9


def _local_pricing(A_loc, rho, y, c_loc, state_loc, lo_loc, hi_loc, s):
    alpha = rho @ A_loc
    d = c_loc - y @ A_loc
    sa = s * alpha
    nonbasic = state_loc < 2
    at_up = state_loc == 1
    elig = nonbasic & (((~at_up) & (sa > _TOL)) | (at_up & (sa < -_TOL)))
    safe = jnp.where(jnp.abs(sa) > _TOL, sa, 1.0)
    ratio = jnp.where(elig, jnp.maximum(d / safe, 0.0), jnp.inf)
    cost = jnp.where(elig, jnp.abs(alpha) * (hi_loc - lo_loc), 0.0)
    return alpha, ratio, cost


def make_pq_step(mesh: Mesh, m: int, n: int,
                 num_buckets: int = NUM_BUCKETS):
    """Builds pq_step(A, c, lo, hi, state, rho, y, s, budget) ->
    (entering ratio, global entering index, flip histogram, has_cross).

    A: (m, n) sharded on columns over all data axes; state/lo/hi/c: (n,).
    """
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    col_spec = P(None, axes)
    vec_spec = P(axes)
    rep = P()

    def step(A_loc, c_loc, lo_loc, hi_loc, state_loc, rho, y, s, budget):
        alpha, ratio, cost = _local_pricing(A_loc, rho, y, c_loc, state_loc,
                                            lo_loc, hi_loc, s)
        finite = jnp.isfinite(ratio)
        big = jnp.float64(1e300) if ratio.dtype == jnp.float64 else 3.4e38
        rmax_l = jnp.max(jnp.where(finite, ratio, -big))
        rmin_l = jnp.min(jnp.where(finite, ratio, big))
        rmax = jax.lax.pmax(rmax_l, axes)
        rmin = jax.lax.pmin(rmin_l, axes)
        span = jnp.maximum(rmax - rmin, 1e-12)
        edges = rmin + span * (jnp.arange(1, num_buckets + 1)
                               / num_buckets)
        # local histogram (BFRT pass 1)
        bucket = jnp.clip(jnp.searchsorted(edges, ratio), 0, num_buckets - 1)
        hist_l = jnp.zeros(num_buckets, cost.dtype).at[bucket].add(
            jnp.where(finite, cost, 0.0))
        hist = jax.lax.psum(hist_l, axes)                   # O(NB) traffic
        csum = jnp.cumsum(hist)
        crossed = csum >= budget - 1e-12
        bidx = jnp.argmax(crossed)
        has_cross = jnp.any(crossed)
        lo_edge = jnp.where(bidx == 0, -jnp.inf, edges[jnp.maximum(bidx - 1, 0)])
        hi_edge = edges[bidx]

        # pass 2: the crossing bucket's minimum enters.  This is a valid
        # *conservative* BFRT pivot (every strictly-smaller ratio flips;
        # their cumulative cost is < budget by bucket construction); the
        # exact in-bucket walk — tiny — runs host-side in the full solver.
        in_b = finite & (ratio > lo_edge) & (ratio <= hi_edge)
        r_in = jnp.where(in_b, ratio, big)
        j_loc = jnp.argmin(r_in)
        r_best_l = r_in[j_loc]
        r_best = jax.lax.pmin(r_best_l, axes)
        # global index of the winner: owner contributes its global index
        my_rank = jax.lax.axis_index(axes[0])
        for ax in axes[1:]:
            my_rank = my_rank * mesh.shape[ax] + jax.lax.axis_index(ax)
        n_loc = A_loc.shape[1]
        g_idx = my_rank * n_loc + j_loc
        winner = jnp.where(r_best_l <= r_best, g_idx, jnp.iinfo(jnp.int32).max)
        q = jax.lax.pmin(winner, axes)
        flips_l = finite & (ratio < r_best)
        n_flips = jax.lax.psum(jnp.sum(flips_l), axes)
        return r_best, q, n_flips, has_cross

    return shard_map(
        step, mesh=mesh,
        in_specs=(col_spec, vec_spec, vec_spec, vec_spec, vec_spec,
                  rep, rep, rep, rep),
        out_specs=(rep, rep, rep, rep),
        check_vma=False), col_spec, vec_spec


def pq_input_specs(m: int, n: int, dtype=jnp.float32):
    """Abstract inputs for the pq_step dry-run cell."""
    f = lambda shape: jax.ShapeDtypeStruct(shape, dtype)
    return (f((m, n)), f((n,)), f((n,)), f((n,)),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            f((m,)), f((m,)), f(()), f(()))
