"""(Parallel) Dual Simplex with Bound-Flipping Ratio Test — paper §2.3 + App. B/C.

Solves the package-query LP in bounded standard form:

    min  cᵀx̃   s.t.  bl <= Ãx̃ <= bu,   0 <= x̃ <= ũ

internally rewritten (Appendix B.1) with slacks s = Ãx̃:

    min cᵀx   s.t.  Ax = 0,  l <= x <= u,   A = [-Ã | I],  x = [x̃ | s],
    l = [0 | bl], u = [ũ | bu].

Structure exploited exactly as the paper does:
  * m is tiny (3–20) and n is huge -> the basis inverse is a dense m×m
    matrix recomputed directly (App. C.2 — no LU updates needed),
  * phase-1 is free: the slack basis is dual-feasible after setting each
    nonbasic variable to the bound matching sign(c) (App. C.1),
  * the two O(n) steps per iteration — pricing (alpha = rho @ A) and the
    BFRT breakpoint scan — are embarrassingly parallel over n (App. C.3);
    here they are vectorised (numpy / jnp) and, on TPU, backed by the
    Pallas kernels in ``repro.kernels`` and the shard_map distribution in
    ``repro.core.distributed``.

Two twin implementations with identical pivot rules:
  solve_lp_np  — numpy, used by branch & bound re-solves and as the oracle,
  solve_lp     — jax.lax.while_loop under jit (f64), used by the benchmarks
                 and the distributed/multi-pod path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

OPTIMAL, ITER_LIMIT, INFEASIBLE = 0, 1, 2
_TOL = 1e-9


@dataclasses.dataclass
class LPResult:
    status: int
    x: np.ndarray            # primal solution over the original n variables
    obj: float               # objective in the ORIGINAL sense (pre-negation)
    iters: int
    basis: np.ndarray        # final basis (indices into n+m)
    at_upper: np.ndarray     # nonbasic-at-upper flags (n+m)
    y: np.ndarray            # duals (m,)

    @property
    def feasible(self) -> bool:
        return self.status == OPTIMAL


def standard_form(c, A_t, bl, bu, ub):
    """Build [x̃ | s] arrays. Returns (c_f, A_f, l_f, u_f)."""
    m, n = A_t.shape
    c_f = np.concatenate([c, np.zeros(m)])
    A_f = np.concatenate([-A_t, np.eye(m)], axis=1)
    l_f = np.concatenate([np.zeros(n), bl])
    u_f = np.concatenate([ub, bu])
    return c_f, A_f, l_f, u_f


def row_scaling(A_t) -> np.ndarray:
    """Row equilibration factors: package-query rows can differ by 12+
    orders of magnitude (count=1 vs FLOPs=1e12); unscaled, the transformed
    pivot rows lose the small rows to cancellation."""
    mx = np.max(np.abs(A_t), axis=1)
    return np.where(mx > 0, 1.0 / mx, 1.0)


def solve_lp_np(c, A_t, bl, bu, ub, *, lb: Optional[np.ndarray] = None,
                max_iters: int = 5000, tol: float = 1e-7) -> LPResult:
    """Bounded dual simplex with BFRT (numpy twin)."""
    c = np.asarray(c, np.float64)
    A_t = np.atleast_2d(np.asarray(A_t, np.float64))
    m, n = A_t.shape
    scale = row_scaling(A_t)
    A_t = A_t * scale[:, None]
    bl = np.asarray(bl, np.float64) * scale
    bu = np.asarray(bu, np.float64) * scale
    cf, A, l, u = standard_form(c, A_t, bl, bu, np.asarray(ub, np.float64))
    if lb is not None:
        l[:n] = lb
    N = n + m
    # infeasible box
    if np.any(l > u + tol):
        return LPResult(INFEASIBLE, np.zeros(n), 0.0, 0,
                        np.arange(n, N), np.zeros(N, bool), np.zeros(m))

    basis = np.arange(n, N)
    in_basis = np.zeros(N, bool)
    in_basis[basis] = True
    # phase-1 for free (App. C.1): nonbasic at the bound matching sign(c)
    at_upper = np.zeros(N, bool)
    at_upper[:n] = cf[:n] < 0
    # variables with infinite lower bound must start at their (finite) upper
    at_upper[:n] |= np.isinf(l[:n])

    status = ITER_LIMIT
    iters = 0
    for iters in range(1, max_iters + 1):
        Binv = np.linalg.inv(A[:, basis])
        xN = np.where(in_basis, 0.0, np.where(at_upper, u, l))
        xN[basis] = 0.0
        xB = -Binv @ (A @ xN)
        lB, uB = l[basis], u[basis]
        viol_lo = lB - xB
        viol_hi = xB - uB
        viol = np.maximum(viol_lo, viol_hi)
        r = int(np.argmax(viol))
        if viol[r] <= tol:
            status = OPTIMAL
            break
        delta = xB[r] - uB[r] if viol_hi[r] >= viol_lo[r] else xB[r] - lB[r]
        s = 1.0 if delta > 0 else -1.0

        rho = Binv[r]
        alpha = rho @ A                      # pricing: O(mn), parallel over n
        y = Binv.T @ cf[basis]
        d = cf - A.T @ y                     # reduced costs

        sa = s * alpha
        elig = (~in_basis) & (
            ((~at_upper) & (sa > tol)) | (at_upper & (sa < -tol)))
        if not np.any(elig):
            status = INFEASIBLE
            break
        ratio = np.where(elig, d / np.where(np.abs(sa) > tol, sa, 1.0), np.inf)
        ratio = np.where(elig, np.maximum(ratio, 0.0), np.inf)

        # ---- BFRT: walk breakpoints in ratio order, flipping bounds while
        # the remaining infeasibility budget allows (App. C.3).
        width = u - l
        flip_cost = np.full(N, np.inf)
        flip_cost[elig] = np.abs(alpha[elig]) * width[elig]
        order = np.argsort(ratio, kind="stable")
        k_elig = int(np.sum(elig))
        cand = order[:k_elig]
        csum = np.cumsum(flip_cost[cand])
        budget = abs(delta)
        cross = int(np.searchsorted(csum, budget - 1e-12))
        if cross >= k_elig:
            status = INFEASIBLE     # dual unbounded: flips cannot absorb
            break
        q = int(cand[cross])
        flips = cand[:cross]

        # apply bound flips
        if len(flips):
            at_upper[flips] = ~at_upper[flips]
        # leaving variable goes to the violated bound
        leave = basis[r]
        at_upper[leave] = delta > 0
        in_basis[leave] = False
        in_basis[q] = True
        basis[r] = q

    Binv = np.linalg.inv(A[:, basis])
    xN = np.where(in_basis, 0.0, np.where(at_upper, u, l))
    xN[basis] = 0.0
    xB = -Binv @ (A @ xN)
    x = xN.copy()
    x[basis] = xB
    y = Binv.T @ cf[basis]
    obj_min = float(cf @ np.where(np.isfinite(x), x, 0.0))
    return LPResult(status, x[:n], obj_min, iters, basis.copy(),
                    at_upper.copy(), y * scale)   # duals in original units


# ----------------------------------------------------------------- JAX twin

import jax
import jax.numpy as jnp
from functools import partial


@partial(jax.jit, static_argnames=("max_iters",))
def _solve_lp_jax(cf, A, l, u, max_iters: int):
    N = A.shape[1]
    m = A.shape[0]
    n = N - m
    tol = 1e-7

    basis0 = jnp.arange(n, N)
    in_basis0 = jnp.zeros(N, bool).at[basis0].set(True)
    at_upper0 = jnp.zeros(N, bool).at[:n].set(
        (cf[:n] < 0) | jnp.isinf(l[:n]))

    def xb_of(basis, in_basis, at_upper):
        Binv = jnp.linalg.inv(A[:, basis])
        xN = jnp.where(in_basis, 0.0, jnp.where(at_upper, u, l))
        xN = xN.at[basis].set(0.0)
        xB = -Binv @ (A @ xN)
        return Binv, xN, xB

    def cond(state):
        basis, in_basis, at_upper, status, it = state
        return (status == ITER_LIMIT) & (it < max_iters)

    def body(state):
        basis, in_basis, at_upper, status, it = state
        Binv, xN, xB = xb_of(basis, in_basis, at_upper)
        lB, uB = l[basis], u[basis]
        viol_lo = lB - xB
        viol_hi = xB - uB
        viol = jnp.maximum(viol_lo, viol_hi)
        r = jnp.argmax(viol)
        done = viol[r] <= tol

        above = viol_hi[r] >= viol_lo[r]
        delta = jnp.where(above, xB[r] - uB[r], xB[r] - lB[r])
        s = jnp.where(delta > 0, 1.0, -1.0)
        rho = Binv[r]
        alpha = rho @ A
        y = Binv.T @ cf[basis]
        d = cf - A.T @ y

        sa = s * alpha
        elig = (~in_basis) & (
            ((~at_upper) & (sa > tol)) | (at_upper & (sa < -tol)))
        any_elig = jnp.any(elig)
        ratio = jnp.where(elig,
                          jnp.maximum(d / jnp.where(jnp.abs(sa) > tol, sa, 1.0),
                                      0.0), jnp.inf)
        width = u - l
        flip_cost = jnp.where(elig, jnp.abs(alpha) * width, 0.0)

        order = jnp.argsort(ratio)
        csum_all = jnp.cumsum(flip_cost[order])
        budget = jnp.abs(delta)
        elig_sorted = elig[order]
        # crossing point among eligible prefix
        crossed = (csum_all >= budget - 1e-12) & elig_sorted
        cross_pos = jnp.argmax(crossed)          # first True (0 if none)
        has_cross = jnp.any(crossed)
        q = order[cross_pos]
        flip_mask = elig & (ratio < ratio[q]) & (
            jnp.arange(N) != q)
        # only flip breakpoints strictly before the crossing in sorted order
        rank = jnp.empty(N, jnp.int32).at[order].set(jnp.arange(N, dtype=jnp.int32))
        flip_mask = elig & (rank < rank[q])

        new_status = jnp.where(done, OPTIMAL,
                               jnp.where(~any_elig | ~has_cross, INFEASIBLE,
                                         ITER_LIMIT)).astype(jnp.int32)
        do_pivot = new_status == ITER_LIMIT

        leave = basis[r]
        at_upper2 = jnp.where(flip_mask, ~at_upper, at_upper)
        at_upper2 = at_upper2.at[leave].set(delta > 0)
        in_basis2 = in_basis.at[leave].set(False).at[q].set(True)
        basis2 = basis.at[r].set(q)

        basis = jnp.where(do_pivot, basis2, basis)
        in_basis = jnp.where(do_pivot, in_basis2, in_basis)
        at_upper = jnp.where(do_pivot, at_upper2, at_upper)
        return (basis, in_basis, at_upper, new_status,
                (it + 1).astype(jnp.int32))

    state = (basis0, in_basis0, at_upper0, jnp.int32(ITER_LIMIT), jnp.int32(0))
    basis, in_basis, at_upper, status, it = jax.lax.while_loop(
        cond, body, state)
    Binv, xN, xB = xb_of(basis, in_basis, at_upper)
    x = xN.at[basis].set(xB)
    y = Binv.T @ cf[basis]
    obj = cf @ jnp.where(jnp.isfinite(x), x, 0.0)
    return status, x[:n], obj, it, basis, at_upper, y


def solve_lp(c, A_t, bl, bu, ub, *, lb: Optional[np.ndarray] = None,
             max_iters: int = 5000) -> LPResult:
    """JAX dual simplex (jit + while_loop).  Same conventions as solve_lp_np."""
    c = np.asarray(c, np.float64)
    A_t = np.atleast_2d(np.asarray(A_t, np.float64))
    m, n = A_t.shape
    scale = row_scaling(A_t)
    A_t = A_t * scale[:, None]
    bl = np.asarray(bl, np.float64) * scale
    bu = np.asarray(bu, np.float64) * scale
    cf, A, l, u = standard_form(c, A_t, bl, bu, np.asarray(ub, np.float64))
    if lb is not None:
        l[:n] = lb
    if np.any(l > u + 1e-9):
        return LPResult(INFEASIBLE, np.zeros(n), 0.0, 0,
                        np.arange(n, n + m), np.zeros(n + m, bool),
                        np.zeros(m))
    status, x, obj, it, basis, at_upper, y = _solve_lp_jax(
        jnp.asarray(cf), jnp.asarray(A), jnp.asarray(l), jnp.asarray(u),
        max_iters)
    return LPResult(int(status), np.asarray(x), float(obj), int(it),
                    np.asarray(basis), np.asarray(at_upper),
                    np.asarray(y) * scale)


# ------------------------------------------------------- certificate check


def verify_optimality(res: LPResult, c, A_t, bl, bu, ub,
                      lb: Optional[np.ndarray] = None,
                      tol: float = 1e-5) -> Tuple[bool, str]:
    """Independent optimality certificate (numpy, no solver internals).

    x* is optimal iff (i) primal feasible and (ii) there exist duals y with
    reduced costs d = c - Aᵀy satisfying d_j >= 0 at lower bounds,
    d_j <= 0 at upper bounds, d_j = 0 for strictly interior x_j.  We check
    the basis-derived y, which by LP theory certifies optimality if valid.
    """
    c = np.asarray(c, np.float64)
    A_t = np.atleast_2d(np.asarray(A_t, np.float64))
    m, n = A_t.shape
    cf, A, l, u = standard_form(c, A_t, np.asarray(bl, np.float64),
                                np.asarray(bu, np.float64),
                                np.asarray(ub, np.float64))
    if lb is not None:
        l[:n] = lb
    x = res.x
    # primal feasibility
    if np.any(x < l[:n] - tol) or np.any(x > u[:n] + tol):
        return False, "primal bounds violated"
    act = A_t @ x
    if np.any(act < np.asarray(bl) - tol) or np.any(act > np.asarray(bu) + tol):
        return False, "constraint bounds violated"
    # dual feasibility + complementary slackness
    sf = np.concatenate([x, act])
    d = cf - A.T @ res.y
    at_lo = sf <= l + tol
    at_hi = sf >= u - tol
    interior = ~(at_lo | at_hi)
    if np.any(np.abs(d[interior]) > tol * (1 + np.abs(cf[interior]))):
        return False, "nonzero reduced cost at interior variable"
    bad_lo = at_lo & ~at_hi & (d < -tol)
    bad_hi = at_hi & ~at_lo & (d > tol)
    if np.any(bad_lo) or np.any(bad_hi):
        return False, "reduced-cost sign violation"
    return True, "optimal certificate valid"
