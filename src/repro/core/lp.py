"""Revised (Parallel) Dual Simplex with Bound-Flipping Ratio Test —
paper §2.3 + App. B/C.

Solves the package-query LP in bounded standard form:

    min  cᵀx̃   s.t.  bl <= Ãx̃ <= bu,   0 <= x̃ <= ũ

internally rewritten (Appendix B.1) with slacks s = Ãx̃:

    min cᵀx   s.t.  Ax = 0,  l <= x <= u,   A = [-Ã | I],  x = [x̃ | s],
    l = [0 | bl], u = [ũ | bu].

Structure exploited exactly as the paper does:
  * m is tiny (3–20) and n is huge -> the basis inverse is a dense m×m
    matrix (App. C.2),
  * phase-1 is free: ANY nonsingular basis is dual-feasible after setting
    each nonbasic variable to the bound matching the sign of its reduced
    cost (App. C.1) — this is also what makes warm starting safe,
  * the two O(n) steps per iteration — pricing (alpha = rho @ A) and the
    BFRT breakpoint scan — are embarrassingly parallel over n (App. C.3);
    here they are vectorised (numpy / jnp) and, on TPU, backed by the
    Pallas kernels in ``repro.kernels`` and the shard_map distribution in
    ``repro.core.distributed``.

Revised-simplex invariants (maintained between pivots, App. C custom loop):
  * ``Binv``    — basis inverse, updated by a Sherman–Morrison /
    product-form rank-1 update per pivot (O(m^2)), refactorized from
    scratch every ``REFACTOR_EVERY`` pivots for f64 stability;
  * ``d``       — reduced costs c - Aᵀy, updated by one O(n) axpy
    ``d -= theta * alpha`` per pivot (exact zeros pinned on the basis);
  * ``y``       — duals, updated by ``y += theta * rho`` (O(m));
  * ``xB``      — basic primal values, updated incrementally after bound
    flips (O(m * |flips|) in the numpy twin; one masked matvec in the
    fixed-shape JAX twins) and the basis exchange (O(m)).
  The ONLY O(mn) sweep of A inside the pivot loop is the pricing pass
  ``alpha = rho @ A`` (the Pallas kernel in ``repro.kernels.pricing``).
  Whenever optimality or dual unboundedness is about to be declared on
  stale (rank-1-updated) factors, the engine refactorizes first and
  re-checks, so the ``verify_optimality`` certificate is always produced
  from a fresh factorization.

Warm-start contract:
  ``solve_lp_np`` / ``solve_lp`` / ``solve_lp_kernel`` accept
  ``warm_start=`` — an ``LPResult``, a ``WarmStart``, or a
  ``(basis, at_upper)`` tuple.  ``basis`` must hold m column indices into
  THIS LP's n+m columns (callers re-map indices when the column set
  changed, cf. ``repro.core.shading.map_warm_basis``); ``at_upper`` is an
  optional (n+m,) hint used only for columns with a ~zero reduced cost.
  The engine validates the basis (shape, uniqueness, nonsingularity,
  no dual-infeasible column pinned at an infinite bound) and silently
  falls back to the cold all-slack start when invalid — a warm start can
  only change the iteration count, never the answer.

Two twin implementations with identical pivot rules:
  solve_lp_np  — numpy, used by branch & bound re-solves and as the oracle,
  solve_lp     — jax.lax.while_loop under jit (f64), used by the benchmarks
                 and the distributed/multi-pod path.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.guard import (DRIFT_TOL, NumericalMonitor, STALL_BLAND,
                              STALL_REFACTOR, SolveBudget, THETA_EPS)
from repro.runtime import faults

OPTIMAL, ITER_LIMIT, INFEASIBLE, BUDGET = 0, 1, 2, 3
_TOL = 1e-9
REFACTOR_EVERY = 64   # pivots between full refactorizations (f64 stability)


@dataclasses.dataclass
class LPResult:
    status: int
    x: np.ndarray            # primal solution over the original n variables
    obj: float               # objective in the ORIGINAL sense (pre-negation)
    iters: int
    basis: np.ndarray        # final basis (indices into n+m)
    at_upper: np.ndarray     # nonbasic-at-upper flags (n+m)
    y: np.ndarray            # duals (m,)
    notes: Tuple[str, ...] = ()   # solver events (warm rejection, stalls,
                                  # budget truncation) for the SolveReport

    @property
    def feasible(self) -> bool:
        return self.status == OPTIMAL

    @property
    def warm(self) -> "WarmStart":
        """Warm-start handle for a sibling LP over the same columns."""
        return WarmStart(self.basis, self.at_upper)


@dataclasses.dataclass
class WarmStart:
    """Starting basis for the dual simplex (see module docstring)."""
    basis: np.ndarray
    at_upper: Optional[np.ndarray] = None


def _unpack_warm(warm_start):
    """Accept LPResult / WarmStart / (basis, at_upper) / None."""
    if warm_start is None:
        return None, None
    if hasattr(warm_start, "basis"):
        return warm_start.basis, getattr(warm_start, "at_upper", None)
    basis, at_upper = warm_start
    return basis, at_upper


def standard_form(c, A_t, bl, bu, ub):
    """Build [x̃ | s] arrays. Returns (c_f, A_f, l_f, u_f)."""
    m, n = A_t.shape
    c_f = np.concatenate([c, np.zeros(m)])
    A_f = np.concatenate([-A_t, np.eye(m)], axis=1)
    l_f = np.concatenate([np.zeros(n), bl])
    u_f = np.concatenate([ub, bu])
    return c_f, A_f, l_f, u_f


def row_scaling(A_t) -> np.ndarray:
    """Row equilibration factors: package-query rows can differ by 12+
    orders of magnitude (count=1 vs FLOPs=1e12); unscaled, the transformed
    pivot rows lose the small rows to cancellation."""
    mx = np.max(np.abs(A_t), axis=1)
    return np.where(mx > 0, 1.0 / mx, 1.0)


def _cold_start(cf, l, n, N):
    """All-slack basis, nonbasic at the bound matching sign(c) (App. C.1)."""
    basis = np.arange(n, N)
    in_basis = np.zeros(N, bool)
    in_basis[basis] = True
    at_upper = np.zeros(N, bool)
    at_upper[:n] = (cf[:n] < 0) | np.isinf(l[:n])
    return basis, in_basis, at_upper


def _warm_state(cf, A, l, u, warm_basis, at_upper_hint, tol):
    """Validate a warm basis; returns
    ((basis, in_basis, at_upper, Binv, y, d), None) or (None, reason).

    Dual feasibility is restored for free by placing every nonbasic column
    at the bound matching the sign of its reduced cost; the ``at_upper``
    hint only decides columns whose reduced cost is ~zero (degenerate),
    which preserves the warm solve's primal point.  The factors computed
    for validation (Binv, y, d) are returned so the solver can seed its
    state without refactorizing again.

    A rejected basis is never an error — the caller falls back to the
    cold all-slack start — but it is no longer *silent*: the reason is
    surfaced through ``LPResult.notes`` / the SolveReport so a bad basis
    can never be proceeded on unnoticed.
    """
    m, N = A.shape
    basis = np.asarray(warm_basis, np.int64).ravel()
    if basis.shape != (m,):
        return None, f"basis shape {basis.shape} != ({m},)"
    if basis.min() < 0 or basis.max() >= N or len(np.unique(basis)) != m:
        return None, "basis indices out of range or duplicated"
    try:
        Binv = np.linalg.inv(A[:, basis])
    except np.linalg.LinAlgError:
        return None, "singular basis"
    if not np.all(np.isfinite(Binv)) or np.abs(Binv).max() > 1e12:
        return None, "ill-conditioned basis"
    in_basis = np.zeros(N, bool)
    in_basis[basis] = True
    y = Binv.T @ cf[basis]
    d = cf - A.T @ y
    d[basis] = 0.0
    hint = np.zeros(N, bool)
    if at_upper_hint is not None:
        h = np.asarray(at_upper_hint, bool).ravel()
        if h.shape == (N,):
            hint = h.copy()
    at_upper = np.where(d < -tol, True, np.where(d > tol, False, hint))
    at_upper |= np.isinf(l)            # -inf lower: must sit at upper
    at_upper &= ~np.isinf(u)           # +inf upper: must sit at lower
    # a nonbasic column whose reduced-cost sign demands an infinite bound
    # cannot be made dual-feasible by bound placement -> reject the basis
    bad = (~in_basis) & (((d < -tol) & np.isinf(u))
                         | ((d > tol) & np.isinf(l))
                         | (np.isinf(l) & np.isinf(u)))
    if np.any(bad):
        return None, "dual-infeasible column pinned at an infinite bound"
    at_upper[in_basis] = False
    return (basis.copy(), in_basis, at_upper, Binv, y, d), None


def fill_warm_basis(new_basis, n_new: int, m: int):
    """Shared warm-basis remap tail (shading / dual_reducer): replace
    unmapped (-1) entries with unused slack columns of the new LP;
    returns an int64 basis or None if duplicates remain."""
    used = set(int(b) for b in new_basis if b >= 0)
    free = [n_new + i for i in range(m) if n_new + i not in used]
    out = []
    for b in new_basis:
        if b < 0:
            if not free:
                return None
            b = free.pop(0)
        out.append(int(b))
    if len(set(out)) != m:
        return None
    return np.asarray(out, np.int64)


def _prep(c, A_t, bl, bu, ub, lb, warm_start, tol=1e-7):
    """Shared solver setup: scale, standard form, warm-basis validation.

    Returns (arrs, scale, m, n, (basis0, at_upper0, winit, wnote)) where
    arrs is None for an infeasible box, winit is the validated warm state
    (basis, in_basis, at_upper, Binv, y, d) or None for a cold start, and
    wnote records why a requested warm basis was rejected (else None).
    """
    c = np.asarray(c, np.float64)
    A_t = np.atleast_2d(np.asarray(A_t, np.float64))
    m, n = A_t.shape
    scale = row_scaling(A_t)
    A_t = A_t * scale[:, None]
    bl = np.asarray(bl, np.float64) * scale
    bu = np.asarray(bu, np.float64) * scale
    cf, A, l, u = standard_form(c, A_t, bl, bu, np.asarray(ub, np.float64))
    if lb is not None:
        l[:n] = lb
    N = n + m
    if np.any(l > u + tol):
        return None, scale, m, n, None
    wb, wh = _unpack_warm(warm_start)
    winit, wnote = (None, None) if wb is None else \
        _warm_state(cf, A, l, u, wb, wh, tol)
    if wnote is not None:
        wnote = f"warm_start_rejected: {wnote}; cold start used"
    if winit is None:
        basis0, _, at_upper0 = _cold_start(cf, l, n, N)
    else:
        basis0, _, at_upper0 = winit[:3]
    return (cf, A, l, u), scale, m, n, (basis0, at_upper0, winit, wnote)


def solve_lp_np(c, A_t, bl, bu, ub, *, lb: Optional[np.ndarray] = None,
                max_iters: int = 5000, tol: float = 1e-7,
                warm_start=None,
                refactor_every: int = REFACTOR_EVERY,
                budget: Optional[SolveBudget] = None,
                monitor: Optional[NumericalMonitor] = None) -> LPResult:
    """Bounded revised dual simplex with BFRT (numpy twin).

    Maintains Binv (rank-1 product-form updates), reduced costs d (one
    O(n) axpy per pivot) and xB (O(m*|flips|)) incrementally; the pricing
    matvec ``rho @ A`` is the only O(mn) work per iteration.

    ``budget=`` bounds wall clock and pivots (status BUDGET on
    truncation); ``monitor=`` collects numerical-health events.  The
    solver checks Binv residual drift every ``monitor.drift_check_every``
    pivots and tracks degenerate-pivot streaks: a streak of
    ``stall_refactor`` forces a refactorization, ``stall_bland``
    escalates to Bland's-rule pivoting (smallest-index row/column, no
    bound flips) until a non-degenerate pivot resumes progress.
    """
    arrs, scale, m, n, start = _prep(c, A_t, bl, bu, ub, lb, warm_start,
                                     tol)
    N = n + m
    if arrs is None:
        return LPResult(INFEASIBLE, np.zeros(n), 0.0, 0,
                        np.arange(n, N), np.zeros(N, bool), np.zeros(m))
    cf, A, l, u = arrs
    basis0, at_upper0, winit, wnote = start
    notes = [] if wnote is None else [wnote]
    mon = monitor if monitor is not None else NumericalMonitor()
    if budget is not None:
        budget.start()
    basis = basis0.copy()
    at_upper = at_upper0.copy()
    in_basis = np.zeros(N, bool)
    in_basis[basis] = True
    if winit is not None:
        # reuse the factors computed during warm-basis validation
        _, _, _, Binv, y, d = winit
        xN = np.where(in_basis, 0.0, np.where(at_upper, u, l))
        xN[basis] = 0.0
        xB = -Binv @ (A @ xN)
        since = 0
    else:
        Binv = np.eye(m)
        xB = np.zeros(m)
        y = np.zeros(m)
        d = cf.copy()
        since = refactor_every      # force a full factorization first

    def refresh():
        nonlocal Binv, xB, y, d, since
        Binv = np.linalg.inv(A[:, basis])
        xN = np.where(in_basis, 0.0, np.where(at_upper, u, l))
        xN[basis] = 0.0
        xB = -Binv @ (A @ xN)
        y = Binv.T @ cf[basis]
        d = cf - A.T @ y
        d[basis] = 0.0
        since = 0

    status = ITER_LIMIT
    iters = 0
    stall = 0
    bland = False
    for iters in range(1, max_iters + 1):
        if budget is not None and (budget.out_of_time()
                                   or iters > budget.remaining_pivots()):
            status = BUDGET
            notes.append(f"budget: truncated at pivot {iters - 1}")
            break
        if since >= refactor_every:
            refresh()
        Binv = faults.perturb(faults.BINV, Binv)
        if iters % mon.drift_check_every == 0:
            resid = float(np.abs(Binv @ A[:, basis] - np.eye(m)).max())
            if mon.record_resid(resid):
                if mon.drift_refactors <= 3:
                    notes.append(f"drift: |BinvB-I|={resid:.2e} -> "
                                 "refactorize")
                refresh()
        lB, uB = l[basis], u[basis]
        viol_lo = lB - xB
        viol_hi = xB - uB
        viol = np.maximum(viol_lo, viol_hi)
        r = int(np.argmax(viol))
        if viol[r] <= tol and since > 0:
            # about to declare optimality on drifted factors: refactorize
            # and re-check so the certificate is exact
            refresh()
            viol_lo = lB - xB
            viol_hi = xB - uB
            viol = np.maximum(viol_lo, viol_hi)
            r = int(np.argmax(viol))
        if viol[r] <= tol:
            status = OPTIMAL
            break
        if bland:
            # Bland anti-cycling: leave the violated row whose BASIC
            # VARIABLE index is smallest — row position alone does not
            # carry the finiteness guarantee (bases reorder across pivots)
            r = int(np.argmin(np.where(viol > tol, basis, N)))
        above = viol_hi[r] >= viol_lo[r]
        delta = xB[r] - (uB[r] if above else lB[r])
        s = 1.0 if delta > 0 else -1.0

        rho = Binv[r]
        alpha = rho @ A           # pricing: the single O(mn) sweep, ∥ over n

        sa = s * alpha
        elig = (~in_basis) & (
            ((~at_upper) & (sa > tol)) | (at_upper & (sa < -tol)))
        if not np.any(elig):
            if since > 0:         # could be drift: retry on fresh factors
                refresh()
                continue
            status = INFEASIBLE
            break
        ratio = np.where(elig, d / np.where(np.abs(sa) > tol, sa, 1.0), np.inf)
        ratio = np.where(elig, np.maximum(ratio, 0.0), np.inf)

        if bland:
            # Bland's rule: smallest-index min-ratio column, no bound
            # flips — finite (anti-cycling) at the cost of progress/pivot
            rmin = float(np.min(ratio))
            q = int(np.argmax(elig & (ratio <= rmin + 1e-12)))
            flips = np.empty(0, np.int64)
            mon.bland_pivots += 1
        else:
            # ---- BFRT: walk breakpoints in ratio order, flipping bounds
            # while the remaining infeasibility budget allows (App. C.3).
            width = u - l
            flip_cost = np.full(N, np.inf)
            flip_cost[elig] = np.abs(alpha[elig]) * width[elig]
            order = np.argsort(ratio, kind="stable")
            k_elig = int(np.sum(elig))
            cand = order[:k_elig]
            csum = np.cumsum(flip_cost[cand])
            flip_budget = abs(delta)
            cross = int(np.searchsorted(csum, flip_budget - 1e-12))
            if cross >= k_elig:
                if since > 0:     # dual unbounded on stale factors: re-check
                    refresh()
                    continue
                status = INFEASIBLE   # dual unbounded: flips cannot absorb
                break
            q = int(cand[cross])
            flips = cand[:cross]

        # ---- incremental pivot (no inv, no full d recompute) ----
        leave = basis[r]
        w = Binv @ A[:, q]                    # entering column in B coords
        if abs(w[r]) < 1e-11:
            # numerically unsafe pivot on drifted factors; fresh factors
            # guarantee |w[r]| = |alpha_q| > tol.  Checked BEFORE any flip
            # is applied so the retry restarts from a consistent state.
            if since > 0:
                refresh()
                continue
            break                             # cannot happen; keep ITER_LIMIT
        if len(flips):
            # bound flips move xB by -Binv A[:,flips] dx: O(m * |flips|)
            dxf = np.where(at_upper[flips], l[flips] - u[flips],
                           u[flips] - l[flips])
            xB -= Binv @ (A[:, flips] @ dxf)
            at_upper[flips] = ~at_upper[flips]
        target = uB[r] if above else lB[r]
        t = (xB[r] - target) / w[r]
        xq = u[q] if at_upper[q] else l[q]
        xB -= t * w
        xB[r] = xq + t
        theta = d[q] / w[r]
        d -= theta * alpha                    # one O(n) axpy
        d[q] = 0.0
        d[leave] = -theta
        y += theta * rho
        # Sherman–Morrison / product-form rank-1 update of Binv
        Binv_r = Binv[r] / w[r]
        Binv -= np.outer(w, Binv_r)
        Binv[r] = Binv_r
        at_upper[leave] = above
        at_upper[q] = False
        in_basis[leave] = False
        in_basis[q] = True
        basis[r] = q
        since += 1

        # ---- anti-cycling: degenerate (theta ~ 0) pivot streaks ----
        if abs(theta) <= THETA_EPS:
            stall += 1
            if stall == mon.stall_refactor:
                mon.stall_refactors += 1
                mon.stall_events += 1
                since = refactor_every          # force refresh next pivot
            if stall >= mon.stall_bland and not bland:
                bland = True
                mon.stall_events += 1
                notes.append(f"stall: {stall} degenerate pivots -> "
                             "Bland's rule")
        elif stall:
            stall = 0
            bland = False                       # progress resumed

    if budget is not None:
        budget.charge_pivots(iters)
    # final answer always from a fresh factorization
    Binv = np.linalg.inv(A[:, basis])
    xN = np.where(in_basis, 0.0, np.where(at_upper, u, l))
    xN[basis] = 0.0
    xB = -Binv @ (A @ xN)
    x = xN.copy()
    x[basis] = xB
    y = Binv.T @ cf[basis]
    obj_min = float(cf @ np.where(np.isfinite(x), x, 0.0))
    return LPResult(status, x[:n], obj_min, iters, basis.copy(),
                    at_upper.copy(), y * scale,   # duals in original units
                    notes=tuple(notes))


# ----------------------------------------------------------------- JAX twin

import jax
import jax.numpy as jnp
from functools import partial


def _refreshed(cf, A, l, u, basis, in_basis, at_upper):
    """Full refactorization of the revised-simplex factor state.  Shared
    by the single-instance jitted twin and the batched bound-variant
    engine (``repro.core.lp_batch``), which vmaps it over instances."""
    Binv = jnp.linalg.inv(A[:, basis])
    # NOTE: masked selects, not ``.at[basis].set`` scatters — a vmapped
    # scatter lowers to a K*m-trip sequential loop on CPU; ``in_basis``
    # is the exact membership mask of ``basis`` by invariant
    xN = jnp.where(in_basis, 0.0, jnp.where(at_upper, u, l))
    xB = -Binv @ (A @ xN)
    y = Binv.T @ cf[basis]
    d = jnp.where(in_basis, 0.0, cf - A.T @ y)
    return Binv, xB, d, y


def _init_pivot_state(cf, A, basis0, at_upper0, refactor_every):
    """Loop-carried state tuple for ``_pivot_iter``.  ``since`` starts at
    ``refactor_every`` so the first iteration factorizes from the basis,
    cold and warm alike."""
    m = A.shape[0]
    N = A.shape[1]
    in_basis0 = jnp.any(jnp.arange(N) == basis0[:, None], axis=0)
    at_upper0 = at_upper0 & ~in_basis0
    return (basis0, in_basis0, at_upper0, jnp.eye(m, dtype=A.dtype),
            jnp.zeros(m, A.dtype), cf, jnp.zeros(m, A.dtype),
            jnp.int32(0), jnp.bool_(False), jnp.int32(0), jnp.int32(0),
            jnp.int32(ITER_LIMIT), jnp.int32(0),
            jnp.int32(refactor_every))


# state-tuple field positions shared with repro.core.lp_batch
_STATE_STATUS = 11
_STATE_IT = 12


def _factor_refresh(cf, A, l, u, state):
    """Unconditional refactorization of the loop-carried state — the
    shared body of both refresh sites in ``_pivot_iter``.  The batched
    engine (``repro.core.lp_batch``) calls this directly under a
    batch-level ``lax.cond`` so the O(m^3) inverse only lowers when some
    lane actually needs it (a vmapped per-lane cond would execute it for
    every lane on every iteration)."""
    (basis, in_basis, at_upper, Binv, xB, d, y, stall, bland, n_bland,
     n_drift, status, it, since) = state
    Binv, xB, d, y = _refreshed(cf, A, l, u, basis, in_basis, at_upper)
    return (basis, in_basis, at_upper, Binv, xB, d, y, stall, bland,
            n_bland, n_drift, status, it, jnp.int32(0))


def _drift_gate(A, refactor_every, state):
    """Numerical-health check: residual drift of the rank-1-updated
    inverse (or the periodic cadence) demands a refactorization.  The
    m×m residual costs nothing next to the O(mn) pricing pass.  Returns
    ``(state with the drift event counted, need_refresh)``."""
    (basis, in_basis, at_upper, Binv, xB, d, y, stall, bland, n_bland,
     n_drift, status, it, since) = state
    m = A.shape[0]
    resid = jnp.abs(Binv @ A[:, basis]
                    - jnp.eye(m, dtype=A.dtype)).max()
    drift = (resid > DRIFT_TOL) & (since > 0)
    n_drift = n_drift + drift.astype(jnp.int32)
    state = (basis, in_basis, at_upper, Binv, xB, d, y, stall, bland,
             n_bland, n_drift, status, it, since)
    return state, drift | (since >= refactor_every)


def _optimal_suspect_gate(l, u, tol, state):
    """Optimality suspected on stale factors -> the caller must
    refactorize and re-check before declaring."""
    basis, xB, since = state[0], state[4], state[13]
    lB, uB = l[basis], u[basis]
    viol = jnp.maximum(lB - xB, xB - uB)
    return (viol[jnp.argmax(viol)] <= tol) & (since > 0)


def _pivot_iter(cf, A, l, u, tol, refactor_every, state):
    """One revised-dual-simplex pivot — the jitted twin's while body.

    Pure function of ``(cf, A, l, u, tol)`` and the loop-carried
    ``state`` tuple (see ``_init_pivot_state``).  ``repro.core.lp_batch``
    runs the same pieces (``_drift_gate`` / ``_factor_refresh`` /
    ``_pivot_core``) vmapped over K bound-variants ``(l, u, tol, state)``
    of one shared ``(cf, A)`` with the refresh conds hoisted to batch
    level, so any change to the pivot rule here applies to both engines
    identically.
    """
    state, need = _drift_gate(A, refactor_every, state)
    # repro: allow[REPRO001] each refresh lambda below is a fresh
    # function identity per trace of this body capturing the same
    # (cf, A, l, u), so the identity-cached branch jaxpr is correct
    state = jax.lax.cond(
        need, lambda s: _factor_refresh(cf, A, l, u, s), lambda s: s,
        state)
    # repro: allow[REPRO001] fresh lambda identity, same captures
    state = jax.lax.cond(
        _optimal_suspect_gate(l, u, tol, state),
        lambda s: _factor_refresh(cf, A, l, u, s), lambda s: s, state)
    return _pivot_core(cf, A, l, u, tol, refactor_every, state)


def _pivot_core(cf, A, l, u, tol, refactor_every, state, active=None):
    """The pivot proper: BFRT column selection + Sherman–Morrison
    update, on factors the caller has already refreshed as needed.

    ``active`` (batched engine only): a scalar bool tracer; when False
    the WHOLE state passes through unchanged.  The array fields are
    already gated by ``do_pivot``, so freezing a lane costs a handful
    of scalar selects instead of the full 14-array tree-select the
    batched loop body used to pay per trip."""
    (basis, in_basis, at_upper, Binv, xB, d, y, stall, bland, n_bland,
     n_drift, status, it, since) = state
    N = A.shape[1]
    lB, uB = l[basis], u[basis]
    viol_lo = lB - xB
    viol_hi = xB - uB
    viol = jnp.maximum(viol_lo, viol_hi)
    r_max = jnp.argmax(viol)
    done = viol[r_max] <= tol
    # Bland mode: violated row with the smallest BASIC VARIABLE index
    # (row position alone does not carry the finiteness guarantee)
    r_bland = jnp.argmin(jnp.where(viol > tol, basis, N))
    r = jnp.where(bland, r_bland, r_max)

    above = viol_hi[r] >= viol_lo[r]
    delta = jnp.where(above, xB[r] - uB[r], xB[r] - lB[r])
    s = jnp.where(delta > 0, 1.0, -1.0)
    rho = Binv[r]
    alpha = rho @ A                 # pricing: the single O(mn) sweep

    sa = s * alpha
    elig = (~in_basis) & (
        ((~at_upper) & (sa > tol)) | (at_upper & (sa < -tol)))
    any_elig = jnp.any(elig)
    ratio = jnp.where(elig,
                      jnp.maximum(d / jnp.where(jnp.abs(sa) > tol, sa, 1.0),
                                  0.0), jnp.inf)
    width = u - l
    flip_cost = jnp.where(elig, jnp.abs(alpha) * width, 0.0)

    order = jnp.argsort(ratio)
    csum_all = jnp.cumsum(flip_cost[order])
    flip_budget = jnp.abs(delta)
    elig_sorted = elig[order]
    crossed = (csum_all >= flip_budget - 1e-12) & elig_sorted
    cross_pos = jnp.argmax(crossed)          # first True (0 if none)
    # Bland mode: smallest-index min-ratio column, no bound flips
    rmin = jnp.min(ratio)
    q_bland = jnp.argmax(elig & (ratio <= rmin + 1e-12))
    has_cross = jnp.any(crossed) | (bland & any_elig)
    q = jnp.where(bland, q_bland, order[cross_pos])
    # only flip breakpoints strictly before the crossing in sorted
    # order; argsort is stable, so "sorted before q" is exactly the
    # lexicographic compare on (ratio, index) — no inverse-permutation
    # scatter (which lowers to a K*N-trip sequential loop when vmapped)
    iN = jnp.arange(N)
    flip_mask = (elig & ~bland
                 & ((ratio < ratio[q])
                    | ((ratio == ratio[q]) & (iN < q))))

    stale = since > 0
    w = Binv @ A[:, q]
    # numerically unsafe pivot (possible only on drifted factors;
    # fresh factors guarantee |w[r]| = |alpha_q| > tol) -> no pivot,
    # force a refactorize-and-retry like the numpy twin
    unsafe = jnp.abs(w[r]) < 1e-11
    no_pivot = ~any_elig | ~has_cross
    # infeasibility on stale factors: force a refactorize-and-retry
    # instead of declaring; on fresh factors it is genuine
    new_status = jnp.where(done, OPTIMAL,
                           jnp.where(no_pivot & ~stale, INFEASIBLE,
                                     ITER_LIMIT)).astype(jnp.int32)
    do_pivot = (new_status == ITER_LIMIT) & ~no_pivot & ~unsafe
    if active is not None:
        do_pivot = do_pivot & active

    # ---- incremental pivot ----
    # single-index updates are one-hot selects, not ``.at[i].set``
    # scatters: a vmapped 1-element scatter lowers to a K-trip
    # sequential loop on CPU, ~10 of which used to dominate the batched
    # engine's per-iteration cost
    leave = basis[r]
    im = jnp.arange(Binv.shape[0])
    dxN = jnp.where(flip_mask,
                    jnp.where(at_upper, l - u, u - l), 0.0)
    xB2 = xB - Binv @ (A @ dxN)     # flip absorption (masked matvec)
    at_upper_f = at_upper ^ flip_mask
    wr = jnp.where(unsafe, 1.0, w[r])
    target = jnp.where(above, uB[r], lB[r])
    t = (xB2[r] - target) / wr
    xq = jnp.where(at_upper_f[q], u[q], l[q])
    xB3 = jnp.where(im == r, xq + t, xB2 - t * w)
    theta = d[q] / wr
    d2 = jnp.where(iN == leave, -theta,
                   jnp.where(iN == q, 0.0, d - theta * alpha))
    y2 = y + theta * rho
    Binv_r = Binv[r] / wr
    Binv2 = jnp.where((im == r)[:, None], Binv_r[None, :],
                      Binv - jnp.outer(w, Binv_r))
    at_upper2 = jnp.where(iN == q, False,
                          jnp.where(iN == leave, above, at_upper_f))
    in_basis2 = jnp.where(iN == q, True,
                          jnp.where(iN == leave, False, in_basis))
    basis2 = jnp.where(im == r, q.astype(basis.dtype), basis)

    basis = jnp.where(do_pivot, basis2, basis)
    in_basis = jnp.where(do_pivot, in_basis2, in_basis)
    at_upper = jnp.where(do_pivot, at_upper2, at_upper)
    Binv = jnp.where(do_pivot, Binv2, Binv)
    xB = jnp.where(do_pivot, xB3, xB)
    d = jnp.where(do_pivot, d2, d)
    y = jnp.where(do_pivot, y2, y)
    since = jnp.where(do_pivot, since + 1,
                      jnp.where((no_pivot | unsafe) & stale,
                                jnp.int32(refactor_every), since))

    # ---- anti-cycling: degenerate (theta ~ 0) pivot streaks ----
    degen = do_pivot & (jnp.abs(theta) <= THETA_EPS)
    progress = do_pivot & (jnp.abs(theta) > THETA_EPS)
    n_bland = n_bland + (bland & do_pivot).astype(jnp.int32)
    stall = jnp.where(progress, 0,
                      jnp.where(degen, stall + 1, stall))
    bland = jnp.where(progress, False,
                      bland | (stall >= STALL_BLAND))
    since = jnp.where(degen & (stall == STALL_REFACTOR),
                      jnp.int32(refactor_every), since)
    it2 = it + 1
    if active is not None:
        # frozen lane: every scalar field passes through (array fields
        # are already unchanged because do_pivot is False)
        st0 = state
        new_status = jnp.where(active, new_status, st0[11])
        it2 = jnp.where(active, it2, st0[12])
        since = jnp.where(active, since, st0[13])
        stall = jnp.where(active, stall, st0[7])
        bland = jnp.where(active, bland, st0[8])
        n_bland = jnp.where(active, n_bland, st0[9])
    return (basis, in_basis, at_upper, Binv, xB, d, y,
            stall.astype(jnp.int32), bland, n_bland, n_drift,
            new_status, it2.astype(jnp.int32),
            since.astype(jnp.int32))


def _gather_solution(cf, l, u, basis, in_basis, at_upper, xB):
    """Assemble the FULL (n+m,) primal vector and objective from basic
    values ``xB`` (factors already fresh — see ``_extract_solution``)."""
    xN = jnp.where(in_basis, 0.0, jnp.where(at_upper, u, l))
    # scatter-free x[basis[i]] = xB[i]: gather the basis row position of
    # each in-basis column (a vmapped scatter would run as a sequential
    # K*m-trip loop on CPU)
    iN = jnp.arange(xN.shape[0])
    pos = jnp.argmax(basis[:, None] == iN[None, :], axis=0)
    x = jnp.where(in_basis, xB[pos], xN)
    obj = cf @ jnp.where(jnp.isfinite(x), x, 0.0)
    return x, obj


def _extract_solution(cf, A, l, u, basis, in_basis, at_upper):
    """Final answer from a fresh factorization (mirrors the numpy twin's
    exit path); returns the FULL (n+m,) primal vector."""
    _, xB, _, y = _refreshed(cf, A, l, u, basis, in_basis, at_upper)
    x, obj = _gather_solution(cf, l, u, basis, in_basis, at_upper, xB)
    return x, obj, y


@partial(jax.jit, static_argnames=("max_iters", "refactor_every"))
def _solve_lp_jax(cf, A, l, u, basis0, at_upper0, max_iters: int,
                  refactor_every: int = REFACTOR_EVERY):
    n = A.shape[1] - A.shape[0]
    tol = 1e-7

    def cond(state):
        status, it = state[_STATE_STATUS], state[_STATE_IT]
        return (status == ITER_LIMIT) & (it < max_iters)

    def body(state):
        return _pivot_iter(cf, A, l, u, tol, refactor_every, state)

    # since=refactor_every in the initial state: factorize on entry
    state = _init_pivot_state(cf, A, basis0, at_upper0, refactor_every)
    state = jax.lax.while_loop(cond, body, state)
    (basis, in_basis, at_upper, _, _, _, _, _, _, n_bland, n_drift,
     status, it, _) = state
    x, obj, y = _extract_solution(cf, A, l, u, basis, in_basis, at_upper)
    return status, x[:n], obj, it, basis, at_upper, y, n_bland, n_drift


def solve_lp(c, A_t, bl, bu, ub, *, lb: Optional[np.ndarray] = None,
             max_iters: int = 5000, warm_start=None,
             mesh=None, budget: Optional[SolveBudget] = None,
             monitor: Optional[NumericalMonitor] = None) -> LPResult:
    """JAX revised dual simplex (jit + while_loop).  Same conventions as
    solve_lp_np, including the warm-start and budget/monitor contracts.
    (Wall-clock cannot be polled inside jit, so the deadline is enforced
    between LP calls and via the pivot cap, which is rounded to a coarse
    granularity so the jitted twin sees few distinct static ``max_iters``
    values instead of retracing per call.)

    ``mesh=``: a ``jax.sharding.Mesh`` routes the solve through the
    DISTRIBUTED pricing backend (``repro.core.distributed.solve_lp_dist``):
    A and the maintained reduced costs stay resident as column-sharded
    arrays across pivots, pricing is the lone O(mn/p) pass per pivot on
    each device, and only the O(num_buckets) BFRT histogram (+ the tiny
    exact in-bucket candidate gather) moves between devices.  ``mesh=None``
    keeps the single-host jit path.
    """
    if mesh is not None:
        from repro.core.distributed import solve_lp_dist
        return solve_lp_dist(c, A_t, bl, bu, ub, lb=lb,
                             max_iters=max_iters, warm_start=warm_start,
                             mesh=mesh, budget=budget, monitor=monitor)
    arrs, scale, m, n, start = _prep(c, A_t, bl, bu, ub, lb, warm_start)
    if arrs is None:
        return LPResult(INFEASIBLE, np.zeros(n), 0.0, 0,
                        np.arange(n, n + m), np.zeros(n + m, bool),
                        np.zeros(m))
    cf, A, l, u = arrs
    basis0, at_upper0, _, wnote = start
    notes = [] if wnote is None else [wnote]
    cap = max_iters
    if budget is not None:
        budget.start()
        if budget.out_of_time() or budget.remaining_pivots() <= 0:
            notes.append("budget: exhausted before LP solve")
            return LPResult(BUDGET, np.zeros(n), 0.0, 0,
                            np.asarray(basis0),
                            np.asarray(at_upper0, bool), np.zeros(m),
                            notes=tuple(notes))
        cap = budget.lp_iter_cap(max_iters)
    # one explicit device->host pull for the whole result tuple: implicit
    # scalar syncs (int(status), float(obj)) are each a separate blocking
    # transfer and fail under the strict_numerics transfer guard
    status, x, obj, it, basis, at_upper, y, n_bland, n_drift = \
        jax.device_get(_solve_lp_jax(
            jnp.asarray(cf), jnp.asarray(A), jnp.asarray(l),
            jnp.asarray(u), jnp.asarray(basis0), jnp.asarray(at_upper0),
            cap))
    status, it = int(status), int(it)
    n_bland, n_drift = int(n_bland), int(n_drift)
    if n_bland:
        notes.append(f"stall: Bland's rule for {n_bland} pivots")
    if n_drift:
        notes.append(f"drift: {n_drift} forced refactorizations")
    if monitor is not None:
        monitor.bland_pivots += n_bland
        monitor.drift_refactors += n_drift
        if n_bland:
            monitor.stall_events += 1
    if budget is not None:
        budget.charge_pivots(it)
        if status == ITER_LIMIT and (cap < max_iters
                                     or budget.exhausted()):
            status = BUDGET
            notes.append(f"budget: truncated at pivot cap {cap}")
    return LPResult(status, np.asarray(x), float(obj), it,
                    np.asarray(basis), np.asarray(at_upper),
                    np.asarray(y) * scale, notes=tuple(notes))


# ------------------------------------------------------- certificate check


def verify_optimality(res: LPResult, c, A_t, bl, bu, ub,
                      lb: Optional[np.ndarray] = None,
                      tol: float = 1e-5) -> Tuple[bool, str]:
    """Independent optimality certificate (numpy, no solver internals).

    x* is optimal iff (i) primal feasible and (ii) there exist duals y with
    reduced costs d = c - Aᵀy satisfying d_j >= 0 at lower bounds,
    d_j <= 0 at upper bounds, d_j = 0 for strictly interior x_j.  We check
    the basis-derived y, which by LP theory certifies optimality if valid.
    """
    c = np.asarray(c, np.float64)
    A_t = np.atleast_2d(np.asarray(A_t, np.float64))
    m, n = A_t.shape
    cf, A, l, u = standard_form(c, A_t, np.asarray(bl, np.float64),
                                np.asarray(bu, np.float64),
                                np.asarray(ub, np.float64))
    if lb is not None:
        l[:n] = lb
    x = res.x
    # primal feasibility
    if np.any(x < l[:n] - tol) or np.any(x > u[:n] + tol):
        return False, "primal bounds violated"
    act = A_t @ x
    if np.any(act < np.asarray(bl) - tol) or np.any(act > np.asarray(bu) + tol):
        return False, "constraint bounds violated"
    # dual feasibility + complementary slackness
    sf = np.concatenate([x, act])
    d = cf - A.T @ res.y
    at_lo = sf <= l + tol
    at_hi = sf >= u - tol
    interior = ~(at_lo | at_hi)
    if np.any(np.abs(d[interior]) > tol * (1 + np.abs(cf[interior]))):
        return False, "nonzero reduced cost at interior variable"
    bad_lo = at_lo & ~at_hi & (d < -tol)
    bad_hi = at_hi & ~at_lo & (d > tol)
    if np.any(bad_lo) or np.any(bad_hi):
        return False, "reduced-cost sign violation"
    return True, "optimal certificate valid"
