"""Shading + Progressive Shading — paper §2, Algorithms 1 and 2.

Each Shading step solves the LP relaxation over the current candidate set at
layer l (Parallel Dual Simplex), keeps the support, and expands/augments via
Neighbor Sampling down to layer l-1.  At layer 0, Dual Reducer produces the
final package.

Warm starts down the cascade (App. C customization): consecutive layer LPs
share the m slack columns and their structural columns are related by the
parent/child group structure, so layer l's final basis is re-mapped onto
layer l-1's candidate set by ``map_warm_basis`` — each basic group maps to
its surviving child representative closest in objective value, slacks map
index-shifted, and every other (new) column enters nonbasic at the bound
matching the sign of its reduced cost, which keeps the start dual-feasible
(core.lp warm-start contract).  The engine validates the mapped basis and
silently falls back to a cold start when it is singular, so warm starting
can only change iteration counts, never answers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core.dual_reducer import PackageResult, dual_reducer
from repro.core.hierarchy import Hierarchy
from repro.core.lp import (INFEASIBLE, OPTIMAL, LPResult, WarmStart,
                           fill_warm_basis, solve_lp_np)
from repro.core.lp_batch import solve_lp_batch
from repro.core.neighbor import neighbor_sampling
from repro.core.paql import PackageQuery
from repro.core.relation import gather_column

FALLBACK_SEED = 64   # LP-infeasible layer: seed with top-k by objective


def _expand_warm(res: LPResult, pos: np.ndarray, n_old: int,
                 n_new: int) -> WarmStart:
    """Re-index an LP state over n_old columns onto a superset LP with
    n_new columns; ``pos[j]`` is old column j's position in the new set
    (slacks shift by the new n).  Used by the batched ladder to carry
    the failed layer LP's basis into the union candidate set."""
    m = len(res.y)
    basis = np.asarray(res.basis, np.int64)
    struct = basis < n_old
    safe = np.minimum(basis, n_old - 1)
    new_basis = np.where(struct, pos[safe], n_new + (basis - n_old))
    at_upper = np.zeros(n_new + m, bool)
    at_upper[pos] = res.at_upper[:n_old]
    at_upper[n_new:] = res.at_upper[n_old:]
    return WarmStart(new_basis.astype(np.int64), at_upper)


def map_warm_basis(hier: Hierarchy, l: int, S_l: np.ndarray,
                   res: Optional[LPResult], S_next: np.ndarray,
                   obj_attr: Optional[str] = None) -> Optional[WarmStart]:
    """Re-map layer-l LP basis/bound state onto the layer-(l-1) LP.

    Column j of the layer-l LP is group ``S_l[j]``; column i of the next LP
    is the layer-(l-1) representative ``S_next[i]`` whose parent group is
    ``hier.layers[l].part.gid[S_next[i]]``.  Basic groups map to their
    child in S_next with the closest objective value (the group rep is the
    member mean, so the closest child is the best stand-in for the basic
    column); slacks shift by the new n.  Unmappable basic columns are
    replaced by unused slacks — the engine's validation rejects the basis
    if that ever makes it singular.
    """
    if res is None:
        return None
    part = hier.layers[l].part
    if part is None:
        return None
    n_prev, n_next = len(S_l), len(S_next)
    m = len(res.y)
    S_next = np.asarray(S_next, np.int64)
    parent = part.gid[S_next]                    # parent group per candidate
    order = np.argsort(parent, kind="stable")
    parent_sorted = parent[order]

    attr = obj_attr if obj_attr in hier.attrs else hier.attrs[0]
    # candidate-only gathers: layer l-1 may be a streamed layer-0 relation,
    # so only the S_next rows are ever materialised
    obj_next_S = gather_column(hier.layers[l - 1].table, attr, S_next)
    obj_prev = np.asarray(hier.layers[l].table[attr], np.float64)

    new_basis = np.full(m, -1, np.int64)
    for k, j in enumerate(np.asarray(res.basis, np.int64)):
        if j >= n_prev:                          # slack i -> slack i
            new_basis[k] = n_next + (j - n_prev)
            continue
        g = int(S_l[j])
        lo = np.searchsorted(parent_sorted, g, side="left")
        hi = np.searchsorted(parent_sorted, g, side="right")
        if hi > lo:                              # children present in S_next
            cand = order[lo:hi]
            new_basis[k] = int(cand[np.argmin(
                np.abs(obj_next_S[cand] - obj_prev[g]))])
    new_basis = fill_warm_basis(new_basis, n_next, m)
    if new_basis is None:
        return None
    # bound-side hint: children inherit their parent group's side
    au_prev = np.zeros(hier.layers[l].size, bool)
    au_prev[np.asarray(S_l, np.int64)] = res.at_upper[:n_prev]
    at_upper = np.concatenate([au_prev[parent], res.at_upper[n_prev:]])
    return WarmStart(new_basis, at_upper)


def shading(hier: Hierarchy, l: int, alpha: int, S_l: np.ndarray,
            query: PackageQuery, *, max_lp_iters: int = 20000,
            layer_solver: str = "lp", sampler: str = "neighbor",
            rng: Optional[np.random.Generator] = None,
            warm_start=None, return_state: bool = False,
            lp_solver=None, budget=None, report=None, widen=None,
            ladder: bool = True, skip_lp: bool = False):
    """One Shading step (Algorithm 2): layer-l candidates -> layer-(l-1).

    Ablation knobs (paper Mini-Experiments 1 and 2):
      layer_solver: 'lp' (paper's choice) | 'ilp' (replace line 2 with an
        ILP — shown not to help);
      sampler: 'neighbor' (Algorithm 3) | 'random' (random representative
        sampling — shown much worse).
    warm_start: optional basis for the layer LP (see map_warm_basis);
    return_state: also return ``(S_next, res, S_used, s_prime)`` — the
      layer LPResult (None for the ilp ablation), the candidate set the
      LP actually solved over (α escalation can widen it, and the basis
      indices only make sense against it), and the surviving support —
      so progressive_shading can warm-start the next layer and widen on
      failure.
    lp_solver: solve_lp_np-compatible callable for the layer LP (default
      the numpy twin; pass e.g. ``partial(solve_lp, mesh=mesh)`` to run
      the cascade through the distributed pricing backend).

    Guard integration (``budget``/``report``: guard objects threaded from
    the engine).  With ``ladder=True`` a failed layer LP degrades in
    order instead of silently seeding: (1) warm retry at relaxed
    tolerance, (2) re-solve over a widened candidate set (``widen(2)``,
    α escalation — the paper's premature-discard remedy), (3) the
    top-objective seed fallback below, each recorded as a rung.
    ``skip_lp=True`` (budget exhausted upstream) bypasses the layer LP
    entirely and descends via the seed path.
    """
    lp_solver = lp_solver or solve_lp_np
    monitor = report.monitor if report is not None else None
    layer_table = hier.layers[l].table
    S_used = np.asarray(S_l)
    res: Optional[LPResult] = None

    def _lp(S_cols, warm, solver=None, **extra):
        c, A, bl, bu, ub = query.matrices(layer_table, S_cols)
        kw = dict(extra)
        if budget is not None:
            kw["budget"] = budget
        if monitor is not None:
            kw["monitor"] = monitor
        return (solver or lp_solver)(c, A, bl, bu, ub,
                                     max_iters=max_lp_iters,
                                     warm_start=warm, **kw)

    if skip_lp:
        s_prime = np.zeros(0, np.int64)
    elif layer_solver == "ilp":
        from repro.core.ilp import solve_ilp
        c, A, bl, bu, ub = query.matrices(layer_table, S_used)
        res_i = solve_ilp(c, A, bl, bu, ub, max_nodes=100, time_limit_s=10,
                          budget=budget, monitor=monitor)
        s_prime = S_used[res_i.x > 1e-9] if res_i.feasible \
            else np.zeros(0, np.int64)
    else:
        res = _lp(S_used, warm_start)
        if report is not None:
            report.absorb_lp(res)
        if res.status != OPTIMAL and ladder:
            retry_wanted = res.status == INFEASIBLE
            # evaluate the widened set up front (neighbor_sampling is
            # deterministic) so both ladder rungs can ride one batched
            # dispatch when they are both in play
            S_wide = None
            if widen is not None and not (budget is not None
                                          and budget.exhausted()):
                S_w = np.asarray(widen(2))
                if len(S_w) > len(S_used):
                    S_wide = S_w
            if retry_wanted and S_wide is not None \
                    and lp_solver is solve_lp_np:
                # both rungs needed: solve them as ONE batched flight of
                # bound-variants over the union candidate set U — the
                # relax-tol retry lane masks non-S_used columns out via
                # ub = 0 (warm from the failed LP's basis, tol 1e-5),
                # the α-escalation lane runs the full U cold.  A
                # degraded rung costs one dispatch, not three solves.
                U = np.union1d(np.asarray(S_used, np.int64),
                               np.asarray(S_wide, np.int64))
                cU, AU, blU, buU, ubU = query.matrices(layer_table, U)
                pos = np.searchsorted(U, np.asarray(S_used, np.int64))
                ub_mask = np.zeros(len(U))
                ub_mask[pos] = ubU[pos]
                lanes = solve_lp_batch(
                    cU, AU, blU, buU, [ub_mask, ubU],
                    tol=[1e-5, 1e-7],
                    warm_starts=[_expand_warm(res, pos, len(S_used),
                                              len(U)), None],
                    max_iters=max_lp_iters, budget=budget,
                    monitor=monitor)
                retry, wide_res = lanes
                if report is not None:
                    report.lp_batches += 1
                    report.rung("layer_relax_tol",
                                detail=f"layer {l}: retry "
                                       f"status={retry.status}")
                    report.absorb_lp(retry)
                if retry.status == OPTIMAL:
                    res = retry
                    S_used = U
                else:
                    if report is not None:
                        report.rung("alpha_escalation",
                                    detail=f"layer {l}: |S| "
                                           f"{len(S_used)} -> "
                                           f"{len(U)}")
                        report.absorb_lp(wide_res)
                    if wide_res.status == OPTIMAL:
                        res = wide_res
                        S_used = U
            else:
                if retry_wanted:
                    # ladder rung 1: warm retry at relaxed tolerance
                    # (numpy twin — the only one with a tol knob)
                    retry = _lp(S_used, res, solver=solve_lp_np, tol=1e-5)
                    if report is not None:
                        report.rung("layer_relax_tol",
                                    detail=f"layer {l}: retry "
                                           f"status={retry.status}")
                        report.absorb_lp(retry)
                    if retry.status == OPTIMAL:
                        res = retry
                if res.status != OPTIMAL and S_wide is not None and not (
                        budget is not None and budget.exhausted()):
                    # ladder rung 2: α escalation — re-solve over a
                    # doubled candidate set (cold: the basis indices
                    # don't transfer)
                    wide_res = _lp(S_wide, None)
                    if report is not None:
                        report.rung("alpha_escalation",
                                    detail=f"layer {l}: |S| "
                                           f"{len(S_used)} -> "
                                           f"{len(S_wide)}")
                        report.absorb_lp(wide_res)
                    if wide_res.status == OPTIMAL:
                        res = wide_res
                        S_used = S_wide
        s_prime = S_used[res.x > 1e-9] if res.status == OPTIMAL \
            else np.zeros(0, np.int64)
    if len(s_prime) == 0:
        # representative-level solve infeasible: seed augmentation with the
        # best-objective representatives so it can still recover
        if report is not None and not skip_lp:
            report.rung("layer_seed_fallback", detail=f"layer {l}")
        obj = layer_table[query.objective_attr][S_used]
        order = np.argsort(-obj if query.maximize else obj, kind="stable")
        s_prime = S_used[order[:FALLBACK_SEED]]

    if sampler == "random":
        rng = rng or np.random.default_rng(0)
        # one vectorized gather for the support's members (batch GetTuples)
        members = [hier.get_tuples_batch(l - 1, np.asarray(s_prime,
                                                           np.int64))]
        seen = set(int(g) for g in s_prime)
        count = sum(len(m) for m in members)
        n_l = hier.layers[l].size
        while count < alpha and len(seen) < n_l:
            g = int(rng.integers(0, n_l))
            if g in seen:
                continue
            seen.add(g)
            m = hier.get_tuples(l - 1, g)
            members.append(m)
            count += len(m)
        cand = np.unique(np.concatenate(members))
        S_next = cand[:alpha]
    else:
        S_next = neighbor_sampling(hier, l, alpha, s_prime,
                                   query.objective_attr, query.maximize)
    if return_state:
        return S_next, res, S_used, s_prime
    return S_next


@dataclasses.dataclass
class PSStats:
    """Cascade-level observability for one progressive_shading call
    (attached to the returned ``PackageResult.ps_stats``)."""
    layer_sizes: list = dataclasses.field(default_factory=list)
    lp_iters: int = 0
    time_s: float = 0.0
    # warm starts that silently fell cold: map_warm_basis re-maps that
    # came back None, plus engine-side basis validations that rejected
    # ("warm_start_rejected" LP notes)
    warm_rejected: int = 0
    cache: str = ""          # "" | "package" | "exact" | "contained"


def _count_warm_rejects(lp_res, stats: PSStats, report) -> None:
    """Surface engine-side warm-start rejections (lp._warm_state notes)."""
    for note in getattr(lp_res, "notes", ()) or ():
        if "warm_start_rejected" in note:
            stats.warm_rejected += 1
            if report is not None:
                report.warm_rejected += 1


def _solve_from_cache(hier, query, table, hit, qcache, *, dr_q,
                      ilp_kwargs, dr_aux, budget, report,
                      stats: PSStats) -> Optional[PackageResult]:
    """Serve a cache hit, or return None to fall back to the cold descent.

    Exact hits with a stored package take the validated fast path:
    ``check_package`` against the relation plus an objective re-compute.
    Every other hit shortcuts to Dual Reducer over the cached layer-0
    candidate set (the pre-prune), warm-started from the cached lp1
    basis; the resulting LP bound must reproduce the cached bound (exact
    hits) or respect containment monotonicity (contained hits), else the
    hit is abandoned.  A private rng keeps the engine rng untouched so
    an abandoned hit leaves the cold descent bit-identical to an
    uncached solve.
    """
    entry = hit.entry
    tol = 1e-6 * max(1.0, abs(entry.lp_bound))
    if hit.exact and qcache.reuse_packages and entry.package_idx is not None:
        idx, mult = entry.package_idx, entry.package_mult
        if query.check_package(table, idx, mult):
            obj = query.objective_value(table, idx, mult)
            if abs(obj - entry.package_obj) <= \
                    1e-6 * max(1.0, abs(entry.package_obj)):
                if report is not None:
                    report.cache_pruned_lps += hier.L + 1
                stats.cache = "package"
                return PackageResult(True, idx.copy(), mult.copy(), obj,
                                     entry.lp_bound,
                                     status="ok cached=package")
        return None
    S0 = entry.candidates(1)
    if S0 is None or len(S0) == 0:
        return None
    warm = hit.warm_for_layer0(hier, query, S0)
    res = dual_reducer(query, table, S0, q=dr_q,
                       rng=np.random.default_rng(0),
                       ilp_kwargs=ilp_kwargs, aux=dr_aux, warm_start=warm,
                       budget=budget, report=report, ladder=False)
    if not res.feasible or res.status != "ok":
        return None
    if hit.exact:
        ok = abs(res.lp_obj - entry.lp_bound) <= tol
    else:
        # containment monotonicity: the tightened query's bound cannot
        # beat the cached (looser) query's bound
        ok = res.lp_obj <= entry.lp_bound + tol if query.maximize \
            else res.lp_obj >= entry.lp_bound - tol
        # quality gate: a pruned solve far off its own LP bound means
        # the cached candidate set lost support this query needed
        gap = (res.lp_obj - res.obj) if query.maximize \
            else (res.obj - res.lp_obj)
        ok &= gap <= qcache.gap_accept * max(1.0, abs(res.lp_obj))
    if not ok:
        return None
    if report is not None:
        report.cache_pruned_lps += hier.L
    stats.cache = hit.kind
    res.status = f"ok cached={hit.kind}"
    return res


def progressive_shading(hier: Hierarchy, query: PackageQuery,
                        table, *,
                        alpha: Optional[int] = None,
                        dr_q: int = 500,
                        rng: Optional[np.random.Generator] = None,
                        ilp_kwargs: Optional[dict] = None,
                        layer_solver: str = "lp",
                        sampler: str = "neighbor",
                        dr_aux: str = "lp",
                        warm_starts: bool = True,
                        lp_solver=None,
                        budget=None, report=None,
                        ladder: bool = True,
                        qcache=None
                        ) -> PackageResult:
    """Algorithm 1: iterate Shading from layer L to 0, then Dual Reducer.

    Each layer's LP is warm-started from the previous layer's final basis
    (``warm_starts=False`` restores the all-cold seed behaviour for
    ablations/benchmarks); the layer-1 basis is likewise re-mapped onto the
    layer-0 candidate set to warm-start Dual Reducer's first LP.
    ``lp_solver`` routes every layer LP through an alternate
    solve_lp_np-compatible engine (e.g. the distributed pricing backend,
    ``functools.partial(solve_lp, mesh=mesh)``).

    Guard integration: one ``budget`` bounds the whole cascade; once it
    is exhausted the remaining layer LPs are skipped (``budget_descend``
    rung, degraded quality: the cascade descends via the top-objective
    seed + Neighbor Sampling instead of solving) so a deadline cannot be
    blown inside a deep hierarchy.  If Dual Reducer fails and budget
    remains, the layer-0 candidate set is rebuilt at double α from the
    layer-1 support and Dual Reducer retried (``dr_alpha_escalation``).

    Cross-query cache (``qcache``: a :class:`repro.core.qcache.QCache`):
    consult-before-descend — a hit serves a validated cached package
    (exact) or shortcuts to Dual Reducer over the cached layer-0
    candidate set (exact/contained); a hit that fails validation records
    a ``cache_fallback`` rung and descends cold, consulting cached
    per-layer bases where the candidate sets still match exactly.
    Populate-after-solve — a clean, non-degraded cold solve stores its
    per-layer candidate sets, LP bases and final package.
    """
    t0 = time.time()
    alpha = alpha or hier.alpha
    stats = PSStats()
    fp = sig = hit = None
    owner = False
    if qcache is not None:
        fp = qcache.register(hier)
        sig = query.signature()
        # Consult loop (at most two probes): a miss claims the populate
        # for this key; if another session already owns the same cold
        # solve, wait for it and re-probe — the waiter then usually
        # takes the freshly stored entry as a hit instead of running a
        # duplicate descent.  Single-threaded this is exactly one probe
        # and an immediate claim (bit-identical to the pre-claim flow).
        for _attempt in (0, 1):
            hit = qcache.lookup(fp, sig)
            if report is not None:
                if hit is not None:
                    report.cache_hits += 1
                else:
                    report.cache_misses += 1
            if hit is not None:
                res = _solve_from_cache(hier, query, table, hit, qcache,
                                        dr_q=dr_q, ilp_kwargs=ilp_kwargs,
                                        dr_aux=dr_aux, budget=budget,
                                        report=report, stats=stats)
                if res is not None:
                    stats.time_s = time.time() - t0
                    res.ps_stats = stats
                    return res
                qcache.note_fallback()
                if report is not None:
                    report.rung("cache_fallback",
                                detail=f"{hit.kind} hit abandoned")
                break
            if qcache.begin_populate(fp, sig):
                owner = True
                break
            qcache.wait_populate(fp, sig)
    try:
        entry = hit.entry if hit is not None else None
        S = np.arange(hier.layers[hier.L].size)
        sizes = [len(S)]
        warm = None
        support = None      # previous layer's surviving support (widening)
        art_cands: Dict[int, np.ndarray] = {}
        art_layers: Dict[int, tuple] = {}
        for l in range(hier.L, 0, -1):
            skip = budget is not None and budget.start().exhausted()
            if skip and report is not None:
                report.rung("budget_descend", degrades=True,
                            detail=f"layer {l}: LP skipped")
            widen = None
            if l < hier.L and support is not None and len(support):
                widen = (lambda f, _s=support, _l=l + 1:
                         neighbor_sampling(hier, _l, f * alpha, _s,
                                           query.objective_attr,
                                           query.maximize))
            if warm is None and warm_starts and entry is not None:
                # consult-before-descend: the abandoned hit's same-layer
                # basis still warm-starts this LP when the candidate
                # columns match exactly (warm starts never change answers)
                state = entry.layer_warms.get(l)
                if state is not None and np.array_equal(
                        np.asarray(state[0]), np.asarray(S)):
                    warm = WarmStart(state[1].copy(), state[2].copy())
            S_next, lp_res, S_used, support = shading(
                hier, l, alpha, S, query, layer_solver=layer_solver,
                sampler=sampler, rng=rng, warm_start=warm,
                return_state=True, lp_solver=lp_solver, budget=budget,
                report=report, widen=widen, ladder=ladder, skip_lp=skip)
            if lp_res is not None:
                stats.lp_iters += int(lp_res.iters)
                _count_warm_rejects(lp_res, stats, report)
                if lp_res.status == OPTIMAL:
                    art_layers[l] = (S_used, lp_res.basis, lp_res.at_upper,
                                     lp_res.obj)
            art_cands[l] = S_next
            warm = map_warm_basis(hier, l, S_used, lp_res, S_next,
                                  obj_attr=query.objective_attr) \
                if warm_starts else None
            if warm_starts and lp_res is not None \
                    and lp_res.status == OPTIMAL and warm is None:
                stats.warm_rejected += 1
                if report is not None:
                    report.warm_rejected += 1
                    report.note(f"warm_map_rejected: layer {l}")
            S = S_next
            sizes.append(len(S))
        if warm is None and warm_starts and entry is not None \
                and entry.dr_warm is not None:
            S0c = entry.candidates(1)
            if S0c is not None and np.array_equal(S0c, np.asarray(S)):
                warm = entry.dr_warm_start()
        res = dual_reducer(query, table, S, q=dr_q, rng=rng,
                           ilp_kwargs=ilp_kwargs, aux=dr_aux,
                           warm_start=warm, budget=budget, report=report,
                           ladder=ladder)
        if not res.feasible and ladder and support is not None \
                and len(support) and not (budget is not None
                                          and budget.exhausted()):
            # α escalation at layer 0: rebuild the candidate set at
            # double width from the layer-1 support and retry Dual
            # Reducer cold — the paper's remedy for tight queries whose
            # support was prematurely discarded upstream
            S_wide = neighbor_sampling(hier, 1, 2 * alpha, support,
                                       query.objective_attr,
                                       query.maximize)
            if len(S_wide) > len(S):
                if report is not None:
                    report.rung("dr_alpha_escalation",
                                detail=f"|S| {len(S)} -> {len(S_wide)}")
                res2 = dual_reducer(query, table, S_wide, q=dr_q, rng=rng,
                                    ilp_kwargs=ilp_kwargs, aux=dr_aux,
                                    budget=budget, report=report,
                                    ladder=ladder)
                if res2.feasible:
                    res = res2
                    sizes[-1] = len(S_wide)
                    art_cands[1] = S_wide
        if qcache is not None and res.feasible and res.status == "ok" \
                and (report is None or not report.degraded):
            # populate-after-solve: only clean, full-quality solves seed
            # the cache (degraded/truncated artifacts would poison reuse)
            qcache.store(fp, sig, hier=hier, cands=art_cands,
                         layer_warms=art_layers, dr_warm=res.lp_warm,
                         lp_bound=res.lp_obj,
                         package=(res.idx, res.mult, res.obj))
        res.status += f" layers={sizes}"
        stats.layer_sizes = sizes
        stats.time_s = time.time() - t0
        res.ps_stats = stats
        return res
    finally:
        # Release the populate claim whether or not the solve stored
        # (waiters re-probe; a failed solve just hands the key to the
        # next session).
        if owner:
            qcache.end_populate(fp, sig)
