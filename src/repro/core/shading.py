"""Shading + Progressive Shading — paper §2, Algorithms 1 and 2.

Each Shading step solves the LP relaxation over the current candidate set at
layer l (Parallel Dual Simplex), keeps the support, and expands/augments via
Neighbor Sampling down to layer l-1.  At layer 0, Dual Reducer produces the
final package.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core.dual_reducer import PackageResult, dual_reducer
from repro.core.hierarchy import Hierarchy
from repro.core.lp import OPTIMAL, solve_lp_np
from repro.core.neighbor import neighbor_sampling
from repro.core.paql import PackageQuery

FALLBACK_SEED = 64   # LP-infeasible layer: seed with top-k by objective


def shading(hier: Hierarchy, l: int, alpha: int, S_l: np.ndarray,
            query: PackageQuery, *, max_lp_iters: int = 20000,
            layer_solver: str = "lp", sampler: str = "neighbor",
            rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """One Shading step (Algorithm 2): layer-l candidates -> layer-(l-1).

    Ablation knobs (paper Mini-Experiments 1 and 2):
      layer_solver: 'lp' (paper's choice) | 'ilp' (replace line 2 with an
        ILP — shown not to help);
      sampler: 'neighbor' (Algorithm 3) | 'random' (random representative
        sampling — shown much worse).
    """
    layer_table = hier.layers[l].table
    c, A, bl, bu, ub = query.matrices(layer_table, S_l)
    if layer_solver == "ilp":
        from repro.core.ilp import solve_ilp
        res_i = solve_ilp(c, A, bl, bu, ub, max_nodes=100, time_limit_s=10)
        s_prime = S_l[res_i.x > 1e-9] if res_i.feasible else np.zeros(0, int)
    else:
        res = solve_lp_np(c, A, bl, bu, ub, max_iters=max_lp_iters)
        s_prime = S_l[res.x > 1e-9] if res.status == OPTIMAL \
            else np.zeros(0, np.int64)
    if len(s_prime) == 0:
        # representative-level solve infeasible: seed augmentation with the
        # best-objective representatives so it can still recover
        obj = layer_table[query.objective_attr][S_l]
        order = np.argsort(-obj if query.maximize else obj, kind="stable")
        s_prime = S_l[order[:FALLBACK_SEED]]

    if sampler == "random":
        rng = rng or np.random.default_rng(0)
        members = [hier.get_tuples(l - 1, int(g)) for g in s_prime]
        seen = set(int(g) for g in s_prime)
        count = sum(len(m) for m in members)
        n_l = hier.layers[l].size
        while count < alpha and len(seen) < n_l:
            g = int(rng.integers(0, n_l))
            if g in seen:
                continue
            seen.add(g)
            m = hier.get_tuples(l - 1, g)
            members.append(m)
            count += len(m)
        cand = np.unique(np.concatenate(members))
        return cand[:alpha]
    return neighbor_sampling(hier, l, alpha, s_prime,
                             query.objective_attr, query.maximize)


@dataclasses.dataclass
class PSStats:
    layer_sizes: list
    lp_iters: int
    time_s: float


def progressive_shading(hier: Hierarchy, query: PackageQuery,
                        table: Dict[str, np.ndarray], *,
                        alpha: Optional[int] = None,
                        dr_q: int = 500,
                        rng: Optional[np.random.Generator] = None,
                        ilp_kwargs: Optional[dict] = None,
                        layer_solver: str = "lp",
                        sampler: str = "neighbor",
                        dr_aux: str = "lp"
                        ) -> PackageResult:
    """Algorithm 1: iterate Shading from layer L to 0, then Dual Reducer."""
    t0 = time.time()
    alpha = alpha or hier.alpha
    S = np.arange(hier.layers[hier.L].size)
    sizes = [len(S)]
    for l in range(hier.L, 0, -1):
        S = shading(hier, l, alpha, S, query, layer_solver=layer_solver,
                    sampler=sampler, rng=rng)
        sizes.append(len(S))
    res = dual_reducer(query, table, S, q=dr_q, rng=rng,
                       ilp_kwargs=ilp_kwargs, aux=dr_aux)
    res.status += f" layers={sizes}"
    return res
