"""Neighbor Sampling — paper §2.2, Algorithm 3.

Expands the LP support S'_l (layer-l tuples) into layer-(l-1) candidates,
then augments with *neighboring groups* found by constructing 3^k probe
tuples just outside / inside each group's attribute box and locating their
groups via the split tree (GetGroup), until the candidate set reaches the
augmenting size alpha.  This is what recovers the paper's "hidden outliers".

All 3^k probes of a group descend the split tree in ONE vectorized batch
(``Partition.get_group_batch``); the discovered groups are then admitted
sequentially so the stop-at-alpha semantics match the scalar loop exactly.
"""
from __future__ import annotations

import heapq
import itertools
from typing import List, Set

import numpy as np

from repro.core.hierarchy import Hierarchy
from repro.core.relation import gather_column

MAX_PROBE_ATTRS = 8  # 3^8 = 6561 probes; queries use <= ~5 attrs


def neighbor_sampling(hier: Hierarchy, l: int, alpha: int,
                      s_prime: np.ndarray, obj_attr: str,
                      maximize: bool) -> np.ndarray:
    """Returns candidate indices at layer l-1 (at most alpha)."""
    layer = hier.layers[l]
    part = layer.part
    eps = layer.eps
    obj_l = layer.table[obj_attr]
    sgn = -1.0 if maximize else 1.0      # heap pops best objective first

    s_prime = np.asarray(s_prime, np.int64)
    members: List[np.ndarray] = [part.members_batch(s_prime)] \
        if len(s_prime) else []
    seen: Set[int] = set(int(g) for g in s_prime)
    count = sum(len(m) for m in members)
    heap: List = [(sgn * float(obj_l[g]), int(g)) for g in seen]
    heapq.heapify(heap)

    k = min(layer.X.shape[1], MAX_PROBE_ATTRS)
    corners = np.array(list(itertools.product(range(3), repeat=k)))  # (3^k, k)
    while heap and count < alpha:
        _, g = heapq.heappop(heap)
        lo, hi = hier.group_box(l, g)
        mid = 0.5 * (lo + hi)
        probes = np.tile(mid, (len(corners), 1))          # (3^k, k_full)
        choices = np.stack([lo[:k] - eps, mid[:k], hi[:k] + eps])  # (3, k)
        probes[:, :k] = choices[corners, np.arange(k)]
        gps = part.get_group_batch(probes)                # ONE batched descent
        for gp in gps:
            gp = int(gp)
            if gp not in seen:
                seen.add(gp)
                heapq.heappush(heap, (sgn * float(obj_l[gp]), gp))
                m = hier.get_tuples(l - 1, gp)
                members.append(m)
                count += len(m)
                if count >= alpha:
                    break

    cand = np.unique(np.concatenate(members)) if members else \
        np.zeros(0, np.int64)
    if len(cand) > alpha:
        # layer l-1 may be the streamed layer-0 relation: gather only the
        # candidate rows of the objective column
        obj_lm1 = gather_column(hier.layers[l - 1].table, obj_attr, cand)
        order = np.argsort(-obj_lm1 if maximize else obj_lm1, kind="stable")
        cand = np.sort(cand[order[:alpha]])
    return cand
