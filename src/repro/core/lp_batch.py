"""Batched bound-variant LP engine — one jitted solve for a whole wave.

Branch & bound, the Dual Reducer's auxiliary re-solves and the shading
ladder's retry rungs all generate *flights* of LPs that share one
``(c, A)`` and differ only in variable bounds (branching pins
``lb_j = ub_j = v``, aux rungs shrink ``ub``, ladder lanes mask columns
out by ``ub = 0``).  Solved one at a time through ``solve_lp_np`` each
tiny LP pays full Python/dispatch overhead per *pivot*; here the whole
flight runs as ONE jitted ``lax.while_loop`` whose body is the single
twin's pivot step (``repro.core.lp._pivot_iter``) vmapped over the K
bound variants — the classic inference-stack batching shape (padding,
shape classes, masked convergence) applied to the optimizer.

Design points (see ``docs/BATCHING.md``):

* **Shape classes** — m pads to a pow2, n and K to multiples of 16
  and 4 (the vmapped trip is memory-bound in (K, N) passes, so pow2
  rounding would stream up to 2x padded garbage); one compiled
  executable per class, kept in a ``BoundedStepCache`` with
  hit/miss/eviction counters, so recompiles are bounded and *counted*
  (no per-K recompile).  Padding is inert by construction: padded
  columns have ``c = 0``, a zero A-column and ``l = u = 0`` (never
  eligible to enter); padded rows are zero with ``l = u = 0`` slacks
  (never violated, their slack never leaves the basis) — the padded
  solve is the unpadded solve embedded, pivot for pivot.
* **Masked convergence** — every lane executes the vmapped pivot step
  each iteration, but a finished (or invalid/padded) lane's state is
  frozen by a per-lane ``jnp.where`` select, so it never perturbs its
  neighbors.  The loop exits when all lanes are done or the shared
  pivot budget is spent (``spent += sum(active)`` per iteration, a
  *traced* cap — budget changes never retrace).
* **Warm starts** — per-lane bases with the PR-1 validation semantics:
  each basis is validated on the padded arrays (same checks as
  ``solve_lp_np``) and rejected-to-cold per lane, surfaced via
  ``warm_start_rejected`` notes exactly like the single twins.
* **Numpy fallback** — for K = 1, or when the caller knows the flight
  is too small for batching to win (``backend="np"``), the engine
  degrades to the sequential ``solve_lp_np`` loop with identical
  per-call budget charging — bit-compatible with today's callers.

Budget contract: the shared pivot budget is charged as the SUM of
per-lane pivots through ``guard.SolveBudget`` (one ``charge_pivots``
per dispatch on the jax path; per call on the numpy path).
"""
from __future__ import annotations

import threading
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.distributed import BoundedStepCache
from repro.core.guard import NumericalMonitor, SolveBudget
from repro.core.lp import (BUDGET, INFEASIBLE, ITER_LIMIT, LPResult,
                           REFACTOR_EVERY, _STATE_IT, _STATE_STATUS,
                           _drift_gate, _factor_refresh, _gather_solution,
                           _init_pivot_state, _optimal_suspect_gate,
                           _pivot_core, _unpack_warm, row_scaling,
                           solve_lp_np)

_M_FLOOR = 4        # smallest row shape class
_CACHE_MAXSIZE = 32  # distinct (m, n, K, cap) compiled classes kept

_K_STEP = 4         # lane-count shape classes are multiples of this
# structural columns round up to a multiple of this, NOT to a power of
# two: on a single core the vmapped trip is memory-bound in (K, N)
# passes, so pow2 rounding (e.g. n = 150 -> 256) would spend ~40% of
# every trip streaming padded columns.  A run touches only a handful of
# distinct n, so the class count stays bounded (and LRU-evicted) anyway
_N_STEP = 16

# ``backend="auto"`` crossover: a warm sequential numpy solve costs
# ~0.4 ms/lane on this class of instance, while a batched jit dispatch
# carries ~1 ms of fixed cost (trace-cache lookup, lane packing, device
# transfer, warm-basis validation, unpack).  Flights at or below this
# width route to the numpy loop; measured on the single-core CI image
# (see benchmarks/batch_lp.py and docs/BATCHING.md)
_AUTO_NP_MAX = 2

_COMPILE_CACHE = BoundedStepCache(maxsize=_CACHE_MAXSIZE)

# dispatch accounting (observability: benches record these to prove the
# shape-class policy holds — bounded classes, no per-K recompile)
_STATS = {"dispatches": 0, "instances": 0, "np_fallbacks": 0,
          "batched_pivots": 0, "prep_hits": 0, "prep_misses": 0}

_STATS_LOCK = threading.Lock()
_PREP_LOCK = threading.Lock()

# Registered with the static concurrency checker (REPRO010): mutations
# of these module globals must hold the matching lock (_STATS under
# _STATS_LOCK, _PREPPED under _PREP_LOCK).  Lock order: _PREP_LOCK may
# take _STATS_LOCK; never the reverse.
SHARED_MUTABLE = ("_STATS", "_PREPPED")


def batch_cache_stats() -> dict:
    """Counters of the compile-class cache (observability API)."""
    return _COMPILE_CACHE.stats()


def batch_stats() -> dict:
    """Dispatch counters of the batched engine (atomic snapshot)."""
    with _STATS_LOCK:
        return dict(_STATS)


def reset_batch_stats() -> None:
    with _STATS_LOCK:
        for k in _STATS:
            _STATS[k] = 0


def _pow2(v: int, floor: int) -> int:
    return max(floor, 1 << max(int(v) - 1, 0).bit_length())


def _batched_core(m_pad: int, n_pad: int, K_pad: int, max_iters: int,
                  refactor_every: int):
    """Jitted batched solver for one (m, n, K, cap) shape class.

    A fresh ``jax.jit`` wrapper is built per class so that evicting a
    cache entry actually releases its compiled executable.

    Host I/O is packed: single-core dispatch overhead is ~0.2 ms per
    device transfer, so ALL per-lane operands travel as ONE f64 array
    ``in_pack`` = [l | u | tol | basis0 | at_upper0 | valid |
    pivot_cap] (integer/bool fields are exact in f64 — indices and
    pivot counts are far below 2^53) and the ten result fields return
    as ONE f64 array ``out_pack`` = [x | y | obj | basis | status | it
    | n_bland | n_drift | at_upper | spent].
    """
    N = n_pad + m_pad

    def factory():
        def core(cf, A, in_pack):
            l_b = in_pack[:, :N]
            u_b = in_pack[:, N:2 * N]
            tol_b = in_pack[:, 2 * N]
            basis0_b = in_pack[:, 2 * N + 1:2 * N + 1 + m_pad] \
                .astype(jnp.int64)
            at_upper0_b = in_pack[:, 2 * N + 1 + m_pad:
                                  3 * N + 1 + m_pad] != 0.0
            valid_b = in_pack[:, 3 * N + 1 + m_pad] != 0.0
            pivot_cap = in_pack[0, 3 * N + 2 + m_pad].astype(jnp.int64)

            def init_one(b0, au0):
                return _init_pivot_state(cf, A, b0, au0, refactor_every)

            def gate1_one(st):
                return _drift_gate(A, refactor_every, st)

            def refresh_one(l, u, st):
                return _factor_refresh(cf, A, l, u, st)

            def gate2_one(l, u, tol, st):
                return _optimal_suspect_gate(l, u, tol, st)

            def core_one(l, u, tol, a, st):
                return _pivot_core(cf, A, l, u, tol, refactor_every, st,
                                   active=a)

            def lanes_active(st):
                return (valid_b & (st[_STATE_STATUS] == ITER_LIMIT)
                        & (st[_STATE_IT] < max_iters))

            def cond(carry):
                st, spent = carry
                return jnp.any(lanes_active(st)) & (spent < pivot_cap)

            def _sel_lanes(mask):
                def sel(a, b):
                    msk = mask.reshape((-1,) + (1,) * (a.ndim - 1))
                    return jnp.where(msk, a, b)
                return sel

            def body(carry):
                st, spent = carry
                act = lanes_active(st)

                # The single twin's pivot runs its two refresh sites as
                # per-instance lax.cond; vmapped, a cond lowers to select
                # and BOTH branches execute for every lane on every
                # iteration — K O(m^3) inverses per pivot.  Here the
                # gates are vmapped but the refresh sits behind ONE
                # batch-level scalar cond (a REAL branch), firing only on
                # the rare iterations where some active lane needs it.
                # Fusing the two sites is exact: a drift-gate refresh
                # zeroes `since`, which makes the optimal-suspect gate
                # (`... & since > 0`) False afterwards, so at most one
                # refresh per lane per trip fires either way — and the
                # suspect gate does not read the one field (n_drift) the
                # drift gate updates, so evaluating it pre-refresh gives
                # the same bit.  The refreshed state is tree-selected per
                # lane on its own `need` bit (need ⊆ act: frozen lanes
                # are never touched — their scalar fields are gated
                # inside _pivot_core via `active`).
                def refresh_where(need):
                    def go(s):
                        ref = jax.vmap(refresh_one)(l_b, u_b, s)
                        return jax.tree_util.tree_map(
                            _sel_lanes(need), ref, s)
                    return go

                st1, need1 = jax.vmap(gate1_one)(st)
                # drift events on frozen lanes don't count (the numpy
                # twin stopped looking when the lane finished)
                st1 = st1[:10] + (jnp.where(act, st1[10], st[10]),) \
                    + st1[11:]
                need2 = jax.vmap(gate2_one)(l_b, u_b, tol_b, st1)
                need = (need1 | need2) & act
                # repro: allow[REPRO001] refresh_where(need) is a fresh
                # identity per trace capturing this body's own tracers
                st1 = jax.lax.cond(jnp.any(need),
                                   refresh_where(need), lambda s: s, st1)
                new = jax.vmap(core_one)(l_b, u_b, tol_b, act, st1)
                return new, spent + jnp.sum(act.astype(spent.dtype))

            state0 = jax.vmap(init_one)(basis0_b, at_upper0_b)
            # eager factorization (like the numpy twin): refresh every
            # lane ONCE before the loop so the first trips — where most
            # warm-started lanes already converge — never enter the
            # refresh branch
            state0 = jax.vmap(refresh_one)(l_b, u_b, state0)
            st, spent = jax.lax.while_loop(
                cond, body, (state0, jnp.asarray(0, jnp.int64)))

            # exit contract of the numpy twin: the final answer comes
            # from a fresh factorization.  A lane exiting with since=0
            # was refreshed on the very trip it settled (the optimal-
            # suspect gate, or the eager factorization above), so its
            # carried xB / y ARE the fresh-factor values — recomputing
            # them is the identity.  Only lanes truncated mid-streak
            # (iteration cap / shared budget) still carry stale factors;
            # the batched refactorization lowers behind a scalar cond
            # that in the common all-optimal dispatch never fires.
            need_exit = st[13] > 0

            def exit_refresh(s):
                ref = jax.vmap(refresh_one)(l_b, u_b, s)
                return jax.tree_util.tree_map(
                    _sel_lanes(need_exit), ref, s)

            # repro: allow[REPRO001] fresh identity per trace, capturing
            # this core's own tracers
            st = jax.lax.cond(jnp.any(need_exit), exit_refresh,
                              lambda s: s, st)
            basis, in_basis, at_upper, xB, y = (st[0], st[1], st[2],
                                                st[4], st[6])
            n_bland, n_drift = st[9], st[10]
            status, it = st[_STATE_STATUS], st[_STATE_IT]

            def fin_one(l, u, b, ib, au, xb):
                return _gather_solution(cf, l, u, b, ib, au, xb)

            x, obj = jax.vmap(fin_one)(l_b, u_b, basis, in_basis,
                                       at_upper, xB)
            # pack in the TRACE dtype (f64 in production; an f32 trace —
            # the IRC005 contract probe — must not introduce f64)
            ff = lambda a: a.astype(in_pack.dtype)  # noqa: E731
            spent_col = jnp.broadcast_to(ff(spent), (K_pad,))
            return jnp.concatenate(
                [x, y, obj[:, None], ff(basis),
                 ff(status)[:, None], ff(it)[:, None],
                 ff(n_bland)[:, None], ff(n_drift)[:, None],
                 ff(at_upper), spent_col[:, None]], axis=1)

        return jax.jit(core)

    key = (m_pad, n_pad, K_pad, max_iters, refactor_every)
    return _COMPILE_CACHE.get_or_create(key, factory)


_PREP_MAX = 8        # prepared shared-(c, A) standard forms kept resident
_PREPPED: List[dict] = []


def _prep_shared(c, A_t, bl, bu, m_pad: int, n_pad: int) -> dict:
    """Build (or reuse) the padded shared standard form + its device
    arrays.  A B&B wave loop re-dispatches the SAME (c, A, bl, bu) every
    wave; re-padding and re-transferring the matrix per dispatch costs
    more than the solve for small flights, so prepared forms are cached
    by content (a memcmp-style compare — in-place caller mutations are
    therefore safe) and bounded FIFO.

    ``_PREP_LOCK`` is held for the whole scan-build-insert (the build is
    numpy padding, cheap relative to a solve), so the check-then-act is
    one atomic scope and concurrent waves share one prepared form."""
    with _PREP_LOCK:
        for e in _PREPPED:
            if (e["m_pad"] == m_pad and e["n_pad"] == n_pad
                    and e["c"].shape == c.shape
                    and e["A_t"].shape == A_t.shape
                    and np.array_equal(e["c"], c)
                    and np.array_equal(e["A_t"], A_t)
                    and np.array_equal(e["bl"], bl)
                    and np.array_equal(e["bu"], bu)):
                with _STATS_LOCK:
                    _STATS["prep_hits"] += 1
                return e
        with _STATS_LOCK:
            _STATS["prep_misses"] += 1
        m, n = A_t.shape
        N_pad = n_pad + m_pad
        scale = row_scaling(A_t)
        cf = np.zeros(N_pad)
        cf[:n] = c
        A = np.zeros((m_pad, N_pad))
        A[:m, :n] = -(A_t * scale[:, None])
        A[:, n_pad:] = np.eye(m_pad)
        e = {"c": c.copy(), "A_t": A_t.copy(), "bl": bl.copy(),
             "bu": bu.copy(), "m_pad": m_pad, "n_pad": n_pad,
             "scale": scale, "cf": cf, "A": A,
             "bls": bl * scale, "bus": bu * scale,
             "cf_dev": jnp.asarray(cf), "A_dev": jnp.asarray(A)}
        _PREPPED.append(e)
        if len(_PREPPED) > _PREP_MAX:
            _PREPPED.pop(0)
        return e


def _validate_warm_batch(A, cf, l_rows, u_rows, tol_rows, WB, HT):
    """Vectorized per-lane warm-basis validation — the same acceptance
    rules as ``lp._warm_state``, applied to all W candidate bases at
    once (one batched inverse instead of W host factorizations).

    Returns ``(ok, at_up, reasons)``: accept mask (W,), the derived
    bound patterns (W, N) for accepted lanes, and a rejection reason
    per lane (None when accepted)."""
    W, m = WB.shape
    N = A.shape[1]
    ok = np.ones(W, bool)
    reasons: List[Optional[str]] = [None] * W
    at_up = np.zeros((W, N), bool)
    srt = np.sort(WB, axis=1)
    bad_idx = (WB.min(axis=1) < 0) | (WB.max(axis=1) >= N) | \
        np.any(srt[:, 1:] == srt[:, :-1], axis=1)
    for i in np.flatnonzero(bad_idx):
        ok[i] = False
        reasons[i] = "basis indices out of range or duplicated"
    good = np.flatnonzero(ok)
    if not good.size:
        return ok, at_up, reasons
    WBg = WB[good]
    B = np.transpose(A[:, WBg], (1, 0, 2))        # (G, m, m)
    try:
        Binv = np.linalg.inv(B)
    except np.linalg.LinAlgError:
        Binv = np.full_like(B, np.inf)
        for gi in range(len(B)):
            try:
                Binv[gi] = np.linalg.inv(B[gi])
            except np.linalg.LinAlgError:
                reasons[good[gi]] = "singular basis"
    with np.errstate(invalid="ignore"):
        illcond = ~np.all(np.isfinite(Binv), axis=(1, 2)) | \
            (np.max(np.abs(np.where(np.isfinite(Binv), Binv, np.inf)),
                    axis=(1, 2)) > 1e12)
    cB = cf[WBg]                                   # (G, m)
    y = (np.transpose(Binv, (0, 2, 1)) @ cB[..., None])[..., 0]
    d = cf[None, :] - y @ A                        # (G, N)
    np.put_along_axis(d, WBg, 0.0, axis=1)
    IB = np.zeros((len(good), N), bool)
    np.put_along_axis(IB, WBg, True, axis=1)
    tg = tol_rows[good][:, None]
    Lg, Ug = l_rows[good], u_rows[good]
    au = np.where(d < -tg, True, np.where(d > tg, False, HT[good]))
    inf_l = np.isinf(Lg)
    inf_u = np.isinf(Ug)
    if inf_l.any() or inf_u.any():
        au |= inf_l
        au &= ~inf_u
        bad_dual = np.any((~IB) & (((d < -tg) & inf_u)
                                   | ((d > tg) & inf_l)
                                   | (inf_l & inf_u)), axis=1)
    else:
        # all-finite bounds (every B&B / aux-rung / ladder flight): no
        # pinned-at-infinity patterns exist, skip their (G, N) passes
        bad_dual = np.zeros(len(good), bool)
    au[IB] = False
    for gi, i in enumerate(good):
        if reasons[i] is not None:                 # singular (fallback)
            ok[i] = False
        elif illcond[gi]:
            ok[i] = False
            reasons[i] = "ill-conditioned basis"
        elif bad_dual[gi]:
            ok[i] = False
            reasons[i] = \
                "dual-infeasible column pinned at an infinite bound"
        else:
            at_up[i] = au[gi]
    return ok, at_up, reasons


def _as_bound_arr(batch, K: int, n: int, default: float,
                  name: str) -> np.ndarray:
    """Normalize ub_batch / lb_batch into one (K, n) float64 array."""
    if batch is None:
        return np.full((K, n), default)
    try:
        # fast path: uniform (n,) rows stack in one numpy call (the B&B
        # wave always lands here — per-lane python only on odd payloads)
        arr = np.asarray(batch, np.float64)
        if arr.shape == (K, n):
            return arr
    except (ValueError, TypeError):
        pass
    rows = []
    for k in range(K):
        b = batch[k]
        if b is None:
            rows.append(np.full(n, default))
            continue
        b = np.asarray(b, np.float64).ravel()
        if b.shape != (n,):
            raise ValueError(f"{name}[{k}] shape {b.shape} != ({n},)")
        rows.append(b)
    return np.stack(rows)


def _infeasible_result(n: int, m: int, note: Optional[str] = None,
                       status: int = INFEASIBLE) -> LPResult:
    return LPResult(status, np.zeros(n), 0.0, 0, np.arange(n, n + m),
                    np.zeros(n + m, bool), np.zeros(m),
                    notes=() if note is None else (note,))


def solve_lp_batch(c, A_t, bl, bu, ub_batch, lb_batch=None, *,
                   tol=1e-7, max_iters: int = 5000, warm_starts=None,
                   budget: Optional[SolveBudget] = None,
                   monitor: Optional[NumericalMonitor] = None,
                   backend: str = "auto",
                   refactor_every: int = REFACTOR_EVERY) -> List[LPResult]:
    """Solve K bound-variants of one shared LP as one batched dispatch.

    ``(c, A_t, bl, bu)`` are shared; ``ub_batch`` / ``lb_batch`` are
    length-K sequences of per-variable bounds (entries may be ``None``
    for the defaults ``ub = +inf`` is NOT assumed — ``ub_batch`` entries
    must be given; ``lb`` defaults to 0).  ``tol`` is a scalar or a
    length-K sequence (the shading ladder relaxes tolerance per lane).
    ``warm_starts`` is ``None`` or a length-K sequence of per-lane
    ``LPResult`` / ``WarmStart`` / ``(basis, at_upper)`` / ``None``.

    Returns a list of K ``LPResult`` in input order, each carrying the
    same status codes, notes and warm-start semantics as the single
    twins.  ``backend="auto"`` falls back to the sequential numpy twin
    for K <= 2 (K = 1 is bit-compatible with ``solve_lp_np``; at K = 2
    the jitted dispatch's fixed cost still exceeds two warm sequential
    solves — see docs/BATCHING.md); ``"np"`` forces the fallback,
    ``"jax"`` forces the batched path.
    """
    if backend not in ("auto", "np", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    ub_batch = list(ub_batch)
    K = len(ub_batch)
    if K == 0:
        return []
    c = np.asarray(c, np.float64)
    A_t = np.atleast_2d(np.asarray(A_t, np.float64))
    m, n = A_t.shape
    ub_arr = _as_bound_arr(ub_batch, K, n, np.inf, "ub_batch")
    lb_arr = _as_bound_arr(lb_batch, K, n, 0.0, "lb_batch")
    tol_arr = (np.full(K, float(tol)) if np.isscalar(tol)
               else np.asarray([float(t) for t in tol], np.float64))
    if tol_arr.shape != (K,):
        raise ValueError(f"tol length {tol_arr.shape[0]} != K={K}")
    warm_list = list(warm_starts) if warm_starts is not None \
        else [None] * K
    if len(warm_list) != K:
        raise ValueError(f"warm_starts length {len(warm_list)} != K={K}")

    with _STATS_LOCK:
        _STATS["instances"] += K
    if backend == "np" or (backend == "auto" and K <= _AUTO_NP_MAX):
        # sequential fallback: per-call budget charging, identical to the
        # existing caller loops (this is what makes W=1 bit-compatible)
        with _STATS_LOCK:
            _STATS["np_fallbacks"] += 1
        return [solve_lp_np(c, A_t, bl, bu, ub_arr[k], lb=lb_arr[k],
                            max_iters=max_iters, tol=float(tol_arr[k]),
                            warm_start=warm_list[k], budget=budget,
                            monitor=monitor)
                for k in range(K)]

    with _STATS_LOCK:
        _STATS["dispatches"] += 1
    # ---- shared standard form, padded to the (m, n, K) shape class ----
    # m rounds up to pow2 (rows are tiny); n and K round up to multiples
    # of _N_STEP / _K_STEP — on a single core the vmapped body's cost is
    # proportional to K_pad * N_pad, so pow2 rounding would waste up to
    # 2x compute streaming padded lanes and padded columns.  Class count
    # stays bounded: K <= 2*wave_width gives at most 2W/_K_STEP classes
    # per geometry, and a run touches a handful of distinct n, all
    # within the LRU's maxsize
    m_pad = _pow2(m, _M_FLOOR)
    n_pad = -(-n // _N_STEP) * _N_STEP
    K_pad = -(-K // _K_STEP) * _K_STEP
    N_pad = n_pad + m_pad
    shared = _prep_shared(c, A_t, np.asarray(bl, np.float64),
                          np.asarray(bu, np.float64), m_pad, n_pad)
    cf, A = shared["cf"], shared["A"]
    bls, bus, scale = shared["bls"], shared["bus"], shared["scale"]

    cap = max_iters
    notes_pre: List[List[str]] = [[] for _ in range(K)]
    if budget is not None:
        budget.start()
        if budget.out_of_time() or budget.remaining_pivots() <= 0:
            return [_infeasible_result(
                n, m, "budget: exhausted before LP solve", BUDGET)
                for _ in range(K)]
        cap = budget.lp_iter_cap(max_iters)

    # ---- vectorized lane assembly (no per-lane python work) ----
    # ALL per-lane operands are packed into ONE f64 array: on a single
    # core every extra device transfer costs ~0.2 ms, which at B&B wave
    # rates adds up to more than the solve itself (layout documented in
    # ``_batched_core``; views below alias in_pack, writes land in it)
    in_pack = np.zeros((K_pad, 3 * N_pad + m_pad + 3))
    l_b = in_pack[:, :N_pad]
    u_b = in_pack[:, N_pad:2 * N_pad]
    basis0_b = in_pack[:, 2 * N_pad + 1:2 * N_pad + 1 + m_pad]
    at_upper0_b = in_pack[:, 2 * N_pad + 1 + m_pad:
                          3 * N_pad + 1 + m_pad]
    valid_b = in_pack[:, 3 * N_pad + 1 + m_pad]
    l_b[:K, :n] = lb_arr
    u_b[:K, :n] = ub_arr
    l_b[:K, n_pad:n_pad + m] = bls
    u_b[:K, n_pad:n_pad + m] = bus
    in_pack[:, 2 * N_pad] = 1e-7
    in_pack[:K, 2 * N_pad] = tol_arr
    box_infeasible = np.any(l_b[:K] > u_b[:K] + tol_arr[:, None], axis=1)
    valid_b[:K] = ~box_infeasible
    # cold start for every lane (vectorized lp._cold_start; warm lanes
    # overwrite below).  Padded lanes keep the all-slack basis over the
    # all-zero padded LP and stay valid_b=0, so they never step.
    basis0_b[:] = np.arange(n_pad, N_pad, dtype=np.int64)
    at_upper0_b[:, :n_pad] = (cf[None, :n_pad] < 0) | \
        np.isinf(l_b[:, :n_pad])

    # ---- warm bases: remap into padded space, validate all at once ----
    # per-lane python here is just ``_unpack_warm`` + a shape check; the
    # pad-space remap, hint packing and acceptance writes are all (L, .)
    # numpy ops (at B&B wave rates the old per-lane remap alone cost
    # more than the device transfer)
    warm_lanes: List[int] = []
    wb_raw: List[np.ndarray] = []
    ht_raw: List[Optional[np.ndarray]] = []
    for k in range(K):
        if not valid_b[k]:
            continue
        wb, wh = _unpack_warm(warm_list[k])
        if wb is None:
            continue
        wb = np.asarray(wb, np.int64).ravel()
        if wb.shape != (m,):
            notes_pre[k].append(
                f"warm_start_rejected: basis shape {wb.shape} != "
                f"({m},); cold start used")
            continue
        warm_lanes.append(k)
        wb_raw.append(wb)
        ht_raw.append(wh)
    if warm_lanes:
        lanes = np.asarray(warm_lanes)
        L = len(warm_lanes)
        # caller (n+m)-space indices into the padded space; padded
        # slacks sit on the padded rows
        WBr = np.stack(wb_raw)
        WB = np.empty((L, m_pad), np.int64)
        WB[:, :m] = np.where(WBr < n, WBr, n_pad + (WBr - n))
        WB[:, m:] = np.arange(n_pad + m, N_pad, dtype=np.int64)
        HT = np.zeros((L, N_pad), bool)
        hs = [None if wh is None else np.asarray(wh, bool).ravel()
              for wh in ht_raw]
        if all(h is not None and h.shape == (n + m,) for h in hs):
            WHr = np.stack(hs)
            HT[:, :n] = WHr[:, :n]
            HT[:, n_pad:n_pad + m] = WHr[:, n:]
        else:  # mixed / odd hint payloads: rare, keep the lane loop
            for i, h in enumerate(hs):
                if h is not None and h.shape == (n + m,):
                    HT[i, :n] = h[:n]
                    HT[i, n_pad:n_pad + m] = h[n:]
        ok, au, reasons = _validate_warm_batch(
            A, cf, l_b[lanes], u_b[lanes], tol_arr[lanes], WB, HT)
        acc = lanes[ok]
        basis0_b[acc] = WB[ok]
        at_upper0_b[acc] = au[ok]
        for i in np.flatnonzero(~ok):
            notes_pre[lanes[i]].append(
                f"warm_start_rejected: {reasons[i]}; cold start used")

    results: List[Optional[LPResult]] = [None] * K
    for k in np.flatnonzero(box_infeasible):
        results[k] = _infeasible_result(n, m)

    if not np.any(valid_b):
        return results  # every lane decided on the host

    pivot_cap = K * cap
    if budget is not None:
        pivot_cap = int(min(pivot_cap, max(budget.remaining_pivots(), 1)))
    in_pack[0, 3 * N_pad + 2 + m_pad] = pivot_cap

    core = _batched_core(m_pad, n_pad, K_pad, cap, refactor_every)
    out = jax.device_get(core(shared["cf_dev"], shared["A_dev"],
                              jnp.asarray(in_pack)))
    # unpack + un-pad ALL lanes vectorized (layout in ``_batched_core``)
    o = N_pad + m_pad
    x_b = out[:K, :n]
    y_b = out[:K, N_pad:N_pad + m] * scale
    obj_b = out[:K, o]
    basis_b = out[:K, o + 1:o + 1 + m].astype(np.int64)
    basis_b = np.where(basis_b < n_pad, basis_b, n + (basis_b - n_pad))
    stats_i = out[:K, o + 1 + m_pad:o + 5 + m_pad].astype(np.int64)
    status_l, it_l, n_bland_l, n_drift_l = stats_i.T.tolist()
    au = out[:K, o + 5 + m_pad:o + 5 + m_pad + N_pad]
    at_upper_b = np.concatenate(
        [au[:, :n], au[:, n_pad:n_pad + m]], axis=1) != 0.0

    spent = int(out[0, 2 * N_pad + 2 * m_pad + 5])
    with _STATS_LOCK:
        _STATS["batched_pivots"] += spent
    shared_hit = spent >= pivot_cap
    if budget is not None:
        budget.charge_pivots(spent)
    lane_ok = valid_b[:K] != 0.0
    n_bland_tot = int(stats_i[lane_ok, 2].sum())
    n_drift_tot = int(stats_i[lane_ok, 3].sum())
    if monitor is not None:
        monitor.bland_pivots += n_bland_tot
        monitor.drift_refactors += n_drift_tot
        if n_bland_tot:
            monitor.stall_events += 1

    truncatable = budget is not None and (cap < max_iters or shared_hit
                                          or budget.exhausted())
    for k in range(K):
        if results[k] is not None:
            continue
        st = status_l[k]
        notes = list(notes_pre[k])
        if n_bland_l[k]:
            notes.append(f"stall: Bland's rule for {n_bland_l[k]} "
                         "pivots")
        if n_drift_l[k]:
            notes.append(f"drift: {n_drift_l[k]} forced "
                         "refactorizations")
        if st == ITER_LIMIT and truncatable:
            st = BUDGET
            notes.append(f"budget: truncated at pivot cap {cap}")
        results[k] = LPResult(st, x_b[k], float(obj_b[k]), it_l[k],
                              basis_b[k], at_upper_b[k], y_b[k],
                              notes=tuple(notes))
    return results
