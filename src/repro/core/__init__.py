# The paper's primary contribution: Progressive Shading package-query
# processing with DLV partitioning, Dual Reducer and (Parallel) Dual Simplex.
#
# LP/ILP numerics require f64; jax x64 mode is enabled at core import time.
# Model code elsewhere uses explicit dtypes so this is safe process-wide.
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.paql import PackageQuery, Constraint  # noqa: E402
from repro.core.lp import solve_lp, LPResult  # noqa: E402
from repro.core.ilp import solve_ilp, ILPResult  # noqa: E402

__all__ = ["PackageQuery", "Constraint", "solve_lp", "LPResult",
           "solve_ilp", "ILPResult"]
