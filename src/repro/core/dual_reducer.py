"""Dual Reducer — paper §2.4, Algorithm 4.

RENS-style heuristic ILP solver: LP relaxation x*, auxiliary LP with
per-variable upper bound E/q (E = ||x*||_1) that spreads the support to
~q variables, then a sub-ILP over the union of both supports; exponential
fallback (double q, uniformly sample additional tuples) guarantees
solvability whenever the full ILP is feasible (up to node limits).

Warm starts (revised dual simplex, core.lp): the auxiliary LP differs
from the first LP ONLY in upper bounds — the textbook dual-simplex
warm-start case — so it reuses lp1's final basis directly; the fallback
sub-ILP root LPs re-map lp1's basis onto the selected columns.  The
caller (progressive_shading) may pass ``warm_start`` to seed lp1 itself
from the last Shading layer's basis.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import ilp as ilp_mod
from repro.core.lp import INFEASIBLE, OPTIMAL, LPResult, WarmStart, \
    fill_warm_basis, solve_lp_np
from repro.core.lp_batch import solve_lp_batch
from repro.core.paql import PackageQuery


@dataclasses.dataclass
class PackageResult:
    feasible: bool
    idx: np.ndarray          # global tuple indices in the package
    mult: np.ndarray         # multiplicities (same length)
    obj: float               # objective in the query's own sense
    lp_obj: float            # LP relaxation bound (query sense) over S
    fallbacks: int = 0
    sub_ilp_size: int = 0
    status: str = ""
    report: Optional[object] = None   # guard.SolveReport (engine.solve)
    lp_warm: Optional[WarmStart] = None   # lp1 final basis (cache artifact)
    ps_stats: Optional[object] = None     # shading.PSStats (cascade solves)

    def integrality_gap(self, eps: float = 0.1) -> float:
        """Paper §4.1 metric vs. this result's own LP bound."""
        return (abs(self.obj) + eps) / (abs(self.lp_obj) + eps)


def _subset_warm(lp1: LPResult, sel: np.ndarray, n: int) -> Optional[WarmStart]:
    """Re-map lp1's basis (over all n columns of S) onto the columns in
    ``sel``; basic columns outside sel become unused slacks."""
    m = len(lp1.y)
    n_sub = len(sel)
    pos = np.full(n, -1, np.int64)
    pos[sel] = np.arange(n_sub)
    new_basis = np.full(m, -1, np.int64)
    for k, j in enumerate(np.asarray(lp1.basis, np.int64)):
        if j >= n:
            new_basis[k] = n_sub + (j - n)
        elif pos[j] >= 0:
            new_basis[k] = pos[j]
    new_basis = fill_warm_basis(new_basis, n_sub, m)
    if new_basis is None:
        return None
    at_upper = np.concatenate([lp1.at_upper[:n][sel], lp1.at_upper[n:]])
    return WarmStart(new_basis, at_upper)


def dual_reducer(query: PackageQuery, table, S: np.ndarray, *, q: int = 500,
                 rng: Optional[np.random.Generator] = None,
                 max_lp_iters: int = 20000,
                 ilp_kwargs: Optional[dict] = None,
                 aux: str = "lp", warm_start=None,
                 budget=None, report=None,
                 ladder: bool = True, aux_rungs: int = 1,
                 batch_backend: str = "auto") -> PackageResult:
    """aux: 'lp' (paper's auxiliary LP, line 4-5) | 'random' (Mini-Exp 4
    ablation: random sample of ~q tuples instead).  warm_start seeds the
    first LP (see module docstring).  ``table`` may be a dict of arrays or
    a Relation: only the <= |S| candidate rows are ever gathered (the
    out-of-core contract — S carries tuple ids, never tuples).

    ``aux_rungs=R`` solves R auxiliary LPs in ONE ``solve_lp_batch``
    dispatch — bound-variants ``ub_j = min(ub, E/(q * 2^j))`` of the
    same (c, A), all warm-started from lp1.  Rung 0 is the paper's
    auxiliary LP; rungs j >= 1 are the supports the exponential
    fallback would otherwise have to re-solve for after doubling q, so
    each fallback round widens ``sel`` from a precomputed rung before
    falling back to random sampling.  ``aux_rungs=1`` is byte-identical
    to the classic single auxiliary solve.

    Guard integration: ``budget`` (guard.SolveBudget) is threaded through
    every LP and the sub-ILPs; ``report`` (guard.SolveReport) accumulates
    LP stats and degradation rungs.  With ``ladder=True`` (default) a
    failed solve degrades instead of failing dry:

      * lp1 INFEASIBLE      -> one warm retry with relaxed tolerance
        (rung ``dr_relax_tol``);
      * sub-ILP out of budget / infeasible with no widening left ->
        round-and-repair lp1's relaxation over the full candidate set
        (``_swap_search``) and return it flagged ``degraded_rounded``.
    """
    rng = rng or np.random.default_rng(0)
    ilp_kwargs = dict(ilp_kwargs or {})
    monitor = report.monitor if report is not None else None
    S = np.asarray(S)
    n = len(S)
    c, A, bl, bu, ub = query.matrices(table, S)

    lp1 = solve_lp_np(c, A, bl, bu, ub, max_iters=max_lp_iters,
                      warm_start=warm_start, budget=budget,
                      monitor=monitor)
    if report is not None:
        report.absorb_lp(lp1)
    if lp1.status == INFEASIBLE and ladder:
        # tight queries can be declared infeasible by a hair: retry warm
        # with a relaxed tolerance before giving up (ladder rung 1)
        lp1 = solve_lp_np(c, A, bl, bu, ub, max_iters=max_lp_iters,
                          tol=1e-5, warm_start=lp1, budget=budget,
                          monitor=monitor)
        if report is not None:
            report.rung("dr_relax_tol",
                        detail=f"retry status={lp1.status}")
            report.absorb_lp(lp1)
    if lp1.status != OPTIMAL:
        status = "lp_budget" if lp1.status == ilp_mod.BUDGET \
            else "lp_infeasible"
        return PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                             0.0, 0.0, status=status)
    lp_obj_query = -lp1.obj if query.maximize else lp1.obj

    tol = 1e-9
    support = lp1.x > tol
    aux_supports = []          # precomputed widening rungs (fallback rounds)
    if aux == "random":
        support |= rng.random(n) < q / max(n, 1)
    else:
        E = float(np.sum(lp1.x))
        rungs = max(1, int(aux_rungs))
        # rung j caps every variable at E/(q*2^j): the support the
        # exponential fallback would need after j doublings of q.  All
        # rungs are bound-variants of one (c, A) warm-started from lp1:
        # one batched dispatch (sequential solve_lp_np when rungs == 1).
        ub_variants = [np.minimum(ub, max(E / (max(q, 1) * 2 ** j), 1e-9))
                       for j in range(rungs)]
        auxs = solve_lp_batch(c, A, bl, bu, ub_variants,
                              max_iters=max_lp_iters,
                              warm_starts=[lp1] * rungs, budget=budget,
                              monitor=monitor, backend=batch_backend)
        if report is not None:
            report.absorb_batch(auxs)
        for jr, lp2 in enumerate(auxs):
            if lp2.status != OPTIMAL:
                continue
            if jr == 0:
                support |= lp2.x > tol
            else:
                aux_supports.append(lp2.x > tol)
    sel = np.flatnonzero(support)

    def _degraded_rounding(n_sel: int, fallbacks: int, why: str):
        """Terminal ladder rung: round-and-repair lp1's relaxation."""
        xr, objr = ilp_mod._swap_search(lp1.x, c, A, bl, bu, np.zeros(n),
                                        ub, 1e-6)
        if xr is None:
            return None
        if report is not None:
            report.rung("degraded_rounded", degrades=True, detail=why)
        nz = xr > 0.5
        obj_query = -objr if query.maximize else objr
        return PackageResult(True, S[nz], xr[nz], obj_query, lp_obj_query,
                             fallbacks, n_sel, status="degraded_rounded",
                             lp_warm=lp1.warm)

    fallbacks = 0
    while True:
        sub = S[sel]
        cs, As, _, _, ubs = query.matrices(table, sub)
        res = ilp_mod.solve_ilp(cs, As, bl, bu, ubs,
                                warm_start=_subset_warm(lp1, sel, n),
                                budget=budget, monitor=monitor,
                                **ilp_kwargs)
        if report is not None:
            report.ilp_nodes += res.nodes
        if res.feasible:
            mult = res.x
            nz = mult > 0.5
            obj_query = -res.obj if query.maximize else res.obj
            return PackageResult(True, sub[nz], mult[nz], obj_query,
                                 lp_obj_query, fallbacks, len(sel),
                                 status="ok", lp_warm=lp1.warm)
        out_of_budget = budget is not None and budget.exhausted()
        if len(sel) >= n or out_of_budget:
            if ladder:
                why = "budget exhausted" if out_of_budget else \
                    "sub-ILP infeasible at full width"
                deg = _degraded_rounding(len(sel), fallbacks, why)
                if deg is not None:
                    return deg
            status = "budget_exhausted" if out_of_budget \
                and len(sel) < n else "ilp_infeasible"
            return PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                                 0.0, lp_obj_query, fallbacks, len(sel),
                                 status=status)
        # fallback: double q, sample additional tuples uniformly (lines 9-14)
        fallbacks += 1
        q = min(2 * max(q, 1), n)
        if aux_supports:
            # a precomputed aux rung already solved this q-doubling:
            # widen deterministically before the random top-up
            sel = np.union1d(sel, np.flatnonzero(aux_supports.pop(0)))
        remaining = np.setdiff1d(np.arange(n), sel, assume_unique=False)
        need = min(max(q - len(sel), 0), len(remaining))
        if need > 0:
            extra = rng.choice(remaining, size=need, replace=False)
            sel = np.union1d(sel, extra)
        else:
            sel = np.arange(n)
