"""Dual Reducer — paper §2.4, Algorithm 4.

RENS-style heuristic ILP solver: LP relaxation x*, auxiliary LP with
per-variable upper bound E/q (E = ||x*||_1) that spreads the support to
~q variables, then a sub-ILP over the union of both supports; exponential
fallback (double q, uniformly sample additional tuples) guarantees
solvability whenever the full ILP is feasible (up to node limits).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import ilp as ilp_mod
from repro.core.lp import INFEASIBLE, OPTIMAL, solve_lp_np
from repro.core.paql import PackageQuery


@dataclasses.dataclass
class PackageResult:
    feasible: bool
    idx: np.ndarray          # global tuple indices in the package
    mult: np.ndarray         # multiplicities (same length)
    obj: float               # objective in the query's own sense
    lp_obj: float            # LP relaxation bound (query sense) over S
    fallbacks: int = 0
    sub_ilp_size: int = 0
    status: str = ""

    def integrality_gap(self, eps: float = 0.1) -> float:
        """Paper §4.1 metric vs. this result's own LP bound."""
        return (abs(self.obj) + eps) / (abs(self.lp_obj) + eps)


def dual_reducer(query: PackageQuery, table: Dict[str, np.ndarray],
                 S: np.ndarray, *, q: int = 500,
                 rng: Optional[np.random.Generator] = None,
                 max_lp_iters: int = 20000,
                 ilp_kwargs: Optional[dict] = None,
                 aux: str = "lp") -> PackageResult:
    """aux: 'lp' (paper's auxiliary LP, line 4-5) | 'random' (Mini-Exp 4
    ablation: random sample of ~q tuples instead)."""
    rng = rng or np.random.default_rng(0)
    ilp_kwargs = dict(ilp_kwargs or {})
    S = np.asarray(S)
    n = len(S)
    c, A, bl, bu, ub = query.matrices(table, S)

    lp1 = solve_lp_np(c, A, bl, bu, ub, max_iters=max_lp_iters)
    if lp1.status != OPTIMAL:
        return PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                             0.0, 0.0, status="lp_infeasible")
    lp_obj_query = -lp1.obj if query.maximize else lp1.obj

    tol = 1e-9
    support = lp1.x > tol
    if aux == "random":
        support |= rng.random(n) < q / max(n, 1)
    else:
        E = float(np.sum(lp1.x))
        ub_aux = np.minimum(ub, max(E / max(q, 1), 1e-9))
        lp2 = solve_lp_np(c, A, bl, bu, ub_aux, max_iters=max_lp_iters)
        if lp2.status == OPTIMAL:
            support |= lp2.x > tol
    sel = np.flatnonzero(support)

    fallbacks = 0
    while True:
        sub = S[sel]
        cs, As, _, _, ubs = query.matrices(table, sub)
        res = ilp_mod.solve_ilp(cs, As, bl, bu, ubs, **ilp_kwargs)
        if res.feasible:
            mult = res.x
            nz = mult > 0.5
            obj_query = -res.obj if query.maximize else res.obj
            return PackageResult(True, sub[nz], mult[nz], obj_query,
                                 lp_obj_query, fallbacks, len(sel),
                                 status="ok")
        if len(sel) >= n:
            return PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                                 0.0, lp_obj_query, fallbacks, len(sel),
                                 status="ilp_infeasible")
        # fallback: double q, sample additional tuples uniformly (lines 9-14)
        fallbacks += 1
        q = min(2 * max(q, 1), n)
        remaining = np.setdiff1d(np.arange(n), sel, assume_unique=False)
        need = min(max(q - len(sel), 0), len(remaining))
        if need > 0:
            extra = rng.choice(remaining, size=need, replace=False)
            sel = np.union1d(sel, extra)
        else:
            sel = np.arange(n)
