"""Out-of-core ``Relation``: the streamed table every layer consumes.

The paper's headline regime is 10^9 tuples processed out-of-core
(Appendix D.2); this module makes that relation a first-class object the
whole query path shares instead of a dict of resident numpy columns:

* :class:`Relation` — a named-column table backed by chunked scans.  The
  contract is intentionally tiny: ``chunks()`` streams ``(n_i, k)`` blocks
  for a subset of columns, ``gather_rows(idx)`` materialises an arbitrary
  index subset (sorted-index gather in chunk order, result restored to the
  caller's order), and ``reduce_columns`` folds a streamed per-column
  reduction without ever holding more than one chunk.  ``rel[name]`` gives
  dict-style column access so existing call sites keep working: in-memory
  relations hand back the real array, out-of-core relations hand back a
  :class:`LazyColumn` that supports fancy indexing (a gather) but refuses
  silent whole-column materialisation.
* :class:`ArrayRelation` — adapter making every existing dict-of-arrays
  table a Relation (zero copy).
* :class:`MemmapRelation` — an on-disk ``(n, k)`` ``.npy``/raw-binary
  matrix with named columns; ``gather_rows`` fancy-indexes the memmap on
  the sorted ids so only touched pages are read.
* :class:`SourceRelation` — wraps any ``ChunkSource`` (the bucketing
  protocol), so anything that can be scanned is a Relation.

Resident-set accounting: every materialisation (chunk or gather) calls
:func:`note_resident`; benchmarks read :func:`peak_resident_rows` to prove
an end-to-end solve held only O(alpha + memory_rows) rows, which is the
acceptance bar for the out-of-core pipeline.  :class:`CountingSource`
wraps a ChunkSource and counts full streaming passes for the same purpose.
"""
from __future__ import annotations

import time
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.core.bucketing import ArraySource, ChunkSource, MemmapSource
from repro.runtime import faults

DEFAULT_CHUNK_ROWS = 1 << 18

# ------------------------------------------------------------- retried I/O

# Transient read faults (flaky disk / network filesystem) are retried with
# capped exponential backoff before they surface.  The jitter rng is
# seeded so a replayed run sleeps the same schedule — determinism is part
# of the failure-semantics contract (see guard / runtime.faults).
_RETRY = {"tries": 4, "base_s": 0.02, "max_s": 1.0, "seed": 0}
_RETRY_STATS = {"retries": 0}


def io_retry_count() -> int:
    """Process-wide count of transient-read retries (all relations);
    ``engine.solve`` diffs it around a solve to fill
    ``SolveReport.fault_retries``."""
    return _RETRY_STATS["retries"]


def configure_retries(*, tries: Optional[int] = None,
                      base_s: Optional[float] = None,
                      max_s: Optional[float] = None,
                      seed: Optional[int] = None) -> Dict[str, float]:
    """Tune the transient-I/O retry policy (None keeps the current value);
    returns the policy now in force.  ``tries`` counts total attempts, so
    ``tries=1`` disables retrying."""
    if tries is not None:
        _RETRY["tries"] = max(1, int(tries))
    if base_s is not None:
        _RETRY["base_s"] = float(base_s)
    if max_s is not None:
        _RETRY["max_s"] = float(max_s)
    if seed is not None:
        _RETRY["seed"] = int(seed)
    return dict(_RETRY)


def _backoff_sleep(attempt: int, rng: np.random.Generator) -> None:
    """Sleep ``min(max_s, base_s * 2^attempt)`` scaled by seeded jitter in
    [0.5, 1.5) — capped exponential backoff."""
    delay = min(_RETRY["max_s"], _RETRY["base_s"] * (2.0 ** attempt))
    time.sleep(delay * (0.5 + rng.random()))


def _retry_io(fn, what: str):
    """Run ``fn()``; transient ``OSError`` retries up to ``tries`` total
    attempts with capped exponential backoff, then re-raises annotated."""
    tries = int(_RETRY["tries"])
    rng = np.random.default_rng(_RETRY["seed"])
    for k in range(tries):
        try:
            return fn()
        except OSError as e:
            if k == tries - 1:
                raise OSError(f"{what}: giving up after {tries} "
                              f"attempts ({e})") from e
            _RETRY_STATS["retries"] += 1
            _backoff_sleep(k, rng)

# ------------------------------------------------------ resident tracking

_PEAK = {"rows": 0}


def note_resident(rows: int) -> None:
    """Record a materialisation of ``rows`` rows (chunk, gather, bucket)."""
    if rows > _PEAK["rows"]:
        _PEAK["rows"] = int(rows)


def peak_resident_rows() -> int:
    return _PEAK["rows"]


def reset_peak_resident() -> None:
    _PEAK["rows"] = 0


def _normalize_idx(idx, num_rows: int) -> np.ndarray:
    """Row selector -> validated int64 id array: boolean masks become
    ``flatnonzero`` (the dict-column idiom), negative / out-of-range ids
    raise instead of silently wrapping."""
    idx = np.asarray(idx)
    if idx.dtype == bool:
        if idx.shape != (num_rows,):
            raise IndexError(f"boolean mask of shape {idx.shape} over "
                             f"{num_rows} rows")
        return np.flatnonzero(idx)
    idx = idx.astype(np.int64, copy=False)
    if len(idx):
        lo, hi = int(idx.min()), int(idx.max())
        if lo < 0:
            raise IndexError(f"negative row id {lo}")
        if hi >= num_rows:
            raise IndexError(f"row id {hi} >= {num_rows}")
    return idx


# -------------------------------------------------------------- lazy column


class LazyColumn:
    """A named column of an out-of-core Relation.

    Supports ``len`` and fancy ``__getitem__`` (one gather per call); any
    attempt to materialise the whole column (``np.asarray``) raises so a
    1e9-row column can never silently become resident.
    """

    def __init__(self, rel: "Relation", name: str):
        self._rel = rel
        self._name = name

    def __len__(self) -> int:
        return self._rel.num_rows

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            idx = np.arange(*idx.indices(self._rel.num_rows))
        arr = np.asarray(idx)
        sel = arr if arr.dtype == bool else np.atleast_1d(arr).ravel()
        out = self._rel.gather_rows(sel, (self._name,))[self._name]
        return float(out[0]) if arr.ndim == 0 else out

    def __array__(self, dtype=None, copy=None):
        raise RuntimeError(
            f"refusing to materialise out-of-core column {self._name!r} "
            f"({self._rel.num_rows} rows); use gather_rows(idx) / chunks() "
            "to stay candidate-resident")


# ----------------------------------------------------------------- Relation


class Relation:
    """Named-column, chunk-scanned table (see module docstring)."""

    columns: Tuple[str, ...] = ()
    in_memory: bool = False
    chunk_rows: int = DEFAULT_CHUNK_ROWS

    # --- required overrides -------------------------------------------
    @property
    def num_rows(self) -> int:
        raise NotImplementedError

    def chunks(self, names: Optional[Sequence[str]] = None,
               chunk_rows: Optional[int] = None) -> Iterator[np.ndarray]:
        """Stream ``(n_i, len(names))`` float64 blocks in row order."""
        raise NotImplementedError

    # --- generic implementations --------------------------------------
    def _cols(self, names: Optional[Sequence[str]]) -> Tuple[str, ...]:
        if names is None:
            return tuple(self.columns)
        missing = [n for n in names if n not in self.columns]
        if missing:
            raise KeyError(f"unknown column(s) {missing}; have "
                           f"{list(self.columns)}")
        return tuple(names)

    def column(self, name: str):
        """Dict-style column access; lazy for out-of-core relations."""
        self._cols((name,))
        return LazyColumn(self, name)

    def __getitem__(self, name: str):
        return self.column(name)

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    def keys(self):
        return tuple(self.columns)

    def gather_rows(self, idx: np.ndarray,
                    names: Optional[Sequence[str]] = None
                    ) -> Dict[str, np.ndarray]:
        """Materialise the rows ``idx`` (any order, duplicates allowed).

        Generic path: one streaming pass, gathering each chunk's members of
        ``sort(idx)`` in chunk order, then the result is un-sorted back to
        the caller's order — O(n/chunk) scan I/O, O(|idx|) resident.
        """
        names = self._cols(names)
        idx = _normalize_idx(idx, self.num_rows)
        order = np.argsort(idx, kind="stable")
        sidx = idx[order]
        out = np.empty((len(idx), len(names)), np.float64)
        base = 0
        lo = 0
        for chunk in self.chunks(names):
            nb = len(chunk)
            hi = lo + np.searchsorted(sidx[lo:], base + nb)
            if hi > lo:
                out[order[lo:hi]] = chunk[sidx[lo:hi] - base]
                lo = hi
            base += nb
            if lo >= len(sidx):
                break
        if lo < len(sidx):
            raise IndexError(f"row ids out of range: {sidx[lo]} >= {base}")
        note_resident(len(idx))
        return {nm: out[:, j] for j, nm in enumerate(names)}

    def gather_matrix(self, idx: np.ndarray,
                      names: Optional[Sequence[str]] = None) -> np.ndarray:
        names = self._cols(names)
        view = self.gather_rows(idx, names)
        return np.stack([view[nm] for nm in names], axis=1)

    def reduce_columns(self, names: Optional[Sequence[str]], chunk_fn,
                       combine, init=None):
        """Streamed per-column reduction: fold ``combine(acc,
        chunk_fn(block))`` over all chunks (``acc`` starts as ``init`` or
        the first chunk's value)."""
        acc = init
        first = init is None
        for chunk in self.chunks(names):
            v = chunk_fn(chunk)
            acc = v if first else combine(acc, v)
            first = False
        return acc

    def chunk_source(self, names: Optional[Sequence[str]] = None,
                     chunk_rows: Optional[int] = None) -> ChunkSource:
        """This relation's columns as a bucketing-protocol ChunkSource."""
        return _RelationSource(self, self._cols(names),
                               chunk_rows or self.chunk_rows)


class _RelationSource(ChunkSource):
    """ChunkSource over a fixed column subset of a Relation."""

    def __init__(self, rel: Relation, names: Tuple[str, ...],
                 chunk_rows: int):
        self.rel = rel
        self.names = names
        self.chunk_rows = chunk_rows

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        return self.rel.chunks(self.names, chunk_rows)

    @property
    def num_rows(self) -> int:
        return self.rel.num_rows

    @property
    def num_cols(self) -> int:
        return len(self.names)


# ------------------------------------------------------------ ArrayRelation


class ArrayRelation(Relation):
    """Every dict-of-arrays table is a Relation (zero-copy adapter)."""

    in_memory = True

    def __init__(self, table: Dict[str, np.ndarray]):
        self._table = {k: np.asarray(v) for k, v in table.items()}
        self.columns = tuple(self._table)
        lens = {len(v) for v in self._table.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged columns: {lens}")

    @property
    def num_rows(self) -> int:
        return len(next(iter(self._table.values()))) if self._table else 0

    def column(self, name: str) -> np.ndarray:
        return self._table[name]

    def chunks(self, names=None, chunk_rows=None) -> Iterator[np.ndarray]:
        names = self._cols(names)
        step = chunk_rows or self.chunk_rows
        n = self.num_rows
        for a in range(0, n, step):
            b = min(a + step, n)
            yield np.stack([np.asarray(self._table[nm][a:b], np.float64)
                            for nm in names], axis=1)

    def gather_rows(self, idx, names=None) -> Dict[str, np.ndarray]:
        names = self._cols(names)
        idx = _normalize_idx(idx, self.num_rows)
        note_resident(len(idx))
        return {nm: np.asarray(self._table[nm], np.float64)[idx]
                for nm in names}


# ----------------------------------------------------------- MemmapRelation


class MemmapRelation(Relation):
    """On-disk ``(n, k)`` matrix with named columns (the container-scale
    stand-in for the paper's PostgreSQL heap file)."""

    in_memory = False

    def __init__(self, X: np.ndarray, columns: Sequence[str],
                 chunk_rows: int = DEFAULT_CHUNK_ROWS):
        if X.ndim != 2 or X.shape[1] != len(columns):
            raise ValueError(f"need (n, {len(columns)}) data, got {X.shape}")
        self.X = X
        self.columns = tuple(columns)
        self.chunk_rows = chunk_rows

    @classmethod
    def from_npy(cls, path: str, columns: Sequence[str],
                 chunk_rows: int = DEFAULT_CHUNK_ROWS) -> "MemmapRelation":
        return cls(np.lib.format.open_memmap(path, mode="r"), columns,
                   chunk_rows)

    @classmethod
    def from_raw(cls, path: str, columns: Sequence[str], *, rows: int,
                 dtype=np.float64, offset: int = 0,
                 chunk_rows: int = DEFAULT_CHUNK_ROWS) -> "MemmapRelation":
        """Headerless binary file: row-major (rows, len(columns))."""
        X = np.memmap(path, dtype=dtype, mode="r", offset=offset,
                      shape=(rows, len(columns)))
        return cls(X, columns, chunk_rows)

    @property
    def num_rows(self) -> int:
        return self.X.shape[0]

    def _col_idx(self, names: Tuple[str, ...]) -> np.ndarray:
        pos = {nm: j for j, nm in enumerate(self.columns)}
        return np.asarray([pos[nm] for nm in names], np.int64)

    def chunks(self, names=None, chunk_rows=None) -> Iterator[np.ndarray]:
        names = self._cols(names)
        cj = self._col_idx(names)
        step = chunk_rows or self.chunk_rows
        full = len(names) == len(self.columns) and \
            np.array_equal(cj, np.arange(len(self.columns)))
        for a in range(0, self.num_rows, step):
            b = min(a + step, self.num_rows)

            def _read(a=a, b=b):
                faults.maybe_raise(faults.CHUNK_READ)
                return np.asarray(self.X[a:b], np.float64)

            block = _retry_io(_read, f"chunk read [{a}:{b})")
            note_resident(b - a)
            yield block if full else block[:, cj]

    def gather_rows(self, idx, names=None) -> Dict[str, np.ndarray]:
        """Sorted-index gather: only the touched memmap pages are read."""
        names = self._cols(names)
        cj = self._col_idx(names)
        idx = _normalize_idx(idx, self.num_rows)
        order = np.argsort(idx, kind="stable")
        rows = np.empty((len(idx), len(self.columns)), np.float64)

        def _read():
            faults.maybe_raise(faults.GATHER_READ)
            return self.X[idx[order]]

        rows[order] = _retry_io(_read, f"gather of {len(idx)} rows")
        note_resident(len(idx))
        return {nm: rows[:, cj[j]] for j, nm in enumerate(names)}

    def chunk_source(self, names=None, chunk_rows=None) -> ChunkSource:
        names = self._cols(names)
        cj = self._col_idx(names)
        if len(names) == len(self.columns) and \
                np.array_equal(cj, np.arange(len(self.columns))):
            src = MemmapSource.__new__(MemmapSource)
            src.X = self.X
            return src
        return super().chunk_source(names, chunk_rows)


# ----------------------------------------------------------- SourceRelation


class SourceRelation(Relation):
    """Any ``ChunkSource`` scan is a Relation once its columns are named."""

    in_memory = False

    def __init__(self, source: ChunkSource, columns: Sequence[str],
                 chunk_rows: int = DEFAULT_CHUNK_ROWS):
        if source.num_cols != len(columns):
            raise ValueError(f"source has {source.num_cols} cols, "
                             f"{len(columns)} names given")
        self.source = source
        self.columns = tuple(columns)
        self.chunk_rows = chunk_rows

    @property
    def num_rows(self) -> int:
        return self.source.num_rows

    def chunks(self, names=None, chunk_rows=None) -> Iterator[np.ndarray]:
        """Resilient scan: a transient ``OSError`` mid-stream restarts the
        source and skips the rows already delivered (a generator that
        raised cannot be resumed), with the same capped backoff as
        :func:`_retry_io`; rows are yielded exactly once."""
        names = self._cols(names)
        pos = {nm: j for j, nm in enumerate(self.columns)}
        cj = np.asarray([pos[nm] for nm in names], np.int64)
        full = np.array_equal(cj, np.arange(len(self.columns)))
        step = chunk_rows or self.chunk_rows
        tries = int(_RETRY["tries"])
        rng = np.random.default_rng(_RETRY["seed"])
        delivered = 0
        failures = 0
        while True:
            gen = self.source.chunks(step)
            skip = delivered
            try:
                for block in gen:
                    faults.maybe_raise(faults.CHUNK_READ)
                    nb = len(block)
                    if skip >= nb:
                        skip -= nb
                        continue
                    if skip:
                        block = block[skip:]
                        skip = 0
                    delivered += len(block)
                    note_resident(len(block))
                    yield block if full else block[:, cj]
                return
            except OSError as e:
                failures += 1
                if failures >= tries:
                    raise OSError(f"source scan: giving up after "
                                  f"{failures} attempts at row "
                                  f"{delivered} ({e})") from e
                _RETRY_STATS["retries"] += 1
                _backoff_sleep(failures - 1, rng)
            finally:
                close = getattr(gen, "close", None)
                if close is not None:
                    close()


# -------------------------------------------------------------- conversion


def as_relation(obj, columns: Optional[Sequence[str]] = None) -> Relation:
    """Coerce a table-ish object to a Relation.

    dict-of-arrays -> :class:`ArrayRelation`; ChunkSource -> a
    :class:`SourceRelation` (``columns`` required, or a MemmapSource
    becomes a :class:`MemmapRelation`); Relations pass through.
    """
    if isinstance(obj, Relation):
        return obj
    if isinstance(obj, ChunkSource):
        if columns is None:
            raise ValueError("need column names to wrap a ChunkSource")
        if isinstance(obj, ArraySource) and hasattr(obj, "X") and \
                getattr(obj.X, "ndim", 0) == 2:
            return MemmapRelation(obj.X, columns)
        return SourceRelation(obj, columns)
    if isinstance(obj, dict):
        return ArrayRelation(obj)
    raise TypeError(f"cannot make a Relation from {type(obj).__name__}")


def gather_column(table, name: str, idx: np.ndarray) -> np.ndarray:
    """One column at ``idx`` (int ids or a boolean mask) for a dict table
    OR a Relation (shared by the shading / neighbor candidate paths)."""
    idx = np.asarray(idx)
    if isinstance(table, Relation) and not table.in_memory:
        return table.gather_rows(idx, (name,))[name]
    # repro: allow[REPRO005] in-memory branch: column already resident
    return np.asarray(table[name], np.float64)[idx]


# --------------------------------------------------------- pass accounting


class CountingSource(ChunkSource):
    """Wraps a ChunkSource and counts full streaming passes + rows read —
    the benchmark instrument proving the bucketed build is O(1) passes."""

    def __init__(self, inner: ChunkSource):
        self.inner = inner
        self.passes = 0
        self.rows_read = 0

    def chunks(self, chunk_rows: int) -> Iterator[np.ndarray]:
        self.passes += 1
        for c in self.inner.chunks(chunk_rows):
            self.rows_read += len(c)
            yield c

    @property
    def num_rows(self) -> int:
        return self.inner.num_rows

    @property
    def num_cols(self) -> int:
        return self.inner.num_cols
