"""Dual simplex driven by the Pallas kernels.

Same pivot rules as ``core.lp._solve_lp_jax`` but the two O(n) inner
procedures run through the TPU kernels:

  * pricing (alpha, BFRT ratios, flip costs) -> kernels.pricing (fused,
    one pass over A),
  * BFRT breakpoint selection -> kernels.bfrt (bucketed two-pass select).

On CPU the kernels execute in interpret mode (slow, correctness only);
on TPU they are the production path.  Tested against solve_lp_np on
random LPs in tests/test_lp_kernel.py.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lp import (INFEASIBLE, ITER_LIMIT, OPTIMAL, LPResult,
                           row_scaling, standard_form)
from repro.kernels.bfrt import bfrt_select
from repro.kernels.pricing import pricing


@partial(jax.jit, static_argnames=("max_iters", "interpret"))
def _solve_lp_kernel_jax(cf, A, l, u, max_iters: int, interpret: bool):
    N = A.shape[1]
    m = A.shape[0]
    n = N - m
    tol = 1e-7

    basis0 = jnp.arange(n, N)
    in_basis0 = jnp.zeros(N, bool).at[basis0].set(True)
    at_upper0 = jnp.zeros(N, bool).at[:n].set(
        (cf[:n] < 0) | jnp.isinf(l[:n]))

    def xb_of(basis, in_basis, at_upper):
        Binv = jnp.linalg.inv(A[:, basis])
        xN = jnp.where(in_basis, 0.0, jnp.where(at_upper, u, l))
        xN = xN.at[basis].set(0.0)
        xB = -Binv @ (A @ xN)
        return Binv, xN, xB

    def cond(state):
        _, _, _, status, it = state
        return (status == ITER_LIMIT) & (it < max_iters)

    def body(state):
        basis, in_basis, at_upper, status, it = state
        Binv, xN, xB = xb_of(basis, in_basis, at_upper)
        lB, uB = l[basis], u[basis]
        viol_lo = lB - xB
        viol_hi = xB - uB
        viol = jnp.maximum(viol_lo, viol_hi)
        r = jnp.argmax(viol)
        done = viol[r] <= tol

        above = viol_hi[r] >= viol_lo[r]
        delta = jnp.where(above, xB[r] - uB[r], xB[r] - lB[r])
        s = jnp.where(delta > 0, 1.0, -1.0)
        rho = Binv[r]
        y = Binv.T @ cf[basis]

        # ---- Pallas: fused pricing over all N columns ----
        state_code = jnp.where(in_basis, 2,
                               jnp.where(at_upper, 1, 0)).astype(jnp.int32)
        lo_safe = jnp.where(jnp.isfinite(l), l, 0.0)
        width = jnp.where(jnp.isfinite(u - l), u - l, 1e30)
        alpha, ratio, cost = pricing(A, rho, y, cf, state_code,
                                     lo_safe, lo_safe + width, s,
                                     block=min(2048, N),
                                     interpret=interpret)
        # ---- Pallas: bucketed BFRT select ----
        q, flips, has_cross = bfrt_select(ratio, cost, jnp.abs(delta),
                                          interpret=interpret)

        new_status = jnp.where(done, OPTIMAL,
                               jnp.where(~has_cross, INFEASIBLE,
                                         ITER_LIMIT)).astype(jnp.int32)
        do_pivot = new_status == ITER_LIMIT

        leave = basis[r]
        at_upper2 = jnp.where(flips, ~at_upper, at_upper)
        at_upper2 = at_upper2.at[leave].set(delta > 0)
        in_basis2 = in_basis.at[leave].set(False).at[q].set(True)
        basis2 = basis.at[r].set(q)

        basis = jnp.where(do_pivot, basis2, basis)
        in_basis = jnp.where(do_pivot, in_basis2, in_basis)
        at_upper = jnp.where(do_pivot, at_upper2, at_upper)
        return (basis, in_basis, at_upper, new_status,
                (it + 1).astype(jnp.int32))

    state = (basis0, in_basis0, at_upper0, jnp.int32(ITER_LIMIT),
             jnp.int32(0))
    basis, in_basis, at_upper, status, it = jax.lax.while_loop(
        cond, body, state)
    Binv, xN, xB = xb_of(basis, in_basis, at_upper)
    x = xN.at[basis].set(xB)
    y = Binv.T @ cf[basis]
    obj = cf @ jnp.where(jnp.isfinite(x), x, 0.0)
    return status, x[:n], obj, it, basis, at_upper, y


def solve_lp_kernel(c, A_t, bl, bu, ub, *, lb: Optional[np.ndarray] = None,
                    max_iters: int = 5000,
                    interpret: Optional[bool] = None) -> LPResult:
    """Kernel-backed twin of core.lp.solve_lp (same conventions)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    c = np.asarray(c, np.float64)
    A_t = np.atleast_2d(np.asarray(A_t, np.float64))
    m, n = A_t.shape
    scale = row_scaling(A_t)
    A_t = A_t * scale[:, None]
    bl = np.asarray(bl, np.float64) * scale
    bu = np.asarray(bu, np.float64) * scale
    cf, A, l, u = standard_form(c, A_t, bl, bu, np.asarray(ub, np.float64))
    if lb is not None:
        l[:n] = lb
    if np.any(l > u + 1e-9):
        return LPResult(INFEASIBLE, np.zeros(n), 0.0, 0,
                        np.arange(n, n + m), np.zeros(n + m, bool),
                        np.zeros(m))
    status, x, obj, it, basis, at_upper, y = _solve_lp_kernel_jax(
        jnp.asarray(cf), jnp.asarray(A), jnp.asarray(l), jnp.asarray(u),
        max_iters, interpret)
    return LPResult(int(status), np.asarray(x), float(obj), int(it),
                    np.asarray(basis), np.asarray(at_upper),
                    np.asarray(y) * scale)
