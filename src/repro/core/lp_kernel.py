"""Revised dual simplex driven by the Pallas kernels.

Same pivot rules and revised-simplex invariants as ``core.lp``
(incrementally-maintained Binv / reduced costs / xB, periodic
refactorization, warm starts) but the O(n) inner procedures run through
the TPU kernels:

  * pricing (alpha, BFRT ratios, flip costs) -> kernels.pricing — with
    reduced costs maintained by an O(n) axpy between pivots, the kernel
    is a single fused pass over A (one rank-1 matvec, one HBM read);
  * BFRT breakpoint selection -> kernels.bfrt (bucketed two-pass select).

On CPU the kernels execute in interpret mode (slow, correctness only);
on TPU they are the production path.  Tested against solve_lp_np on
random LPs in tests/test_lp_kernel.py and tests/test_warm_start.py.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.guard import (DRIFT_TOL, NumericalMonitor, STALL_REFACTOR,
                              SolveBudget, THETA_EPS)
from repro.core.lp import (BUDGET, INFEASIBLE, ITER_LIMIT, OPTIMAL,
                           LPResult, REFACTOR_EVERY, _prep)
from repro.kernels.bfrt import bfrt_select
from repro.kernels.pricing import pricing


@partial(jax.jit, static_argnames=("max_iters", "interpret",
                                   "refactor_every"))
def _solve_lp_kernel_jax(cf, A, l, u, basis0, at_upper0, max_iters: int,
                         interpret: bool,
                         refactor_every: int = REFACTOR_EVERY):
    N = A.shape[1]
    m = A.shape[0]
    n = N - m
    tol = 1e-7

    in_basis0 = jnp.zeros(N, bool).at[basis0].set(True)
    at_upper0 = at_upper0 & ~in_basis0

    def refreshed(basis, in_basis, at_upper):
        Binv = jnp.linalg.inv(A[:, basis])
        xN = jnp.where(in_basis, 0.0, jnp.where(at_upper, u, l))
        xN = xN.at[basis].set(0.0)
        xB = -Binv @ (A @ xN)
        y = Binv.T @ cf[basis]
        d = (cf - A.T @ y).at[basis].set(0.0)
        return Binv, xB, d, y

    def cond(state):
        status, it = state[-3], state[-2]
        return (status == ITER_LIMIT) & (it < max_iters)

    def body(state):
        (basis, in_basis, at_upper, Binv, xB, d, y, stall, n_drift,
         status, it, since) = state

        # refresh branches take the factor state as an explicit operand
        # (lax.cond caches branch jaxprs by function identity; a closure
        # reused across cond calls replays stale captured tracers)
        def do_ref(ops):
            return refreshed(basis, in_basis, at_upper) + (jnp.int32(0),)

        # Binv residual drift -> forced refactorization (guard contract;
        # Bland escalation lives in the non-kernel twins, where the
        # entering-column selection is host-visible)
        resid = jnp.abs(Binv @ A[:, basis]
                        - jnp.eye(m, dtype=A.dtype)).max()
        drift = (resid > DRIFT_TOL) & (since > 0)
        n_drift = n_drift + drift.astype(jnp.int32)
        # repro: allow[REPRO001] do_ref captures the SAME loop-carried
        # tracers at both cond sites within one trace of this body
        Binv, xB, d, y, since = jax.lax.cond(
            drift | (since >= refactor_every), do_ref, lambda ops: ops,
            (Binv, xB, d, y, since))
        lB, uB = l[basis], u[basis]
        viol = jnp.maximum(lB - xB, xB - uB)
        # repro: allow[REPRO001] same captured tracers as the cond above
        Binv, xB, d, y, since = jax.lax.cond(
            (viol[jnp.argmax(viol)] <= tol) & (since > 0), do_ref,
            lambda ops: ops, (Binv, xB, d, y, since))
        viol_lo = lB - xB
        viol_hi = xB - uB
        viol = jnp.maximum(viol_lo, viol_hi)
        r = jnp.argmax(viol)
        done = viol[r] <= tol

        above = viol_hi[r] >= viol_lo[r]
        delta = jnp.where(above, xB[r] - uB[r], xB[r] - lB[r])
        s = jnp.where(delta > 0, 1.0, -1.0)
        rho = Binv[r]

        # ---- Pallas: fused pricing, the single O(mn) sweep over A ----
        state_code = jnp.where(in_basis, 2,
                               jnp.where(at_upper, 1, 0)).astype(jnp.int32)
        lo_safe = jnp.where(jnp.isfinite(l), l, 0.0)
        width = jnp.where(jnp.isfinite(u - l), u - l, 1e30)
        alpha, ratio, cost = pricing(A, rho, d, state_code,
                                     lo_safe, lo_safe + width, s,
                                     block=min(2048, N),
                                     interpret=interpret)
        # ---- Pallas: bucketed BFRT select ----
        q, flip_mask, has_cross = bfrt_select(ratio, cost, jnp.abs(delta),
                                              interpret=interpret)

        stale = since > 0
        w = Binv @ A[:, q]
        # unsafe pivot on drifted factors -> refactorize-and-retry
        # (parity with the numpy twin; impossible on fresh factors)
        unsafe = jnp.abs(w[r]) < 1e-11
        no_pivot = ~has_cross
        new_status = jnp.where(done, OPTIMAL,
                               jnp.where(no_pivot & ~stale, INFEASIBLE,
                                         ITER_LIMIT)).astype(jnp.int32)
        do_pivot = (new_status == ITER_LIMIT) & ~no_pivot & ~unsafe

        # ---- incremental pivot (no inv, no full d recompute) ----
        leave = basis[r]
        dxN = jnp.where(flip_mask,
                        jnp.where(at_upper, l - u, u - l), 0.0)
        xB2 = xB - Binv @ (A @ dxN)     # flip absorption (masked matvec)
        at_upper_f = at_upper ^ flip_mask
        wr = jnp.where(unsafe, 1.0, w[r])
        target = jnp.where(above, uB[r], lB[r])
        t = (xB2[r] - target) / wr
        xq = jnp.where(at_upper_f[q], u[q], l[q])
        xB3 = (xB2 - t * w).at[r].set(xq + t)
        theta = d[q] / wr
        d2 = (d - theta * alpha).at[q].set(0.0).at[leave].set(-theta)
        y2 = y + theta * rho
        Binv_r = Binv[r] / wr
        Binv2 = (Binv - jnp.outer(w, Binv_r)).at[r].set(Binv_r)
        at_upper2 = at_upper_f.at[leave].set(above).at[q].set(False)
        in_basis2 = in_basis.at[leave].set(False).at[q].set(True)
        basis2 = basis.at[r].set(q)

        basis = jnp.where(do_pivot, basis2, basis)
        in_basis = jnp.where(do_pivot, in_basis2, in_basis)
        at_upper = jnp.where(do_pivot, at_upper2, at_upper)
        Binv = jnp.where(do_pivot, Binv2, Binv)
        xB = jnp.where(do_pivot, xB3, xB)
        d = jnp.where(do_pivot, d2, d)
        y = jnp.where(do_pivot, y2, y)
        since = jnp.where(do_pivot, since + 1,
                          jnp.where((no_pivot | unsafe) & stale,
                                    jnp.int32(refactor_every), since))
        # degenerate-pivot streak -> forced refactorization (anti-cycling)
        degen = do_pivot & (jnp.abs(theta) <= THETA_EPS)
        progress = do_pivot & (jnp.abs(theta) > THETA_EPS)
        stall = jnp.where(progress, 0,
                          jnp.where(degen, stall + 1, stall))
        since = jnp.where(degen & (stall == STALL_REFACTOR),
                          jnp.int32(refactor_every), since)
        return (basis, in_basis, at_upper, Binv, xB, d, y,
                stall.astype(jnp.int32), n_drift, new_status,
                (it + 1).astype(jnp.int32), since.astype(jnp.int32))

    state = (basis0, in_basis0, at_upper0, jnp.eye(m, dtype=A.dtype),
             jnp.zeros(m, A.dtype), cf, jnp.zeros(m, A.dtype),
             jnp.int32(0), jnp.int32(0),
             jnp.int32(ITER_LIMIT), jnp.int32(0),
             jnp.int32(refactor_every))  # since=K: factorize on entry
    state = jax.lax.while_loop(cond, body, state)
    (basis, in_basis, at_upper, _, _, _, _, _, n_drift, status, it,
     _) = state
    Binv, xB, d, y = refreshed(basis, in_basis, at_upper)
    xN = jnp.where(in_basis, 0.0, jnp.where(at_upper, u, l))
    xN = xN.at[basis].set(0.0)
    x = xN.at[basis].set(xB)
    obj = cf @ jnp.where(jnp.isfinite(x), x, 0.0)
    return status, x[:n], obj, it, basis, at_upper, y, n_drift


def solve_lp_kernel(c, A_t, bl, bu, ub, *, lb: Optional[np.ndarray] = None,
                    max_iters: int = 5000,
                    interpret: Optional[bool] = None,
                    warm_start=None,
                    budget: Optional[SolveBudget] = None,
                    monitor: Optional[NumericalMonitor] = None) -> LPResult:
    """Kernel-backed twin of core.lp.solve_lp (same conventions, including
    the warm-start and budget/monitor contracts)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    arrs, scale, m, n, start = _prep(c, A_t, bl, bu, ub, lb, warm_start)
    if arrs is None:
        return LPResult(INFEASIBLE, np.zeros(n), 0.0, 0,
                        np.arange(n, n + m), np.zeros(n + m, bool),
                        np.zeros(m))
    cf, A, l, u = arrs
    basis0, at_upper0, _, wnote = start
    notes = [] if wnote is None else [wnote]
    cap = max_iters
    if budget is not None:
        budget.start()
        if budget.out_of_time() or budget.remaining_pivots() <= 0:
            notes.append("budget: exhausted before LP solve")
            return LPResult(BUDGET, np.zeros(n), 0.0, 0,
                            np.asarray(basis0),
                            np.asarray(at_upper0, bool), np.zeros(m),
                            notes=tuple(notes))
        cap = budget.lp_iter_cap(max_iters)
    status, x, obj, it, basis, at_upper, y, n_drift = _solve_lp_kernel_jax(
        jnp.asarray(cf), jnp.asarray(A), jnp.asarray(l), jnp.asarray(u),
        jnp.asarray(basis0), jnp.asarray(at_upper0), cap, interpret)
    status, it, n_drift = int(status), int(it), int(n_drift)
    if n_drift:
        notes.append(f"drift: {n_drift} forced refactorizations")
    if monitor is not None:
        monitor.drift_refactors += n_drift
    if budget is not None:
        budget.charge_pivots(it)
        if status == ITER_LIMIT and (cap < max_iters
                                     or budget.exhausted()):
            status = BUDGET
            notes.append(f"budget: truncated at pivot cap {cap}")
    return LPResult(status, np.asarray(x), float(obj), it,
                    np.asarray(basis), np.asarray(at_upper),
                    np.asarray(y) * scale, notes=tuple(notes))
