"""PaQL-style package queries and their ILP/LP standard forms.

A package query over a relation R (columns = named float arrays, OR a
:class:`repro.core.relation.Relation` for out-of-core tables):

    SELECT PACKAGE(*) FROM R REPEAT r
    WHERE <local predicate mask>
    SUCH THAT
        cl <= COUNT(P.*) <= cu
        SUM(P.attr) {<=,>=,BETWEEN} b ...
        AVG(P.attr) {<=,>=} t ...
    {MAXIMIZE|MINIMIZE} SUM(P.obj)

maps to the ILP  opt cᵀx  s.t.  bl <= Ax <= bu,  0 <= x <= r+1,  x ∈ ℤ.

AVG(P.a) >= t is linearised as SUM(P.a) - t*COUNT(P) >= 0, i.e. a row with
coefficients (a_i - t).

Out-of-core path: ``matrices(rel, subset)`` builds the candidate-resident
standard form from ONE ``gather_rows`` over the query's attributes — the
whole pipeline (shading layers, Dual Reducer, validation) passes tuple-id
subsets around and only ever materialises O(|subset|) rows.  With
``subset=None`` over a streamed relation the (m, n) assembly is filled
chunk-wise (each constraint row is a plain column gather) behind a size
guard, since a dense full-relation form at 10^9 tuples is exactly what the
paper's architecture avoids.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

INF = float("inf")

# Dense full-relation (c, A, ub) assembly guard for streamed relations:
# raise above this many bytes instead of silently materialising.
FULL_MATRIX_BUDGET_BYTES = 4 << 30


@dataclasses.dataclass(frozen=True)
class Constraint:
    """bl <= SUM(coeff_expr) <= bu over the package."""
    attr: Optional[str]          # None => COUNT (coefficients 1)
    lo: float = -INF
    hi: float = INF
    avg_target: Optional[float] = None  # AVG constraint: coeff = attr - target

    def coeffs(self, table: Dict[str, np.ndarray], n: int) -> np.ndarray:
        if self.attr is None:
            return np.ones(n)
        col = np.asarray(table[self.attr], dtype=np.float64)
        if self.avg_target is not None:
            return col - self.avg_target
        return col


def _is_streamed(table) -> bool:
    from repro.core.relation import Relation
    return isinstance(table, Relation) and not table.in_memory


def _gather_view(table, names: Sequence[str],
                 idx: np.ndarray) -> Dict[str, np.ndarray]:
    """The rows ``idx`` of the named columns, for a dict or a Relation."""
    from repro.core.relation import Relation
    if isinstance(table, Relation):
        return table.gather_rows(idx, tuple(names))
    return {nm: np.asarray(table[nm], np.float64)[idx] for nm in names}


@dataclasses.dataclass(frozen=True)
class QuerySignature:
    """Canonical, hashable identity of a package query's constraint
    region (the cross-query cache key — see ``repro.core.qcache``).

    Constraint order is normalized away (sorted by constraint identity),
    bounds are canonical floats, and equality/hash follow from the
    frozen-dataclass field tuple.  ``keys`` holds one ``(attr,
    avg_target)`` identity per constraint ('' = COUNT, None = plain
    SUM); ``los``/``his`` the matching interval endpoints.
    """
    objective_attr: str
    maximize: bool
    repeat: int
    predicate_attr: Optional[str]
    keys: Tuple[Tuple[str, Optional[float]], ...]
    los: Tuple[float, ...]
    his: Tuple[float, ...]

    def same_structure(self, other: "QuerySignature") -> bool:
        """Identical up to the constraint intervals: same objective and
        sense, same repeat/predicate, same constraint identities."""
        return (self.objective_attr == other.objective_attr
                and self.maximize == other.maximize
                and self.repeat == other.repeat
                and self.predicate_attr == other.predicate_attr
                and self.keys == other.keys)

    def contained_in(self, other: "QuerySignature") -> bool:
        """True when this query's constraint region is contained in
        ``other``'s: same structure and every interval nested.  Sound
        for the cache's subsumption path — any package feasible for
        ``self`` is feasible for ``other``, so ``other``'s candidate
        sets cover at least the region ``self`` can draw from."""
        if not self.same_structure(other):
            return False
        return all(lo >= olo and hi <= ohi
                   for lo, olo, hi, ohi in zip(self.los, other.los,
                                               self.his, other.his))

    def digest(self) -> str:
        """Process-stable hex digest (string ``hash()`` is salted per
        process; persisted/shared caches need this instead)."""
        payload = repr((self.objective_attr, self.maximize, self.repeat,
                        self.predicate_attr, self.keys, self.los,
                        self.his))
        return hashlib.sha1(payload.encode()).hexdigest()


@dataclasses.dataclass(frozen=True)
class PackageQuery:
    objective_attr: str
    maximize: bool
    constraints: Tuple[Constraint, ...]
    repeat: int = 0              # each tuple usable up to repeat+1 times
    predicate_attr: Optional[str] = None   # local predicate: column of {0,1}

    @property
    def m(self) -> int:
        return len(self.constraints)

    def needed_attrs(self, table=None) -> List[str]:
        """Columns this query touches (objective, constraints, predicate —
        the predicate only where the table actually carries it)."""
        names = [self.objective_attr]
        for ct in self.constraints:
            if ct.attr is not None and ct.attr not in names:
                names.append(ct.attr)
        if self.predicate_attr is not None and \
                self.predicate_attr not in names and \
                (table is None or self.predicate_attr in table):
            names.append(self.predicate_attr)
        return names

    def signature(self) -> QuerySignature:
        """Canonical :class:`QuerySignature` for cross-query caching.

        Reordered-but-identical constraint lists produce identical
        signatures; tightening any interval produces a signature
        ``contained_in`` the original's.
        """
        rows = sorted(
            ((ct.attr or "",
              None if ct.avg_target is None else float(ct.avg_target),
              float(ct.lo), float(ct.hi))
             for ct in self.constraints),
            key=lambda r: (r[0], -INF if r[1] is None else r[1],
                           r[2], r[3]))
        return QuerySignature(
            objective_attr=self.objective_attr,
            maximize=bool(self.maximize),
            repeat=int(self.repeat),
            predicate_attr=self.predicate_attr,
            keys=tuple((a, t) for a, t, _, _ in rows),
            los=tuple(lo for _, _, lo, _ in rows),
            his=tuple(hi for _, _, _, hi in rows))

    # ------------------------------------------------------------------
    def _assemble(self, view: Dict[str, np.ndarray], n: int):
        c = np.asarray(view[self.objective_attr], np.float64).copy()
        if self.maximize:
            c = -c
        A = np.stack([ct.coeffs(view, n) for ct in self.constraints]) \
            if self.constraints else np.zeros((0, n))
        ub = np.full(n, self.repeat + 1, np.float64)
        # Local predicates (Appendix E): applied where the column exists —
        # layer-0 tables carry it (final ILP forces ub=0 on excluded
        # tuples); representative layers don't (predicates are ignored
        # until the final layer, the paper's "efficient approach").
        if self.predicate_attr is not None and self.predicate_attr in view:
            ub = ub * np.asarray(view[self.predicate_attr], np.float64)
        return c, A, ub

    def matrices(self, table, subset: Optional[np.ndarray] = None):
        """Dense (c, A, bl, bu, ub) for the tuples in ``subset`` (or all).

        Returns the MINIMIZATION form: internal c is negated for MAXIMIZE.
        ``table`` may be a dict of arrays or any Relation; only the
        query's own attributes are ever gathered.
        """
        bl = np.array([ct.lo for ct in self.constraints], np.float64)
        bu = np.array([ct.hi for ct in self.constraints], np.float64)
        names = self.needed_attrs(table)
        if subset is not None:
            idx = np.asarray(subset)
            view = _gather_view(table, names, idx)
            c, A, ub = self._assemble(view, len(idx))
            return c, A, bl, bu, ub
        if not _is_streamed(table):
            # dict of arrays, or an in-memory Relation (columns resident)
            # repro: allow[REPRO005] guarded by _is_streamed above
            view = {nm: np.asarray(table[nm], np.float64) for nm in names}
            n = len(view[self.objective_attr])
            c, A, ub = self._assemble(view, n)
            return c, A, bl, bu, ub
        # streamed full-relation assembly: chunk-wise column gathers
        n = table.num_rows
        need = (self.m + 2) * n * 8
        if need > FULL_MATRIX_BUDGET_BYTES:
            raise ValueError(
                f"full-relation matrix assembly over {n} streamed rows "
                f"needs ~{need / 1e9:.1f} GB (> "
                f"{FULL_MATRIX_BUDGET_BYTES / 1e9:.1f} GB budget); use the "
                "hierarchical solver (engine.solve) for out-of-core "
                "relations, or raise repro.core.paql."
                "FULL_MATRIX_BUDGET_BYTES explicitly")
        c = np.empty(n, np.float64)
        A = np.empty((self.m, n), np.float64)
        ub = np.empty(n, np.float64)
        a = 0
        for block in table.chunks(tuple(names)):
            b = a + len(block)
            view = {nm: block[:, j] for j, nm in enumerate(names)}
            cc, Ac, uc = self._assemble(view, b - a)
            c[a:b] = cc
            A[:, a:b] = Ac
            ub[a:b] = uc
            a = b
        return c, A, bl, bu, ub

    def objective_value(self, table, idx: np.ndarray,
                        mult: np.ndarray) -> float:
        col = _gather_view(table, (self.objective_attr,),
                           np.asarray(idx))[self.objective_attr]
        return float(np.dot(col, mult))

    def check_package(self, table, idx: np.ndarray,
                      mult: np.ndarray, tol: float = 1e-6) -> bool:
        """Validate the package against the relation — one gather of the
        package's own rows (streamed columns for out-of-core tables)."""
        idx = np.asarray(idx)
        names = [ct.attr for ct in self.constraints if ct.attr is not None]
        view = _gather_view(table, list(dict.fromkeys(names)), idx) \
            if names else {}
        for ct in self.constraints:
            coeff = ct.coeffs(view, len(idx))
            val = float(np.dot(coeff, mult))
            if val < ct.lo - tol or val > ct.hi + tol:
                return False
        return True
