"""PaQL-style package queries and their ILP/LP standard forms.

A package query over a relation R (columns = named float arrays):

    SELECT PACKAGE(*) FROM R REPEAT r
    WHERE <local predicate mask>
    SUCH THAT
        cl <= COUNT(P.*) <= cu
        SUM(P.attr) {<=,>=,BETWEEN} b ...
        AVG(P.attr) {<=,>=} t ...
    {MAXIMIZE|MINIMIZE} SUM(P.obj)

maps to the ILP  opt cᵀx  s.t.  bl <= Ax <= bu,  0 <= x <= r+1,  x ∈ ℤ.

AVG(P.a) >= t is linearised as SUM(P.a) - t*COUNT(P) >= 0, i.e. a row with
coefficients (a_i - t).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Constraint:
    """bl <= SUM(coeff_expr) <= bu over the package."""
    attr: Optional[str]          # None => COUNT (coefficients 1)
    lo: float = -INF
    hi: float = INF
    avg_target: Optional[float] = None  # AVG constraint: coeff = attr - target

    def coeffs(self, table: Dict[str, np.ndarray], n: int) -> np.ndarray:
        if self.attr is None:
            return np.ones(n)
        col = np.asarray(table[self.attr], dtype=np.float64)
        if self.avg_target is not None:
            return col - self.avg_target
        return col


@dataclasses.dataclass(frozen=True)
class PackageQuery:
    objective_attr: str
    maximize: bool
    constraints: Tuple[Constraint, ...]
    repeat: int = 0              # each tuple usable up to repeat+1 times
    predicate_attr: Optional[str] = None   # local predicate: column of {0,1}

    @property
    def m(self) -> int:
        return len(self.constraints)

    # ------------------------------------------------------------------
    def matrices(self, table: Dict[str, np.ndarray],
                 subset: Optional[np.ndarray] = None):
        """Dense (c, A, bl, bu, ub) for the tuples in ``subset`` (or all).

        Returns the MINIMIZATION form: internal c is negated for MAXIMIZE.
        """
        any_col = next(iter(table.values()))
        n_all = len(any_col)
        idx = np.arange(n_all) if subset is None else np.asarray(subset)
        view = {k: np.asarray(v, np.float64)[idx] for k, v in table.items()}
        n = len(idx)
        c = np.asarray(view[self.objective_attr], np.float64).copy()
        if self.maximize:
            c = -c
        A = np.stack([ct.coeffs(view, n) for ct in self.constraints])
        bl = np.array([ct.lo for ct in self.constraints], np.float64)
        bu = np.array([ct.hi for ct in self.constraints], np.float64)
        ub = np.full(n, self.repeat + 1, np.float64)
        # Local predicates (Appendix E): applied where the column exists —
        # layer-0 tables carry it (final ILP forces ub=0 on excluded
        # tuples); representative layers don't (predicates are ignored
        # until the final layer, the paper's "efficient approach").
        if self.predicate_attr is not None and self.predicate_attr in view:
            ub = ub * np.asarray(view[self.predicate_attr], np.float64)
        return c, A, bl, bu, ub

    def objective_value(self, table: Dict[str, np.ndarray],
                        idx: np.ndarray, mult: np.ndarray) -> float:
        col = np.asarray(table[self.objective_attr], np.float64)
        return float(np.dot(col[idx], mult))

    def check_package(self, table: Dict[str, np.ndarray], idx: np.ndarray,
                      mult: np.ndarray, tol: float = 1e-6) -> bool:
        for ct in self.constraints:
            coeff = ct.coeffs({k: np.asarray(v, np.float64)[idx]
                               for k, v in table.items()}, len(idx))
            val = float(np.dot(coeff, mult))
            if val < ct.lo - tol or val > ct.hi + tol:
                return False
        return True
