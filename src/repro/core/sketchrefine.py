"""SketchRefine baseline (Brucato et al. [5]) — the prior state of the art
Progressive Shading is evaluated against (paper §4.2).

Sketch: solve the package ILP over KD-tree representative tuples, where each
representative may be picked up to |group| times.  Refine: for each sketched
group in objective order, replace its representative with the group's actual
tuples and re-solve, keeping already-fixed tuples and the other groups'
representatives; greedy, no backtracking — exactly the behaviour whose
false-infeasibility/quality limits §4.2 demonstrates.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import ilp as ilp_mod
from repro.core import partitioner
from repro.core.dual_reducer import PackageResult
from repro.core.paql import PackageQuery


def sketch_refine(query: PackageQuery, table, attrs, *,
                  tau_frac: float = 0.001,
                  ilp_kwargs: Optional[dict] = None,
                  backend: str = "kdtree",
                  memory_rows: Optional[int] = None,
                  chunk_rows: Optional[int] = None) -> PackageResult:
    """SketchRefine over any registered partitioner backend (the paper's
    baseline uses KD-tree; ``backend="dlv"`` gives Stochastic-SketchRefine
    style cheap re-partitioning on DLV groups).  ``table`` may be a dict
    of arrays or a Relation: a streamed relation is partitioned through
    the out-of-core bucketing backend and the refine loop gathers only
    each step's fixed tuples + one group's members."""
    from repro.core.relation import as_relation

    ilp_kwargs = dict(ilp_kwargs or {})
    rel = as_relation(table, columns=list(attrs))
    n = rel.num_rows
    tau = max(2, int(tau_frac * n))
    if rel.in_memory:
        # repro: allow[REPRO005] guarded by rel.in_memory: resident view
        X = np.stack([np.asarray(rel[a], np.float64) for a in attrs],
                     axis=1)
        part = partitioner.fit(X, backend=backend,
                               **({"tau": tau} if backend == "kdtree"
                                  else {"d_f": tau}))
    else:
        kw = {"d_f": tau}
        if memory_rows is not None:
            kw["memory_rows"] = memory_rows
        if chunk_rows is not None:
            kw["chunk_rows"] = chunk_rows
        part = partitioner.fit(rel.chunk_source(list(attrs), chunk_rows),
                               backend="bucketing", **kw)
    col = {a: part.reps[:, i] for i, a in enumerate(attrs)}
    sizes = part.counts.astype(np.float64)

    # ---- sketch: ILP over representatives, multiplicity up to group size
    c, A, bl, bu, _ = query.matrices(col, None)
    res = ilp_mod.solve_ilp(c, A, bl, bu, sizes * (query.repeat + 1),
                            **ilp_kwargs)
    if not res.feasible:
        return PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                             0.0, 0.0, status="sketch_infeasible")
    lp_obj_query = -res.lp_obj if query.maximize else res.lp_obj

    # ---- refine: group by group, in representative-objective order
    chosen_groups = np.flatnonzero(res.x > 0.5)
    obj_rep = col[query.objective_attr][chosen_groups]
    order = np.argsort(-obj_rep if query.maximize else obj_rep)
    chosen_groups = chosen_groups[order]

    fixed_idx: list = []
    fixed_mult: list = []
    rep_mult = res.x.copy()
    for g in chosen_groups:
        members = np.flatnonzero(part.gid == g)
        # candidate variables: fixed tuples (bounds pinned) + this group's
        # tuples + remaining representatives
        rem_groups = rep_mult.copy()
        rem_groups[g] = 0.0
        rg = np.flatnonzero(rem_groups > 0.5)
        nf, ng, nr = len(fixed_idx), len(members), len(rg)
        attrs_q = query_attrs(query, table)
        fixed_view = rel.gather_rows(np.asarray(fixed_idx, np.int64),
                                     attrs_q) if nf else \
            {a: np.zeros(0) for a in attrs_q}
        mem_view = rel.gather_rows(members, attrs_q)
        cols = {a: np.concatenate([fixed_view[a], mem_view[a],
                                   col[a][rg]]) for a in attrs_q}
        c2, A2, bl2, bu2, _ = query.matrices(cols, None)
        lb2 = np.concatenate([np.asarray(fixed_mult, np.float64) if nf
                              else np.zeros(0), np.zeros(ng + nr)])
        ub2 = np.concatenate([
            np.asarray(fixed_mult, np.float64) if nf else np.zeros(0),
            np.full(ng, query.repeat + 1.0),
            sizes[rg] * (query.repeat + 1)])
        r2 = ilp_mod.solve_ilp(c2, A2, bl2, bu2, ub2, lb=lb2, **ilp_kwargs)
        if not r2.feasible:
            return PackageResult(False, np.zeros(0, np.int64), np.zeros(0),
                                 0.0, lp_obj_query,
                                 status="refine_infeasible")
        x2 = r2.x
        gm = x2[nf:nf + ng]
        nz = gm > 0.5
        fixed_idx.extend(members[nz].tolist())
        fixed_mult.extend(gm[nz].tolist())
        rep_mult[rg] = x2[nf + ng:]
        rep_mult[g] = 0.0
        if not np.any(rep_mult > 0.5):
            break

    idx = np.asarray(fixed_idx, np.int64)
    mult = np.asarray(fixed_mult, np.float64)
    if not query.check_package(table, idx, mult):
        return PackageResult(False, idx, mult, 0.0, lp_obj_query,
                             status="refine_package_invalid")
    obj = query.objective_value(table, idx, mult)
    return PackageResult(True, idx, mult, obj, lp_obj_query, status="ok")


def query_attrs(query: PackageQuery, table) -> list:
    attrs = [query.objective_attr]
    for ct in query.constraints:
        if ct.attr is not None and ct.attr not in attrs:
            attrs.append(ct.attr)
    return attrs
