"""Unified Partitioner subsystem — the common layer over every partitioning
strategy in the repo (paper §3 + Appendix D).

All backends (``dlv`` — Algorithm 6, ``kdtree`` — the SketchRefine baseline,
``bucketing`` — the out-of-core Appendix D.2 scheme) produce the same
:class:`Partition`: group ids, a permutation making groups contiguous
slices, per-group representatives/bounding boxes, and a *flat array split
tree* answering GetGroup for one tuple (scalar descent) or a whole batch
(vectorized descent, optionally jitted through ``lax.while_loop``).

Select a backend by name::

    from repro.core import partitioner
    part = partitioner.fit(X, backend="dlv", d_f=100)
    part.get_group(X[0])          # scalar GiST-style descent
    part.get_group_batch(X[:1000])  # one vectorized descent for all rows

Group statistics (representatives = member means, boxes = member min/max)
are produced by :func:`group_stats` — a single vectorized ``reduceat`` pass
in memory, or a chunked accumulation that optionally runs each chunk's
count/sum/sum-of-squares on a device mesh (shard_map + psum, the
``kernels/segstats.py`` role) so layer-0 stats at 10^8+ tuples never
require a host-side sorted copy of the relation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- split tree


@dataclasses.dataclass
class SplitTree:
    """Flat array split tree (replaces the old ``List[SplitNode]`` pointers).

    Node ``i`` splits on attribute ``attr[i]`` with ascending boundary
    values ``bounds[bound_off[i]:bound_off[i+1]]``; its ``b_i + 1`` children
    (``b_i`` = number of bounds) live at ``children[bound_off[i] + i :]`` —
    the child base is ``bound_off[i] + i`` because every node has exactly
    one more child than bounds, so no second offset array is needed.
    ``children`` entries >= 0 are node ids; entries < 0 encode leaf group
    ids as ``~gid``.  ``root`` is a node id, or ``~gid`` when the partition
    never split (single group).
    """
    attr: np.ndarray          # (N,) int32
    bound_off: np.ndarray     # (N+1,) int64
    bounds: np.ndarray        # (B,) float64
    children: np.ndarray      # (B+N,) int64
    root: int

    @property
    def num_nodes(self) -> int:
        return len(self.attr)

    @staticmethod
    def single_leaf() -> "SplitTree":
        return SplitTree(np.zeros(0, np.int32), np.zeros(1, np.int64),
                         np.zeros(0, np.float64), np.zeros(0, np.int64), ~0)

    def descend(self, t: np.ndarray) -> int:
        """Scalar GetGroup: sub-linear split-tree descent (GiST analogue)."""
        node = int(self.root)
        while node >= 0:
            b0, b1 = self.bound_off[node], self.bound_off[node + 1]
            pos = b0 + np.searchsorted(self.bounds[b0:b1],
                                       t[self.attr[node]], side="right")
            node = int(self.children[node + pos])
        return ~node

    def descend_batch(self, T: np.ndarray) -> np.ndarray:
        """Vectorized GetGroup over a (m, k) batch of tuples.

        All rows descend in lock-step: one vectorized binary search per
        tree level over each row's private bounds slice (ragged slices, so
        a masked manual bisection instead of ``np.searchsorted``).
        """
        T = np.asarray(T, np.float64)
        cur = np.full(T.shape[0], self.root, np.int64)
        if self.num_nodes == 0:
            return ~cur
        act = np.flatnonzero(cur >= 0)
        while len(act):
            nodes = cur[act]
            vals = T[act, self.attr[nodes]]
            lo = self.bound_off[nodes].copy()
            hi = self.bound_off[nodes + 1].copy()
            live = lo < hi
            while live.any():
                mid = (lo + hi) >> 1
                take = live & (self.bounds[np.minimum(mid, len(self.bounds)
                                                      - 1)] <= vals)
                lo = np.where(take, mid + 1, lo)
                hi = np.where(live & ~take, mid, hi)
                live = lo < hi
            cur[act] = self.children[nodes + lo]   # child base = bound_off+node
            act = act[cur[act] >= 0]
        return ~cur

    def descend_batch_jax(self, T) -> jax.Array:
        """Jit-able batch GetGroup (``lax.while_loop`` over tree levels)."""
        T = jnp.asarray(T)
        if self.num_nodes == 0:
            return jnp.full(T.shape[0], ~int(self.root), jnp.int64)
        # nodes may all be bound-less (single-child chains, e.g. a merged
        # single-bucket tree): pad with a sentinel so the traced gather in
        # the bisect body never reads from a size-0 array
        bounds = self.bounds if len(self.bounds) else np.array([np.inf])
        return _descend_batch_jax(jnp.asarray(self.attr),
                                  jnp.asarray(self.bound_off),
                                  jnp.asarray(bounds, T.dtype),
                                  jnp.asarray(self.children),
                                  int(self.root), T)


@jax.jit
def _descend_batch_jax(attr, bound_off, bounds, children, root, T):
    m = T.shape[0]
    rows = jnp.arange(m)

    def level(cur):
        node = jnp.maximum(cur, 0)
        vals = T[rows, attr[node]]
        lo0 = bound_off[node]

        def bisect_body(state):
            lo, hi = state
            live = lo < hi
            mid = (lo + hi) >> 1
            take = live & (bounds[jnp.minimum(mid, bounds.shape[0] - 1)]
                           <= vals)
            return (jnp.where(take, mid + 1, lo),
                    jnp.where(live & ~take, mid, hi))

        lo, _ = jax.lax.while_loop(lambda s: jnp.any(s[0] < s[1]),
                                   bisect_body, (lo0, bound_off[node + 1]))
        nxt = children[node + lo]
        return jnp.where(cur >= 0, nxt, cur)

    cur = jax.lax.while_loop(lambda c: jnp.any(c >= 0), level,
                             jnp.full(m, root, jnp.int64))
    return ~cur


# ----------------------------------------------------------------- Partition


@dataclasses.dataclass
class Partition:
    """Common result of every partitioning backend (``fit``)."""
    gid: np.ndarray           # (n,) group id per tuple
    order: np.ndarray         # permutation; groups are contiguous slices
    offsets: np.ndarray       # (G+1,) slice bounds into order
    reps: np.ndarray          # (G, k) group means (representative tuples)
    boxes_lo: np.ndarray      # (G, k) member min per attr
    boxes_hi: np.ndarray      # (G, k)
    tree: SplitTree

    @property
    def num_groups(self) -> int:
        return len(self.offsets) - 1

    @property
    def counts(self) -> np.ndarray:
        return np.diff(self.offsets)

    def members(self, g: int) -> np.ndarray:
        return self.order[self.offsets[g]:self.offsets[g + 1]]

    def members_batch(self, gs: np.ndarray) -> np.ndarray:
        """Concatenated members of groups ``gs`` (one vectorized gather)."""
        gs = np.asarray(gs, np.int64)
        starts = self.offsets[gs]
        lens = self.offsets[gs + 1] - starts
        total = int(lens.sum())
        if total == 0:
            return np.zeros(0, np.int64)
        base = np.repeat(starts - np.concatenate(
            [[0], np.cumsum(lens)[:-1]]), lens)
        return self.order[base + np.arange(total)]

    def get_group(self, t: np.ndarray) -> int:
        return self.tree.descend(np.asarray(t))

    def get_group_batch(self, T: np.ndarray, *, jit: bool = False):
        if jit:
            return self.tree.descend_batch_jax(T)
        return self.tree.descend_batch(T)


# --------------------------------------------------------- backend registry


_BACKENDS: Dict[str, Callable[..., Partition]] = {}


def register_backend(name: str):
    def deco(fn):
        _BACKENDS[name] = fn
        return fn
    return deco


def _ensure_backends() -> None:
    # Importing the strategy modules registers them (kept lazy so this
    # module stays import-cycle-free).
    from repro.core import bucketing, dlv, kdtree  # noqa: F401


def available_backends():
    _ensure_backends()
    return sorted(_BACKENDS)


def fit(X, *, backend: str = "dlv", **kwargs) -> Partition:
    """Partition ``X`` (array, or a ChunkSource for ``bucketing`` — e.g.
    ``Relation.chunk_source()`` for an out-of-core table; the bucketing
    backend also accepts ``mesh=`` to shard its streaming stats passes)."""
    _ensure_backends()
    if backend not in _BACKENDS:
        raise ValueError(f"unknown partitioner backend {backend!r}; "
                         f"have {sorted(_BACKENDS)}")
    return _BACKENDS[backend](X, **kwargs)


# ------------------------------------------------------------- group stats


def _chunk_stats_jit(mesh, G: int, k: int):
    """Per-chunk (count, sum, sumsq) on the mesh: rows sharded over the
    'data' axis, per-device scatter-add partials psum-reduced — the
    shard-level twin of ``kernels.segstats`` (ids must be < G+1; row G is
    the padding bin)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import shard_map

    axis = mesh.axis_names[0]

    def local(v, i):
        cnt = jnp.zeros(G + 1, v.dtype).at[i].add(1.0)
        s = jnp.zeros((G + 1, k), v.dtype).at[i].add(v)
        q = jnp.zeros((G + 1, k), v.dtype).at[i].add(v * v)
        return (jax.lax.psum(cnt, axis), jax.lax.psum(s, axis),
                jax.lax.psum(q, axis))

    fn = jax.jit(shard_map(local, mesh=mesh,
                           in_specs=(P(axis, None), P(axis)),
                           out_specs=(P(None), P(None, None), P(None, None))))
    vsh = NamedSharding(mesh, P(axis, None))
    ish = NamedSharding(mesh, P(axis))
    return fn, vsh, ish


def group_stats(X: np.ndarray, order: np.ndarray, offsets: np.ndarray, *,
                mesh=None, chunk_rows: Optional[int] = None
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(reps, boxes_lo, boxes_hi) for contiguous groups — the one
    finalization pass shared by every backend.

    In-memory default: a single vectorized ``reduceat`` sweep over
    ``X[order]``.  With ``chunk_rows`` set, the sorted relation is consumed
    chunk by chunk and only the (G, k) accumulators live on the host; with
    ``mesh`` also set, each chunk's count/sum pass runs sharded across the
    mesh's leading axis with psum reduction (reps reduced across shards) —
    the layer-0 path for relations whose sorted copy must never
    materialize host-side.
    """
    X = np.asarray(X)
    n, k = X.shape
    G = len(offsets) - 1
    counts = np.diff(offsets).astype(np.float64)
    if chunk_rows is None or n <= chunk_rows:
        Xo = X[order]
        sums = np.add.reduceat(Xo, offsets[:-1], axis=0) \
            if G else np.zeros((0, k))
        lo = np.minimum.reduceat(Xo, offsets[:-1], axis=0) \
            if G else np.zeros((0, k))
        hi = np.maximum.reduceat(Xo, offsets[:-1], axis=0) \
            if G else np.zeros((0, k))
        reps = sums / np.maximum(counts, 1.0)[:, None]
        return reps, lo, hi

    sums = np.zeros((G, k))
    lo = np.full((G, k), np.inf)
    hi = np.full((G, k), -np.inf)
    fn = None
    for a in range(0, n, chunk_rows):
        b = min(a + chunk_rows, n)
        chunk = X[order[a:b]]
        # contiguous layout -> chunk-local ids are sorted ascending
        ids = np.searchsorted(offsets, np.arange(a, b), side="right") - 1
        u0, u1 = int(ids[0]), int(ids[-1])
        if mesh is not None:
            if fn is None:
                fn, vsh, ish = _chunk_stats_jit(mesh, G, k)
            nd = int(mesh.shape[mesh.axis_names[0]])
            # pad every chunk to the same sharded shape: one compilation
            rows = ((chunk_rows + nd - 1) // nd) * nd
            cpad = np.pad(chunk, ((0, rows - len(chunk)), (0, 0)))
            ipad = np.pad(ids, (0, rows - len(ids)), constant_values=G)
            cnt_d, sum_d, _ = fn(jax.device_put(jnp.asarray(cpad), vsh),
                                 jax.device_put(jnp.asarray(ipad), ish))
            sums += np.asarray(sum_d)[:G]
        else:
            loc = ids - u0
            nloc = u1 - u0 + 1
            for j in range(k):
                sums[u0:u1 + 1, j] += np.bincount(loc, weights=chunk[:, j],
                                                  minlength=nloc)
        # boxes: reduceat over the chunk's group boundary positions
        bpos = np.concatenate([[0], np.flatnonzero(np.diff(ids)) + 1])
        np.minimum.at(lo, ids[bpos],
                      np.minimum.reduceat(chunk, bpos, axis=0))
        np.maximum.at(hi, ids[bpos],
                      np.maximum.reduceat(chunk, bpos, axis=0))
    reps = sums / np.maximum(counts, 1.0)[:, None]
    return reps, lo, hi


def finalize(X: np.ndarray, order: np.ndarray, offsets: np.ndarray,
             tree: SplitTree, *, mesh=None,
             chunk_rows: Optional[int] = None) -> Partition:
    """Assemble a Partition from the contiguous layout + split tree."""
    n = len(order)
    G = len(offsets) - 1
    gid = np.empty(n, np.int64)
    gid[order] = np.repeat(np.arange(G), np.diff(offsets))
    reps, lo, hi = group_stats(X, order, offsets, mesh=mesh,
                               chunk_rows=chunk_rows)
    return Partition(gid, order, offsets, reps, lo, hi, tree)
