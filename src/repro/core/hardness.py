"""Query-hardness benchmark — paper §4.1 (+ Tables 1 and 2).

Hardness h̃ := -log10 Π P(C_i); bounds are derived by inverting the CLT
normal CDF so every constraint satisfies P(C_i) = 10^(-h̃/m) for a random
package of the expected size E.  Verified to reproduce the paper's Table 1
bounds (e.g. Q1 SDSS h̃=1: b1=445.37, b2=420.68, b3=406.04, b4=417.76).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.paql import Constraint, PackageQuery

SQRT2 = math.sqrt(2.0)


def ndtri(p: float) -> float:
    """Inverse standard normal CDF (Acklam's rational approximation +
    one Halley refinement; |error| < 1e-12 — no scipy in-container)."""
    if not 0.0 < p < 1.0:
        raise ValueError(p)
    a = [-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00]
    b = [-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00]
    d = [7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00]
    plow, phigh = 0.02425, 1 - 0.02425
    if p < plow:
        ql = math.sqrt(-2 * math.log(p))
        x = (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql
             + c[5]) / ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    elif p <= phigh:
        ql = p - 0.5
        r = ql * ql
        x = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r
             + a[5]) * ql / (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r
                              + b[4]) * r + 1)
    else:
        ql = math.sqrt(-2 * math.log(1 - p))
        x = -(((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql
              + c[5]) / ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    # one step of Halley's method on Phi(x) - p
    e = 0.5 * math.erfc(-x / SQRT2) - p
    u = e * math.sqrt(2 * math.pi) * math.exp(x * x / 2)
    return x - u / (1 + x * u / 2)


@dataclasses.dataclass(frozen=True)
class BoundSpec:
    attr: str
    kind: str          # 'ge' | 'le' | 'between'


@dataclasses.dataclass(frozen=True)
class QueryTemplate:
    """A package-query template whose bounds are set by hardness level."""
    name: str
    objective_attr: str
    maximize: bool
    count_lo: int
    count_hi: int
    bounds: Tuple[BoundSpec, ...]
    repeat: int = 0

    @property
    def expected_size(self) -> float:
        return 0.5 * (self.count_lo + self.count_hi)


def instantiate(template: QueryTemplate, stats: Dict[str, Tuple[float, float]],
                hardness: float) -> PackageQuery:
    """Set constraint bounds for hardness h̃ per §4.1."""
    E = template.expected_size
    m = len(template.bounds)
    p = 10.0 ** (-hardness / m)
    cons: List[Constraint] = [
        Constraint(None, template.count_lo, template.count_hi)]
    for spec in template.bounds:
        mu, sigma = stats[spec.attr]
        se = math.sqrt(E) * sigma
        if spec.kind == "ge":
            b = E * mu + se * ndtri(1 - p)
            cons.append(Constraint(spec.attr, lo=b))
        elif spec.kind == "le":
            b = E * mu + se * ndtri(p)
            cons.append(Constraint(spec.attr, hi=b))
        elif spec.kind == "between":
            z = ndtri(0.5 * (1 + p))
            cons.append(Constraint(spec.attr, lo=E * mu - z * se,
                                   hi=E * mu + z * se))
        else:
            raise ValueError(spec.kind)
    return PackageQuery(template.objective_attr, template.maximize,
                        tuple(cons), repeat=template.repeat)


def column_stats(table: Dict[str, np.ndarray],
                 attrs: Sequence[str]) -> Dict[str, Tuple[float, float]]:
    return {a: (float(np.mean(table[a])), float(np.std(table[a])))
            for a in attrs}


# ------------------------------------------------- the paper's benchmark

Q1_SDSS = QueryTemplate(
    name="Q1_SDSS", objective_attr="tmass_prox", maximize=False,
    count_lo=15, count_hi=45,
    bounds=(BoundSpec("j", "ge"), BoundSpec("h", "le"),
            BoundSpec("k", "between")))

Q2_TPCH = QueryTemplate(
    name="Q2_TPCH", objective_attr="price", maximize=True,
    count_lo=15, count_hi=45,
    bounds=(BoundSpec("quantity", "ge"), BoundSpec("discount", "le"),
            BoundSpec("tax", "between")))

Q3_SDSS = QueryTemplate(
    name="Q3_SDSS", objective_attr="k", maximize=True,
    count_lo=25, count_hi=75,
    bounds=(BoundSpec("tmass_prox", "ge"), BoundSpec("j", "le"),
            BoundSpec("h", "between")))

Q4_TPCH = QueryTemplate(
    name="Q4_TPCH", objective_attr="tax", maximize=False,
    count_lo=50, count_hi=150,
    bounds=(BoundSpec("quantity", "le"), BoundSpec("price", "between")))

TEMPLATES = {t.name: t for t in (Q1_SDSS, Q2_TPCH, Q3_SDSS, Q4_TPCH)}
