"""Solve Guard — budgets, numerical health, and failure semantics.

The paper's headline robustness claim is that Progressive Shading
"gracefully handles tight constraints" where SketchRefine falsely reports
infeasibility (§1, Fig. 9).  This module makes *graceful* a contract the
whole pipeline shares instead of a property of the happy path:

* :class:`SolveBudget` — wall-clock deadline + pivot/node budgets carried
  through every LP twin (``core.lp``, ``core.lp_kernel``,
  ``core.distributed``), branch & bound (``core.ilp``), Dual Reducer and
  the shading cascade.  Budgets are charged by the solvers themselves, so
  one budget object bounds an entire ``engine.solve`` end to end: no LP,
  node loop or cascade layer can hang past the deadline.
* :class:`NumericalMonitor` — configuration + counters for the in-solver
  health checks: ``Binv`` residual-drift detection (forced
  refactorization when the rank-1-updated inverse drifts past
  ``drift_tol``) and pivot-stall streaks (degenerate ``theta == 0``
  pivots), which escalate to a Bland's-rule pivot mode until progress
  resumes so degenerate/tight instances terminate instead of cycling.
* :class:`SolveReport` — the structured answer sheet every
  ``engine.solve`` returns alongside the package: final status, budget
  spent, every degradation-ladder rung taken, numerical events and fault
  retries.  Silent ``ITER_LIMIT`` truncation is gone — a truncated or
  degraded solve says so.

Status contract (what the serving layer may rely on):

``OK``               — package returned and validated; produced by the
                       normal pipeline (warm retries / stall recovery /
                       drift refactorizations do NOT degrade quality).
``DEGRADED``         — a package is returned and satisfies the query's
                       constraints, but a quality-degrading rung fired
                       (budget-truncated search, LP-rounding fallback,
                       budget-skipped cascade layers): the objective may
                       be off-optimal.
``INFEASIBLE``       — the solver concluded no package exists, with the
                       full ladder exhausted and budget remaining on the
                       critical path; safe to surface as "no answer".
``BUDGET_EXHAUSTED`` — budgets ran out before any package was found;
                       the right reaction is retry with a larger budget,
                       not "infeasible".
``ERROR``            — an unexpected exception was contained by the
                       guard; no package.  Never raised to the caller.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

# ------------------------------------------------------------- statuses

OK = "ok"
DEGRADED = "degraded"
INFEASIBLE = "infeasible"
BUDGET_EXHAUSTED = "budget_exhausted"
ERROR = "error"

STATUSES = (OK, DEGRADED, INFEASIBLE, BUDGET_EXHAUSTED, ERROR)

# Numerical-health defaults, shared by the numpy twin (via
# NumericalMonitor defaults) and baked into the jitted JAX/Pallas twins.
DRIFT_TOL = 1e-6          # max |Binv @ B - I| before a forced refactorize
DRIFT_CHECK_EVERY = 16    # pivots between residual checks (numpy twin)
STALL_REFACTOR = 12       # degenerate-pivot streak -> force refactorize
STALL_BLAND = 24          # streak -> escalate to Bland's-rule pivoting
THETA_EPS = 1e-12         # |theta| below this = degenerate (no progress)


# --------------------------------------------------------------- budget


@dataclasses.dataclass
class SolveBudget:
    """Wall-clock + pivot + node budget for one end-to-end solve.

    All limits are optional (``None`` = unlimited).  The budget is
    *shared*: every LP call and B&B node loop charges the same object, so
    ``engine.solve`` passes one budget down the cascade and the total
    spend is bounded regardless of how many sub-solves fire.
    """
    deadline_s: Optional[float] = None
    max_pivots: Optional[int] = None
    max_nodes: Optional[int] = None
    pivots_spent: int = 0
    nodes_spent: int = 0
    _t0: Optional[float] = dataclasses.field(default=None, repr=False)

    def start(self) -> "SolveBudget":
        """Arm the wall clock (idempotent — first call wins)."""
        if self._t0 is None:
            self._t0 = time.monotonic()
        return self

    @property
    def elapsed_s(self) -> float:
        return 0.0 if self._t0 is None else time.monotonic() - self._t0

    def remaining_s(self) -> float:
        if self.deadline_s is None:
            return float("inf")
        self.start()
        return self.deadline_s - self.elapsed_s

    def remaining_pivots(self) -> float:
        if self.max_pivots is None:
            return float("inf")
        return self.max_pivots - self.pivots_spent

    def remaining_nodes(self) -> float:
        if self.max_nodes is None:
            return float("inf")
        return self.max_nodes - self.nodes_spent

    def charge_pivots(self, k: int) -> None:
        self.pivots_spent += int(k)

    def charge_nodes(self, k: int) -> None:
        self.nodes_spent += int(k)

    def out_of_time(self) -> bool:
        return self.remaining_s() <= 0.0

    def exhausted(self) -> bool:
        return (self.out_of_time() or self.remaining_pivots() <= 0
                or self.remaining_nodes() <= 0)

    def lp_iter_cap(self, default: int, *, floor: int = 32,
                    granularity: int = 256) -> int:
        """Per-LP ``max_iters`` from the remaining pivot budget.

        Rounded up to ``granularity`` so the jitted twins (whose
        ``max_iters`` is a static argument) see a handful of distinct
        caps instead of retracing per call; the numpy/distributed host
        loops additionally re-check the exact budget every few pivots.
        """
        rem = self.remaining_pivots()
        if not np.isfinite(rem):
            return default
        cap = max(int(rem), floor)
        cap = -(-cap // granularity) * granularity
        return min(default, cap)

    def clamp_ilp_kwargs(self, kw: Optional[dict]) -> dict:
        """Bound an ``ilp_kwargs`` dict by the remaining budget."""
        kw = dict(kw or {})
        if self.deadline_s is not None:
            rem = max(self.remaining_s(), 0.0)
            kw["time_limit_s"] = min(kw.get("time_limit_s", rem), rem)
        if self.max_nodes is not None:
            rem_n = max(int(self.remaining_nodes()), 0)
            kw["max_nodes"] = min(kw.get("max_nodes", rem_n), rem_n)
        return kw


# -------------------------------------------------------------- monitor


@dataclasses.dataclass
class NumericalMonitor:
    """Numerical-health configuration + counters for one solve.

    One monitor is shared across every LP call of an ``engine.solve`` so
    the report can say "3 drift refactorizations, 41 Bland pivots" for
    the whole query, not per-LP.
    """
    drift_tol: float = DRIFT_TOL
    drift_check_every: int = DRIFT_CHECK_EVERY
    stall_refactor: int = STALL_REFACTOR
    stall_bland: int = STALL_BLAND
    # counters (mutated by the solver twins)
    drift_refactors: int = 0
    stall_refactors: int = 0
    stall_events: int = 0
    bland_pivots: int = 0
    max_resid: float = 0.0

    def record_resid(self, resid: float) -> bool:
        """Track a Binv residual; returns True when it demands a
        refactorization."""
        self.max_resid = max(self.max_resid, float(resid))
        if resid > self.drift_tol:
            self.drift_refactors += 1
            return True
        return False

    @property
    def events(self) -> int:
        return (self.drift_refactors + self.stall_refactors
                + self.stall_events)


# --------------------------------------------------------------- report


@dataclasses.dataclass
class SolveReport:
    """Structured outcome of one guarded solve (see module docstring for
    the status contract)."""
    status: str = OK
    budget: Optional[SolveBudget] = None
    monitor: Optional[NumericalMonitor] = None
    notes: List[str] = dataclasses.field(default_factory=list)
    fallbacks: List[str] = dataclasses.field(default_factory=list)
    degraded: bool = False
    lp_calls: int = 0
    lp_pivots: int = 0
    lp_truncated: int = 0     # LPs that hit an iteration/pivot/time cap
    lp_batches: int = 0       # batched dispatches (core.lp_batch flights)
    ilp_nodes: int = 0
    fault_retries: int = 0
    wall_s: float = 0.0
    warm_rejected: int = 0    # cascade warm-basis re-maps that fell cold
    # cross-query cache accounting (engine cache= knob; repro.core.qcache)
    cache_hits: int = 0
    cache_misses: int = 0
    cache_pruned_lps: int = 0  # layer LPs skipped thanks to cached sets

    def note(self, msg: str) -> None:
        self.notes.append(str(msg))

    def rung(self, name: str, *, degrades: bool = False,
             detail: str = "") -> None:
        """Record a degradation-ladder rung.  ``degrades=True`` marks
        rungs that can cost solution quality (they flip the final status
        to DEGRADED even when a valid package comes back)."""
        self.fallbacks.append(name)
        self.degraded |= degrades
        self.note(f"fallback:{name}" + (f" ({detail})" if detail else ""))

    def absorb_lp(self, res) -> None:
        """Account one LPResult (any twin) into the report."""
        self.lp_calls += 1
        self.lp_pivots += int(getattr(res, "iters", 0))
        for n in getattr(res, "notes", ()) or ():
            self.note(n)
        # status codes: 0 OPTIMAL, 1 ITER_LIMIT, 2 INFEASIBLE, 3 BUDGET
        if getattr(res, "status", 0) in (1, 3):
            self.lp_truncated += 1

    def absorb_batch(self, results) -> None:
        """Account one ``solve_lp_batch`` flight (a sequence of
        LPResults solved as a single dispatch)."""
        self.lp_batches += 1
        for res in results:
            self.absorb_lp(res)

    def finalize(self, feasible: bool) -> "SolveReport":
        """Derive the final status from what happened (ERROR sticks)."""
        if self.budget is not None:
            self.wall_s = self.budget.elapsed_s
        if self.status == ERROR:
            return self
        if feasible:
            self.status = DEGRADED if self.degraded else OK
        elif self.budget is not None and self.budget.exhausted():
            self.status = BUDGET_EXHAUSTED
        else:
            self.status = INFEASIBLE
        return self

    def summary(self) -> str:
        b = self.budget
        spent = (f" pivots={b.pivots_spent} nodes={b.nodes_spent} "
                 f"wall={b.elapsed_s:.2f}s" if b is not None else "")
        fb = f" fallbacks={','.join(self.fallbacks)}" if self.fallbacks \
            else ""
        cache = (f" cache=hits:{self.cache_hits}/misses:{self.cache_misses}"
                 f" pruned_lps={self.cache_pruned_lps}"
                 if self.cache_hits or self.cache_misses else "")
        wr = f" warm_rejected={self.warm_rejected}" if self.warm_rejected \
            else ""
        return f"guard[{self.status}]{spent}{fb}{cache}{wr}"
