"""KD-TREE partitioning as used by SketchRefine (Brucato et al. [5]) —
the baseline DLV is compared against (paper §3.3, Mini-Exp 5, Fig. 7).

A cluster is split (on its widest-variance attribute, at the mean) while
(1) |P| > size threshold tau, or (2) radius > omega.  Produces the same
unified :class:`repro.core.partitioner.Partition` as every other backend:
the binary mean-splits are recorded into the flat split tree (each node has
one boundary, two children), so batch GetGroup and Progressive Shading's
machinery work identically over KD-tree partitions.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.partitioner import (Partition, SplitTree, finalize,
                                    register_backend)


def kdtree_partition(X: np.ndarray, *, tau: int, omega: float = np.inf,
                     max_groups: int = 1 << 20) -> Partition:
    X = np.asarray(X, np.float64)
    n, k = X.shape
    attrs: List[int] = []              # flat tree under construction
    mus: List[float] = []
    children: List[List[int]] = []
    root = -1
    # stack / finalized entries carry their (parent node, child slot)
    stack: List[Tuple[np.ndarray, int, int]] = [(np.arange(n), -1, -1)]
    final: List[Tuple[np.ndarray, int, int]] = []
    while stack and len(stack) + len(final) < max_groups:
        idx, pn, slot = stack.pop()
        sub = X[idx]
        radius = np.abs(sub - sub.mean(0)).max() if len(idx) else 0.0
        if len(idx) <= 1 or (len(idx) <= tau and radius <= omega):
            final.append((idx, pn, slot))
            continue
        j = int(np.argmax(sub.var(0)))
        mu = sub[:, j].mean()
        left = idx[sub[:, j] < mu]
        right = idx[sub[:, j] >= mu]
        if len(left) == 0 or len(right) == 0:
            final.append((idx, pn, slot))  # degenerate: all equal to mean side
            continue
        node_id = len(attrs)
        attrs.append(j)
        mus.append(mu)
        children.append([-1, -1])
        if pn >= 0:
            children[pn][slot] = node_id
        elif root == -1:
            root = node_id
        stack.append((left, node_id, 0))    # descent: t[j] < mu -> slot 0
        stack.append((right, node_id, 1))
    final.extend(stack)

    order = np.concatenate([f[0] for f in final]) if final \
        else np.zeros(0, np.int64)
    lens = np.fromiter((len(f[0]) for f in final), np.int64, len(final))
    offsets = np.concatenate([[0], np.cumsum(lens)])
    for g, (_, pn, slot) in enumerate(final):
        if pn >= 0:
            children[pn][slot] = ~g
    if root == -1:
        tree = SplitTree.single_leaf()
    else:
        N = len(attrs)
        tree = SplitTree(np.asarray(attrs, np.int32),
                         np.arange(N + 1, dtype=np.int64),
                         np.asarray(mus, np.float64),
                         np.asarray(children, np.int64).reshape(-1), root)
    return finalize(X, order, offsets, tree)


@register_backend("kdtree")
def _kdtree_backend(X, *, tau: int = None, d_f: int = None,
                    omega: float = np.inf, max_groups: int = 1 << 20,
                    rng=None, mesh=None,
                    chunk_rows: int = None) -> Partition:
    """Partitioner backend: ``tau`` defaults to ``d_f`` (target group size).
    ``rng`` is accepted for signature uniformity (the build is
    deterministic); sharded/chunked stats are not implemented here — asking
    for them raises instead of silently running fully in-memory."""
    if mesh is not None or chunk_rows is not None:
        raise TypeError("kdtree backend does not support mesh/chunk_rows "
                        "(sharded group stats); use backend='dlv' or "
                        "'bucketing'")
    if tau is None:
        tau = d_f if d_f is not None else 100
    return kdtree_partition(np.asarray(X), tau=tau, omega=omega,
                            max_groups=max_groups)


# Back-compat: old callers imported KDResult; a Partition is the same shape.
KDResult = Partition
