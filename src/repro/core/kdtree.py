"""KD-TREE partitioning as used by SketchRefine (Brucato et al. [5]) —
the baseline DLV is compared against (paper §3.3, Mini-Exp 5, Fig. 7).

A cluster is split (on its widest-variance attribute, at the mean) while
(1) |P| > size threshold tau, or (2) radius > omega.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class KDResult:
    gid: np.ndarray
    reps: np.ndarray
    num_groups: int


def kdtree_partition(X: np.ndarray, *, tau: int, omega: float = np.inf,
                     max_groups: int = 1 << 20) -> KDResult:
    X = np.asarray(X, np.float64)
    n, k = X.shape
    gid = np.zeros(n, np.int64)
    stack: List[np.ndarray] = [np.arange(n)]
    final: List[np.ndarray] = []
    while stack and len(stack) + len(final) < max_groups:
        idx = stack.pop()
        sub = X[idx]
        radius = np.abs(sub - sub.mean(0)).max() if len(idx) else 0.0
        if len(idx) <= 1 or (len(idx) <= tau and radius <= omega):
            final.append(idx)
            continue
        j = int(np.argmax(sub.var(0)))
        mu = sub[:, j].mean()
        left = idx[sub[:, j] < mu]
        right = idx[sub[:, j] >= mu]
        if len(left) == 0 or len(right) == 0:
            final.append(idx)     # degenerate: all values equal to mean side
            continue
        stack.append(left)
        stack.append(right)
    final.extend(stack)
    reps = np.empty((len(final), k))
    for g, idx in enumerate(final):
        gid[idx] = g
        reps[g] = X[idx].mean(0)
    return KDResult(gid, reps, len(final))
