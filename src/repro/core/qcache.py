"""Cross-query partition cache (PartitionCache-style, ROADMAP item).

Progressive Shading re-descends the same hierarchy and re-prices the same
groups for every query, yet real workloads are flights of overlapping
variants: the same query re-run, a bound tightened, a constraint widened.
This module caches the per-query artifacts that survive one
``engine.solve`` and lets the next query reuse them:

* **per-group candidate-id sets** — each layer's candidate set, stored
  split by its parent group id (``hier.layers[l].part.gid``), so a leaf-
  local ``Hierarchy.append`` invalidates exactly the touched groups (and
  their ancestors) instead of the whole entry;
* **group LP objective bounds** — the layer/Dual-Reducer LP objective at
  store time, consulted on reuse as a staleness check (a cached prune
  whose LP bound no longer reproduces is abandoned, never trusted);
* **final layer bases** — each layer LP's final basis/bound state and
  Dual Reducer's lp1 basis, so a reusing query warm-starts its cascade
  LPs (directly when the candidate columns match, via
  ``shading.map_warm_basis`` otherwise) instead of cold-starting.

Keying: ``(hierarchy fingerprint, canonical query signature)`` at the
entry level, ``(layer, group id)`` inside the entry — together the
``(fingerprint, group, signature)`` scheme of the ROADMAP.  Signatures
come from :meth:`repro.core.paql.PackageQuery.signature`: constraint
order is normalized away, and ``sig_a.contained_in(sig_b)`` is a sound
test that a's constraint region lies inside b's, which drives the
subsumption path: a query contained in a cached signature starts from
the cached layer-0 candidate set (the pre-prune) instead of descending
the full hierarchy.

Correctness contract (what a consumer may rely on):

* a cache hit can only *shortcut* the descent, never change the answer
  class: every reused package is re-validated against the relation
  (``check_package``) and every reused candidate set is re-solved by the
  ordinary guarded Dual Reducer, whose LP bound must reproduce the
  cached bound (exact hits) or respect containment monotonicity
  (subsumption hits).  Any mismatch — including an invalidated group,
  an evicted basis, or an infeasible pruned solve — falls back to the
  cold descent and records a ``cache_fallback`` rung in the
  ``SolveReport``; quality is never silently degraded.
* ``Hierarchy.append`` invalidates the touched leaves' group entries and
  their ancestors through the invalidation hook installed by
  :meth:`QCache.register`; an entry that lost any group is incomplete
  and never serves hits again (it is re-populated by the next cold
  solve).
* memory is bounded: entries are LRU-evicted by artifact bytes against
  ``max_bytes``, with eviction counts surfaced in :class:`CacheStats`.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from types import SimpleNamespace
from typing import Dict, Optional, Tuple

import numpy as np

from repro.runtime import racecheck

# Default artifact budget: candidate-id sets dominate; 64 MiB holds
# ~2000 distinct alpha=100k query entries' worth of int64 ids.
DEFAULT_MAX_BYTES = 64 << 20

_ENTRY_OVERHEAD = 256       # rough per-group dict/bookkeeping bytes


@dataclasses.dataclass
class CacheStats:
    """Counters for one :class:`QCache` (cumulative across queries)."""
    hits: int = 0
    exact_hits: int = 0
    contained_hits: int = 0
    misses: int = 0
    stale_misses: int = 0       # entry matched but had invalidated groups
    fallbacks: int = 0          # hits abandoned by validation -> cold path
    stores: int = 0
    evictions: int = 0
    invalidated_groups: int = 0
    bytes: int = 0

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CacheEntry:
    """Artifacts of one solved query over one hierarchy."""
    sig: object                     # paql.QuerySignature
    fingerprint: str
    # layer l (1..L) -> {parent gid at layer l -> candidate ids at l-1}
    cands: Dict[int, Dict[int, np.ndarray]]
    expected: Dict[int, int]        # layer -> group count at store time
    # layer l -> (S_used, basis, at_upper, obj_minform) of the layer-l LP
    layer_warms: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray, float]]
    dr_warm: Optional[Tuple[np.ndarray, np.ndarray]]   # lp1 basis/at_upper
    lp_bound: float                 # Dual Reducer lp1 bound (query sense)
    package_idx: Optional[np.ndarray] = None
    package_mult: Optional[np.ndarray] = None
    package_obj: float = 0.0
    complete: bool = True
    nbytes: int = 0

    def layer_complete(self, l: int) -> bool:
        return len(self.cands.get(l, {})) == self.expected.get(l, -1)

    def group_ids(self, l: int):
        """Sorted group ids still cached at layer ``l`` (test/debug API)."""
        return sorted(self.cands.get(l, {}).keys())

    def candidates(self, l: int) -> Optional[np.ndarray]:
        """The layer-(l-1) candidate set, reassembled from its per-group
        pieces — None once any of the layer's groups was invalidated."""
        if not self.layer_complete(l):
            return None
        parts = list(self.cands[l].values())
        if not parts:
            return np.zeros(0, np.int64)
        return np.sort(np.concatenate(parts))

    def dr_warm_start(self):
        from repro.core.lp import WarmStart
        if self.dr_warm is None:
            return None
        basis, at_upper = self.dr_warm
        return WarmStart(basis.copy(), at_upper.copy())

    def measure(self) -> int:
        total = 0
        for d in self.cands.values():
            for arr in d.values():
                total += arr.nbytes + _ENTRY_OVERHEAD
        for (S, basis, au, _obj) in self.layer_warms.values():
            total += S.nbytes + basis.nbytes + au.nbytes
        if self.dr_warm is not None:
            total += self.dr_warm[0].nbytes + self.dr_warm[1].nbytes
        if self.package_idx is not None:
            total += self.package_idx.nbytes + self.package_mult.nbytes
        return total + _ENTRY_OVERHEAD


@dataclasses.dataclass
class CacheHit:
    """One successful lookup: the entry plus how the signature matched."""
    entry: CacheEntry
    exact: bool

    @property
    def kind(self) -> str:
        return "exact" if self.exact else "contained"

    def warm_for_layer0(self, hier, query, S0: np.ndarray):
        """Warm start for Dual Reducer's lp1 over ``S0``.

        Prefers the cached lp1 final basis (identical columns on the
        shortcut path); falls back to re-mapping the cached layer-1
        basis down onto ``S0`` via :func:`shading.map_warm_basis` when
        the lp1 basis is gone (e.g. stored before an eviction trim).
        """
        ws = self.entry.dr_warm_start()
        if ws is not None:
            return ws
        state = self.entry.layer_warms.get(1)
        if state is None:
            return None
        from repro.core.shading import map_warm_basis
        S_used, basis, at_upper, _obj = state
        pseudo = SimpleNamespace(basis=basis, at_upper=at_upper,
                                 y=np.zeros(query.m))
        return map_warm_basis(hier, 1, S_used, pseudo, S0,
                              obj_attr=query.objective_attr)


class QCache:
    """Cross-query artifact cache over one or more hierarchies.

    One instance may serve many engines/hierarchies (the serving-layer
    shape): entries are keyed by hierarchy fingerprint, and
    :meth:`register` installs the append-invalidation hook per
    hierarchy.  ``reuse_packages=False`` disables the exact-hit package
    fast path (every hit then re-solves Dual Reducer over the cached
    candidate set — the pure artifact-reuse mode).

    Concurrency: every structure (entries, stats, registration set, the
    in-flight populate claims) is guarded by one reentrant instrumented
    lock, so concurrent sessions share the cache safely and the lock's
    contention/hold-time counters feed ``benchmarks/concurrency_bench``.
    Cold solves are NEVER run under the lock (the REPRO011 discipline —
    a descent is seconds-long); instead :meth:`begin_populate` claims a
    key with an in-flight event, the owner solves outside the lock and
    :meth:`store`s, and concurrent same-key sessions
    :meth:`wait_populate` then re-probe — the atomic get-or-populate
    protocol (:meth:`get_or_populate` packages it).
    """

    __guarded_by__ = {"_entries": "_lock", "stats": "_lock",
                      "_registered": "_lock", "_inflight": "_lock"}

    def __init__(self, max_bytes: int = DEFAULT_MAX_BYTES, *,
                 reuse_packages: bool = True,
                 gap_accept: float = 0.01):
        self.max_bytes = int(max_bytes)
        self.reuse_packages = bool(reuse_packages)
        # contained-hit quality gate: a pruned solve whose integrality
        # gap (ILP obj vs its own LP bound) exceeds this relative
        # threshold is abandoned for the cold descent — the prune lost
        # support the tightened query needed
        self.gap_accept = float(gap_accept)
        self.stats = CacheStats()
        self._entries: "OrderedDict[tuple, CacheEntry]" = OrderedDict()
        self._registered: set = set()
        self._lock = racecheck.InstrumentedRLock("qcache")
        self._inflight: Dict[tuple, threading.Event] = {}

    # ------------------------------------------------------------ admin
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def entries(self):
        """(fingerprint, signature, entry) triples (test/debug API)."""
        with self._lock:
            return [(fp, sig, e) for (fp, sig), e in self._entries.items()]

    def register(self, hier) -> str:
        """Bind a hierarchy: returns its fingerprint and installs the
        append-invalidation hook (idempotent per hierarchy object).

        The hook install happens under the cache lock; ``Hierarchy``
        keeps no lock of its own, so QCache._lock stays a leaf in the
        lock order (see docs/CONCURRENCY.md)."""
        with self._lock:
            if id(hier) not in self._registered:
                hier.add_invalidation_hook(self._on_append)
                self._registered.add(id(hier))
        return hier.fingerprint

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.stats.bytes = 0

    def stats_snapshot(self) -> CacheStats:
        """Atomic copy of the counters — never torn mid-update."""
        with self._lock:
            return dataclasses.replace(self.stats)

    def note_fallback(self) -> None:
        """A hit was abandoned by validation (cold path taken)."""
        with self._lock:
            self.stats.fallbacks += 1

    def lock_stats(self) -> dict:
        """Contention/hold-time counters of the cache lock."""
        return self._lock.stats()

    # ----------------------------------------------------------- lookup
    def lookup(self, fingerprint: str, sig) -> Optional[CacheHit]:
        """Exact-signature hit, else the tightest complete superset
        (subsumption): among cached signatures that contain ``sig``,
        the one with the fewest layer-0 candidates wins."""
        racecheck.checkpoint("qcache.lookup")
        with self._lock:
            return self._lookup_locked(fingerprint, sig)

    @racecheck.guarded_by("_lock")
    def _lookup_locked(self, fingerprint: str, sig) -> Optional[CacheHit]:
        key = (fingerprint, sig)
        entry = self._entries.get(key)
        if entry is not None:
            if entry.complete:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                self.stats.exact_hits += 1
                return CacheHit(entry, exact=True)
            self.stats.misses += 1
            self.stats.stale_misses += 1
            return None
        best = best_key = None
        for (fp, cached_sig), e in self._entries.items():
            if fp != fingerprint or not e.complete:
                continue
            if not sig.contained_in(cached_sig):
                continue
            size = sum(len(a) for a in e.cands.get(1, {}).values())
            if best is None or size < best[0]:
                best, best_key = (size, e), (fp, cached_sig)
        if best is None:
            self.stats.misses += 1
            return None
        self._entries.move_to_end(best_key)
        self.stats.hits += 1
        self.stats.contained_hits += 1
        return CacheHit(best[1], exact=False)

    # ------------------------------------------------- populate protocol
    def begin_populate(self, fingerprint: str, sig) -> bool:
        """Claim the cold solve for ``(fingerprint, sig)``.  True means
        the caller owns the populate and MUST call :meth:`end_populate`
        (a ``finally`` obligation); False means another session is
        already solving the same key — :meth:`wait_populate` for it."""
        key = (fingerprint, sig)
        with self._lock:
            if key in self._inflight:
                return False
            self._inflight[key] = threading.Event()
            return True

    def end_populate(self, fingerprint: str, sig) -> None:
        """Release the claim and wake waiters (store or not — a failed
        solve releases too, and waiters re-probe and miss)."""
        with self._lock:
            ev = self._inflight.pop((fingerprint, sig), None)
        if ev is not None:
            ev.set()

    def wait_populate(self, fingerprint: str, sig,
                      timeout: Optional[float] = None) -> bool:
        """Block until an in-flight populate of the key (if any)
        finishes; True unless the timeout expired first."""
        with self._lock:
            ev = self._inflight.get((fingerprint, sig))
        if ev is None:
            return True
        return racecheck.wait_event(ev, "qcache.wait_populate", timeout)

    def get_or_populate(self, fingerprint: str, sig, solve):
        """Atomic get-or-populate: returns ``("hit", CacheHit)`` or
        ``("solved", solve())``.  Exactly one caller runs ``solve()``
        per cold key; concurrent same-key callers wait and take the
        hit.  ``solve`` runs OUTSIDE the lock and is expected to
        :meth:`store` before returning (a non-storing solve is legal —
        waiters then re-probe, miss, and one of them solves next)."""
        key = (fingerprint, sig)
        while True:
            racecheck.checkpoint("qcache.get_or_populate")
            owner_ev = None
            with self._lock:
                hit = self._lookup_locked(fingerprint, sig)
                if hit is not None:
                    return "hit", hit
                ev = self._inflight.get(key)
                if ev is None:
                    owner_ev = self._inflight[key] = threading.Event()
            if owner_ev is not None:
                break
            racecheck.wait_event(ev, "qcache.wait_inflight")
        try:
            value = solve()
        finally:
            with self._lock:
                self._inflight.pop(key, None)
            owner_ev.set()
        return "solved", value

    # ------------------------------------------------------------ store
    def store(self, fingerprint: str, sig, *, hier,
              cands: Dict[int, np.ndarray],
              layer_warms: Dict[int, tuple],
              dr_warm, lp_bound: float,
              package: Optional[tuple] = None) -> CacheEntry:
        """Populate after a clean cold solve.

        ``cands[l]`` is the layer-(l-1) candidate set the cascade used
        (l = 1..L); it is split per parent group here so invalidation
        can be leaf-local.  ``layer_warms[l]`` is the layer-l LP state
        ``(S_used, basis, at_upper, obj)``; ``dr_warm`` the lp1
        basis/at_upper pair (or None); ``package`` the validated final
        ``(idx, mult, obj)``.

        The numpy grouping/copy work runs outside the lock; only the
        insert + eviction mutate shared state.
        """
        grouped: Dict[int, Dict[int, np.ndarray]] = {}
        expected: Dict[int, int] = {}
        for l, ids in cands.items():
            ids = np.asarray(ids, np.int64)
            gid = np.asarray(hier.layers[l].part.gid[ids], np.int64)
            order = np.argsort(gid, kind="stable")
            gs, starts = np.unique(gid[order], return_index=True)
            bounds = np.append(starts, len(ids))
            grouped[l] = {int(g): np.ascontiguousarray(
                ids[order[bounds[i]:bounds[i + 1]]])
                for i, g in enumerate(gs)}
            expected[l] = len(gs)
        warms = {int(l): (np.asarray(S, np.int64).copy(),
                          np.asarray(b, np.int64).copy(),
                          np.asarray(a, bool).copy(), float(o))
                 for l, (S, b, a, o) in layer_warms.items()}
        dw = None
        if dr_warm is not None:
            dw = (np.asarray(dr_warm.basis, np.int64).copy(),
                  np.asarray(dr_warm.at_upper, bool).copy()
                  if dr_warm.at_upper is not None
                  else np.zeros(0, bool))
        entry = CacheEntry(sig=sig, fingerprint=fingerprint, cands=grouped,
                           expected=expected, layer_warms=warms,
                           dr_warm=dw, lp_bound=float(lp_bound))
        if package is not None:
            idx, mult, obj = package
            entry.package_idx = np.asarray(idx, np.int64).copy()
            entry.package_mult = np.asarray(mult, np.float64).copy()
            entry.package_obj = float(obj)
        entry.nbytes = entry.measure()
        key = (fingerprint, sig)
        racecheck.checkpoint("qcache.store")
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self.stats.bytes -= old.nbytes
            self._entries[key] = entry
            self.stats.bytes += entry.nbytes
            self.stats.stores += 1
            self._evict(keep=key)
        return entry

    @racecheck.guarded_by("_lock")
    def _evict(self, keep: tuple) -> None:
        """LRU-evict by artifact bytes until under budget (the entry
        just stored survives even if alone over budget — a cache that
        cannot hold one entry would silently disable itself)."""
        while self.stats.bytes > self.max_bytes and len(self._entries) > 1:
            key = next(iter(self._entries))
            if key == keep:
                break
            entry = self._entries.pop(key)
            self.stats.bytes -= entry.nbytes
            self.stats.evictions += 1

    # ----------------------------------------------------- invalidation
    def _on_append(self, hier, touched_leaves: np.ndarray) -> None:
        """Hierarchy.append hook: drop the touched leaves' group entries
        and their ancestors at every layer, for every entry of this
        hierarchy.  Entries that lost any group stop serving hits."""
        fp = hier.fingerprint
        ancestors = hier.leaf_ancestors(touched_leaves)
        with self._lock:
            for (efp, _sig), entry in self._entries.items():
                if efp != fp:
                    continue
                for l, gids in ancestors.items():
                    d = entry.cands.get(l)
                    if not d:
                        continue
                    for g in gids:
                        arr = d.pop(int(g), None)
                        if arr is not None:
                            removed = arr.nbytes + _ENTRY_OVERHEAD
                            entry.nbytes -= removed
                            self.stats.bytes -= removed
                            self.stats.invalidated_groups += 1
                            entry.complete = False
