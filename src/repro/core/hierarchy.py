"""Hierarchy of relations (paper §2, Fig. 3).

Layer 0 = original tuples; layer l >= 1 = representative tuples (group
means) from partitioning layer l-1 with downscale factor d_f, built until
the top layer has at most ``alpha`` tuples: L = ceil(log_{d_f}(n / alpha)).

``layers[l].part`` (l >= 1) is the :class:`~repro.core.partitioner.Partition`
that partitioned layer l-1; its groups ARE the layer-l tuples, giving:
    get_tuples(l-1, g) = layers[l].part.members(g)
    get_group(l, t)    = layers[l].part.get_group(t)   (split-tree descent)
    get_group_batch(l, T)                              (vectorized descent)

The partitioning strategy is selected by name through the Partitioner
registry (``backend="dlv" | "kdtree" | "bucketing"``).

Out-of-core layer 0: the hierarchy accepts any
:class:`~repro.core.relation.Relation` (or a dict of arrays, which becomes
an :class:`~repro.core.relation.ArrayRelation`).  A streamed relation is
partitioned through the ``bucketing`` backend — the default for
out-of-core sources — consuming the relation chunk-by-chunk without ever
materialising the layer-0 attribute matrix; ``memory_rows`` bounds the
per-bucket resident set and ``mesh`` shards the streaming stats passes.
For in-memory tables ``chunk_rows`` (optionally with ``mesh``) still
routes layer-0 group stats through the chunked / mesh-sharded
accumulation, as before.

Appends (the Stochastic SketchRefine re-partitioning story): see
:meth:`Hierarchy.append` — new tuples descend to their layer-0 leaf via
the split tree, leaf counts/moments grow, and leaves whose total variance
crosses the build-time bar are reported for a local re-split (the re-split
itself is a ROADMAP item).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core import partitioner
from repro.core.partitioner import Partition
from repro.core.relation import Relation, as_relation

_EXACT_GAP_LIMIT = 2_000_000
_GAP_SAMPLE = 200_000


def _min_gap(X: np.ndarray, *, exact_limit: int = _EXACT_GAP_LIMIT,
             sample: int = _GAP_SAMPLE,
             rng: Optional[np.random.Generator] = None) -> float:
    """Smallest positive per-attribute gap (Alg 3, line 1).

    Exact for layers up to ``exact_limit`` rows (one sort per attribute —
    no ``np.unique`` duplicate pass).  Above that, a sorted random sample
    estimates the gap: sampling can only OVERestimate the true minimum,
    which keeps Neighbor Sampling's probes conservative (they step at least
    one true gap outside the box) instead of the old hard-coded 1e-9.
    """
    n = X.shape[0]
    if n > exact_limit:
        rng = rng or np.random.default_rng(0)
        X = X[rng.choice(n, size=sample, replace=False)]
    best = np.inf
    for j in range(X.shape[1]):
        v = np.sort(X[:, j])
        gaps = np.diff(v)
        pos = gaps[gaps > 0]
        if len(pos):
            best = min(best, float(pos.min()))
    return best if np.isfinite(best) else 1e-9


@dataclasses.dataclass
class Layer:
    table: Union[Relation, Dict[str, np.ndarray]]
    X: Optional[np.ndarray]          # (n_l, k) attr matrix; None = streamed
    part: Optional[Partition]        # partition of layer l-1 (None for layer 0)
    eps: float                       # min positive attr gap (Alg 3, line 1)

    @property
    def size(self) -> int:
        if self.X is not None:
            return self.X.shape[0]
        return self.table.num_rows


@dataclasses.dataclass
class AppendReport:
    """Result of one :meth:`Hierarchy.append` call."""
    gids: np.ndarray          # layer-0 leaf (group) id per appended tuple
    flagged: np.ndarray       # leaves whose total variance crossed the bar
    tv_bar: float             # the bar the leaves were compared against


class Hierarchy:
    def __init__(self, table, attrs: Sequence[str],
                 d_f: int = 100, alpha: int = 100_000,
                 rng: Optional[np.random.Generator] = None,
                 max_layers: int = 12, backend: str = "dlv",
                 layer0_backend: Optional[str] = None,
                 backend_kwargs: Optional[dict] = None,
                 mesh=None, chunk_rows: Optional[int] = None,
                 memory_rows: Optional[int] = None):
        self.attrs = list(attrs)
        self.d_f = d_f
        self.alpha = alpha
        self.backend = backend
        rng = rng or np.random.default_rng(0)
        rel = as_relation(table, columns=self.attrs)
        self.relation = rel
        if layer0_backend is None:
            # streamed relations default layer 0 to the one chunk-capable
            # backend; upper layers (rep arrays) keep ``backend``
            layer0_backend = "bucketing" \
                if (not rel.in_memory and backend == "dlv") else backend
        if not rel.in_memory and layer0_backend != "bucketing":
            raise TypeError(
                f"partitioner backend {layer0_backend!r} cannot consume a "
                "streamed relation (only 'bucketing' scans ChunkSources); "
                "pass an in-memory table or layer0_backend='bucketing'")
        self.layer0_backend = layer0_backend
        if rel.in_memory:
            # repro: allow[REPRO005] guarded by rel.in_memory: columns
            # are already resident; this is a view stack, not a load
            X0 = np.stack([np.asarray(rel[a], np.float64)
                           for a in self.attrs], axis=1)
            self.layers: List[Layer] = [
                Layer(rel, X0, None, _min_gap(X0, rng=rng))]
        else:
            # layer-0 eps is never consumed (Neighbor Sampling probes only
            # layers >= 1), so a streamed build skips the sample gather
            self.layers = [Layer(rel, None, None, 1e-9)]
        kw = dict(backend_kwargs or {})
        self._append_state: Optional[dict] = None
        self._fingerprint: Optional[str] = None
        self._invalidation_hooks: List[Callable] = []
        while self.layers[-1].size > alpha and len(self.layers) <= max_layers:
            if len(self.layers) == 1 and not rel.in_memory:
                # streamed layer 0: the bucketing backend consumes the
                # relation chunk-by-chunk (Appendix D.2) — the attribute
                # matrix never materialises
                layer_kw = dict(kw)
                if memory_rows is not None:
                    layer_kw.setdefault("memory_rows", memory_rows)
                if chunk_rows is not None:
                    layer_kw.setdefault("chunk_rows", chunk_rows)
                if mesh is not None:
                    layer_kw.setdefault("mesh", mesh)
                part = partitioner.fit(
                    rel.chunk_source(self.attrs, chunk_rows),
                    backend=layer0_backend, d_f=d_f, rng=rng, **layer_kw)
            else:
                Xl = self.layers[-1].X
                lb = layer0_backend if len(self.layers) == 1 else backend
                layer_kw = dict(kw)
                if len(self.layers) == 1 and chunk_rows is not None:
                    # layer 0 is the big one: chunked (optionally mesh-
                    # sharded) group-stats accumulation instead of a full
                    # sorted copy
                    layer_kw.update(chunk_rows=chunk_rows, mesh=mesh)
                if len(self.layers) == 1 and lb == "bucketing" and \
                        memory_rows is not None:
                    # same bucket layout as the streamed path -> in-memory
                    # and memmap builds of the same data stay bit-identical
                    layer_kw.setdefault("memory_rows", memory_rows)
                part = partitioner.fit(Xl, backend=lb, d_f=d_f,
                                       rng=rng, **layer_kw)
            if part.num_groups >= self.layers[-1].size:
                break  # no reduction possible
            reps = part.reps
            tbl = {a: reps[:, i] for i, a in enumerate(self.attrs)}
            self.layers.append(Layer(tbl, reps, part, _min_gap(reps)))

    @property
    def L(self) -> int:
        return len(self.layers) - 1

    @property
    def fingerprint(self) -> str:
        """Stable identity of this hierarchy's *structure* (cross-query
        cache key component).  Derived from the build parameters, layer
        shapes and per-layer group-count vectors — identical rebuilds of
        the same data share it; any structural difference breaks it.
        Appends do NOT change the fingerprint: they only grow leaf
        bookkeeping, and cache consistency across appends is handled by
        the invalidation hooks below (leaf-local, not wholesale)."""
        if self._fingerprint is None:
            h = hashlib.sha1()
            h.update(repr((self.relation.num_rows, tuple(self.attrs),
                           self.d_f, self.alpha, self.backend,
                           self.layer0_backend,
                           tuple(l.size for l in self.layers))).encode())
            for lyr in self.layers[1:]:
                h.update(np.ascontiguousarray(
                    lyr.part.counts, dtype=np.int64).tobytes())
            self._fingerprint = h.hexdigest()
        return self._fingerprint

    # ----------------------------------------------------- invalidation
    def add_invalidation_hook(self, cb: Callable) -> None:
        """Register ``cb(hier, touched_leaf_gids)`` to fire on every
        :meth:`append` with the layer-0 leaves the new rows landed in
        (cache layers subscribe here; see ``repro.core.qcache``)."""
        if cb not in self._invalidation_hooks:
            self._invalidation_hooks.append(cb)

    def leaf_ancestors(self, leaves) -> Dict[int, np.ndarray]:
        """Map layer -> group ids on the ancestor paths of the given
        layer-0 leaves: ``{1: leaves, 2: their layer-2 groups, ...}`` —
        the exact set of cached per-group artifacts an append to those
        leaves invalidates."""
        ids = np.unique(np.asarray(leaves, np.int64))
        out: Dict[int, np.ndarray] = {1: ids}
        for l in range(2, self.L + 1):
            ids = np.unique(np.asarray(self.layers[l].part.gid[ids],
                                       np.int64))
            out[l] = ids
        return out

    def get_tuples(self, l_minus_1: int, g: int) -> np.ndarray:
        """Member indices (at layer l-1) of group g (a layer-l tuple)."""
        return self.layers[l_minus_1 + 1].part.members(g)

    def get_tuples_batch(self, l_minus_1: int, gs: np.ndarray) -> np.ndarray:
        """Concatenated member indices of many groups (one gather)."""
        return self.layers[l_minus_1 + 1].part.members_batch(gs)

    def get_group(self, l: int, t: np.ndarray) -> int:
        return self.layers[l].part.get_group(t)

    def get_group_batch(self, l: int, T: np.ndarray, **kw) -> np.ndarray:
        """Vectorized split-tree descent for a whole batch of tuples."""
        return self.layers[l].part.get_group_batch(T, **kw)

    def group_box(self, l: int, g: int):
        part = self.layers[l].part
        return part.boxes_lo[g], part.boxes_hi[g]

    # --------------------------------------------------------- appends
    def _init_append_state(self) -> dict:
        """Per-leaf (count, sum, sumsq) of the layer-0 partition, computed
        once with a chunked bincount pass over the relation; the total-
        variance bar is the worst build-time leaf."""
        part = self.layers[1].part
        G = part.num_groups
        k = len(self.attrs)
        cnt = part.counts.astype(np.float64).copy()
        s1 = np.zeros((G, k))
        s2 = np.zeros((G, k))
        a = 0
        for block in self.relation.chunks(tuple(self.attrs)):
            ids = part.gid[a:a + len(block)]
            for j in range(k):
                s1[:, j] += np.bincount(ids, weights=block[:, j],
                                        minlength=G)
                s2[:, j] += np.bincount(ids, weights=block[:, j] ** 2,
                                        minlength=G)
            a += len(block)
        nz = np.maximum(cnt, 1.0)[:, None]
        var = np.maximum(s2 / nz - (s1 / nz) ** 2, 0.0)
        tv = cnt * var.max(axis=1)
        return {"cnt": cnt, "s1": s1, "s2": s2,
                "tv_bar": float(tv.max()) if G else 0.0}

    def append(self, rows, *, tv_bar: Optional[float] = None
               ) -> AppendReport:
        """Fast-path append toward Stochastic SketchRefine re-partitioning.

        ``rows`` (a dict of columns or an (r, k) array in ``attrs`` order)
        descend the layer-0 split tree in ONE ``get_group_batch``; each
        leaf's count / per-attribute moments grow incrementally, and the
        report lists every leaf whose total variance (|P| * max_j var_j)
        now exceeds ``tv_bar`` (default: the worst leaf at build time) —
        those are the candidates for a local re-split seeded as a
        ``dlv_rounds`` frontier (the re-split itself stays a ROADMAP
        item).  The base relation and split tree are NOT rewritten here.
        """
        if self.L < 1:
            raise ValueError("hierarchy has no partition layer to append "
                             "into")
        if isinstance(rows, dict):
            R = np.stack([np.asarray(rows[a], np.float64)
                          for a in self.attrs], axis=1)
        else:
            R = np.atleast_2d(np.asarray(rows, np.float64))
        if R.shape[1] != len(self.attrs):
            raise ValueError(f"appended rows have {R.shape[1]} attrs, "
                             f"hierarchy has {len(self.attrs)}")
        if self._append_state is None:
            self._append_state = self._init_append_state()
        st = self._append_state
        gids = np.asarray(self.layers[1].part.get_group_batch(R), np.int64)
        G = len(st["cnt"])
        st["cnt"] += np.bincount(gids, minlength=G)
        for j in range(R.shape[1]):
            st["s1"][:, j] += np.bincount(gids, weights=R[:, j],
                                          minlength=G)
            st["s2"][:, j] += np.bincount(gids, weights=R[:, j] ** 2,
                                          minlength=G)
        bar = st["tv_bar"] if tv_bar is None else float(tv_bar)
        nz = np.maximum(st["cnt"], 1.0)[:, None]
        var = np.maximum(st["s2"] / nz - (st["s1"] / nz) ** 2, 0.0)
        tv = st["cnt"] * var.max(axis=1)
        touched = np.unique(gids)
        for cb in self._invalidation_hooks:
            cb(self, touched)
        return AppendReport(gids, np.flatnonzero(tv > bar), bar)

    @property
    def leaf_counts(self) -> np.ndarray:
        """Layer-0 leaf sizes including appended tuples."""
        if self._append_state is not None:
            return self._append_state["cnt"].astype(np.int64)
        return self.layers[1].part.counts
