"""Hierarchy of relations (paper §2, Fig. 3).

Layer 0 = original tuples; layer l >= 1 = representative tuples (group
means) from partitioning layer l-1 with downscale factor d_f, built until
the top layer has at most ``alpha`` tuples: L = ceil(log_{d_f}(n / alpha)).

``layers[l].part`` (l >= 1) is the :class:`~repro.core.partitioner.Partition`
that partitioned layer l-1; its groups ARE the layer-l tuples, giving:
    get_tuples(l-1, g) = layers[l].part.members(g)
    get_group(l, t)    = layers[l].part.get_group(t)   (split-tree descent)
    get_group_batch(l, T)                              (vectorized descent)

The partitioning strategy is selected by name through the Partitioner
registry (``backend="dlv" | "kdtree" | "bucketing"``).  For huge layer-0
relations pass ``chunk_rows`` (and optionally a ``mesh``): group stats are
then accumulated chunk by chunk — sharded across the mesh with psum
reduction — so the layer-0 sorted copy never materializes host-side.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import partitioner
from repro.core.partitioner import Partition

_EXACT_GAP_LIMIT = 2_000_000
_GAP_SAMPLE = 200_000


def _min_gap(X: np.ndarray, *, exact_limit: int = _EXACT_GAP_LIMIT,
             sample: int = _GAP_SAMPLE,
             rng: Optional[np.random.Generator] = None) -> float:
    """Smallest positive per-attribute gap (Alg 3, line 1).

    Exact for layers up to ``exact_limit`` rows (one sort per attribute —
    no ``np.unique`` duplicate pass).  Above that, a sorted random sample
    estimates the gap: sampling can only OVERestimate the true minimum,
    which keeps Neighbor Sampling's probes conservative (they step at least
    one true gap outside the box) instead of the old hard-coded 1e-9.
    """
    n = X.shape[0]
    if n > exact_limit:
        rng = rng or np.random.default_rng(0)
        X = X[rng.choice(n, size=sample, replace=False)]
    best = np.inf
    for j in range(X.shape[1]):
        v = np.sort(X[:, j])
        gaps = np.diff(v)
        pos = gaps[gaps > 0]
        if len(pos):
            best = min(best, float(pos.min()))
    return best if np.isfinite(best) else 1e-9


@dataclasses.dataclass
class Layer:
    table: Dict[str, np.ndarray]
    X: np.ndarray                    # (n_l, k) attr matrix (column order = attrs)
    part: Optional[Partition]        # partition of layer l-1 (None for layer 0)
    eps: float                       # min positive attr gap (Alg 3, line 1)

    @property
    def size(self) -> int:
        return self.X.shape[0]


class Hierarchy:
    def __init__(self, table: Dict[str, np.ndarray], attrs: Sequence[str],
                 d_f: int = 100, alpha: int = 100_000,
                 rng: Optional[np.random.Generator] = None,
                 max_layers: int = 12, backend: str = "dlv",
                 backend_kwargs: Optional[dict] = None,
                 mesh=None, chunk_rows: Optional[int] = None):
        self.attrs = list(attrs)
        self.d_f = d_f
        self.alpha = alpha
        self.backend = backend
        rng = rng or np.random.default_rng(0)
        X0 = np.stack([np.asarray(table[a], np.float64) for a in self.attrs],
                      axis=1)
        self.layers: List[Layer] = [
            Layer({a: X0[:, i] for i, a in enumerate(self.attrs)}, X0, None,
                  _min_gap(X0, rng=rng))]
        kw = dict(backend_kwargs or {})
        while self.layers[-1].size > alpha and len(self.layers) <= max_layers:
            Xl = self.layers[-1].X
            layer_kw = dict(kw)
            if len(self.layers) == 1 and chunk_rows is not None:
                # layer 0 is the big one: chunked (optionally mesh-sharded)
                # group-stats accumulation instead of a full sorted copy
                layer_kw.update(chunk_rows=chunk_rows, mesh=mesh)
            part = partitioner.fit(Xl, backend=backend, d_f=d_f, rng=rng,
                                   **layer_kw)
            if part.num_groups >= Xl.shape[0]:
                break  # no reduction possible
            reps = part.reps
            tbl = {a: reps[:, i] for i, a in enumerate(self.attrs)}
            self.layers.append(Layer(tbl, reps, part, _min_gap(reps)))

    @property
    def L(self) -> int:
        return len(self.layers) - 1

    def get_tuples(self, l_minus_1: int, g: int) -> np.ndarray:
        """Member indices (at layer l-1) of group g (a layer-l tuple)."""
        return self.layers[l_minus_1 + 1].part.members(g)

    def get_tuples_batch(self, l_minus_1: int, gs: np.ndarray) -> np.ndarray:
        """Concatenated member indices of many groups (one gather)."""
        return self.layers[l_minus_1 + 1].part.members_batch(gs)

    def get_group(self, l: int, t: np.ndarray) -> int:
        return self.layers[l].part.get_group(t)

    def get_group_batch(self, l: int, T: np.ndarray, **kw) -> np.ndarray:
        """Vectorized split-tree descent for a whole batch of tuples."""
        return self.layers[l].part.get_group_batch(T, **kw)

    def group_box(self, l: int, g: int):
        part = self.layers[l].part
        return part.boxes_lo[g], part.boxes_hi[g]
