"""Hierarchy of relations (paper §2, Fig. 3).

Layer 0 = original tuples; layer l >= 1 = representative tuples (group
means) from DLV-partitioning layer l-1 with downscale factor d_f, built
until the top layer has at most ``alpha`` tuples:
L = ceil(log_{d_f}(n / alpha)).

``layers[l].part`` (l >= 1) is the DLVResult that partitioned layer l-1;
its groups ARE the layer-l tuples, giving:
    get_tuples(l-1, g) = layers[l].part.members(g)
    get_group(l, t)    = layers[l].part.get_group(t)   (split-tree descent)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.dlv import DLVResult, dlv


@dataclasses.dataclass
class Layer:
    table: Dict[str, np.ndarray]
    X: np.ndarray                    # (n_l, k) attr matrix (column order = attrs)
    part: Optional[DLVResult]        # partition of layer l-1 (None for layer 0)
    eps: float                       # min positive attr gap (Alg 3, line 1)

    @property
    def size(self) -> int:
        return self.X.shape[0]


def _min_gap(X: np.ndarray) -> float:
    best = np.inf
    for j in range(X.shape[1]):
        v = np.unique(X[:, j])
        if len(v) > 1:
            gaps = np.diff(v)
            pos = gaps[gaps > 0]
            if len(pos):
                best = min(best, float(pos.min()))
    return best if np.isfinite(best) else 1e-9


class Hierarchy:
    def __init__(self, table: Dict[str, np.ndarray], attrs: Sequence[str],
                 d_f: int = 100, alpha: int = 100_000,
                 rng: Optional[np.random.Generator] = None,
                 max_layers: int = 12):
        self.attrs = list(attrs)
        self.d_f = d_f
        self.alpha = alpha
        rng = rng or np.random.default_rng(0)
        X0 = np.stack([np.asarray(table[a], np.float64) for a in self.attrs],
                      axis=1)
        self.layers: List[Layer] = [
            Layer({a: X0[:, i] for i, a in enumerate(self.attrs)}, X0, None,
                  _min_gap(X0) if X0.shape[0] <= 2_000_000 else 1e-9)]
        while self.layers[-1].size > alpha and len(self.layers) <= max_layers:
            Xl = self.layers[-1].X
            part = dlv(Xl, d_f, rng=rng)
            if part.num_groups >= Xl.shape[0]:
                break  # no reduction possible
            reps = part.reps
            tbl = {a: reps[:, i] for i, a in enumerate(self.attrs)}
            self.layers.append(Layer(tbl, reps, part, _min_gap(reps)))

    @property
    def L(self) -> int:
        return len(self.layers) - 1

    def get_tuples(self, l_minus_1: int, g: int) -> np.ndarray:
        """Member indices (at layer l-1) of group g (a layer-l tuple)."""
        return self.layers[l_minus_1 + 1].part.members(g)

    def get_group(self, l: int, t: np.ndarray) -> int:
        return self.layers[l].part.get_group(t)

    def group_box(self, l: int, g: int):
        part = self.layers[l].part
        return part.boxes_lo[g], part.boxes_hi[g]
