"""Fleet coordinator: fault tolerance, straggler mitigation, elastic scale.

On a real multi-pod deployment each host runs a worker agent that
heartbeats this coordinator (which lives next to the job scheduler).  In
this container the coordinator is exercised against a virtual clock with
injected failures (tests/test_runtime.py), but the state machine is the
production one:

  * heartbeats + timeout -> worker FAILED -> job enters RESHAPE: pick the
    largest feasible mesh from the survivors (elastic data-parallel width:
    batch must divide), restore the latest checkpoint on the new mesh
    (CheckpointManager.restore with new shardings), resume;
  * per-step deadline = straggler_factor x trailing-median step time;
    stragglers get WARN then, if persistent, are treated as failed
    (backup-worker takeover) — mitigating slow-host tail latency;
  * checkpoint cadence adapts: halves after a failure (down to min_cadence)
    and decays back to nominal after ``stable_steps`` clean steps.
"""
from __future__ import annotations

import dataclasses
import enum
import statistics
from typing import Dict, List, Optional, Tuple


class WorkerState(enum.Enum):
    HEALTHY = "healthy"
    STRAGGLER = "straggler"
    FAILED = "failed"


class JobPhase(enum.Enum):
    RUNNING = "running"
    RESHAPING = "reshaping"
    RESTORING = "restoring"


@dataclasses.dataclass
class Worker:
    wid: int
    last_heartbeat: float = 0.0
    state: WorkerState = WorkerState.HEALTHY
    slow_strikes: int = 0


@dataclasses.dataclass
class Event:
    t: float
    kind: str
    detail: str


class Coordinator:
    def __init__(self, num_workers: int, *, heartbeat_timeout_s: float = 30.0,
                 straggler_factor: float = 2.0, straggler_strikes: int = 3,
                 ckpt_cadence_steps: int = 100, min_cadence: int = 10,
                 stable_steps: int = 500,
                 dp_candidates: Optional[List[int]] = None):
        self.workers: Dict[int, Worker] = {
            i: Worker(i) for i in range(num_workers)}
        self.timeout = heartbeat_timeout_s
        self.straggler_factor = straggler_factor
        self.straggler_strikes = straggler_strikes
        self.nominal_cadence = ckpt_cadence_steps
        self.cadence = ckpt_cadence_steps
        self.min_cadence = min_cadence
        self.stable_steps = stable_steps
        self.dp_candidates = sorted(dp_candidates or
                                    [2 ** i for i in range(11)], reverse=True)
        self.phase = JobPhase.RUNNING
        self.step_times: List[float] = []
        self.events: List[Event] = []
        self.clean_steps_since_failure = 0
        self.restores = 0

    # ---------------------------------------------------------- signals
    def heartbeat(self, wid: int, t: float):
        w = self.workers[wid]
        w.last_heartbeat = t
        if w.state == WorkerState.FAILED:
            # rejoining worker: admitted at the next reshape point
            self.events.append(Event(t, "rejoin", f"worker {wid}"))
            w.state = WorkerState.HEALTHY
            w.slow_strikes = 0

    def report_step(self, wid: int, t: float, step_time_s: float):
        self.step_times.append(step_time_s)
        if len(self.step_times) > 64:
            self.step_times.pop(0)
        w = self.workers[wid]
        med = statistics.median(self.step_times)
        if step_time_s > self.straggler_factor * med and len(
                self.step_times) >= 8:
            w.slow_strikes += 1
            if w.state == WorkerState.HEALTHY:
                w.state = WorkerState.STRAGGLER
                self.events.append(Event(t, "straggler", f"worker {wid}"))
            if w.slow_strikes >= self.straggler_strikes:
                self._fail(w, t, "persistent straggler -> backup takeover")
        else:
            w.slow_strikes = 0
            if w.state == WorkerState.STRAGGLER:
                w.state = WorkerState.HEALTHY
        self.clean_steps_since_failure += 1
        if self.clean_steps_since_failure >= self.stable_steps:
            self.cadence = self.nominal_cadence

    # --------------------------------------------------------- failures
    def _fail(self, w: Worker, t: float, why: str):
        if w.state != WorkerState.FAILED:
            w.state = WorkerState.FAILED
            self.events.append(Event(t, "failure", f"worker {w.wid}: {why}"))
            self.phase = JobPhase.RESHAPING
            self.clean_steps_since_failure = 0
            self.cadence = max(self.min_cadence, self.cadence // 2)

    def check_health(self, t: float):
        for w in self.workers.values():
            if (w.state != WorkerState.FAILED
                    and t - w.last_heartbeat > self.timeout):
                self._fail(w, t, "heartbeat timeout")

    # ----------------------------------------------------------- policy
    def healthy_workers(self) -> List[int]:
        return [w.wid for w in self.workers.values()
                if w.state != WorkerState.FAILED]

    def plan_mesh(self, global_batch: int) -> Tuple[int, List[int]]:
        """Elastic scale: the widest dp degree the survivors support such
        that the global batch still divides.  Returns (dp, member ids)."""
        alive = self.healthy_workers()
        for dp in self.dp_candidates:
            if dp <= len(alive) and global_batch % dp == 0:
                return dp, alive[:dp]
        return 1, alive[:1]

    def should_checkpoint(self, step: int) -> bool:
        return step % max(self.cadence, 1) == 0

    def resume_plan(self, global_batch: int):
        """After RESHAPING: the restore directive for the training driver."""
        dp, members = self.plan_mesh(global_batch)
        self.phase = JobPhase.RUNNING
        self.restores += 1
        self.events.append(Event(0.0, "reshape",
                                 f"dp={dp} members={members[:8]}..."))
        return {"dp": dp, "members": members,
                "restore_latest_checkpoint": True}
