from repro.runtime.coordinator import Coordinator, WorkerState
from repro.runtime import faults
from repro.runtime import racecheck

__all__ = ["Coordinator", "WorkerState", "faults", "racecheck"]
