from repro.runtime.coordinator import Coordinator, WorkerState
from repro.runtime import faults

__all__ = ["Coordinator", "WorkerState", "faults"]
