from repro.runtime.coordinator import Coordinator, WorkerState

__all__ = ["Coordinator", "WorkerState"]
