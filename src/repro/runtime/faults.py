"""Deterministic, seed-driven fault injection for the resilience bench.

Every fallback the Solve Guard promises (``core.guard``) is pinned by a
test that *forces* the failure it handles.  This module is the forcing
side: a process-global :class:`FaultInjector` that production code polls
at a handful of named sites, each a single cheap call that is a no-op
when no injector is active:

* ``relation.chunk_read`` / ``relation.gather`` — raise a transient
  ``OSError`` inside a Relation chunk/gather read (``core.relation``
  retries with capped exponential backoff);
* ``lp.binv``   — perturb the maintained basis inverse inside
  ``solve_lp_np`` (forcing the NumericalMonitor drift path);
* ``dist.shard`` — raise inside the ``solve_lp_dist`` pivot loop,
  standing in for a dead mesh shard (forcing the single-host fallback).

Determinism: firing depends only on the injector's seed and the per-site
opportunity counter (``after`` skips, ``times`` caps, ``prob`` draws from
the seeded rng), so a failing resilience test replays exactly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

# ------------------------------------------------------------ site names

CHUNK_READ = "relation.chunk_read"
GATHER_READ = "relation.gather"
BINV = "lp.binv"
SHARD = "dist.shard"


@dataclasses.dataclass
class FaultSpec:
    """When/how one site fires.

    ``after`` opportunities are skipped, then up to ``times`` fires (None
    = unlimited), each gated by ``prob`` (drawn from the injector's
    seeded rng).  ``scale`` is the magnitude for perturbation sites.
    """
    prob: float = 1.0
    times: Optional[int] = 1
    after: int = 0
    scale: float = 1e-3
    message: str = "injected fault"


class FaultInjector:
    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.specs: Dict[str, FaultSpec] = {}
        self.seen: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}
        self.log: List[Tuple[str, int]] = []
        self._lock = threading.Lock()

    def arm(self, site: str, **kw) -> "FaultInjector":
        self.specs[site] = FaultSpec(**kw)
        self.seen[site] = 0
        self.fired[site] = 0
        return self

    def fire_count(self, site: str) -> int:
        return self.fired.get(site, 0)

    def _should_fire(self, site: str) -> Optional[FaultSpec]:
        spec = self.specs.get(site)
        if spec is None:
            return None
        with self._lock:
            k = self.seen.get(site, 0)
            self.seen[site] = k + 1
            if k < spec.after:
                return None
            if spec.times is not None and \
                    self.fired.get(site, 0) >= spec.times:
                return None
            if spec.prob < 1.0 and self.rng.random() >= spec.prob:
                return None
            self.fired[site] = self.fired.get(site, 0) + 1
            self.log.append((site, k))
        return spec

    def maybe_raise(self, site: str, exc=OSError) -> None:
        spec = self._should_fire(site)
        if spec is not None:
            raise exc(f"{spec.message} [site={site} "
                      f"fire={self.fired[site]}]")

    def perturb(self, site: str, arr: np.ndarray) -> np.ndarray:
        """Deterministic additive perturbation (seeded rng, call-order
        reproducible) when the site is armed; identity otherwise."""
        spec = self._should_fire(site)
        if spec is None:
            return arr
        return arr + spec.scale * self.rng.standard_normal(arr.shape)


# -------------------------------------------------- process-global hooks

_ACTIVE: Optional[FaultInjector] = None


def get() -> Optional[FaultInjector]:
    return _ACTIVE


def activate(inj: Optional[FaultInjector]) -> Optional[FaultInjector]:
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, inj
    return prev


@contextlib.contextmanager
def injected(seed: int = 0,
             arms: Optional[Dict[str, dict]] = None
             ) -> Iterator[FaultInjector]:
    """``with faults.injected(seed=7, arms={faults.BINV: {...}}) as inj``
    — installs a fresh injector for the block, restoring the previous
    one (usually None) on exit."""
    inj = FaultInjector(seed)
    for site, kw in (arms or {}).items():
        inj.arm(site, **kw)
    prev = activate(inj)
    try:
        yield inj
    finally:
        activate(prev)


def maybe_raise(site: str, exc=OSError) -> None:
    """Production-side hook: no-op unless an injector is active."""
    if _ACTIVE is not None:
        _ACTIVE.maybe_raise(site, exc)


def perturb(site: str, arr: np.ndarray) -> np.ndarray:
    if _ACTIVE is None:
        return arr
    return _ACTIVE.perturb(site, arr)


def fire_count(site: str) -> int:
    return 0 if _ACTIVE is None else _ACTIVE.fire_count(site)


# ----------------------------------------------------------- test double


class FlakySource:
    """ChunkSource wrapper raising transient ``OSError`` on chosen chunk
    indices for their first ``fail_times`` read attempts — the
    deterministic stand-in for a flaky disk/network read.  Duck-types the
    ``core.bucketing.ChunkSource`` protocol so it wraps any source.
    """

    def __init__(self, inner, *, fail_chunks=(1,), fail_times: int = 2,
                 exc=OSError):
        self.inner = inner
        self.fail_chunks = set(int(i) for i in fail_chunks)
        self.fail_times = int(fail_times)
        self.exc = exc
        self.attempts: Dict[int, int] = {}
        self.raised = 0

    def chunks(self, chunk_rows: int):
        for i, chunk in enumerate(self.inner.chunks(chunk_rows)):
            if i in self.fail_chunks:
                k = self.attempts.get(i, 0)
                if k < self.fail_times:
                    self.attempts[i] = k + 1
                    self.raised += 1
                    raise self.exc(f"flaky chunk {i} (attempt {k + 1})")
            yield chunk

    @property
    def num_rows(self) -> int:
        return self.inner.num_rows

    @property
    def num_cols(self) -> int:
        return self.inner.num_cols
