"""Deterministic, seed-driven fault injection for the resilience bench.

Every fallback the Solve Guard promises (``core.guard``) is pinned by a
test that *forces* the failure it handles.  This module is the forcing
side: a process-global :class:`FaultInjector` that production code polls
at a handful of named sites, each a single cheap call that is a no-op
when no injector is active:

* ``relation.chunk_read`` / ``relation.gather`` — raise a transient
  ``OSError`` inside a Relation chunk/gather read (``core.relation``
  retries with capped exponential backoff);
* ``lp.binv``   — perturb the maintained basis inverse inside
  ``solve_lp_np`` (forcing the NumericalMonitor drift path);
* ``dist.shard`` — raise inside the ``solve_lp_dist`` pivot loop,
  standing in for a dead mesh shard (forcing the single-host fallback).

Determinism — now per *thread*: each thread that touches an injector is
lazily assigned a stream in registration order; stream 0 draws from
``SeedSequence(seed)`` (bit-identical to the historical single-thread
``default_rng(seed)`` behaviour) and stream ``k`` from
``SeedSequence(seed, spawn_key=(k-1,))``.  Opportunity counters
(``after`` skips, ``times`` caps) and probability draws are per-stream,
so concurrent sessions see independent, seed-reproducible fault
schedules instead of racing over one shared rng.  Aggregate counters
(``fire_count``, ``log``) are kept under the injector lock.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.runtime import racecheck

# ------------------------------------------------------------ site names

CHUNK_READ = "relation.chunk_read"
GATHER_READ = "relation.gather"
BINV = "lp.binv"
SHARD = "dist.shard"


@dataclasses.dataclass
class FaultSpec:
    """When/how one site fires.

    ``after`` opportunities are skipped, then up to ``times`` fires (None
    = unlimited), each gated by ``prob`` — all evaluated against the
    *calling thread's* stream, so each thread replays its own schedule.
    ``scale`` is the magnitude for perturbation sites.
    """
    prob: float = 1.0
    times: Optional[int] = 1
    after: int = 0
    scale: float = 1e-3
    message: str = "injected fault"


class _Stream:
    """Per-thread rng + opportunity counters (thread-confined: only the
    owning thread ever touches ``rng``/``seen``/``fired``)."""

    __slots__ = ("idx", "rng", "seen", "fired")

    def __init__(self, idx: int, seed: int):
        self.idx = idx
        if idx == 0:
            ss = np.random.SeedSequence(seed)
        else:
            ss = np.random.SeedSequence(seed, spawn_key=(idx - 1,))
        self.rng = np.random.default_rng(ss)
        self.seen: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}


class FaultInjector:

    __guarded_by__ = {"specs": "_lock", "seen": "_lock", "fired": "_lock",
                      "log": "_lock", "_streams": "_lock"}

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.specs: Dict[str, FaultSpec] = {}
        # Aggregate (all-thread) counters; per-thread schedules live on
        # the thread's _Stream.
        self.seen: Dict[str, int] = {}
        self.fired: Dict[str, int] = {}
        self.log: List[Tuple[str, int, int]] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._streams: List[_Stream] = []

    # ---------------------------------------------------------- streams

    def _stream(self) -> _Stream:
        st = getattr(self._tls, "stream", None)
        if st is None:
            with self._lock:
                st = _Stream(len(self._streams), self.seed)
                self._streams.append(st)
            self._tls.stream = st
        return st

    @property
    def rng(self) -> np.random.Generator:
        """The calling thread's generator (compat accessor)."""
        return self._stream().rng

    def thread_index(self) -> int:
        """Registration index of the calling thread's stream."""
        return self._stream().idx

    # ------------------------------------------------------------ set-up

    def arm(self, site: str, **kw) -> "FaultInjector":
        with self._lock:
            self.specs[site] = FaultSpec(**kw)
            self.seen[site] = 0
            self.fired[site] = 0
        return self

    def fire_count(self, site: str) -> int:
        """Total fires across all threads."""
        with self._lock:
            return self.fired.get(site, 0)

    def stream_fire_count(self, site: str) -> int:
        """Fires seen by the calling thread's own stream."""
        return self._stream().fired.get(site, 0)

    # ------------------------------------------------------------ firing

    def _should_fire(self, site: str) -> Optional[FaultSpec]:
        spec = self.specs.get(site)
        if spec is None:
            return None
        st = self._stream()
        racecheck.checkpoint(f"faults:{site}")
        # Schedule decisions are thread-confined (per-stream counters and
        # rng); only the aggregate tallies need the lock.
        k = st.seen.get(site, 0)
        st.seen[site] = k + 1
        with self._lock:
            self.seen[site] = self.seen.get(site, 0) + 1
        if k < spec.after:
            return None
        if spec.times is not None and st.fired.get(site, 0) >= spec.times:
            return None
        if spec.prob < 1.0 and st.rng.random() >= spec.prob:
            return None
        st.fired[site] = st.fired.get(site, 0) + 1
        with self._lock:
            self.fired[site] = self.fired.get(site, 0) + 1
            self.log.append((site, st.idx, k))
        return spec

    def maybe_raise(self, site: str, exc=OSError) -> None:
        spec = self._should_fire(site)
        if spec is not None:
            raise exc(f"{spec.message} [site={site} "
                      f"fire={self.fire_count(site)}]")

    def perturb(self, site: str, arr: np.ndarray) -> np.ndarray:
        """Deterministic additive perturbation (per-thread seeded rng,
        call-order reproducible) when the site is armed; identity
        otherwise."""
        spec = self._should_fire(site)
        if spec is None:
            return arr
        return arr + spec.scale * self._stream().rng.standard_normal(
            arr.shape)


# -------------------------------------------------- process-global hooks

# Registered with the static concurrency checker: rebinding the active
# injector must hold _ACTIVE_LOCK; thread-scoped activations live on
# _SCOPED and never race.
SHARED_MUTABLE = ("_ACTIVE",)

_ACTIVE: Optional[FaultInjector] = None
_ACTIVE_LOCK = threading.Lock()
_SCOPED = threading.local()      # .stack: per-thread activation stack


def get() -> Optional[FaultInjector]:
    """The effective injector for the calling thread: innermost
    thread-scoped activation first, then the process-global one."""
    stack = getattr(_SCOPED, "stack", None)
    if stack:
        return stack[-1]
    return _ACTIVE


def activate(inj: Optional[FaultInjector]) -> Optional[FaultInjector]:
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, inj
    return prev


@contextlib.contextmanager
def injected(seed: int = 0,
             arms: Optional[Dict[str, dict]] = None,
             scope: str = "process") -> Iterator[FaultInjector]:
    """``with faults.injected(seed=7, arms={faults.BINV: {...}}) as inj``
    — installs a fresh injector for the block, restoring the previous
    one on exit.  Reentrant: nested blocks stack and unwind correctly.
    ``scope="thread"`` confines the activation to the calling thread
    (other threads keep seeing the process-global injector, if any).
    """
    if scope not in ("process", "thread"):
        raise ValueError(f"scope must be 'process' or 'thread', "
                         f"got {scope!r}")
    inj = FaultInjector(seed)
    for site, kw in (arms or {}).items():
        inj.arm(site, **kw)
    if scope == "thread":
        stack = getattr(_SCOPED, "stack", None)
        if stack is None:
            stack = _SCOPED.stack = []
        stack.append(inj)
        try:
            yield inj
        finally:
            stack.pop()
    else:
        prev = activate(inj)
        try:
            yield inj
        finally:
            activate(prev)


def maybe_raise(site: str, exc=OSError) -> None:
    """Production-side hook: no-op unless an injector is active."""
    inj = get()
    if inj is not None:
        inj.maybe_raise(site, exc)


def perturb(site: str, arr: np.ndarray) -> np.ndarray:
    inj = get()
    if inj is None:
        return arr
    return inj.perturb(site, arr)


def fire_count(site: str) -> int:
    inj = get()
    return 0 if inj is None else inj.fire_count(site)


# ----------------------------------------------------------- test double


class FlakySource:
    """ChunkSource wrapper raising transient ``OSError`` on chosen chunk
    indices for their first ``fail_times`` read attempts — the
    deterministic stand-in for a flaky disk/network read.  Duck-types the
    ``core.bucketing.ChunkSource`` protocol so it wraps any source.
    """

    def __init__(self, inner, *, fail_chunks=(1,), fail_times: int = 2,
                 exc=OSError):
        self.inner = inner
        self.fail_chunks = set(int(i) for i in fail_chunks)
        self.fail_times = int(fail_times)
        self.exc = exc
        self.attempts: Dict[int, int] = {}
        self.raised = 0

    def chunks(self, chunk_rows: int):
        for i, chunk in enumerate(self.inner.chunks(chunk_rows)):
            if i in self.fail_chunks:
                k = self.attempts.get(i, 0)
                if k < self.fail_times:
                    self.attempts[i] = k + 1
                    self.raised += 1
                    raise self.exc(f"flaky chunk {i} (attempt {k + 1})")
            yield chunk

    @property
    def num_rows(self) -> int:
        return self.inner.num_rows

    @property
    def num_cols(self) -> int:
        return self.inner.num_cols
