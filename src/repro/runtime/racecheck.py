"""Deterministic race harness + instrumented locks — the dynamic twin of
the static concurrency checker (``repro.analysis.concurrency``).

The serving layer shares one resident ``Hierarchy`` + ``Relation`` +
``QCache`` across many concurrent PAQL sessions, so the shared-state
classes (``QCache``, ``BoundedStepCache``, the fault injector, the
scheduler) carry locks and a ``__guarded_by__`` contract.  A lock is easy
to *add* and hard to *trust*: a plain multi-threaded test only explores
whatever interleavings the OS scheduler happens to produce that day.
This module makes interleavings a controlled input:

* :func:`checkpoint` — registered shared-state touchpoints in production
  code (one module-global read when inactive; the same pattern as
  ``runtime.faults``).  ``QCache.lookup``/``store``,
  ``BoundedStepCache.get_or_create`` and the fault injector call it.
* :class:`InstrumentedLock` / :class:`InstrumentedRLock` — drop-in
  ``threading`` locks that (a) count acquisitions / contention and
  accumulate hold/wait time (surfaced by ``benchmarks/concurrency_bench``)
  and (b) cooperate with an active schedule controller, yielding instead
  of blocking so a forced schedule can never self-deadlock on a parked
  lock holder.
* :class:`ScheduleController` — runs N thread bodies with exactly ONE
  running at a time; at every checkpoint the controller decides, from a
  seed or an explicit schedule list, which thread runs next.  Given the
  same seed/schedule and code paths the interleaving replays exactly, so
  a race is a *reproducible test failure*: the known-bad interleaving on
  an unlocked cache double must fail, and the fixed class must pass
  every seeded schedule (see ``tests/test_concurrency.py``).
* :func:`guarded_by` — marker decorator declaring that a method must be
  called with the named lock held; consumed by the static checker
  (REPRO008) and by readers of the code.

Determinism argument: only one managed thread executes at a time, every
switch decision is drawn from the controller's seeded rng (or the pinned
schedule) under the controller mutex, and the sequence of checkpoint
calls is a pure function of the code paths taken — so the full
interleaving is a pure function of (seed, code).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

_TLS = threading.local()          # .slot = managed-thread index

# Active controller: rebinding is guarded; production reads are a single
# unlocked poll (exactly like runtime.faults._ACTIVE).
SHARED_MUTABLE = ("_CONTROLLER",)   # REPRO010 registry

_CONTROLLER: Optional["ScheduleController"] = None
_CONTROLLER_LOCK = threading.Lock()


def guarded_by(lock_name: str) -> Callable:
    """Declare that a function/method must run with ``lock_name`` held by
    the caller.  A no-op marker at runtime; the static checker
    (REPRO008) treats the body as lock-protected."""
    def deco(fn):
        fn.__guarded_by__ = str(lock_name)
        return fn
    return deco


def controller() -> Optional["ScheduleController"]:
    return _CONTROLLER


def install(ctl: Optional["ScheduleController"]
            ) -> Optional["ScheduleController"]:
    """Install (or clear) the active controller; returns the previous
    one so nesting restores correctly."""
    global _CONTROLLER
    with _CONTROLLER_LOCK:
        prev, _CONTROLLER = _CONTROLLER, ctl
    return prev


def checkpoint(site: str) -> None:
    """Shared-state touchpoint.  No-op unless a schedule controller is
    active AND the calling thread is managed by it."""
    ctl = _CONTROLLER
    if ctl is not None:
        ctl._checkpoint(site)


class Deadlock(RuntimeError):
    """A forced schedule cannot make progress (or ran away)."""


def wait_event(ev: threading.Event, site: str = "event.wait",
               timeout: Optional[float] = None) -> bool:
    """Controller-cooperative ``Event.wait``.

    Managed threads must never block the OS thread on an event another
    *parked* managed thread is responsible for setting — that would
    deadlock the forced schedule.  Under a controller the wait becomes a
    poll-and-yield loop (the setter gets scheduled eventually); without
    one it is a plain ``ev.wait(timeout)``."""
    ctl = _CONTROLLER
    if ctl is not None and ctl._managed():
        spins = 0
        while not ev.is_set():
            ctl._yield_blocked(site)
            spins += 1
            if spins > ctl.max_switches:
                raise Deadlock(f"{site}: event never set")
        return True
    return ev.wait(timeout)


# ------------------------------------------------------------------ locks


class InstrumentedLock:
    """``threading.Lock`` with contention/hold-time counters and
    controller cooperation.

    Counters (``stats()``): ``acquisitions``, ``contended`` (acquire
    found the lock held), ``wait_s`` (time spent blocked acquiring),
    ``hold_s`` (outermost-hold wall time).  The counters themselves are
    guarded by a private meter lock, so reads are never torn.

    Under an active :class:`ScheduleController`, a blocked acquire
    *yields to another managed thread* instead of blocking the OS
    thread — the lock holder is parked and must be scheduled to ever
    release, so cooperative yielding is what makes lock-based code
    explorable without deadlock.
    """

    _reentrant = False

    def __init__(self, name: str = "lock"):
        self.name = name
        self._inner = self._make_inner()
        self._meter = threading.Lock()
        self.acquisitions = 0
        self.contended = 0
        self.wait_s = 0.0
        self.hold_s = 0.0
        self._depth = 0            # guarded by holding the lock itself
        self._acquired_at = 0.0

    def _make_inner(self):
        return threading.Lock()

    def acquire(self) -> bool:
        ctl = _CONTROLLER
        if ctl is not None:
            ctl._checkpoint(f"lock:{self.name}")
        t0 = time.perf_counter()
        got = self._inner.acquire(blocking=False)
        contended = not got
        if not got:
            if ctl is not None and ctl._managed():
                spins = 0
                while not self._inner.acquire(blocking=False):
                    ctl._yield_blocked(f"lock:{self.name}")
                    spins += 1
                    if spins > ctl.max_switches:
                        raise Deadlock(f"lock:{self.name} never released")
            else:
                self._inner.acquire()
        wait = time.perf_counter() - t0
        with self._meter:
            self.acquisitions += 1
            if contended:
                self.contended += 1
            self.wait_s += wait
        if self._depth == 0:       # we own the lock: private fields safe
            self._acquired_at = time.perf_counter()
        self._depth += 1
        return True

    def release(self) -> None:
        self._depth -= 1
        held = time.perf_counter() - self._acquired_at \
            if self._depth == 0 else None
        self._inner.release()
        if held is not None:
            with self._meter:
                self.hold_s += held
        ctl = _CONTROLLER
        if ctl is not None:
            ctl._checkpoint(f"unlock:{self.name}")

    def __enter__(self) -> "InstrumentedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def stats(self) -> dict:
        with self._meter:
            return {"name": self.name, "acquisitions": self.acquisitions,
                    "contended": self.contended,
                    "wait_s": self.wait_s, "hold_s": self.hold_s}

    def reset_stats(self) -> None:
        with self._meter:
            self.acquisitions = 0
            self.contended = 0
            self.wait_s = 0.0
            self.hold_s = 0.0


class InstrumentedRLock(InstrumentedLock):
    """Reentrant variant (``threading.RLock`` semantics).  Re-acquiring
    while owning never contends and never yields to the controller."""

    _reentrant = True

    def _make_inner(self):
        return threading.RLock()

    def acquire(self) -> bool:
        # A reentrant re-acquire by the owner must not try-fail-yield:
        # the non-blocking probe succeeds for the owner, so the base
        # implementation is correct as long as depth tracking is ours.
        return super().acquire()


# ------------------------------------------------------- schedule control


class ScheduleController:
    """Deterministic cooperative scheduler for race tests.

    ``run(fns)`` starts one real thread per body but grants execution to
    exactly one at a time.  At every :func:`checkpoint` (and every
    instrumented lock edge) the controller picks the next thread to run:
    from ``schedule`` — a pinned list of thread indices consumed one
    decision at a time (the first entry picks the starting thread) — or
    from the seeded rng once the list (if any) is exhausted.  Unmanaged
    threads (e.g. the pytest main thread) pass checkpoints untouched.

    ``trace`` records every ``(site, chosen_thread)`` decision so a
    failing seed can be pinned as an explicit schedule.
    """

    def __init__(self, seed: int = 0,
                 schedule: Optional[Sequence[int]] = None,
                 max_switches: int = 100_000):
        self.rng = np.random.default_rng(seed)
        self.schedule: List[int] = [] if schedule is None \
            else [int(s) for s in schedule]
        self.max_switches = int(max_switches)
        self.switches = 0
        self.trace: List[tuple] = []
        self._mtx = threading.Lock()
        self._gates: List[threading.Event] = []
        self._done: List[bool] = []
        self._errors: List[Optional[BaseException]] = []
        self._results: List[object] = []

    # ------------------------------------------------------------ internal

    def _managed(self) -> bool:
        return getattr(_TLS, "slot", None) is not None

    @guarded_by("_mtx")
    def _alive(self) -> List[int]:
        return [i for i, d in enumerate(self._done) if not d]

    @guarded_by("_mtx")
    def _choose(self, runnable: List[int], site: str) -> int:
        self.switches += 1
        if self.switches > self.max_switches:
            raise Deadlock(f"runaway schedule at {site!r} "
                           f"({self.switches} switches)")
        if self.schedule:
            want = self.schedule.pop(0)
            choice = want if want in runnable else runnable[0]
        else:
            choice = int(runnable[int(self.rng.integers(len(runnable)))])
        self.trace.append((site, choice))
        return choice

    def _switch(self, site: str, candidates_of) -> None:
        """Common checkpoint body: pick who runs next; park if not us."""
        i = getattr(_TLS, "slot", None)
        if i is None:
            return
        with self._mtx:
            runnable = candidates_of(i)
            if not runnable:
                raise Deadlock(f"{site}: no runnable thread to yield to")
            j = self._choose(runnable, site)
            if j == i:
                return
            self._gates[j].set()
            self._gates[i].clear()
        self._gates[i].wait()

    def _checkpoint(self, site: str) -> None:
        self._switch(site, lambda i: self._alive())

    def _yield_blocked(self, site: str) -> None:
        """The calling thread CANNOT progress (lock held elsewhere):
        grant someone else unconditionally."""
        self._switch(site, lambda i: [t for t in self._alive() if t != i])

    # -------------------------------------------------------------- public

    def run(self, fns: Sequence[Callable[[], object]],
            timeout_s: float = 30.0) -> List[object]:
        """Run the bodies to completion under the schedule; returns their
        results in order.  Re-raises the first body exception; raises
        :class:`Deadlock` on timeout (a schedule that cannot finish)."""
        n = len(fns)
        self._gates = [threading.Event() for _ in range(n)]
        self._done = [False] * n
        self._errors = [None] * n
        self._results = [None] * n

        def _body(i: int, fn: Callable[[], object]) -> None:
            _TLS.slot = i
            self._gates[i].wait()
            try:
                self._results[i] = fn()
            # repro: allow[REPRO004] harness thread body: the error is
            # recorded and RE-RAISED by run() on the caller's thread
            except BaseException as e:      # surfaced to run()'s caller
                self._errors[i] = e
            finally:
                _TLS.slot = None
                with self._mtx:
                    self._done[i] = True
                    rest = self._alive()
                    if rest:
                        self._gates[self._choose(rest, "exit")].set()

        threads = [threading.Thread(target=_body, args=(i, fn),
                                    daemon=True, name=f"racecheck-{i}")
                   for i, fn in enumerate(fns)]
        prev = install(self)
        try:
            for t in threads:
                t.start()
            with self._mtx:
                self._gates[self._choose(list(range(n)), "start")].set()
            deadline = time.monotonic() + timeout_s
            for t in threads:
                t.join(max(0.0, deadline - time.monotonic()))
            if any(t.is_alive() for t in threads):
                raise Deadlock(
                    f"schedule did not complete in {timeout_s}s; "
                    f"trace tail: {self.trace[-8:]}")
        finally:
            install(prev)
        for e in self._errors:
            if e is not None:
                raise e
        return list(self._results)


def run_schedules(make_case: Callable[[], Sequence[Callable[[], object]]],
                  seeds: Sequence[int] = range(16),
                  timeout_s: float = 30.0) -> List["ScheduleController"]:
    """Sweep seeded schedules: for each seed, build a FRESH case (state +
    thread bodies) and run it under a fresh controller.  Returns the
    controllers (for trace/switch inspection); raises on the first seed
    whose schedule fails — the seed is in the exception message so the
    failure replays exactly."""
    out = []
    for seed in seeds:
        ctl = ScheduleController(seed=seed)
        try:
            ctl.run(make_case(), timeout_s=timeout_s)
        # repro: allow[REPRO004] harness loop: re-raised as an
        # AssertionError naming the failing seed (replayable)
        except BaseException as e:
            raise AssertionError(
                f"schedule seed={seed} failed: {type(e).__name__}: {e}"
            ) from e
        out.append(ctl)
    return out


def run_threads(fns: Sequence[Callable[[], object]],
                timeout_s: float = 60.0) -> List[object]:
    """Plain preemptive-concurrency helper (hammer tests): run bodies on
    real threads simultaneously, join, re-raise the first exception."""
    n = len(fns)
    results: List[object] = [None] * n
    errors: List[Optional[BaseException]] = [None] * n
    start = threading.Barrier(n)

    def _body(i: int, fn: Callable[[], object]) -> None:
        try:
            start.wait(timeout_s)
            results[i] = fn()
        # repro: allow[REPRO004] harness thread body: first error is
        # re-raised by run_threads() on the caller's thread
        except BaseException as e:
            errors[i] = e

    threads = [threading.Thread(target=_body, args=(i, fn), daemon=True)
               for i, fn in enumerate(fns)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout_s)
    if any(t.is_alive() for t in threads):
        raise Deadlock(f"threads did not finish in {timeout_s}s")
    for e in errors:
        if e is not None:
            raise e
    return results
