"""LP solver: KKT optimality certificates (hypothesis property tests),
numpy/JAX twin agreement, infeasibility detection."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # only the property tests need hypothesis; the deterministic tests
    # below must still run on a bare container
    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    class _St:
        @staticmethod
        def integers(*a, **k):
            return None

    st = _St()

from repro.core.lp import (INFEASIBLE, OPTIMAL, solve_lp, solve_lp_np,
                           verify_optimality)


def _random_lp(seed, one_sided=True):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 50))
    m = int(rng.integers(1, 6))
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = rng.integers(1, 4, size=n).astype(float)
    x0 = rng.uniform(0, 1, n) * ub
    act = A @ x0
    width = np.abs(rng.normal(size=m)) * 2
    bl = act - width * rng.uniform(0, 1, m)
    bu = act + width * rng.uniform(0, 1, m)
    if one_sided:
        for i in range(m):
            r = rng.random()
            if r < 0.2:
                bl[i] = -np.inf
            elif r < 0.3:
                bu[i] = np.inf
    return c, A, bl, bu, ub


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 10_000))
def test_lp_optimality_certificate(seed):
    """Every OPTIMAL answer carries an independently-verifiable KKT
    certificate (primal feasibility + dual feasibility + compl. slack)."""
    c, A, bl, bu, ub = _random_lp(seed)
    res = solve_lp_np(c, A, bl, bu, ub)
    if res.status == OPTIMAL:
        ok, msg = verify_optimality(res, c, A, bl, bu, ub)
        assert ok, msg


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_lp_twins_agree(seed):
    c, A, bl, bu, ub = _random_lp(seed)
    r1 = solve_lp_np(c, A, bl, bu, ub)
    r2 = solve_lp(c, A, bl, bu, ub)
    assert r1.status == r2.status
    if r1.status == OPTIMAL:
        assert abs(r1.obj - r2.obj) <= 1e-6 * (1 + abs(r1.obj))


def test_lp_detects_infeasible_box():
    # count >= 5 but every upper bound is 0
    c = np.ones(4)
    A = np.ones((1, 4))
    res = solve_lp_np(c, A, np.array([5.0]), np.array([np.inf]), np.zeros(4))
    assert res.status == INFEASIBLE


def test_lp_detects_infeasible_constraints():
    # sum x >= 10 with 3 vars of ub 1
    c = np.ones(3)
    A = np.ones((1, 3))
    res = solve_lp_np(c, A, np.array([10.0]), np.array([np.inf]), np.ones(3))
    assert res.status == INFEASIBLE


def test_lp_jit_twin_under_strict_numerics(strict_numerics):
    """The jitted twin's host boundary is fully explicit (jnp.asarray in,
    device_get out): it must solve correctly under a blanket implicit-
    transfer guard with the NaN debugger armed."""
    c, A, bl, bu, ub = _random_lp(7)
    r1 = solve_lp_np(c, A, bl, bu, ub)
    r2 = solve_lp(c, A, bl, bu, ub)
    assert r1.status == r2.status
    if r1.status == OPTIMAL:
        assert abs(r1.obj - r2.obj) <= 1e-6 * (1 + abs(r1.obj))


def test_lp_known_optimum():
    # max x0 + 2 x1 s.t. x0 + x1 <= 1.5, 0<=x<=1  -> x=(0.5,1), obj 2.5
    c = np.array([-1.0, -2.0])
    A = np.array([[1.0, 1.0]])
    res = solve_lp_np(c, A, np.array([-np.inf]), np.array([1.5]),
                      np.ones(2))
    assert res.status == OPTIMAL
    assert res.obj == pytest.approx(-2.5, abs=1e-9)
    assert res.x == pytest.approx([0.5, 1.0], abs=1e-9)


def test_degenerate_lp_terminates_under_stall_monitor():
    """A fully-degenerate feasibility LP (zero objective: every dual
    pivot has theta == 0) cycles under the plain BFRT pivot rule; the
    stall monitor escalates to Bland's rule and both twins terminate on
    the same answer instead of spinning to the iteration cap."""
    from repro.core.guard import NumericalMonitor
    rng = np.random.default_rng(1)
    m, n = 40, 80
    A = rng.integers(-1, 2, size=(m, n)).astype(float)
    b = A @ rng.uniform(0.2, 0.8, n)     # feasible equality RHS
    c = np.zeros(n)
    mon = NumericalMonitor()
    r1 = solve_lp_np(c, A, b, b, np.ones(n), monitor=mon, max_iters=8000)
    r2 = solve_lp(c, A, b, b, np.ones(n), max_iters=8000)
    assert r1.status == OPTIMAL
    assert r2.status == OPTIMAL
    assert r1.iters < 8000 and r2.iters < 8000
    assert mon.stall_events > 0 and mon.bland_pivots > 0
    assert abs(r1.obj - r2.obj) <= 1e-9
    assert any(note.startswith("stall:") for note in r1.notes)


@pytest.mark.parametrize("seed", [0, 17, 123, 4096, 9999])
def test_lp_batch_agrees_with_sequential(seed):
    """Property check of the batched engine against the numpy twin: a
    random flight of bound-variants of one random LP must agree lane by
    lane on status and objective (the batched pivot loop is the single
    twin's pivot step vmapped — padding and masking are inert)."""
    from repro.core.lp_batch import solve_lp_batch
    rng = np.random.default_rng(seed)
    c, A, bl, bu, ub = _random_lp(seed)
    K = int(rng.integers(2, 5))
    ubs = [ub * rng.uniform(0.3, 1.0, len(ub)) for _ in range(K)]
    ress = solve_lp_batch(c, A, bl, bu, ubs, backend="jax")
    for k in range(K):
        ref = solve_lp_np(c, A, bl, bu, ubs[k])
        assert ress[k].status == ref.status
        if ref.status == OPTIMAL:
            assert abs(ress[k].obj - ref.obj) <= 1e-7 * (1 + abs(ref.obj))


def test_lp_bfrt_long_step_count():
    """Package-structured LP solves in few iterations (BFRT long steps)."""
    rng = np.random.default_rng(1)
    n = 20_000
    c = rng.normal(size=n)
    A = np.stack([np.ones(n), rng.normal(14, 1.5, n)])
    bl = np.array([15.0, 430.0])
    bu = np.array([45.0, 450.0])
    res = solve_lp_np(c, A, bl, bu, np.ones(n))
    assert res.status == OPTIMAL
    assert res.iters < 100, res.iters
    # support size <= m + ||x||_1 (paper §2.4)
    support = int(np.sum(res.x > 1e-9))
    assert support <= int(np.ceil(2 + res.x.sum())) + 1
