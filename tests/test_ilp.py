"""ILP branch & bound + heuristics vs exhaustive enumeration."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.ilp import (ILP_OPTIMAL, brute_force_ilp, solve_ilp,
                            _swap_search)


def _random_ilp(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 9))
    m = int(rng.integers(1, 4))
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = rng.integers(1, 3, size=n).astype(float)
    x0 = rng.integers(0, 2, n).astype(float)
    act = A @ x0
    bl = act - np.abs(rng.normal(size=m))
    bu = act + np.abs(rng.normal(size=m))
    return c, A, bl, bu, ub


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_ilp_matches_brute_force(seed):
    c, A, bl, bu, ub = _random_ilp(seed)
    r1 = solve_ilp(c, A, bl, bu, ub)
    r2 = brute_force_ilp(c, A, bl, bu, ub)
    assert r1.feasible == r2.feasible
    if r1.feasible and r1.status == ILP_OPTIMAL:
        assert abs(r1.obj - r2.obj) < 1e-6


def test_ilp_infeasible():
    c = np.ones(4)
    A = np.ones((1, 4))
    r = solve_ilp(c, A, np.array([10.0]), np.array([np.inf]), np.ones(4))
    assert not r.feasible


def test_ilp_solution_is_integral_and_feasible():
    rng = np.random.default_rng(5)
    n = 200
    c = rng.normal(size=n)
    A = np.stack([np.ones(n), rng.normal(10, 2, n)])
    bl = np.array([10.0, 95.0])
    bu = np.array([20.0, 160.0])
    r = solve_ilp(c, A, bl, bu, np.ones(n))
    assert r.feasible
    assert np.all(np.abs(r.x - np.round(r.x)) < 1e-9)
    act = A @ r.x
    assert np.all(act >= bl - 1e-6) and np.all(act <= bu + 1e-6)


def test_swap_search_repairs_tight_window():
    """The tight-BETWEEN regime that defeats naive rounding."""
    rng = np.random.default_rng(11)
    n = 1500
    vals = rng.normal(14, 1.2, n)
    c = np.abs(rng.normal(1, 0.5, n))
    A = np.stack([np.ones(n), vals])
    target = 30 * 14.0
    bl = np.array([15.0, target - 0.5])
    bu = np.array([45.0, target + 0.5])     # width-1 window on a sum of ~30
    from repro.core.lp import solve_lp_np
    root = solve_lp_np(c, A, bl, bu, np.ones(n))
    assert root.status == 0
    x, obj = _swap_search(root.x, c, A, bl, bu, np.zeros(n), np.ones(n), 1e-6)
    assert x is not None
    act = A @ x
    assert np.all(act >= bl - 1e-6) and np.all(act <= bu + 1e-6)
