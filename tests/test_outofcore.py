"""Out-of-core pipeline: end-to-end solve parity between a memmap-backed
and a dict-backed relation, candidate-resident accounting, streamed
hierarchy construction, and the append fast path."""
import numpy as np
import pytest

from repro.core import relation as relation_mod
from repro.core.engine import PackageQueryEngine
from repro.core.hierarchy import Hierarchy
from repro.core.paql import Constraint, PackageQuery
from repro.core.relation import MemmapRelation

N = 24_000
ATTRS = ["v", "w"]
ILP_KW = dict(max_nodes=100, time_limit_s=10)


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(7)
    return {"v": rng.normal(10, 2, N), "w": rng.uniform(0.5, 2.0, N)}


@pytest.fixture(scope="module")
def rel(tmp_path_factory, table):
    path = str(tmp_path_factory.mktemp("ooc") / "rel.npy")
    np.save(path, np.stack([table[a] for a in ATTRS], axis=1))
    return MemmapRelation.from_npy(path, ATTRS, chunk_rows=4000)


@pytest.fixture(scope="module")
def query():
    return PackageQuery("v", maximize=True,
                        constraints=(Constraint(None, 5, 15),
                                     Constraint("w", hi=20.0)))


def _engine(source, **kw):
    return PackageQueryEngine(source, ATTRS, d_f=20, alpha=1500, seed=0,
                              memory_rows=6000, chunk_rows=3000, **kw)


def test_streamed_relation_rejects_array_only_backend(rel):
    with pytest.raises(TypeError, match="cannot consume a streamed"):
        Hierarchy(rel, ATTRS, d_f=20, alpha=1500, backend="kdtree")


def test_streamed_hierarchy_never_materialises_layer0(rel):
    hier = Hierarchy(rel, ATTRS, d_f=20, alpha=1500,
                     memory_rows=6000, chunk_rows=3000)
    assert hier.layers[0].X is None           # streamed layer 0
    assert hier.layers[0].size == N
    assert hier.L >= 1
    assert hier.layers[1].size < N
    # split-tree descent agrees with the stored gids on random probes
    rng = np.random.default_rng(1)
    idx = rng.choice(N, 200, replace=False)
    T = rel.gather_matrix(np.sort(idx), ATTRS)
    got = hier.get_group_batch(1, T)
    np.testing.assert_array_equal(got, hier.layers[1].part.gid[np.sort(idx)])


def test_solve_parity_memmap_vs_dict(table, rel, query):
    """Same data, same per-layer backends (bucketing at layer 0, dlv
    above), same seeds: the memmap-backed and dict-backed engines return
    the SAME package."""
    e_mem = _engine(table, layer0_backend="bucketing")
    e_ooc = _engine(rel)           # bucketing is the out-of-core default
    r_mem = e_mem.solve(query, ilp_kwargs=ILP_KW)
    r_ooc = e_ooc.solve(query, ilp_kwargs=ILP_KW)
    assert r_mem.feasible and r_ooc.feasible
    assert r_ooc.obj == pytest.approx(r_mem.obj, rel=1e-12)
    np.testing.assert_array_equal(r_mem.idx, r_ooc.idx)
    np.testing.assert_array_equal(r_mem.mult, r_ooc.mult)
    assert query.check_package(rel, r_ooc.idx, r_ooc.mult)


def test_solve_stays_candidate_resident(rel, query):
    eng = _engine(rel)
    eng.partition()        # build: chunk/bucket-resident + O(gap sample)
    relation_mod.reset_peak_resident()
    res = eng.solve(query, ilp_kwargs=ILP_KW)
    assert res.feasible
    peak = relation_mod.peak_resident_rows()
    # the solve gathers candidate subsets only: O(alpha), never the relation
    assert peak <= 2 * eng.alpha
    assert peak < N // 2


def test_solve_direct_streams_with_guard(table, rel, query, monkeypatch):
    r_ooc = _engine(rel).solve_direct(query, ilp_kwargs=ILP_KW)
    r_mem = _engine(table).solve_direct(query, ilp_kwargs=ILP_KW)
    assert r_ooc.feasible and r_mem.feasible
    assert r_ooc.obj == pytest.approx(r_mem.obj)
    from repro.core import paql
    monkeypatch.setattr(paql, "FULL_MATRIX_BUDGET_BYTES", 1024)
    with pytest.raises(ValueError, match="engine.solve"):
        _engine(rel).solve_direct(query)


def test_sketchrefine_over_memmap(table, rel, query):
    res = _engine(rel).solve_sketchrefine(query, ilp_kwargs=ILP_KW)
    if res.feasible:                       # SR may legitimately fail
        assert query.check_package(rel, res.idx, res.mult)


# ------------------------------------------------------------- appends


def test_append_lands_in_rebuild_groups(table):
    """Appended copies of existing tuples land in exactly the group a full
    (deterministic) rebuild assigns those tuples."""
    hier = Hierarchy(table, ATTRS, d_f=20, alpha=1500)
    rebuild = Hierarchy(table, ATTRS, d_f=20, alpha=1500)
    X = np.stack([table[a] for a in ATTRS], axis=1)
    idx = np.random.default_rng(3).choice(N, 300, replace=False)
    rep = hier.append(X[idx])
    np.testing.assert_array_equal(rep.gids,
                                  rebuild.layers[1].part.gid[idx])
    assert hier.leaf_counts.sum() == N + 300
    base = rebuild.layers[1].part.counts
    grown = hier.leaf_counts - base
    np.testing.assert_array_equal(
        grown, np.bincount(rep.gids, minlength=len(base)))


def test_append_flags_variance_crossing_leaves(table):
    hier = Hierarchy(table, ATTRS, d_f=20, alpha=1500)
    X = np.stack([table[a] for a in ATTRS], axis=1)
    # a wide blob centered on one tuple blows up its leaf's variance
    rng = np.random.default_rng(4)
    blob = X[100] + rng.normal(0, 8.0, (4000, 2))
    rep = hier.append(blob)
    assert len(rep.flagged) > 0
    assert rep.tv_bar > 0
    # flagged leaves really did cross the bar
    st = hier._append_state
    nz = np.maximum(st["cnt"], 1.0)[:, None]
    var = np.maximum(st["s2"] / nz - (st["s1"] / nz) ** 2, 0.0)
    tv = st["cnt"] * var.max(axis=1)
    assert np.all(tv[rep.flagged] > rep.tv_bar)


def test_append_over_streamed_relation(rel):
    hier = Hierarchy(rel, ATTRS, d_f=20, alpha=1500,
                     memory_rows=6000, chunk_rows=3000)
    rows = rel.gather_matrix(np.arange(50), ATTRS)
    rep = hier.append(rows)             # moments init streams the relation
    np.testing.assert_array_equal(rep.gids,
                                  hier.layers[1].part.gid[:50])
    assert hier.leaf_counts.sum() == N + 50
