"""Distributed pricing backend: step-level equivalence vs the sequential
BFRT reference, full-solve parity vs solve_lp_np (cold and warm) on real
1x2 / 2x2 host meshes, and the dtype-derived reduction sentinel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import (big_sentinel, make_pq_step,
                                    solve_lp_dist)
from repro.core.lp import (OPTIMAL, row_scaling, solve_lp, solve_lp_np,
                           verify_optimality)
from repro.kernels.ref import bfrt_sequential_ref


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _random_state(seed, m=4, n=4096):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n))
    c = rng.normal(size=n)
    lo = np.zeros(n)
    hi = rng.uniform(1, 3, n)
    state = rng.integers(0, 3, n).astype(np.int32)
    rho = rng.normal(size=m)
    y = rng.normal(size=m)
    d = c - y @ A                       # "maintained" reduced costs
    return A, d, lo, hi, state, rho


def test_pq_step_matches_sequential_bfrt(mesh, strict_numerics):
    """The step consumes MAINTAINED reduced costs and — via the exact
    in-crossing-bucket walk — selects the same entering breakpoint as the
    sequential BFRT."""
    m, n = 4, 4096
    A, d, lo, hi, state, rho = _random_state(0, m, n)
    s, budget = 1.0, 25.0
    step, col_spec, vec_spec = make_pq_step(mesh, m, n, num_buckets=256)
    (alpha_d, flips_d, r_best, q, d_q, at_up_q, Acol, fvec, n_flips,
     has_cross, exact) = step(
        jnp.asarray(A), jnp.asarray(d), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(state), jnp.asarray(rho),
        # scalars must ride in as 0-d arrays: a bare Python float is an
        # implicit transfer under the strict_numerics guard
        jnp.asarray(np.asarray(s)), jnp.asarray(np.asarray(budget)))
    # sequential reference from the same maintained d (no recompute)
    alpha = rho @ A
    sa = s * alpha
    tol = 1e-9
    nonbasic = state < 2
    at_up = state == 1
    elig = nonbasic & (((~at_up) & (sa > tol)) | (at_up & (sa < -tol)))
    ratio = np.where(elig, np.maximum(d / np.where(np.abs(sa) > tol, sa, 1),
                                      0), np.inf)
    cost = np.where(elig, np.abs(alpha) * (hi - lo), 0.0)
    q_ref, flips_ref, ok_ref = bfrt_sequential_ref(ratio, cost, budget)
    assert bool(has_cross) == ok_ref
    np.testing.assert_allclose(np.asarray(alpha_d), alpha, atol=1e-10)
    if ok_ref:
        assert bool(exact)
        assert float(r_best) == pytest.approx(ratio[q_ref])
        assert float(d_q) == pytest.approx(d[int(q)])
        assert bool(at_up_q) == bool(state[int(q)] == 1)
        np.testing.assert_allclose(np.asarray(Acol), A[:, int(q)])
        # strict-below flips are a subset of the reference flip set and
        # stay within budget
        fl = np.asarray(flips_d)
        assert fl.sum() == int(n_flips)
        assert cost[fl].sum() <= budget + 1e-9
        assert np.all(ratio[fl] < float(r_best) + 1e-15)
        # flip absorption vector matches A @ dx over the flipped columns
        dx = np.where(at_up, lo - hi, hi - lo) * fl
        np.testing.assert_allclose(np.asarray(fvec), A @ dx, atol=1e-8)


def test_pq_step_infeasible_detection(mesh):
    m, n = 3, 1024
    A, d, lo, hi, state, rho = _random_state(1, m, n)
    step, _, _ = make_pq_step(mesh, m, n)
    out = step(
        jnp.asarray(A), jnp.asarray(d), jnp.asarray(lo), jnp.asarray(hi),
        jnp.asarray(state), jnp.asarray(rho),
        jnp.asarray(1.0), jnp.asarray(1e12))   # impossible budget
    assert not bool(out[-2])                   # has_cross


def test_row_scaling_equilibrates():
    A = np.array([[1.0, 1.0], [1e12, 2e12], [1e-6, 3e-6]])
    s = row_scaling(A)
    scaled = A * s[:, None]
    assert np.all(np.abs(scaled).max(axis=1) == pytest.approx(1.0))


def test_big_sentinel_is_finite_in_any_x64_mode():
    """The masked-reduction sentinel must stay finite for every dtype —
    ``jnp.float64(1e300)`` under default no-x64 truncates to inf and
    poisons the pmax/pmin reductions."""
    for dt in (jnp.float32, jnp.float64):
        v = big_sentinel(dt)
        assert v.dtype == jnp.dtype(dt)
        assert bool(jnp.isfinite(v))
        assert bool(jnp.isfinite(-v))
    # f32 case is exactly what an unguarded 1e300 would break
    assert float(big_sentinel(jnp.float32)) < float("inf")


# ------------------------------------------------- full-solve parity


def _package_lp(seed, m=6, n=800):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A = np.stack([np.ones(n)] + [
        rng.normal(rng.uniform(-2, 5), rng.uniform(0.5, 2), n)
        for _ in range(m - 1)])
    x0 = np.zeros(n)
    x0[rng.choice(n, 16, replace=False)] = 1.0
    act = A @ x0
    w = np.maximum(np.abs(act) * 0.05, 0.5)
    return c, A, act - w, act + w, np.ones(n)


def _meshes():
    shapes = [(1, 2)]
    if len(jax.devices()) >= 4:
        shapes.append((2, 2))
    return shapes


@pytest.mark.parametrize("shape", _meshes())
def test_distributed_solve_matches_numpy_twin(shape, strict_numerics):
    """Cold full solve through the distributed pricing path reaches the
    numpy twin's objective AND basis, with an independent certificate."""
    mesh = jax.make_mesh(shape, ("data", "model"))
    for seed in (0, 3):
        c, A, bl, bu, ub = _package_lp(seed)
        ref = solve_lp_np(c, A, bl, bu, ub)
        res = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh)
        assert res.status == ref.status == OPTIMAL
        assert res.obj == pytest.approx(ref.obj, rel=1e-8, abs=1e-8)
        assert np.array_equal(np.sort(res.basis), np.sort(ref.basis))
        ok, why = verify_optimality(res, c, A, bl, bu, ub)
        assert ok, why
        # exact-BFRT selection: no conservative fallback on these sizes
        assert res.pivot_stats["conservative"] == 0
        assert res.pivot_stats["exact"] > 0


@pytest.mark.parametrize("shape", _meshes())
def test_distributed_solve_warm_start_parity(shape):
    """Warm-started distributed solve: same answer, fewer pivots."""
    mesh = jax.make_mesh(shape, ("data", "model"))
    c, A, bl, bu, ub = _package_lp(1)
    cold = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh)
    ref = solve_lp_np(c, A, bl, bu, ub)
    assert cold.status == OPTIMAL
    # sibling LP provides the warm basis (the Progressive-Shading pattern)
    c2 = c + 0.01 * np.random.default_rng(42).normal(size=len(c))
    sib = solve_lp_np(c2, A, bl, bu, ub)
    warm = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh, warm_start=sib)
    assert warm.status == OPTIMAL
    assert warm.obj == pytest.approx(ref.obj, rel=1e-8, abs=1e-8)
    assert warm.iters <= cold.iters
    ok, why = verify_optimality(warm, c, A, bl, bu, ub)
    assert ok, why


def test_solve_lp_mesh_kwarg_routes_to_distributed():
    """core.lp.solve_lp(mesh=...) is the engine's distributed entry."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    c, A, bl, bu, ub = _package_lp(5, n=300)
    ref = solve_lp_np(c, A, bl, bu, ub)
    res = solve_lp(c, A, bl, bu, ub, mesh=mesh)
    assert res.status == ref.status
    assert res.obj == pytest.approx(ref.obj, rel=1e-8, abs=1e-8)
    assert hasattr(res, "pivot_stats")


def test_distributed_conservative_fallback_still_optimal():
    """A tiny gather_k forces the truncation fallback; the conservative
    bucket-minimum pivot is still a valid BFRT step, so the solve reaches
    the same optimum (possibly in more pivots)."""
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    c, A, bl, bu, ub = _package_lp(2, n=1500)
    ref = solve_lp_np(c, A, bl, bu, ub)
    res = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh, gather_k=2)
    assert res.status == OPTIMAL
    assert res.obj == pytest.approx(ref.obj, rel=1e-8, abs=1e-8)
    assert res.pivot_stats["conservative"] > 0
    ok, why = verify_optimality(res, c, A, bl, bu, ub)
    assert ok, why


def test_distributed_infeasible_box():
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    c = np.ones(4)
    A = np.ones((1, 4))
    ref = solve_lp_np(c, A, np.array([10.0]), np.array([20.0]), np.ones(4))
    res = solve_lp_dist(c, A, np.array([10.0]), np.array([20.0]),
                        np.ones(4), mesh=mesh)
    assert res.status == ref.status
