"""Distributed pq_step (shard_map dual-simplex iteration) numerical
equivalence vs the sequential implementation, on a real (tiny) mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed import make_pq_step
from repro.core.lp import row_scaling
from repro.kernels.ref import bfrt_sequential_ref


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _random_state(seed, m=4, n=4096):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, n))
    c = rng.normal(size=n)
    lo = np.zeros(n)
    hi = rng.uniform(1, 3, n)
    state = rng.integers(0, 3, n).astype(np.int32)
    rho = rng.normal(size=m)
    y = rng.normal(size=m)
    return A, c, lo, hi, state, rho, y


def test_pq_step_matches_sequential_bfrt(mesh):
    m, n = 4, 4096
    A, c, lo, hi, state, rho, y = _random_state(0, m, n)
    s, budget = 1.0, 25.0
    step, col_spec, vec_spec = make_pq_step(mesh, m, n, num_buckets=256)
    with mesh:
        r_best, q, n_flips, has_cross = step(
            jnp.asarray(A), jnp.asarray(c), jnp.asarray(lo), jnp.asarray(hi),
            jnp.asarray(state), jnp.asarray(rho), jnp.asarray(y),
            jnp.asarray(s), jnp.asarray(budget))
    # sequential reference
    alpha = rho @ A
    d = c - y @ A
    sa = s * alpha
    tol = 1e-9
    nonbasic = state < 2
    at_up = state == 1
    elig = nonbasic & (((~at_up) & (sa > tol)) | (at_up & (sa < -tol)))
    ratio = np.where(elig, np.maximum(d / np.where(np.abs(sa) > tol, sa, 1),
                                      0), np.inf)
    cost = np.where(elig, np.abs(alpha) * (hi - lo), 0.0)
    q_ref, flips_ref, ok_ref = bfrt_sequential_ref(ratio, cost, budget)
    assert bool(has_cross) == ok_ref
    if ok_ref:
        # pq_step's pass 2 enters at the crossing bucket's minimum — a
        # *valid, conservative* BFRT step (all strictly-smaller ratios are
        # flipped; their cumulative cost is below the budget by
        # construction).  Assert validity + proximity to the exact walk:
        rb = float(r_best)
        assert rb <= ratio[q_ref] + 1e-9          # never overshoots
        flip_cost = cost[np.isfinite(ratio) & (ratio < rb)].sum()
        assert flip_cost <= budget + 1e-9         # flips stay within budget
        assert int(n_flips) <= int(flips_ref.sum())
        # entering variable is eligible
        q_i = int(q)
        assert np.isfinite(ratio[q_i])


def test_pq_step_infeasible_detection(mesh):
    m, n = 3, 1024
    A, c, lo, hi, state, rho, y = _random_state(1, m, n)
    step, _, _ = make_pq_step(mesh, m, n)
    with mesh:
        _, _, _, has_cross = step(
            jnp.asarray(A), jnp.asarray(c), jnp.asarray(lo), jnp.asarray(hi),
            jnp.asarray(state), jnp.asarray(rho), jnp.asarray(y),
            jnp.asarray(1.0), jnp.asarray(1e12))   # impossible budget
    assert not bool(has_cross)


def test_row_scaling_equilibrates():
    A = np.array([[1.0, 1.0], [1e12, 2e12], [1e-6, 3e-6]])
    s = row_scaling(A)
    scaled = A * s[:, None]
    assert np.all(np.abs(scaled).max(axis=1) == pytest.approx(1.0))
