"""The project lint layer: every REPRO rule fires on a seeded snippet,
suppression comments silence them (with a justification required), the
baseline ratchet admits the pinned debt and nothing else at repo head,
and the CLI exits non-zero per seeded rule.  The concurrency pass
(REPRO008-012) rides the same machinery and is tested through the same
parametrizations."""
import json
import os

import pytest

from repro.analysis.concurrency import (ALL_RULES, check_paths,
                                        check_source)
from repro.analysis.lint import (DEFAULT_LINT_DIRS, lint_paths,
                                 lint_source)
from repro.analysis.report import (compare_baseline, count_by_key,
                                   load_baseline)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def scan_source(src, path="snippet.py"):
    """Both AST passes over one source string (lint + concurrency)."""
    return lint_source(src, path) + check_source(src, path)

SNIPPETS = {
    "REPRO001": """\
import jax
def f(x):
    y = x + 1
    def t(_):
        return y
    def e(_):
        return x * 0
    return jax.lax.cond(x.sum() > 0, t, e, None)
""",
    "REPRO002": """\
import jax.numpy as jnp
def f(x):
    big = 1e300
    return jnp.float64(x) + big
""",
    "REPRO003": """\
import jax
import numpy as np
@jax.jit
def f(x):
    q = x.item()
    return np.asarray(x) + q
""",
    "REPRO004": """\
def f():
    try:
        g()
    except Exception:
        pass
""",
    "REPRO005": """\
import numpy as np
def f(table, idx):
    return np.asarray(table["price"])[idx]
""",
    "REPRO006": """\
def solve(A, max_iters=100):
    for it in range(max_iters):
        step(A)
""",
    "REPRO007": """\
def f(qcache, key):
    try:
        val = compute()
        qcache.store(key, val)
    except Exception:
        pass
""",
    "REPRO008": """\
import threading
class Registry:
    __guarded_by__ = {"entries": "_lock"}
    def __init__(self):
        self.entries = {}
        self._lock = threading.Lock()
    def put(self, k, v):
        self.entries[k] = v
""",
    "REPRO009": """\
import threading
class Cache:
    def __init__(self):
        self._lock = threading.Lock()
        self._cache = {}
    def get_or_add(self, k, factory):
        with self._lock:
            if k in self._cache:
                return self._cache[k]
        v = factory()
        with self._lock:
            self._cache[k] = v
        return v
""",
    "REPRO010": """\
import threading
_CACHE = {}
_LOCK = threading.Lock()
def put(k, v):
    _CACHE[k] = v
""",
    "REPRO011": """\
import threading
_LOCK = threading.Lock()
def solve(c, A):
    with _LOCK:
        return solve_lp_batch(c, A)
""",
    "REPRO012": """\
import threading
class Cache:
    def __init__(self):
        self.stats = CacheStats()
        self._lock = threading.Lock()
    def hit_and_miss(self):
        self.stats.hits += 1
        self.stats.misses += 1
""",
}


@pytest.mark.parametrize("rule", sorted(SNIPPETS))
def test_rule_fires_on_seeded_snippet(rule):
    vs = scan_source(SNIPPETS[rule])
    assert any(v.rule == rule for v in vs), \
        f"{rule} ({ALL_RULES[rule]}) did not fire"
    assert all(v.path == "snippet.py" and v.line > 0 for v in vs)


@pytest.mark.parametrize("rule", sorted(SNIPPETS))
def test_suppression_comment_silences_rule(rule):
    vs = scan_source(SNIPPETS[rule])
    lines = SNIPPETS[rule].splitlines()
    for line_no in sorted({v.line for v in vs if v.rule == rule},
                          reverse=True):
        indent = lines[line_no - 1][:len(lines[line_no - 1])
                                    - len(lines[line_no - 1].lstrip())]
        lines.insert(line_no - 1,
                     f"{indent}# repro: allow[{rule}] tested escape hatch")
    vs2 = scan_source("\n".join(lines) + "\n")
    assert not any(v.rule == rule for v in vs2)


def test_suppression_requires_justification():
    src = """\
def f():
    try:
        g()
    # repro: allow[REPRO004]
    except Exception:
        pass
"""
    assert any(v.rule == "REPRO004" for v in lint_source(src, "s.py"))


def test_suppression_is_rule_specific():
    src = """\
def f():
    try:
        g()
    # repro: allow[REPRO001] wrong rule id
    except Exception:
        pass
"""
    assert any(v.rule == "REPRO004" for v in lint_source(src, "s.py"))


def test_syntax_error_reports_repro000():
    vs = lint_source("def f(:\n", "bad.py")
    assert [v.rule for v in vs] == ["REPRO000"]


def test_repo_head_is_clean_against_baseline():
    """The tree carries no lint debt beyond the pinned baseline."""
    vs, n_files = lint_paths(DEFAULT_LINT_DIRS, root=ROOT)
    assert n_files > 50
    pinned = load_baseline(os.path.join(ROOT, "analysis", "baseline.json"))
    new, shrunk, stale = compare_baseline(vs, pinned)
    assert new == [], "new violations:\n" + "\n".join(
        v.format() for v in new)
    assert stale == [], f"stale baseline pins: {stale}"


def test_repo_head_has_zero_concurrency_debt():
    """The serving path carries ZERO unsuppressed REPRO008-012 — the
    concurrency contracts hold with no pinned debt at all."""
    vs, n_files = check_paths(DEFAULT_LINT_DIRS, root=ROOT)
    assert n_files > 50
    assert vs == [], "concurrency violations:\n" + "\n".join(
        v.format() for v in vs)


def test_audited_files_detectably_in_scope():
    """The clean bill of health above is from real detection, not a
    scoping hole: stripping the suppression markers re-fires the rules
    at the two by-design sites (claim-token cache, tick-exclusivity
    dispatch)."""
    expected = {
        "src/repro/core/distributed.py": "REPRO009",
        "src/repro/serving/scheduler.py": "REPRO011",
    }
    for rel, rule in expected.items():
        with open(os.path.join(ROOT, rel)) as f:
            src = f.read().replace("repro: allow", "repro: unallow")
        vs = check_source(src, rel)
        assert any(v.rule == rule for v in vs), \
            f"{rule} no longer detected in {rel} without its suppression"


def test_baseline_ratchet_counts():
    pinned = {"REPRO004:a.py": 2}
    vs3 = lint_source(SNIPPETS["REPRO004"] * 3, "a.py")
    new, _, _ = compare_baseline(vs3, pinned)
    assert len(new) == 1                    # 3 found, 2 pinned
    vs1 = lint_source(SNIPPETS["REPRO004"], "a.py")
    new, shrunk, _ = compare_baseline(vs1, pinned)
    assert new == [] and shrunk == ["REPRO004:a.py"]
    new, _, stale = compare_baseline([], pinned)
    assert new == [] and stale == ["REPRO004:a.py"]
    assert count_by_key(vs3) == {"REPRO004:a.py": 3}


@pytest.mark.parametrize("rule", sorted(SNIPPETS))
def test_cli_exits_nonzero_on_seeded_violation(rule, tmp_path):
    from repro.analysis.__main__ import main
    (tmp_path / "seeded.py").write_text(SNIPPETS[rule])
    out = tmp_path / "analysis.json"
    rc = main(["--grid", "none", "--root", str(tmp_path),
               "--lint-dir", ".", "--out", str(out)])
    assert rc == 1
    rep = json.loads(out.read_text())
    assert rep["exit_code"] == 1
    assert any(v["rule"] == rule for v in rep["lint"]["violations"])


def test_cli_repo_head_with_baseline_passes(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "analysis.json"
    rc = main(["--grid", "none", "--root", ROOT,
               "--baseline", os.path.join(ROOT, "analysis/baseline.json"),
               "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["exit_code"] == 0 and rep["grid"] == "none"


def test_cli_update_baseline_refuses_to_grow(tmp_path):
    from repro.analysis.__main__ import main
    (tmp_path / "seeded.py").write_text(SNIPPETS["REPRO004"])
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps({"version": 1, "pinned": {}}))
    rc = main(["--grid", "none", "--root", str(tmp_path),
               "--lint-dir", ".", "--baseline", str(base),
               "--update-baseline",
               "--out", str(tmp_path / "analysis.json")])
    assert rc == 2
    assert load_baseline(str(base)) == {}   # pin file untouched
