"""The project lint layer: every REPRO rule fires on a seeded snippet,
suppression comments silence them (with a justification required), the
baseline ratchet admits the pinned debt and nothing else at repo head,
and the CLI exits non-zero per seeded rule."""
import json
import os

import pytest

from repro.analysis.lint import (DEFAULT_LINT_DIRS, RULES, lint_paths,
                                 lint_source)
from repro.analysis.report import (compare_baseline, count_by_key,
                                   load_baseline)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SNIPPETS = {
    "REPRO001": """\
import jax
def f(x):
    y = x + 1
    def t(_):
        return y
    def e(_):
        return x * 0
    return jax.lax.cond(x.sum() > 0, t, e, None)
""",
    "REPRO002": """\
import jax.numpy as jnp
def f(x):
    big = 1e300
    return jnp.float64(x) + big
""",
    "REPRO003": """\
import jax
import numpy as np
@jax.jit
def f(x):
    q = x.item()
    return np.asarray(x) + q
""",
    "REPRO004": """\
def f():
    try:
        g()
    except Exception:
        pass
""",
    "REPRO005": """\
import numpy as np
def f(table, idx):
    return np.asarray(table["price"])[idx]
""",
    "REPRO006": """\
def solve(A, max_iters=100):
    for it in range(max_iters):
        step(A)
""",
    "REPRO007": """\
def f(qcache, key):
    try:
        val = compute()
        qcache.store(key, val)
    except Exception:
        pass
""",
}


@pytest.mark.parametrize("rule", sorted(SNIPPETS))
def test_rule_fires_on_seeded_snippet(rule):
    vs = lint_source(SNIPPETS[rule], "snippet.py")
    assert any(v.rule == rule for v in vs), \
        f"{rule} ({RULES[rule]}) did not fire"
    assert all(v.path == "snippet.py" and v.line > 0 for v in vs)


@pytest.mark.parametrize("rule", sorted(SNIPPETS))
def test_suppression_comment_silences_rule(rule):
    vs = lint_source(SNIPPETS[rule], "snippet.py")
    lines = SNIPPETS[rule].splitlines()
    for line_no in sorted({v.line for v in vs if v.rule == rule},
                          reverse=True):
        indent = lines[line_no - 1][:len(lines[line_no - 1])
                                    - len(lines[line_no - 1].lstrip())]
        lines.insert(line_no - 1,
                     f"{indent}# repro: allow[{rule}] tested escape hatch")
    vs2 = lint_source("\n".join(lines) + "\n", "snippet.py")
    assert not any(v.rule == rule for v in vs2)


def test_suppression_requires_justification():
    src = """\
def f():
    try:
        g()
    # repro: allow[REPRO004]
    except Exception:
        pass
"""
    assert any(v.rule == "REPRO004" for v in lint_source(src, "s.py"))


def test_suppression_is_rule_specific():
    src = """\
def f():
    try:
        g()
    # repro: allow[REPRO001] wrong rule id
    except Exception:
        pass
"""
    assert any(v.rule == "REPRO004" for v in lint_source(src, "s.py"))


def test_syntax_error_reports_repro000():
    vs = lint_source("def f(:\n", "bad.py")
    assert [v.rule for v in vs] == ["REPRO000"]


def test_repo_head_is_clean_against_baseline():
    """The tree carries no lint debt beyond the pinned baseline."""
    vs, n_files = lint_paths(DEFAULT_LINT_DIRS, root=ROOT)
    assert n_files > 50
    pinned = load_baseline(os.path.join(ROOT, "analysis", "baseline.json"))
    new, shrunk, stale = compare_baseline(vs, pinned)
    assert new == [], "new violations:\n" + "\n".join(
        v.format() for v in new)
    assert stale == [], f"stale baseline pins: {stale}"


def test_baseline_ratchet_counts():
    pinned = {"REPRO004:a.py": 2}
    vs3 = lint_source(SNIPPETS["REPRO004"] * 3, "a.py")
    new, _, _ = compare_baseline(vs3, pinned)
    assert len(new) == 1                    # 3 found, 2 pinned
    vs1 = lint_source(SNIPPETS["REPRO004"], "a.py")
    new, shrunk, _ = compare_baseline(vs1, pinned)
    assert new == [] and shrunk == ["REPRO004:a.py"]
    new, _, stale = compare_baseline([], pinned)
    assert new == [] and stale == ["REPRO004:a.py"]
    assert count_by_key(vs3) == {"REPRO004:a.py": 3}


@pytest.mark.parametrize("rule", sorted(SNIPPETS))
def test_cli_exits_nonzero_on_seeded_violation(rule, tmp_path):
    from repro.analysis.__main__ import main
    (tmp_path / "seeded.py").write_text(SNIPPETS[rule])
    out = tmp_path / "analysis.json"
    rc = main(["--grid", "none", "--root", str(tmp_path),
               "--lint-dir", ".", "--out", str(out)])
    assert rc == 1
    rep = json.loads(out.read_text())
    assert rep["exit_code"] == 1
    assert any(v["rule"] == rule for v in rep["lint"]["violations"])


def test_cli_repo_head_with_baseline_passes(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "analysis.json"
    rc = main(["--grid", "none", "--root", ROOT,
               "--baseline", os.path.join(ROOT, "analysis/baseline.json"),
               "--out", str(out)])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["exit_code"] == 0 and rep["grid"] == "none"


def test_cli_update_baseline_refuses_to_grow(tmp_path):
    from repro.analysis.__main__ import main
    (tmp_path / "seeded.py").write_text(SNIPPETS["REPRO004"])
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps({"version": 1, "pinned": {}}))
    rc = main(["--grid", "none", "--root", str(tmp_path),
               "--lint-dir", ".", "--baseline", str(base),
               "--update-baseline",
               "--out", str(tmp_path / "analysis.json")])
    assert rc == 2
    assert load_baseline(str(base)) == {}   # pin file untouched
