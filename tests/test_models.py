"""Per-arch smoke tests (reduced same-family configs) + layer-level
numerical consistency (MoE vs dense oracle, SSD scan vs sequential
recurrence, prefill-vs-decode logits agreement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.param import init_params


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    tok = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(tok)}
    if cfg.is_encoder_decoder:
        batch["enc_inputs"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)),
            jnp.bfloat16)
    if cfg.num_prefix_tokens:
        batch["prefix"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Brief requirement: reduced config, one forward/train step on CPU,
    output shapes + no NaNs."""
    from repro.training.optimizer import OptHyper
    from repro.training.step import init_train_state, make_train_step
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    step = jax.jit(make_train_step(model, OptHyper(lr=1e-3)))
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert float(metrics["loss"]) > 0
    # params changed and stayed finite
    p0 = jax.tree.leaves(state["params"])[0]
    p1 = jax.tree.leaves(new_state["params"])[0]
    assert p0.shape == p1.shape
    assert bool(jnp.all(jnp.isfinite(p1.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode(arch):
    cfg = get_config(arch).smoke()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 64)
    logits, cache = jax.jit(model.decode_step)(
        params, cache, jnp.ones((B, 1), jnp.int32))
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["index"]) == 1


def _f32(cfg):
    return dataclasses.replace(cfg, param_dtype="float32")


def test_moe_dispatch_matches_dense_oracle():
    """Capacity dispatch == dense per-expert compute when nothing drops."""
    cfg = _f32(get_config("mixtral-8x22b").smoke())
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    spec = moe_lib.moe_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe_lib.apply_moe(params, cfg, x)
    ref = moe_lib.ref_moe(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert jnp.isfinite(aux)


def test_moe_shared_expert_path():
    cfg = _f32(get_config("deepseek-v3-671b").smoke())
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    spec = moe_lib.moe_spec(cfg)
    assert "shared" in spec
    params = init_params(spec, jax.random.PRNGKey(1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model),
                          jnp.float32)
    out, _ = moe_lib.apply_moe(params, cfg, x)
    ref = moe_lib.ref_moe(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_scan_matches_sequential_recurrence():
    """Chunked SSD (training path) == token-by-token recurrence (decode)."""
    cfg = _f32(get_config("mamba2-1.3b").smoke())
    spec = ssm_lib.ssm_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(1), jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                                jnp.float32)
    y_par = ssm_lib.ssd_forward(params, cfg, x)
    y_seq = ssm_lib.ssd_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen2-1.5b",
                                  "mamba2-1.3b", "deepseek-v3-671b"])
def test_prefill_decode_logits_agree(arch):
    """Parallel forward logits at position t == step-by-step decode logits
    (KV-cache correctness across GQA / MLA / SSM).  capacity_factor is
    raised so MoE archs drop no tokens in the parallel path (decode never
    drops, so dropping would be a legitimate difference, not a bug)."""
    cfg = dataclasses.replace(get_config(arch).smoke(),
                              param_dtype="float32", capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full = model.prefill_logits(params, {"tokens": toks})   # (B, S, V)
    cache = model.init_cache(B, S + 4)
    for t in range(S):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3)


def test_sliding_window_rolling_cache():
    """SWA decode with a rolling cache matches full-forward logits."""
    cfg = dataclasses.replace(get_config("h2o-danube-3-4b").smoke(),
                              param_dtype="float32", sliding_window=8)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 20
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32)
    full = model.prefill_logits(params, {"tokens": toks})
    cache = model.init_cache(B, S)       # rolling: kv_len == window == 8
    assert cache["k"].shape[2] == 8
    for t in range(S):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3)


def test_param_counts_match_analytic():
    """Analytic estimator (used for MODEL_FLOPS) within 2% of actual
    (it skips norm scales / tiny vectors by design)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch).smoke()
        model = Model(cfg)
        actual = model.param_count()
        est = cfg.param_count()
        assert abs(actual - est) <= 0.02 * actual, (arch, actual, est)


def test_full_config_param_counts_sane():
    """Full (unreduced) configs land near their nameplate sizes."""
    expect = {"mixtral-8x22b": 141e9, "deepseek-v3-671b": 671e9,
              "glm4-9b": 9e9, "qwen2-1.5b": 1.5e9,
              "jamba-1.5-large-398b": 398e9, "mamba2-1.3b": 1.3e9,
              "smollm-135m": 135e6}
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert 0.55 * target <= n <= 1.6 * target, (arch, n, target)
