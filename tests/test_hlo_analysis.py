"""Unit tests for the static HLO walkers on hand-written HLO text:
trip-count recovery, the per-collective byte model (both replica_groups
forms, async start/done pairs, while weighting), the host-transfer /
python-callback walker, and the per-while-body per-trip stats."""
import pytest

from repro.distributed.hlo_analysis import (collective_bytes, hlo_stats,
                                            host_transfer_ops, shape_bytes,
                                            while_body_stats,
                                            while_trip_counts)

# 25-trip scan whose body issues one all-reduce (explicit 4-wide groups),
# one all-gather (iota groups, 8-wide) and an async all-reduce pair; one
# collective-permute outside the loop.
LOOP_HLO = """\
HloModule loop_fixture

%cond.1 (arg.1: (s32[], f64[128])) -> pred[] {
  %arg.1 = (s32[], f64[128]) parameter(0)
  %iv = s32[] get-tuple-element(%arg.1), index=0
  %small = s32[] constant(3)
  %limit = s32[] constant(25)
  ROOT %lt = pred[] compare(%iv, %limit), direction=LT
}

%body.1 (arg.2: (s32[], f64[128])) -> (s32[], f64[128]) {
  %arg.2 = (s32[], f64[128]) parameter(0)
  %x = f64[128] get-tuple-element(%arg.2), index=1
  %ar = f64[128] all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%sum
  %ag = f64[512]{0} all-gather(%x), replica_groups=[4,8]<=[32], dimensions={0}
  %ars = f64[32] all-reduce-start(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ard = f64[32] all-reduce-done(%ars)
  %iv.2 = s32[] get-tuple-element(%arg.2), index=0
  ROOT %t = (s32[], f64[128]) tuple(%iv.2, %ar)
}

ENTRY %main (p0: f64[128]) -> f64[128] {
  %p0 = f64[128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f64[128]) tuple(%zero, %p0)
  %w = (s32[], f64[128]) while(%init), condition=%cond.1, body=%body.1
  %cp = f64[64] collective-permute(%p0), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f64[128] get-tuple-element(%w), index=1
}
"""

# A 7-trip loop containing a python-callback custom-call and an outfeed,
# plus a benign Sharding custom-call and a top-level (not-in-loop)
# callback in ENTRY.
HOST_HLO = """\
HloModule host_fixture

%cond.2 (arg.1: (s32[], f32[4])) -> pred[] {
  %arg.1 = (s32[], f32[4]) parameter(0)
  %iv = s32[] get-tuple-element(%arg.1), index=0
  %limit = s32[] constant(7)
  ROOT %lt = pred[] compare(%iv, %limit), direction=LT
}

%body.2 (arg.2: (s32[], f32[4])) -> (s32[], f32[4]) {
  %arg.2 = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%arg.2), index=1
  %cb = f32[4] custom-call(%x), custom_call_target="xla_python_cpu_callback"
  %shard = f32[4] custom-call(%cb), custom_call_target="Sharding"
  %tok = token[] after-all()
  %of = token[] outfeed(%x, %tok)
  %iv.2 = s32[] get-tuple-element(%arg.2), index=0
  ROOT %t = (s32[], f32[4]) tuple(%iv.2, %cb)
}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[4]) tuple(%zero, %p0)
  %w = (s32[], f32[4]) while(%init), condition=%cond.2, body=%body.2
  %top = f32[4] custom-call(%p0), custom_call_target="SomeHostTransfer"
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""

# 10-trip loop around one dot: f64[8,32] @ f64[32,16].
DOT_HLO = """\
HloModule dot_fixture

%cond.3 (arg.1: (s32[], f64[8,16])) -> pred[] {
  %arg.1 = (s32[], f64[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%arg.1), index=0
  %limit = s32[] constant(10)
  ROOT %lt = pred[] compare(%iv, %limit), direction=LT
}

%body.3 (arg.2: (s32[], f64[8,16])) -> (s32[], f64[8,16]) {
  %arg.2 = (s32[], f64[8,16]) parameter(0)
  %a = f64[8,32] parameter(1)
  %b = f64[32,16] parameter(2)
  %d = f64[8,16] dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %iv.2 = s32[] get-tuple-element(%arg.2), index=0
  ROOT %t = (s32[], f64[8,16]) tuple(%iv.2, %d)
}

ENTRY %main (p0: f64[8,16]) -> f64[8,16] {
  %p0 = f64[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f64[8,16]) tuple(%zero, %p0)
  %w = (s32[], f64[8,16]) while(%init), condition=%cond.3, body=%body.3
  ROOT %out = f64[8,16] get-tuple-element(%w), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("f64[128]") == 1024
    assert shape_bytes("f32[4,4]") == 64
    assert shape_bytes("pred[8]") == 8
    assert shape_bytes("(f32[10], pred[2])") == 42
    assert shape_bytes("s32[]") == 4
    assert shape_bytes("token[]") == 0


def test_trip_count_recovery_takes_loop_bound():
    # the condition holds two constants (3 and 25); the bound is the max
    assert while_trip_counts(LOOP_HLO) == {"body.1": 25}
    assert while_trip_counts(HOST_HLO) == {"body.2": 7}


def test_collective_byte_model_with_while_weighting():
    st = collective_bytes(LOOP_HLO)
    # all-reduce: explicit groups of 4 -> 2*(4-1)/4 per byte.  Per trip:
    # f64[128] (1024 B) plus the async f64[32] start/done pair counted
    # once (256 B); x25 trips.
    assert st.bytes_by_kind["all-reduce"] == pytest.approx(
        25 * (1024 + 256) * 1.5)
    # all-gather: iota groups [4,8]<=[32] -> group size 8 -> 7/8
    assert st.bytes_by_kind["all-gather"] == pytest.approx(
        25 * 4096 * 7 / 8)
    # collective-permute outside the loop: counted once, factor 1
    assert st.bytes_by_kind["collective-permute"] == pytest.approx(512)
    assert st.count_by_kind == {"all-reduce": 50, "all-gather": 25,
                                "collective-permute": 1}
    assert st.total_bytes == pytest.approx(sum(st.bytes_by_kind.values()))


def test_collective_default_group_size():
    # strip replica_groups annotations -> the caller-declared default
    import re
    hlo = re.sub(r"replica_groups=(\{\{[^}]*\}[^=]*\}|\[[\dx,]+\]<=\[\d+\])",
                 "channel_id=1", LOOP_HLO)
    st2 = collective_bytes(hlo, default_group=2)
    assert st2.bytes_by_kind["all-reduce"] == pytest.approx(
        25 * (1024 + 256) * 1.0)          # 2(n-1)/n = 1 at n=2
    assert st2.bytes_by_kind["all-gather"] == pytest.approx(
        25 * 4096 * 0.5)


def test_host_transfer_walker_finds_callbacks_in_loops():
    ops = host_transfer_ops(HOST_HLO)
    by_op = {(o["op"], o["target"]): o for o in ops}
    cb = by_op[("custom-call", "xla_python_cpu_callback")]
    assert cb["in_while"] and cb["trips"] == 7
    assert cb["computation"] == "body.2"
    of = by_op[("outfeed", "")]
    assert of["in_while"] and of["trips"] == 7
    top = by_op[("custom-call", "SomeHostTransfer")]
    assert not top["in_while"] and top["trips"] == 1
    # the Sharding custom-call is benign and must NOT be reported
    assert not any(o["target"] == "Sharding" for o in ops)


def test_host_transfer_walker_clean_module():
    assert host_transfer_ops(LOOP_HLO) == []


def test_while_body_stats_per_trip():
    stats = while_body_stats(LOOP_HLO)
    trips, st = stats["body.1"]
    assert trips == 25
    # per-trip (un-multiplied) bytes
    assert st.bytes_by_kind["all-reduce"] == pytest.approx(
        (1024 + 256) * 1.5)
    assert st.bytes_by_kind["all-gather"] == pytest.approx(4096 * 7 / 8)
    assert "collective-permute" not in st.bytes_by_kind
    assert st.count_by_kind == {"all-reduce": 2, "all-gather": 1}


def test_hlo_stats_dot_flops_while_weighted():
    st = hlo_stats(DOT_HLO)
    # dot: out 8x16, contraction 32 -> 2*128*32 flops, x10 trips
    assert st.flops == pytest.approx(10 * 2 * 128 * 32)
    # operand + result bytes: f64[8,32] + f64[32,16] + f64[8,16]
    assert st.dot_bytes == pytest.approx(10 * (2048 + 4096 + 1024))


def test_real_lowering_roundtrip():
    """The walkers agree with an actual jax lowering: a psum inside a
    scan over a 2-device mesh produces a while whose recovered trip
    count matches the scan length, with all-reduce traffic to match."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    mesh = jax.make_mesh((2,), ("data",))
    L = 6

    def fn(x):
        def body(c, _):
            s = jax.lax.psum(c, "data")
            return c + 1e-3 * s, ()
        c, _ = jax.lax.scan(body, x, None, length=L)
        return c

    from jax.experimental.shard_map import shard_map
    sm = shard_map(fn, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
    hlo = jax.jit(sm).lower(
        jax.ShapeDtypeStruct((8,), jnp.float64)).compile().as_text()
    trips = while_trip_counts(hlo)
    assert max(trips.values()) == L
    st = collective_bytes(hlo, default_group=2)
    assert st.count_by_kind.get("all-reduce", 0) >= L
    assert st.bytes_by_kind["all-reduce"] > 0
