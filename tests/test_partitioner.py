"""Unified Partitioner subsystem: backend registry, batch-vs-scalar
GetGroup parity across all backends, round-based vs heap-based DLV quality,
sharded/chunked group stats, and the paper's DLV-beats-KD-tree property
through the common API."""
import numpy as np
import pytest

from repro.core import partitioner
from repro.core.bucketing import ArraySource
from repro.core.dlv import dlv, dlv_heap, dlv_rounds, ratio_score
from repro.core.hierarchy import Hierarchy, _min_gap
from repro.core.partitioner import fit, group_stats

BACKENDS = ["dlv", "kdtree", "bucketing"]


@pytest.fixture(scope="module")
def X():
    rng = np.random.default_rng(7)
    return np.concatenate([
        rng.normal(0, 1, (9000, 3)),
        rng.normal(7, 2, (9000, 3)),
    ]) * np.array([1.0, 4.0, 0.3])


@pytest.fixture(scope="module", params=BACKENDS)
def fitted(request, X):
    return request.param, fit(X, backend=request.param, d_f=60)


def test_registry_lists_all_backends():
    assert set(BACKENDS) <= set(partitioner.available_backends())
    with pytest.raises(ValueError):
        fit(np.zeros((4, 2)), backend="no-such-backend")


def test_partition_invariants(fitted, X):
    name, part = fitted
    n = len(X)
    assert part.offsets[0] == 0 and part.offsets[-1] == n
    assert len(np.unique(part.order)) == n          # a permutation
    assert np.all(part.counts >= 1)
    assert part.gid.min() == 0 and part.gid.max() == part.num_groups - 1
    # gid constant within each contiguous slice
    rng = np.random.default_rng(0)
    for g in rng.integers(0, part.num_groups, 25):
        sl = part.order[part.offsets[g]:part.offsets[g + 1]]
        assert np.all(part.gid[sl] == g), name


def test_reps_and_boxes_are_member_stats(fitted, X):
    _, part = fitted
    for g in (0, part.num_groups // 2, part.num_groups - 1):
        m = part.members(g)
        np.testing.assert_allclose(part.reps[g], X[m].mean(0), rtol=1e-9)
        np.testing.assert_allclose(part.boxes_lo[g], X[m].min(0))
        np.testing.assert_allclose(part.boxes_hi[g], X[m].max(0))


def test_members_batch_matches_scalar(fitted):
    _, part = fitted
    gs = np.array([0, part.num_groups // 3, part.num_groups - 1])
    got = part.members_batch(gs)
    want = np.concatenate([part.members(int(g)) for g in gs])
    np.testing.assert_array_equal(got, want)


def test_batch_get_group_matches_scalar_descent(fitted, X):
    """Acceptance: vectorized descent == scalar split-tree descent on 10k
    random probes, for every backend, in numpy AND the jitted while_loop."""
    name, part = fitted
    rng = np.random.default_rng(1)
    T = X[rng.choice(len(X), 10_000, replace=True)]
    scalar = np.fromiter((part.get_group(t) for t in T), np.int64, len(T))
    np.testing.assert_array_equal(part.get_group_batch(T), scalar, err_msg=name)
    np.testing.assert_array_equal(
        np.asarray(part.get_group_batch(T, jit=True)), scalar, err_msg=name)
    # membership probes agree with assigned ids
    idx = rng.choice(len(X), 2_000, replace=False)
    np.testing.assert_array_equal(part.get_group_batch(X[idx]),
                                  part.gid[idx], err_msg=name)


def test_rounds_match_heap_quality(X):
    """Round-based DLV reproduces the heap build's ratio score (tolerance)
    at a comparable group count."""
    heap = dlv_heap(X, 60)
    rounds = dlv_rounds(X, 60)
    assert abs(rounds.num_groups - heap.num_groups) <= \
        max(10, heap.num_groups // 3)
    for j in range(X.shape[1]):
        z_h = ratio_score(X[:, j], heap.gid, weighted=True)
        z_r = ratio_score(X[:, j], rounds.gid, weighted=True)
        assert z_r <= z_h * 1.25 + 5e-3, (j, z_r, z_h)


def test_dlv_beats_kdtree_through_registry():
    """Fig. 7 through the common API: DLV ratio score <= KD-tree's at equal
    group count (the paper's headline partitioning property)."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(20_000, 1))
    res = fit(X, backend="dlv", d_f=100)
    kd = fit(X, backend="kdtree", tau=max(2, 20_000 // res.num_groups))
    assert ratio_score(X[:, 0], res.gid) < ratio_score(X[:, 0], kd.gid)


def test_bucketing_source_and_array_agree(X):
    a = fit(X, backend="bucketing", d_f=60, memory_rows=4000)
    b = fit(ArraySource(X), backend="bucketing", d_f=60, memory_rows=4000)
    np.testing.assert_array_equal(a.gid, b.gid)


# ------------------------------------------------------------ group stats


def test_group_stats_chunked_matches_dense(X):
    part = fit(X, backend="dlv", d_f=60)
    dense = group_stats(X, part.order, part.offsets)
    chunked = group_stats(X, part.order, part.offsets, chunk_rows=700)
    for d, c in zip(dense, chunked):
        np.testing.assert_allclose(c, d, rtol=1e-9, atol=1e-12)


def test_group_stats_sharded_on_mesh(X):
    """Chunk-wise segstats accumulation across a real (host-device) mesh
    reproduces the dense reduceat pass."""
    jax = pytest.importorskip("jax")
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (conftest provides host devices)")
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(data=2, model=1)
    part = fit(X, backend="dlv", d_f=60)
    dense = group_stats(X, part.order, part.offsets)
    sharded = group_stats(X, part.order, part.offsets, mesh=mesh,
                          chunk_rows=2048)
    for d, s in zip(dense, sharded):
        np.testing.assert_allclose(s, d, rtol=1e-8, atol=1e-8)


def test_hierarchy_chunked_build_matches_in_memory(X):
    tbl = {f"a{j}": X[:, j] for j in range(X.shape[1])}
    h_mem = Hierarchy(tbl, list(tbl), d_f=40, alpha=200,
                      rng=np.random.default_rng(0))
    h_chk = Hierarchy(tbl, list(tbl), d_f=40, alpha=200,
                      rng=np.random.default_rng(0), chunk_rows=1500)
    assert h_mem.L == h_chk.L
    for l in range(1, h_mem.L + 1):
        np.testing.assert_allclose(h_chk.layers[l].X, h_mem.layers[l].X,
                                   rtol=1e-9)
        np.testing.assert_array_equal(h_chk.layers[l].part.gid,
                                      h_mem.layers[l].part.gid)


def test_hierarchy_backend_selection(X):
    tbl = {f"a{j}": X[:, j] for j in range(X.shape[1])}
    for be in BACKENDS:
        h = Hierarchy(tbl, list(tbl), d_f=40, alpha=400,
                      rng=np.random.default_rng(0), backend=be)
        assert h.L >= 1
        part = h.layers[1].part
        rng = np.random.default_rng(3)
        idx = rng.choice(len(X), 300, replace=False)
        np.testing.assert_array_equal(h.get_group_batch(1, X[idx]),
                                      part.gid[idx], err_msg=be)


# ------------------------------------------------------------- edge cases


def test_duplicate_heavy_membership_consistency():
    """Cuts snap to equal-value run starts: get_group == gid even when the
    data is mostly ties (boundaries can otherwise land mid-run and route
    tied tuples to the wrong side of the split tree)."""
    rng = np.random.default_rng(11)
    X = np.repeat(rng.normal(size=(50, 2)), 20, axis=0)
    for method in ("rounds", "heap"):
        res = dlv(X, 10, method=method, rng=np.random.default_rng(0))
        got = res.get_group_batch(X)
        np.testing.assert_array_equal(got, res.gid, err_msg=method)


def test_jit_descent_on_boundless_tree():
    """A merged single-bucket tree can have nodes with zero bounds; the
    jitted descent must not gather from an empty bounds array."""
    X = np.full((3000, 2), 5.0)
    part = fit(X, backend="bucketing")
    out = np.asarray(part.get_group_batch(X[:50], jit=True))
    np.testing.assert_array_equal(out, part.gid[:50])


def test_bucketing_survives_concentrated_data():
    """Point-mass clusters that equal-width edge refinement cannot isolate
    degrade to an oversized in-memory bucket instead of crashing."""
    rng = np.random.default_rng(12)
    X = np.concatenate([rng.normal(0, 0.01, (5000, 2)),
                        rng.normal(1000, 0.01, (5000, 2))])
    with pytest.warns(UserWarning, match="oversized bucket"):
        part = fit(X, backend="bucketing", d_f=50, memory_rows=3000)
    assert part.counts.sum() == len(X)
    idx = rng.choice(len(X), 500, replace=False)
    np.testing.assert_array_equal(part.get_group_batch(X[idx]),
                                  part.gid[idx])


# ---------------------------------------------------------------- min gap


def test_min_gap_exact_and_sampled():
    rng = np.random.default_rng(5)
    X = rng.integers(0, 50, size=(30_000, 2)).astype(np.float64) * 0.25
    exact = _min_gap(X)
    assert exact == pytest.approx(0.25)
    # sampled path (force it) can only overestimate the true minimum gap
    est = _min_gap(X, exact_limit=1000, sample=5000,
                   rng=np.random.default_rng(0))
    assert est >= exact - 1e-12
