"""Additional coverage: hardness properties (hypothesis), MoE drop
behaviour, hierarchy/neighbor invariants, local predicates, paql."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.hardness import (Q1_SDSS, instantiate, ndtri)
from repro.core.paql import Constraint, PackageQuery


# ------------------------------------------------------------- hardness


@settings(max_examples=50, deadline=None)
@given(st.floats(1e-9, 1 - 1e-9))
def test_ndtri_inverts_cdf(p):
    import math
    x = ndtri(p)
    phi = 0.5 * math.erfc(-x / math.sqrt(2))
    assert phi == pytest.approx(p, abs=1e-9)


@settings(max_examples=30, deadline=None)
@given(st.floats(0.5, 15.0), st.floats(0.6, 15.0))
def test_hardness_ordering_shrinks_feasible_region(h1, h2):
    stats = {"j": (14.82, 1.562), "h": (14.05, 1.657), "k": (13.73, 1.727),
             "tmass_prox": (14.45, 14.96)}
    lo, hi = min(h1, h2), max(h1, h2)
    if hi - lo < 1e-6:
        return
    qa = {c.attr: c for c in instantiate(Q1_SDSS, stats, lo).constraints
          if c.attr}
    qb = {c.attr: c for c in instantiate(Q1_SDSS, stats, hi).constraints
          if c.attr}
    assert qb["j"].lo >= qa["j"].lo            # >= bound tightens up
    assert qb["h"].hi <= qa["h"].hi            # <= bound tightens down
    assert (qb["k"].hi - qb["k"].lo) <= (qa["k"].hi - qa["k"].lo)


# ------------------------------------------------------------------ MoE


def test_moe_drops_are_bounded_and_finite():
    """With a tiny capacity factor tokens drop, output stays finite and
    close to the no-drop oracle for the kept tokens."""
    from repro.configs import get_config
    from repro.models import moe as moe_lib
    from repro.models.param import init_params
    cfg = dataclasses.replace(get_config("mixtral-8x22b").smoke(),
                              param_dtype="float32", capacity_factor=0.25)
    spec = moe_lib.moe_spec(cfg)
    params = init_params(spec, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32)
    out, aux = moe_lib.apply_moe(params, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    # dropped tokens -> smaller norm than the no-drop oracle overall
    ref = moe_lib.ref_moe(params, cfg, x)
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(ref)) + 1e-3


# -------------------------------------------------------------- engine


def test_local_predicate_excludes_tuples():
    from repro.core.engine import PackageQueryEngine
    rng = np.random.default_rng(0)
    n = 5000
    table = {
        "v": rng.normal(10, 2, n),
        "w": rng.uniform(0.5, 2.0, n),
        "ok": (rng.random(n) < 0.5).astype(np.float64),
    }
    q = PackageQuery("v", maximize=True,
                     constraints=(Constraint(None, 5, 15),
                                  Constraint("w", hi=20.0)),
                     predicate_attr="ok")
    eng = PackageQueryEngine(table, ["v", "w"], d_f=10, alpha=1000, seed=0)
    res = eng.solve(q)
    assert res.feasible
    assert np.all(table["ok"][res.idx] == 1.0)


def test_repeat_allows_multiplicity():
    from repro.core.engine import PackageQueryEngine
    rng = np.random.default_rng(1)
    n = 200
    table = {"v": rng.normal(10, 2, n), "w": rng.uniform(1, 2, n)}
    q = PackageQuery("v", maximize=True, repeat=2,
                     constraints=(Constraint(None, 10, 10),))
    eng = PackageQueryEngine(table, ["v", "w"], d_f=10, alpha=200, seed=0)
    res = eng.solve(q)
    assert res.feasible
    assert np.all(res.mult <= 3)               # REPEAT 2 -> up to 3 copies
    assert res.mult.sum() == 10
    # optimum takes the best tuple 3 times
    assert res.mult.max() == 3


def test_neighbor_sampling_respects_alpha():
    from repro.core.hierarchy import Hierarchy
    from repro.core.neighbor import neighbor_sampling
    rng = np.random.default_rng(2)
    table = {"a": rng.normal(size=20000), "b": rng.normal(size=20000)}
    hier = Hierarchy(table, ["a", "b"], d_f=20, alpha=500)
    assert hier.L >= 1
    s_prime = np.arange(min(5, hier.layers[hier.L].size))
    cand = neighbor_sampling(hier, hier.L, 500, s_prime, "a", True)
    assert len(cand) <= 500
    assert len(np.unique(cand)) == len(cand)
    # candidates are valid layer-(L-1) indices
    assert cand.min() >= 0
    assert cand.max() < hier.layers[hier.L - 1].size


def test_avg_constraint_linearisation():
    """AVG(P.a) >= t == SUM(a - t) >= 0."""
    rng = np.random.default_rng(3)
    n = 3000
    table = {"v": rng.normal(5, 1, n), "a": rng.normal(10, 3, n)}
    q = PackageQuery("v", maximize=True,
                     constraints=(Constraint(None, 8, 12),
                                  Constraint("a", lo=0.0, avg_target=12.0)))
    from repro.core.engine import PackageQueryEngine
    eng = PackageQueryEngine(table, ["v", "a"], d_f=10, alpha=1000, seed=0)
    res = eng.solve(q)
    assert res.feasible
    sel_avg = np.average(table["a"][res.idx], weights=res.mult)
    assert sel_avg >= 12.0 - 1e-6
