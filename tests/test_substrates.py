"""Checkpointing, fault-tolerant coordinator, data pipeline + PQ selection,
serving scheduler, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.runtime import Coordinator, WorkerState


# ------------------------------------------------------------ checkpoint


def _state():
    return {"params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                       "b": jnp.ones(3, jnp.float32)},
            "opt": {"step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(7, st)
    out = mgr.restore(st)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_k=2)
    st = _state()
    for s in (5, 10, 15, 20):
        mgr.save(s, st)
    assert mgr.all_steps() == [15, 20]
    assert mgr.latest_step() == 20


def test_checkpoint_atomicity(tmp_path):
    """A stale tmp dir (simulated crash mid-save) never corrupts restore."""
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(1, st)
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_000002_999"),
                exist_ok=True)  # crashed half-written save
    assert mgr.latest_step() == 1
    mgr.restore(st)  # does not raise


def test_checkpoint_restore_with_sharding(tmp_path):
    """Elastic restore: arrays land with an explicitly-given sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mgr = CheckpointManager(str(tmp_path))
    st = _state()
    mgr.save(3, st)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), st)
    out = mgr.restore(st, sharding=sh)
    assert jax.tree.leaves(out)[0].sharding == NamedSharding(mesh, P())


# ------------------------------------------------------------ coordinator


def test_coordinator_detects_heartbeat_failure():
    co = Coordinator(4, heartbeat_timeout_s=10)
    for w in range(4):
        co.heartbeat(w, t=0.0)
    co.check_health(t=5.0)
    assert len(co.healthy_workers()) == 4
    co.heartbeat(0, 12.0)
    co.heartbeat(1, 12.0)
    co.heartbeat(2, 12.0)      # worker 3 silent
    co.check_health(t=12.0)
    assert co.workers[3].state == WorkerState.FAILED
    assert co.phase.value == "reshaping"


def test_coordinator_straggler_escalation():
    co = Coordinator(2, straggler_strikes=2)
    for i in range(10):
        co.report_step(0, t=i, step_time_s=1.0)
        co.report_step(1, t=i, step_time_s=1.0)
    co.report_step(1, t=11, step_time_s=5.0)
    assert co.workers[1].state == WorkerState.STRAGGLER
    co.report_step(1, t=12, step_time_s=5.0)
    assert co.workers[1].state == WorkerState.FAILED


def test_coordinator_elastic_plan():
    co = Coordinator(16)
    for w in (3, 7, 11):
        co._fail(co.workers[w], 0.0, "test")
    dp, members = co.plan_mesh(global_batch=256)
    assert dp <= 13 and 256 % dp == 0
    assert dp == 8           # largest power-of-two <= 13 dividing 256
    plan = co.resume_plan(256)
    assert plan["restore_latest_checkpoint"]


def test_coordinator_adaptive_checkpoint_cadence():
    co = Coordinator(2, ckpt_cadence_steps=100, min_cadence=10,
                     stable_steps=5)
    assert co.cadence == 100
    co._fail(co.workers[0], 0.0, "test")
    assert co.cadence == 50
    for i in range(5):
        co.report_step(1, t=i, step_time_s=1.0)
    assert co.cadence == 100


# ------------------------------------------------------------------ data


def test_pipeline_determinism_across_sharding():
    from repro.data.pipeline import DataConfig, SyntheticTokens
    cfg = DataConfig(vocab_size=512, seq_len=32, global_batch=8, seed=1)
    d = SyntheticTokens(cfg)
    g = d.global_batch(step=3)
    # shard views reassemble to the same global batch
    parts = [d.shard_batch(3, s, 4)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), g["tokens"])
    # and are reproducible
    np.testing.assert_array_equal(d.global_batch(3)["tokens"], g["tokens"])


def test_package_query_data_selection():
    from repro.data.selection import (CorpusSpec, selection_query,
                                      select_training_docs, synth_corpus)
    corpus = synth_corpus(CorpusSpec(num_docs=8000, seed=2))
    q = selection_query(corpus, token_budget=1.5e6,
                        domain_caps={"web": 9e5}, dup_budget=40.0)
    res = select_training_docs(corpus, q, d_f=20, alpha=1500)
    assert res.feasible
    assert q.check_package(corpus, res.idx, res.mult)
    toks = corpus["tokens"][res.idx].sum()
    assert 1.425e6 - 1 <= toks <= 1.5e6 + 1
    assert corpus["tok_web"][res.idx].sum() <= 9e5 + 1


# ------------------------------------------------------------- scheduler


def test_scheduler_respects_budgets_and_beats_fcfs():
    from repro.serving import PackageScheduler, Request
    cfg = get_config("qwen2-1.5b")
    rng = np.random.default_rng(0)
    reqs = [Request(i, int(rng.integers(16, 512)),
                    int(rng.integers(16, 256)),
                    float(rng.uniform(0.01, 1.0))) for i in range(200)]
    hbm = 2e9
    flops = 1e14
    sched = PackageScheduler(cfg, hbm_budget_bytes=hbm, flop_budget=flops,
                             max_batch=32)
    for r in reqs:
        sched.submit(r)
    batch = sched.tick()
    assert 0 < len(batch) <= 32
    assert sum(r.kv_bytes(cfg) for r in batch) <= hbm * (1 + 1e-6)
    assert sum(r.prefill_flops(cfg) for r in batch) <= flops * (1 + 1e-6)
    # FCFS baseline under the same budgets
    fcfs, kv, fl = [], 0.0, 0.0
    for r in reqs:
        if len(fcfs) < 32 and kv + r.kv_bytes(cfg) <= hbm \
                and fl + r.prefill_flops(cfg) <= flops:
            fcfs.append(r)
            kv += r.kv_bytes(cfg)
            fl += r.prefill_flops(cfg)
    assert sum(r.priority for r in batch) >= sum(r.priority for r in fcfs)


# ------------------------------------------------------------ compression


def test_gradient_compression_error_feedback():
    from repro.training.compression import compress_with_ef, ef_init
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    res = ef_init(g)
    total_in, total_out = jnp.zeros((64, 64)), jnp.zeros((64, 64))
    for _ in range(50):
        gq, res = compress_with_ef(g, res)
        total_in = total_in + g["w"]
        total_out = total_out + gq["w"]
    # error feedback: accumulated compressed grads track accumulated true
    # grads (residual stays bounded)
    err = jnp.abs(total_in - total_out).max()
    assert float(err) < 0.1 * float(jnp.abs(total_in).max())
