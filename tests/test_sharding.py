"""Sharding rules: divisibility fallbacks, cache pspecs, HLO analyzer."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.distributed.hlo_analysis import (collective_bytes, hlo_stats,
                                            shape_bytes)
from repro.distributed.sharding import make_rules
from repro.models import Model


@pytest.fixture(scope="module")
def rules16():
    # AbstractMesh: build shardings without 256 real devices
    from repro.launch.mesh import make_abstract_mesh
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    return make_rules(mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_pspecs_valid_for_all_archs(arch, rules16):
    """Every param gets a pspec whose sharded dims divide exactly, with no
    mesh axis used twice."""
    cfg = get_config(arch)
    model = Model(cfg)
    params = model.abstract_params()
    axes = model.axes()
    flat_p = jax.tree.leaves(params)
    flat_a = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    assert len(flat_p) == len(flat_a)
    n_tp = 0
    for p, ax in zip(flat_p, flat_a):
        spec = rules16.param_pspec(p.shape, ax)
        used = []
        for dim, entry in zip(p.shape, tuple(spec)):
            if entry is None:
                continue
            entries = entry if isinstance(entry, tuple) else (entry,)
            for e in entries:
                used.append(e)
                size = rules16.mesh.shape[e]
                assert dim % size == 0, (arch, p.shape, ax, spec)
            if "model" in entries:
                n_tp += 1
        assert len(used) == len(set(used)), (arch, spec)
    assert n_tp > 0, f"{arch}: no parameter is tensor-parallel"


def test_fsdp_shards_large_params(rules16):
    spec = rules16.param_pspec((1024, 4096), ("embed", "mlp"))
    # mlp -> model TP; largest remaining (1024) -> data FSDP
    assert tuple(spec) == ("data", "model")


def test_small_params_stay_replicated(rules16):
    spec = rules16.param_pspec((576,), ("embed",))
    assert tuple(spec) == (None,)


def test_nondivisible_dims_fall_back(rules16):
    # 9 heads on a 16-way model axis: falls back, never invalid
    spec = rules16.param_pspec((576, 9, 64), ("embed", "heads", "head"))
    for dim, entry in zip((576, 9, 64), tuple(spec)):
        if entry is not None:
            es = entry if isinstance(entry, tuple) else (entry,)
            for e in es:
                assert dim % rules16.mesh.shape[e] == 0


def test_cache_pspecs(rules16):
    # decode_32k style: B divisible -> B over dp, S over model
    spec = rules16.cache_pspec((40, 128, 32768, 2, 128), "kv")
    assert tuple(spec)[1] == "data"
    assert tuple(spec)[2] == "model"
    # long_500k style: B=1 -> S over (data, model)
    spec = rules16.cache_pspec((48, 1, 524288, 8, 64), "kv")
    assert tuple(spec)[1] is None
    assert "model" in tuple(spec)[2] and "data" in tuple(spec)[2]


def test_shape_applicability_matrix():
    """The 40-cell matrix: 34 runnable + 6 documented long_500k skips."""
    runnable = skipped = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert s.name == "long_500k"
                assert why
    assert runnable == 34 and skipped == 6


# ------------------------------------------------------------- HLO tools


def test_shape_bytes():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[2,3]") == 12
    assert shape_bytes("(f32[2], s32[4])") == 24
    assert shape_bytes("pred[]") == 1


def test_hlo_stats_counts_scanned_dots():
    L, d = 8, 64
    W = jnp.ones((L, d, d), jnp.float32)
    x = jnp.ones((4, d), jnp.float32)

    def f(x, W):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, W)[0]

    hlo = jax.jit(f).lower(x, W).compile().as_text()
    st = hlo_stats(hlo)
    assert st.flops == pytest.approx(L * 2 * 4 * d * d)


def test_collective_parser_on_sharded_module():
    mesh = jax.make_mesh((1,), ("x",))
    x = jnp.ones((8, 8))

    @jax.jit
    def f(a):
        return a.sum()

    hlo = f.lower(x).compile().as_text()
    stats = collective_bytes(hlo)   # no collectives on 1 device
    assert stats.total_bytes == 0.0
