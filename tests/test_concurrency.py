"""Deterministic race harness + thread-safety of the shared serving
path.

Three layers:

* the harness itself — a seeded :class:`ScheduleController` replays the
  SAME interleaving for the same seed, and a pinned known-bad schedule
  deterministically reproduces the duplicate-cold-solve race on an
  intentionally UNLOCKED cache double (what ``QCache.get_or_populate``
  would be without its claim protocol);
* the fixed implementations — ``QCache`` and ``BoundedStepCache`` pass
  every seeded schedule with exactly one cold solve per key, the fault
  injector keeps per-thread deterministic streams, and preemptive
  hammer tests hold the counter invariants;
* the serving integration — concurrent ``engine.solve`` sessions over a
  shared cache return the same packages as sequential solves, and the
  scheduler never loses a request under concurrent submits.
"""
import threading
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.distributed import BoundedStepCache
from repro.core.engine import PackageQueryEngine
from repro.core.hardness import Q2_TPCH, Q4_TPCH, column_stats, instantiate
from repro.core.qcache import QCache
from repro.data.synth_tables import make_table
from repro.runtime import faults, racecheck
from repro.runtime.racecheck import (Deadlock, InstrumentedLock,
                                     ScheduleController, run_threads)

ATTRS = ["price", "quantity", "discount", "tax"]
ILP_KW = dict(max_nodes=200, time_limit_s=15)


# ---------------------------------------------------- harness test doubles


class _UnlockedCacheDouble:
    """QCache.get_or_populate WITHOUT the claim protocol — the pre-fix
    shape.  Probe and store are separate unlocked steps, so two threads
    interleaved between them both run the cold solve."""

    def __init__(self):
        self.entries = {}
        self.solves = 0

    def get_or_populate(self, key, solve):
        racecheck.checkpoint("double.probe")
        if key in self.entries:
            return "hit", self.entries[key]
        racecheck.checkpoint("double.solve")
        v = solve()
        self.solves += 1
        racecheck.checkpoint("double.store")
        self.entries[key] = v
        return "solved", v


class _FakeHier:
    """Just enough hierarchy for QCache.store: a fingerprint, layer-1
    group ids, and a no-op invalidation hook."""

    def __init__(self, fingerprint="fp0"):
        self.fingerprint = fingerprint
        self.layers = {1: SimpleNamespace(
            part=SimpleNamespace(gid=np.zeros(64, np.int64)))}

    def add_invalidation_hook(self, fn):
        pass


class _Sig:
    def __init__(self, tag):
        self.tag = tag

    def __hash__(self):
        return hash(self.tag)

    def __eq__(self, other):
        return isinstance(other, _Sig) and self.tag == other.tag

    def contained_in(self, other):
        return self == other


# The pinned known-bad interleaving (decisions in consumption order):
# start->T0; T0 parks at its probe -> T1; T1 probes, solves, then parks
# at its store -> T0; T0 re-checks (store not yet published!), solves
# again.  Two cold solves for one key — the check-then-act race.
_BAD_SCHEDULE = [0, 1, 1, 1, 0, 0, 0]
# Fully serial: T0 runs to completion, T1 takes the hit.
_SERIAL_SCHEDULE = [0] * 16


def _double_case():
    cache = _UnlockedCacheDouble()

    def body():
        return cache.get_or_populate("k", lambda: "v")[0]

    return cache, [body, body]


def test_pinned_schedule_reproduces_unlocked_race():
    cache, fns = _double_case()
    ctl = ScheduleController(schedule=list(_BAD_SCHEDULE))
    kinds = ctl.run(fns)
    assert cache.solves == 2, \
        f"known-bad schedule must duplicate the cold solve; {ctl.trace}"
    assert kinds == ["solved", "solved"]


def test_serial_schedule_passes_unlocked_double():
    cache, fns = _double_case()
    kinds = ScheduleController(schedule=list(_SERIAL_SCHEDULE)).run(fns)
    assert cache.solves == 1
    assert sorted(kinds) == ["hit", "solved"]


def test_seeded_schedules_replay_exactly():
    """Same seed => same interleaving => same outcome; and the sweep
    finds at least one racy seed on the unlocked double (the pre-fix
    regression the fixed QCache must survive below)."""
    outcomes = {}
    for seed in range(24):
        runs = []
        for _ in range(2):
            cache, fns = _double_case()
            ctl = ScheduleController(seed=seed)
            ctl.run(fns)
            runs.append((cache.solves, tuple(ctl.trace)))
        assert runs[0] == runs[1], f"seed {seed} did not replay"
        outcomes[seed] = runs[0][0]
    assert set(outcomes.values()) == {1, 2}, \
        f"sweep should see both clean and racy interleavings: {outcomes}"


# --------------------------------------------------------- fixed QCache


def _qcache_case(n_threads=3):
    qc = QCache()
    hier = _FakeHier()
    sig = _Sig("q")
    solves = []

    def body():
        def solve():
            solves.append(1)
            qc.store("fp0", sig, hier=hier, cands={1: np.arange(8)},
                     layer_warms={}, dr_warm=None, lp_bound=1.0)
            return "cold"

        kind, _val = qc.get_or_populate("fp0", sig, solve)
        return kind

    return qc, solves, [body] * n_threads


@pytest.mark.parametrize("seed", range(12))
def test_qcache_get_or_populate_atomic_under_schedule(seed):
    """The fixed claim protocol: every seeded interleaving (including
    the class of the known-bad one above) runs exactly ONE cold solve;
    every other session takes the hit — no duplicate solves, no lost
    stores."""
    qc, solves, fns = _qcache_case()
    kinds = ScheduleController(seed=seed).run(fns, timeout_s=30)
    assert sum(solves) == 1, f"seed {seed}: duplicate cold solve"
    assert sorted(kinds) == ["hit", "hit", "solved"]
    assert len(qc) == 1
    st = qc.stats_snapshot()
    assert st.stores == 1 and st.hits >= 2


def test_qcache_populate_protocol_single_thread():
    qc = QCache()
    sig = _Sig("a")
    assert qc.begin_populate("fp", sig) is True
    assert qc.begin_populate("fp", sig) is False      # already claimed
    assert qc.wait_populate("fp", sig, timeout=0.01) is False
    qc.end_populate("fp", sig)
    assert qc.wait_populate("fp", sig, timeout=0.01) is True
    assert qc.begin_populate("fp", sig) is True       # claim reusable
    qc.end_populate("fp", sig)


def test_qcache_failed_solve_releases_claim():
    qc, _solves, _fns = _qcache_case()
    sig = _Sig("q")

    def boom():
        raise RuntimeError("cold solve died")

    with pytest.raises(RuntimeError):
        qc.get_or_populate("fp0", sig, boom)
    # the claim is released: the next caller becomes the owner
    assert qc.begin_populate("fp0", sig) is True
    qc.end_populate("fp0", sig)


def test_qcache_lock_stats_counters():
    qc, _solves, fns = _qcache_case()
    ScheduleController(seed=3).run(fns)
    ls = qc.lock_stats()
    assert ls["name"] == "qcache"
    assert ls["acquisitions"] > 0
    assert ls["wait_s"] >= 0.0 and ls["hold_s"] >= 0.0


# ------------------------------------------------------ BoundedStepCache


def test_step_cache_hammer_counter_invariant():
    """8 preemptive threads over 6 overlapping keys: each key is built
    exactly once (claim token), and hits + misses == lookups even under
    contention (unresolved waiter probes are never charged)."""
    cache = BoundedStepCache(maxsize=64)
    built = []
    build_lock = threading.Lock()

    def body(t):
        def run():
            out = []
            for rep in range(5):
                for k in range(6):
                    def factory(k=k):
                        with build_lock:
                            built.append(k)
                        return ("steps", k)

                    out.append(cache.get_or_create(("key", k), factory))
            return out

        return run

    results = run_threads([body(t) for t in range(8)])
    assert sorted(built) == list(range(6)), \
        f"every key must be built exactly once, got {built}"
    st = cache.stats()
    assert st["hits"] + st["misses"] == st["lookups"]
    assert st["misses"] == 6 and st["lookups"] == 8 * 5 * 6
    for out in results:
        assert out == [("steps", k) for _ in range(5) for k in range(6)]


def test_step_cache_atomic_under_schedules():
    cases = []

    def make_case():
        cache = BoundedStepCache(maxsize=8)
        built = []
        cases.append((cache, built))

        def body():
            return cache.get_or_create(
                "k", lambda: built.append(1) or "entry")

        return [body, body, body]

    ctls = racecheck.run_schedules(make_case, seeds=range(10))
    assert len(ctls) == len(cases) == 10
    for cache, built in cases:
        assert len(built) == 1                 # one build per schedule
        st = cache.stats()
        assert st["hits"] + st["misses"] == st["lookups"] == 3


# ------------------------------------------------------- fault injector


def test_faults_single_thread_stream_matches_legacy_seed():
    """Stream 0 is bit-identical to the pre-PR10 single-rng injector:
    single-threaded fault schedules (and every recorded experiment)
    reproduce exactly."""
    inj = faults.FaultInjector(seed=5)
    legacy = np.random.default_rng(5)
    assert np.allclose(inj.rng.random(8), legacy.random(8))
    assert inj.thread_index() == 0


def test_faults_two_thread_streams_deterministic():
    """Each thread gets its own deterministic stream: per-thread draw
    sequences equal the spawned SeedSequence streams regardless of
    interleaving, and per-thread fire budgets apply independently."""
    site = "test.site"

    def expected(idx, seed=9):
        ss = np.random.SeedSequence(seed) if idx == 0 \
            else np.random.SeedSequence(seed, spawn_key=(idx - 1,))
        return np.random.default_rng(ss).random(4)

    for trial in range(3):                     # stable across repeats
        inj = faults.FaultInjector(seed=9).arm(site, times=1)

        def body():
            fires = 0
            for _ in range(3):                 # budget is per-thread
                try:
                    inj.maybe_raise(site)
                except OSError:
                    fires += 1
            return inj.thread_index(), tuple(inj.rng.random(4)), fires

        out = run_threads([body, body])
        idxs = sorted(t[0] for t in out)
        assert idxs == [0, 1], "each thread must own a distinct stream"
        for idx, draws, fires in out:
            assert np.allclose(draws, expected(idx))
            assert fires == 1                  # times=1 PER THREAD
        assert inj.fire_count(site) == 2       # aggregate across streams
        assert sorted(s for _site, s, _k in inj.log) == [0, 1]


def test_faults_thread_scoped_injection_is_confined():
    site = "test.scoped"
    ev_armed = threading.Event()
    ev_checked = threading.Event()

    def armed_thread():
        with faults.injected(seed=1, arms={site: dict(times=1)},
                             scope="thread") as inj:
            with pytest.raises(OSError):
                inj.maybe_raise(site)
            ev_armed.set()
            assert ev_checked.wait(10)
            return inj.fire_count(site)

    def other_thread():
        assert ev_armed.wait(10)
        assert faults.get() is None            # activation never leaks
        faults.maybe_raise(site)               # must be a no-op
        ev_checked.set()
        return True

    fired, ok = run_threads([armed_thread, other_thread])
    assert fired == 1 and ok is True
    assert faults.get() is None


# -------------------------------------------------- instrumented locks


def test_instrumented_lock_contention_counters():
    lk = InstrumentedLock("bench")
    held = []

    def body():
        for _ in range(50):
            with lk:
                held.append(1)
        return True

    run_threads([body] * 4)
    st = lk.stats()
    assert st["acquisitions"] == 200 and len(held) == 200
    assert 0 <= st["contended"] <= 200
    assert st["wait_s"] >= 0.0 and st["hold_s"] >= 0.0
    lk.reset_stats()
    assert lk.stats()["acquisitions"] == 0


def test_controller_detects_self_deadlock():
    lk = InstrumentedLock("stuck")
    lk.acquire()                               # held by the main thread

    def body():
        with lk:
            return True

    with pytest.raises(Deadlock):
        ScheduleController(seed=0, max_switches=500).run([body],
                                                         timeout_s=5)
    lk.release()


# ------------------------------------------------- serving integration


@pytest.fixture(scope="module")
def dataset():
    table = make_table("tpch", 4_000, seed=1)
    return table, column_stats(table, ATTRS)


def _pkg(res):
    order = np.argsort(res.idx, kind="stable")
    return np.asarray(res.idx)[order], np.asarray(res.mult)[order]


def test_engine_concurrent_sessions_match_sequential(dataset):
    """Concurrent sessions over ONE shared engine + QCache return the
    same packages as sequential solves of the same queries."""
    table, stats = dataset
    queries = [instantiate(Q2_TPCH, stats, 2.0),
               instantiate(Q4_TPCH, stats, 2.0)]

    def build():
        eng = PackageQueryEngine(table, ATTRS, d_f=20, alpha=600,
                                 seed=0, cache=QCache())
        eng.partition()
        return eng

    seq = build()
    baseline = [seq.session(seed=100 + i).solve(q, ilp_kwargs=ILP_KW)
                for i, q in enumerate(queries)]
    assert all(r.feasible for r in baseline)

    conc = build()

    def body(i):
        def run():
            # two sessions per query, same seeds as the baseline pass
            return conc.session(seed=100 + (i % 2)).solve(
                queries[i % 2], ilp_kwargs=ILP_KW)

        return run

    results = run_threads([body(i) for i in range(4)], timeout_s=300)
    for i, res in enumerate(results):
        assert res.feasible, f"thread {i} infeasible: {res.status}"
        want_idx, want_mult = _pkg(baseline[i % 2])
        got_idx, got_mult = _pkg(res)
        assert np.array_equal(got_idx, want_idx)
        assert np.array_equal(got_mult, want_mult)
        # same package, so obj may differ only by summation order
        assert np.isclose(res.obj, baseline[i % 2].obj, rtol=1e-12)
    st = conc.cache.stats_snapshot()
    assert st.stores >= 1
    assert st.hits + st.misses >= len(results)


def test_scheduler_concurrent_submits_lose_nothing():
    from repro.configs import get_config
    from repro.serving import PackageScheduler, Request

    cfg = get_config("qwen2-1.5b")
    sched = PackageScheduler(cfg, hbm_budget_bytes=2e9, flop_budget=1e14,
                             max_batch=16)
    rng = np.random.default_rng(0)
    reqs = [[Request(t * 1000 + i, int(rng.integers(16, 256)),
                     int(rng.integers(16, 128)),
                     float(rng.uniform(0.01, 1.0))) for i in range(40)]
            for t in range(4)]

    admitted = []
    adm_lock = threading.Lock()

    def submitter(t):
        def run():
            for r in reqs[t]:
                sched.submit(r)
            return True

        return run

    def ticker():
        for _ in range(6):
            batch = sched.tick()
            with adm_lock:
                admitted.extend(r.rid for r in batch)
        return True

    run_threads([submitter(t) for t in range(4)] + [ticker, ticker],
                timeout_s=120)
    # drain what is left
    for _ in range(40):
        batch = sched.tick()
        with adm_lock:
            admitted.extend(r.rid for r in batch)
        if not batch and len(sched.queue) == 0:
            break
    all_rids = {r.rid for group in reqs for r in group}
    assert sorted(admitted) == sorted(all_rids), \
        "requests were lost or duplicated across concurrent submits"
    assert sched.admitted_total == len(all_rids)
    assert len(sched.queue) == 0 and len(sched._store) == 0
