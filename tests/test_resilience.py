"""Resilience: every Solve Guard promise is pinned by a forced failure.

Deterministic fault injection (``repro.runtime.faults``) drives each
degradation path the guard contract advertises — transient-read retries,
Binv drift recovery, budget preemption, dead-shard fallback, the
degradation ladder — and every test asserts the pipeline comes back with
a defined status instead of hanging or raising.
"""
import numpy as np
import pytest

from repro.core import guard
from repro.core import relation as relation_mod
from repro.core.bucketing import ArraySource
from repro.core.dual_reducer import dual_reducer
from repro.core.engine import PackageQueryEngine
from repro.core.hardness import TEMPLATES, column_stats, instantiate
from repro.core.lp import BUDGET, OPTIMAL, solve_lp, solve_lp_np
from repro.core.paql import Constraint, PackageQuery
from repro.core.relation import (MemmapRelation, SourceRelation,
                                 configure_retries)
from repro.data.synth_tables import make_table
from repro.runtime import faults

ILP_KW = dict(max_nodes=100, time_limit_s=10)


@pytest.fixture(autouse=True)
def fast_retries():
    old = configure_retries()
    configure_retries(base_s=1e-4, max_s=1e-3)
    yield
    configure_retries(**old)


def _mat(n=20, k=3):
    return np.arange(float(n * k)).reshape(n, k)


# ------------------------------------------------------- transient reads


def test_chunk_read_retry_recovers():
    X = _mat()
    rel = MemmapRelation(X, ["a", "b", "c"], chunk_rows=5)
    with faults.injected(seed=1,
                         arms={faults.CHUNK_READ: dict(times=2)}) as inj:
        got = np.vstack(list(rel.chunks()))
    np.testing.assert_allclose(got, X)
    assert inj.fire_count(faults.CHUNK_READ) == 2


def test_chunk_read_retry_gives_up():
    rel = MemmapRelation(_mat(), ["a", "b", "c"], chunk_rows=5)
    with faults.injected(seed=1,
                         arms={faults.CHUNK_READ: dict(times=None)}):
        with pytest.raises(OSError, match="giving up after 4 attempts"):
            list(rel.chunks())


def test_gather_read_retry_recovers():
    X = _mat()
    rel = MemmapRelation(X, ["a", "b", "c"])
    idx = np.array([7, 0, 13, 7])
    with faults.injected(seed=2,
                         arms={faults.GATHER_READ: dict(times=1)}) as inj:
        out = rel.gather_rows(idx, ("b",))["b"]
    np.testing.assert_allclose(out, X[idx, 1])
    assert inj.fire_count(faults.GATHER_READ) == 1


def test_backoff_capped_and_deterministic(monkeypatch):
    """Delays follow min(max_s, base_s * 2^k) with seeded jitter — the
    schedule is capped and replays identically."""
    configure_retries(tries=4, base_s=0.1, max_s=0.15, seed=5)
    rel = MemmapRelation(_mat(), ["a", "b", "c"], chunk_rows=100)

    def _delays():
        slept = []
        monkeypatch.setattr(relation_mod.time, "sleep", slept.append)
        with faults.injected(seed=1,
                             arms={faults.CHUNK_READ: dict(times=3)}):
            list(rel.chunks())
        return slept

    d1, d2 = _delays(), _delays()
    assert d1 == d2                      # deterministic replay
    rng = np.random.default_rng(5)
    exp = [min(0.15, 0.1 * 2.0 ** k) * (0.5 + rng.random())
           for k in range(3)]
    np.testing.assert_allclose(d1, exp)
    assert max(d1) <= 0.15 * 1.5 + 1e-12  # capped


def test_flaky_source_scan_delivers_rows_exactly_once():
    X = _mat(23, 3)
    src = faults.FlakySource(ArraySource(X), fail_chunks=(1,), fail_times=2)
    rel = SourceRelation(src, ["a", "b", "c"], chunk_rows=4)
    got = np.vstack(list(rel.chunks()))
    np.testing.assert_allclose(got, X)
    assert src.raised == 2


def test_flaky_source_scan_gives_up():
    src = faults.FlakySource(ArraySource(_mat()), fail_chunks=(0,),
                             fail_times=99)
    rel = SourceRelation(src, ["a", "b", "c"], chunk_rows=4)
    with pytest.raises(OSError, match="source scan: giving up"):
        list(rel.chunks())


# -------------------------------------------------- numerical health / LP


def _random_lp(seed, n=160, m=6):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = rng.integers(1, 4, size=n).astype(float)
    x0 = rng.uniform(0, 1, n) * ub
    act = A @ x0
    width = np.abs(rng.normal(size=m)) * 2
    bl = act - width * rng.uniform(0, 1, m)
    bu = act + width * rng.uniform(0, 1, m)
    return c, A, bl, bu, ub


def test_binv_perturbation_detected_and_recovered():
    """An injected Binv corruption trips the drift monitor, forces a
    refactorization, and the solve still reaches the clean optimum."""
    c, A, bl, bu, ub = _random_lp(7, n=240, m=14)
    clean = solve_lp_np(c, A, bl, bu, ub)
    assert clean.status == OPTIMAL and clean.iters > 20
    mon = guard.NumericalMonitor(drift_check_every=4)
    with faults.injected(seed=0, arms={faults.BINV: dict(times=2, after=1,
                                                         scale=1e-2)}) as inj:
        res = solve_lp_np(c, A, bl, bu, ub, monitor=mon)
    assert inj.fire_count(faults.BINV) >= 1
    assert res.status == OPTIMAL
    assert mon.drift_refactors >= 1
    assert abs(res.obj - clean.obj) <= 1e-6 * (1 + abs(clean.obj))


def test_budget_pivot_truncation_is_reported():
    c, A, bl, bu, ub = _random_lp(4)
    b = guard.SolveBudget(max_pivots=3).start()
    res = solve_lp_np(c, A, bl, bu, ub, budget=b)
    assert res.status == BUDGET
    assert any(n.startswith("budget:") for n in res.notes)
    assert b.pivots_spent > 0


def test_budget_deadline_preempts_lp():
    c, A, bl, bu, ub = _random_lp(5)
    b = guard.SolveBudget(deadline_s=0.0).start()
    res = solve_lp(c, A, bl, bu, ub, budget=b)
    assert res.status == BUDGET
    res_np = solve_lp_np(c, A, bl, bu, ub, budget=b)
    assert res_np.status == BUDGET


def test_warm_start_rejection_is_surfaced():
    c, A, bl, bu, ub = _random_lp(6)
    m, n = A.shape
    bad = (np.zeros(m, np.int64), np.zeros(n + m, bool))  # duplicate basis
    res = solve_lp_np(c, A, bl, bu, ub, warm_start=bad)
    assert res.status == OPTIMAL
    assert any("warm_start_rejected" in note for note in res.notes)


def test_dist_shard_fault_falls_back_to_single_host():
    jax = pytest.importorskip("jax")
    from repro.core.distributed import solve_lp_dist
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    c, A, bl, bu, ub = _random_lp(7)
    ref = solve_lp_np(c, A, bl, bu, ub)
    with faults.injected(seed=0,
                         arms={faults.SHARD: dict(times=1)}) as inj:
        res = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh)
    assert inj.fire_count(faults.SHARD) == 1
    assert any("single_host_fallback" in note for note in res.notes)
    assert res.pivot_stats.get("fallback") == 1
    assert res.status == ref.status == OPTIMAL
    assert abs(res.obj - ref.obj) <= 1e-6 * (1 + abs(ref.obj))


# ------------------------------------------------------ degradation ladder


def _dr_query(lo=10, hi=20):
    return PackageQuery("obj", maximize=True, constraints=(
        Constraint(None, lo, hi), Constraint("a", lo=4.5 * lo, hi=5.5 * hi)))


def test_dual_reducer_degraded_rounding_rung(monkeypatch):
    """With the sub-ILP solver dead, the ladder's terminal rung rounds
    and repairs the LP relaxation instead of failing dry."""
    from repro.core import ilp as ilp_mod

    def _dead_ilp(*a, **k):
        n = len(a[0])
        return ilp_mod.ILPResult(ilp_mod.ILP_LIMIT, np.zeros(n), np.inf,
                                 0, 0.0)

    monkeypatch.setattr("repro.core.dual_reducer.ilp_mod.solve_ilp",
                        _dead_ilp)
    rng = np.random.default_rng(0)
    table = {"obj": rng.normal(10, 3, 2000), "a": rng.normal(5, 1, 2000)}
    q = _dr_query()
    report = guard.SolveReport(budget=guard.SolveBudget(),
                               monitor=guard.NumericalMonitor())
    res = dual_reducer(q, table, np.arange(2000), q=50,
                       budget=report.budget, report=report)
    assert res.feasible
    assert res.status == "degraded_rounded"
    assert "degraded_rounded" in report.fallbacks
    assert q.check_package(table, res.idx, res.mult)


def test_dual_reducer_no_ladder_fails_dry(monkeypatch):
    from repro.core import ilp as ilp_mod

    def _dead_ilp(*a, **k):
        n = len(a[0])
        return ilp_mod.ILPResult(ilp_mod.ILP_LIMIT, np.zeros(n), np.inf,
                                 0, 0.0)

    monkeypatch.setattr("repro.core.dual_reducer.ilp_mod.solve_ilp",
                        _dead_ilp)
    rng = np.random.default_rng(0)
    table = {"obj": rng.normal(10, 3, 500), "a": rng.normal(5, 1, 500)}
    res = dual_reducer(_dr_query(), table, np.arange(500), q=50,
                       ladder=False)
    assert not res.feasible
    assert res.status == "ilp_infeasible"


# --------------------------------------------------------- engine contract


def _memmap_engine(n=2000, seed=0):
    t = make_table("tpch", n, seed=seed)
    attrs = ["price", "quantity", "discount", "tax"]
    X = np.stack([np.asarray(t[a], np.float64) for a in attrs], axis=1)
    rel = MemmapRelation(X, attrs, chunk_rows=max(n // 7, 16))
    eng = PackageQueryEngine(rel, attrs, d_f=8, alpha=300, seed=seed)
    eng._stats = column_stats(t, attrs)  # stats off the resident dict
    return eng


def _query(eng, h=2.0, template="Q2_TPCH"):
    return instantiate(TEMPLATES[template], eng._stats, h)


@pytest.mark.parametrize("site,arm", [
    (faults.CHUNK_READ, dict(times=2)),
    (faults.GATHER_READ, dict(times=None, prob=0.3)),
    (faults.BINV, dict(times=3, after=1, scale=1e-3)),
    (faults.SHARD, dict(times=1)),
])
def test_engine_never_raises_under_faults(site, arm):
    """The guard contract: under injected faults every engine.solve
    returns a report with a defined status — no hangs, no exceptions."""
    eng = _memmap_engine()
    eng.partition()
    q = _query(eng)
    with faults.injected(seed=3, arms={site: arm}):
        res = eng.solve(q, ilp_kwargs=ILP_KW)
    assert res.report is not None
    assert res.report.status in guard.STATUSES
    if res.feasible:
        assert q.check_package(eng.table, res.idx, res.mult)


def test_engine_reports_fault_retries():
    eng = _memmap_engine()
    eng.partition()
    q = _query(eng)
    with faults.injected(seed=3,
                         arms={faults.GATHER_READ: dict(times=3)}) as inj:
        res = eng.solve(q, ilp_kwargs=ILP_KW)
    assert inj.fire_count(faults.GATHER_READ) == 3
    assert res.report.fault_retries >= 3
    assert res.report.status in (guard.OK, guard.DEGRADED)


def test_engine_budget_exhaustion_has_defined_status():
    eng = _memmap_engine()
    eng.partition()
    q = _query(eng, h=9.0)
    b = guard.SolveBudget(max_pivots=1)
    res = eng.solve(q, ilp_kwargs=ILP_KW, budget=b)
    r = res.report
    assert r.status in guard.STATUSES
    # the cascade must have either descended on budget or stopped with
    # the budget status — never a silent full-effort run
    assert ("budget_descend" in r.fallbacks
            or r.status in (guard.BUDGET_EXHAUSTED, guard.DEGRADED))
    assert b.pivots_spent <= 64  # floor-granularity slack, not a full run


def test_engine_contains_unexpected_errors():
    eng = _memmap_engine()
    eng.partition()
    q = _query(eng)

    def _boom(*a, **k):
        raise ValueError("synthetic pipeline bug")

    import repro.core.engine as engine_mod
    orig = engine_mod.progressive_shading
    engine_mod.progressive_shading = _boom
    try:
        res = eng.solve(q)
    finally:
        engine_mod.progressive_shading = orig
    assert res.report.status == guard.ERROR
    assert not res.feasible
    assert any("synthetic pipeline bug" in note for note in res.report.notes)
    with pytest.raises(ValueError):
        engine_mod.progressive_shading = _boom
        try:
            eng.solve(q, guarded=False)
        finally:
            engine_mod.progressive_shading = orig
