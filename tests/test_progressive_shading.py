"""End-to-end Progressive Shading: solvability, package validity,
integrality gap, comparison against direct ILP and SketchRefine, and the
paper's hardness machinery (Table 1 regression)."""
import numpy as np
import pytest

from repro.core.engine import PackageQueryEngine
from repro.core.hardness import (Q1_SDSS, Q2_TPCH, column_stats, instantiate,
                                 ndtri)
from repro.data.synth_tables import make_table

ILP_KW = dict(max_nodes=200, time_limit_s=15)


@pytest.fixture(scope="module")
def sdss_engine():
    table = make_table("sdss", 30_000, seed=3)
    attrs = ["tmass_prox", "j", "h", "k"]
    eng = PackageQueryEngine(table, attrs, d_f=20, alpha=1500, seed=0)
    eng.partition()
    return eng, table, column_stats(table, attrs)


def test_hierarchy_shape(sdss_engine):
    eng, _, _ = sdss_engine
    H = eng.hierarchy
    assert H.layers[0].size == 30_000
    assert H.layers[-1].size <= eng.alpha
    for l in range(1, H.L + 1):
        # downscale per layer within a sane band around d_f
        f = H.layers[l - 1].size / H.layers[l].size
        assert 2 <= f <= eng.d_f * 4


@pytest.mark.parametrize("h", [1, 3, 5, 7])
def test_ps_solves_and_validates(sdss_engine, h):
    eng, table, stats = sdss_engine
    q = instantiate(Q1_SDSS, stats, h)
    res = eng.solve(q, ilp_kwargs=ILP_KW)
    assert res.feasible, res.status
    assert q.check_package(table, res.idx, res.mult)
    # multiplicities are positive ints within REPEAT+1
    assert np.all(res.mult >= 1) and np.all(res.mult <= q.repeat + 1)
    # package size within COUNT bounds
    assert 15 <= res.mult.sum() <= 45


@pytest.mark.parametrize("h", [1, 5])
def test_ps_integrality_gap_close_to_lp(sdss_engine, h):
    """Paper §4.2: PS integrality gap stays close to 1 (min query)."""
    eng, table, stats = sdss_engine
    q = instantiate(Q1_SDSS, stats, h)
    res = eng.solve(q, ilp_kwargs=ILP_KW)
    lp = eng.lp_bound(q)
    assert res.feasible and np.isfinite(lp)
    gap = (abs(res.obj) + 0.1) / (abs(lp) + 0.1)
    assert 1.0 - 1e-9 <= gap <= 1.10, gap


def test_ps_beats_or_matches_sketchrefine(sdss_engine):
    """Paper Fig. 8: PS objective is at least as good as SketchRefine's
    (minimisation: lower is better), and SR may fail where PS succeeds."""
    eng, table, stats = sdss_engine
    q = instantiate(Q1_SDSS, stats, 3)
    ps = eng.solve(q, ilp_kwargs=ILP_KW)
    sr = eng.solve_sketchrefine(q, ilp_kwargs=ILP_KW)
    assert ps.feasible
    if sr.feasible:
        assert ps.obj <= sr.obj * 1.02 + 0.5


def test_tpch_maximization():
    table = make_table("tpch", 20_000, seed=4)
    attrs = ["price", "quantity", "discount", "tax"]
    stats = column_stats(table, attrs)
    eng = PackageQueryEngine(table, attrs, d_f=20, alpha=1500, seed=0)
    eng.partition()
    q = instantiate(Q2_TPCH, stats, 5)
    res = eng.solve(q, ilp_kwargs=ILP_KW)
    assert res.feasible
    assert q.check_package(table, res.idx, res.mult)
    lp = eng.lp_bound(q)
    assert res.obj <= lp + 1e-6          # LP is an upper bound (max query)
    assert res.obj >= 0.9 * lp           # and we get close to it


# ---------------------------------------------------- hardness machinery


def test_ndtri_accuracy():
    # spot values of the inverse normal CDF
    assert ndtri(0.5) == pytest.approx(0.0, abs=1e-12)
    assert ndtri(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert ndtri(0.9) == pytest.approx(1.2815516, abs=1e-6)
    assert ndtri(1e-6) == pytest.approx(-4.753424, abs=1e-5)


def test_hardness_reproduces_paper_table1():
    """Bounds for Q1 SDSS at h̃=1 and h̃=3 match the published Table 1."""
    stats = {"j": (14.82, 1.562), "h": (14.05, 1.657), "k": (13.73, 1.727),
             "tmass_prox": (14.45, 14.96)}
    q1 = instantiate(Q1_SDSS, stats, 1)
    b = {c.attr: c for c in q1.constraints if c.attr}
    assert b["j"].lo == pytest.approx(445.37, abs=0.05)
    assert b["h"].hi == pytest.approx(420.68, abs=0.05)
    assert b["k"].lo == pytest.approx(406.04, abs=0.05)
    assert b["k"].hi == pytest.approx(417.76, abs=0.05)
    q3 = instantiate(Q1_SDSS, stats, 3)
    b3 = {c.attr: c for c in q3.constraints if c.attr}
    assert b3["j"].lo == pytest.approx(455.56, abs=0.05)
    assert b3["h"].hi == pytest.approx(409.87, abs=0.05)


def test_hardness_monotone():
    """Higher h̃ shrinks the feasible region monotonically."""
    stats = {"j": (14.82, 1.562), "h": (14.05, 1.657), "k": (13.73, 1.727),
             "tmass_prox": (14.45, 14.96)}
    prev_lo, prev_width = -np.inf, np.inf
    for h in (1, 3, 5, 7, 9):
        q = instantiate(Q1_SDSS, stats, h)
        b = {c.attr: c for c in q.constraints if c.attr}
        assert b["j"].lo >= prev_lo
        width = b["k"].hi - b["k"].lo
        assert width <= prev_width
        prev_lo, prev_width = b["j"].lo, width
