"""End-to-end system behaviour: training loss decreases, crash/resume is
bit-deterministic, serving completes, hierarchy+engine integration."""

import numpy as np
import pytest


def _run_train(args):
    from repro.launch import train as train_mod
    return train_mod.main(args)


def test_training_loss_decreases(tmp_path):
    losses = _run_train(["--arch", "smollm-135m-smoke", "--steps", "25",
                         "--batch", "4", "--seq", "64", "--lr", "3e-3",
                         "--log-every", "50"])
    assert losses[-1] < losses[0] - 0.05


def test_crash_resume_is_deterministic(tmp_path):
    ck = str(tmp_path / "ck")
    # uninterrupted reference
    ref = _run_train(["--arch", "smollm-135m-smoke", "--steps", "14",
                      "--batch", "4", "--seq", "64", "--log-every", "50"])
    # crash at step 9 then resume
    with pytest.raises(SystemExit):
        _run_train(["--arch", "smollm-135m-smoke", "--steps", "14",
                    "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                    "--ckpt-every", "5", "--fail-at", "9",
                    "--log-every", "50"])
    resumed = _run_train(["--arch", "smollm-135m-smoke", "--steps", "14",
                          "--batch", "4", "--seq", "64", "--ckpt-dir", ck,
                          "--ckpt-every", "5", "--log-every", "50"])
    # the final losses agree exactly (same batches, same state)
    assert resumed[-1] == pytest.approx(ref[-1], abs=1e-6)


def test_microbatched_grad_accumulation_matches():
    """2 microbatches ~= single batch step (same data, same update)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import Model
    from repro.training.optimizer import OptHyper
    from repro.training.step import init_train_state, make_train_step
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-135m").smoke(),
                              param_dtype="float32")
    model = Model(cfg)
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 32)), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    h = OptHyper(lr=1e-3)
    s0 = init_train_state(model, jax.random.PRNGKey(0))
    s1, m1 = jax.jit(make_train_step(model, h, microbatches=1))(s0, batch)
    s0b = init_train_state(model, jax.random.PRNGKey(0))
    s2, m2 = jax.jit(make_train_step(model, h, microbatches=2))(s0b, batch)
    p1 = jax.tree.leaves(s1["params"])[0]
    p2 = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(np.asarray(p1, np.float32),
                               np.asarray(p2, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_compressed_training_still_learns():
    losses = _run_train(["--arch", "smollm-135m-smoke", "--steps", "20",
                         "--batch", "4", "--seq", "64", "--lr", "3e-3",
                         "--compress-grads", "--log-every", "50"])
    assert losses[-1] < losses[0] - 0.03


def test_serving_end_to_end():
    from repro.launch import serve as serve_mod
    done = serve_mod.main(["--arch", "smollm-135m-smoke",
                           "--requests", "8", "--ticks", "4"])
    assert len(done) == 8
