"""Batched bound-variant LP engine (core.lp_batch): parity with the
sequential twin (cold and warm, down to the basis), exact freezing of
masked-done lanes, W-wave B&B equivalence, budget salvage mid-batch and
bounded compile-class counts."""
import numpy as np
import pytest

from repro.core.guard import NumericalMonitor, SolveBudget
from repro.core.ilp import ILP_LIMIT, ILP_OPTIMAL, solve_ilp
from repro.core.lp import BUDGET, OPTIMAL, solve_lp_np, verify_optimality
from repro.core.lp_batch import (batch_cache_stats, batch_stats,
                                 solve_lp_batch)


def _flight(seed, K=5, n=24, m=3):
    """One shared (c, A, bl, bu) plus K feasible bound-variants."""
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = rng.integers(1, 4, size=n).astype(float)
    x0 = rng.uniform(0, 1, n) * ub
    act = A @ x0
    width = np.abs(rng.normal(size=m)) * 2 + 0.5
    bl = act - width
    bu = act + width
    ubs = [ub * rng.uniform(0.5, 1.0, n) for _ in range(K)]
    lbs = [np.zeros(n) for _ in range(K)]
    return c, A, bl, bu, ubs, lbs


def _assert_lane_parity(res, ref, lane=""):
    assert res.status == ref.status, lane
    if ref.status == OPTIMAL:
        assert res.obj == pytest.approx(ref.obj, abs=1e-9), lane
        assert res.iters == ref.iters, lane
        assert np.array_equal(np.sort(res.basis), np.sort(ref.basis)), lane
        assert np.array_equal(res.at_upper, ref.at_upper), lane
        np.testing.assert_allclose(res.x, ref.x, atol=1e-9)


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_batched_matches_sequential_cold(seed):
    """jax-batched flight == per-lane solve_lp_np, pivot for pivot: same
    status, objective, iteration count, basis and bound pattern."""
    c, A, bl, bu, ubs, lbs = _flight(seed)
    ress = solve_lp_batch(c, A, bl, bu, ubs, lbs, backend="jax")
    for k, (u, l) in enumerate(zip(ubs, lbs)):
        ref = solve_lp_np(c, A, bl, bu, u, lb=l)
        _assert_lane_parity(ress[k], ref, lane=f"lane {k}")
        if ref.status == OPTIMAL:
            ok, msg = verify_optimality(ress[k], c, A, bl, bu, u, lb=l)
            assert ok, msg


def test_batched_matches_sequential_warm(seed=7):
    """Per-lane warm bases reproduce the sequential warm solves (the
    padded-space basis remap preserves the pivot sequence)."""
    c, A, bl, bu, ubs, _ = _flight(seed, K=4)
    base = solve_lp_np(c, A, bl, bu, np.max(ubs, axis=0))
    assert base.status == OPTIMAL
    warms = [base] * len(ubs)
    ress = solve_lp_batch(c, A, bl, bu, ubs, warm_starts=warms,
                          backend="jax")
    for k, u in enumerate(ubs):
        ref = solve_lp_np(c, A, bl, bu, u, warm_start=base)
        _assert_lane_parity(ress[k], ref, lane=f"lane {k}")


def test_backend_np_is_bit_compatible():
    """The sequential fallback routes through solve_lp_np verbatim."""
    c, A, bl, bu, ubs, lbs = _flight(2, K=3)
    ress = solve_lp_batch(c, A, bl, bu, ubs, lbs, backend="np")
    for k, (u, l) in enumerate(zip(ubs, lbs)):
        ref = solve_lp_np(c, A, bl, bu, u, lb=l)
        assert ress[k].status == ref.status
        assert ress[k].obj == ref.obj
        assert ress[k].iters == ref.iters
        assert np.array_equal(ress[k].x, ref.x)
        assert ress[k].notes == ref.notes


def test_masked_done_lane_frozen_exactly():
    """A lane that converges early is frozen by the per-lane select: its
    answer is bit-identical whether its neighbors pivot on for 1 or 100
    more iterations (here: solved alone vs. in a mixed flight)."""
    rng = np.random.default_rng(4)
    n, m = 30, 3
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = np.ones(n)
    act = A @ (0.5 * ub)
    bl, bu = act - 1.0, act + 1.0
    # lane 0: trivially-done variant (all bounds pinned to 0 feasible only
    # if box allows; use a tiny box so it converges in very few pivots)
    ub_fast = np.full(n, 1e-3)
    blf = np.minimum(bl, A @ np.zeros(n))
    alone = solve_lp_batch(c, A, blf, bu, [ub_fast], backend="np")[0]
    mixed = solve_lp_batch(c, A, blf, bu, [ub_fast, ub, ub * 0.7,
                                           ub * 0.4], backend="jax")
    assert mixed[0].status == alone.status
    if alone.status == OPTIMAL:
        assert mixed[0].obj == pytest.approx(alone.obj, abs=1e-12)
        assert mixed[0].iters == alone.iters
        assert np.array_equal(np.sort(mixed[0].basis),
                              np.sort(alone.basis))
    # and the slow lanes still match their sequential references
    for k, u in [(1, ub), (2, ub * 0.7), (3, ub * 0.4)]:
        ref = solve_lp_np(c, A, blf, bu, u)
        _assert_lane_parity(mixed[k], ref, lane=f"lane {k}")


def test_wave_bb_matches_node_loop():
    """W=1 (sequential fallback) is the legacy node loop; W>1 waves must
    find the same optimum on a tight-window instance, and the wave
    engine's incumbents stay integral/feasible."""
    rng = np.random.default_rng(9)
    n = 60
    vals = rng.normal(10, 2, n)
    c = rng.normal(size=n)
    A = np.stack([np.ones(n), vals])
    bl = np.array([5.0, 57.0])
    bu = np.array([9.0, 63.0])
    r1 = solve_ilp(c, A, bl, bu, np.ones(n), wave_width=1)
    r4 = solve_ilp(c, A, bl, bu, np.ones(n), wave_width=4)
    r16 = solve_ilp(c, A, bl, bu, np.ones(n), wave_width=16,
                    batch_backend="jax")
    assert r1.feasible and r1.status == ILP_OPTIMAL
    for r in (r4, r16):
        assert r.feasible and r.status == ILP_OPTIMAL
        assert r.obj == pytest.approx(r1.obj, abs=1e-9)
        assert np.array_equal(r.x, r1.x)
        act = A @ r.x
        assert np.all(act >= bl - 1e-6) and np.all(act <= bu + 1e-6)
    # W=1 is deterministic: running it twice is bit-identical
    r1b = solve_ilp(c, A, bl, bu, np.ones(n), wave_width=1)
    assert r1b.nodes == r1.nodes and r1b.lp_iters == r1.lp_iters
    assert np.array_equal(r1b.x, r1.x)


def test_budget_exhaustion_mid_batch_salvages_incumbent():
    """Pivot budget dies mid-search: the wave B&B returns the best
    incumbent found so far (ILP_LIMIT + feasible), and a batched flight
    under an exhausted budget reports BUDGET instead of hanging."""
    rng = np.random.default_rng(9)
    n = 60
    vals = rng.normal(10, 2, n)
    c = rng.normal(size=n)
    A = np.stack([np.ones(n), vals])
    bl = np.array([5.0, 57.0])
    bu = np.array([9.0, 63.0])
    full = solve_ilp(c, A, bl, bu, np.ones(n), wave_width=8,
                     batch_backend="jax")
    assert full.status == ILP_OPTIMAL
    budget = SolveBudget(max_pivots=200).start()
    r = solve_ilp(c, A, bl, bu, np.ones(n), wave_width=8,
                  batch_backend="jax", budget=budget)
    assert r.status in (ILP_LIMIT, ILP_OPTIMAL)
    if r.feasible:   # salvaged incumbent must be genuinely feasible
        act = A @ r.x
        assert np.all(act >= bl - 1e-6) and np.all(act <= bu + 1e-6)
        assert np.all(np.abs(r.x - np.round(r.x)) < 1e-9)
    assert budget.pivots_spent > 0
    # flight under an already-dead budget: immediate BUDGET lanes
    dead = SolveBudget(max_pivots=1)
    dead.charge_pivots(5)
    ress = solve_lp_batch(c, A, bl, bu, [np.ones(n)] * 3, budget=dead,
                          backend="jax")
    assert all(res.status == BUDGET for res in ress)


def test_budget_charged_as_sum_of_lane_pivots():
    c, A, bl, bu, ubs, lbs = _flight(5, K=4)
    budget = SolveBudget(max_pivots=100_000).start()
    mon = NumericalMonitor()
    ress = solve_lp_batch(c, A, bl, bu, ubs, lbs, budget=budget,
                          monitor=mon, backend="jax")
    assert budget.pivots_spent >= sum(r.iters for r in ress)


def test_compile_classes_bounded_across_K():
    """Varying K inside one pow2 class reuses the executable: growing a
    flight from 5 to 8 lanes must not recompile (no per-K recompile)."""
    c, A, bl, bu, ubs, lbs = _flight(1, K=8)
    before = batch_cache_stats()
    solve_lp_batch(c, A, bl, bu, ubs[:5], lbs[:5], backend="jax")
    mid = batch_cache_stats()
    solve_lp_batch(c, A, bl, bu, ubs[:6], lbs[:6], backend="jax")
    solve_lp_batch(c, A, bl, bu, ubs[:7], lbs[:7], backend="jax")
    solve_lp_batch(c, A, bl, bu, ubs[:8], lbs[:8], backend="jax")
    after = batch_cache_stats()
    assert mid["misses"] >= before["misses"]      # first solve may compile
    assert after["misses"] == mid["misses"]       # K=6,7,8 share K_pad=8
    assert after["hits"] >= mid["hits"] + 3
    assert after["size"] <= after["maxsize"]
    assert batch_stats()["dispatches"] >= 4


def test_empty_and_single_flights():
    c, A, bl, bu, ubs, lbs = _flight(6, K=1)
    assert solve_lp_batch(c, A, bl, bu, []) == []
    # K=1 on auto routes through the numpy twin (bit-compatible)
    res = solve_lp_batch(c, A, bl, bu, ubs, lbs)[0]
    ref = solve_lp_np(c, A, bl, bu, ubs[0], lb=lbs[0])
    assert res.status == ref.status and res.obj == ref.obj
    assert res.iters == ref.iters


def test_box_infeasible_lane_decided_on_host():
    c, A, bl, bu, ubs, lbs = _flight(8, K=3)
    lbs = [l.copy() for l in lbs]
    lbs[1][:] = 2.0          # lb > ub: box-infeasible lane
    ress = solve_lp_batch(c, A, bl, bu, ubs, lbs, backend="jax")
    from repro.core.lp import INFEASIBLE
    assert ress[1].status == INFEASIBLE
    for k in (0, 2):
        ref = solve_lp_np(c, A, bl, bu, ubs[k], lb=lbs[k])
        _assert_lane_parity(ress[k], ref, lane=f"lane {k}")


def test_warm_rejection_per_lane():
    """An out-of-range warm basis falls cold for ITS lane only, with the
    PR-1 rejection note; the other lanes keep their warm starts."""
    c, A, bl, bu, ubs, _ = _flight(10, K=3)
    base = solve_lp_np(c, A, bl, bu, np.max(ubs, axis=0))
    assert base.status == OPTIMAL
    from repro.core.lp import WarmStart
    bad = WarmStart(np.full(A.shape[0], 10_000, np.int64), None)
    ress = solve_lp_batch(c, A, bl, bu, ubs,
                          warm_starts=[base, bad, base], backend="jax")
    assert any(n.startswith("warm_start_rejected")
               for n in ress[1].notes), ress[1].notes
    for k in (0, 2):
        assert not any(n.startswith("warm_start_rejected")
                       for n in ress[k].notes)
        ref = solve_lp_np(c, A, bl, bu, ubs[k], warm_start=base)
        _assert_lane_parity(ress[k], ref, lane=f"lane {k}")
