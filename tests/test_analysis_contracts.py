"""The IR contract layer: the real hot paths satisfy their contracts on
the host-device mesh grid, and each checker actually fires on a seeded
violation (tiny budget, f64 promotion, callback-in-loop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core  # noqa: F401  (x64 on, before any tracing)
from repro.analysis import contracts
from repro.analysis.contracts import (callback_prims, check_lp_batch,
                                      check_lp_twin, check_pq_step,
                                      check_refresh_step, check_update_step,
                                      collective_prims, dense_dot_counts,
                                      f64_introductions,
                                      pq_collective_budget, run_contracts)


def _mesh():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    return jax.make_mesh((1, 2), ("data", "model"))


# ------------------------------------------------------- jaxpr primitives


def test_f64_introduction_detector():
    f = lambda x: x.astype(jnp.float64) * 2.0
    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4,), jnp.float32)).jaxpr
    assert "convert_element_type" in f64_introductions(jx)
    g = lambda x: x * 2.0
    jx = jax.make_jaxpr(g)(jax.ShapeDtypeStruct((4,), jnp.float32)).jaxpr
    assert f64_introductions(jx) == []


def test_collective_prims_found_through_shard_map():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = _mesh()
    f = shard_map(lambda x: jax.lax.psum(x, "model"), mesh=mesh,
                  in_specs=P("model"), out_specs=P())
    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((4,), jnp.float64)).jaxpr
    # jax versions the primitive name (psum -> psum2): match the family
    assert any(p.startswith("psum") for p, _ in collective_prims(jx))


def test_callback_prims_context_includes_while():
    def f(x):
        def body(c):
            y = jax.pure_callback(
                lambda v: np.asarray(v) * 2, jax.ShapeDtypeStruct((), x.dtype),
                c)
            return y
        return jax.lax.while_loop(lambda c: c < 10.0, body, x)

    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((), jnp.float64)).jaxpr
    found = callback_prims(jx)
    assert found and any("while" in ctx for _, ctx in found)


def test_dense_dot_counts_top_vs_cond():
    def f(A, x):
        top = A @ x
        return jax.lax.cond(top.sum() > 0, lambda _: A @ x,
                            lambda _: jnp.zeros_like(top), None)

    jx = jax.make_jaxpr(f)(jax.ShapeDtypeStruct((64, 64), jnp.float64),
                           jax.ShapeDtypeStruct((64,), jnp.float64)).jaxpr
    top, cond = dense_dot_counts(jx, 64 * 64)
    assert (top, cond) == (1, 1)


# ----------------------------------------------------- hot-path contracts


def test_update_step_lowers_with_zero_collectives():
    r = check_update_step(_mesh(), m=8, n=1 << 12)
    assert r.ok, [v.format() for v in r.violations]
    assert r.record["collective_counts"] == {}
    assert r.record["dense_passes"] == {"top": 0, "cond": 0}


def test_pq_step_within_declared_budget():
    r = check_pq_step(_mesh(), m=8, n=1 << 12)
    assert r.ok, [v.format() for v in r.violations]
    assert 0 < r.record["budget_used_frac"] < 1
    assert r.record["dense_passes"]["top"] == 1


def test_refresh_step_is_the_recompute_site():
    r = check_refresh_step(_mesh(), m=8, n=1 << 12)
    assert r.ok, [v.format() for v in r.violations]
    assert 1 <= r.record["dense_passes"]["top"] <= 2


def test_lp_twin_clean_and_trip_bounded():
    r = check_lp_twin(m=4, N=64, max_iters=32)
    assert r.ok, [v.format() for v in r.violations]
    # the pivot body is scatter-free (one-hot selects, stable-sort rank
    # compare), so the only inner while loops left are the LU sweeps of
    # the refresh factorization — bound by m, never by N or max_iters
    assert r.record["max_trip"] == 4


def test_lp_batch_core_clean():
    r = check_lp_batch(m=4, n=16, K=4, max_iters=16)
    assert r.ok, [v.format() for v in r.violations]
    # single-device batch: the record must carry the while trip bounds
    assert r.record["max_trip"] > 0


def test_budget_formula_scales_with_p():
    assert pq_collective_budget(512, 8) > pq_collective_budget(2, 8)
    # O(1) in n by construction: n does not appear in the signature


def test_seeded_budget_violation_fires(monkeypatch):
    monkeypatch.setattr(contracts, "pq_collective_budget",
                        lambda *a, **k: 1.0)
    r = check_pq_step(_mesh(), m=8, n=1 << 12)
    assert any(v.rule == "IRC004" for v in r.violations)


def test_run_contracts_host_grid_green():
    violations, records, wall_s = run_contracts("host")
    assert violations == [], "\n".join(v.format() for v in violations)
    names = {r["hot_path"].split("@")[0] for r in records}
    assert {"distributed.pq_step", "distributed.update_step",
            "distributed.refresh_step", "lp.twin_step", "lp_batch.core",
            "kernels.pricing", "kernels.segstats",
            "partitioner.descend_batch"} <= names
    assert wall_s > 0
