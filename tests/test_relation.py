"""Relation abstraction: chunked scans, sorted-index gathers, lazy
columns, and the query-path integration (matrices / validation) that keeps
out-of-core solves candidate-resident."""
import numpy as np
import pytest

from repro.core.bucketing import ArraySource
from repro.core.paql import Constraint, PackageQuery
from repro.core.relation import (ArrayRelation, CountingSource, LazyColumn,
                                 MemmapRelation, SourceRelation, as_relation,
                                 gather_column)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    n = 5000
    table = {
        "v": rng.normal(10, 2, n),
        "w": rng.uniform(0.5, 2.0, n),
        "ok": (rng.random(n) < 0.5).astype(np.float64),
    }
    X = np.stack([table["v"], table["w"], table["ok"]], axis=1)
    return table, X


@pytest.fixture(scope="module")
def mm_rel(tmp_path_factory, data):
    _, X = data
    path = str(tmp_path_factory.mktemp("rel") / "rel.npy")
    np.save(path, X)
    return MemmapRelation.from_npy(path, ["v", "w", "ok"], chunk_rows=700)


def test_array_relation_is_zero_copy_dict_adapter(data):
    table, _ = data
    rel = ArrayRelation(table)
    assert rel.in_memory and rel.num_rows == len(table["v"])
    assert rel["v"] is table["v"]            # raw column, no copy
    assert "w" in rel and "nope" not in rel
    view = rel.gather_rows(np.array([3, 1, 4]), ("v", "w"))
    np.testing.assert_array_equal(view["v"], table["v"][[3, 1, 4]])


@pytest.mark.parametrize("names", [None, ("w", "v")])
def test_chunks_cover_relation_in_order(data, mm_rel, names):
    table, X = data
    got = np.concatenate(list(mm_rel.chunks(names, 700)))
    cols = names or ("v", "w", "ok")
    want = np.stack([table[nm] for nm in cols], axis=1)
    np.testing.assert_array_equal(got, want)


def test_gather_rows_restores_caller_order(data, mm_rel):
    table, _ = data
    rng = np.random.default_rng(1)
    idx = rng.choice(mm_rel.num_rows, 300, replace=True)  # unsorted, dupes
    view = mm_rel.gather_rows(idx, ("v", "ok"))
    np.testing.assert_array_equal(view["v"], table["v"][idx])
    np.testing.assert_array_equal(view["ok"], table["ok"][idx])


def test_source_relation_generic_gather_matches_memmap(data, mm_rel):
    _, X = data
    rel = SourceRelation(ArraySource(X), ["v", "w", "ok"], chunk_rows=700)
    idx = np.array([4999, 0, 700, 699, 701, 0])
    a = rel.gather_rows(idx, ("v", "w"))
    b = mm_rel.gather_rows(idx, ("v", "w"))
    np.testing.assert_array_equal(a["v"], b["v"])
    np.testing.assert_array_equal(a["w"], b["w"])


def test_gather_rows_out_of_range_raises(mm_rel):
    with pytest.raises(IndexError):
        mm_rel.chunk_source()  # touch nothing yet
        SourceRelation(ArraySource(np.zeros((10, 3))), ["v", "w", "ok"]) \
            .gather_rows(np.array([11]))
    with pytest.raises(IndexError):
        SourceRelation(ArraySource(np.zeros((10, 3))), ["v", "w", "ok"]) \
            .gather_rows(np.array([-1]))


def test_lazy_column_gathers_but_never_materialises(data, mm_rel):
    table, _ = data
    col = mm_rel["v"]
    assert isinstance(col, LazyColumn)
    assert len(col) == mm_rel.num_rows
    np.testing.assert_array_equal(col[np.array([5, 2, 5])],
                                  table["v"][[5, 2, 5]])
    assert col[7] == pytest.approx(table["v"][7])
    with pytest.raises(RuntimeError, match="refusing to materialise"):
        np.asarray(col)


def test_boolean_mask_selects_rows(data, mm_rel):
    """Boolean masks behave like the dict-column idiom, not 0/1 ids."""
    table, _ = data
    mask = table["ok"] > 0
    np.testing.assert_array_equal(mm_rel["v"][mask], table["v"][mask])
    view = mm_rel.gather_rows(mask, ("v",))
    np.testing.assert_array_equal(view["v"], table["v"][mask])
    np.testing.assert_array_equal(gather_column(mm_rel, "v", mask),
                                  gather_column(table, "v", mask))
    with pytest.raises(IndexError, match="boolean mask"):
        mm_rel.gather_rows(mask[:10], ("v",))


def test_memmap_gather_rejects_negative_ids(mm_rel):
    """Negative ids raise instead of silently wrapping to the tail."""
    with pytest.raises(IndexError, match="negative"):
        mm_rel.gather_rows(np.array([3, -1]), ("v",))
    with pytest.raises(IndexError):
        mm_rel.gather_rows(np.array([mm_rel.num_rows]), ("v",))


def test_gather_column_uniform_helper(data, mm_rel):
    table, _ = data
    idx = np.array([10, 3, 3, 4998])
    np.testing.assert_array_equal(gather_column(table, "w", idx),
                                  table["w"][idx])
    np.testing.assert_array_equal(gather_column(mm_rel, "w", idx),
                                  table["w"][idx])


def test_as_relation_coercions(data, mm_rel):
    table, X = data
    assert as_relation(mm_rel) is mm_rel
    assert isinstance(as_relation(table), ArrayRelation)
    r = as_relation(ArraySource(X), columns=["v", "w", "ok"])
    assert isinstance(r, MemmapRelation)      # 2-D array source fast path
    with pytest.raises(ValueError):
        as_relation(CountingSource(ArraySource(X)))  # needs column names


def test_reduce_columns_streams(mm_rel, data):
    table, _ = data
    hi = mm_rel.reduce_columns(("v", "w"), lambda c: c.max(axis=0),
                               np.maximum)
    np.testing.assert_allclose(hi, [table["v"].max(), table["w"].max()])


# ------------------------------------------------ query-path integration


@pytest.fixture(scope="module")
def query():
    return PackageQuery("v", maximize=True,
                        constraints=(Constraint(None, 5, 15),
                                     Constraint("w", hi=20.0),
                                     Constraint("w", lo=0.0,
                                                avg_target=1.8)),
                        predicate_attr="ok")


def test_matrices_subset_parity_dict_vs_relation(data, mm_rel, query):
    table, _ = data
    idx = np.random.default_rng(2).choice(5000, 400, replace=False)
    got = query.matrices(mm_rel, idx)
    want = query.matrices(table, idx)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w)


def test_matrices_full_streamed_parity(data, mm_rel, query):
    got = query.matrices(mm_rel, None)
    want = query.matrices(data[0], None)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w)


def test_matrices_full_size_guard(mm_rel, query, monkeypatch):
    from repro.core import paql
    monkeypatch.setattr(paql, "FULL_MATRIX_BUDGET_BYTES", 1024)
    with pytest.raises(ValueError, match="size guard|engine.solve|budget"):
        query.matrices(mm_rel, None)


def test_check_package_and_objective_stream(data, mm_rel, query):
    table, _ = data
    ok_rows = np.flatnonzero(table["ok"] > 0)
    idx = ok_rows[np.argsort(-table["v"][ok_rows])[:10]]
    mult = np.ones(10)
    assert query.check_package(mm_rel, idx, mult) == \
        query.check_package(table, idx, mult)
    assert query.objective_value(mm_rel, idx, mult) == \
        pytest.approx(query.objective_value(table, idx, mult))


def test_counting_source_counts_passes(data):
    _, X = data
    src = CountingSource(ArraySource(X))
    for _ in src.chunks(700):
        pass
    for _ in src.chunks(700):
        pass
    assert src.passes == 2
    assert src.rows_read == 2 * len(X)
