import os
import sys

# Give the CPU test host virtual devices BEFORE jax first initializes so
# the distributed-pricing parity tests can build real 1x2 / 2x2 meshes.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.hostdev import ensure_host_devices  # noqa: E402

ensure_host_devices()

import numpy as np
import pytest

# The core engine enables jax x64 at import; import it first so every test
# module sees the same (production) numeric configuration regardless of
# collection order.
import repro.core  # noqa: F401


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def strict_numerics():
    """Fail the test on ANY implicit host<->device transfer and on NaNs
    escaping jitted code.  The engine's contract (REPRO003 / IRC003) is
    that every transfer around the hot paths is explicit — jnp.asarray /
    device_put on the way in, device_get on the way out — so the jitted
    LP twin and the distributed-pricing paths must pass under a full
    transfer guard."""
    import jax
    with jax.transfer_guard("disallow"), jax.debug_nans(True):
        yield
