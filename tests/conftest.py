import numpy as np
import pytest

# The core engine enables jax x64 at import; import it first so every test
# module sees the same (production) numeric configuration regardless of
# collection order.
import repro.core  # noqa: F401


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
