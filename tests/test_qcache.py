"""Cross-query artifact cache (repro.core.qcache) + its engine wiring.

Covers the PR-8 contract: canonical signatures (reorder-identity,
containment), exact-hit package reuse with validation, the artifact-only
and contained/pre-prune paths, gap-gated fallback parity, leaf-local
append invalidation, LRU eviction, fingerprint stability, warm-start
rejection observability, and the bounded distributed step cache.
"""
import numpy as np
import pytest

from repro.core.engine import PackageQueryEngine
from repro.core.hardness import Q2_TPCH, Q4_TPCH, column_stats, instantiate
from repro.core.paql import Constraint, PackageQuery
from repro.core.qcache import QCache
from repro.data.synth_tables import make_table

ATTRS = ["price", "quantity", "discount", "tax"]
ILP_KW = dict(max_nodes=200, time_limit_s=15)
N = 12_000
D_F = 20
ALPHA = 800


@pytest.fixture(scope="module")
def dataset():
    table = make_table("tpch", N, seed=1)
    stats = column_stats(table, ATTRS)
    return table, stats


def _engine(table, cache=None, seed=0):
    eng = PackageQueryEngine(table, ATTRS, d_f=D_F, alpha=ALPHA,
                             seed=seed, cache=cache)
    eng.partition()
    return eng


def _pkg(res):
    order = np.argsort(res.idx, kind="stable")
    return np.asarray(res.idx)[order], np.asarray(res.mult)[order]


def _same_package(a, b):
    ia, ma = _pkg(a)
    ib, mb = _pkg(b)
    return np.array_equal(ia, ib) and np.array_equal(ma, mb)


# ------------------------------------------------------------ signatures


def test_signature_reorder_identity():
    cts = (Constraint(None, 2, 10), Constraint("price", 5.0, 50.0),
           Constraint("tax", 0.0, 1.0, avg_target=0.5))
    q1 = PackageQuery("price", True, cts)
    q2 = PackageQuery("price", True, cts[::-1])
    assert q1.signature() == q2.signature()
    assert q1.signature().digest() == q2.signature().digest()


def test_signature_containment(dataset):
    _, stats = dataset
    prime = instantiate(Q2_TPCH, stats, 2.0).signature()
    tight = instantiate(Q2_TPCH, stats, 3.0).signature()
    wide = instantiate(Q2_TPCH, stats, 1.0).signature()
    disjoint = instantiate(Q4_TPCH, stats, 2.0).signature()
    assert tight.contained_in(prime)
    assert tight.contained_in(tight)            # reflexive
    assert not prime.contained_in(tight)        # widening never contained
    assert not wide.contained_in(prime)
    assert not disjoint.contained_in(prime)     # different structure
    assert not prime.contained_in(disjoint)


def test_signature_digest_process_stable():
    q = PackageQuery("price", True, (Constraint(None, 2, 10),))
    d = q.signature().digest()
    assert d == q.signature().digest()
    assert len(d) == 40                         # sha1 hex, not hash()
    q2 = PackageQuery("price", True, (Constraint(None, 2, 11),))
    assert q2.signature().digest() != d


# ------------------------------------------------------- hit/parity paths


def test_exact_hit_package_parity_and_counters(dataset):
    table, stats = dataset
    q = instantiate(Q2_TPCH, stats, 2.0)
    cache = QCache()
    eng = _engine(table, cache=cache)
    r1 = eng.solve(q, ilp_kwargs=ILP_KW)
    r2 = eng.solve(q, ilp_kwargs=ILP_KW)
    assert r1.feasible and r2.feasible
    assert "cached=package" in r2.status
    assert _same_package(r1, r2) and r1.obj == r2.obj
    assert cache.stats.exact_hits == 1 and cache.stats.misses == 1
    assert cache.stats.stores == 1 and cache.stats.bytes > 0
    assert r2.report.cache_hits == 1 and r2.report.cache_pruned_lps > 0
    assert r1.report.cache_misses == 1
    assert "cache=" in r2.report.summary()
    assert r2.ps_stats is not None and r2.ps_stats.cache == "package"


def test_artifact_only_mode_parity(dataset):
    table, stats = dataset
    q = instantiate(Q2_TPCH, stats, 2.0)
    cache = QCache(reuse_packages=False)
    eng = _engine(table, cache=cache)
    r1 = eng.solve(q, ilp_kwargs=ILP_KW)
    r2 = eng.solve(q, ilp_kwargs=ILP_KW)
    assert "cached=exact" in r2.status          # re-solved, not replayed
    assert _same_package(r1, r2)
    assert r2.report.cache_pruned_lps > 0


def test_contained_hit_prune_accepted(dataset):
    table, stats = dataset
    cache = QCache(gap_accept=2.0)              # lenient: prune accepted
    eng = _engine(table, cache=cache)
    q_prime = instantiate(Q2_TPCH, stats, 2.0)
    q_tight = instantiate(Q2_TPCH, stats, 3.0)
    r0 = eng.solve(q_prime, ilp_kwargs=ILP_KW)
    assert r0.feasible
    r1 = eng.solve(q_tight, ilp_kwargs=ILP_KW)
    assert r1.feasible
    assert "cached=contained" in r1.status
    assert cache.stats.contained_hits == 1
    # a pruned solve is still a *valid* package with a monotone bound
    assert q_tight.check_package(table, r1.idx, r1.mult)
    assert r1.lp_obj <= r0.lp_obj + 1e-6 * max(1.0, abs(r0.lp_obj))


def test_gap_rejected_prune_falls_back_with_parity(dataset):
    table, stats = dataset
    cache = QCache(gap_accept=-1.0)             # reject every prune
    eng = _engine(table, cache=cache)
    q_prime = instantiate(Q2_TPCH, stats, 2.0)
    q_tight = instantiate(Q2_TPCH, stats, 3.0)
    eng.solve(q_prime, ilp_kwargs=ILP_KW)
    r1 = eng.solve(q_tight, ilp_kwargs=ILP_KW)
    r_cold = _engine(table).solve(q_tight, ilp_kwargs=ILP_KW)
    assert "cached" not in r1.status
    assert "cache_fallback" in r1.report.fallbacks
    assert cache.stats.fallbacks == 1
    assert _same_package(r1, r_cold) and r1.obj == r_cold.obj
    # the fallback cold solve re-populated the tightened entry cleanly
    r2 = eng.solve(q_tight, ilp_kwargs=ILP_KW)
    assert "cached=package" in r2.status and _same_package(r1, r2)


def test_poisoned_entry_falls_back_with_parity(dataset):
    table, stats = dataset
    q = instantiate(Q2_TPCH, stats, 2.0)
    cache = QCache()
    eng = _engine(table, cache=cache)
    r1 = eng.solve(q, ilp_kwargs=ILP_KW)
    (_, _, entry), = cache.entries()
    entry.package_obj += 1e9                    # poison: validation fails
    entry.lp_bound += 1e9
    r2 = eng.solve(q, ilp_kwargs=ILP_KW)
    assert "cached" not in r2.status
    assert "cache_fallback" in r2.report.fallbacks
    assert _same_package(r1, r2) and r1.obj == r2.obj


# ------------------------------------------------ invalidation + appends


def test_append_invalidates_exactly_touched_ancestry(dataset):
    table, stats = dataset
    q = instantiate(Q2_TPCH, stats, 2.0)
    cache = QCache()
    eng = _engine(table, cache=cache)
    r0 = eng.solve(q, ilp_kwargs=ILP_KW)
    assert r0.feasible
    (_, _, entry), = cache.entries()
    hier = eng.hierarchy
    before = {l: set(entry.group_ids(l)) for l in range(1, hier.L + 1)}
    assert entry.complete and all(before[l] for l in before)

    # package-colocated rows guarantee at least one cached leaf is hit
    rows = {a: np.asarray(table[a][r0.idx[:7]], np.float64)
            for a in ATTRS}
    rep = hier.append(rows)
    touched = np.unique(rep.gids)
    ancestors = hier.leaf_ancestors(touched)
    assert np.array_equal(ancestors[1], touched)

    assert not entry.complete
    for l in range(1, hier.L + 1):
        removed = before[l] - set(entry.group_ids(l))
        expected = before[l] & set(int(g) for g in ancestors[l])
        assert removed == expected, (l, removed, expected)
        if removed:
            assert entry.candidates(l) is None
    total_removed = sum(len(before[l] - set(entry.group_ids(l)))
                        for l in before)
    assert cache.stats.invalidated_groups == total_removed > 0

    # an incomplete entry never serves hits again: stale miss
    misses0, stale0 = cache.stats.misses, cache.stats.stale_misses
    assert cache.lookup(hier.fingerprint, q.signature()) is None
    assert cache.stats.stale_misses == stale0 + 1
    assert cache.stats.misses == misses0 + 1


def test_cached_vs_cold_parity_after_append(dataset):
    table, stats = dataset
    q = instantiate(Q2_TPCH, stats, 2.0)
    cache = QCache()
    eng = _engine(table, cache=cache)
    r0 = eng.solve(q, ilp_kwargs=ILP_KW)
    assert r0.feasible
    # rows colocated with the package's own tuples land in cached leaf
    # groups by construction, so this append MUST invalidate the entry
    eng.hierarchy.append({a: np.asarray(table[a][r0.idx[:3]], np.float64)
                          for a in ATTRS})
    (_, _, entry), = cache.entries()
    assert not entry.complete
    r1 = eng.solve(q, ilp_kwargs=ILP_KW)        # stale -> cold, re-store
    r_cold = _engine(table).solve(q, ilp_kwargs=ILP_KW)
    assert "cached" not in r1.status
    assert _same_package(r1, r_cold) and r1.obj == r_cold.obj
    r2 = eng.solve(q, ilp_kwargs=ILP_KW)        # re-populated entry hits
    assert "cached=package" in r2.status and _same_package(r1, r2)


def test_fingerprint_stable_across_rebuilds(dataset):
    table, _ = dataset
    h1 = _engine(table).hierarchy.fingerprint
    h2 = _engine(table).hierarchy.fingerprint
    assert h1 == h2
    eng3 = PackageQueryEngine(table, ATTRS, d_f=D_F + 5, alpha=ALPHA,
                              seed=0)
    eng3.partition()
    assert eng3.hierarchy.fingerprint != h1


# ----------------------------------------------------- eviction + bounds


def test_lru_eviction_by_bytes(dataset):
    table, stats = dataset
    cache = QCache(max_bytes=1)                 # everything over budget
    eng = _engine(table, cache=cache)
    q_a = instantiate(Q2_TPCH, stats, 2.0)
    q_b = instantiate(Q4_TPCH, stats, 1.0)      # disjoint: its own entry
    assert eng.solve(q_a, ilp_kwargs=ILP_KW).feasible
    assert len(cache) == 1                      # sole entry survives
    assert eng.solve(q_b, ilp_kwargs=ILP_KW).feasible
    assert len(cache) == 1 and cache.stats.evictions == 1
    # q_a was evicted: solving it again is a miss, not a hit
    hits0 = cache.stats.hits
    r = eng.solve(q_a, ilp_kwargs=ILP_KW)
    assert r.feasible and "cached" not in r.status
    assert cache.stats.hits == hits0
    assert cache.stats.bytes <= max(e.nbytes for _, _, e
                                    in cache.entries()) + 1


# -------------------------------------------------- warm-start telemetry


def test_warm_rejected_surfaced(dataset, monkeypatch):
    import repro.core.shading as shading_mod
    table, stats = dataset
    q = instantiate(Q2_TPCH, stats, 2.0)
    monkeypatch.setattr(shading_mod, "fill_warm_basis",
                        lambda *a, **k: None)   # every re-map rejects
    eng = _engine(table)
    res = eng.solve(q, ilp_kwargs=ILP_KW)
    assert res.feasible
    assert res.ps_stats.warm_rejected > 0
    assert res.report.warm_rejected > 0
    assert "warm_rejected" in res.report.summary()
    assert any("warm_map_rejected" in n for n in res.report.notes)


# ------------------------------------------------ distributed step cache


def test_bounded_step_cache_counters():
    from repro.core.distributed import (STEP_CACHE_MAXSIZE,
                                        BoundedStepCache, _STEP_CACHE,
                                        step_cache_stats)
    c = BoundedStepCache(maxsize=2)
    made = []
    for key in ("a", "b", "a", "c", "b"):       # LRU 'b' evicted by 'c'
        c.get_or_create(key, lambda k=key: made.append(k) or k.upper())
    assert made == ["a", "b", "c", "b"]
    assert c.hits == 1 and c.misses == 4 and c.evictions == 2
    assert len(c) == 2
    assert c.stats() == {"hits": 1, "misses": 4, "evictions": 2,
                         "lookups": 5, "size": 2, "maxsize": 2}
    assert c.hits + c.misses == c.lookups
    c.clear()
    assert len(c) == 0
    # module-level cache: bounded, stats exposed
    assert _STEP_CACHE.maxsize == STEP_CACHE_MAXSIZE == 64
    assert set(step_cache_stats()) == {"hits", "misses", "evictions",
                                       "lookups", "size", "maxsize"}
