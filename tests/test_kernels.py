"""Per-kernel shape/dtype sweeps vs the ref.py pure-jnp oracles
(interpret=True executes kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (bfrt_select_op, flash_attention_op,
                               pricing_op, segment_stats_op)
from repro.kernels.ref import bfrt_sequential_ref


@pytest.mark.parametrize("m,n,block", [(3, 1000, 256), (8, 3000, 512),
                                       (1, 257, 128), (16, 4096, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_pricing_kernel(m, n, block, dtype, rng):
    A = jnp.asarray(rng.normal(size=(m, n)), dtype)
    rho = jnp.asarray(rng.normal(size=m), dtype)
    d = jnp.asarray(rng.normal(size=n), dtype)
    state = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    lo = jnp.zeros(n, dtype)
    hi = jnp.asarray(rng.uniform(1, 3, n), dtype)
    for s in (1.0, -1.0):
        a1, r1, c1 = pricing_op(A, rho, d, state, lo, hi, s, block=block)
        a2, r2, c2 = ref.pricing_ref(A, rho, d, state, lo, hi, s)
        tol = 1e-5 if dtype == jnp.float32 else 1e-10
        np.testing.assert_allclose(a1, a2, rtol=tol, atol=tol)
        np.testing.assert_allclose(np.where(np.isfinite(r1), r1, -1),
                                   np.where(np.isfinite(r2), r2, -1),
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(c1, c2, rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [300, 2048, 5000])
@pytest.mark.parametrize("frac_elig", [0.05, 0.5])
def test_bfrt_select_matches_sequential(n, frac_elig, rng):
    ratio = np.where(rng.random(n) < frac_elig,
                     rng.uniform(0, 10, n), np.inf)
    cost = np.where(np.isfinite(ratio), rng.uniform(0.1, 2, n), 0.0)
    for budget in (0.5, 10.0, 100.0):
        q1, f1, ok1 = bfrt_select_op(jnp.asarray(ratio), jnp.asarray(cost),
                                     budget)
        q2, f2, ok2 = bfrt_sequential_ref(ratio, cost, budget)
        assert bool(ok1) == ok2
        if ok2:
            assert int(q1) == q2
            np.testing.assert_array_equal(np.asarray(f1), f2)


def test_bfrt_dual_unbounded(rng):
    """Total flip capacity below budget => no crossing (infeasible LP)."""
    n = 500
    ratio = np.where(rng.random(n) < 0.1, rng.uniform(0, 1, n), np.inf)
    cost = np.where(np.isfinite(ratio), 0.01, 0.0)
    _, _, ok = bfrt_select_op(jnp.asarray(ratio), jnp.asarray(cost), 1e9)
    assert not bool(ok)


@pytest.mark.parametrize("n,k,G,block", [(1000, 1, 11, 128),
                                         (5000, 4, 57, 256),
                                         (777, 2, 9, 512)])
def test_segstats_kernel(n, k, G, block, rng):
    ids = np.sort(rng.integers(0, G, n)).astype(np.int32)
    vals = rng.normal(size=(n, k)).astype(np.float32)
    c1, s1, q1 = segment_stats_op(jnp.asarray(vals), jnp.asarray(ids), G,
                                  block=block)
    c2, s2, q2 = ref.segment_stats_ref(vals, ids, G)
    np.testing.assert_allclose(c1, c2, atol=1e-3)
    np.testing.assert_allclose(s1, s2, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(q1, q2, rtol=2e-3, atol=2e-3)


def test_segstats_builds_representatives(rng):
    """count/sum/sumsq -> means and variances (the DLV rep builder)."""
    n, k, G = 4000, 3, 40
    ids = np.sort(rng.integers(0, G, n)).astype(np.int32)
    vals = rng.normal(size=(n, k)).astype(np.float32)
    cnt, sm, sq = segment_stats_op(jnp.asarray(vals), jnp.asarray(ids), G)
    cnt = np.maximum(np.asarray(cnt), 1)
    means = np.asarray(sm) / cnt[:, None]
    for g in range(0, G, 7):
        mask = ids == g
        if mask.sum():
            np.testing.assert_allclose(means[g], vals[mask].mean(0),
                                       rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("S,blk", [(128, 64), (256, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(S, blk, causal, window, dtype, rng):
    B, H, KV, d = 2, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, d)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, d)), dtype)
    o1 = flash_attention_op(q, k, v, causal=causal, window=window,
                            block_q=blk, block_k=blk)
    kx = jnp.repeat(k, H // KV, axis=2)
    vx = jnp.repeat(v, H // KV, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = kx.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = vx.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    o2 = ref.attention_ref(qf, kf, vf, causal=causal, window=window)
    o2 = np.asarray(o2, np.float32).reshape(B, H, S, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(o1, np.float32), o2,
                               rtol=tol, atol=tol)


def test_flash_matches_model_chunked_attention(rng):
    """Kernel vs the pure-XLA chunked scan used by the dry-run path."""
    from repro.models.attention import chunked_attention
    B, S, H, KV, d = 1, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, d)), jnp.float32)
    pos = jnp.arange(S)
    o_scan = chunked_attention(q, k, v, pos, pos, causal=True, chunk=32)
    o_kern = flash_attention_op(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_scan), np.asarray(o_kern),
                               rtol=2e-3, atol=2e-3)
