"""Warm-start contract: warm-started solves return the same
objective/certificate as cold solves (all three twins), across the
dual_reducer auxiliary-LP path, an added-columns shading-style case, and
the progressive-shading cascade; invalid warm bases fall back to cold.

These are seed-parametrised property tests so they run even without
hypothesis; a hypothesis-widened sweep is added when it is installed.
"""
import importlib.util

import numpy as np
import pytest

from repro.core.lp import (OPTIMAL, WarmStart, solve_lp, solve_lp_np,
                           verify_optimality)
from repro.core.lp_kernel import solve_lp_kernel

HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _random_lp(seed, one_sided=True):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 60))
    m = int(rng.integers(1, 6))
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = rng.integers(1, 4, size=n).astype(float)
    x0 = rng.uniform(0, 1, n) * ub
    act = A @ x0
    width = np.abs(rng.normal(size=m)) * 2
    bl = act - width * rng.uniform(0, 1, m)
    bu = act + width * rng.uniform(0, 1, m)
    if one_sided:
        for i in range(m):
            r = rng.random()
            if r < 0.2:
                bl[i] = -np.inf
            elif r < 0.3:
                bu[i] = np.inf
    return c, A, bl, bu, ub


TWINS = [("np", solve_lp_np), ("jax", solve_lp), ("kernel", solve_lp_kernel)]


@pytest.mark.parametrize("name,solver",
                         TWINS, ids=[t[0] for t in TWINS])
def test_warm_restart_from_own_basis(name, solver):
    """Re-solving from a solve's own final basis is optimal immediately
    with the same objective and a valid certificate."""
    seeds = range(12) if name == "np" else range(6)
    for seed in seeds:
        c, A, bl, bu, ub = _random_lp(seed)
        cold = solver(c, A, bl, bu, ub)
        if cold.status != OPTIMAL:
            continue
        warm = solver(c, A, bl, bu, ub, warm_start=cold)
        assert warm.status == OPTIMAL
        assert warm.obj == pytest.approx(cold.obj, rel=1e-6, abs=1e-6)
        ok, msg = verify_optimality(warm, c, A, bl, bu, ub)
        assert ok, (seed, msg)
        assert warm.iters <= 2, (seed, warm.iters)


@pytest.mark.parametrize("name,solver",
                         TWINS, ids=[t[0] for t in TWINS])
def test_warm_tightened_ub_matches_cold(name, solver):
    """Dual Reducer auxiliary-LP shape: same LP with tightened upper
    bounds, warm-started from the loose solve's basis (the textbook
    dual-simplex warm start).  Same optimum as cold, fewer total iters."""
    seeds = range(15) if name == "np" else range(6)
    warm_total = cold_total = compared = 0
    for seed in seeds:
        c, A, bl, bu, ub = _random_lp(seed, one_sided=False)
        lp1 = solver(c, A, bl, bu, ub)
        if lp1.status != OPTIMAL:
            continue
        E = float(np.sum(lp1.x))
        ub_aux = np.minimum(ub, max(E / 7.0, 1e-9))
        cold = solver(c, A, bl, bu, ub_aux)
        warm = solver(c, A, bl, bu, ub_aux, warm_start=lp1)
        assert warm.status == cold.status, seed
        if cold.status != OPTIMAL:
            continue
        compared += 1
        assert warm.obj == pytest.approx(cold.obj, rel=1e-6, abs=1e-6)
        ok, msg = verify_optimality(warm, c, A, bl, bu, ub_aux)
        assert ok, (seed, msg)
        warm_total += warm.iters
        cold_total += cold.iters
    assert compared > 0
    assert warm_total <= cold_total, (warm_total, cold_total)


def test_warm_added_columns_shading_style():
    """Shading cascade shape: a 'parent' LP whose columns are group
    representatives, and a 'child' LP whose columns are perturbed copies
    (members) of each parent column.  The parent basis is re-mapped to one
    child per basic parent (what shading.map_warm_basis does); answers
    match the cold solve and the warm cascade needs fewer total pivots."""
    warm_total = cold_total = compared = 0
    for seed in range(12):
        rng = np.random.default_rng(1000 + seed)
        n_par = int(rng.integers(20, 50))
        m = int(rng.integers(2, 5))
        kids = 3
        c_par = rng.normal(size=n_par)
        A_par = rng.normal(size=(m, n_par))
        # children cluster tightly around their parent representative
        A_full = (np.repeat(A_par, kids, axis=1)
                  + 0.05 * rng.normal(size=(m, n_par * kids)))
        c_full = np.repeat(c_par, kids) + 0.05 * rng.normal(size=n_par * kids)
        ub_par = np.full(n_par, 2.0)
        ub_full = np.full(n_par * kids, 2.0)
        x0 = rng.uniform(0, 1, n_par) * ub_par
        act = A_par @ x0
        width = np.abs(rng.normal(size=m)) * 2
        bl = act - width * rng.uniform(0, 1, m)
        bu = act + width * rng.uniform(0, 1, m)

        parent = solve_lp_np(c_par, A_par, bl, bu, ub_par)
        if parent.status != OPTIMAL:
            continue
        n_full = n_par * kids
        # basic parent j -> its first child (j * kids); slack i shifts
        basis = np.where(parent.basis >= n_par,
                         n_full + (parent.basis - n_par),
                         np.minimum(parent.basis, n_par - 1) * kids)
        at_upper = np.zeros(n_full + m, bool)
        at_upper[:n_full] = np.repeat(parent.at_upper[:n_par], kids)
        at_upper[n_full:] = parent.at_upper[n_par:]
        cold = solve_lp_np(c_full, A_full, bl, bu, ub_full)
        warm = solve_lp_np(c_full, A_full, bl, bu, ub_full,
                           warm_start=WarmStart(basis, at_upper))
        assert warm.status == cold.status, seed
        if cold.status != OPTIMAL:
            continue
        compared += 1
        assert warm.obj == pytest.approx(cold.obj, rel=1e-6, abs=1e-6)
        ok, msg = verify_optimality(warm, c_full, A_full, bl, bu, ub_full)
        assert ok, (seed, msg)
        warm_total += warm.iters
        cold_total += cold.iters
    assert compared > 0
    assert warm_total < cold_total, (warm_total, cold_total)


def test_invalid_warm_start_falls_back_to_cold():
    """Garbage warm bases (duplicates, out-of-range, singular) are
    rejected by validation and produce the cold-start answer."""
    c, A, bl, bu, ub = _random_lp(3)
    m, n = A.shape
    cold = solve_lp_np(c, A, bl, bu, ub)
    bad_bases = [
        np.zeros(m, np.int64),                      # duplicates (m > 1)
        np.full(m, n + m + 99),                     # out of range
        np.arange(m),                               # possibly singular
        np.arange(m + 1),                           # wrong shape
    ]
    for bad in bad_bases:
        res = solve_lp_np(c, A, bl, bu, ub,
                          warm_start=WarmStart(bad, None))
        assert res.status == cold.status
        if cold.status == OPTIMAL:
            assert res.obj == pytest.approx(cold.obj, rel=1e-9)


def test_dual_reducer_warm_aux_path():
    """dual_reducer with warm starts (aux LP + fallback re-solves) returns
    the same package quality as before; lp_bound unchanged."""
    from repro.core.dual_reducer import dual_reducer
    from repro.core.paql import Constraint, PackageQuery

    rng = np.random.default_rng(11)
    n = 4000
    table = {"count1": np.ones(n), "val": rng.normal(14, 1.5, n),
             "obj": rng.normal(size=n)}
    query = PackageQuery(
        objective_attr="obj", maximize=False,
        constraints=(Constraint(None, 15, 45),
                     Constraint("val", 14 * 30 - 9, 14 * 30 + 9)),
        repeat=0)
    S = np.arange(n)
    res = dual_reducer(query, table, S, q=60, rng=np.random.default_rng(0))
    assert res.feasible, res.status
    # warm-starting lp1 from its own previous basis must not change anything
    from repro.core.lp import solve_lp_np as _s
    c, A, bl, bu, ub = query.matrices(table, S)
    lp1 = _s(c, A, bl, bu, ub)
    res_w = dual_reducer(query, table, S, q=60,
                         rng=np.random.default_rng(0), warm_start=lp1)
    assert res_w.feasible
    assert res_w.lp_obj == pytest.approx(res.lp_obj, rel=1e-9)
    assert res_w.obj == pytest.approx(res.obj, rel=1e-6)


def test_progressive_shading_warm_equals_cold():
    """The warm-started cascade produces the same package quality as the
    all-cold cascade (identical LPs, only iteration counts may differ)."""
    from repro.core.engine import PackageQueryEngine
    from repro.core.hardness import Q1_SDSS, column_stats, instantiate
    from repro.core.shading import progressive_shading
    from repro.data.synth_tables import make_table

    table = make_table("sdss", 8000, seed=5)
    attrs = ["tmass_prox", "j", "h", "k"]
    eng = PackageQueryEngine(table, attrs, d_f=20, alpha=800, seed=0)
    eng.partition()
    q = instantiate(Q1_SDSS, column_stats(table, attrs), 3)
    kw = dict(ilp_kwargs=dict(max_nodes=150, time_limit_s=10),
              rng=np.random.default_rng(0))
    res_w = progressive_shading(eng.hierarchy, q, table,
                                warm_starts=True, **kw)
    res_c = progressive_shading(eng.hierarchy, q, table,
                                warm_starts=False, **kw)
    assert res_w.feasible == res_c.feasible
    if res_w.feasible:
        assert res_w.obj == pytest.approx(res_c.obj, rel=0.05, abs=0.5)


if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 10_000))
    def test_warm_matches_cold_property(seed):
        """Property: warm-started numpy solves agree with cold solves."""
        c, A, bl, bu, ub = _random_lp(seed)
        cold = solve_lp_np(c, A, bl, bu, ub)
        if cold.status != OPTIMAL:
            return
        rng = np.random.default_rng(seed)
        ub2 = np.minimum(ub, np.maximum(rng.uniform(0.3, 1.0) * ub, 1.0))
        c2 = solve_lp_np(c, A, bl, bu, ub2)
        w2 = solve_lp_np(c, A, bl, bu, ub2, warm_start=cold)
        assert w2.status == c2.status
        if c2.status == OPTIMAL:
            assert abs(w2.obj - c2.obj) <= 1e-6 * (1 + abs(c2.obj))
            ok, msg = verify_optimality(w2, c, A, bl, bu, ub2)
            assert ok, msg
