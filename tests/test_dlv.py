"""DLV partitioning: the paper's Theorems 1-2, tree lookups, KD-tree
comparison (Fig. 7 qualitative), scale factors."""
import numpy as np
import pytest

from repro.core.dlv import (dlv, dlv_1d, dlv_1d_partition, get_scale_factors,
                            ratio_score)
from repro.core.kdtree import kdtree_partition

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # property test skips; the rest of the file runs
    HAVE_HYPOTHESIS = False


def _theorem2_case(seed, n):
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        vals = rng.normal(size=n)
    elif kind == 1:
        vals = rng.exponential(size=n)
    else:
        vals = np.concatenate([rng.normal(-5, 0.1, n // 2),
                               rng.normal(5, 3.0, n - n // 2)])
    vals = np.sort(vals)
    if np.var(vals) <= 0:
        return
    beta = 24 * np.var(vals) / n ** 2
    gid, _ = dlv_1d_partition(vals, beta)
    p = int(gid.max()) + 1
    assert ratio_score(vals, gid) <= 24 / n + 1e-9
    assert p <= 0.75 * n + 0.5


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 1000), st.sampled_from([100, 500, 2000]))
    def test_theorem2_universal_ratio_score(seed, n):
        """1-D DLV, beta = 24 sigma^2/n^2: z <= 24/n and p <= 3n/4 + 1/2."""
        _theorem2_case(seed, n)
else:
    @pytest.mark.parametrize("seed,n", [(0, 100), (1, 500), (2, 2000),
                                        (3, 500), (7, 100), (11, 2000)])
    def test_theorem2_universal_ratio_score(seed, n):
        """Fixed-seed fallback when hypothesis is not installed."""
        _theorem2_case(seed, n)


def test_theorem1_construction():
    """KD-tree ratio score explodes; 1-D DLV's goes to 0."""
    omega, n = 1.0, 400
    eps = 3 * omega / n
    S = np.sort(np.concatenate([[-omega, omega], np.full(n, omega + eps)]))
    # DLV
    beta = 24 * np.var(S) / len(S) ** 2
    gid, _ = dlv_1d_partition(S, beta)
    assert ratio_score(S, gid) == pytest.approx(0.0, abs=1e-12)
    # KD-tree with radius limit omega: groups {-w, w} together
    kd = kdtree_partition(S[:, None], tau=2, omega=omega)
    z_kd = ratio_score(S, kd.gid)
    assert z_kd > 1.0   # catastrophically bad (unbounded as n grows)


def test_dlv_beats_kdtree_ratio_score():
    """Fig. 7: DLV's ratio score beats KD-tree's at equal #groups."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(20_000, 1))
    res = dlv(X, d_f=100)
    kd = kdtree_partition(X, tau=max(2, 20_000 // res.num_groups))
    z_dlv = ratio_score(X[:, 0], res.gid)
    z_kd = ratio_score(X[:, 0], kd.gid)
    assert z_dlv < z_kd


def test_dlv_group_membership_tree():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(5000, 3)) * np.array([1.0, 5.0, 0.2])
    res = dlv(X, d_f=50)
    assert res.num_groups >= 5000 // 50 * 0.5
    for i in rng.choice(5000, 100, replace=False):
        assert res.get_group(X[i]) == res.gid[i]


def test_dlv_reps_and_boxes():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(2000, 2))
    res = dlv(X, d_f=20)
    for g in (0, res.num_groups // 2, res.num_groups - 1):
        m = res.members(g)
        np.testing.assert_allclose(res.reps[g], X[m].mean(0), rtol=1e-10)
        np.testing.assert_allclose(res.boxes_lo[g], X[m].min(0), rtol=1e-10)
        np.testing.assert_allclose(res.boxes_hi[g], X[m].max(0), rtol=1e-10)


def test_dlv_groups_are_contiguous_slices():
    """The cache-friendly layout the paper designs for."""
    rng = np.random.default_rng(5)
    X = rng.normal(size=(3000, 2))
    res = dlv(X, d_f=30)
    assert res.offsets[0] == 0 and res.offsets[-1] == 3000
    assert np.all(np.diff(res.offsets) >= 1)
    # order is a permutation; gid is constant within each slice
    assert len(np.unique(res.order)) == 3000
    for g in rng.integers(0, res.num_groups, 20):
        sl = res.order[res.offsets[g]:res.offsets[g + 1]]
        assert np.all(res.gid[sl] == g)


def test_get_scale_factors_hits_target():
    rng = np.random.default_rng(6)
    X = rng.normal(size=(5000, 2))
    c = get_scale_factors(X, d_f=50, rng=rng)
    for j in range(2):
        vals = np.sort(X[:, j])
        beta = c[j] * np.var(vals) / 50 ** 2
        p = int(dlv_1d(vals, beta).sum()) + 1
        # binary search on a sample: within 3x of the target split count
        assert 50 / 3 <= p <= 50 * 3


# ------------------------------------------------- scan numerics satellite


def test_scan_f32_cut_parity_on_wide_magnitude_values():
    """The compensated, dtype-derived scan: even in float32 (the no-x64
    footgun path) the cut decisions match the float64 host reference for
    mean-centered wide-magnitude values — where the seed's unshifted scan
    produces ~60x too many cuts."""
    import jax.numpy as jnp

    from repro.core.dlv import _dlv_scan_cols, _dlv_scan_np
    for mag in (1e6, 3e7):
        for seed in range(3):
            rng = np.random.default_rng(seed)
            v = np.sort(rng.normal(mag, 1.0, 5000))
            beta = 13.5 * np.var(v) / 100 ** 2
            vc = v - v.mean()
            ref = _dlv_scan_np(vc, beta)
            f32 = np.asarray(_dlv_scan_cols(
                jnp.asarray(vc[:, None], jnp.float32),
                jnp.asarray([beta], jnp.float32)))[:, 0]
            assert ref.sum() > 10          # the case actually splits
            np.testing.assert_array_equal(f32, ref)


def test_scan_segmented_matches_per_segment_reference():
    """_seg_cuts over concatenated segments == per-segment f64 reference,
    across both the batched-columns and jump-scan paths."""
    from repro.core.dlv import _dlv_scan_np, _seg_cuts
    rng = np.random.default_rng(8)
    for lens in ([4000], [900] * 40, [17, 2500, 300, 41] * 8):
        segs = [np.sort(rng.normal(rng.uniform(-5, 5), 1.0, L))
                for L in lens]
        beta = np.array([13.5 * max(np.var(s), 1e-12) / 60 ** 2
                         for s in segs])
        shifted = np.concatenate([s - s.mean() for s in segs])
        got = _seg_cuts(shifted, np.array(lens), beta)
        want = np.concatenate([_dlv_scan_np(s - s.mean(), b)
                               for s, b in zip(segs, beta)])
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------- ratio_score satellite


def test_ratio_score_sparse_and_negative_ids():
    """Sparse / negative / non-integer gids compact to the same score as
    their dense relabeling (single np.unique pass)."""
    rng = np.random.default_rng(9)
    vals = rng.normal(size=2000)
    dense = rng.integers(0, 20, 2000)
    z_dense = ratio_score(vals, dense)
    remap = np.array([-7, 3, 10**6, 55, -1, 17, 999_999, 123456, 42, 8,
                      -100, 7_000_000, 31, 2, 900_000, 64, -3, 5, 77, 88])
    z_sparse = ratio_score(vals, remap[dense])
    assert z_sparse == pytest.approx(z_dense, rel=1e-12)
    z_float = ratio_score(vals, remap[dense].astype(np.float64))
    assert z_float == pytest.approx(z_dense, rel=1e-12)
    # weighted variant stays within [0, 1] and agrees too
    zw = ratio_score(vals, remap[dense], weighted=True)
    assert 0.0 <= zw <= 1.0 + 1e-12
    assert zw == pytest.approx(ratio_score(vals, dense, weighted=True),
                               rel=1e-12)
