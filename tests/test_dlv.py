"""DLV partitioning: the paper's Theorems 1-2, tree lookups, KD-tree
comparison (Fig. 7 qualitative), scale factors."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.dlv import (dlv, dlv_1d, dlv_1d_partition, get_scale_factors,
                            ratio_score)
from repro.core.kdtree import kdtree_partition


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([100, 500, 2000]))
def test_theorem2_universal_ratio_score(seed, n):
    """1-D DLV with beta = 24 sigma^2/n^2: z <= 24/n and p <= 3n/4 + 1/2."""
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        vals = rng.normal(size=n)
    elif kind == 1:
        vals = rng.exponential(size=n)
    else:
        vals = np.concatenate([rng.normal(-5, 0.1, n // 2),
                               rng.normal(5, 3.0, n - n // 2)])
    vals = np.sort(vals)
    if np.var(vals) <= 0:
        return
    beta = 24 * np.var(vals) / n ** 2
    gid, _ = dlv_1d_partition(vals, beta)
    p = int(gid.max()) + 1
    assert ratio_score(vals, gid) <= 24 / n + 1e-9
    assert p <= 0.75 * n + 0.5


def test_theorem1_construction():
    """KD-tree ratio score explodes; 1-D DLV's goes to 0."""
    omega, n = 1.0, 400
    eps = 3 * omega / n
    S = np.sort(np.concatenate([[-omega, omega], np.full(n, omega + eps)]))
    # DLV
    beta = 24 * np.var(S) / len(S) ** 2
    gid, _ = dlv_1d_partition(S, beta)
    assert ratio_score(S, gid) == pytest.approx(0.0, abs=1e-12)
    # KD-tree with radius limit omega: groups {-w, w} together
    kd = kdtree_partition(S[:, None], tau=2, omega=omega)
    z_kd = ratio_score(S, kd.gid)
    assert z_kd > 1.0   # catastrophically bad (unbounded as n grows)


def test_dlv_beats_kdtree_ratio_score():
    """Fig. 7: DLV's ratio score beats KD-tree's at equal #groups."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(20_000, 1))
    res = dlv(X, d_f=100)
    kd = kdtree_partition(X, tau=max(2, 20_000 // res.num_groups))
    z_dlv = ratio_score(X[:, 0], res.gid)
    z_kd = ratio_score(X[:, 0], kd.gid)
    assert z_dlv < z_kd


def test_dlv_group_membership_tree():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(5000, 3)) * np.array([1.0, 5.0, 0.2])
    res = dlv(X, d_f=50)
    assert res.num_groups >= 5000 // 50 * 0.5
    for i in rng.choice(5000, 100, replace=False):
        assert res.get_group(X[i]) == res.gid[i]


def test_dlv_reps_and_boxes():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(2000, 2))
    res = dlv(X, d_f=20)
    for g in (0, res.num_groups // 2, res.num_groups - 1):
        m = res.members(g)
        np.testing.assert_allclose(res.reps[g], X[m].mean(0), rtol=1e-10)
        np.testing.assert_allclose(res.boxes_lo[g], X[m].min(0), rtol=1e-10)
        np.testing.assert_allclose(res.boxes_hi[g], X[m].max(0), rtol=1e-10)


def test_dlv_groups_are_contiguous_slices():
    """The cache-friendly layout the paper designs for."""
    rng = np.random.default_rng(5)
    X = rng.normal(size=(3000, 2))
    res = dlv(X, d_f=30)
    assert res.offsets[0] == 0 and res.offsets[-1] == 3000
    assert np.all(np.diff(res.offsets) >= 1)
    # order is a permutation; gid is constant within each slice
    assert len(np.unique(res.order)) == 3000
    for g in rng.integers(0, res.num_groups, 20):
        sl = res.order[res.offsets[g]:res.offsets[g + 1]]
        assert np.all(res.gid[sl] == g)


def test_get_scale_factors_hits_target():
    rng = np.random.default_rng(6)
    X = rng.normal(size=(5000, 2))
    c = get_scale_factors(X, d_f=50, rng=rng)
    for j in range(2):
        vals = np.sort(X[:, j])
        beta = c[j] * np.var(vals) / 50 ** 2
        p = int(dlv_1d(vals, beta).sum()) + 1
        # binary search on a sample: within 3x of the target split count
        assert 50 / 3 <= p <= 50 * 3
