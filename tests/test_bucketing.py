"""Appendix D.2 out-of-core DLV: streaming stats, memory budget, global
group-id consistency, quality parity with in-memory DLV."""
import numpy as np
import pytest

from repro.core.bucketing import (ArraySource, MemmapSource, dlv_bucketed,
                                  streaming_stats)
from repro.core.dlv import dlv, ratio_score


@pytest.fixture(scope="module")
def X():
    rng = np.random.default_rng(0)
    return np.concatenate([
        rng.normal(0, 1, (8000, 3)),
        rng.normal(6, 2, (8000, 3)),
    ]) * np.array([1.0, 4.0, 0.3])


def test_streaming_stats_match_numpy(X):
    st = streaming_stats(ArraySource(X), chunk_rows=700)
    assert st.count == len(X)
    np.testing.assert_allclose(st.mean, X.mean(0), rtol=1e-10)
    np.testing.assert_allclose(st.var, X.var(0), rtol=1e-10)
    np.testing.assert_allclose(st.lo, X.min(0))
    np.testing.assert_allclose(st.hi, X.max(0))


def test_bucketed_dlv_respects_memory_budget_and_ids(X):
    res = dlv_bucketed(ArraySource(X), d_f=40, memory_rows=3000,
                       chunk_rows=1000)
    n = len(X)
    assert res.gid.min() >= 0 and res.gid.max() < res.num_groups
    assert len(res.reps) == res.num_groups
    assert res.counts.sum() == n
    # reps are the member means (global-id consistency)
    for g in (0, res.num_groups // 2, res.num_groups - 1):
        members = np.flatnonzero(res.gid == g)
        np.testing.assert_allclose(res.reps[g], X[members].mean(0),
                                   rtol=1e-8)
    # membership queries agree with assigned ids
    rng = np.random.default_rng(1)
    for i in rng.choice(n, 100, replace=False):
        assert res.get_group(X[i]) == res.gid[i]


def test_bucketed_quality_close_to_in_memory(X):
    """Bucketing is on one attribute; within-group variance stays in the
    same ballpark as unconstrained in-memory DLV."""
    full = dlv(X, 40)
    buck = dlv_bucketed(ArraySource(X), d_f=40, memory_rows=3000)
    z_full = ratio_score(X[:, 1], full.gid)      # highest-variance attr
    z_buck = ratio_score(X[:, 1], buck.gid)
    assert z_buck <= max(4 * z_full, 0.05)


def test_memmap_source_roundtrip(tmp_path, X):
    path = str(tmp_path / "relation.npy")
    np.save(path, X)
    src = MemmapSource(path, X.shape)
    res = dlv_bucketed(src, d_f=50, memory_rows=4000)
    assert res.counts.sum() == len(X)
    assert res.num_groups >= len(X) // 50 // 4
