"""Appendix D.2 out-of-core DLV: streaming stats, memory budget, global
group-id consistency, quality parity with in-memory DLV."""
import numpy as np
import pytest

from repro.core.bucketing import (ArraySource, MemmapSource, dlv_bucketed,
                                  streaming_stats)
from repro.core.dlv import dlv, ratio_score


@pytest.fixture(scope="module")
def X():
    rng = np.random.default_rng(0)
    return np.concatenate([
        rng.normal(0, 1, (8000, 3)),
        rng.normal(6, 2, (8000, 3)),
    ]) * np.array([1.0, 4.0, 0.3])


def test_streaming_stats_match_numpy(X):
    st = streaming_stats(ArraySource(X), chunk_rows=700)
    assert st.count == len(X)
    np.testing.assert_allclose(st.mean, X.mean(0), rtol=1e-10)
    np.testing.assert_allclose(st.var, X.var(0), rtol=1e-10)
    np.testing.assert_allclose(st.lo, X.min(0))
    np.testing.assert_allclose(st.hi, X.max(0))


def test_bucketed_dlv_respects_memory_budget_and_ids(X):
    res = dlv_bucketed(ArraySource(X), d_f=40, memory_rows=3000,
                       chunk_rows=1000)
    n = len(X)
    assert res.gid.min() >= 0 and res.gid.max() < res.num_groups
    assert len(res.reps) == res.num_groups
    assert res.counts.sum() == n
    # reps are the member means (global-id consistency)
    for g in (0, res.num_groups // 2, res.num_groups - 1):
        members = np.flatnonzero(res.gid == g)
        np.testing.assert_allclose(res.reps[g], X[members].mean(0),
                                   rtol=1e-8)
    # membership queries agree with assigned ids
    rng = np.random.default_rng(1)
    for i in rng.choice(n, 100, replace=False):
        assert res.get_group(X[i]) == res.gid[i]


def test_bucketed_quality_close_to_in_memory(X):
    """Bucketing is on one attribute; within-group variance stays in the
    same ballpark as unconstrained in-memory DLV."""
    full = dlv(X, 40)
    buck = dlv_bucketed(ArraySource(X), d_f=40, memory_rows=3000)
    z_full = ratio_score(X[:, 1], full.gid)      # highest-variance attr
    z_buck = ratio_score(X[:, 1], buck.gid)
    assert z_buck <= max(4 * z_full, 0.05)


def test_memmap_source_roundtrip(tmp_path, X):
    path = str(tmp_path / "relation.npy")
    np.save(path, X)
    src = MemmapSource(path, X.shape)
    res = dlv_bucketed(src, d_f=50, memory_rows=4000)
    assert res.counts.sum() == len(X)
    assert res.num_groups >= len(X) // 50 // 4


def test_memmap_source_validates_dtype_and_shape(tmp_path, X):
    path = str(tmp_path / "f32.npy")
    np.save(path, X.astype(np.float32))
    src = MemmapSource(path, X.shape, dtype=np.float32)
    assert src.X.dtype == np.float32
    with pytest.raises(ValueError, match="dtype"):
        MemmapSource(path, X.shape, dtype=np.float64)
    with pytest.raises(ValueError, match="shape"):
        MemmapSource(path, (len(X), 99))


def test_memmap_source_from_raw_headerless(tmp_path, X):
    path = str(tmp_path / "raw.bin")
    X.astype(np.float32).tofile(path)
    src = MemmapSource.from_raw(path, X.shape, dtype=np.float32)
    assert src.num_rows == len(X) and src.num_cols == X.shape[1]
    got = np.concatenate(list(src.chunks(1000)))
    np.testing.assert_allclose(got, X.astype(np.float32), rtol=1e-6)


def test_bucket_edges_constant_attribute(tmp_path):
    """lo == hi: one bucket, no phantom empties, build still works."""
    from repro.core.bucketing import _bucket_edges, streaming_stats
    n = 4000
    X = np.ones((n, 2))
    X[:, 1] = np.random.default_rng(0).normal(size=n) * 1e-12  # ~constant
    src = ArraySource(X)
    st = streaming_stats(src, 1000)
    attr = int(np.argmax(st.var))
    edges, counts = _bucket_edges(src, 0, st.lo[0], st.hi[0], 500, 1000)
    assert len(edges) == 2 and counts.sum() == n
    with pytest.warns(UserWarning, match="oversized|memory_rows"):
        res = dlv_bucketed(ArraySource(np.ones((n, 2))), d_f=50,
                           memory_rows=500, chunk_rows=1000)
    assert res.counts.sum() == n


def test_bucket_edges_point_mass_dedupes(tmp_path):
    """A point mass heavier than the budget cannot be split by equal-width
    refinement: edges stay strictly increasing (no zero-width phantom
    buckets) and the oversized bucket degrades with a warning."""
    rng = np.random.default_rng(0)
    X = np.concatenate([np.full((6000, 2), 3.25),
                        rng.normal(10, 1, (2000, 2))])
    rng.shuffle(X)
    from repro.core.bucketing import _bucket_edges, streaming_stats
    src = ArraySource(X)
    st = streaming_stats(src, 1000)
    edges, counts = _bucket_edges(src, 0, st.lo[0], st.hi[0], 1000, 1000)
    assert np.all(np.diff(edges) > 0)
    assert counts.sum() == len(X)
    with pytest.warns(UserWarning, match="oversized|memory_rows"):
        res = dlv_bucketed(src, d_f=40, memory_rows=1000, chunk_rows=1000)
    assert res.counts.sum() == len(X)
    assert res.gid.min() >= 0


def test_memmap_vs_array_vs_spill_parity(tmp_path, X):
    """Identical gids/order/offsets/reps across: ArraySource, MemmapSource,
    and the forced-memmap spill path."""
    path = str(tmp_path / "parity.npy")
    np.save(path, X)
    kw = dict(d_f=40, memory_rows=3000, chunk_rows=1000)
    a = dlv_bucketed(ArraySource(X), **kw)
    m = dlv_bucketed(MemmapSource(path), **kw)
    s = dlv_bucketed(ArraySource(X), spill_rows=0, **kw)  # memmap scratch
    for other in (m, s):
        np.testing.assert_array_equal(a.gid, other.gid)
        np.testing.assert_array_equal(a.order, other.order)
        np.testing.assert_array_equal(a.offsets, other.offsets)
        np.testing.assert_allclose(a.reps, other.reps)
        np.testing.assert_allclose(a.boxes_lo, other.boxes_lo)
        np.testing.assert_allclose(a.boxes_hi, other.boxes_hi)


def test_single_bucket_equals_in_memory_dlv(X):
    """memory_rows >= n: one bucket, and the result is exactly plain DLV."""
    b = dlv_bucketed(ArraySource(X), d_f=40, memory_rows=len(X),
                     chunk_rows=4000)
    f = dlv(X, 40)
    np.testing.assert_array_equal(b.gid, f.gid)
    np.testing.assert_array_equal(b.offsets, f.offsets)
    np.testing.assert_allclose(b.reps, f.reps)


def test_mesh_stats_and_build_parity(X):
    """Sharded streaming stats (psum) match the host pass — including on
    large-mean/small-spread data where an unshifted raw-moment variance
    cancels catastrophically — and the mesh build is gid-identical."""
    import jax
    from jax.sharding import Mesh
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 devices")
    mesh = Mesh(np.array(devs[:2]), ("data",))
    # column 1 has the larger spread; huge means stress the cancellation
    Y = np.stack([1e9 + X[:, 0], 2e9 + X[:, 1]], axis=1)
    st_m = streaming_stats(ArraySource(Y), 1100, mesh=mesh)
    st_h = streaming_stats(ArraySource(Y), 1100)
    np.testing.assert_allclose(st_m.mean, st_h.mean, rtol=1e-12)
    np.testing.assert_allclose(st_m.var, st_h.var, rtol=1e-6)
    assert int(np.argmax(st_m.var)) == int(np.argmax(st_h.var))
    np.testing.assert_allclose(st_m.lo, st_h.lo)
    np.testing.assert_allclose(st_m.hi, st_h.hi)
    pm = dlv_bucketed(ArraySource(X), 40, memory_rows=3000,
                      chunk_rows=1000, mesh=mesh)
    p0 = dlv_bucketed(ArraySource(X), 40, memory_rows=3000,
                      chunk_rows=1000)
    np.testing.assert_array_equal(pm.gid, p0.gid)


def test_build_is_constant_pass_count(X):
    """The build does O(1) full streaming passes INDEPENDENT of the bucket
    count (the seed rescanned the relation once per bucket)."""
    from repro.core.relation import CountingSource

    def passes(memory_rows):
        src = CountingSource(ArraySource(X))
        res = dlv_bucketed(src, d_f=40, memory_rows=memory_rows,
                           chunk_rows=1000)
        n_buckets = 0
        tree_root_bounds = res.tree.bound_off[1] - res.tree.bound_off[0]
        n_buckets = int(tree_root_bounds) + 1
        return src.passes, n_buckets

    p_few, nb_few = passes(8000)
    p_many, nb_many = passes(1000)
    assert nb_many > nb_few >= 2
    # pass count bounded by stats + spill + (depth-bounded) refinement —
    # NOT by the bucket count (the seed did nb_many + ~3 passes here)
    assert p_many <= 2 + 8 and p_few <= 2 + 8
    assert p_many - p_few <= 2        # only deeper refinement, no rescan
    assert p_many < nb_many           # sub-linear in bucket count
