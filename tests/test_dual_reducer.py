"""Dual Reducer: support-size theory, auxiliary-LP spreading, fallback."""
import numpy as np

from repro.core.dual_reducer import dual_reducer
from repro.core.lp import solve_lp_np
from repro.core.paql import Constraint, PackageQuery


def _table(n, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "obj": rng.normal(10, 3, n),
        "a": rng.normal(5, 1, n),
    }


def _query(lo=10, hi=20):
    return PackageQuery("obj", maximize=True, constraints=(
        Constraint(None, lo, hi), Constraint("a", lo=4.5 * lo, hi=5.5 * hi)))


def test_lp_support_bound():
    """#positives <= ceil(m + ||x*||_1)  (paper §2.4)."""
    table = _table(5000)
    q = _query()
    c, A, bl, bu, ub = q.matrices(table, None)
    res = solve_lp_np(c, A, bl, bu, ub)
    assert res.status == 0
    support = int(np.sum(res.x > 1e-9))
    assert support <= int(np.ceil(A.shape[0] + res.x.sum()))


def test_auxiliary_lp_spreads_support():
    """Upper bound E/q forces ~q positive variables (paper §2.4)."""
    table = _table(5000)
    q = _query()
    c, A, bl, bu, ub = q.matrices(table, None)
    lp1 = solve_lp_np(c, A, bl, bu, ub)
    E = lp1.x.sum()
    target_q = 300
    lp2 = solve_lp_np(c, A, bl, bu, np.minimum(ub, E / target_q))
    assert lp2.status == 0
    support = int(np.sum(lp2.x > 1e-9))
    assert support >= target_q * 0.8


def test_dual_reducer_solves():
    table = _table(5000)
    q = _query()
    res = dual_reducer(q, table, np.arange(5000), q=100)
    assert res.feasible
    assert q.check_package(table, res.idx, res.mult)
    # objective close to its own LP bound
    assert res.obj >= 0.95 * res.lp_obj


def test_dual_reducer_fallback_fires():
    """Tiny q forces the exponential fallback; it must still solve."""
    table = _table(2000, seed=1)
    q = _query()
    res = dual_reducer(q, table, np.arange(2000), q=1,
                       ilp_kwargs=dict(max_nodes=50, time_limit_s=5))
    assert res.feasible


def test_dual_reducer_reports_infeasible():
    table = _table(100)
    q = PackageQuery("obj", maximize=True, constraints=(
        Constraint(None, 150, 200),))   # needs 150 tuples of 100
    res = dual_reducer(q, table, np.arange(100))
    assert not res.feasible
    assert res.status.startswith("lp_infeasible")
