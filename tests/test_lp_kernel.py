"""Kernel-backed dual simplex == numpy dual simplex (same pivots modulo
bucketed-BFRT tie handling; identical optima certified independently)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core.lp import OPTIMAL, solve_lp_np, verify_optimality
from repro.core.lp_kernel import solve_lp_kernel


def _random_lp(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 40))
    m = int(rng.integers(1, 5))
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = rng.integers(1, 4, size=n).astype(float)
    x0 = rng.uniform(0, 1, n) * ub
    act = A @ x0
    width = np.abs(rng.normal(size=m)) * 2
    bl = act - width * rng.uniform(0, 1, m)
    bu = act + width * rng.uniform(0, 1, m)
    return c, A, bl, bu, ub


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_kernel_lp_matches_numpy(seed):
    c, A, bl, bu, ub = _random_lp(seed)
    r_np = solve_lp_np(c, A, bl, bu, ub)
    r_k = solve_lp_kernel(c, A, bl, bu, ub, max_iters=2000)
    assert r_np.status == r_k.status
    if r_np.status == OPTIMAL:
        assert r_k.obj == pytest.approx(r_np.obj, rel=1e-6, abs=1e-6)
        ok, msg = verify_optimality(r_k, c, A, bl, bu, ub)
        assert ok, msg


def test_kernel_lp_package_query_shape():
    """A package-query-shaped LP (count + sum bounds) through the kernels."""
    rng = np.random.default_rng(7)
    n = 3000
    c = rng.normal(size=n)
    A = np.stack([np.ones(n), rng.normal(14, 1.5, n)])
    bl = np.array([15.0, 14 * 30 - 9.0])
    bu = np.array([45.0, 14 * 30 + 9.0])
    r = solve_lp_kernel(c, A, bl, bu, np.ones(n))
    assert r.status == OPTIMAL
    ok, msg = verify_optimality(r, c, A, bl, bu, np.ones(n))
    assert ok, msg
    r_np = solve_lp_np(c, A, bl, bu, np.ones(n))
    assert r.obj == pytest.approx(r_np.obj, rel=1e-8)
