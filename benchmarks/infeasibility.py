"""Paper Fig. 9 (+ App. F.1 Fig. 11): false infeasibility as hardness
increases.  Ground truth = direct solver run in pure-feasibility mode
(objective dropped), the paper's Gurobi protocol."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import ILP_KW, build_engine, emit, query_for, timed
from repro.core.paql import PackageQuery


def _feasibility_query(q: PackageQuery) -> PackageQuery:
    return dataclasses.replace(q, objective_attr=q.objective_attr,
                               maximize=False)


def run(full: bool = False):
    hardnesses = (1, 5, 9, 13) if not full else (1, 3, 5, 7, 9, 11, 13, 15)
    trials = 3 if not full else 5
    n = 15_000
    for kind, tmpl in (("sdss", "Q1_SDSS"), ("tpch", "Q2_TPCH"),
                       ("sdss", "Q3_SDSS"), ("tpch", "Q4_TPCH")):
        for h in hardnesses:
            truth = ps_ok = sr_ok = 0
            t_total = 0.0
            for trial in range(trials):
                eng = build_engine(kind, n, seed=100 + trial)
                eng.partition()
                q = query_for(eng, tmpl, h)
                gt = eng.solve_direct(_feasibility_query(q), ILP_KW)
                truth += int(gt.feasible)
                ps, t = timed(eng.solve, q, ilp_kwargs=ILP_KW)
                t_total += t
                ps_ok += int(ps.feasible)
                sr = eng.solve_sketchrefine(q, ilp_kwargs=ILP_KW)
                sr_ok += int(sr.feasible)
            emit(f"fig9/{tmpl}/h{h}", t_total / trials * 1e6,
                 f"ground_truth={truth}/{trials};ps={ps_ok};sr={sr_ok}")
