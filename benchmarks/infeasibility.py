"""Paper Fig. 9 (+ App. F.1 Fig. 11): false infeasibility as hardness
increases.  Ground truth = direct solver run in pure-feasibility mode
(objective dropped), the paper's Gurobi protocol.

Also the Solve Guard robustness bench (``--smoke`` / ``--full``):

* false-infeasibility on tight queries, guarded (degradation ladder on)
  vs unguarded — the guarded rate must be no worse;
* deterministic fault scenarios (``repro.runtime.faults``): every
  ``engine.solve`` under injection must return a report with a defined
  status — zero uncaught exceptions — and the fallback rate is recorded.

Results land in ``BENCH_robustness.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import numpy as np

from benchmarks.common import ILP_KW, build_engine, emit, query_for, timed
from repro.core import guard
from repro.core.engine import PackageQueryEngine
from repro.core.hardness import TEMPLATES, column_stats, instantiate
from repro.core.paql import PackageQuery
from repro.core.relation import MemmapRelation, configure_retries
from repro.data.synth_tables import make_table
from repro.runtime import faults

BENCH_PATH = Path(__file__).resolve().parent.parent / \
    "BENCH_robustness.json"


def _feasibility_query(q: PackageQuery) -> PackageQuery:
    return dataclasses.replace(q, objective_attr=q.objective_attr,
                               maximize=False)


def run(full: bool = False):
    hardnesses = (1, 5, 9, 13) if not full else (1, 3, 5, 7, 9, 11, 13, 15)
    trials = 3 if not full else 5
    n = 15_000
    for kind, tmpl in (("sdss", "Q1_SDSS"), ("tpch", "Q2_TPCH"),
                       ("sdss", "Q3_SDSS"), ("tpch", "Q4_TPCH")):
        for h in hardnesses:
            truth = ps_ok = sr_ok = 0
            t_total = 0.0
            for trial in range(trials):
                eng = build_engine(kind, n, seed=100 + trial)
                eng.partition()
                q = query_for(eng, tmpl, h)
                gt = eng.solve_direct(_feasibility_query(q), ILP_KW)
                truth += int(gt.feasible)
                ps, t = timed(eng.solve, q, ilp_kwargs=ILP_KW)
                t_total += t
                ps_ok += int(ps.feasible)
                sr = eng.solve_sketchrefine(q, ilp_kwargs=ILP_KW)
                sr_ok += int(sr.feasible)
            emit(f"fig9/{tmpl}/h{h}", t_total / trials * 1e6,
                 f"ground_truth={truth}/{trials};ps={ps_ok};sr={sr_ok}")


# ------------------------------------------------------- robustness bench

ATTRS = {"tpch": ["price", "quantity", "discount", "tax"],
         "sdss": ["tmass_prox", "j", "h", "k"]}

FAULT_SCENARIOS = (
    ("chunk_read_flaky", faults.CHUNK_READ, dict(times=3)),
    ("gather_flaky", faults.GATHER_READ, dict(times=None, prob=0.25)),
    ("binv_corruption", faults.BINV, dict(times=3, after=1, scale=1e-3)),
    ("shard_death", faults.SHARD, dict(times=1)),
)


def _memmap_engine(kind: str, n: int, seed: int):
    """Out-of-core engine so the read-fault sites sit on the solve path."""
    attrs = ATTRS[kind]
    t = make_table(kind, n, seed=seed)
    X = np.stack([np.asarray(t[a], np.float64) for a in attrs], axis=1)
    rel = MemmapRelation(X, attrs, chunk_rows=max(n // 7, 64))
    eng = PackageQueryEngine(rel, attrs, d_f=10, alpha=max(n // 10, 200),
                             seed=seed)
    stats = column_stats(t, attrs)
    return eng, stats


def _shard_death_trial(trial: int):
    """Kill a shard mid-pivot-loop in solve_lp_dist; success = the
    single-host fallback recovers the numpy twin's optimum."""
    import jax

    from repro.core.distributed import solve_lp_dist
    from repro.core.lp import OPTIMAL, solve_lp_np

    rng = np.random.default_rng(trial)
    m, n = 6, 160
    c = rng.normal(size=n)
    A = rng.normal(size=(m, n))
    ub = rng.integers(1, 4, size=n).astype(float)
    act = A @ (rng.uniform(0, 1, n) * ub)
    width = np.abs(rng.normal(size=m)) * 2
    bl = act - width * rng.uniform(0, 1, m)
    bu = act + width * rng.uniform(0, 1, m)
    ref = solve_lp_np(c, A, bl, bu, ub)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with faults.injected(seed=trial,
                         arms={faults.SHARD: dict(times=1)}) as inj:
        res = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh)
    fell_back = any("single_host_fallback" in note for note in res.notes)
    ok = (res.status == ref.status == OPTIMAL
          and abs(res.obj - ref.obj) <= 1e-6 * (1 + abs(ref.obj)))
    return ok, fell_back, inj.fire_count(faults.SHARD)


def run_robustness(full: bool = False) -> dict:
    """Guarded-vs-unguarded false infeasibility + fault-scenario sweep."""
    configure_retries(base_s=1e-3, max_s=1e-2)
    n = 15_000 if full else 4_000
    trials = 4 if full else 2
    hardnesses = (9, 11, 13) if full else (9, 13)
    templates = (("tpch", "Q2_TPCH"), ("tpch", "Q4_TPCH"))

    # ---- false infeasibility: the ladder must not cost feasibility ----
    gt_feas = guarded_feas = unguarded_feas = cases = 0
    uncaught = 0
    for kind, tmpl in templates:
        for h in hardnesses:
            for trial in range(trials):
                eng, stats = _memmap_engine(kind, n, seed=100 + trial)
                eng.partition()
                q = instantiate(TEMPLATES[tmpl], stats, h)
                gt = eng.solve_direct(_feasibility_query(q), ILP_KW)
                res_g = eng.solve(q, ilp_kwargs=ILP_KW)
                try:
                    res_u = eng.solve(q, ilp_kwargs=ILP_KW, guarded=False)
                    u_feas = res_u.feasible
                # repro: allow[REPRO004] this benchmark counts uncaught
                # failures of the unguarded path by design
                except Exception:
                    uncaught += 1
                    u_feas = False
                cases += 1
                gt_feas += int(gt.feasible)
                guarded_feas += int(res_g.feasible)
                unguarded_feas += int(u_feas)
    false_inf_guarded = (gt_feas - guarded_feas) / max(cases, 1)
    false_inf_unguarded = (gt_feas - unguarded_feas) / max(cases, 1)
    emit("robustness/false_infeasibility", 0.0,
         f"guarded={false_inf_guarded:.3f};"
         f"unguarded={false_inf_unguarded:.3f};cases={cases}")

    # ---- fault scenarios: defined status, zero uncaught exceptions ----
    scenarios = {}
    for name, site, arm in FAULT_SCENARIOS:
        fired = fallbacks = feasible = errors = 0
        statuses = []
        for trial in range(trials):
            if site == faults.SHARD:
                # the dead-shard site sits in solve_lp_dist (the engine's
                # host loop is numpy): drive it directly on a host mesh
                ok, fb, k = _shard_death_trial(trial)
                fired += k
                fallbacks += int(fb)
                feasible += int(ok)
                statuses.append("ok" if ok else "error")
                continue
            eng, stats = _memmap_engine("tpch", n, seed=200 + trial)
            q = instantiate(TEMPLATES["Q2_TPCH"], stats, 5.0)
            try:
                with faults.injected(seed=trial, arms={site: arm}) as inj:
                    eng.partition()   # chunk reads live here: retried
                    res = eng.solve(q, ilp_kwargs=ILP_KW)
                report = res.report
                assert report is not None and \
                    report.status in guard.STATUSES
            # repro: allow[REPRO004] fault-injection harness: uncaught
            # escapes are the metric being measured
            except Exception:
                uncaught += 1
                continue
            fired += inj.fire_count(site)
            fallbacks += int(bool(report.fallbacks)
                             or report.fault_retries > 0)
            feasible += int(res.feasible)
            errors += int(report.status == guard.ERROR)
            statuses.append(report.status)
        scenarios[name] = dict(fired=fired, trials=trials,
                               fallback_rate=fallbacks / trials,
                               feasible=feasible, errors=errors,
                               statuses=statuses)
        emit(f"robustness/fault/{name}", 0.0,
             f"fired={fired};fallback_rate={fallbacks / trials:.2f};"
             f"feasible={feasible}/{trials}")

    entry = dict(
        n=n, trials=trials, hardnesses=list(hardnesses), cases=cases,
        false_infeasibility=dict(guarded=false_inf_guarded,
                                 unguarded=false_inf_unguarded),
        fault_scenarios=scenarios, uncaught_exceptions=uncaught,
    )
    data = {}
    if BENCH_PATH.exists():
        data = json.loads(BENCH_PATH.read_text())
    data["full" if full else "smoke"] = entry
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"# wrote {BENCH_PATH}", flush=True)

    # the acceptance gates of the robustness issue
    assert uncaught == 0, f"{uncaught} uncaught exceptions under faults"
    assert false_inf_guarded <= false_inf_unguarded + 1e-9, \
        "degradation ladder increased false infeasibility"
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast robustness profile (CI gate)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale robustness sweep")
    ap.add_argument("--fig9", action="store_true",
                    help="also run the Fig. 9 false-infeasibility sweep")
    args = ap.parse_args()
    run_robustness(full=args.full and not args.smoke)
    if args.fig9:
        run(full=args.full)


if __name__ == "__main__":
    main()
