"""Concurrent-serving benchmark — acceptance instrument for the PR-10
thread-safety work (shared QCache under concurrent engine sessions).

A repeat-query flight runs over ONE resident engine: T worker threads
each solve the same small set of overlapping queries through private
``engine.session`` handles sharing the hierarchy and the QCache.  The
claim/wait populate protocol must keep cold solves at one per distinct
query (no duplicate descents), every thread must see the same validated
package, and the instrumented cache lock reports how contended the
shared path actually is (hold time per acquisition is the REPRO011
discipline made measurable: only probes/publishes under the lock,
never solves).

Reported per profile in ``BENCH_concurrency.json``:

* ``lock`` — ``QCache.lock_stats()``: acquisitions, contended count,
  total wait/hold seconds (and derived mean hold per acquisition);
* ``cache`` — hit/miss/store counters for the whole flight
  (cold solves == distinct queries is asserted, not just reported);
* wall time of the concurrent flight vs the sequential flight of the
  same (thread x query) work list.

CLI (the smoke profile is wired into CI):

    python -m benchmarks.concurrency_bench --smoke
    python -m benchmarks.concurrency_bench --full
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.engine import PackageQueryEngine
from repro.core.hardness import Q2_TPCH, Q4_TPCH, column_stats, instantiate
from repro.core.qcache import QCache
from repro.data.synth_tables import make_table
from repro.runtime.racecheck import run_threads

BENCH_PATH = Path(__file__).resolve().parent.parent \
    / "BENCH_concurrency.json"
ATTRS = ["price", "quantity", "discount", "tax"]


def _pkg(res):
    order = np.argsort(res.idx, kind="stable")
    return np.asarray(res.idx)[order], np.asarray(res.mult)[order]


def _build(table, *, d_f, alpha):
    eng = PackageQueryEngine(table, ATTRS, d_f=d_f, alpha=alpha, seed=0,
                             cache=QCache())
    eng.partition()
    return eng


def run(full: bool = False) -> dict:
    n = 200_000 if full else 20_000
    alpha = 4_000 if full else 1_000
    d_f = 50 if full else 20
    threads = 8 if full else 4
    ilp_kw = dict(max_nodes=200, time_limit_s=60)

    table = make_table("tpch", n, seed=1)
    stats = column_stats(table, ATTRS)
    queries = [instantiate(Q2_TPCH, stats, 2.0),
               instantiate(Q4_TPCH, stats, 2.0)]
    work = [(t, queries[t % len(queries)]) for t in range(threads)]

    # -- sequential reference: same work list, one thread
    seq = _build(table, d_f=d_f, alpha=alpha)
    t0 = time.perf_counter()
    ref = {}
    for t, q in work:
        res = seq.session(seed=t % len(queries)).solve(
            q, ilp_kwargs=ilp_kw)
        assert res.feasible, res.status
        ref.setdefault(t % len(queries), res)
    seq_s = time.perf_counter() - t0

    # -- concurrent flight: shared engine + cache, per-thread sessions
    conc = _build(table, d_f=d_f, alpha=alpha)

    def body(t, q):
        def runner():
            return conc.session(seed=t % len(queries)).solve(
                q, ilp_kwargs=ilp_kw)

        return runner

    t0 = time.perf_counter()
    results = run_threads([body(t, q) for t, q in work], timeout_s=600)
    conc_s = time.perf_counter() - t0

    for (t, _q), res in zip(work, results):
        assert res.feasible, f"thread {t}: {res.status}"
        want_i, want_m = _pkg(ref[t % len(queries)])
        got_i, got_m = _pkg(res)
        assert np.array_equal(got_i, want_i), f"thread {t} parity"
        assert np.array_equal(got_m, want_m), f"thread {t} parity"

    cs = conc.cache.stats_snapshot()
    assert cs.stores == len(queries), \
        f"duplicate cold solves: {cs.stores} stores for " \
        f"{len(queries)} distinct queries"
    ls = conc.cache.lock_stats()
    mean_hold_us = 1e6 * ls["hold_s"] / max(ls["acquisitions"], 1)

    entry = {
        "n": n, "alpha": alpha, "d_f": d_f, "threads": threads,
        "full": bool(full),
        "sequential_s": round(seq_s, 4),
        "concurrent_s": round(conc_s, 4),
        "lock": {"acquisitions": ls["acquisitions"],
                 "contended": ls["contended"],
                 "wait_s": round(ls["wait_s"], 6),
                 "hold_s": round(ls["hold_s"], 6),
                 "mean_hold_us": round(mean_hold_us, 2)},
        "cache": cs.as_dict(),
        "parity": True,
    }
    print(f"concurrency_flight,{conc_s * 1e6 / threads:.0f},"
          f"threads={threads} seq={seq_s:.2f}s conc={conc_s:.2f}s "
          f"stores={cs.stores} lock_acq={ls['acquisitions']} "
          f"contended={ls['contended']} "
          f"mean_hold_us={mean_hold_us:.1f}", flush=True)

    data = {}
    if BENCH_PATH.exists():
        data = json.loads(BENCH_PATH.read_text())
    data["smoke" if not full else "full"] = entry
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"# wrote {BENCH_PATH}", flush=True)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast profile (CI gate)")
    ap.add_argument("--full", action="store_true",
                    help="acceptance-scale run")
    args = ap.parse_args()
    run(full=args.full and not args.smoke)


if __name__ == "__main__":
    main()
