"""Cold vs warm dual-simplex starts across Progressive Shading and the
Dual Reducer (App. C customization).

Paired design: the warm-started pipeline is run once, and every LP in it
(each Shading layer, Dual Reducer's lp1, its bound-tightened auxiliary
LP, and every branch & bound node re-solve inside the sub-ILP) is also
re-solved cold, so iteration counts compare the SAME LP sequence —
branching-path divergence from non-unique optima cannot skew the totals.
Warm starts never change an answer (asserted here per LP); they only
change how many pivots reach it.

Records totals in ``BENCH_lp.json`` at the repo root so later PRs can
track the trajectory; CSV rows go through benchmarks.common.emit.

NOTE: ``_pipeline`` intentionally replays the shading/dual-reducer LP
sequence inline (rather than calling progressive_shading) so that every
LP flows through the paired probe exactly once; if shading() or
dual_reducer() grow new LP call sites, mirror them here or the
trajectory numbers will measure a stale replica of the pipeline.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import build_engine, emit, query_for, timed
from repro.core import ilp as ilp_mod
from repro.core.lp import OPTIMAL, solve_lp_np
from repro.core.shading import map_warm_basis

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_lp.json"


class _PairedProbe:
    """Wraps an LP solver (default the numpy twin; pass e.g. the
    distributed engine): forwards the (possibly warm) solve, and re-runs
    the same LP cold to get the paired cold iteration count."""

    def __init__(self, solver=None):
        self.solver = solver or solve_lp_np
        self.warm_iters = 0
        self.cold_iters = 0
        self.n_lps = 0
        self.n_warmed = 0

    def __call__(self, c, A, bl, bu, ub, **kw):
        res = self.solver(c, A, bl, bu, ub, **kw)
        self.n_lps += 1
        self.warm_iters += res.iters
        if kw.get("warm_start") is not None:
            self.n_warmed += 1
            kw_cold = dict(kw, warm_start=None)
            cold = self.solver(c, A, bl, bu, ub, **kw_cold)
            self.cold_iters += cold.iters
            if res.status == OPTIMAL and cold.status == OPTIMAL:
                assert abs(res.obj - cold.obj) <= 1e-6 * (1 + abs(cold.obj))
        else:
            self.cold_iters += res.iters
        return res


def _pipeline(eng, query, probe, *, dr_q: int = 500):
    """Warm-threaded cascade + dual reducer, all LPs through ``probe``."""
    hier = eng.hierarchy
    S = np.arange(hier.layers[hier.L].size)
    ws = None
    marks = {}
    for l in range(hier.L, 0, -1):
        from repro.core.neighbor import neighbor_sampling
        from repro.core.shading import FALLBACK_SEED
        c, A, bl, bu, ub = query.matrices(hier.layers[l].table, S)
        res = probe(c, A, bl, bu, ub, warm_start=ws, max_iters=20000)
        s_prime = S[res.x > 1e-9] if res.status == OPTIMAL \
            else np.zeros(0, np.int64)
        if len(s_prime) == 0:
            # same fallback as shading(): seed with top-k by objective
            # (no second LP solve — every probe'd LP stays paired)
            obj = hier.layers[l].table[query.objective_attr][S]
            order = np.argsort(-obj if query.maximize else obj,
                               kind="stable")
            s_prime = S[order[:FALLBACK_SEED]]
            res = None
        S_next = neighbor_sampling(hier, l, hier.alpha, s_prime,
                                   query.objective_attr, query.maximize)
        ws = map_warm_basis(hier, l, S, res, S_next,
                            obj_attr=query.objective_attr)
        S = S_next
    marks["cascade"] = (probe.warm_iters, probe.cold_iters)
    c, A, bl, bu, ub = query.matrices(eng.table, S)
    lp1 = probe(c, A, bl, bu, ub, warm_start=ws)
    obj = None
    if lp1.status == OPTIMAL:
        E = float(np.sum(lp1.x))
        ub_aux = np.minimum(ub, max(E / dr_q, 1e-9))
        aux = probe(c, A, bl, bu, ub_aux, warm_start=lp1)
        marks["reducer_lps"] = (probe.warm_iters, probe.cold_iters)
        support = lp1.x > 1e-9
        if aux.status == OPTIMAL:
            support |= aux.x > 1e-9
        sel = np.flatnonzero(support)
        sub = S[sel]
        cs, As, _, _, ubs = query.matrices(eng.table, sub)
        from repro.core.dual_reducer import _subset_warm
        res_i = ilp_mod.solve_ilp(cs, As, bl, bu, ubs, max_nodes=250,
                                  time_limit_s=20,
                                  warm_start=_subset_warm(lp1, sel, len(S)))
        marks["sub_ilp"] = (probe.warm_iters, probe.cold_iters)
        obj = res_i.obj if res_i.feasible else None
    return marks, obj


def _big_package_lp(n: int, m: int = 12, seed: int = 0):
    """Paper-style package LP at scale (shared by the per-iteration and
    distributed-pricing sections)."""
    rng = np.random.default_rng(seed)
    c = rng.normal(size=n)
    A = np.stack([np.ones(n)] + [
        rng.normal(rng.uniform(-5, 15), rng.uniform(1, 3), n)
        for _ in range(m - 1)])
    x0 = np.zeros(n)
    x0[rng.choice(n, 30, replace=False)] = 1.0
    act = A @ x0
    w = np.maximum(np.abs(act) * 0.02, 0.5)
    return c, A, act - w, act + w, np.ones(n)


def _per_iteration_work(record, full: bool) -> None:
    """Revised engine (incremental Binv/d/xB, refactor every 64) vs the
    textbook per-iteration recompute (refactor_every=1 rebuilds the
    inverse, reduced costs and xB from scratch each pivot — the seed
    engine's work profile) on a large package LP.  Same pivot rules, same
    optimum; the wall-clock ratio is the per-iteration sweep reduction."""
    n = 1_000_000 if full else 200_000
    c, A, bl, bu, ub = _big_package_lp(n)

    def best_of(k, **kw):
        best, res = np.inf, None
        for _ in range(k):
            t0 = time.time()
            res = solve_lp_np(c, A, bl, bu, ub, max_iters=20000, **kw)
            best = min(best, time.time() - t0)
        return res, best

    fast, t_fast = best_of(2)
    slow, t_slow = best_of(2, refactor_every=1)
    assert fast.status == slow.status == OPTIMAL
    assert abs(fast.obj - slow.obj) <= 1e-6 * (1 + abs(fast.obj))
    us_fast = t_fast / max(fast.iters, 1) * 1e6
    us_slow = t_slow / max(slow.iters, 1) * 1e6
    emit("lp_engine_revised_us_per_iter", us_fast,
         f"n={n};iters={fast.iters}")
    emit("lp_engine_textbook_us_per_iter", us_slow,
         f"n={n};iters={slow.iters};speedup={us_slow / us_fast:.2f}x")
    record["per_iteration"] = {
        "n": n, "revised_us_per_iter": round(us_fast, 1),
        "textbook_us_per_iter": round(us_slow, 1),
        "revised_iters": fast.iters, "textbook_iters": slow.iters,
        "speedup": round(us_slow / us_fast, 3)}


def _distributed_pricing(record, full: bool, eng=None, query=None) -> None:
    """The distributed pricing backend (core.distributed.solve_lp_dist:
    sharded A + maintained reduced costs, exact-BFRT shard_map step) on a
    paper-scale package LP: cold + warm parity vs the numpy twin, with
    per-iteration engine cost and exact/conservative pivot counts.  Under
    ``--full`` the warm-threaded Shading cascade is additionally replayed
    through the distributed engine (B&B node re-solves stay on the numpy
    twin)."""
    import jax

    from repro.core.distributed import solve_lp_dist

    p = len(jax.devices())
    mesh = jax.make_mesh((p, 1), ("data", "model"))
    n = 1_000_000 if full else 200_000
    c, A, bl, bu, ub = _big_package_lp(n)

    ref, t_ref = timed(solve_lp_np, c, A, bl, bu, ub, max_iters=20000)
    t0 = time.time()
    cold = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh, max_iters=20000)
    t_cold = time.time() - t0
    assert cold.status == ref.status == OPTIMAL
    assert abs(cold.obj - ref.obj) <= 1e-6 * (1 + abs(ref.obj))
    t0 = time.time()
    warm = solve_lp_dist(c, A, bl, bu, ub, mesh=mesh, max_iters=20000,
                         warm_start=ref)
    t_warm = time.time() - t0
    assert abs(warm.obj - ref.obj) <= 1e-6 * (1 + abs(ref.obj))

    us_cold = t_cold / max(cold.iters, 1) * 1e6
    emit("lp_engine_distributed_us_per_iter", us_cold,
         f"n={n};devices={p};iters={cold.iters};"
         f"exact={cold.pivot_stats['exact']};"
         f"conservative={cold.pivot_stats['conservative']};"
         f"warm_iters={warm.iters}")
    record["distributed"] = {
        "n": n, "devices": p,
        "cold_iters": cold.iters, "warm_iters": warm.iters,
        "numpy_iters": ref.iters,
        "us_per_iter": round(us_cold, 1),
        "numpy_us_per_iter": round(t_ref / max(ref.iters, 1) * 1e6, 1),
        "pivots_exact": cold.pivot_stats["exact"],
        "pivots_conservative": cold.pivot_stats["conservative"],
        "seconds_cold": round(t_cold, 3), "seconds_warm": round(t_warm, 3)}

    if full and eng is not None and query is not None:
        from functools import partial

        from repro.core.lp import solve_lp
        probe = _PairedProbe(solver=partial(solve_lp, mesh=mesh))
        t0 = time.time()
        marks, obj = _pipeline(eng, query, probe)
        dt = time.time() - t0
        # de-cumulate the phase marks (same convention as run()'s records:
        # 'cascade' is the Shading layers only, 'reducer' the two Dual
        # Reducer LPs; B&B node re-solves stay on the numpy twin)
        cw, cc = marks["cascade"]
        phases = {"cascade": {"warm": cw, "cold": cc}}
        if "reducer_lps" in marks:
            rw, rc = marks["reducer_lps"]
            phases["reducer"] = {"warm": rw - cw, "cold": rc - cc}
        emit("warm_start_distributed_cascade", dt * 1e6,
             f"devices={p};cascade_warm={cw};cascade_cold={cc};"
             f"lps={probe.n_lps};feasible={obj is not None}")
        record["distributed"]["cascade"] = {
            "phases": phases, "lps": probe.n_lps, "seconds": round(dt, 3),
            "feasible": obj is not None}


def run(full: bool = False) -> None:
    n = 120_000 if full else 30_000
    eng = build_engine("sdss", n, d_f=8, alpha=600)
    eng.partition()
    record = {"n": n,
              "layers": [l.size for l in eng.hierarchy.layers],
              "queries": []}
    tot_w = tot_c = 0
    orig_ilp_lp = ilp_mod.solve_lp_np
    query = None
    for h in ([1, 3, 5, 7] if full else [1, 3, 5]):
        query = query_for(eng, "Q1_SDSS", h)
        probe = _PairedProbe()
        # route the B&B node re-solves through the probe as well
        ilp_mod.solve_lp_np = probe
        try:
            t0 = time.time()
            marks, obj = _pipeline(eng, query, probe)
            dt = time.time() - t0
        finally:
            ilp_mod.solve_lp_np = orig_ilp_lp
        # de-cumulate the phase marks
        phases = {}
        prev = (0, 0)
        for name in ("cascade", "reducer_lps", "sub_ilp"):
            if name in marks:
                w, c = marks[name]
                phases[name] = {"warm": w - prev[0], "cold": c - prev[1]}
                prev = marks[name]
        tot_w += probe.warm_iters
        tot_c += probe.cold_iters
        emit(f"warm_start_h{h}", dt * 1e6,
             f"warm_iters={probe.warm_iters};cold_iters={probe.cold_iters};"
             f"lps={probe.n_lps};warmed={probe.n_warmed}")
        record["queries"].append({
            "h": h, "phases": phases,
            "warm_iters": probe.warm_iters, "cold_iters": probe.cold_iters,
            "lps": probe.n_lps, "warmed": probe.n_warmed,
            "feasible": obj is not None, "seconds": round(dt, 3)})
    record["total_warm_iters"] = tot_w
    record["total_cold_iters"] = tot_c
    record["iters_speedup"] = round(tot_c / max(tot_w, 1), 3)
    _per_iteration_work(record, full)
    _distributed_pricing(record, full, eng, query)
    BENCH_PATH.write_text(json.dumps(record, indent=2) + "\n")
    emit("warm_start_total", 0.0,
         f"cold_iters={tot_c};warm_iters={tot_w};"
         f"speedup={record['iters_speedup']}x;json={BENCH_PATH.name}")
