"""Paper Mini-Experiments 1, 2, 4: LP-vs-ILP shading, Neighbor Sampling
vs random sampling, Dual Reducer auxiliary LP vs random sampling."""
from __future__ import annotations


from benchmarks.common import ILP_KW, build_engine, emit, gap, query_for, timed


def run(full: bool = False):
    hardnesses = (1, 5, 9) if not full else (1, 3, 5, 7, 9, 11, 13)
    n = 15_000
    eng = build_engine("sdss", n)
    eng.partition()

    # Mini-Exp 1: LP vs ILP for the intermediate Shading solve
    for h in hardnesses:
        q = query_for(eng, "Q1_SDSS", h)
        lp = eng.lp_bound(q)
        a, ta = timed(eng.solve, q, ilp_kwargs=ILP_KW, layer_solver="lp")
        b, tb = timed(eng.solve, q, ilp_kwargs=ILP_KW, layer_solver="ilp")
        emit(f"miniexp1/shading_lp/h{h}", ta * 1e6,
             f"feasible={a.feasible};gap={gap(a, lp):.4f}")
        emit(f"miniexp1/shading_ilp/h{h}", tb * 1e6,
             f"feasible={b.feasible};gap={gap(b, lp):.4f}")

    # Mini-Exp 2: Neighbor Sampling vs random sampling
    for h in hardnesses:
        q = query_for(eng, "Q1_SDSS", h)
        lp = eng.lp_bound(q)
        a, _ = timed(eng.solve, q, ilp_kwargs=ILP_KW, sampler="neighbor")
        b, _ = timed(eng.solve, q, ilp_kwargs=ILP_KW, sampler="random")
        emit(f"miniexp2/neighbor/h{h}", 0.0,
             f"feasible={a.feasible};obj={a.obj:.3f};gap={gap(a, lp):.4f}")
        emit(f"miniexp2/random/h{h}", 0.0,
             f"feasible={b.feasible};obj={b.obj:.3f};gap={gap(b, lp):.4f}")

    # Mini-Exp 4: Dual Reducer auxiliary LP vs random sub-ILP sampling
    for h in hardnesses:
        q = query_for(eng, "Q1_SDSS", h)
        lp = eng.lp_bound(q)
        a, _ = timed(eng.solve, q, ilp_kwargs=ILP_KW, dr_aux="lp")
        b, _ = timed(eng.solve, q, ilp_kwargs=ILP_KW, dr_aux="random")
        emit(f"miniexp4/aux_lp/h{h}", 0.0,
             f"feasible={a.feasible};gap={gap(a, lp):.4f}")
        emit(f"miniexp4/aux_random/h{h}", 0.0,
             f"feasible={b.feasible};gap={gap(b, lp):.4f}")
