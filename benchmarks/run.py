"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig8,...]

Prints ``name,us_per_call,derived`` CSV rows (also saved to
results/bench.csv).  Default is the quick profile (~10 min on one CPU
core); --full runs the paper-scale sweeps.
"""
import argparse
import os
import time

# Give the CPU host virtual devices BEFORE jax first initializes so the
# distributed-pricing section of appc_warm_start runs on a real multi-device
# mesh (no-op when XLA_FLAGS already pins a device count, e.g. on TPU).
from repro.hostdev import ensure_host_devices

ensure_host_devices()

from benchmarks import (ablations, analysis_bench, batch_lp, cache_bench,
                        concurrency_bench, dual_reducer_bench, grid,
                        infeasibility, partitioning, pds_scaling,
                        ratio_score, roofline, scaling, warm_start)
from benchmarks.common import ROWS

MODULES = {
    "fig7_ratio_score": ratio_score,
    "fig8_scaling": scaling,
    "fig9_infeasibility": infeasibility,
    "table3_grid": grid,
    "miniexp1_2_4_ablations": ablations,
    "miniexp3_pds": pds_scaling,
    "miniexp5_partitioning": partitioning,
    "miniexp7_8_dual_reducer": dual_reducer_bench,
    "appc_warm_start": warm_start,
    "cache": cache_bench,
    "concurrency": concurrency_bench,
    "batch_lp": batch_lp,
    "roofline": roofline,
    "analysis": analysis_bench,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    t0 = time.time()
    print("name,us_per_call,derived")
    for name, mod in MODULES.items():
        if only and not any(o in name for o in only):
            continue
        print(f"# === {name} ===", flush=True)
        t = time.time()
        try:
            mod.run(full=args.full)
        # repro: allow[REPRO004] harness by design: record and continue
        except Exception as e:  # keep the harness going; record the failure
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)
        print(f"# {name} took {time.time() - t:.1f}s", flush=True)
    os.makedirs("results", exist_ok=True)
    with open("results/bench.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(ROWS) + "\n")
    print(f"# total {time.time() - t0:.1f}s; {len(ROWS)} rows -> results/bench.csv")


if __name__ == '__main__':
    main()
