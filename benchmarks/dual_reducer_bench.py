"""Paper Mini-Experiments 7 and 8: sub-ILP size q sweep, and Dual Reducer
vs direct black-box ILP for the final layer."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ILP_KW, build_engine, emit, gap, query_for, timed
from repro.core.dual_reducer import dual_reducer


def run(full: bool = False):
    n = 20_000
    eng = build_engine("sdss", n)
    eng.partition()
    hardnesses = (1, 5, 9) if not full else (1, 3, 5, 7, 9, 11, 13)

    # Mini-Exp 7: q sweep
    for qsize in (50, 500, 5000):
        for h in hardnesses:
            q = query_for(eng, "Q1_SDSS", h)
            res, t = timed(dual_reducer, q, eng.table, np.arange(n),
                           q=qsize, ilp_kwargs=ILP_KW)
            emit(f"miniexp7/q{qsize}/h{h}", t * 1e6,
                 f"feasible={res.feasible};sub_ilp={res.sub_ilp_size};"
                 f"fallbacks={res.fallbacks}")

    # Mini-Exp 8: Dual Reducer vs direct ILP on the final candidate set
    for h in hardnesses:
        q = query_for(eng, "Q1_SDSS", h)
        lp = eng.lp_bound(q)
        dr, t_dr = timed(dual_reducer, q, eng.table, np.arange(n), q=500,
                         ilp_kwargs=ILP_KW)
        bb, t_bb = timed(eng.solve_direct, q, ILP_KW)
        emit(f"miniexp8/dual_reducer/h{h}", t_dr * 1e6,
             f"feasible={dr.feasible};gap={gap(dr, lp):.4f}")
        emit(f"miniexp8/direct_ilp/h{h}", t_bb * 1e6,
             f"feasible={bb.feasible};gap={gap(bb, lp):.4f}")
