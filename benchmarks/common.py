"""Shared benchmark utilities: timing, CSV emission, engines."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.engine import PackageQueryEngine
from repro.core.hardness import TEMPLATES, column_stats, instantiate
from repro.data.synth_tables import make_table

ROWS: List[str] = []

ILP_KW = dict(max_nodes=250, time_limit_s=20)


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0)


def build_engine(kind: str, n: int, *, d_f: int = 20, alpha: int = 2000,
                 seed: int = 0) -> PackageQueryEngine:
    table = make_table(kind, n, seed=seed)
    attrs = (["tmass_prox", "j", "h", "k"] if kind == "sdss"
             else ["price", "quantity", "discount", "tax"])
    eng = PackageQueryEngine(table, attrs, d_f=d_f, alpha=alpha, seed=seed)
    return eng


def query_for(eng: PackageQueryEngine, template_name: str, h: float):
    stats = column_stats(eng.table, eng.attrs)
    return instantiate(TEMPLATES[template_name], stats, h)


def gap(res, lp_bound: float) -> float:
    """Paper integrality-gap metric, normalised >= 1."""
    if not res.feasible or not np.isfinite(lp_bound):
        return float("nan")
    g = (abs(res.obj) + 0.1) / (abs(lp_bound) + 0.1)
    return g if g >= 1 else 1.0 / g
