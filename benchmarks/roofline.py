"""Roofline analysis from the multi-pod dry-run artifacts (§Roofline).

For every (arch x shape) single-pod cell:
    compute term    = HLO_dot_FLOPs(/dev) / peak_FLOPs(bf16, per chip)
    memory term     = HLO_dot_bytes(/dev) / HBM bandwidth
    collective term = collective_bytes(/dev) / ICI link bandwidth
plus MODEL_FLOPS = 6*N(_active)*D (train) or 2*N_active*D_new (decode),
the useful/compiled ratio, the dominant term, and a one-line lever.

Writes results/roofline.csv and prints the table run.py embeds in
EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

CHIPS = 256  # single-pod roofline per the brief


def model_flops_per_device(arch: str, shape_name: str) -> float:
    """Useful FLOPs per device per step: 6*N_active*tokens (train),
    2*N_active*tokens (prefill), 2*N_active*B new tokens (decode)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2 * n_active * tokens
    else:  # decode: one new token per sequence
        total = 2 * n_active * shape.global_batch
    return total / CHIPS


def lever(dom: str, arch: str, shape: str) -> str:
    cfg = get_config(arch)
    if dom == "collective":
        if cfg.uses_moe:
            return ("replace replicate-and-psum MoE combine with shard_map "
                    "all-to-all over the expert axis")
        return "reshard FSDP gathers: batch-axis all-gather -> per-layer rs/ag overlap"
    if dom == "memory":
        if SHAPES[shape].kind == "decode":
            return "decode is KV/weight-bandwidth bound: raise batch or quantize KV"
        return "fuse attention (Pallas flash) to cut score-matrix HBM traffic"
    if cfg.num_heads and cfg.num_heads % 16 != 0:
        return ("attention replicated over model axis (heads %% 16 != 0): "
                "shard head_dim or use sequence parallelism")
    return "raise arithmetic intensity: larger per-device matmul tiles"


def analyze(results_dir: str = "results/dryrun",
            out_csv: str = "results/roofline.csv") -> list:
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*__16x16.json"))):
        rec = json.load(open(f))
        if rec.get("arch") == "pq_step":
            continue
        if rec.get("status") != "OK":
            if rec.get("status") == "SKIP":
                rows.append({"arch": rec["arch"], "shape": rec["shape"],
                             "skip": rec.get("reason", "")})
            continue
        flops = rec["dot_flops"]
        mem_bytes = rec["dot_bytes"]
        coll = rec["collectives"].get("total", 0.0)
        t_comp = flops / PEAK_FLOPS_BF16
        t_mem = mem_bytes / HBM_BW
        t_coll = coll / ICI_BW
        dom = max((("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll)), key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(rec["arch"], rec["shape"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll, "dominant": dom,
            "model_flops_dev": mf, "hlo_flops_dev": flops,
            "useful_ratio": mf / flops if flops else float("nan"),
            "roofline_frac": t_comp / max(t_comp, t_mem, t_coll)
            if max(t_comp, t_mem, t_coll) > 0 else float("nan"),
            "arg_gib_dev": rec["memory"].get("argument_size_in_bytes", 0) / 2**30,
            "lever": lever(dom, rec["arch"], rec["shape"]),
        })
    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    keys = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
            "dominant", "model_flops_dev", "hlo_flops_dev", "useful_ratio",
            "roofline_frac", "arg_gib_dev", "lever"]
    with open(out_csv, "w") as fh:
        fh.write(",".join(keys) + "\n")
        for r in rows:
            if "skip" in r:
                fh.write(f"{r['arch']},{r['shape']},SKIP: {r['skip']}\n")
            else:
                fh.write(",".join(
                    f"{r[k]:.4e}" if isinstance(r[k], float) else str(r[k])
                    for k in keys) + "\n")
    return rows


def run(full: bool = False):
    rows = analyze()
    for r in rows:
        if "skip" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0, "SKIP")
            continue
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]) * 1e6,
             f"dom={r['dominant']};comp={r['t_compute_s']:.3e};"
             f"mem={r['t_memory_s']:.3e};coll={r['t_collective_s']:.3e};"
             f"useful={r['useful_ratio']:.3f}")
    # optimized sweep (shipped §Perf changes), if present
    if os.path.isdir("results/dryrun_opt"):
        base = {(r.get("arch"), r.get("shape")): r for r in rows
                if "skip" not in r}
        for r in analyze("results/dryrun_opt", "results/roofline_opt.csv"):
            if "skip" in r:
                continue
            b = base.get((r["arch"], r["shape"]))
            bb = max(b["t_compute_s"], b["t_memory_s"],
                     b["t_collective_s"]) if b else float("nan")
            oo = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
            emit(f"roofline_opt/{r['arch']}/{r['shape']}", oo * 1e6,
                 f"dom={r['dominant']};speedup_vs_base="
                 f"{bb / oo if oo else float('nan'):.2f}x")
