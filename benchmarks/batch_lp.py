"""Batched bound-variant LP engine benchmark — acceptance instrument
for ``repro.core.lp_batch`` (ROADMAP "batched wave LP engine").

Two workloads, each a paired flight of the SAME search with the batched
engine on and off:

* **bnb** — a many-node best-bound B&B on a tight BETWEEN window
  (thousands of nodes, every wave a flight of warm-started bound
  variants).  ``wave_width=1`` runs the bit-compatible sequential numpy
  path; ``wave_width=32`` solves each wave as one jitted dispatch.
  Gate: >= 3x wall-clock speedup AND an identical final package /
  objective on every paired flight.
* **dr_rungs** — the Dual Reducer's auxiliary-rung flight: R shrinking
  ``ub`` caps of one shared (c, A), all warm-started from lp1, solved
  ``backend="np"`` vs ``backend="jax"``.  Parity is gated lane by lane
  (status / iterations / objective / x / basis); the speedup is
  recorded, not gated — a 12-lane flight is glue-bound on one core.

Compile-cache counters are recorded (and gated) to prove the shape-class
policy holds: class count stays bounded, no per-K recompiles.

Results land in ``BENCH_batchlp.json`` at the repo root (same pattern
as ``BENCH_cache.json``).

CLI (the smoke profile is wired into CI):

    python -m benchmarks.batch_lp --smoke   # ~3.5k-node tree; asserts
    python -m benchmarks.batch_lp --full    # ~14k-node acceptance run
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.ilp import solve_ilp
from repro.core.lp import solve_lp_np
from repro.core.lp_batch import (batch_cache_stats, batch_stats,
                                 solve_lp_batch)

BENCH_PATH = Path(__file__).resolve().parent.parent / "BENCH_batchlp.json"

WAVE_W = 64
ILP_KW = dict(max_nodes=50_000, time_limit_s=600)


def _instance(seed: int, n: int, width: float):
    """Tight BETWEEN window over one synthetic gift-basket table: count
    in [15, 45], value sum in 420 +/- width.  Narrower windows make the
    LP face miss the integer lattice harder -> more B&B nodes."""
    rng = np.random.default_rng(seed)
    vals = rng.normal(14.0, 1.5, n)
    c = np.abs(rng.normal(1.0, 0.5, n))
    A = np.vstack([np.ones(n), vals])
    bl = np.array([15.0, 420.0 - width])
    bu = np.array([45.0, 420.0 + width])
    return c, A, bl, bu


def _best_of(fn, reps: int):
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _bnb(full: bool, reps: int) -> dict:
    seed, n, width = (42, 150, 0.02) if full else (42, 150, 0.05)
    c, A, bl, bu = _instance(seed, n, width)
    ub = np.ones(n)

    def solve(W):
        return solve_ilp(c, A, bl, bu, ub, wave_width=W, **ILP_KW)

    solve(1)                        # warm numpy caches
    t_seq, r_seq = _best_of(lambda: solve(1), reps)
    d0 = batch_stats()["dispatches"]
    solve(WAVE_W)                   # compile the wave's shape classes
    t_bat, r_bat = _best_of(lambda: solve(WAVE_W), reps)
    dispatches = batch_stats()["dispatches"] - d0

    assert r_seq.feasible and r_bat.feasible, (r_seq.status, r_bat.status)
    # paired-flight parity: identical final package and objective
    assert np.array_equal(r_bat.x, r_seq.x), "B&B package parity violated"
    assert abs(r_bat.obj - r_seq.obj) < 1e-9, (r_bat.obj, r_seq.obj)
    speedup = t_seq / max(t_bat, 1e-9)
    assert speedup >= 3.0, \
        f"batched wave speedup {speedup:.2f}x < 3x gate"
    print(f"bnb,{t_bat * 1e6:.0f},speedup={speedup:.2f}x "
          f"nodes={r_seq.nodes} seq={t_seq:.3f}s", flush=True)
    return {"n": n, "width": width, "wave_width": WAVE_W,
            "nodes": r_seq.nodes, "seq_s": round(t_seq, 4),
            "batched_s": round(t_bat, 4), "speedup": round(speedup, 2),
            "dispatches": dispatches, "parity": True}


def _dr_rungs(reps: int) -> dict:
    n, rungs, q = 300, 12, 25.0
    c, A, bl, bu = _instance(9, n, 2.0)
    ub = np.full(n, 3.0)
    lp1 = solve_lp_np(c, A, bl, bu, ub)
    assert lp1.status == 0, lp1.status
    E = float(np.sum(lp1.x))
    ub_variants = [np.minimum(ub, max(E / (q * 2 ** j), 1e-9))
                   for j in range(rungs)]

    def flight(backend):
        return solve_lp_batch(c, A, bl, bu, ub_variants,
                              warm_starts=[lp1] * rungs, backend=backend)

    ref = flight("np")
    t_np, _ = _best_of(lambda: flight("np"), reps)
    flight("jax")                   # compile
    t_jax, got = _best_of(lambda: flight("jax"), reps)
    for k, (a, b) in enumerate(zip(ref, got)):
        assert a.status == b.status and a.iters == b.iters, \
            f"rung {k}: status/iters diverge"
        assert np.array_equal(a.x, b.x), f"rung {k}: x diverges"
        assert np.array_equal(a.basis, b.basis), f"rung {k}: basis"
        assert abs(a.obj - b.obj) < 1e-12, f"rung {k}: obj"
    speedup = t_np / max(t_jax, 1e-9)
    print(f"dr_rungs,{t_jax * 1e6:.0f},speedup={speedup:.2f}x "
          f"rungs={rungs}", flush=True)
    return {"n": n, "rungs": rungs, "np_s": round(t_np, 5),
            "jax_s": round(t_jax, 5), "speedup": round(speedup, 2),
            "parity": True}


def run(full: bool = False) -> dict:
    # smoke's tree is ~4x smaller, so its paired timings see more
    # relative noise: take best-of-5 there to keep the 3x gate stable
    reps = 3 if full else 5
    entry = {"full": bool(full)}
    entry["bnb"] = _bnb(full, reps)
    entry["dr_rungs"] = _dr_rungs(reps)

    cache = batch_cache_stats()
    stats = batch_stats()
    # bounded shape classes: every compile landed in the LRU without
    # churn (evictions would mean the class policy degenerated into
    # per-flight recompiles)
    assert cache["size"] <= cache["maxsize"], cache
    assert cache["evictions"] == 0, cache
    entry["compile_cache"] = cache
    entry["dispatch_stats"] = stats
    print(f"compile_cache,0,classes={cache['size']} "
          f"hits={cache['hits']} misses={cache['misses']}", flush=True)

    data = {}
    if BENCH_PATH.exists():
        data = json.loads(BENCH_PATH.read_text())
    data["smoke" if not full else "full"] = entry
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    print(f"# wrote {BENCH_PATH}", flush=True)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast profile (CI gate)")
    ap.add_argument("--full", action="store_true",
                    help="many-node acceptance run")
    args = ap.parse_args()
    run(full=args.full and not args.smoke)


if __name__ == "__main__":
    main()
