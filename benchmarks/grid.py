"""Paper Table 3 (Mini-Experiment 6): augmenting size alpha x downscale
factor d_f grid — query time, partitioning time, gap, solve rate."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ILP_KW, build_engine, emit, gap, query_for, timed


def run(full: bool = False):
    n = 20_000
    alphas = (500, 2000) if not full else (500, 2000, 8000)
    dfs = (10, 20, 100) if not full else (10, 20, 100)
    hardnesses = (1, 5) if not full else (1, 3, 5, 7)
    for alpha in alphas:
        for d_f in dfs:
            eng = build_engine("sdss", n, d_f=d_f, alpha=alpha)
            _, t_part = timed(eng.partition)
            solved = 0
            gaps = []
            t_q = 0.0
            for h in hardnesses:
                q = query_for(eng, "Q1_SDSS", h)
                lp = eng.lp_bound(q)
                res, t = timed(eng.solve, q, ilp_kwargs=ILP_KW)
                t_q += t
                solved += int(res.feasible)
                g = gap(res, lp)
                if np.isfinite(g):
                    gaps.append(g)
            emit(f"table3/alpha{alpha}/df{d_f}", t_q / len(hardnesses) * 1e6,
                 f"partition_s={t_part:.2f};solve={solved}/{len(hardnesses)};"
                 f"gap={np.mean(gaps) if gaps else float('nan'):.4f}")
